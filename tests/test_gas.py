"""GAS engine: PageRank correctness + RF-driven comm accounting."""

import numpy as np

from repro.core import S5PConfig, s5p_partition, replication_factor
from repro.core.baselines import hash_partition
from repro.gas import build_gas_graph, pagerank
from repro.gas.engine import comm_stats
from repro.graphs.generators import community_graph


def _reference_pagerank(src, dst, n, iters=10):
    vals = np.ones(n)
    out_deg = np.bincount(src, minlength=n).astype(float)
    for _ in range(iters):
        contrib = np.zeros(n)
        nz = out_deg[src] > 0
        np.add.at(contrib, dst[nz], vals[src[nz]] / out_deg[src[nz]])
        vals = 0.15 + 0.85 * contrib
    return vals


def test_pagerank_matches_reference_any_partitioning():
    src, dst, n = community_graph(500, n_communities=8, avg_degree=6, seed=2)
    ref = _reference_pagerank(src, dst, n)
    for k, parts_fn in ((4, hash_partition), (4, None)):
        parts = (parts_fn(src, dst, n, k) if parts_fn
                 else s5p_partition(src, dst, n, S5PConfig(k=k)).parts)
        g = build_gas_graph(src, dst, parts, n, k)
        vals, _ = pagerank(g, iterations=10)
        np.testing.assert_allclose(np.asarray(vals), ref, rtol=1e-4)


def test_comm_volume_tracks_rf():
    """mirrors = Σ(|P(v)|−1): the replica-sync identity the paper's Fig. 11
    relies on — better RF ⇒ strictly less GAS communication."""
    src, dst, n = community_graph(1000, n_communities=16, avg_degree=8, seed=4)
    k = 8
    p_hash = hash_partition(src, dst, n, k)
    p_s5p = s5p_partition(src, dst, n, S5PConfig(k=k)).parts
    g_hash = build_gas_graph(src, dst, p_hash, n, k)
    g_s5p = build_gas_graph(src, dst, p_s5p, n, k)
    c_hash = comm_stats(g_hash).total_bytes()
    c_s5p = comm_stats(g_s5p).total_bytes()
    rf_hash = replication_factor(src, dst, p_hash, n_vertices=n, k=k)
    rf_s5p = replication_factor(src, dst, p_s5p, n_vertices=n, k=k)
    assert rf_s5p < rf_hash
    assert c_s5p < c_hash
