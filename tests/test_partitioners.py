"""System invariants for every streaming partitioner + paper-claim checks."""

import numpy as np
import pytest

from proptest import cases, random_graph
from repro.core import S5PConfig, load_balance, replication_factor, s5p_partition
from repro.core.baselines import PARTITIONERS
from repro.core.metrics import partition_loads, replica_matrix

BALANCED = {"grid", "greedy", "hdrf", "2ps-l", "clugp", "s5p", "s5p-exact"}


@pytest.mark.parametrize("name", sorted(PARTITIONERS))
@pytest.mark.parametrize("seed", list(cases(3)))
def test_every_edge_assigned_once(name, seed, parts_cache):
    src, dst, n, label = random_graph(seed)
    if len(src) == 0:
        return
    k = 4
    parts = parts_cache(name, seed, k, seed)
    valid = src != dst
    assert parts.shape == (len(src),)
    assert np.all(parts[valid] >= 0), f"{name} dropped edges on {label}"
    assert np.all(parts[valid] < k)


@pytest.mark.parametrize("name", sorted(BALANCED))
def test_balance_constraint(name, parts_cache):
    src, dst, n, _ = random_graph(1)  # community graph
    k = 4
    parts = parts_cache(name, 1, k, 1)
    loads = np.asarray(partition_loads(parts, k=k))
    E = int((src != dst).sum())
    cap = int(np.ceil(1.1 * E / k)) + 1  # τ ≈ 1 (+1 slack for ceil effects)
    assert loads.max() <= cap, f"{name}: max load {loads.max()} > {cap}"


@pytest.mark.parametrize("seed", list(cases(4)))
def test_rf_bounds(seed):
    src, dst, n, _ = random_graph(seed)
    if (src != dst).sum() == 0:
        return
    k = 4
    out = s5p_partition(src, dst, n, S5PConfig(k=k))
    rf = replication_factor(src, dst, out.parts, n_vertices=n, k=k)
    assert 1.0 <= rf <= k + 1e-6
    # RF(v) can also never exceed v's degree
    mat = np.asarray(replica_matrix(src, dst, out.parts, n_vertices=n, k=k))
    deg = np.bincount(src, minlength=n) + np.bincount(dst, minlength=n)
    assert np.all(mat.sum(1) <= np.maximum(deg, 1))


def test_s5p_beats_baselines_on_community_graph():
    """The paper's headline claim (Table 3) in miniature: S5P wins on
    skewed, community-structured graphs at equal balance.

    Asserted as a *mean over 3 partitioner seeds* — the Table-3 claim is
    about the method, not one lucky draw of the game's damping RNG.
    """
    from repro.graphs.generators import community_graph

    src, dst, n = community_graph(3000, n_communities=48, avg_degree=8, seed=7)
    k = 8
    seeds = (0, 1, 2)

    def mean_rf(name):
        rfs = []
        # hdrf / 2ps-l are deterministic in the partitioner seed — one run
        for s in seeds if name in ("clugp", "s5p") else seeds[:1]:
            parts = PARTITIONERS[name](src, dst, n, k, s)
            assert load_balance(parts, k=k) <= 1.11, (name, s)
            rfs.append(replication_factor(src, dst, parts, n_vertices=n, k=k))
        return float(np.mean(rfs))

    rf = {name: mean_rf(name) for name in ("hdrf", "2ps-l", "clugp", "s5p")}
    assert rf["s5p"] < rf["hdrf"], rf
    assert rf["s5p"] < rf["2ps-l"], rf
    assert rf["s5p"] < rf["clugp"], rf


def test_two_stage_beats_one_stage(community_bench_graph, s5p_exact_community):
    """Fig. 7(d): the Stackelberg (two-stage) game ≤ one-stage RF."""
    src, dst, n = community_bench_graph
    k = 8
    two = s5p_exact_community
    one = s5p_partition(src, dst, n, S5PConfig(k=k, use_cms=False, one_stage=True))
    rf2 = replication_factor(src, dst, two.parts, n_vertices=n, k=k)
    rf1 = replication_factor(src, dst, one.parts, n_vertices=n, k=k)
    assert rf2 <= rf1 * 1.05, (rf2, rf1)


def test_cms_vs_exact_rf_close(community_bench_graph, s5p_exact_community):
    """Fig. 9: sketch-backed Θ costs ≲1% RF vs exact counts."""
    src, dst, n = community_bench_graph
    k = 8
    exact = s5p_exact_community
    cms = s5p_partition(src, dst, n, S5PConfig(k=k, use_cms=True))
    rf_e = replication_factor(src, dst, exact.parts, n_vertices=n, k=k)
    rf_c = replication_factor(src, dst, cms.parts, n_vertices=n, k=k)
    assert abs(rf_c - rf_e) / rf_e < 0.10
    assert cms.aux["sketch_bytes"] < cms.aux["exact_count_bytes"] * 2


def test_s5p_b_bounded_variant_runs():
    src, dst, n, _ = random_graph(1)
    out = s5p_partition(src, dst, n, S5PConfig(k=4, bounded=True))
    parts = np.asarray(out.parts)
    assert np.all(parts[np.asarray(src != dst)] >= 0)


def test_determinism():
    src, dst, n, _ = random_graph(0)
    a = s5p_partition(src, dst, n, S5PConfig(k=4, seed=9)).parts
    b = s5p_partition(src, dst, n, S5PConfig(k=4, seed=9)).parts
    assert np.array_equal(np.asarray(a), np.asarray(b))
