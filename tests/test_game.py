"""Stackelberg game: equilibrium, potential descent, PoA sanity."""

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases
from repro.core.game import (
    GameInputs, best_response_gap, compute_delta, init_assignment, run_game,
    social_welfare, _cluster_degrees,
)


def _random_inputs(seed, n_clusters=40, k=4, n_head=8):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 50, n_clusters).astype(np.float32)
    n_pairs = n_clusters * 3
    a = rng.integers(0, n_clusters, n_pairs)
    b = rng.integers(0, n_clusters, n_pairs)
    keep = a != b
    a, b = np.minimum(a, b)[keep], np.maximum(a, b)[keep]
    w = rng.integers(1, 10, a.size).astype(np.float32)
    return GameInputs(
        sizes=jnp.asarray(sizes), pair_a=jnp.asarray(a, jnp.int32),
        pair_b=jnp.asarray(b, jnp.int32), pair_w=jnp.asarray(w),
        n_head=n_head, k=k,
    ), n_clusters


@pytest.mark.parametrize("seed", list(cases(6)))
def test_converged_game_is_nash(seed):
    inputs, C = _random_inputs(seed)
    res = run_game(inputs, C, batch_size=1, max_rounds=200, accept_prob=1.0)
    assert bool(res.converged)
    gap = float(best_response_gap(inputs, res.assignment, C))
    assert gap <= 1e-4, f"equilibrium violated: gap={gap}"


@pytest.mark.parametrize("seed", list(cases(4, 50)))
def test_batched_game_converges_and_is_nash(seed):
    inputs, C = _random_inputs(seed, n_clusters=60)
    res = run_game(inputs, C, batch_size=16, max_rounds=300, accept_prob=0.7)
    assert bool(res.converged)
    gap = float(best_response_gap(inputs, res.assignment, C))
    assert gap <= 1e-4


def test_game_reduces_social_welfare():
    inputs, C = _random_inputs(0)
    degs = _cluster_degrees(inputs, C)
    delta = compute_delta(inputs.sizes, degs, inputs.k)
    init = jnp.asarray(init_assignment(np.asarray(inputs.sizes), inputs.k))
    s0 = float(social_welfare(inputs, init, delta))
    res = run_game(inputs, C, batch_size=1, max_rounds=200, accept_prob=1.0)
    s1 = float(social_welfare(inputs, res.assignment, delta))
    assert s1 <= s0 + 1e-5


def test_leaders_move_first():
    """With one round budget, only a full leader+follower sweep happens —
    sanity that the two-stage structure is wired (no crash, legal output)."""
    inputs, C = _random_inputs(1)
    res = run_game(inputs, C, batch_size=8, max_rounds=1)
    assign = np.asarray(res.assignment)
    assert assign.shape == (C,)
    assert assign.min() >= 0 and assign.max() < inputs.k


def test_delta_in_paper_range():
    """Eq. (11): 1/Σ|c| ≤ δ ≤ k·Σ(F+|c|)/(Σ|c|)²."""
    inputs, C = _random_inputs(2)
    degs = _cluster_degrees(inputs, C)
    delta = float(compute_delta(inputs.sizes, degs, inputs.k))
    total = float(jnp.sum(inputs.sizes))
    lo = 1.0 / total
    hi = inputs.k * float(jnp.sum(degs + inputs.sizes)) / total**2
    assert lo <= delta <= hi + 1e-9
