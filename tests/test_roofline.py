"""Roofline HLO parser: loop-trip correction verified against unrolled HLO."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analysis import parse_hlo_costs


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    """A scanned matmul stack must report ≈ the unrolled flops (±10%)."""
    L, n = 8, 128
    w = jnp.ones((L, n, n), jnp.float32)
    x = jnp.ones((4, n), jnp.float32)

    def scanned(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), ()
        h, _ = jax.lax.scan(body, x, w)
        return jnp.sum(h)

    def unrolled(w, x):
        h = x
        for i in range(L):
            h = jnp.tanh(h @ w[i])
        return jnp.sum(h)

    c_scan = parse_hlo_costs(_compile(scanned, w, x))
    c_unroll = parse_hlo_costs(_compile(unrolled, w, x))
    assert c_unroll["flops"] > 0
    assert c_scan["max_trip"] == L
    ratio = c_scan["flops"] / c_unroll["flops"]
    assert 0.9 < ratio < 1.1, (c_scan["flops"], c_unroll["flops"])


def test_dot_flops_exact():
    a = jnp.ones((64, 128), jnp.float32)
    b = jnp.ones((128, 32), jnp.float32)
    c = parse_hlo_costs(_compile(lambda a, b: a @ b, a, b))
    assert c["flops"] == 2 * 64 * 128 * 32


def test_no_collectives_single_device():
    a = jnp.ones((16, 16), jnp.float32)
    c = parse_hlo_costs(_compile(lambda a: a @ a, a))
    assert c["collective_bytes"] == 0


def test_bytes_reasonable_for_copy():
    """Elementwise op traffic ≈ read + write of the array (±2×)."""
    a = jnp.ones((1024, 1024), jnp.float32)  # 4 MB
    c = parse_hlo_costs(_compile(lambda a: a * 2.0 + 1.0, a))
    assert 4e6 < c["hbm_bytes"] < 2.5e7
