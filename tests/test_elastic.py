"""Elastic controller + label propagation on the GAS engine."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import S5PConfig, s5p_partition
from repro.gas import build_gas_graph
from repro.gas.engine import label_propagation
from repro.graphs.generators import community_graph
from repro.optim import AdamWConfig, adamw_update, init_state
from repro.runtime import ElasticController


def test_elastic_resize_preserves_state(tmp_path):
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = init_state({"w": jnp.arange(4.0)})
    for _ in range(3):
        state = adamw_update(state, {"w": jnp.ones(4)}, cfg)
    manager = CheckpointManager(tmp_path, keep=2, async_write=False)
    calls = []
    controller = ElasticController(
        manager,
        make_mesh=lambda n: jax.make_mesh((1,), ("data",)),
        repartition=lambda k: calls.append(k) or k,
    )
    new_state, mesh, parts, step = controller.resize(state, 3, 7)
    assert calls == [7]
    np.testing.assert_array_equal(np.asarray(new_state.params["w"]),
                                  np.asarray(state.params["w"]))
    np.testing.assert_array_equal(np.asarray(new_state.mu["w"]),
                                  np.asarray(state.mu["w"]))
    assert step == 3


def test_label_propagation_components():
    """Two disjoint communities → two final labels, any partitioning."""
    rng = np.random.default_rng(0)
    # two cliques of 20, no cross edges
    edges = []
    for base in (0, 20):
        for i in range(20):
            for j in range(i + 1, 20):
                if rng.random() < 0.4:
                    edges.append((base + i, base + j))
    # ensure connectivity with a path
    for base in (0, 20):
        for i in range(19):
            edges.append((base + i, base + i + 1))
    src = np.array([e[0] for e in edges], np.int32)
    dst = np.array([e[1] for e in edges], np.int32)
    n = 40
    parts = s5p_partition(src, dst, n, S5PConfig(k=4)).parts
    g = build_gas_graph(src, dst, parts, n, 4)
    labels, stats = label_propagation(g, iterations=25)
    labels = np.asarray(labels)
    assert len(set(labels[:20].tolist())) == 1
    assert len(set(labels[20:].tolist())) == 1
    assert labels[0] != labels[20]
    assert stats.total_bytes() > 0
