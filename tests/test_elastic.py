"""Elastic controller + k→k′ resharding + label propagation.

The reshard layer (``repro.elastic``) is the tentpole under test here:

- *bundle reshard* — grow keeps every placement that survives (bounded
  migration), shrink displaces exactly the dead partitions' edges; both
  land with a consistent load vector, in-range parts, and a k′-era κ so
  the window chain keeps absorbing deltas;
- *game migration cost* — ``move_cost=0`` is bitwise the plain masked
  game (the goldens' guarantee extends to the new operands), and a large
  cost freezes every cluster at home;
- *scan-carry reshard* — greedy/HDRF carries grow with zero migration
  and shrink through the exact retract algebra; grid is k-bound and
  refuses;
- *elastic controller* — a warm ``ElasticPartition`` resize rides the
  checkpoint→mesh→reshard flow, state leaves bitwise intact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import S5PConfig, replication_factor, s5p_partition
from repro.core import game as _game
from repro.elastic import ReshardResult, reshard_bundle, reshard_carry
from repro.gas import build_gas_graph
from repro.gas.engine import label_propagation
from repro.graphs.generators import community_graph
from repro.incremental import s5p_apply_delta, s5p_cold_bundle
from repro.kernels.stream_scan import GreedyCarry, GridCarry, HdrfCarry
from repro.optim import AdamWConfig, adamw_update, init_state
from repro.runtime import ElasticController, ElasticPartition
from repro.streaming import EdgeStream, run_carry


K = 8


def _warm_bundle(seed=0, k=K):
    src, dst, n = community_graph(800, n_communities=16, avg_degree=6,
                                  p_intra=0.9, seed=seed)
    cfg = S5PConfig(k=k, seed=seed, chunk_size=512)
    _, bundle = s5p_cold_bundle(src, dst, n, cfg)
    return src, dst, n, cfg, bundle


def _check_invariants(bundle, res, src, dst, n):
    """Reshard postconditions every path must satisfy."""
    k = res.k_new
    parts = np.asarray(bundle["parts"], np.int32)
    alive = np.asarray(bundle["alive"], bool)
    placed = alive & (parts >= 0)
    assert parts[placed].max() < k
    # the load vector is exactly the placed-parts histogram
    hist = np.bincount(parts[placed], minlength=k)
    np.testing.assert_array_equal(np.asarray(bundle["load"]), hist)
    assert np.asarray(bundle["c2p"]).max() < k
    assert res.rf == pytest.approx(float(replication_factor(
        src[np.asarray(bundle["arrival"])[placed]],
        dst[np.asarray(bundle["arrival"])[placed]],
        parts[placed], n_vertices=n, k=k)))


# ================================================== bundle reshard
def test_reshard_grow_bounded_migration():
    src, dst, n, cfg, bundle = _warm_bundle()
    old_parts = np.asarray(bundle["parts"], np.int32).copy()
    old_c2p = np.asarray(bundle["c2p"], np.int32).copy()
    b2, cfg2, res = reshard_bundle(bundle, cfg, 12, src, dst)
    assert cfg2.k == 12 and res.k_old == K and res.k_new == 12
    _check_invariants(b2, res, src, dst, n)
    assert res.n_displaced == 0  # grow never displaces
    assert res.migrated_fraction < 1.0  # bounded: survivors stayed
    # kept-edge stability: an edge whose clusters the game left home
    # keeps its exact placement
    moved_c = np.asarray(b2["c2p"], np.int32) != old_c2p
    cu = np.asarray(b2["edge_cu"], np.int32)
    cv = np.asarray(b2["edge_cv"], np.int32)
    stable = (~moved_c[np.maximum(cu, 0)]) & (~moved_c[np.maximum(cv, 0)])
    parts = np.asarray(b2["parts"], np.int32)
    np.testing.assert_array_equal(parts[stable], old_parts[stable])
    # input bundle untouched (reshard copies)
    np.testing.assert_array_equal(
        np.asarray(bundle["parts"], np.int32), old_parts)


def test_reshard_shrink_displaces_dead_partitions():
    src, dst, n, cfg, bundle = _warm_bundle(1)
    old_parts = np.asarray(bundle["parts"], np.int32).copy()
    b2, cfg2, res = reshard_bundle(bundle, cfg, 4, src, dst)
    _check_invariants(b2, res, src, dst, n)
    alive = np.asarray(bundle["alive"], bool)
    want_displaced = int(np.count_nonzero(
        alive & (old_parts >= 4)))
    assert res.n_displaced == want_displaced > 0
    assert res.migrated_edges >= res.n_displaced  # displaced must move
    assert res.migrated_fraction < 1.0


def test_reshard_noop_and_validation():
    src, dst, n, cfg, bundle = _warm_bundle(2)
    b2, cfg2, res = reshard_bundle(bundle, cfg, K, src, dst)
    assert res.migrated_edges == 0 and res.game_rounds == 0
    np.testing.assert_array_equal(np.asarray(b2["parts"]),
                                  np.asarray(bundle["parts"]))
    with pytest.raises(ValueError, match="k_new"):
        reshard_bundle(bundle, cfg, 0, src, dst)


def test_resharded_bundle_keeps_absorbing_deltas():
    """The k′ bundle drops back into the delta pipeline: κ was re-derived
    at k′ so the advisory must not trip from the resize alone, and the
    fold itself places every new edge in range."""
    src, dst, n = community_graph(800, n_communities=16, avg_degree=6,
                                  p_intra=0.9, seed=3)
    E0 = int(src.size * 0.95)  # small delta: drift comes only from |E|
    cfg = S5PConfig(k=K, seed=0, chunk_size=512)
    _, bundle = s5p_cold_bundle(src[:E0], dst[:E0], n, cfg)
    b2, cfg2, _ = reshard_bundle(bundle, cfg, 12, src[:E0], dst[:E0])
    b3, res = s5p_apply_delta(b2, cfg2, src, dst, E0)
    assert not res.needs_cold_restart
    assert np.all(res.parts[E0:] >= 0)
    assert np.all(res.parts[E0:] < 12)


def test_freeze_at_high_move_cost():
    """move_cost_scale → ∞ pins every survivor: migration is exactly the
    displaced set (zero on grow)."""
    src, dst, n, cfg, bundle = _warm_bundle(4)
    _, _, res = reshard_bundle(bundle, cfg, 12, src, dst,
                               move_cost_scale=1e9)
    assert res.migrated_edges == 0 and res.moved_clusters == 0


# ================================================== game move_cost payoff
def _game_fixture(seed=0):
    src, dst, n, cfg, bundle = _warm_bundle(seed)
    sizes = np.asarray(bundle["sizes"], np.float32)
    inputs = _game.GameInputs(
        sizes=jnp.asarray(sizes),
        pair_a=jnp.asarray(bundle["pair_a"], jnp.int32),
        pair_b=jnp.asarray(bundle["pair_b"], jnp.int32),
        pair_w=jnp.asarray(bundle["pair_w"], jnp.float32),
        n_head=0, k=K)
    C = sizes.shape[0]
    rng = np.random.default_rng(seed)
    assign0 = rng.integers(0, K, C).astype(np.int32)
    leader = np.asarray(bundle["comb_is_head"], bool)
    return inputs, C, assign0, leader, sizes


def test_game_zero_move_cost_bitwise_noop():
    """The migration payoff with all-zero costs is bitwise the plain
    masked game — the pinned-golden guarantee extends across the new
    operands."""
    inputs, C, assign0, leader, sizes = _game_fixture()
    kw = dict(batch_size=_game.default_batch_size(0, C), max_rounds=6,
              assign0=assign0, seed=7, leader_mask=leader)
    base = _game.run_game(inputs, C, **kw)
    zeroed = _game.run_game(inputs, C, **kw,
                            move_cost=np.zeros(C, np.float32),
                            home=assign0)
    np.testing.assert_array_equal(np.asarray(base.assignment),
                                  np.asarray(zeroed.assignment))
    assert base.rounds == zeroed.rounds


def test_game_huge_move_cost_freezes_home():
    inputs, C, assign0, leader, sizes = _game_fixture(1)
    res = _game.run_game(
        inputs, C, batch_size=_game.default_batch_size(0, C), max_rounds=6,
        assign0=assign0, seed=7, leader_mask=leader,
        move_cost=np.full(C, 1e9, np.float32), home=assign0)
    np.testing.assert_array_equal(np.asarray(res.assignment), assign0)


# ================================================== scan-carry reshard
@pytest.mark.parametrize("name", ["greedy", "hdrf"])
@pytest.mark.parametrize("k_new", [12, 4])
def test_reshard_scan_carry(name, k_new):
    src, dst, n = community_graph(600, n_communities=8, avg_degree=5,
                                  seed=5)
    make = (lambda k: GreedyCarry(n, k)) if name == "greedy" else \
        (lambda k: HdrfCarry(n, k, 1.1))
    st = EdgeStream(src, dst, n, chunk_size=256)
    parts, carry = run_carry(st, make(K))
    parts = np.asarray(parts)
    new_carry, new_parts, res = reshard_carry(
        make(k_new), k_new, src, dst, parts, carry=carry)
    assert isinstance(res, ReshardResult)
    assert new_parts.min() >= 0 and new_parts.max() < k_new
    # carry load is exactly the new parts histogram
    np.testing.assert_array_equal(
        np.asarray(new_carry[0]), np.bincount(new_parts, minlength=k_new))
    if k_new > K:  # grow: nothing moves at all
        assert res.migrated_edges == 0
        np.testing.assert_array_equal(new_parts, parts)
    else:  # shrink: exactly the displaced set moved
        assert res.n_displaced == int(np.count_nonzero(parts >= k_new))
        moved = new_parts != parts
        assert res.migrated_edges == int(np.count_nonzero(moved))
        np.testing.assert_array_equal(moved, parts >= k_new)


def test_reshard_grid_carry_refuses():
    rng = np.random.default_rng(0)
    n = 64
    pc = GridCarry(4, rng.integers(0, 2, n).astype(np.int32),
                   rng.integers(0, 2, n).astype(np.int32), 2)
    with pytest.raises(ValueError, match="grid"):
        reshard_carry(pc, 8, np.zeros(4, np.int32), np.ones(4, np.int32),
                      np.zeros(4, np.int32), carry=pc.init())


# ================================================== elastic controller
def test_elastic_partition_warm_resize():
    src, dst, n, cfg, bundle = _warm_bundle(6)
    part = ElasticPartition(bundle, cfg, src, dst)
    assert part.k == K
    p0 = part.parts
    assert p0.shape == (src.size,) and p0.max() < K
    res = part.resize(12)
    assert part.k == 12 and res.k_new == 12
    p1 = part.parts
    assert p1.max() < 12
    assert np.count_nonzero(p1 != p0) == res.migrated_edges
    # shrink back down through the same object
    res2 = part.resize(4)
    assert part.k == 4 and part.parts.max() < 4
    assert res2.migrated_fraction < 1.0


def test_elastic_controller_warm_resize_roundtrip(tmp_path):
    """Satellite: the full elastic flow — checkpoint, mesh, warm
    reshard, reshard_state — returns bitwise-identical leaves on the
    host mesh and the warm ReshardResult."""
    src, dst, n, cfg, bundle = _warm_bundle(7)
    part = ElasticPartition(bundle, cfg, src, dst)
    cfg_o = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = init_state({"w": jnp.arange(6.0), "b": jnp.ones((2, 3))})
    for _ in range(2):
        state = adamw_update(
            state, {"w": jnp.ones(6), "b": jnp.ones((2, 3))}, cfg_o)
    mesh = jax.make_mesh((1,), ("data",))
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec())
    controller = ElasticController(
        CheckpointManager(tmp_path, keep=2, async_write=False),
        make_mesh=lambda size: mesh,
        make_shardings=lambda m: jax.tree.map(lambda _: sharding, state),
        partition=part)
    new_state, out_mesh, res, step = controller.resize(state, 5, 12)
    assert step == 5 and out_mesh is mesh
    assert isinstance(res, ReshardResult) and res.k_new == 12
    assert part.k == 12
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(new_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert b.sharding.is_equivalent_to(sharding, np.ndim(b))


def test_elastic_resize_preserves_state(tmp_path):
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = init_state({"w": jnp.arange(4.0)})
    for _ in range(3):
        state = adamw_update(state, {"w": jnp.ones(4)}, cfg)
    manager = CheckpointManager(tmp_path, keep=2, async_write=False)
    calls = []
    controller = ElasticController(
        manager,
        make_mesh=lambda n: jax.make_mesh((1,), ("data",)),
        repartition=lambda k: calls.append(k) or k,
    )
    new_state, mesh, parts, step = controller.resize(state, 3, 7)
    assert calls == [7]
    np.testing.assert_array_equal(np.asarray(new_state.params["w"]),
                                  np.asarray(state.params["w"]))
    np.testing.assert_array_equal(np.asarray(new_state.mu["w"]),
                                  np.asarray(state.mu["w"]))
    assert step == 3


def test_label_propagation_components():
    """Two disjoint communities → two final labels, any partitioning."""
    rng = np.random.default_rng(0)
    # two cliques of 20, no cross edges
    edges = []
    for base in (0, 20):
        for i in range(20):
            for j in range(i + 1, 20):
                if rng.random() < 0.4:
                    edges.append((base + i, base + j))
    # ensure connectivity with a path
    for base in (0, 20):
        for i in range(19):
            edges.append((base + i, base + i + 1))
    src = np.array([e[0] for e in edges], np.int32)
    dst = np.array([e[1] for e in edges], np.int32)
    n = 40
    parts = s5p_partition(src, dst, n, S5PConfig(k=4)).parts
    g = build_gas_graph(src, dst, parts, n, 4)
    labels, stats = label_propagation(g, iterations=25)
    labels = np.asarray(labels)
    assert len(set(labels[:20].tolist())) == 1
    assert len(set(labels[20:].tolist())) == 1
    assert labels[0] != labels[20]
    assert stats.total_bytes() > 0
