"""Live partition serving: atomic swaps, churn-path bugfixes, GAS reads.

Layers:

1. *BundleRegistry* — pin/publish atomicity under real thread churn: a
   writer swaps versions while readers pin and fingerprint-check; no
   reader ever observes a torn bundle, versions retire exactly when the
   last pin drops.
2. *Serving smoke* (tier-1 gate) — small block graph, S5P window chain
   through controller + GAS server: ≥ 2 atomic swaps under churn and
   exact byte counters (independently recomputed from the replica sets).
3. *Correctness under churn* — served PageRank (values carried across
   swaps) converges to the same fixed point as a from-scratch run on the
   final window; GNN / label-propagation queries execute over pinned
   bundles.
4. *Churn-path regressions* — the three bugfix satellites:
   slot compaction frees tombstones without perturbing the partition or
   breaking resumed streams / CarryStore checkpoints (tombstone leak);
   ``needs_cold_restart`` is acted on (chain auto-restart and controller
   ``request_cold_restart``), landing as one more atomic swap;
   deletion batches shard through ``run_parallel`` bit-identically to
   the sequential retraction (lane-masked retraction).
"""

from __future__ import annotations

import threading

import jax
import numpy as np
import pytest

from repro.core import S5PConfig, replication_factor
from repro.gas import build_gas_graph, pagerank
from repro.graphs import block_rmat_graph, community_graph
from repro.incremental import (
    S5PWindowChain,
    compact_edge_slots,
    s5p_apply_deletion,
    s5p_apply_delta,
    s5p_cold_bundle,
    s5p_identity_config,
)
from repro.incremental.store import CarryStore
from repro.kernels.stream_scan import GreedyCarry, GridCarry, HdrfCarry
from repro.serving import (
    BundleRegistry,
    GASServer,
    ServingController,
    build_bundle,
)
from repro.streaming import EdgeStream, run_carry, run_retract

K = 4


def _leaves(c):
    return jax.tree_util.tree_leaves(c)


def _tree_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(_leaves(a), _leaves(b)))


def _small_graph(seed=0):
    return community_graph(512, n_communities=8, avg_degree=6,
                           p_intra=0.9, seed=seed)


def _serve_chain(src, dst, n, *, window, step, k=K, seed=0,
                 supersteps_per_swap=2, auto_cold_restart=True):
    cfg = S5PConfig(k=k, seed=seed, chunk_size=max(window, 256))
    chain = S5PWindowChain(src, dst, n, cfg, window, step_edges=step,
                           auto_cold_restart=auto_cold_restart)
    registry = BundleRegistry()
    controller = ServingController(registry, chain)
    server = GASServer(registry)
    rng = np.random.default_rng(seed)
    last = -1
    while controller.step() is not None:
        if registry.current_version == last:
            continue
        last = registry.current_version
        server.run(supersteps_per_swap)
        server.query_pagerank(rng.integers(0, n, 8))
    return server, controller, registry


# ================================================ 1. registry atomicity
def test_registry_pin_refcount_and_retirement():
    src, dst, n = _small_graph()
    reg = BundleRegistry()
    assert reg.current is None
    with reg.pin() as b:
        assert b is None
    parts = np.zeros(src.size, np.int32)
    reg.publish(build_bundle(1, src, dst, parts, n, K))
    assert reg.swap_count == 0 and reg.current_version == 1
    with reg.pin() as b1:
        b1.check()
        reg.publish(build_bundle(2, src, dst, parts, n, K))
        # superseded version stays valid while pinned
        assert reg.swap_count == 1 and reg.versions_retired == 0
        b1.check()
        assert b1.version == 1
    assert reg.versions_retired == 1  # retired when the last pin dropped
    with reg.pin() as b2:
        assert b2.version == 2
    assert reg.active_pins == 0


def test_registry_swap_atomicity_under_thread_churn():
    """Readers pinning during concurrent publishes never see a torn
    bundle, and versions advance monotonically per reader."""
    src, dst, n = _small_graph()
    rng = np.random.default_rng(0)
    reg = BundleRegistry()
    stop = threading.Event()
    errors: list[BaseException] = []

    def reader():
        seen = -1
        try:
            while not stop.is_set():
                with reg.pin() as b:
                    if b is None:
                        continue
                    b.check()  # fingerprint: src/dst/parts one version
                    assert b.version >= seen
                    seen = b.version
                    # consistent shapes (a torn mix would desync these)
                    assert b.parts.shape == b.src.shape == b.dst.shape
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    for v in range(1, 30):
        m = int(rng.integers(50, src.size))
        parts = rng.integers(0, K, m).astype(np.int32)
        reg.publish(build_bundle(v, src[:m], dst[:m], parts, n, K))
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors
    assert reg.swap_count == 28
    assert reg.active_pins == 0
    # every superseded version eventually retired
    assert reg.versions_retired == 28


def test_bundle_fingerprint_detects_tear():
    src, dst, n = _small_graph()
    b = build_bundle(1, src, dst, np.zeros(src.size, np.int32), n, K)
    b.check()
    torn = b._replace(parts=np.ones(src.size, np.int32))
    with pytest.raises(AssertionError, match="torn"):
        torn.check()


# ================================================ 2. serving smoke (tier-1)
def test_serving_smoke_two_swaps_and_exact_bytes():
    src, dst, n = block_rmat_graph(block_scale=5, n_blocks=8,
                                   edge_factor=6, seed=0)
    E = src.size
    server, controller, reg = _serve_chain(src, dst, n, window=E // 2,
                                           step=E // 6)
    s = server.metrics.summary()
    assert s["swaps_observed"] >= 2
    assert controller.version >= 3
    assert reg.active_pins == 0
    # byte counters are exact: recompute the final version's mirror set
    # independently of the GAS layout
    b = reg.current
    b.check()
    key = np.stack([np.concatenate([b.src, b.dst]),
                    np.concatenate([b.parts, b.parts])], axis=1)
    replicas = np.unique(key, axis=0)
    counts = np.bincount(replicas[:, 0], minlength=n)
    mirrors = int(np.maximum(counts - 1, 0).sum())
    assert b.bytes_per_superstep() == 2 * mirrors * 8
    last = server.metrics.supersteps[-1]
    assert last.version == b.version
    assert last.sync_bytes == 2 * mirrors * 8
    # every super-step pinned exactly one version whose counters it used
    assert s["sync_bytes_total"] == sum(
        r.sync_bytes for r in server.metrics.supersteps)
    assert s["query_latency_us_mean"] > 0


# ================================================ 3. correctness under churn
def test_pagerank_under_churn_matches_from_scratch():
    """Values carried across swaps converge to the same fixed point as a
    cold run over the same final window."""
    src, dst, n = _small_graph(3)
    E = src.size
    server, controller, reg = _serve_chain(src, dst, n, window=E // 2,
                                           step=E // 4)
    assert server.metrics.swaps_observed >= 1
    server.run_to_convergence(tol=1e-7, max_steps=300)
    b = reg.current
    cold_vals, _ = pagerank(b.gas, iterations=300)
    np.testing.assert_allclose(np.asarray(server.values),
                               np.asarray(cold_vals), rtol=1e-3, atol=1e-5)


def test_queries_over_pinned_bundle():
    from repro.models.gnn import GCNConfig, gcn_forward, gcn_init

    src, dst, n = _small_graph(4)
    reg = BundleRegistry()
    parts = (src % K).astype(np.int32)
    reg.publish(build_bundle(1, src, dst, parts, n, K))
    server = GASServer(reg)
    server.run(3)
    vals = server.query_pagerank([0, 1, 2])
    assert vals.shape == (3,) and np.all(np.isfinite(vals))
    labels = server.query_components(iterations=3)
    assert labels.shape == (n,)
    cfg = GCNConfig(n_layers=2, d_hidden=8, d_feat=4, n_classes=3)
    params = gcn_init(cfg, jax.random.PRNGKey(0))
    feats = jax.random.normal(jax.random.PRNGKey(1), (n, 4))
    logits = server.query_gnn(params, feats, cfg, vertices=[0, 5])
    assert logits.shape == (2, 3) and np.all(np.isfinite(logits))
    want = np.asarray(gcn_forward(params, feats, src, dst, n, cfg))[[0, 5]]
    np.testing.assert_allclose(logits, want, rtol=1e-5, atol=1e-6)
    assert len(server.metrics.query_latency_us) == 3


# ================================================ 4a. tombstone leak fixed
def test_slot_compaction_frees_tombstones_and_stream_resumes():
    """Compaction drops dead slots, keeps the arrival map stable: the
    same stream position still folds the same delta afterwards, and
    re-deleting a compacted-away arrival still raises."""
    src, dst, n = _small_graph(5)
    E = src.size
    E0 = int(E * 0.6)
    cfg = S5PConfig(k=K, seed=0, chunk_size=256)
    _, bundle = s5p_cold_bundle(src[:E0], dst[:E0], n, cfg)
    dead = np.arange(0, E0 // 3, dtype=np.int64)
    bundle, _ = s5p_apply_deletion(bundle, cfg, src[:E0], dst[:E0], dead)
    twin = {k2: (np.array(v) if isinstance(v, np.ndarray) else v)
            for k2, v in bundle.items()}

    bundle, n_freed = compact_edge_slots(bundle)
    assert n_freed == dead.size
    assert np.asarray(bundle["parts"]).shape[0] == E0 - dead.size
    assert bool(np.asarray(bundle["alive"]).all())
    assert int(bundle["stream_pos"]) == E0  # stream position unmoved

    # compacted-away arrivals are still "already deleted", not aliased
    with pytest.raises(ValueError, match="already deleted"):
        s5p_apply_deletion(bundle, cfg, src[:E0], dst[:E0], dead[:4])

    # the resumed stream folds identically with and without compaction
    b1, r1 = s5p_apply_delta(bundle, cfg, src, dst, E0)
    b2, r2 = s5p_apply_delta(twin, cfg, src, dst, E0)
    np.testing.assert_array_equal(r1.parts, r2.parts)
    assert r1.parts.shape == (E,)
    assert np.all(r1.parts[dead] == -1)
    assert np.all(r1.parts[E0:] >= 0)
    assert r1.rf == pytest.approx(r2.rf)


def test_compacted_bundle_carrystore_roundtrip(tmp_path):
    src, dst, n = _small_graph(6)
    E = src.size
    E0 = int(E * 0.7)
    cfg = S5PConfig(k=K, seed=0, chunk_size=256)
    _, bundle = s5p_cold_bundle(src[:E0], dst[:E0], n, cfg)
    bundle, _ = s5p_apply_deletion(
        bundle, cfg, src[:E0], dst[:E0], np.arange(E0 // 4, dtype=np.int64))
    bundle, n_freed = compact_edge_slots(bundle)
    assert n_freed > 0
    store = CarryStore(tmp_path)
    store.save(bundle, consumer="s5p", config=s5p_identity_config(cfg),
               stream_pos=int(bundle["stream_pos"]))
    loaded, meta = store.load(consumer="s5p",
                              config=s5p_identity_config(cfg),
                              max_stream_pos=E)
    assert int(meta["stream_pos"]) == E0
    for key in ("arrival", "parts", "alive", "stream_pos"):
        np.testing.assert_array_equal(np.asarray(loaded[key]),
                                      np.asarray(bundle[key]), err_msg=key)
    _, res = s5p_apply_delta(loaded, cfg, src, dst, E0)
    assert res.parts.shape == (E,)
    assert np.all(res.parts[E0:] >= 0)


def test_window_chain_slot_compaction_bounds_memory():
    """With aggressive slot compaction the chain's per-edge arrays stay
    O(window) while the uncompacted twin grows O(arrivals) — and the
    live partition itself is unchanged."""
    src, dst, n = _small_graph(7)
    E = src.size
    W, B = E // 4, E // 8
    cfg = S5PConfig(k=K, seed=0, chunk_size=max(W, 256))
    lean = S5PWindowChain(src, dst, n, cfg, W, step_edges=B,
                          slot_compact_factor=1.5)
    fat = S5PWindowChain(src, dst, n, cfg, W, step_edges=B,
                         slot_compact_factor=0.0)
    freed = 0
    while True:
        a, b = lean.step(), fat.step()
        assert (a is None) == (b is None)
        if a is None:
            break
        freed += a.n_slots_freed
        sa, sb = lean.live_partition(), fat.live_partition()
        assert (sa is None) == (sb is None)
        if sa is not None:
            for x, y in zip(sa, sb):
                np.testing.assert_array_equal(x, y)
    assert freed > 0
    n_live = int(np.asarray(lean.bundle["alive"]).sum())
    assert np.asarray(lean.bundle["parts"]).shape[0] <= 1.5 * max(n_live, 1)
    assert np.asarray(fat.bundle["parts"]).shape[0] == E


# ================================================ 4b. cold restart acted on
def test_auto_cold_restart_acts_and_swaps():
    """``needs_cold_restart`` is no longer advisory-only: with
    ``auto_cold_restart=True`` the chain re-partitions the live window
    and the controller publishes the result as one more atomic swap.

    A fixed-size window never drifts ξ organically (ξ is a function of
    |E|/|V|, both window-constant), so the advisory trigger is forced
    via a negative threshold — the test pins the *acting*, not the
    signal (the signal itself is pinned by test_window.py)."""
    src, dst, n = _small_graph(10)
    E = src.size
    cfg = S5PConfig(k=K, seed=0, chunk_size=max(E // 3, 256),
                    xi_refresh_threshold=-1.0)
    chain = S5PWindowChain(src, dst, n, cfg, E // 3, step_edges=E // 6,
                           auto_cold_restart=True)
    reg = BundleRegistry()
    controller = ServingController(reg, chain)
    controller.run()
    post_fill = [r for r in controller.history if not r.filling]
    restarts = [r for r in post_fill if r.cold_restarted]
    assert restarts, "forced advisory signal was never acted on"
    for r in restarts:
        assert r.needs_cold_restart  # the signal that triggered it
        assert r.rf > 0
    # the restart landed as a published version like any other swap
    assert reg.swap_count >= 1
    assert reg.current.origin == "cold-restart"
    # the re-partition kept serving exactly the live window
    s, d, p = chain.live_partition()
    assert reg.current.n_edges == s.size
    assert np.all(p >= 0)


def test_request_cold_restart_publishes_swap():
    src, dst, n = _small_graph(8)
    E = src.size
    cfg = S5PConfig(k=K, seed=0, chunk_size=max(E // 2, 256))
    chain = S5PWindowChain(src, dst, n, cfg, E // 2, step_edges=E // 4,
                           auto_cold_restart=False)
    reg = BundleRegistry()
    controller = ServingController(reg, chain)
    assert not controller.request_cold_restart()  # nothing live yet
    while reg.current is None:
        assert controller.step() is not None
    v0 = reg.current_version
    rf0 = reg.current.rf
    assert controller.request_cold_restart()
    assert reg.current_version == v0 + 1
    assert reg.swap_count >= 1
    assert reg.current.origin == "cold-restart"
    # the re-partition covers exactly the live window
    s, d, p = chain.live_partition()
    assert reg.current.n_edges == s.size
    assert np.all(p >= 0)
    want = replication_factor(s, d, p, n_vertices=n, k=K)
    assert reg.current.rf == pytest.approx(float(want))
    assert rf0 > 0


# ================================================ 4d. origin provenance
def test_first_published_version_origin_is_cold():
    """Regression: the version counter used to be bumped before the
    origin was derived, so the very first bundle — the cold partition —
    reported origin "delta"."""
    src, dst, n = _small_graph(11)
    E = src.size
    cfg = S5PConfig(k=K, seed=0, chunk_size=max(E // 2, 256))
    chain = S5PWindowChain(src, dst, n, cfg, E // 2, step_edges=E // 4)
    reg = BundleRegistry()
    controller = ServingController(reg, chain)
    while reg.current is None:
        assert controller.step() is not None
    assert reg.current.version == 1
    assert reg.current.origin == "cold"
    # subsequent churn publishes are deltas again
    controller.run()
    origins = [b.origin for b in [reg.current]]
    assert reg.current.version > 1 and reg.current.origin != "cold"


# ================================================ 4e. restart/ingest race
def test_cold_restart_races_background_ingest():
    """Regression: ``request_cold_restart`` from the control plane while
    the background ingest thread churns used to interleave with a
    half-applied step — now both serialize on the controller lock, so
    every published version is internally consistent and versions are
    strictly monotonic."""
    src, dst, n = _small_graph(12)
    E = src.size
    cfg = S5PConfig(k=K, seed=0, chunk_size=max(E // 4, 256))
    chain = S5PWindowChain(src, dst, n, cfg, E // 4, step_edges=E // 16)
    reg = BundleRegistry()
    controller = ServingController(reg, chain)
    seen: list[int] = []
    errors: list[BaseException] = []

    def restarter():
        try:
            while not controller.done.is_set():
                controller.request_cold_restart()
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    t = threading.Thread(target=restarter)
    controller.start()
    t.start()
    controller.join(60)
    t.join(60)
    assert not errors, errors
    assert controller.done.is_set()
    # every version the registry holds is untorn and the final window is
    # exactly what the chain serves
    b = reg.current
    b.check()
    s, d, p = chain.live_partition()
    assert b.n_edges == s.size
    np.testing.assert_array_equal(b.parts, p)
    assert np.all(p >= 0)
    # restarts really interleaved with churn steps (both kinds published)
    restart_seen = any(r.cold_restarted for r in controller.history) or \
        controller.version > len([r for r in controller.history
                                  if not getattr(r, "filling", False)])
    assert restart_seen


# ================================================ 4f. elastic resize swap
def test_resize_publishes_swap_and_keeps_serving():
    """``ServingController.resize`` lands the k→k′ reshard as one more
    atomic swap (origin "resize") and the chain keeps absorbing churn —
    and publishing — at k′."""
    src, dst, n = _small_graph(13)
    E = src.size
    cfg = S5PConfig(k=K, seed=0, chunk_size=max(E // 3, 256))
    chain = S5PWindowChain(src, dst, n, cfg, E // 3, step_edges=E // 6)
    reg = BundleRegistry()
    controller = ServingController(reg, chain)
    assert controller.resize(K + 2) is None  # nothing live yet
    while reg.current is None:
        assert controller.step() is not None
    v0 = reg.current_version
    res = controller.resize(K + 2)
    assert res is not None and res.k_new == K + 2
    assert res.migrated_fraction < 1.0
    assert reg.current_version == v0 + 1
    assert reg.current.origin == "resize"
    assert reg.current.k == K + 2
    b = reg.current
    b.check()
    assert np.all(b.parts < K + 2)
    # serving continues at k': subsequent churn publishes in range
    server = GASServer(reg)
    server.run(2)
    assert controller.step() is not None
    controller.run()
    assert reg.current.k == K + 2
    assert np.all(reg.current.parts < K + 2)
    assert reg.current_version > v0 + 1


# ================================================ 4c. sharded retraction
@pytest.mark.parametrize("name", ["greedy", "hdrf", "grid"])
def test_parallel_retraction_bit_parity(name):
    """Deletion batches shard through run_parallel exactly like
    insertions: threads and vmap lanes reproduce the sequential
    retraction bit-for-bit (carry group algebra)."""
    src, dst, n = _small_graph(9)
    E = src.size
    if name == "greedy":
        pc = GreedyCarry(n, K)
    elif name == "hdrf":
        pc = HdrfCarry(n, K, 1.1)
    else:
        rng = np.random.default_rng(0)
        pc = GridCarry(K, rng.integers(0, 2, n).astype(np.int32),
                       rng.integers(0, 2, n).astype(np.int32), 2)
    st = EdgeStream(src, dst, n, chunk_size=128)
    parts, carry = run_carry(st, pc)
    parts = np.asarray(parts)
    # retract a scattered batch (not a clean suffix)
    idx = np.arange(0, E, 3, dtype=np.int64)
    back = EdgeStream(src[idx], dst[idx], n, chunk_size=64)
    seq = run_retract(back, pc, parts[idx], carry=carry)
    par = run_retract(back, pc, parts[idx], carry=carry, num_streams=3)
    vm = run_retract(back, pc, parts[idx], carry=carry, num_streams=3,
                     backend="vmap")
    assert _tree_equal(seq, par), name
    assert _tree_equal(seq, vm), name
    assert not _tree_equal(seq, carry)  # it actually subtracted


# ================================================ 8. reader backpressure
def test_backpressure_max_lag_blocks_behind_slow_reader():
    """start(max_lag=N): ingest stalls while the newest published version
    is more than N ahead of the oldest pinned reader version, and resumes
    the moment the slow reader lets go."""
    src, dst, n = _small_graph(5)
    E = src.size
    cfg = S5PConfig(k=K, seed=0, chunk_size=max(E, 256))
    chain = S5PWindowChain(src, dst, n, cfg, E // 2, step_edges=E // 12)
    reg = BundleRegistry()
    controller = ServingController(reg, chain)
    # drive synchronously to the first published version
    while reg.current is None:
        assert controller.step() is not None
    assert reg.reader_lag() == 0  # idle registry never counts as lagging
    # a deliberately slow reader: pin the current version and hold it
    pin_cm = reg.pin()
    held = pin_cm.__enter__()
    try:
        v0 = held.version
        assert reg.oldest_pinned_version() == v0
        controller.start(max_lag=1)
        # ingest may run at most max_lag versions past the held pin
        # before the gate closes; give it ample time to (wrongly) race on
        assert reg.wait_version(v0 + 1, timeout=30)
        assert not controller.done.wait(0.5)
        assert reg.current_version <= v0 + 2  # gate closes past lag 1
        assert reg.reader_lag() <= 2
        assert not controller.done.is_set()
        blocked_at = reg.current_version
    finally:
        pin_cm.__exit__(None, None, None)  # slow reader catches up
    # with no pins the registry is idle again — ingest drains to the end
    assert controller.done.wait(60)
    controller.join(5)
    assert reg.current_version > blocked_at
    assert reg.active_pins == 0


def test_backpressure_rejects_negative_lag():
    reg = BundleRegistry()
    controller = ServingController(reg, object())
    with pytest.raises(ValueError):
        controller.start(max_lag=-1)
    assert controller._thread is None  # nothing was spawned


# ================================================ 9. multi-reader fan-out
def test_fanout_eight_readers_under_churn():
    """≥8 GASServer readers over one registry while the controller churns:
    no reader ever sees a torn bundle, every superseded version retires
    exactly once, and each reader's carried PageRank converges to the
    final window's fixed point."""
    import time

    src, dst, n = _small_graph(6)
    E = src.size
    cfg = S5PConfig(k=K, seed=0, chunk_size=max(E, 256))
    chain = S5PWindowChain(src, dst, n, cfg, E // 2, step_edges=E // 4)
    reg = BundleRegistry()
    controller = ServingController(reg, chain)
    servers = [GASServer(reg) for _ in range(8)]
    stop = threading.Event()
    errors: list[BaseException] = []

    def reader(srv):
        seen = -1
        try:
            while not stop.is_set():
                with reg.pin() as b:
                    if b is None:
                        time.sleep(0.01)
                        continue
                    b.check()  # pin/publish atomicity: never torn
                    assert b.version >= seen
                    seen = b.version
                srv.superstep()
                # yield the GIL so ingest makes progress under 8 readers
                time.sleep(0.005)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(s,)) for s in servers]
    for t in threads:
        t.start()
    controller.start()
    assert controller.done.wait(120)
    controller.join(5)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors
    assert reg.active_pins == 0
    # refcounted retirement drained every superseded version exactly once
    assert reg.swap_count == controller.version - 1
    assert reg.versions_retired == reg.swap_count
    # per-reader convergence: all 8 carried states reach the same fixed
    # point as a cold PageRank over the final published window
    b = reg.current
    b.check()
    cold_vals, _ = pagerank(b.gas, iterations=300)
    for srv in servers:
        srv.run_to_convergence(tol=1e-7, max_steps=300)
        np.testing.assert_allclose(np.asarray(srv.values),
                                   np.asarray(cold_vals),
                                   rtol=1e-3, atol=1e-5)
