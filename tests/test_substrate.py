"""Optimizer, checkpointing, fault tolerance, straggler, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.data import TokenPipeline
from repro.optim import AdamWConfig, adamw_update, init_state
from repro.optim.grad_compress import (
    CompressState, init_compress_state, int8_compress, int8_decompress,
    topk_compress_update,
)
from repro.runtime import FaultInjector, FaultTolerantLoop, StragglerMonitor


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    target = jnp.array([1.0, -2.0, 3.0])
    state = init_state({"w": jnp.zeros(3)})
    for _ in range(300):
        grads = {"w": 2 * (state.params["w"] - target)}
        state = adamw_update(state, grads, cfg)
    np.testing.assert_allclose(np.asarray(state.params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    state = init_state({"w": jnp.zeros(4)})
    new = adamw_update(state, {"w": jnp.full(4, 1e6)}, cfg)
    assert float(jnp.max(jnp.abs(new.params["w"]))) < 2.0


def test_topk_error_feedback_telescopes():
    """sent_total + residual == grad_total (nothing is ever lost)."""
    params = {"w": jnp.zeros(100)}
    state = init_compress_state(params)
    rng = np.random.default_rng(0)
    total = np.zeros(100)
    sent_total = np.zeros(100)
    for step in range(10):
        g = {"w": jnp.asarray(rng.standard_normal(100), jnp.float32)}
        total += np.asarray(g["w"])
        sent, state, frac = topk_compress_update(g, state, frac=0.1)
        sent_total += np.asarray(sent["w"])
    np.testing.assert_allclose(sent_total + np.asarray(state.residual["w"]),
                               total, atol=1e-4)


def test_int8_compress_unbiased():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(512), jnp.float32)
    acc = np.zeros(512)
    n = 200
    for i in range(n):
        q, s = int8_compress(g, jax.random.PRNGKey(i))
        acc += np.asarray(int8_decompress(q, s))
    np.testing.assert_allclose(acc / n, np.asarray(g), atol=0.02)


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(5, dtype=jnp.float32),
             "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}, "step": jnp.int32(7)}
    save_checkpoint(tmp_path, 7, state)
    restored, step = restore_checkpoint(tmp_path, like=state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_corruption_detected(tmp_path):
    state = {"a": jnp.arange(100, dtype=jnp.float32)}
    path = save_checkpoint(tmp_path, 1, state)
    import numpy as onp
    z = dict(onp.load(path / "arrays.npz"))
    z["a"][3] += 1
    onp.savez(path / "arrays.npz", **z)
    with pytest.raises(IOError):
        restore_checkpoint(tmp_path, like=state)


def test_manager_keep_n(tmp_path):
    m = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        m.save(s, {"w": jnp.float32(s)})
    assert m.steps() == [3, 4]


def test_fault_tolerant_loop_bitwise_resume(tmp_path):
    """A run with injected failures converges to the *identical* state as a
    clean run — checkpoint/restart must be invisible to the math."""
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)

    def step_fn(state, batch):
        grads = {"w": 2 * (state.params["w"] - batch)}
        new = adamw_update(state, grads, cfg)
        return new, {"loss": jnp.sum((state.params["w"] - batch) ** 2)}

    def data_fn(step):
        return jnp.float32(np.sin(step))  # step-addressable

    def run(fail_at, d):
        m = CheckpointManager(d, keep=2, async_write=False)
        loop = FaultTolerantLoop(step_fn, data_fn, m, ckpt_every=5,
                                 injector=FaultInjector(fail_at))
        state = init_state({"w": jnp.zeros(3)})
        state, step, _ = loop.run(state, 20)
        return state, loop.restarts

    clean, r0 = run((), tmp_path / "clean")
    faulty, r1 = run((7, 13), tmp_path / "faulty")
    assert r0 == 0 and r1 == 2
    np.testing.assert_array_equal(np.asarray(clean.params["w"]),
                                  np.asarray(faulty.params["w"]))
    np.testing.assert_array_equal(np.asarray(clean.mu["w"]),
                                  np.asarray(faulty.mu["w"]))


def test_straggler_monitor_flags_and_rebalances():
    mon = StragglerMonitor(n_shards=4, threshold=1.5)
    for step in range(20):
        for s in range(4):
            mon.record(step, 1.0 if s != 2 else 3.0, shard=s)
    assert mon.stragglers() == [2]
    ranges = [(0, 100), (100, 200), (200, 300), (300, 400)]
    new = mon.rebalance_plan(ranges, give_frac=0.25)
    assert new[2][1] - new[2][0] == 75  # straggler gave up 25%
    total = sum(hi - lo for lo, hi in new)
    assert total == 400  # nothing lost


def test_token_pipeline_step_addressable():
    p = TokenPipeline(vocab=100, batch=2, seq=8, seed=1)
    a = p(5)
    b = p(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = p(6)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
