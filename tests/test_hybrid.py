"""Memory-budget hybrid partitioner (``repro.hybrid``).

Tier-1 coverage of the whole subsystem:

1. *Planner* — budget 0 plans pure streaming, a budget covering the edge
   list plans fully in-memory, and the refinement ladder is
   budget-independent: a smaller budget's ladder is always a prefix of a
   larger one's (the structural fact behind the monotone-RF gate).
2. *Zero-budget parity* — ``run_hybrid`` at budget 0 is bit-identical to
   the pure-streaming :func:`~repro.core.s5p.s5p_partition`.
3. *Small-budget smoke* (the tier-1 gate from the bench) — on a
   hub-heavy block R-MAT: peak resident bytes ≤ the requested budget,
   hybrid RF ≤ pure-streaming RF, and the result packs a standard
   40-key warm bundle.
4. *Monotone frontier* — RF non-increasing over three budget rungs.
5. *Round-trips* — a hybrid bundle persisted through
   :class:`~repro.incremental.CarryStore` warm-starts
   :func:`~repro.incremental.run_incremental` on a grown stream, and a
   :class:`~repro.hybrid.HybridServingChain` publishes through the
   standard :class:`~repro.serving.ServingController` (atomic, untorn)
   with delta steps landing as further swaps.
6. *CLI* — ``--host-budget`` accepts ``512M`` / ``2G`` style sizes.
"""

from __future__ import annotations

import argparse

import numpy as np
import pytest

from repro.core import S5PConfig, replication_factor
from repro.core.s5p import s5p_partition
from repro.graphs import block_rmat_graph
from repro.graphs.generators import community_graph
from repro.hybrid import (
    CORE_EDGE_BYTES,
    HybridServingChain,
    plan_budget,
    run_hybrid,
)
from repro.incremental import CarryStore, run_incremental, s5p_identity_config
from repro.incremental.driver import _prefix_crc
from repro.launch.partition import parse_bytes
from repro.serving import BundleRegistry, ServingController

K = 4


def _graph(seed=0):
    return community_graph(400, n_communities=8, avg_degree=6,
                           p_intra=0.9, seed=seed)


def _cfg(k=K, seed=0, chunk=1 << 12):
    return S5PConfig(k=k, seed=seed, chunk_size=chunk)


# =================================================== 1. budget planner
def test_planner_modes_and_ladder_prefix():
    src, dst, n = _graph()
    E = src.size
    full = E * CORE_EDGE_BYTES + (1 << 20)  # past every record + overhead

    p0 = plan_budget(src, dst, n, 0)
    assert p0.mode == "streaming" and not p0.resident
    assert p0.ladder == ()

    p_full = plan_budget(src, dst, n, full)
    assert p_full.mode == "in_memory"
    assert p_full.xi_star == 0  # threshold 0 = every edge is core
    assert p_full.ladder[-1] == 0

    # budget-independent ladder: smaller budget => prefix of larger
    p_mid = plan_budget(src, dst, n, full // 4)
    p_big = plan_budget(src, dst, n, full // 2)
    assert p_big.ladder[:len(p_mid.ladder)] == p_mid.ladder
    assert p_full.ladder[:len(p_big.ladder)] == p_big.ladder
    # conservative plan: estimated core cost respects the budget
    for p in (p_mid, p_big):
        if p.resident:
            assert p.est_core_bytes <= p.budget_bytes


# ============================================== 2. zero-budget parity
def test_zero_budget_bit_identical_to_streaming():
    src, dst, n = _graph(1)
    cfg = _cfg()
    base = s5p_partition(src, dst, n, cfg)
    res = run_hybrid((src, dst, n), cfg, host_budget=0)
    assert res.mode == "streaming"
    assert res.core_edges == 0
    np.testing.assert_array_equal(res.parts, np.asarray(base.parts))
    assert res.rf == pytest.approx(res.rf_streaming)


# ============================================ 3. small-budget smoke
def test_small_budget_hybrid_gates():
    src, dst, n = block_rmat_graph(block_scale=6, n_blocks=4,
                                   edge_factor=8, seed=0)
    E = src.size
    cfg = _cfg(chunk=1 << 12)
    budget = int(0.25 * E * CORE_EDGE_BYTES * 2)
    res = run_hybrid((src, dst, n), cfg, host_budget=budget)

    assert res.mode in ("hybrid", "in_memory")
    assert res.core_edges > 0
    # gate: resident accounting never exceeded the requested budget
    assert res.peak_budget_bytes <= budget
    # gate: refinement never loses to the pure-streaming incumbent
    assert res.rf <= res.rf_streaming + 1e-9
    assert res.rf == pytest.approx(
        replication_factor(src, dst, res.parts, n_vertices=n, k=K))
    # a standard warm bundle, ready for the incremental/serving stack
    assert len(res.bundle) == 40
    for key in ("parts", "c2p", "load", "stream_pos", "arrival", "alive"):
        assert key in res.bundle
    assert int(res.bundle["stream_pos"]) == E


# ============================================== 4. monotone frontier
def test_frontier_monotone_rf():
    src, dst, n = _graph(2)
    E = src.size
    cfg = _cfg()
    full = E * CORE_EDGE_BYTES * 2
    prev = None
    for frac in (0.0, 0.3, 1.0):
        res = run_hybrid((src, dst, n), cfg,
                         host_budget=int(frac * full))
        if prev is not None:
            assert res.rf <= prev + 1e-9
        prev = res.rf


# ========================================== 5a. incremental round-trip
def test_bundle_roundtrip_run_incremental(tmp_path):
    src, dst, n = _graph(3)
    E = src.size
    cfg = _cfg()
    res = run_hybrid((src, dst, n), cfg,
                     host_budget=E * CORE_EDGE_BYTES * 2)

    store = CarryStore(tmp_path)
    store.save(res.bundle, consumer="s5p",
               config=s5p_identity_config(cfg), stream_pos=E,
               extra_meta={"n_vertices": int(n),
                           "prefix_crc": _prefix_crc(src, dst, E)})

    # grow the stream with a foreign suffix and warm-start from the store
    rng = np.random.default_rng(7)
    dsrc = rng.integers(0, n, 64).astype(np.int32)
    ddst = rng.integers(0, n, 64).astype(np.int32)
    full_src = np.concatenate([src, dsrc])
    full_dst = np.concatenate([dst, ddst])
    inc = run_incremental(tmp_path, "s5p", full_src, full_dst, n, K,
                          s5p_config=cfg, save=False)
    assert inc.n_delta_edges == 64
    assert inc.parts.shape[0] == E + 64
    live = inc.parts >= 0
    assert inc.rf == pytest.approx(replication_factor(
        full_src[live], full_dst[live], inc.parts[live],
        n_vertices=n, k=K), abs=1e-6)


# ============================================= 5b. serving round-trip
def test_serving_roundtrip_publishes_hybrid_bundle():
    src, dst, n = _graph(4)
    E = src.size
    cfg = _cfg()
    res = run_hybrid((src, dst, n), cfg,
                     host_budget=E * CORE_EDGE_BYTES * 2)

    rng = np.random.default_rng(11)
    delta = (rng.integers(0, n, 48).astype(np.int32),
             rng.integers(0, n, 48).astype(np.int32))
    chain = HybridServingChain(res, cfg, src, dst, n, deltas=[delta])
    reg = BundleRegistry()
    controller = ServingController(reg, chain)

    # step 1 publishes the hybrid partition itself, atomically
    assert controller.step() is not None
    b1 = reg.current
    assert b1.version == 1 and b1.origin == "cold"
    b1.check()
    assert b1.n_edges == E
    assert b1.rf == pytest.approx(res.rf)

    # step 2 folds the delta through the ordinary warm-bundle path
    assert controller.step() is not None
    b2 = reg.current
    assert b2.version == 2
    b2.check()
    assert b2.n_edges == E + 48
    assert reg.swap_count == 1

    assert controller.step() is None  # deltas drained
    assert controller.done.is_set()


# ======================================================== 6. CLI sizes
def test_parse_bytes_accepts_human_sizes():
    assert parse_bytes("512M") == 512 << 20
    assert parse_bytes("2G") == 2 << 30
    assert parse_bytes("64KB") == 64 << 10
    assert parse_bytes("1048576") == 1 << 20
    assert parse_bytes("0") == 0
    for bad in ("", "-1", "12Q", "G", "1.5.2M"):
        with pytest.raises(argparse.ArgumentTypeError):
            parse_bytes(bad)
