"""PartitionerCarry protocol: merge algebra, parallel ingest, validation.

Four layers:

1. *Merge algebra* — for every carry implementation in the repo, ``merge``
   is associative, commutative, and idempotent-safe w.r.t. the identity
   carry (``init()``), ``merge([c]) == c`` bitwise, and ``merge_stacked``
   agrees with ``merge``.  Property-based: hypothesis when installed, the
   seeded ``proptest`` harness otherwise.  All merged fields are int/bool,
   so every law is checked with exact equality — no tolerance.
2. *Parallel engine* — ``num_streams=1`` delegates bit-identically to the
   sequential driver; the threads and vmap backends agree bitwise for
   every carry; linear-merge carries (degrees, Θ sketch) are *exact*
   under any S; parts stay valid partitions.
3. *Sharding plan* — range/round-robin lanes partition the chunk id
   space; S is clamped to the chunk count.
4. *Validation* — non-positive chunk_size/window/num_streams/super_chunk
   raise ValueError at construction (not deep inside numpy), and the CLI
   rejects them at argparse level.

The 8-device shard_map quality-band test lives at the bottom (slow lane,
subprocess — same pattern as tests/test_distributed.py).
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import random_graph
from repro.core.clustering import ClusterCarry, DegreeCarry, compute_degrees
from repro.core.cms import SketchCarry
from repro.core.postprocess import AssignCarry
from repro.kernels.stream_scan import GreedyCarry, GridCarry, HdrfCarry
from repro.streaming import (
    EdgeStream,
    FnCarry,
    ParallelEdgeStream,
    run_carry,
    run_parallel,
)

try:  # optional — the container image has no hypothesis; gate, don't require
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

K = 4


def _leaves(c):
    return jax.tree_util.tree_leaves(c)


def _tree_equal(a, b) -> bool:
    la, lb = _leaves(a), _leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _make_carry_impls(n, extras_info=False):
    """Every PartitionerCarry implementation in the repo, ready to step
    random (src, dst) chunks of vertex ids < n."""
    deg = jnp.full((n,), 5, jnp.int32)  # fixed plausible degrees for Alg. 1
    c2p = jnp.arange(8, dtype=jnp.int32) % K
    impls = {
        "greedy": (GreedyCarry(n, K), 0),
        "hdrf": (HdrfCarry(n, K, 1.1), 0),
        "grid": (GridCarry(K, jnp.arange(n, dtype=jnp.int32) % 2,
                           jnp.arange(n, dtype=jnp.int32) % 2, 2), 0),
        "cluster": (ClusterCarry(deg, n, xi=3, kappa=17), 0),
        "assign": (AssignCarry(K, 50, c2p), 3),  # is_head, cu, cv extras
        "degree": (DegreeCarry(n), 0),
        "sketch": (SketchCarry(32, 3, seed=1), 0),
    }
    return impls


def _fold_random(pc, n_extras, n, rng, n_chunks=2, chunk=17):
    """Build a carry by folding random chunks from the identity."""
    carry = pc.init()
    for _ in range(n_chunks):
        src = jnp.asarray(rng.integers(0, n, chunk).astype(np.int32))
        dst = jnp.asarray(rng.integers(0, n, chunk).astype(np.int32))
        extras = []
        if n_extras:
            extras = [
                jnp.asarray(rng.integers(0, 2, chunk).astype(bool)),
                jnp.asarray(rng.integers(0, 8, chunk).astype(np.int32)),
                jnp.asarray(rng.integers(0, 8, chunk).astype(np.int32)),
            ]
        carry, _ = pc.step_chunk(carry, src, dst, jnp.int32(chunk), *extras)
    return carry


def _check_merge_algebra(name, pc, n_extras, n, seed):
    """The base-relative merge algebra every backend relies on.

    Since the decremental refactor all non-replicated fields are group
    elements merged as base + Σ(cᵢ − base) — so the laws are stated
    against a shared merge base (``run_parallel`` always supplies one;
    assignment tables init to −1, which is not the group identity)."""
    rng = np.random.default_rng(seed)
    base = pc.init()
    c1 = _fold_random(pc, n_extras, n, rng)
    c2 = _fold_random(pc, n_extras, n, rng)
    c3 = _fold_random(pc, n_extras, n, rng)
    m = pc.merge
    # singleton merge is the bitwise identity
    assert _tree_equal(m([c1]), c1), name
    # the base itself is the merge identity: base + (c1 − base) == c1
    assert _tree_equal(m([c1, base], base=base), c1), name
    assert _tree_equal(m([base, c1], base=base), c1), name
    # commutative on the group leaves.  pick_first leaves (assignment
    # tables: ClusterCarry's v2c) trade commutativity for sanity under
    # contention — they are deterministic by lane order instead: both
    # orders agree wherever at most one lane wrote, and the winner on a
    # contested cell is the first changed lane (a real id, never the
    # telescoped sum).  run_parallel always merges in lane order, so the
    # parallel result stays deterministic.
    ab = m([c1, c2], base=base)
    ba = m([c2, c1], base=base)
    pick = set(getattr(pc, "pick_first", ()))
    if not pick:
        assert _tree_equal(ab, ba), name
    else:
        la = jax.tree_util.tree_leaves(ab)
        lb = jax.tree_util.tree_leaves(ba)
        l1 = jax.tree_util.tree_leaves(c1)
        l2 = jax.tree_util.tree_leaves(c2)
        l0 = jax.tree_util.tree_leaves(base)
        for i, (x, y) in enumerate(zip(la, lb)):
            x, y = np.asarray(x), np.asarray(y)
            if i not in pick:
                np.testing.assert_array_equal(x, y, err_msg=name)
                continue
            v1, v2, b0 = (np.asarray(l1[i]), np.asarray(l2[i]),
                          np.asarray(l0[i]))
            ch1, ch2 = v1 != b0, v2 != b0
            both = ch1 & ch2
            np.testing.assert_array_equal(x[~both], y[~both], err_msg=name)
            np.testing.assert_array_equal(x, np.where(ch1, v1, v2),
                                          err_msg=name)
            np.testing.assert_array_equal(y, np.where(ch2, v2, v1),
                                          err_msg=name)
    # associative: merging a merged pair against the same base equals the
    # flat n-ary merge (the merged pair re-enters as one diverged carry)
    flat = m([c1, c2, c3], base=base)
    assert _tree_equal(m([m([c1, c2], base=base), c3], base=base), flat), name
    assert _tree_equal(m([c1, m([c2, c3], base=base)], base=base), flat), name
    # stacked reduction agrees with the list form
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), c1, c2, c3)
    assert _tree_equal(pc.merge_stacked(stacked, base=base),
                       m([c1, c2, c3], base=base)), name


def _check_group_laws(name, pc, n_extras, n, seed):
    """merge(c, δ) ∘ merge(·, −δ) is the identity for every carry type:
    signed deltas form a group, bitwise (integer / ℤ-2³² arithmetic)."""
    rng = np.random.default_rng(seed)
    c = _fold_random(pc, n_extras, n, rng)
    after = _fold_random(pc, n_extras, n, rng, n_chunks=3)
    delta = pc.signed_delta(after, c)
    # applying the delta reconstructs `after` exactly...
    assert _tree_equal(pc.apply_delta(c, delta), after), name
    # ...and applying its inverse is the identity, both ways round
    assert _tree_equal(pc.apply_delta(pc.apply_delta(c, delta),
                                      pc.negate(delta)), c), name
    assert _tree_equal(
        pc.apply_delta(pc.apply_delta(after, pc.negate(delta)), delta),
        after), name
    # double negation is the identity on the delta itself
    assert _tree_equal(pc.negate(pc.negate(delta)), delta), name


CARRY_NAMES = sorted(_make_carry_impls(8).keys())


# ====================================================== 1. merge algebra
@pytest.mark.parametrize("name", CARRY_NAMES)
@pytest.mark.parametrize("seed", [0, 1])
def test_merge_algebra(name, seed):
    n = 23
    pc, n_extras = _make_carry_impls(n)[name]
    _check_merge_algebra(name, pc, n_extras, n, seed)


@pytest.mark.parametrize("name", CARRY_NAMES)
@pytest.mark.parametrize("seed", [0, 1])
def test_group_laws(name, seed):
    n = 23
    pc, n_extras = _make_carry_impls(n)[name]
    _check_group_laws(name, pc, n_extras, n, seed)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(name=st_.sampled_from(CARRY_NAMES), seed=st_.integers(0, 255),
           n=st_.integers(2, 64))
    def test_merge_algebra_fuzzed(name, seed, n):
        pc, n_extras = _make_carry_impls(n)[name]
        _check_merge_algebra(name, pc, n_extras, n, seed)

    @settings(max_examples=20, deadline=None)
    @given(name=st_.sampled_from(CARRY_NAMES), seed=st_.integers(0, 255),
           n=st_.integers(2, 64))
    def test_group_laws_fuzzed(name, seed, n):
        pc, n_extras = _make_carry_impls(n)[name]
        _check_group_laws(name, pc, n_extras, n, seed)


# =================================================== 1b. exact retraction
@pytest.mark.parametrize("name", CARRY_NAMES)
@pytest.mark.parametrize("seed", [0, 7])
def test_retract_is_exact_inverse_of_step(name, seed):
    """For the exact-retract carries, inserting a batch and then deleting
    it bitwise-restores the pre-batch carry — in any retraction order."""
    n = 23
    pc, n_extras = _make_carry_impls(n)[name]
    if not pc.supports_retract:
        pytest.skip(f"{name} does not retract")
    rng = np.random.default_rng(seed)
    before = _fold_random(pc, n_extras, n, rng)
    after, log = _fold_chunks_from(pc, n_extras, n, rng, before)
    if not pc.retract_exact:
        # cluster: retraction is the documented approximation — check the
        # exactly-counted fields (membership counters, local degrees)
        got = after
        for src, dst, parts, extras in reversed(log):
            got = pc.retract_chunk(got, src, dst, jnp.int32(src.shape[0]),
                                   parts, *extras)
        assert np.array_equal(np.asarray(got.cnt_h), np.asarray(before.cnt_h))
        assert np.array_equal(np.asarray(got.cnt_t), np.asarray(before.cnt_t))
        assert np.array_equal(np.asarray(got.ld), np.asarray(before.ld))
        return
    # exact carries restore bitwise — and retraction order cannot matter
    for order in (reversed(log), log):
        got = after
        for src, dst, parts, extras in order:
            got = pc.retract_chunk(got, src, dst, jnp.int32(src.shape[0]),
                                   parts, *extras)
        assert _tree_equal(got, before), name


def _fold_chunks_from(pc, n_extras, n, rng, carry, n_chunks=3, chunk=17):
    log = []
    for _ in range(n_chunks):
        src = jnp.asarray(rng.integers(0, n, chunk).astype(np.int32))
        dst = jnp.asarray(rng.integers(0, n, chunk).astype(np.int32))
        extras = []
        if n_extras:
            extras = [
                jnp.asarray(rng.integers(0, 2, chunk).astype(bool)),
                jnp.asarray(rng.integers(0, 8, chunk).astype(np.int32)),
                jnp.asarray(rng.integers(0, 8, chunk).astype(np.int32)),
            ]
        carry, parts = pc.step_chunk(carry, src, dst, jnp.int32(chunk), *extras)
        log.append((src, dst, parts, extras))
    return carry, log


def test_run_retract_driver_roundtrip():
    """run_carry over a deletion batch then run_retract with the recorded
    parts is the identity on the carry (greedy, chunked arbitrarily)."""
    from repro.streaming import run_retract

    src, dst, n, _ = random_graph(1)
    if len(src) < 64:
        pytest.skip("graph too small")
    cut = len(src) // 2
    pc = GreedyCarry(n, K)
    st_prefix = EdgeStream(src[:cut], dst[:cut], n, chunk_size=29)
    _, before = run_carry(st_prefix, pc)
    st_delta = EdgeStream(src[cut:], dst[cut:], n, chunk_size=29)
    delta_parts, after = run_carry(st_delta, pc, carry=before)
    # retract the delta through a *different* chunking than it arrived in
    st_back = EdgeStream(src[cut:], dst[cut:], n, chunk_size=13)
    got = run_retract(st_back, pc, np.asarray(delta_parts), carry=after)
    assert _tree_equal(got, before)


def test_merge_with_base_subtracts_deltas():
    """SUM fields merged against a common base count the base once:
    base + Σ(cᵢ − base).  DegreeCarry makes this exactly checkable."""
    n = 31
    rng = np.random.default_rng(7)
    pc = DegreeCarry(n)
    base = _fold_random(pc, 0, n, rng)
    all_src, all_dst = [], []

    def fold_from(base, n_chunks):
        carry = base
        for _ in range(n_chunks):
            src = jnp.asarray(rng.integers(0, n, 13).astype(np.int32))
            dst = jnp.asarray(rng.integers(0, n, 13).astype(np.int32))
            all_src.append(np.asarray(src))
            all_dst.append(np.asarray(dst))
            carry, _ = pc.step_chunk(carry, src, dst, jnp.int32(13))
        return carry

    merged = pc.merge([fold_from(base, 2), fold_from(base, 1),
                       fold_from(base, 3)], base=base)
    expect = np.asarray(base) + np.asarray(compute_degrees(
        jnp.asarray(np.concatenate(all_src)),
        jnp.asarray(np.concatenate(all_dst)), n))
    assert np.array_equal(np.asarray(merged), expect)


def test_merge_validates_op_declaration():
    pc = DegreeCarry(4)
    pc.merge_ops = ("sum", "sum")  # wrong arity
    with pytest.raises(ValueError, match="leaves"):
        pc.merge([pc.init(), pc.init()])
    pc.merge_ops = ("nope",)
    with pytest.raises(ValueError, match="unknown merge op"):
        pc.merge([pc.init(), pc.init()])
    with pytest.raises(ValueError, match="at least one"):
        DegreeCarry(4).merge([])


# ==================================================== 2. parallel engine
def test_run_parallel_s1_is_sequential_bitwise():
    src, dst, n, _ = random_graph(1)
    st = EdgeStream(src, dst, n, chunk_size=29)
    pc = HdrfCarry(n, K)
    seq_parts, seq_carry = run_carry(st, pc)
    par_parts, par_carry = run_parallel(st, pc, num_streams=1)
    assert np.array_equal(np.asarray(seq_parts), np.asarray(par_parts))
    assert _tree_equal(seq_carry, par_carry)


@pytest.mark.parametrize("graph_seed", [0, 1])
@pytest.mark.parametrize("S", [2, 4])
def test_backends_agree_bitwise(graph_seed, S):
    """threads and vmap realize the same plan + merge algebra, so they
    must agree bit-for-bit — for parts-emitting and state-only carries."""
    src, dst, n, _ = random_graph(graph_seed)
    if len(src) < 64:
        pytest.skip("graph too small for multiple chunks")
    st = EdgeStream(src, dst, n, chunk_size=31)
    for name, (pc, n_extras) in _make_carry_impls(n).items():
        extras = ()
        if n_extras:
            E = len(src)
            rng = np.random.default_rng(0)
            extras = (rng.integers(0, 2, E).astype(bool),
                      rng.integers(0, 8, E).astype(np.int32),
                      rng.integers(0, 8, E).astype(np.int32))
        pt, ct = run_parallel(st, pc, *extras, num_streams=S, super_chunk=3,
                              backend="threads")
        pv, cv = run_parallel(st, pc, *extras, num_streams=S, super_chunk=3,
                              backend="vmap")
        if pt is None:
            assert pv is None, name
        else:
            assert np.array_equal(np.asarray(pt), np.asarray(pv)), name
        assert _tree_equal(ct, cv), name


def test_parallel_parts_stay_valid_partitions():
    src, dst, n, _ = random_graph(1)
    st = EdgeStream(src, dst, n, chunk_size=23)
    for S in (2, 4):
        parts, _ = run_parallel(st, GreedyCarry(n, K), num_streams=S,
                                super_chunk=2, backend="threads")
        parts = np.asarray(parts)
        valid = src != dst
        assert parts.shape == src.shape
        assert np.all(parts[valid] >= 0) and np.all(parts[valid] < K)
        assert np.all(parts[~valid] == -1)


def test_parallel_linear_carries_are_exact():
    """SUM-only carries commute with sharding: parallel degree and Θ
    sketch ingest equal the sequential result exactly, any S."""
    src, dst, n, _ = random_graph(2)
    st = EdgeStream(src, dst, n, chunk_size=17)
    ref_deg = np.asarray(compute_degrees(jnp.asarray(src), jnp.asarray(dst), n))
    _, seq_sk = run_parallel(st, SketchCarry(64, 4, seed=3), num_streams=1)
    for S in (2, 4, 8):
        _, deg = run_parallel(st, DegreeCarry(n), num_streams=S,
                              super_chunk=2, backend="threads")
        assert np.array_equal(np.asarray(deg), ref_deg), S
        _, sk = run_parallel(st, SketchCarry(64, 4, seed=3), num_streams=S,
                             super_chunk=2, backend="threads")
        assert np.array_equal(np.asarray(sk.table), np.asarray(seq_sk.table)), S
        assert np.array_equal(np.asarray(sk.seeds), np.asarray(seq_sk.seeds)), S


def test_parallel_cli_paths_run():
    """The partitioner entry points accept num_streams/super_chunk and the
    parallel S5P pipeline produces a full assignment."""
    from repro.core import S5PConfig, s5p_partition
    from repro.core.baselines import hdrf_partition

    src, dst, n, _ = random_graph(1)
    p = np.asarray(hdrf_partition(src, dst, n, K, chunk_size=31,
                                  num_streams=2, super_chunk=2))
    valid = src != dst
    assert np.all(p[valid] >= 0) and np.all(p[valid] < K)
    out = s5p_partition(src, dst, n,
                        S5PConfig(k=K, use_cms=False, chunk_size=31,
                                  num_streams=2, super_chunk=2))
    p = np.asarray(out.parts)
    assert np.all(p[valid] >= 0) and np.all(p[valid] < K)


def test_fn_carry_has_no_merge_semantics():
    fc = FnCarry((jnp.zeros((2,)),), lambda c, s, d: (c, s))
    with pytest.raises(ValueError):
        fc.merge([fc.init(), fc.init()])


# ====================================================== 3. sharding plan
@pytest.mark.parametrize("shard", ["range", "round-robin"])
def test_parallel_stream_plan_partitions_chunks(shard):
    src, dst, n, _ = random_graph(0)
    st = EdgeStream(src, dst, n, chunk_size=7)
    ps = ParallelEdgeStream(st, 3, shard=shard)
    seen = sorted(cid for lane in ps.lanes for cid in lane)
    assert seen == list(range(st.n_chunks))
    for lane in ps.lanes:  # sub-stream-local order preserves stream order
        assert lane == sorted(lane)
    assert ps.n_rounds == max(len(lane) for lane in ps.lanes)
    # n_valid bookkeeping matches the underlying chunks
    for cid in range(st.n_chunks):
        assert ps.chunk_n_valid(cid) == st.chunk_at(cid).n_valid


def test_parallel_stream_clamps_num_streams():
    src, dst, n, _ = random_graph(0)
    st = EdgeStream(src, dst, n, chunk_size=1 << 16)  # single chunk
    assert ParallelEdgeStream(st, 8).num_streams == 1
    with pytest.raises(ValueError):
        ParallelEdgeStream(st, 0)
    with pytest.raises(ValueError):
        ParallelEdgeStream(st, 2, shard="nope")


# ======================================================== 4. validation
def test_stream_rejects_bad_sizes(tmp_path):
    from repro.streaming import ShardedEdgeStream, write_shards

    src, dst, n, _ = random_graph(3)
    with pytest.raises(ValueError, match="window"):
        EdgeStream(src, dst, n, window=0)
    with pytest.raises(ValueError, match="chunk_size"):
        EdgeStream(src, dst, n, chunk_size=0)
    with pytest.raises(ValueError, match="shard_edges"):
        write_shards(tmp_path, src, dst, shard_edges=-1)
    man = write_shards(tmp_path, src, dst, shard_edges=16, n_vertices=n)
    with pytest.raises(ValueError, match="window"):
        ShardedEdgeStream(man, window=-3)
    with pytest.raises(ValueError, match="chunk_size"):
        ShardedEdgeStream(man, chunk_size=0)


def test_run_parallel_rejects_bad_knobs():
    src, dst, n, _ = random_graph(0)
    st = EdgeStream(src, dst, n, chunk_size=16)
    with pytest.raises(ValueError, match="num_streams"):
        run_parallel(st, DegreeCarry(n), num_streams=0)
    with pytest.raises(ValueError, match="super_chunk"):
        run_parallel(st, DegreeCarry(n), num_streams=2, super_chunk=0)
    with pytest.raises(ValueError, match="backend"):
        run_parallel(st, DegreeCarry(n), num_streams=2, backend="nope")


def test_cli_rejects_nonpositive_sizes(monkeypatch, capsys):
    from repro.launch import partition as cli

    for flag, val in (("--chunk-size", "0"), ("--window", "-1"),
                      ("--num-streams", "0"), ("--super-chunk", "0"),
                      ("--shard-edges", "0"), ("--k", "0"),
                      ("--chunk-size", "abc")):
        monkeypatch.setattr(sys, "argv", ["partition", flag, val])
        with pytest.raises(SystemExit) as exc:
            cli.main()
        assert exc.value.code == 2  # argparse usage error, not a traceback
        err = capsys.readouterr().err
        assert ("must be >= 1" in err or "expected an integer" in err
                or "chunk count >= 1 or 'auto'" in err)
    # the library-level entry validates too (not just argparse)
    with pytest.raises(ValueError, match="num_streams"):
        cli.run("toy", 4, "hdrf", num_streams=0)


# ===================== 4b. S5P bundle round-trip + repr-version guard
def test_s5p_insert_then_delete_restores_carry_golden():
    """Inserting a 10 % delta then deleting it bitwise-restores the
    pre-delta S5P carry bundle, golden-anchored: the restored parts hash
    is the pinned sequential golden of tests/test_streaming.py."""
    import hashlib

    from repro.core import S5PConfig
    from repro.incremental import (
        JOURNAL_PREFIX,
        s5p_apply_delta,
        s5p_apply_deletion,
        s5p_cold_bundle,
    )

    def _h(a):
        return hashlib.sha256(
            np.ascontiguousarray(np.asarray(a)).tobytes()).hexdigest()[:16]

    src, dst, n, _ = random_graph(0)
    # the seed-era game parameters of the pinned goldens; refinement off so
    # the insertion keeps its rollback journal intact
    cfg = S5PConfig(k=4, use_cms=False, game_accept_prob=0.7,
                    game_max_rounds=64, seed=0,
                    drift_rf_threshold=float("inf"),
                    drift_balance_threshold=float("inf"),
                    drift_churn_threshold=float("inf"))
    _, before = s5p_cold_bundle(src, dst, n, cfg)
    assert _h(before["parts"]) == "5c2abcabc60d546d"  # GOLDEN[(0, "s5p")]
    E0 = len(src)
    rng = np.random.default_rng(9)
    m = max(E0 // 10, 4)
    full_src = np.concatenate([src, rng.integers(0, n, m).astype(np.int32)])
    full_dst = np.concatenate([dst, rng.integers(0, n, m).astype(np.int32)])
    mid, _ = s5p_apply_delta(before, cfg, full_src, full_dst, E0)
    assert bool(mid["journal_valid"])
    after, res = s5p_apply_deletion(mid, cfg, full_src, full_dst,
                                    np.arange(E0, E0 + m))
    assert res.rolled_back and res.n_retracted == m
    skip = ("journal_valid", "journal_pos")
    keys = {k_ for k_ in list(before) + list(after)
            if not k_.startswith(JOURNAL_PREFIX) and k_ not in skip}
    for key in sorted(keys):
        a = np.asarray(before[key])
        b = np.asarray(after[key])
        assert a.shape == b.shape and np.array_equal(a, b), key
    assert _h(after["parts"]) == "5c2abcabc60d546d"


def test_store_rejects_pre_refactor_monotone_checkpoint(tmp_path,
                                                        monkeypatch):
    """A carry persisted under the old monotone (OR/MAX) representation
    must raise CarryMismatchError, not silently mis-restore."""
    from repro.incremental import CarryMismatchError, CarryStore
    from repro.incremental import store as store_mod

    pc = DegreeCarry(8)
    st = CarryStore(tmp_path)
    with monkeypatch.context() as mp:
        mp.setattr(store_mod, "CARRY_REPR", 1)  # simulate a v1 writer
        st.save(pc.init(), consumer="degree", config={"n": 8}, stream_pos=0)
    with pytest.raises(CarryMismatchError, match="representation"):
        st.load(consumer="degree", config={"n": 8})


# ================================== 5. 8-device mesh quality (slow lane)
SRC_DIR = str(Path(__file__).resolve().parent.parent / "src")


def _run_subprocess(code: str) -> dict:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=1500,
        env={"PYTHONPATH": SRC_DIR, "XLA_FLAGS":
             "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin", "HOME": "/root"},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_parallel_quality_band_on_8device_mesh():
    """num_streams ∈ {2,4,8} on the 8-device CPU mesh (shard_map backend):
    multi-seed mean RF for greedy/HDRF/S5P stays within the pinned band of
    the sequential run, and shard_map agrees bitwise with the vmap backend
    (same plan, same integer merge algebra)."""
    res = _run_subprocess("""
        import json
        import numpy as np
        from repro.core import S5PConfig, s5p_partition, replication_factor
        from repro.core.baselines import greedy_partition, hdrf_partition
        from repro.graphs.generators import community_graph

        CS = 512
        K = 8
        out = {"band": {}, "bitwise": None}
        algos = {
            "greedy": lambda s, d, n, **kw: greedy_partition(
                s, d, n, K, chunk_size=CS, **kw),
            "hdrf": lambda s, d, n, **kw: hdrf_partition(
                s, d, n, K, chunk_size=CS, **kw),
            "s5p": lambda s, d, n, **kw: s5p_partition(
                s, d, n, S5PConfig(k=K, use_cms=False, chunk_size=CS, **kw)
            ).parts,
        }
        graphs = [community_graph(1200, n_communities=24, avg_degree=8,
                                  seed=s) for s in (0, 1)]
        for name, fn in algos.items():
            seq = [replication_factor(s, d, fn(s, d, n), n_vertices=n, k=K)
                   for s, d, n in graphs]
            for S in (2, 8):
                # 8 devices >= S: run_parallel resolves to shard_map here
                kw = dict(num_streams=S, super_chunk=4)
                rfs = []
                for s, d, n in graphs:
                    parts = fn(s, d, n, **kw)
                    p = np.asarray(parts)
                    valid = np.asarray(s) != np.asarray(d)
                    assert (p[valid] >= 0).all() and (p[valid] < K).all()
                    rfs.append(replication_factor(s, d, parts,
                                                  n_vertices=n, k=K))
                out["band"][f"{name}/S{S}"] = [float(np.mean(rfs)),
                                               float(np.mean(seq))]
        # shard_map vs vmap bitwise agreement on the real 8-wide mesh
        from repro.streaming import EdgeStream, run_parallel
        from repro.kernels.stream_scan import HdrfCarry
        s, d, n = graphs[0]
        st = EdgeStream(s, d, n, chunk_size=CS)
        pc = HdrfCarry(n, K)
        pa, _ = run_parallel(st, pc, num_streams=8, super_chunk=4,
                             backend="shard_map")
        pb, _ = run_parallel(st, pc, num_streams=8, super_chunk=4,
                             backend="vmap")
        out["bitwise"] = bool(np.array_equal(np.asarray(pa), np.asarray(pb)))
        print(json.dumps(out))
    """)
    assert res["bitwise"], "shard_map and vmap backends diverged"
    for key, (rf_par, rf_seq) in res["band"].items():
        # the pinned tolerance band: S-way carry staleness may cost RF but
        # boundedly so (and may help S5P — more, smaller clusters)
        assert 0.6 * rf_seq <= rf_par <= 1.75 * rf_seq + 0.05, (key, res)
