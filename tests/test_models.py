"""Model-level behaviour: decode≡forward, EGNN equivariance, MoE, recsys."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn as G
from repro.models import lm as LM
from repro.models import recsys as R

SMOKE = LM.LMConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                    d_ff=128, vocab=128, attn_chunk=32, dtype=jnp.float32)
# capacity_factor high enough that no token drops: capacity truncation is a
# train-throughput tradeoff and intentionally absent at decode (cap ≥ K), so
# the decode≡forward identity only holds drop-free.
SMOKE_MOE = LM.LMConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_head=16, d_ff=128, vocab=128, n_experts=4, top_k=2,
                        sliding_window=16, attn_chunk=32, dtype=jnp.float32,
                        capacity_factor=8.0)


@pytest.mark.parametrize("cfg", [SMOKE, SMOKE_MOE], ids=["dense", "moe"])
def test_decode_matches_forward(cfg):
    """Greedy decode logits == teacher-forced forward logits, step by step."""
    key = jax.random.PRNGKey(0)
    params = LM.init_params(cfg, key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, dtype=jnp.int32)
    logits_full, _ = LM.forward(params, toks, cfg)
    prompt = 16
    logits_pre, cache = LM.prefill(params, toks[:, :prompt], cfg, max_seq=S)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, prompt - 1]),
                               atol=2e-3, rtol=1e-3)
    for i in range(prompt, S):
        pos = jnp.full((B,), i, jnp.int32)
        logits_dec, cache = LM.decode_step(params, cache, toks[:, i], pos, cfg)
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_full[:, i]),
                                   atol=2e-3, rtol=1e-3,
                                   err_msg=f"decode step {i}")


def test_swa_limits_context():
    """With window W, positions ≥ W behind the query must not influence it."""
    cfg = LM.LMConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                      d_head=16, d_ff=64, vocab=64, sliding_window=8,
                      attn_chunk=16, dtype=jnp.float32)
    params = LM.init_params(cfg, jax.random.PRNGKey(1))
    S = 32
    t1 = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0, 64, jnp.int32)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % 64)  # perturb far outside window
    l1, _ = LM.forward(params, t1, cfg)
    l2, _ = LM.forward(params, t2, cfg)
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               atol=1e-5)


def test_moe_load_balance_loss_positive():
    params = LM.init_params(SMOKE_MOE, jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, 128, jnp.int32)
    _, aux = LM.forward(params, toks, SMOKE_MOE)
    assert float(aux) >= 1.0 - 1e-3  # ≥1 by Cauchy-Schwarz, =1 iff balanced


def test_lm_param_count_formula():
    assert LM.count_params(SMOKE) == sum(
        int(np.prod(p.shape)) for p in jax.tree.leaves(
            LM.init_params(SMOKE, jax.random.PRNGKey(0)))
    )


def test_egnn_energy_invariance():
    """E(n) invariance: rotating + translating inputs leaves energy fixed."""
    cfg = G.EGNNConfig(n_layers=2, d_hidden=16)
    params = G.egnn_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    V, E = 12, 30
    species = jnp.asarray(rng.integers(1, 5, V), jnp.int32)
    pos = jnp.asarray(rng.standard_normal((V, 3)), jnp.float32)
    es = jnp.asarray(rng.integers(0, V, E), jnp.int32)
    ed = jnp.asarray(rng.integers(0, V, E), jnp.int32)
    e1 = G.egnn_forward(params, species, pos, es, ed, V, cfg)
    # random rotation (QR) + translation
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    pos2 = pos @ jnp.asarray(q, jnp.float32) + jnp.asarray([1.5, -2.0, 0.3])
    e2 = G.egnn_forward(params, species, pos2, es, ed, V, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4, atol=1e-4)


def test_schnet_cutoff():
    """Edges beyond the cutoff contribute nothing."""
    cfg = G.SchNetConfig(n_interactions=1, d_hidden=8, n_rbf=8, cutoff=2.0)
    params = G.schnet_init(cfg, jax.random.PRNGKey(0))
    species = jnp.array([1, 2, 3], jnp.int32)
    pos = jnp.array([[0, 0, 0], [1, 0, 0], [10, 0, 0]], jnp.float32)
    es = jnp.array([0, 0], jnp.int32)
    ed = jnp.array([1, 2], jnp.int32)
    e_with = G.schnet_forward(params, species, pos, es, ed, 3, cfg)
    # removing the out-of-cutoff edge (0→2) changes nothing
    e_without = G.schnet_forward(params, species, pos, es[:1], ed[:1], 3, cfg)
    np.testing.assert_allclose(np.asarray(e_with), np.asarray(e_without),
                               atol=1e-5)


def test_gcn_forward_shapes_and_grad():
    cfg = G.GCNConfig(n_layers=2, d_hidden=8, d_feat=16, n_classes=3)
    params = G.gcn_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    V, E = 20, 50
    batch = {
        "feats": jnp.asarray(rng.standard_normal((V, 16)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, V, E), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, V, E), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 3, V), jnp.int32),
    }
    loss, _ = G.gcn_loss(params, batch, cfg)
    grads = jax.grad(lambda p: G.gcn_loss(p, batch, cfg)[0])(params)
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(g))) for g in jax.tree.leaves(grads))


def test_dimenet_triplets_consistency():
    """build_triplets: each (kj, ji) pair shares vertex j and k ≠ i."""
    rng = np.random.default_rng(2)
    E = 24
    src = rng.integers(0, 8, E).astype(np.int32)
    dst = rng.integers(0, 8, E).astype(np.int32)
    kj, ji, mask = G.build_triplets(src, dst, max_triplets=128)
    for t in range(int(mask.sum())):
        assert dst[kj[t]] == src[ji[t]]  # share j
        assert src[kj[t]] != dst[ji[t]]  # no backtrack


def test_embedding_bag_matches_manual():
    table = jnp.asarray(np.random.default_rng(0).standard_normal((50, 8)),
                        jnp.float32)
    indices = jnp.array([3, 7, 1, 0, 2, 9, 9], jnp.int32)
    offsets = jnp.array([0, 3, 3, 5], jnp.int32)  # bag 1 is empty
    out = R.embedding_bag(table, indices, offsets, mode="sum")
    expect = np.stack([
        np.asarray(table)[[3, 7, 1]].sum(0),
        np.zeros(8),
        np.asarray(table)[[0, 2]].sum(0),
        np.asarray(table)[[9, 9]].sum(0),
    ])
    np.testing.assert_allclose(np.asarray(out), expect, atol=1e-6)
    out_mean = R.embedding_bag(table, indices, offsets, mode="mean")
    np.testing.assert_allclose(np.asarray(out_mean)[0], expect[0] / 3, atol=1e-6)


def test_xdeepfm_forward_and_grad():
    cfg = R.XDeepFMConfig(n_fields=4, embed_dim=4, cin_layers=(6, 6),
                          mlp_dims=(8,), field_vocabs=(16, 16, 8, 8))
    params = R.xdeepfm_init(cfg, jax.random.PRNGKey(0))
    ids = jnp.asarray(np.random.default_rng(0).integers(0, 8, (10, 4)), jnp.int32)
    batch = {"field_ids": ids,
             "labels": jnp.asarray(np.random.default_rng(1).integers(0, 2, 10),
                                   jnp.float32)}
    loss, _ = R.xdeepfm_loss(params, batch, cfg)
    g = jax.grad(lambda p: R.xdeepfm_loss(p, batch, cfg)[0])(params)
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(x))) for x in jax.tree.leaves(g))


def test_s5p_row_placement_replicates_hot_rows():
    """The paper's technique on the embedding-access bipartite graph:
    hot rows end up with more replicas than cold rows."""
    rng = np.random.default_rng(0)
    n_rows, n_samples = 64, 800
    rows = (rng.zipf(1.3, n_samples * 4) % n_rows).astype(np.int64)
    samples = np.repeat(np.arange(n_samples), 4)
    shard, mat = R.s5p_row_placement(rows, samples, n_rows, k=4)
    counts = np.bincount(rows, minlength=n_rows)
    hot = counts.argsort()[-8:]
    cold = counts.argsort()[:8]
    assert mat[hot].sum(1).mean() >= mat[cold].sum(1).mean()
