"""Count-min sketch: one-sided error, ε-bound, mergeability, pair keys."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases
from repro.core.cms import (
    cms_merge, cms_query, cms_update, make_sketch, pair_key, suggest_params,
)


def test_suggested_params_match_paper():
    w, d = suggest_params(0.1, 0.01)
    assert w == 28 and d == 5  # ⌈e/0.1⌉, ⌈ln 100⌉


@pytest.mark.parametrize("seed", list(cases(6)))
def test_one_sided_error(seed):
    """CMS never under-counts, and over-counts ≤ ε·N w.h.p."""
    rng = np.random.default_rng(seed)
    n_keys = 200
    keys = rng.integers(0, 2**31, n_keys).astype(np.uint32)
    counts = rng.integers(1, 20, n_keys).astype(np.uint32)
    sk = make_sketch(256, 5, seed=seed)
    sk = cms_update(sk, jnp.asarray(keys), jnp.asarray(counts))
    est = np.asarray(cms_query(sk, jnp.asarray(keys)))
    # aggregate exact counts per distinct key (collisions in draw possible)
    exact = {}
    for k, c in zip(keys.tolist(), counts.tolist()):
        exact[k] = exact.get(k, 0) + c
    truth = np.array([exact[k] for k in keys.tolist()])
    assert np.all(est >= truth), "CMS must never under-count"
    total = counts.sum()
    eps = np.e / 256
    viol = np.mean(est - truth > eps * total)
    assert viol < 0.05


def test_mergeable():
    """merge(update(A), update(B)) == update(A ++ B) — the psum property."""
    keys = jnp.arange(100, dtype=jnp.uint32) * 7919
    sk0 = make_sketch(64, 4, seed=3)
    a = cms_update(sk0, keys[:50])
    b = cms_update(sk0, keys[50:])
    merged = cms_merge(a, b)
    direct = cms_update(sk0, keys)
    assert jnp.all(merged.table == direct.table)


def test_pair_key_symmetric():
    a = jnp.array([3, 9, 100], jnp.int32)
    b = jnp.array([9, 3, 100], jnp.int32)
    assert jnp.all(pair_key(a, b) == pair_key(b, a))
    # distinct pairs should (almost surely) hash apart
    k1 = pair_key(jnp.array([1]), jnp.array([2]))
    k2 = pair_key(jnp.array([1]), jnp.array([3]))
    assert int(k1[0]) != int(k2[0])
