"""Mini property-based harness (hypothesis is not installed offline).

``cases(n)`` yields seeded RNGs; ``random_graph`` draws structurally
diverse graphs (power-law / community / uniform / star / path / tiny)
so every invariant is exercised across the regimes hypothesis would
explore.  Failures print the seed for exact replay.
"""

from __future__ import annotations

import numpy as np

from repro.graphs import powerlaw_graph, erdos_renyi_graph
from repro.graphs.generators import community_graph


def cases(n: int, base_seed: int = 0):
    for i in range(n):
        yield base_seed + i


def random_graph(seed: int):
    """Deterministic diverse graph draw: (src, dst, n_vertices, label)."""
    rng = np.random.default_rng(seed)
    kind = seed % 6
    # sizes are deliberately modest: structural diversity, not scale, is
    # what exercises the invariants — scale lives in benchmarks/ (slow)
    if kind == 0:
        n = int(rng.integers(50, 220))
        return (*powerlaw_graph(n, avg_degree=float(rng.uniform(3, 8)),
                                rho=float(rng.uniform(1.8, 2.8)), seed=seed), "powerlaw")
    if kind == 1:
        n = int(rng.integers(80, 260))
        return (*community_graph(n, n_communities=int(rng.integers(2, 16)),
                                 avg_degree=6.0, seed=seed), "community")
    if kind == 2:
        n = int(rng.integers(50, 160))
        return (*erdos_renyi_graph(n, avg_degree=5.0, seed=seed), "uniform")
    if kind == 3:  # star: one extreme hub (max skew)
        n = int(rng.integers(20, 80))
        src = np.zeros(n - 1, np.int32)
        dst = np.arange(1, n, dtype=np.int32)
        return src, dst, n, "star"
    if kind == 4:  # path: zero skew
        n = int(rng.integers(20, 100))
        src = np.arange(0, n - 1, dtype=np.int32)
        dst = np.arange(1, n, dtype=np.int32)
        return src, dst, n, "path"
    # tiny random multigraph-ish
    n = int(rng.integers(4, 12))
    m = int(rng.integers(3, 20))
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    return src, dst, n, "tiny"
