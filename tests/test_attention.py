"""Flash attention (custom VJP): forward + gradients vs direct softmax."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention, flash_attention


def _direct(q, k, v, q_pos, kv_pos, causal, window):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.astype(jnp.float32).reshape(B, S, KV, G, hd) * (hd ** -0.5)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    dp = q_pos[:, :, None] - kv_pos[:, None, :]
    ok = kv_pos[:, None, :] >= 0
    if causal:
        ok = ok & (dp >= 0)
    if window is not None:
        ok = ok & (dp < window)
    s = jnp.where(ok[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("shape", [(1, 64, 4, 2, 16), (2, 96, 6, 3, 8)])
def test_forward_matches_direct(shape, window):
    B, S, H, KV, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = flash_attention(q, k, v, pos, pos, True, window, 32, 32)
    ref = _direct(q, k, v, pos, pos, True, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [None, 24])
def test_gradients_match_direct(window):
    B, S, H, KV, hd = 1, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, pos, pos, True, window, 32, 32)))

    def f_direct(q, k, v):
        return jnp.sum(jnp.sin(_direct(q, k, v, pos, pos, True, window)))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_direct, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   err_msg=f"grad d{name}")


def test_decode_matches_flash_last_position():
    """Decoding one token == the last row of a full causal forward."""
    B, S, H, KV, hd = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full = flash_attention(q, k, v, pos, pos, True, None, 16, 16)
    dec = decode_attention(q[:, -1:], k, v, pos[:, -1:], pos)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-5)


def test_rolling_cache_positions():
    """Out-of-order kv positions (rolling SWA cache) still mask correctly."""
    B, H, KV, hd, W = 1, 2, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    k = jax.random.normal(ks[0], (B, W, KV, hd), jnp.float32)
    v = jax.random.normal(ks[1], (B, W, KV, hd), jnp.float32)
    q = jax.random.normal(ks[2], (B, 1, H, hd), jnp.float32)
    # rolling cache: physical slot i holds logical position perm[i]
    perm = jnp.asarray(np.random.default_rng(0).permutation(W))
    qpos = jnp.full((B, 1), W - 1, jnp.int32)
    out_rolled = decode_attention(q, k[:, jnp.argsort(perm)][:, perm][:, :] if False
                                  else k, v, qpos, perm[None], window=W)
    # same content presented in sorted order must give the same answer
    order = jnp.argsort(perm)
    out_sorted = decode_attention(q, k[:, order], v[:, order], qpos,
                                  perm[order][None], window=W)
    np.testing.assert_allclose(np.asarray(out_rolled), np.asarray(out_sorted),
                               atol=2e-5)
