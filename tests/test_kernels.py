"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cms import cms_query, cms_update, make_sketch
from repro.kernels.cin import cin_layer_kernel, cin_layer_ref
from repro.kernels.cms_sketch import cms_query_kernel, cms_update_kernel
from repro.kernels.flash_attention import attention_ref, flash_attention_tpu
from repro.kernels.segment_agg import segment_agg_ref, segment_aggregate


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 128, 4, 2, 64),   # small GQA
    (2, 256, 8, 8, 64),   # MHA (G=1)
    (1, 200, 6, 2, 32),   # ragged (padding path)
])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_kernel_sweep(shape, dtype, window):
    B, S, H, KV, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(hash((shape, window)) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd)).astype(dtype)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = flash_attention_tpu(q, k, v, pos, pos, causal=True, window=window,
                              block_q=64, block_k=64)
    G = H // KV
    qk = q.reshape(B, S, KV, G, hd).transpose(0, 2, 1, 3, 4).reshape(B * KV, S, G * hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    pp = jnp.repeat(pos, KV, axis=0)
    ref = attention_ref(qk, kk, vk, pp, pp, causal=True, window=window)
    ref = ref.reshape(B, KV, S, G, hd).transpose(0, 2, 1, 3, 4).reshape(B, S, H, hd)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("width,depth,n", [(64, 4, 1000), (256, 5, 5000),
                                           (32, 3, 100)])
def test_cms_kernel_bit_exact(width, depth, n):
    sk = make_sketch(width, depth, seed=width)
    keys = jax.random.randint(jax.random.PRNGKey(n), (n,), 0, 2**31 - 1
                              ).astype(jnp.uint32)
    ref = cms_update(sk, keys)
    out = cms_update_kernel(sk, keys)
    assert jnp.all(ref.table == out.table)
    q = keys[: min(n, 500)]
    assert jnp.all(cms_query(ref, q) == cms_query_kernel(out, q))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("V,E,d", [(200, 1000, 32), (513, 4097, 64), (64, 100, 16)])
def test_segment_agg_sweep(V, E, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(V + E), 4)
    x = jax.random.normal(ks[0], (V, d)).astype(dtype)
    src = jax.random.randint(ks[1], (E,), 0, V, dtype=jnp.int32)
    dst = jax.random.randint(ks[2], (E,), 0, V, dtype=jnp.int32)
    w = jax.random.uniform(ks[3], (E,))
    out = segment_aggregate(x, src, dst, w, V)
    ref = segment_agg_ref(x, src, dst, w, V)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hk,m,D,Hn", [(64, 10, 6, 8, 12), (300, 39, 39, 10, 200)])
def test_cin_kernel_sweep(B, Hk, m, D, Hn, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B), 3)
    xk = jax.random.normal(ks[0], (B, Hk, D)).astype(dtype)
    x0 = jax.random.normal(ks[1], (B, m, D)).astype(dtype)
    w = (jax.random.normal(ks[2], (Hk * m, Hn)) * 0.1).astype(dtype)
    out = cin_layer_kernel(xk, x0, w, batch_block=64)
    ref = cin_layer_ref(xk, x0, w)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=1e-2)


# ===========================================================================
# stream_scan megakernel: one dispatch per chunk, insert + retract (sign=±1)
# ===========================================================================

from proptest import cases, random_graph  # noqa: E402
from repro.kernels import stream_scan as ss  # noqa: E402

try:  # optional — the container image has no hypothesis; gate, don't require
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_ON_CPU = jax.default_backend() == "cpu"
K = 4


def _graph(seed, cap=400):
    src, dst, n, label = random_graph(seed)
    return (jnp.asarray(src[:cap], jnp.int32),
            jnp.asarray(dst[:cap], jnp.int32), n, label)


def _scoring_carry(mode, n):
    if mode == "greedy":
        return ss.greedy_init(n, K)
    return ss.hdrf_init(n, K, 1.1)


def _scoring_step_ref(mode, carry, src, dst):
    fn = ss.greedy_chunk if mode == "greedy" else ss.hdrf_chunk
    return fn(carry, src, dst)


def _scoring_step_kernel(mode, carry, src, dst, tiled, block=64):
    if mode == "greedy":
        parts, load, rep, _ = ss.scoring_scan(
            src, dst, carry[0], carry[1], mode=mode, tiled=tiled, block=block)
        return (load, rep), parts
    parts, load, rep, pd = ss.scoring_scan(
        src, dst, carry[0], carry[1], carry[2], carry[3], mode=mode,
        tiled=tiled, block=block)
    return (load, rep, pd, carry[3], carry[4]), parts


def _tree_bitwise(a, b, label=""):
    for i, (x, y) in enumerate(zip(a, b)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), f"{label} leaf {i}"


@pytest.mark.parametrize("tiled", [False, True], ids=["fused", "tiled"])
@pytest.mark.parametrize("mode", ["greedy", "hdrf"])
@pytest.mark.parametrize("seed", list(cases(3)))
def test_scoring_scan_insert_parity(seed, mode, tiled):
    """Megakernel insert is bit-identical to the lax.scan oracle — exact
    counted replica table, not just the 0/1 scoring projection."""
    src, dst, n, label = _graph(seed)
    if src.shape[0] == 0:
        return
    carry = _scoring_carry(mode, n)
    ref_carry, ref_parts = _scoring_step_ref(mode, carry, src, dst)
    out_carry, parts = _scoring_step_kernel(mode, carry, src, dst, tiled)
    assert np.array_equal(np.asarray(parts), np.asarray(ref_parts)), label
    _tree_bitwise(out_carry, ref_carry, label)


@pytest.mark.parametrize("tiled", [False, True], ids=["fused", "tiled"])
@pytest.mark.parametrize("mode", ["greedy", "hdrf"])
@pytest.mark.parametrize("seed", list(cases(3)))
def test_scoring_retract_is_bitwise_inverse(seed, mode, tiled):
    """retract_chunk through the kernel (same kernel, sign=-1) undoes
    step_chunk exactly — the counted-table roundtrip property."""
    src, dst, n, label = _graph(seed)
    E = int(src.shape[0])
    if E == 0:
        return
    carry0 = _scoring_carry(mode, n)
    carry1, parts = _scoring_step_kernel(mode, carry0, src, dst, tiled)
    if mode == "greedy":
        _, load, rep, _ = ss.scoring_scan(
            src, dst, carry1[0], carry1[1], mode=mode, sign=-1, parts=parts,
            n_valid=E, tiled=tiled, block=64)
        back = (load, rep)
    else:
        _, load, rep, pd = ss.scoring_scan(
            src, dst, carry1[0], carry1[1], carry1[2], carry1[3], mode=mode,
            sign=-1, parts=parts, n_valid=E, tiled=tiled, block=64)
        back = (load, rep, pd, carry1[3], carry1[4])
    _tree_bitwise(back, carry0, label)


@pytest.mark.parametrize("mode", ["greedy", "hdrf"])
def test_carry_retract_kernel_matches_oracle(mode):
    """GreedyCarry/HdrfCarry retract through the kernel == the vectorized
    oracle retraction, bitwise (deletion batches chunk arbitrarily)."""
    src, dst, n, _ = _graph(1)
    E = int(src.shape[0])
    pc_k = (ss.GreedyCarry(n, K, use_kernel=True) if mode == "greedy"
            else ss.HdrfCarry(n, K, use_kernel=True))
    pc_o = (ss.GreedyCarry(n, K, use_kernel=False) if mode == "greedy"
            else ss.HdrfCarry(n, K, use_kernel=False))
    carry, parts = pc_k.step_chunk(pc_k.init(), src, dst, jnp.int32(E))
    nv = jnp.int32(max(E - 37, 1))  # partial retraction exercises the limit
    a = pc_k.retract_chunk(carry, src, dst, nv, parts)
    b = pc_o.retract_chunk(carry, src, dst, nv, parts)
    _tree_bitwise(a, b, mode)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st_.data())
    def test_scoring_roundtrip_property(data):
        n = data.draw(st_.integers(4, 40), label="n")
        E = data.draw(st_.integers(1, 120), label="E")
        mode = data.draw(st_.sampled_from(["greedy", "hdrf"]), label="mode")
        edges = st_.integers(0, n - 1)
        src = jnp.asarray(data.draw(st_.lists(edges, min_size=E, max_size=E)),
                          jnp.int32)
        dst = jnp.asarray(data.draw(st_.lists(edges, min_size=E, max_size=E)),
                          jnp.int32)
        carry0 = _scoring_carry(mode, n)
        carry1, parts = _scoring_step_kernel(mode, carry0, src, dst, False)
        if mode == "greedy":
            _, load, rep, _ = ss.scoring_scan(
                src, dst, carry1[0], carry1[1], mode=mode, sign=-1,
                parts=parts, n_valid=E, block=64)
            back = (load, rep)
        else:
            _, load, rep, pd = ss.scoring_scan(
                src, dst, carry1[0], carry1[1], carry1[2], carry1[3],
                mode=mode, sign=-1, parts=parts, n_valid=E, block=64)
            back = (load, rep, pd, carry1[3], carry1[4])
        _tree_bitwise(back, carry0, mode)


# --------------------------------------------------- Alg. 1 / Alg. 3 kernels


@pytest.mark.parametrize("global_tail", [False, True], ids=["s5p", "s5p-b"])
@pytest.mark.parametrize("seed", list(cases(3)))
def test_cluster_scan_parity(seed, global_tail):
    from repro.core.clustering import compute_degrees, init_state

    src, dst, n, label = _graph(seed)
    if src.shape[0] == 0:
        return
    deg = compute_degrees(src, dst, n)
    xi = max(int(np.asarray(deg).mean()), 1)
    kappa = max(2 * int(src.shape[0]) // K, 2)
    s0 = tuple(init_state(n))
    ref = ss.cluster_chunk_oracle(s0, src, dst, deg, xi=xi, kappa=kappa,
                                  global_tail=global_tail)
    out = ss.cluster_scan(s0, src, dst, deg, xi=xi, kappa=kappa,
                          global_tail=global_tail, block=64)
    _tree_bitwise(out, ref, label)


@pytest.mark.parametrize("seed", list(cases(3)))
def test_assign_scan_parity(seed):
    src, dst, n, label = _graph(seed)
    E = int(src.shape[0])
    if E == 0:
        return
    rng = np.random.default_rng(seed)
    n_cl = 8
    c2p = jnp.asarray(rng.integers(0, K, n_cl), jnp.int32)
    cu = jnp.asarray(rng.integers(0, n_cl, E), jnp.int32)
    cv = jnp.asarray(rng.integers(0, n_cl, E), jnp.int32)
    head = jnp.asarray(rng.integers(0, 2, E), jnp.int32)
    load0 = jnp.zeros((K,), jnp.int32)
    L = max(E // (2 * K), 1)  # tight cap: exercise the overflow branches
    ref_load, ref_parts = ss.assign_chunk_oracle(
        load0, jnp.int32(L), src, dst, head, cu, cv, c2p, k=K)
    parts, load = ss.assign_scan(load0, src, dst, head, c2p[cu], c2p[cv],
                                 max_load=L, block=64)
    assert np.array_equal(np.asarray(parts), np.asarray(ref_parts)), label
    assert np.array_equal(np.asarray(load), np.asarray(ref_load))
    # retract through the same kernel == the vectorized oracle
    from repro.core.postprocess import _retract_load

    nv = jnp.int32(max(E - 19, 1))
    _, l2 = ss.assign_scan(load, src, dst, head, c2p[cu], c2p[cv],
                           max_load=L, sign=-1, parts=parts, n_valid=nv,
                           block=64)
    assert np.array_equal(
        np.asarray(l2), np.asarray(_retract_load(load, src, dst, nv, parts)))


def test_cluster_carry_kernel_via_engine():
    """ClusterCarry(use_kernel=True) through run_carry == oracle, bitwise."""
    from repro.core.clustering import ClusterCarry, compute_degrees
    from repro.streaming import EdgeStream, run_carry

    src, dst, n, _ = _graph(2)
    deg = compute_degrees(src, dst, n)
    st = EdgeStream(src, dst, n, chunk_size=128)
    kw = dict(xi=3, kappa=max(int(src.shape[0]) // 2, 2))
    _, a = run_carry(st, ClusterCarry(deg, n, use_kernel=True, **kw))
    _, b = run_carry(st, ClusterCarry(deg, n, use_kernel=False, **kw))
    _tree_bitwise(tuple(a), tuple(b), "cluster engine")


def test_assign_carry_kernel_via_engine():
    """AssignCarry(use_kernel=True) through run_carry == oracle, bitwise."""
    from repro.core.postprocess import AssignCarry
    from repro.streaming import EdgeStream, run_carry

    src, dst, n, _ = _graph(3)
    E = int(src.shape[0])
    rng = np.random.default_rng(3)
    n_cl = 8
    c2p = jnp.asarray(rng.integers(0, K, n_cl), jnp.int32)
    cu = jnp.asarray(rng.integers(0, n_cl, E), jnp.int32)
    cv = jnp.asarray(rng.integers(0, n_cl, E), jnp.int32)
    head = jnp.asarray(rng.integers(0, 2, E), jnp.int32)
    L = max(E // K, 1)
    st = EdgeStream(src, dst, n, chunk_size=128)
    pa, la = run_carry(st, AssignCarry(K, L, c2p, use_kernel=True),
                       head, cu, cv)
    pb, lb = run_carry(st, AssignCarry(K, L, c2p, use_kernel=False),
                       head, cu, cv)
    assert np.array_equal(np.asarray(pa), np.asarray(pb))
    assert np.array_equal(np.asarray(la), np.asarray(lb))


# --------------------------------------------------- VMEM ladder + logging


def test_vmem_budget_resolution(monkeypatch):
    monkeypatch.delenv(ss.VMEM_BUDGET_ENV, raising=False)
    assert ss.vmem_budget() == ss.DEFAULT_VMEM_BUDGET
    monkeypatch.setenv(ss.VMEM_BUDGET_ENV, "123456")
    assert ss.vmem_budget() == 123456
    assert ss.vmem_budget(777) == 777  # explicit beats env


def test_select_path_gate_boundaries():
    V, k, chunk = 100, 4, 64
    state = ss.scoring_state_bytes(V, k, "hdrf")
    ids = 2 * chunk * 4
    assert ss.select_path(V, k, chunk, mode="hdrf",
                          budget=state + ids) == "fused"
    assert ss.select_path(V, k, chunk, mode="hdrf",
                          budget=state + ids - 1) == "tiled"
    assert ss.select_path(V, k, chunk, mode="hdrf",
                          budget=ids + k * 4 - 1) == "oracle"
    assert ss.kernel_fits(V, k, chunk, mode="hdrf", budget=state + ids)
    assert not ss.kernel_fits(V, k, chunk, mode="hdrf",
                              budget=state + ids - 1)
    # greedy state is smaller (no partial degrees): same budget, wider gate
    assert ss.scoring_state_bytes(V, k, "greedy") < state
    # cluster ladder has no tiled rung
    cstate = ss.cluster_state_bytes(V)
    assert ss.select_path(V, 1, chunk, consumer="cluster",
                          budget=cstate + ids) == "fused"
    assert ss.select_path(V, 1, chunk, consumer="cluster",
                          budget=cstate + ids - 1) == "oracle"


def test_path_logged_once_per_run(caplog):
    ss.reset_path_log()
    with caplog.at_level("INFO", logger="repro.kernels.stream_scan.ops"):
        ss.select_path(100, 4, 64, mode="greedy", budget=1 << 20)
        ss.select_path(100, 4, 64, mode="greedy", budget=1 << 20)
    hits = [r for r in caplog.records if "greedy" in r.getMessage()]
    assert len(hits) == 1 and "fused" in hits[0].getMessage()
    ss.reset_path_log()
    with caplog.at_level("INFO", logger="repro.kernels.stream_scan.ops"):
        ss.select_path(100, 4, 64, mode="greedy", budget=1 << 20)
    assert len([r for r in caplog.records
                if "greedy" in r.getMessage()]) == 2  # re-armed


def test_ladder_tiled_path_bitwise_via_carry():
    """A budget too small for the fused table (but fine for edge ids)
    forces the tiled rung — results must stay bitwise-oracle."""
    src, dst, n, _ = _graph(0)
    E = int(src.shape[0])
    if E == 0:
        return
    state = ss.scoring_state_bytes(n, K, "hdrf")
    tight = state - 1 + 2 * 65536 * 4  # ids for the default chunk fit
    pc_t = ss.HdrfCarry(n, K, use_kernel=True, vmem_budget=tight)
    pc_o = ss.HdrfCarry(n, K, use_kernel=False)
    ca, pa = pc_t.step_chunk(pc_t.init(), src, dst, jnp.int32(E))
    cb, pb = pc_o.step_chunk(pc_o.init(), src, dst, jnp.int32(E))
    assert np.array_equal(np.asarray(pa), np.asarray(pb))
    _tree_bitwise(ca, cb, "tiled ladder")


def test_dispatch_count_one_per_chunk():
    """The acceptance contract on CPU: 1 pallas_call per chunk (the oracle
    re-materializes the carry per edge inside its scan)."""
    from repro.streaming import EdgeStream, run_carry

    src, dst, n, _ = _graph(1)
    E = int(src.shape[0])
    chunk = 100
    st = EdgeStream(src, dst, n, chunk_size=chunk)
    ss.reset_dispatch_count()
    run_carry(st, ss.GreedyCarry(n, K, use_kernel=True))
    assert ss.dispatch_count() == -(-E // chunk)


# --------------------------------------------------- compiled (accelerator)


@pytest.mark.skipif(_ON_CPU, reason="compiled Pallas needs a TPU/GPU backend")
@pytest.mark.parametrize("mode", ["greedy", "hdrf"])
def test_scoring_scan_compiled_matches_oracle(mode):
    """Accelerator lane: the compiled (non-interpret) megakernel against
    the XLA oracle.  Skips cleanly on CPU-only hosts."""
    src, dst, n, _ = _graph(0)
    if src.shape[0] == 0:
        return
    carry = _scoring_carry(mode, n)
    ref_carry, ref_parts = _scoring_step_ref(mode, carry, src, dst)
    if mode == "greedy":
        parts, load, rep, _ = ss.scoring_scan(
            src, dst, carry[0], carry[1], mode=mode, interpret=False)
        out_carry = (load, rep)
    else:
        parts, load, rep, pd = ss.scoring_scan(
            src, dst, carry[0], carry[1], carry[2], carry[3], mode=mode,
            interpret=False)
        out_carry = (load, rep, pd, carry[3], carry[4])
    assert np.array_equal(np.asarray(parts), np.asarray(ref_parts))
    _tree_bitwise(out_carry, ref_carry, mode)


@pytest.mark.skipif(_ON_CPU, reason="compiled Pallas needs a TPU/GPU backend")
def test_cluster_scan_compiled_matches_oracle():
    from repro.core.clustering import compute_degrees, init_state

    src, dst, n, _ = _graph(1)
    if src.shape[0] == 0:
        return
    deg = compute_degrees(src, dst, n)
    s0 = tuple(init_state(n))
    kw = dict(xi=3, kappa=max(int(src.shape[0]) // 2, 2))
    ref = ss.cluster_chunk_oracle(s0, src, dst, deg, **kw)
    out = ss.cluster_scan(s0, src, dst, deg, interpret=False, **kw)
    _tree_bitwise(out, ref, "cluster compiled")
