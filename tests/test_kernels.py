"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cms import cms_query, cms_update, make_sketch
from repro.kernels.cin import cin_layer_kernel, cin_layer_ref
from repro.kernels.cms_sketch import cms_query_kernel, cms_update_kernel
from repro.kernels.flash_attention import attention_ref, flash_attention_tpu
from repro.kernels.segment_agg import segment_agg_ref, segment_aggregate


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 128, 4, 2, 64),   # small GQA
    (2, 256, 8, 8, 64),   # MHA (G=1)
    (1, 200, 6, 2, 32),   # ragged (padding path)
])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_kernel_sweep(shape, dtype, window):
    B, S, H, KV, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(hash((shape, window)) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd)).astype(dtype)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = flash_attention_tpu(q, k, v, pos, pos, causal=True, window=window,
                              block_q=64, block_k=64)
    G = H // KV
    qk = q.reshape(B, S, KV, G, hd).transpose(0, 2, 1, 3, 4).reshape(B * KV, S, G * hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    pp = jnp.repeat(pos, KV, axis=0)
    ref = attention_ref(qk, kk, vk, pp, pp, causal=True, window=window)
    ref = ref.reshape(B, KV, S, G, hd).transpose(0, 2, 1, 3, 4).reshape(B, S, H, hd)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("width,depth,n", [(64, 4, 1000), (256, 5, 5000),
                                           (32, 3, 100)])
def test_cms_kernel_bit_exact(width, depth, n):
    sk = make_sketch(width, depth, seed=width)
    keys = jax.random.randint(jax.random.PRNGKey(n), (n,), 0, 2**31 - 1
                              ).astype(jnp.uint32)
    ref = cms_update(sk, keys)
    out = cms_update_kernel(sk, keys)
    assert jnp.all(ref.table == out.table)
    q = keys[: min(n, 500)]
    assert jnp.all(cms_query(ref, q) == cms_query_kernel(out, q))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("V,E,d", [(200, 1000, 32), (513, 4097, 64), (64, 100, 16)])
def test_segment_agg_sweep(V, E, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(V + E), 4)
    x = jax.random.normal(ks[0], (V, d)).astype(dtype)
    src = jax.random.randint(ks[1], (E,), 0, V, dtype=jnp.int32)
    dst = jax.random.randint(ks[2], (E,), 0, V, dtype=jnp.int32)
    w = jax.random.uniform(ks[3], (E,))
    out = segment_aggregate(x, src, dst, w, V)
    ref = segment_agg_ref(x, src, dst, w, V)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hk,m,D,Hn", [(64, 10, 6, 8, 12), (300, 39, 39, 10, 200)])
def test_cin_kernel_sweep(B, Hk, m, D, Hn, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B), 3)
    xk = jax.random.normal(ks[0], (B, Hk, D)).astype(dtype)
    x0 = jax.random.normal(ks[1], (B, m, D)).astype(dtype)
    w = (jax.random.normal(ks[2], (Hk * m, Hn)) * 0.1).astype(dtype)
    out = cin_layer_kernel(xk, x0, w, batch_block=64)
    ref = cin_layer_ref(xk, x0, w)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=1e-2)
