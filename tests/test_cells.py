"""Per-arch smoke tests: every (arch × shape) cell runs one real step on
CPU with the reduced config — output shapes correct, no NaNs (deliverable f)."""

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.launch.cells import build_cell

CELLS = [
    (a.name, s) for a in REGISTRY.values() for s in a.shapes if s not in a.skips
]

# multi-second compiles on CPU; still smoked in the `-m slow` CI lane
_SLOW_ARCHS = {"qwen2.5-14b", "qwen3-14b", "mixtral-8x7b", "mixtral-8x22b",
               "xdeepfm", "schnet", "dimenet"}


@pytest.mark.parametrize(
    "arch,shape",
    [pytest.param(a, s, marks=[pytest.mark.slow] if a in _SLOW_ARCHS else [])
     for a, s in CELLS],
    ids=[f"{a}-{s}" for a, s in CELLS],
)
def test_cell_smoke(arch, shape):
    cell = build_cell(arch, shape, smoke=True)
    key = jax.random.PRNGKey(0)
    state = cell.init_state(key)
    batch = cell.make_batch(key)
    out = cell.step_fn(state, *batch)
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "dtype") and np.issubdtype(leaf.dtype, np.floating):
            assert np.all(np.isfinite(np.asarray(leaf, np.float32))), \
                f"non-finite output in {arch}×{shape}"


def test_skips_documented():
    """Every skipped cell carries a reason (DESIGN.md §4 contract)."""
    for a in REGISTRY.values():
        for s, why in a.skips.items():
            assert s in a.shapes and len(why) > 10


def test_lm_train_loss_decreases():
    """Three steps of the smoke llama train cell reduce the loss."""
    cell = build_cell("llama3-8b", "train_4k", smoke=True)
    key = jax.random.PRNGKey(0)
    state = cell.init_state(key)
    batch = cell.make_batch(key)  # overfit one batch
    step = jax.jit(cell.step_fn)
    losses = []
    for _ in range(5):
        state, metrics = step(state, *batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
