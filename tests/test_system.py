"""End-to-end behaviour: partition → GAS deploy → comm win (the paper's
full pipeline), plus sampler and postprocess invariants."""

import numpy as np
import pytest

from proptest import cases, random_graph
from repro.core import S5PConfig, s5p_partition, gas_comm_bytes
from repro.core.baselines import hash_partition, dbh_partition
from repro.gas import build_gas_graph, pagerank
from repro.graphs import build_csr, NeighborSampler
from repro.graphs.generators import community_graph


def test_end_to_end_partition_then_pagerank():
    """The paper's deployment story (§6.6): S5P → PowerGraph-style engine →
    lower comm than hash/DBH at equal PageRank results."""
    src, dst, n = community_graph(1500, n_communities=24, avg_degree=8, seed=11)
    k = 8
    results = {}
    values = {}
    for name, parts in (
        ("hash", hash_partition(src, dst, n, k)),
        ("dbh", dbh_partition(src, dst, n, k)),
        ("s5p", s5p_partition(src, dst, n, S5PConfig(k=k)).parts),
    ):
        g = build_gas_graph(src, dst, parts, n, k)
        vals, stats = pagerank(g, iterations=5)
        results[name] = stats.total_bytes()
        values[name] = np.asarray(vals)
    # same answer regardless of partitioning
    np.testing.assert_allclose(values["s5p"], values["hash"], rtol=1e-4)
    # S5P communicates least (the paper's Fig. 11 claim)
    assert results["s5p"] < results["hash"]
    assert results["s5p"] < results["dbh"]


@pytest.mark.parametrize("seed", list(cases(3)))
def test_neighbor_sampler_valid(seed):
    src, dst, n, _ = random_graph(seed)
    if len(src) < 20:
        return
    csr = build_csr(src, dst, n)
    sampler = NeighborSampler(csr, fanouts=(3, 2), batch_nodes=4, seed=seed)
    sub = sampler.sample()
    n_real_edges = int(sub.edge_mask.sum())
    # every sampled edge exists in the symmetrized graph
    edge_set = set()
    for u, v in zip(src.tolist(), dst.tolist()):
        edge_set.add((u, v))
        edge_set.add((v, u))
    for i in range(n_real_edges):
        gu = int(sub.nodes[sub.edge_src[i]])
        gv = int(sub.nodes[sub.edge_dst[i]])
        assert (gu, gv) in edge_set
    # fanout budget respected
    assert n_real_edges <= 4 * 3 + 4 * 3 * 2
    assert sub.nodes.shape[0] == sampler.max_nodes


def test_postprocess_respects_capacity():
    from repro.core.postprocess import assign_edges_stream
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    E, k, C = 1000, 4, 10
    src = jnp.asarray(rng.integers(0, 100, E), jnp.int32)
    dst = jnp.asarray(rng.integers(100, 200, E), jnp.int32)
    cu = jnp.asarray(rng.integers(0, C, E), jnp.int32)
    cv = jnp.asarray(rng.integers(0, C, E), jnp.int32)
    c2p = jnp.asarray(rng.integers(0, k, C), jnp.int32)
    is_head = jnp.asarray(rng.random(E) < 0.3)
    L = int(np.ceil(E / k))
    parts, load = assign_edges_stream(src, dst, is_head, cu, cv, c2p, k, L)
    assert int(jnp.max(load)) <= L + 1
    assert int(jnp.sum(load)) == E
