"""Fault/straggler hardening of the parallel ingest path.

The recovery contract under test:

- *kill-a-lane replay* — a lane that dies mid-super-chunk is detected at
  the merge barrier and its chunk range replayed into a surviving worker
  from the last committed merge base; lanes only publish state at merge
  points, so the recovered drive is **bit-identical** to the unkilled
  one — in memory and through on-disk :class:`CarryStore` checkpoints;
- *straggler handoff* — the monitor's ``rebalance_plan`` moves a tail
  cut of a slow lane's remaining chunks to the fastest lane live, with
  edge conservation (regrouping drifts within the lane-count staleness
  envelope, so quality — not bit-identity — is the invariant);
- *loop hardening satellites* — ``FaultTolerantLoop`` resumes bitwise
  from the *entry* state when it dies before the first checkpoint, and
  attributes per-step times to lanes through ``shard_fn`` so multi-lane
  straggler detection actually sees lanes.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.incremental.store import CarryStore
from repro.kernels.stream_scan import GreedyCarry, HdrfCarry
from repro.optim import AdamWConfig, adamw_update, init_state
from repro.runtime import (
    FaultInjector,
    FaultTolerantLoop,
    LaneFaultInjector,
    StragglerMonitor,
)
from repro.streaming import EdgeStream, ParallelEdgeStream, run_parallel
from repro.streaming.parallel import _handoff_lanes

V, E, K = 500, 8000, 8


def _graph(seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, V, E).astype(np.int32),
            rng.integers(0, V, E).astype(np.int32))


def _drive(pc, src, dst, **kw):
    st = EdgeStream(src, dst, V, chunk_size=256)
    parts, carry = run_parallel(st, pc, num_streams=4, super_chunk=2,
                                backend="threads", **kw)
    return np.asarray(parts), carry


# ================================================== kill-a-lane replay
@pytest.mark.parametrize("name", ["greedy", "hdrf"])
def test_lane_replay_bit_identical(name):
    src, dst = _graph()
    make = (lambda: GreedyCarry(V, K)) if name == "greedy" else \
        (lambda: HdrfCarry(V, K, 1.1))
    p0, _ = _drive(make(), src, dst)
    # kill lane 1 mid-way through the second super-chunk
    inj = LaneFaultInjector(fail_at=[(1, 11)])
    p1, _ = _drive(make(), src, dst, on_lane_failure="replay",
                   lane_injector=inj)
    assert inj.fired == [(1, 11)]  # the failure actually happened
    np.testing.assert_array_equal(p0, p1)


@pytest.mark.parametrize("name", ["greedy", "hdrf"])
def test_hub_lane_replay_bit_identical(name):
    """Lane death under hub pinning: the replayed lane re-folds its own
    pinned chunk registry (the plan is deterministic), so every hub's
    edges stay on their rendezvous lane and the drive is bit-identical."""
    src, dst = _graph(3)
    # the plan is a pure function of the stream, so a probe instance sees
    # the same synthetic chunk ids the drive will build internally
    probe = ParallelEdgeStream(EdgeStream(src, dst, V, chunk_size=256), 4,
                               shard="hub")
    assert probe.n_hubs > 0  # the graph actually exercises pinning
    fail_cid = probe.lanes[1][2]  # lane 1, mid second super-chunk
    make = (lambda: GreedyCarry(V, K)) if name == "greedy" else \
        (lambda: HdrfCarry(V, K, 1.1))
    p0, _ = _drive(make(), src, dst, shard="hub")
    inj = LaneFaultInjector(fail_at=[(1, fail_cid)])
    p1, _ = _drive(make(), src, dst, shard="hub", on_lane_failure="replay",
                   lane_injector=inj)
    assert inj.fired == [(1, fail_cid)]
    np.testing.assert_array_equal(p0, p1)


def test_lane_replay_from_carrystore_checkpoint(tmp_path):
    """With a CarryStore the merge bases are checkpointed and the replay
    restores from disk — two kills in different super-chunks both
    recover bit-identically."""
    src, dst = _graph(1)
    p0, c0 = _drive(GreedyCarry(V, K), src, dst)
    store = CarryStore(tmp_path)
    inj = LaneFaultInjector(fail_at=[(1, 11), (3, 29)])
    p1, c1 = _drive(GreedyCarry(V, K), src, dst, on_lane_failure="replay",
                    lane_injector=inj, carry_store=store)
    assert inj.fired == [(1, 11), (3, 29)]
    np.testing.assert_array_equal(p0, p1)
    for a, b in zip(c0, c1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # checkpoints actually landed on disk, keyed to this consumer
    _, meta = store.load(like=c0, consumer="parallel:GreedyCarry",
                         max_stream_pos=E)
    assert int(meta["stream_pos"]) > 0


def test_lane_failure_raise_mode_propagates():
    src, dst = _graph()
    inj = LaneFaultInjector(fail_at=[(0, 0)])
    with pytest.raises(RuntimeError, match="injected lane 0"):
        _drive(GreedyCarry(V, K), src, dst, lane_injector=inj)


def test_fault_path_rejected_off_threads_backend():
    src, dst = _graph()
    st = EdgeStream(src, dst, V, chunk_size=256)
    with pytest.raises(ValueError, match="threads"):
        run_parallel(st, GreedyCarry(V, K), num_streams=4, backend="vmap",
                     on_lane_failure="replay")
    with pytest.raises(ValueError, match="on_lane_failure"):
        run_parallel(st, GreedyCarry(V, K), num_streams=4,
                     backend="threads", on_lane_failure="retry")


# ================================================== straggler handoff
def test_straggler_handoff_moves_chunks_and_conserves_edges():
    src, dst = _graph(2)
    mon = StragglerMonitor(threshold=1.01)
    # pre-seed lane 2 as the straggler (EMAs persist across drives — the
    # monitor is how operators carry observed lane speeds in)
    for s in range(4):
        mon.record(0, 100.0 if s == 2 else 1.0, shard=s)
    assert mon.stragglers() == [2]
    p, carry = _drive(GreedyCarry(V, K), src, dst, straggler=mon)
    # every edge still placed exactly once, in range
    assert p.shape == (E,)
    placed = p >= 0
    np.testing.assert_array_equal(
        np.asarray(carry[0]), np.bincount(p[placed], minlength=K))
    # the drive recorded per-lane times on top of the seed
    lanes_seen = {h[1] for h in mon.history}
    assert lanes_seen == {0, 1, 2, 3}
    assert len(mon.history) > 4


def test_hub_handoff_moves_whole_hubs_and_pins():
    """Hub-granular handoff: the straggler's tail cut re-slices at a
    whole-hub boundary, the moved hubs' ``pin_map`` entries follow the
    edges, and the union of lane registries still partitions the edge
    set exactly."""
    src, dst = _graph(4)
    ps = ParallelEdgeStream(EdgeStream(src, dst, V, chunk_size=256), 4,
                            shard="hub")
    assert ps.n_hubs > 0
    pins_before = dict(ps.pin_map)
    mon = StragglerMonitor(threshold=1.01)
    for s in range(4):
        mon.record(0, 100.0 if s == 1 else 1.0, shard=s)
    lanes = [list(l) for l in ps.lanes]
    pos = [0, 0, 0, 0]
    _handoff_lanes(ps, lanes, pos, mon)
    assert lanes != [list(l) for l in ps.lanes]  # something moved
    # edge conservation: the re-registered chunks still partition 0..E-1
    allpos = np.concatenate(
        [ps._chunk_pos[c] for lane in lanes for c in lane])
    np.testing.assert_array_equal(np.sort(allpos), np.arange(E))
    # pinning invariant: each hub's edges live wholly on its pinned lane
    lane_of = np.empty(E, np.int32)
    for s, lane in enumerate(lanes):
        for c in lane:
            lane_of[ps._chunk_pos[c]] = s
    pv = ps._pin_vertex
    for v, lane in ps.pin_map.items():
        assert np.all(lane_of[pv == v] == lane), f"hub {v} split"
    # the moved hubs were re-pinned to the fastest (receiving) lane,
    # never to another straggler
    moved = {v for v in pins_before if ps.pin_map[v] != pins_before[v]}
    assert all(pins_before[v] == 1 for v in moved)  # only straggler gave
    assert all(ps.pin_map[v] == 0 for v in moved)  # fastest received


def test_hub_straggler_handoff_live_drive_conserves_placement():
    src, dst = _graph(5)
    mon = StragglerMonitor(threshold=1.01)
    for s in range(4):
        mon.record(0, 100.0 if s == 2 else 1.0, shard=s)
    p, carry = _drive(GreedyCarry(V, K), src, dst, shard="hub",
                      straggler=mon)
    assert p.shape == (E,)
    placed = p >= 0
    np.testing.assert_array_equal(
        np.asarray(carry[0]), np.bincount(p[placed], minlength=K))
    assert len({h[1] for h in mon.history}) == 4


def test_straggler_monitor_multi_lane_trace():
    """Satellite: shard ids survive into the monitor — a multi-lane
    trace flags exactly the slow lanes and the plan moves their tails to
    the fastest lane."""
    mon = StragglerMonitor(threshold=1.5)
    for step in range(30):
        for s in range(4):
            dt = {0: 1.0, 1: 1.1, 2: 4.0, 3: 1.2}[s]
            mon.record(step, dt, shard=s)
    assert mon.n_shards == 4  # auto-grown from shard ids
    # median of the EMAs is ~1.15: only lane 2 crosses 1.5x
    assert mon.stragglers() == [2]
    ranges = [(0, 40), (40, 80), (80, 120), (120, 160)]
    plan = mon.rebalance_plan(ranges, give_frac=0.25)
    assert plan[2] == (80, 110)  # straggler gave up 25 % of its tail
    assert plan[0] == (0, 50)  # fastest lane absorbed it
    assert plan[1] == (40, 80) and plan[3] == (120, 160)
    assert sum(hi - lo for lo, hi in plan) == 160


def test_straggler_record_default_shard_zero():
    mon = StragglerMonitor()
    mon.record(0, 1.0)
    assert mon.n_shards == 1 and mon.history == [(0, 0, 1.0)]


# ================================================== FaultTolerantLoop
def _make_loop_parts(tmp_path, **kw):
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)

    def step_fn(state, batch):
        grads = {"w": 2 * (state.params["w"] - batch)}
        return adamw_update(state, grads, cfg), {"loss": jnp.float32(0)}

    def data_fn(step):
        return jnp.float32(np.sin(step))

    manager = CheckpointManager(tmp_path, keep=2, async_write=False)
    return FaultTolerantLoop(step_fn, data_fn, manager, **kw), step_fn


def test_loop_restart_before_first_checkpoint_is_exact(tmp_path):
    """Satellite: a failure *before the first checkpoint exists* must
    replay from the entry state, not keep the crashed attempt's mutated
    state (which would double-apply the pre-crash steps)."""
    loop, _ = _make_loop_parts(tmp_path / "clean", ckpt_every=100)
    state0 = init_state({"w": jnp.zeros(3)})
    clean, step, _ = loop.run(state0, 8)

    loop2, _ = _make_loop_parts(tmp_path / "faulty", ckpt_every=100,
                                injector=FaultInjector([5]))
    faulty, step2, _ = loop2.run(init_state({"w": jnp.zeros(3)}), 8)
    assert loop2.restarts == 1 and step == step2 == 8
    np.testing.assert_array_equal(np.asarray(clean.params["w"]),
                                  np.asarray(faulty.params["w"]))
    np.testing.assert_array_equal(np.asarray(clean.mu["w"]),
                                  np.asarray(faulty.mu["w"]))


def test_loop_shard_fn_attributes_lanes(tmp_path):
    """Satellite: the loop's step times land on the lane ``shard_fn``
    names, not all on shard 0."""
    mon = StragglerMonitor(threshold=1.5)
    loop, _ = _make_loop_parts(tmp_path, ckpt_every=4,
                               straggler_monitor=mon,
                               shard_fn=lambda step: step % 3)
    loop.run(init_state({"w": jnp.zeros(3)}), 9)
    assert mon.n_shards == 3
    shards = [h[1] for h in mon.history]
    assert shards == [s % 3 for s in range(9)]
