"""Sliding-window streams + decremental partitioning end-to-end.

Layers:

1. *SlidingWindowStream* — event bookkeeping: inserts cover the stream
   exactly once in arrival order, expiry is FIFO, the live window is
   always the last W arrivals; OOC manifests stream identically; only
   natural ordering is accepted.
2. *Scan deletion* — greedy's counted retraction is exact end-to-end:
   ingest the full stream then delete the suffix ⇒ the carry equals the
   prefix-only cold start **bitwise** (and the driver's tombstones mark
   exactly the deleted edges).
3. *S5P window* — the warm chain maintains exactly the live window
   (tombstoned parts outside, valid partitions inside), retractions count
   toward drift, compaction keeps the combined id space bounded and
   preserves the partition, and the ξ/κ refresh signal fires under
   degree-shifting churn.
4. *Slow lane* — the churn quality band: steady-state sliding-window RF
   within 1.10× of a cold re-partition of the same window contents.
"""

import numpy as np
import pytest

from proptest import random_graph
from repro.core import S5PConfig, replication_factor, s5p_partition
from repro.incremental import (
    compact_bundle,
    run_incremental,
    s5p_apply_deletion,
    s5p_cold_bundle,
    s5p_sliding_window,
)
from repro.incremental.driver import cold_start
from repro.incremental.store import CarryStore
from repro.streaming import EdgeStream, SlidingWindowStream, write_shards
from repro.streaming.oocstream import ShardedEdgeStream

K = 4


# ================================================ 1. window stream events
def test_window_events_cover_stream_fifo():
    src, dst, n, _ = random_graph(1)
    if len(src) < 64:
        pytest.skip("graph too small")
    st = EdgeStream(src, dst, n, chunk_size=1 << 16)
    W, B = 40, 16
    sw = SlidingWindowStream(st, W, step_edges=B)
    seen, expired = [], []
    for ev in sw.events():
        assert ev.start == len(seen)
        seen.extend(range(ev.start, ev.start + len(ev.src)))
        np.testing.assert_array_equal(ev.src, src[ev.start:ev.hi])
        np.testing.assert_array_equal(ev.expire_src, src[ev.expire_idx])
        np.testing.assert_array_equal(ev.expire_dst, dst[ev.expire_idx])
        expired.extend(ev.expire_idx.tolist())
        # live window is exactly the last W arrivals (fewer while filling)
        assert ev.hi - ev.lo == min(ev.hi, W)
        assert expired == list(range(ev.lo))
    assert seen == list(range(len(src)))
    assert sw.n_steps == len(list(sw.events()))


def test_window_stream_ooc_matches_in_memory(tmp_path):
    src, dst, n, _ = random_graph(1)
    if len(src) < 64:
        pytest.skip("graph too small")
    man = write_shards(tmp_path, src, dst, shard_edges=32, n_vertices=n)
    with ShardedEdgeStream(man, chunk_size=1 << 16) as ooc:
        evs_mem = list(SlidingWindowStream(
            EdgeStream(src, dst, n), 48, step_edges=16).events())
        evs_ooc = list(SlidingWindowStream(ooc, 48, step_edges=16).events())
    assert len(evs_mem) == len(evs_ooc)
    for a, b in zip(evs_mem, evs_ooc):
        np.testing.assert_array_equal(a.src, b.src)
        np.testing.assert_array_equal(a.dst, b.dst)
        np.testing.assert_array_equal(a.expire_idx, b.expire_idx)
        assert (a.lo, a.hi) == (b.lo, b.hi)


def test_window_stream_validation():
    src, dst, n, _ = random_graph(0)
    st = EdgeStream(src, dst, n)
    with pytest.raises(ValueError, match="window_edges"):
        SlidingWindowStream(st, 0)
    with pytest.raises(ValueError, match="step_edges"):
        SlidingWindowStream(st, 8, step_edges=0)
    shuffled = EdgeStream(src, dst, n, ordering="shuffled")
    with pytest.raises(ValueError, match="arrival order"):
        SlidingWindowStream(shuffled, 8)


# ================================================== 2. scan deletion
@pytest.mark.parametrize("name", ["greedy", "grid"])
def test_scan_suffix_deletion_equals_prefix_cold_start(name, tmp_path):
    """Exact counted retraction: ingest all, delete the suffix ⇒ the carry
    bitwise-equals a cold start on the prefix alone."""
    import jax

    src, dst, n, _ = random_graph(1)
    if len(src) < 64:
        pytest.skip("graph too small")
    E = len(src)
    cut = int(E * 0.8)
    cold_start(tmp_path / "full", name, src, dst, n, K, chunk_size=37)
    res = run_incremental(tmp_path / "full", name, src, dst, n, K,
                          chunk_size=37,
                          delete=np.arange(cut, E), save=True)
    assert res.n_retracted == E - cut
    # tombstones: deleted parts are -1, prefix parts are untouched
    cold_start(tmp_path / "prefix", name, src[:cut], dst[:cut], n, K,
               chunk_size=37)
    flat_full, _ = CarryStore(tmp_path / "full").load()
    flat_pref, _ = CarryStore(tmp_path / "prefix").load()
    np.testing.assert_array_equal(
        np.asarray(flat_full["parts"])[:cut], flat_pref["parts"])
    assert np.all(np.asarray(flat_full["parts"])[cut:] == -1)
    for key in flat_pref:
        if key in ("parts", "alive"):
            continue
        np.testing.assert_array_equal(
            np.asarray(flat_full[key]), np.asarray(flat_pref[key]),
            err_msg=f"{name}/{key}")


def test_hdrf_deletion_keeps_valid_partitions(tmp_path):
    src, dst, n, _ = random_graph(2)
    if len(src) < 64:
        pytest.skip("graph too small")
    E = len(src)
    rng = np.random.default_rng(0)
    idx = np.sort(rng.choice(E, size=E // 5, replace=False))
    cold_start(tmp_path, "hdrf", src, dst, n, K, chunk_size=41)
    res = run_incremental(tmp_path, "hdrf", src, dst, n, K, chunk_size=41,
                          delete=idx, save=True)
    parts = np.asarray(res.parts)
    assert np.all(parts[idx] == -1)
    live = np.ones(E, bool)
    live[idx] = False
    live &= src != dst
    assert np.all(parts[live] >= 0) and np.all(parts[live] < K)
    # double deletion is rejected, in range is enforced
    with pytest.raises(ValueError, match="already deleted"):
        run_incremental(tmp_path, "hdrf", src, dst, n, K, chunk_size=41,
                        delete=idx[:3], save=False)


# ==================================================== 3. s5p windowing
def _cfg(**kw):
    base = dict(k=K, use_cms=True, seed=0, drift_rf_threshold=0.02,
                drift_churn_threshold=0.2, refine_rounds=8)
    base.update(kw)
    return S5PConfig(**base)


def test_s5p_sliding_window_tracks_live_set():
    src, dst, n, _ = random_graph(1)
    if len(src) < 200:
        pytest.skip("graph too small")
    W, B = 128, 48
    hist, bundle = s5p_sliding_window(src, dst, n, _cfg(), W, step_edges=B)
    assert len(hist) == -(-len(src) // B)
    last = hist[-1]
    alive = np.asarray(bundle["alive"], bool)
    # the live set is exactly the last W arrivals
    expect = np.zeros(last.hi, bool)
    expect[last.lo:last.hi] = True
    np.testing.assert_array_equal(alive, expect)
    parts = np.asarray(bundle["parts"])
    assert np.all(parts[~alive] == -1)
    valid = alive & (src[:last.hi] != dst[:last.hi])
    assert np.all(parts[valid] >= 0) and np.all(parts[valid] < K)
    # expiry counted toward drift in at least one steady step
    assert any(h.n_retracted > 0 for h in hist)
    assert all(h.hi - h.lo <= W for h in hist)


def test_s5p_deletion_decremental_path_counts_churn():
    src, dst, n, _ = random_graph(1)
    if len(src) < 100:
        pytest.skip("graph too small")
    cfg = _cfg(drift_rf_threshold=float("inf"),
               drift_balance_threshold=float("inf"),
               drift_churn_threshold=float("inf"))
    _, b = s5p_cold_bundle(src, dst, n, cfg)
    rng = np.random.default_rng(3)
    idx = np.sort(rng.choice(len(src), size=len(src) // 10, replace=False))
    b2, res = s5p_apply_deletion(b, cfg, src, dst, idx)
    assert not res.rolled_back and not res.refined
    assert res.n_retracted == idx.size
    assert res.churn > 0
    parts = np.asarray(b2["parts"])
    assert np.all(parts[idx] == -1)
    # degrees subtracted exactly
    deg = np.asarray(b["degrees"]).copy()
    np.subtract.at(deg, src[idx], 1)
    np.subtract.at(deg, dst[idx], 1)
    np.testing.assert_array_equal(np.asarray(b2["degrees"]), deg)
    # deleting again raises
    with pytest.raises(ValueError, match="already deleted"):
        s5p_apply_deletion(b2, cfg, src, dst, idx[:1])


def test_compact_bundle_preserves_partition():
    src, dst, n, _ = random_graph(1)
    if len(src) < 100:
        pytest.skip("graph too small")
    cfg = _cfg(refine_rounds=0)
    _, b = s5p_cold_bundle(src, dst, n, cfg)
    # delete a big chunk so some clusters die
    idx = np.arange(0, len(src) // 2)
    b, _ = s5p_apply_deletion(b, cfg, src, dst, idx)
    C_before = int(b["comb_is_head"].shape[0])
    b2, dropped = compact_bundle(b, cfg)
    assert dropped >= 0
    assert int(b2["comb_is_head"].shape[0]) == C_before - dropped
    # the partition itself is untouched by compaction
    np.testing.assert_array_equal(b2["parts"], b["parts"])
    np.testing.assert_array_equal(b2["load"], b["load"])
    # tags stay consistent: live edges' clusters exist and keep their c2p
    alive = np.asarray(b2["alive"], bool)
    cu = np.asarray(b2["edge_cu"])[alive]
    ok = cu >= 0
    assert np.all(cu[ok] < int(b2["comb_is_head"].shape[0]))
    old_cu = np.asarray(b["edge_cu"])[alive]
    old_c2p = np.asarray(b["c2p"])
    new_c2p = np.asarray(b2["c2p"])
    np.testing.assert_array_equal(new_c2p[cu[ok]], old_c2p[old_cu[ok]])
    # idempotent: a second pass drops nothing
    b3, dropped2 = compact_bundle(b2, cfg)
    assert dropped2 == 0


def test_refresh_signal_fires_under_heavy_growth():
    """Doubling the stream with denser edges drifts ξ past the threshold."""
    src, dst, n, _ = random_graph(1)
    if len(src) < 100:
        pytest.skip("graph too small")
    from repro.incremental import s5p_apply_delta

    cfg = _cfg(xi_refresh_threshold=0.2, refine_rounds=0,
               drift_rf_threshold=float("inf"),
               drift_balance_threshold=float("inf"),
               drift_churn_threshold=float("inf"))
    E0 = len(src) // 3
    _, b = s5p_cold_bundle(src[:E0], dst[:E0], n, cfg)
    b, res = s5p_apply_delta(b, cfg, src, dst, E0)
    assert res.xi_drift > 0.2
    assert res.needs_cold_restart


# ===================================================== 4. slow-lane band
@pytest.mark.slow
def test_sliding_window_quality_band():
    """Steady-state sliding-window S5P stays within the churn-bench
    acceptance band: RF ≤ 1.10× a cold re-partition of the same window."""
    from repro.graphs import rmat_graph

    src, dst, n = rmat_graph(11, edge_factor=8, seed=3)
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    cfg = S5PConfig(k=K, drift_rf_threshold=0.02, refine_rounds=16,
                    drift_churn_threshold=0.2)
    W = 4096
    for rate in (0.125, 0.25):
        B = int(W * rate)
        hist, _ = s5p_sliding_window(src, dst, n, cfg, W, step_edges=B)
        steady = [h for h in hist if h.hi - h.lo == W and not h.filling]
        ratios = []
        for h in (steady[len(steady) // 2], steady[-1]):
            ws, wd = src[h.lo:h.hi], dst[h.lo:h.hi]
            cold = s5p_partition(ws, wd, n, cfg)
            rf_cold = float(replication_factor(ws, wd, cold.parts,
                                               n_vertices=n, k=K))
            ratios.append(h.rf / max(rf_cold, 1e-9))
        assert float(np.mean(ratios)) <= 1.10, (rate, ratios)
        assert max(ratios) <= 1.15, (rate, ratios)
