"""Out-of-core ShardedEdgeStream vs the in-memory engine.

Four layers, mirroring the contract:

1. *Bit parity* — for every ordering × chunk_size × shard_edges, chunks
   from disk shards are byte-identical to :class:`EdgeStream`'s (the
   headline guarantee: consumers cannot tell the engines apart).
2. *Golden reproduction* — the pinned seed hashes of
   ``tests/test_streaming.py`` reproduce when the scans page from disk.
3. *Bounded memory* — every host allocation the stream makes goes through
   its ``HostBudget``; peaks stay O(shard_edges + chunk + window) and far
   below the full edge list (plus a tracemalloc cross-check that doesn't
   trust the stream's own accounting).
4. *Shared stream invariants* — property-based checks (hypothesis when
   installed, the seeded ``proptest`` harness otherwise) run against BOTH
   engines: order is a permutation, scatter_back round-trips batched
   arrays, tail padding is (0,0) self-loops with correct ``n_valid``,
   windowed never emits an edge more than ``window`` slots early.

Plus the Prefetcher lifecycle regression (stop() used to leave the worker
blocked forever in ``queue.put``).
"""

import gc
import time
import tracemalloc

import numpy as np
import pytest

from proptest import random_graph
from test_streaming import GOLDEN, _h
from repro.core import S5PConfig, s5p_partition
from repro.core.baselines import greedy_partition, grid_partition, hdrf_partition
from repro.core.clustering import cluster_stream
from repro.data.pipeline import EdgeChunkPipeline, Prefetcher
from repro.streaming import (
    BudgetExceededError,
    EdgeStream,
    HostBudget,
    ShardedEdgeStream,
    read_manifest,
    write_shards,
)

try:  # optional — the container image has no hypothesis; gate, don't require
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ORDERINGS = ("natural", "shuffled", "dst-sorted", "windowed")
CHUNK_SIZES = (1, 7, 1 << 16)
SHARD_EDGES = (13, 1 << 16)  # odd small (many ragged shards) vs single-shard


def _np(a):
    return np.asarray(a)


@pytest.fixture(scope="module")
def parity_setup(tmp_path_factory):
    """One diverse graph, sharded at both granularities."""
    src, dst, n, _ = random_graph(1)
    manifests = {}
    for se in SHARD_EDGES:
        d = tmp_path_factory.mktemp(f"shards-{se}")
        manifests[se] = write_shards(d, src, dst, shard_edges=se, n_vertices=n)
    return src, dst, n, manifests


# ======================================================== 1. bit parity
@pytest.mark.parametrize("shard_edges", SHARD_EDGES)
@pytest.mark.parametrize("chunk_size", CHUNK_SIZES)
@pytest.mark.parametrize("ordering", ORDERINGS)
def test_bit_parity_chunks(parity_setup, ordering, chunk_size, shard_edges):
    src, dst, n, manifests = parity_setup
    tag = np.arange(len(src), dtype=np.int32)
    ref = EdgeStream(src, dst, n, chunk_size=chunk_size, ordering=ordering,
                     seed=5, window=16)
    with ShardedEdgeStream(manifests[shard_edges], chunk_size=chunk_size,
                           ordering=ordering, seed=5, window=16) as got:
        assert got.n_edges == ref.n_edges
        assert got.n_vertices == ref.n_vertices
        assert got.n_chunks == ref.n_chunks
        for i in range(ref.n_chunks):
            a = ref.chunk_at(i, tag)
            b = got.chunk_at(i, tag)
            assert a.start == b.start and a.n_valid == b.n_valid
            assert _np(a.src).dtype == _np(b.src).dtype == np.int32
            assert np.array_equal(_np(a.src), _np(b.src))
            assert np.array_equal(_np(a.dst), _np(b.dst))
            assert np.array_equal(_np(a.extras[0]), _np(b.extras[0]))
        # unpadded replay agrees too
        ca = np.concatenate([_np(c.src) for c in ref.chunks(pad=False)])
        cb = np.concatenate([_np(c.src) for c in got.chunks(pad=False)])
        assert np.array_equal(ca, cb)
        # per-edge results map back to arrival order identically
        vals = np.arange(len(src), dtype=np.float32)
        assert np.array_equal(_np(ref.scatter_back(vals)),
                              _np(got.scatter_back(vals)))


def test_stored_extra_fields_page_through_chunks(tmp_path):
    """Extras written into shards ride through chunks() via open_field —
    identical to passing the host array to the in-memory engine."""
    src, dst, n, _ = random_graph(0)
    w = np.random.default_rng(7).random(len(src)).astype(np.float32)
    man = write_shards(tmp_path, src, dst, w, shard_edges=19, n_vertices=n,
                       field_names=["w"])
    ref = EdgeStream(src, dst, n, chunk_size=23, ordering="dst-sorted")
    with ShardedEdgeStream(man, chunk_size=23, ordering="dst-sorted") as got:
        assert got.field_names == ("src", "dst", "w")
        view = got.open_field("w")
        assert view.shape == (len(src),)
        for a, b in zip(ref.chunks(w), got.chunks(view)):
            assert np.array_equal(_np(a.extras[0]), _np(b.extras[0]))


def test_manifest_round_trip_and_validation(tmp_path):
    src, dst, n, _ = random_graph(3)
    man = write_shards(tmp_path / "g", src, dst, shard_edges=11, n_vertices=n)
    path, meta = read_manifest(man.parent)  # directory resolves to manifest
    assert path == man
    assert meta["n_edges"] == len(src) and meta["n_vertices"] == n
    assert [f["name"] for f in meta["fields"]] == ["src", "dst"]
    assert sum(s["n_edges"] for s in meta["shards"]) == len(src)
    with pytest.raises(ValueError):
        write_shards(tmp_path / "bad", src, dst, shard_edges=0)
    with pytest.raises(ValueError):
        write_shards(tmp_path / "bad", src, dst[:-1])
    with ShardedEdgeStream(man) as st:
        with pytest.raises(IndexError):
            st.chunk_at(st.n_chunks)
        with pytest.raises(AttributeError):  # no host-resident edge arrays
            st.src
        s, d = st.arrival_arrays()  # the explicit opt-in materialization
        assert np.array_equal(s, src) and np.array_equal(d, dst)


def test_empty_graph_round_trip(tmp_path):
    man = write_shards(tmp_path, np.empty(0, np.int32), np.empty(0, np.int32),
                       shard_edges=7, n_vertices=0)
    for ordering in ORDERINGS:
        with ShardedEdgeStream(man, ordering=ordering, chunk_size=4) as st:
            assert st.n_edges == 0 and st.n_chunks == 1
            (ch,) = list(st.chunks())
            assert ch.n_valid == 0 and _np(ch.src).shape == (0,)


# ================================================ 2. golden reproduction
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("name", ["greedy", "hdrf", "grid"])
def test_golden_hashes_from_disk_baselines(tmp_path, seed, name):
    src, dst, n, _ = random_graph(seed)
    man = write_shards(tmp_path, src, dst, shard_edges=17, n_vertices=n)
    fn = {"greedy": greedy_partition, "hdrf": hdrf_partition,
          "grid": grid_partition}[name]
    with ShardedEdgeStream(man, chunk_size=64) as st:
        assert _h(fn(src, dst, n, 4, stream=st)) == GOLDEN[(seed, name)]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_golden_hashes_from_disk_clustering(tmp_path, seed):
    src, dst, n, _ = random_graph(seed)
    man = write_shards(tmp_path, src, dst, shard_edges=17, n_vertices=n)
    with ShardedEdgeStream(man, chunk_size=64) as st:
        state = cluster_stream(None, None, None, xi=3, kappa=50, stream=st)
    got = _h(np.concatenate([_np(state.v2c_h), _np(state.v2c_t)]))
    assert got == GOLDEN[(seed, "cluster")]


@pytest.mark.parametrize("seed", [
    0, 1,
    pytest.param(2, marks=pytest.mark.slow),
    pytest.param(3, marks=pytest.mark.slow),
])
def test_golden_hashes_from_disk_s5p(tmp_path, seed):
    src, dst, n, _ = random_graph(seed)
    man = write_shards(tmp_path, src, dst, shard_edges=29, n_vertices=n)
    cfg = S5PConfig(k=4, use_cms=False, game_accept_prob=0.7,
                    game_max_rounds=64, seed=0)
    with ShardedEdgeStream(man, chunk_size=64) as st:
        out = s5p_partition(src, dst, n, cfg, stream=st)
    assert _h(out.parts) == GOLDEN[(seed, "s5p")]


# ==================================================== 3. bounded memory
@pytest.fixture(scope="module")
def big_sharded(tmp_path_factory):
    """~100k edges, sharded small — the regime where O(E) vs O(shard)
    host memory is clearly separable."""
    from repro.graphs import powerlaw_graph

    src, dst, n = powerlaw_graph(30000, avg_degree=8, seed=3)
    d = tmp_path_factory.mktemp("big-shards")
    man = write_shards(d, src, dst, shard_edges=4096, n_vertices=n)
    return src, dst, n, man


@pytest.mark.parametrize("ordering", ORDERINGS)
def test_host_budget_bounded(big_sharded, ordering):
    src, _, _, man = big_sharded
    se, cs, w = 4096, 2048, 512
    with ShardedEdgeStream(man, chunk_size=cs, ordering=ordering, seed=1,
                           window=w) as st:
        edges_seen = 0
        for ch in st.chunks():
            edges_seen += ch.n_valid
        assert edges_seen == len(src)
        # scatter_back walks the order mmap in blocks — it must not add an
        # O(E) inverse permutation to the stream's own accounting (the
        # result arrays themselves are the caller's, and excluded)
        st.scatter_back(np.zeros(len(src), np.int32))
        peak = st.budget.peak_bytes
    # O(shard_edges + chunk + window), with the per-term constants of the
    # reorder passes (runs, merge buffers, spill gathers) made explicit —
    # and in all cases a small fraction of the full edge list
    assert peak <= 8 * (3 * se + 4 * cs + 8 * w) + (1 << 14), ordering
    assert peak < (8 * len(src)) // 4, ordering


@pytest.mark.parametrize("ordering", ["dst-sorted", "windowed"])
def test_partition_budget_bounded_under_reordering(big_sharded, ordering):
    """Full HDRF partition through a *reordered* disk stream: results match
    the in-memory engine and the stream's own allocations (including the
    scatter_back at the end of run_scan) stay bounded."""
    src, dst, n, man = big_sharded
    se, cs, w = 4096, 4096, 512
    ref = _np(hdrf_partition(
        src, dst, n, 4,
        stream=EdgeStream(src, dst, n, chunk_size=cs, ordering=ordering,
                          seed=1, window=w)))
    with ShardedEdgeStream(man, chunk_size=cs, ordering=ordering, seed=1,
                           window=w) as st:
        parts = _np(hdrf_partition(None, None, n, 4, stream=st))
        peak = st.budget.peak_bytes
    assert np.array_equal(parts, ref)
    assert peak <= 8 * (3 * se + 4 * cs + 8 * w) + (1 << 14), peak
    assert peak < (8 * len(src)) // 4, peak


def test_no_full_edge_list_on_read_path(big_sharded):
    """tracemalloc cross-check: a full natural pass allocates nowhere near
    the edge list (this does NOT trust the stream's own accounting)."""
    src, _, _, man = big_sharded
    edge_bytes = 8 * len(src)
    st = ShardedEdgeStream(man, chunk_size=2048)
    gc.collect()
    tracemalloc.start()
    edges_seen = 0
    for ch in st.chunks():
        edges_seen += ch.n_valid
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    st.close()
    assert edges_seen == len(src)
    assert peak < edge_bytes // 3, (peak, edge_bytes)


@pytest.mark.slow
def test_no_full_edge_list_at_scale(tmp_path):
    """~1M-edge R-MAT partitioned from disk under tracemalloc: the HDRF
    scan completes while read-path host allocations stay ~2 orders of
    magnitude below the edge list."""
    from repro.graphs import rmat_graph

    src, dst, n = rmat_graph(16, edge_factor=17, seed=0, dedup=False)
    E = len(src)
    man = write_shards(tmp_path, src, dst, shard_edges=1 << 17, n_vertices=n)
    ref = _np(hdrf_partition(src, dst, n, 8, chunk_size=1 << 15))
    del src, dst
    gc.collect()
    tracemalloc.start()
    with ShardedEdgeStream(man, chunk_size=1 << 15) as st:
        parts = _np(hdrf_partition(None, None, n, 8, stream=st))
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert np.array_equal(parts, ref)
    # parts itself is O(E) (4E bytes) and unavoidable; the stream must not
    # add another edge list on top — bound well below src+dst (8E bytes)
    assert peak < 4 * E + 4 * E // 2, (peak, E)


# ============================================ 4. shared stream invariants
def _both_engines(src, dst, n, manifest, **kw):
    yield EdgeStream(src, dst, n, **kw)
    with ShardedEdgeStream(manifest, **kw) as st:
        yield st


def _check_invariants(src, dst, n, manifest, *, ordering, chunk_size, window):
    E = len(src)
    for stream in _both_engines(src, dst, n, manifest, ordering=ordering,
                                chunk_size=chunk_size, seed=9, window=window):
        # order is a permutation of arrival indices
        order = stream.order
        if ordering == "natural":
            assert order is None
            order_np = np.arange(E)
        else:
            order_np = np.asarray(order)
            assert sorted(order_np.tolist()) == list(range(E))
        # scatter_back round-trips batched (B, E) stream-order payloads
        payload = np.stack([np.arange(E)[order_np],
                            np.arange(E)[order_np] * 2 + 1])
        back = _np(stream.scatter_back(payload))
        assert np.array_equal(back[0], np.arange(E))
        assert np.array_equal(back[1], np.arange(E) * 2 + 1)
        # tail padding is (0, 0) self-loops with zeroed extras; n_valid sums
        tag = np.arange(E, dtype=np.int32) + 1
        n_valid_total = 0
        for ch in stream.chunks(tag):
            n_valid_total += ch.n_valid
            s, d, x = _np(ch.src), _np(ch.dst), _np(ch.extras[0])
            assert np.all(s[ch.n_valid:] == 0)
            assert np.all(d[ch.n_valid:] == 0)
            assert np.all(x[ch.n_valid:] == 0)
            if stream.n_chunks > 1:
                assert s.shape[0] == chunk_size  # fixed device shape
        assert n_valid_total == E
        # windowed: never emitted more than `window` slots early
        if ordering == "windowed":
            for out_pos, arrival in enumerate(order_np.tolist()):
                assert out_pos >= arrival - window


@pytest.mark.parametrize("ordering", ORDERINGS)
@pytest.mark.parametrize("graph_seed", [0, 2, 5])
def test_stream_invariants_both_engines(tmp_path, ordering, graph_seed):
    src, dst, n, _ = random_graph(graph_seed)
    man = write_shards(tmp_path, src, dst, shard_edges=13, n_vertices=n)
    _check_invariants(src, dst, n, man, ordering=ordering, chunk_size=29,
                      window=8)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(graph_seed=st_.integers(0, 63),
           ordering=st_.sampled_from(ORDERINGS),
           chunk_size=st_.integers(1, 97),
           shard_edges=st_.integers(1, 64),
           window=st_.integers(1, 64))
    def test_stream_invariants_fuzzed(tmp_path_factory, graph_seed, ordering,
                                      chunk_size, shard_edges, window):
        src, dst, n, _ = random_graph(graph_seed)
        d = tmp_path_factory.mktemp("hyp")
        man = write_shards(d, src, dst, shard_edges=shard_edges, n_vertices=n)
        _check_invariants(src, dst, n, man, ordering=ordering,
                          chunk_size=chunk_size, window=window)


# =============================================== pipeline + prefetcher
def test_edge_chunk_pipeline_accepts_stream_and_path(tmp_path):
    src, dst, n, _ = random_graph(0)
    man = write_shards(tmp_path, src, dst, shard_edges=23, n_vertices=n)
    mem = EdgeChunkPipeline(src, dst, n, chunk_size=31, ordering="shuffled",
                            seed=4)
    via_path = EdgeChunkPipeline(f"file:{man}", chunk_size=31,
                                 ordering="shuffled", seed=4)
    via_stream = EdgeChunkPipeline(
        ShardedEdgeStream(man, chunk_size=31, ordering="shuffled", seed=4))
    for step in (0, 2, mem.stream.n_chunks + 1):
        a, b, c = mem(step), via_path(step), via_stream(step)
        assert np.array_equal(_np(a["src"]), _np(b["src"]))
        assert np.array_equal(_np(a["src"]), _np(c["src"]))
        assert a["start"] == b["start"] and a["n_valid"] == b["n_valid"]
    with pytest.raises(ValueError):
        EdgeChunkPipeline(f"file:{man}", dst, n)


def test_prefetcher_overlaps_disk_paging(tmp_path):
    """Compose Prefetcher with an out-of-core pipeline: batches match the
    direct path and the worker shuts down cleanly."""
    src, dst, n, _ = random_graph(1)
    man = write_shards(tmp_path, src, dst, shard_edges=23, n_vertices=n)
    pipe = EdgeChunkPipeline(str(man), chunk_size=17)
    pf = Prefetcher(pipe, depth=2)
    pf.start(0)
    try:
        for step in range(min(pipe.stream.n_chunks, 4)):
            got = pf(step)
            want = pipe(step)
            assert np.array_equal(_np(got["src"]), _np(want["src"]))
    finally:
        pf.stop()
    assert pf._thread is None


def test_prefetcher_stop_unblocks_full_queue_and_restarts():
    """Regression: stop() used to leave the worker blocked forever in
    queue.put when the queue was full (daemon-thread leak), and restart
    reused the stale queue."""
    produced = []

    def fn(step):
        produced.append(step)
        return {"step": step}

    p = Prefetcher(fn, depth=1)
    p.start(0)
    deadline = time.time() + 5.0
    while not p._q.full() and time.time() < deadline:  # slow consumer: never reads
        time.sleep(0.01)
    assert p._q.full()
    worker = p._thread
    p.stop()
    worker.join(timeout=2.0)
    assert not worker.is_alive()
    # restart from a different step is safe and serves fresh batches
    p.start(10)
    assert p(10)["step"] == 10
    assert p(11)["step"] == 11
    worker2 = p._thread
    p.stop()
    assert not worker2.is_alive() and p._thread is None
    # a stopped prefetcher degrades to direct synthesis
    assert p(3)["step"] == 3
    # stop() is idempotent
    p.stop()


def test_prefetcher_worker_death_raises_instead_of_hanging():
    """Regression: an exception in fn used to kill the worker silently,
    leaving the consumer blocked forever in queue.get."""

    def fn(step):
        if step >= 2:
            raise ValueError(f"shard vanished at step {step}")
        return {"step": step}

    p = Prefetcher(fn, depth=1)
    p.start(0)
    try:
        assert p(0)["step"] == 0
        assert p(1)["step"] == 1
        with pytest.raises(RuntimeError, match="prefetch worker died"):
            p(2)
    finally:
        p.stop()


# ---------------------------------------------------------------------------
# HostBudget hard-cap mode (the hybrid partitioner's enforcement knob)
# ---------------------------------------------------------------------------


def test_host_budget_default_observe_mode_unchanged():
    """No limit ⇒ the original observe-only accounting, bit for bit."""
    hb = HostBudget()
    assert hb.limit_bytes is None
    hb.charge(100)
    hb.charge(1 << 40)  # absurdly large: observe mode never raises
    assert hb.current_bytes == 100 + (1 << 40)
    assert hb.peak_bytes == hb.current_bytes
    hb.release(1 << 40)
    assert hb.current_bytes == 100
    assert hb.peak_bytes == 100 + (1 << 40)  # peak is a high-water mark
    with hb.scoped(50):
        assert hb.current_bytes == 150
    assert hb.current_bytes == 100


def test_host_budget_hard_cap_raises_and_keeps_state():
    hb = HostBudget(limit_bytes=1000)
    hb.charge(600)
    with pytest.raises(BudgetExceededError) as ei:
        hb.charge(500)
    err = ei.value
    assert (err.requested, err.current, err.limit) == (500, 600, 1000)
    assert isinstance(err, MemoryError)
    # a refused charge leaves the accounting untouched (retry-safe)
    assert hb.current_bytes == 600
    assert hb.peak_bytes == 600
    hb.charge(400)  # exactly to the cap is allowed
    assert hb.current_bytes == 1000
    with pytest.raises(BudgetExceededError):
        hb.charge(1)
    hb.release(1000)
    # scoped() composes with the cap: inside ≤ limit, released after
    with hb.scoped(1000):
        assert hb.current_bytes == 1000
    assert hb.current_bytes == 0
    with pytest.raises(ValueError):
        HostBudget(limit_bytes=-1)
