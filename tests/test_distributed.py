"""Distributed pieces needing >1 device run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main process keeps
1 device so all other tests see the real topology)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# each test here boots a fresh 8-device subprocess and recompiles the full
# pipeline — minutes apiece on CPU; run explicitly with `-m slow`
pytestmark = pytest.mark.slow

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(code: str) -> dict:
    prog = textwrap.dedent(code)
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": SRC, "XLA_FLAGS":
             "--xla_force_host_platform_device_count=8", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_partition_quality():
    """shard_map S5P ≈ single-host S5P quality; every edge assigned."""
    res = _run("""
        import json
        import jax
        import numpy as np
        from repro.core import S5PConfig, s5p_partition, replication_factor
        from repro.core.distributed import distributed_partition
        from repro.graphs.generators import community_graph

        src, dst, n = community_graph(1200, n_communities=24, avg_degree=8, seed=1)
        k = 4
        mesh = jax.make_mesh((8,), ("data",))
        cfg = S5PConfig(k=k, use_cms=True)
        parts, info = distributed_partition(src, dst, n, cfg, mesh)
        rf_dist = replication_factor(src, dst, parts, n_vertices=n, k=k)
        single = s5p_partition(src, dst, n, cfg)
        rf_single = replication_factor(src, dst, single.parts, n_vertices=n, k=k)
        valid = np.asarray(src) != np.asarray(dst)
        all_assigned = bool((np.asarray(parts)[valid] >= 0).all())
        print(json.dumps(dict(rf_dist=rf_dist, rf_single=rf_single,
                              all_assigned=all_assigned, **info)))
    """)
    assert res["all_assigned"]
    assert res["converged"]
    # distributed clustering sees shard-local streams: allow 35% quality gap
    assert res["rf_dist"] <= res["rf_single"] * 1.35 + 0.2, res


def test_ep_moe_on_divisible_mesh():
    """Expert parallelism: 4 experts over a 4-wide model axis compiles and
    matches the single-device forward."""
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.models import lm as LM
        from repro.sharding import use_rules, DEFAULT_RULES

        cfg = LM.LMConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=128, vocab=128, n_experts=4, top_k=2,
                          attn_chunk=32, dtype=jnp.float32)
        params = LM.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128,
                                  dtype=jnp.int32)
        ref, _ = LM.forward(params, toks, cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = dict(DEFAULT_RULES)
        rules["expert"] = ("model",)  # true EP: 4 experts / 4-wide axis
        with use_rules(mesh, rules):
            out, _ = jax.jit(lambda p, t: LM.forward(p, t, cfg))(params, toks)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps(dict(err=err)))
    """)
    assert res["err"] < 1e-3


def test_sharded_lm_train_step_matches_single():
    """One DP×TP train step on 8 devices == single-device step (numerics)."""
    res = _run("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.launch.cells import build_cell
        from repro.launch.dryrun import lower_cell
        from repro.launch.mesh import make_test_mesh
        from repro.sharding import use_rules, DEFAULT_RULES

        cell = build_cell("llama3-8b", "train_4k", smoke=True)
        key = jax.random.PRNGKey(0)
        state = cell.init_state(key)
        batch = cell.make_batch(key)
        ref_state, ref_metrics = jax.jit(cell.step_fn)(state, *batch)
        mesh = make_test_mesh()
        with use_rules(mesh, DEFAULT_RULES):
            out_state, out_metrics = jax.jit(cell.step_fn)(state, *batch)
        err = abs(float(ref_metrics["loss"]) - float(out_metrics["loss"]))
        print(json.dumps(dict(err=err, loss=float(out_metrics["loss"]))))
    """)
    assert res["err"] < 5e-3, res
