"""Hub-sharded parallel ingest: routing invariants, cadence, CLI satellites.

What ISSUE 10's quality-neutrality argument rests on, pinned as tests:

1. *Plan invariants* — ``shard="hub"`` is a permutation-free re-dealing
   of the stream: the edge multiset is preserved exactly, every lane's
   edge sequence is a subsequence of arrival order, and every pinned
   hub's edges live on exactly one lane (the rendezvous lane).
2. *Degenerate exactness* — ``num_streams=1`` is bit-identical to the
   sequential driver in every shard mode, and linear-merge carries
   (degrees) are exact under hub sharding at any S.
3. *Adaptive cadence* — ``super_chunk="auto"`` is consumer-aware:
   parts-emitting carries start contested (cadence 1) and back off
   geometrically; state-only carries isolate (exactly one merge per
   lane).  The realized schedule is published via ``last_ingest_stats``
   and logged once per (consumer, shard, cadence) key.
4. *Validation and CLI satellites* — bad ``super_chunk``/``num_streams``
   fail fast with argparse-style messages, and the ``--hybrid``
   auto-budget helpers (meminfo parsing, fraction checks) are exact.

Property style follows tests/test_carry.py: hypothesis when installed,
the seeded ``proptest`` harness otherwise.
"""

from __future__ import annotations

import argparse
import logging

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import random_graph
from repro.core.clustering import ClusterCarry, DegreeCarry, compute_degrees
from repro.core.s5p import S5PConfig, s5p_partition
from repro.kernels.stream_scan import GreedyCarry, HdrfCarry
from repro.launch.partition import (
    _fraction_arg,
    _parse_meminfo_available,
    _super_chunk_arg,
    auto_host_budget,
    detect_available_memory,
)
from repro.streaming import EdgeStream, ParallelEdgeStream, run_carry, run_parallel
from repro.streaming.parallel import (
    ISOLATE_CADENCE,
    _compress_schedule,
    last_ingest_stats,
    reset_cadence_log,
)

try:  # optional — the container image has no hypothesis; gate, don't require
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

K = 4


def _stream(src, dst, n_vertices, chunk_size=64):
    return EdgeStream(np.asarray(src, np.int32), np.asarray(dst, np.int32),
                      n_vertices, chunk_size=chunk_size)


def _lane_sequences(ps):
    """Per-lane (src, dst) sequences in lane-serving order, valid rows only."""
    out = []
    for lane in ps.lanes:
        ss, dd = [], []
        for cid in lane:
            ch = ps.chunk_for(cid)
            nv = ch.n_valid
            ss.append(np.asarray(ch.src)[:nv])
            dd.append(np.asarray(ch.dst)[:nv])
        out.append((np.concatenate(ss) if ss else np.empty(0, np.int32),
                    np.concatenate(dd) if dd else np.empty(0, np.int32)))
    return out


# ==================================================== hub plan invariants
def _check_hub_plan(src, dst, n_vertices, S, chunk_size=64):
    st = _stream(src, dst, n_vertices, chunk_size=chunk_size)
    ps = ParallelEdgeStream(st, S, shard="hub")
    lanes = ps.edge_lanes()
    assert lanes.shape == (st.n_edges,)
    assert lanes.min() >= 0 and lanes.max() < ps.num_streams

    seqs = _lane_sequences(ps)
    # edge multiset preserved: every edge served by exactly one lane
    assert sum(len(s) for s, _ in seqs) == st.n_edges
    served = np.concatenate([np.stack([s, d], 1) for s, d in seqs])
    want = np.stack([np.asarray(src), np.asarray(dst)], 1)
    assert np.array_equal(np.sort(served.view("i4,i4").ravel()),
                          np.sort(want.view("i4,i4").ravel()))
    # within-lane arrival order: lane s's sequence == the arrival-order
    # stream filtered to edge_lanes() == s — order and content in one check
    for s, (ls, ld) in enumerate(seqs):
        mask = lanes == s
        assert np.array_equal(ls, np.asarray(src)[mask])
        assert np.array_equal(ld, np.asarray(dst)[mask])
    # pin invariant: every hub's (hub-classified) edges on its pinned lane
    pv = ps._pin_vertex
    order = np.asarray(st.order) if st.order is not None else None
    by_pos = ps._lane_of_pos
    for v, lane in ps.pin_map.items():
        assert np.all(by_pos[pv == v] == lane), f"hub {v} split across lanes"
    return ps


def test_hub_plan_invariants_proptest():
    for seed in range(6):
        src, dst, n_vertices, label = random_graph(seed)
        for S in (2, 4):
            _check_hub_plan(src, dst, n_vertices, S)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_hub_plan_invariants_hypothesis():
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st_.integers(0, 2 ** 16), st_.integers(2, 5))
    def prop(seed, S):
        src, dst, n_vertices, label = random_graph(seed)
        _check_hub_plan(src, dst, n_vertices, S)

    prop()


def test_hub_threshold_override_pins_more():
    src, dst, n_vertices, _ = random_graph(0)
    st = _stream(src, dst, n_vertices)
    lo = ParallelEdgeStream(st, 4, shard="hub", hub_threshold=1)
    hi = ParallelEdgeStream(st, 4, shard="hub", hub_threshold=1 << 20)
    assert lo.n_hubs >= hi.n_hubs
    assert hi.n_hubs == 0  # nothing clears an absurd threshold


# ==================================================== degenerate exactness
@pytest.mark.parametrize("shard", ["range", "rr", "hub"])
@pytest.mark.parametrize("name", ["greedy", "hdrf"])
def test_s1_bit_identical_every_mode(name, shard):
    src, dst, n_vertices, _ = random_graph(1)
    make = (lambda: GreedyCarry(n_vertices, K)) if name == "greedy" else \
        (lambda: HdrfCarry(n_vertices, K, 1.1))
    st = _stream(src, dst, n_vertices)
    p_seq, _ = run_carry(st, make())
    p_par, _ = run_parallel(st, make(), num_streams=1, shard=shard,
                            super_chunk="auto")
    assert np.array_equal(np.asarray(p_seq), np.asarray(p_par))


def test_s5p_s1_bit_identical_across_shards():
    src, dst, n_vertices, _ = random_graph(2)
    base = None
    for shard in ("range", "rr", "hub"):
        cfg = S5PConfig(k=K, chunk_size=64, num_streams=1, shard=shard)
        out = s5p_partition(jnp.asarray(src), jnp.asarray(dst), n_vertices, cfg)
        parts = np.asarray(out.parts)
        if base is None:
            base = parts
        else:
            assert np.array_equal(base, parts), shard


@pytest.mark.parametrize("S", [2, 4])
def test_degree_carry_exact_under_hub(S):
    src, dst, n_vertices, _ = random_graph(3)
    st = _stream(src, dst, n_vertices)
    _, deg = run_parallel(st, DegreeCarry(n_vertices), num_streams=S,
                          shard="hub", super_chunk="auto", backend="threads")
    want = compute_degrees(jnp.asarray(src), jnp.asarray(dst), n_vertices)
    assert np.array_equal(np.asarray(deg), np.asarray(want))


# ==================================================== adaptive cadence
def test_auto_cadence_parts_emitting_starts_contested():
    src, dst, n_vertices, _ = random_graph(4)
    st = _stream(src, dst, n_vertices, chunk_size=32)
    run_parallel(st, GreedyCarry(n_vertices, K), num_streams=4,
                 super_chunk="auto", backend="threads")
    stats = last_ingest_stats()
    assert stats is not None and stats.super_chunk == "auto"
    assert stats.schedule, "auto run must publish a realized schedule"
    assert stats.schedule[0] == 1, "placement scans start contested"
    assert all(c & (c - 1) == 0 for c in stats.schedule), "geometric ladder"
    assert all(l.merge_count >= 1 for l in stats.lanes)


def test_auto_cadence_state_only_isolates():
    src, dst, n_vertices, _ = random_graph(7)  # large enough for 4 lanes
    deg = compute_degrees(jnp.asarray(src), jnp.asarray(dst), n_vertices)
    pc = ClusterCarry(deg, n_vertices, xi=3, kappa=17)
    st = _stream(src, dst, n_vertices, chunk_size=32)
    run_parallel(st, pc, num_streams=4, super_chunk="auto", backend="threads")
    stats = last_ingest_stats()
    assert stats.schedule == (ISOLATE_CADENCE,)
    assert _compress_schedule(stats.schedule) == "all"
    for lane in stats.lanes:
        assert lane.merge_count == 1, "isolated lanes merge exactly once"


def test_cadence_logged_once_per_run(caplog):
    src, dst, n_vertices, _ = random_graph(6)
    st = _stream(src, dst, n_vertices, chunk_size=32)
    reset_cadence_log()
    with caplog.at_level(logging.INFO, logger="repro.streaming.parallel"):
        for _ in range(2):
            run_parallel(st, GreedyCarry(n_vertices, K), num_streams=2,
                         super_chunk=2, backend="threads")
    hits = [r for r in caplog.records if "cadence" in r.getMessage()]
    assert len(hits) == 1, "same (consumer, shard, schedule) logs once"
    reset_cadence_log()
    with caplog.at_level(logging.INFO, logger="repro.streaming.parallel"):
        run_parallel(st, GreedyCarry(n_vertices, K), num_streams=2,
                     super_chunk=2, backend="threads")
    assert len([r for r in caplog.records
                if "cadence" in r.getMessage()]) == 2, "reset re-arms"


def test_ingest_stats_account_every_edge():
    src, dst, n_vertices, _ = random_graph(7)
    st = _stream(src, dst, n_vertices, chunk_size=32)
    run_parallel(st, GreedyCarry(n_vertices, K), num_streams=3,
                 shard="hub", super_chunk="auto", backend="threads")
    stats = last_ingest_stats()
    assert stats.shard == "hub" and stats.num_streams == 3
    assert sum(l.edges for l in stats.lanes) == st.n_edges
    assert all(l.wall_s >= 0 for l in stats.lanes)


# ==================================================== touch-up smoke
def test_touch_up_stats_present_when_parallel():
    src, dst, n_vertices, _ = random_graph(8)
    cfg = S5PConfig(k=K, chunk_size=64, num_streams=2, shard="hub",
                    super_chunk="auto")
    out = s5p_partition(jnp.asarray(src), jnp.asarray(dst), n_vertices, cfg)
    tu = out.aux.get("touch_up")
    assert tu is not None
    assert tu["contested_clusters"] >= 0
    assert tu["moved_clusters"] >= 0
    parts = np.asarray(out.parts)
    assert parts.min() >= 0 and parts.max() < K


# ==================================================== validation
def test_super_chunk_string_validation():
    src, dst, n_vertices, _ = random_graph(9)
    st = _stream(src, dst, n_vertices)
    pc = GreedyCarry(n_vertices, K)
    with pytest.raises(ValueError, match="super_chunk must be >= 1 or 'auto'"):
        run_parallel(st, pc, num_streams=2, super_chunk="bogus")
    with pytest.raises(ValueError, match="super_chunk must be >= 1"):
        run_parallel(st, pc, num_streams=2, super_chunk=0)
    with pytest.raises(ValueError, match="num_streams must be >= 1"):
        run_parallel(st, pc, num_streams=0)
    with pytest.raises(ValueError, match="unknown shard mode"):
        run_parallel(st, pc, num_streams=2, shard="zigzag")


def test_cli_stream_arg_validation():
    from repro.launch import partition as cli

    with pytest.raises(ValueError, match="num_streams must be <= the "
                                         "stream's chunk count"):
        cli.run("community:200", k=4, chunk_size=1 << 16, num_streams=64)
    with pytest.raises(ValueError, match="super_chunk must be <= "):
        cli.run("community:200", k=4, chunk_size=64, num_streams=2,
                super_chunk=10_000)
    with pytest.raises(ValueError, match="super_chunk must be >= 1 or 'auto'"):
        cli.run("community:200", k=4, num_streams=2, super_chunk="fast")


# ==================================================== --hybrid auto-budget
MEMINFO = """\
MemTotal:       16316412 kB
MemFree:         1056716 kB
MemAvailable:    9874456 kB
Buffers:          504812 kB
"""


def test_parse_meminfo_prefers_memavailable():
    assert _parse_meminfo_available(MEMINFO) == 9874456 * 1024


def test_parse_meminfo_falls_back_to_memfree():
    text = "MemTotal: 4096 kB\nMemFree: 2048 kB\n"
    assert _parse_meminfo_available(text) == 2048 * 1024


def test_parse_meminfo_units_and_garbage():
    assert _parse_meminfo_available("MemAvailable: 3 GB\n") == 3 << 30
    assert _parse_meminfo_available("MemAvailable: 7 MB\n") == 7 << 20
    assert _parse_meminfo_available("MemAvailable: 42 B\n") == 42
    assert _parse_meminfo_available("") is None
    assert _parse_meminfo_available("MemAvailable: lots kB\n") is None
    assert _parse_meminfo_available("MemAvailable: 5 parsecs\n") is None


def test_detect_available_memory_on_this_host():
    avail = detect_available_memory()
    # the CI/dev containers are all Linux with /proc — a None here means
    # the fallback chain regressed, not that the host is exotic
    assert avail is not None and avail > 0


def test_auto_host_budget_fraction_validation():
    with pytest.raises(ValueError, match="budget_fraction"):
        auto_host_budget(0.0)
    with pytest.raises(ValueError, match="budget_fraction"):
        auto_host_budget(1.5)
    half, full = auto_host_budget(0.5), auto_host_budget(1.0)
    assert 0 < half <= full


def test_super_chunk_and_fraction_arg_types():
    assert _super_chunk_arg("auto") == "auto"
    assert _super_chunk_arg(" AUTO ") == "auto"
    assert _super_chunk_arg("8") == 8
    for bad in ("0", "-3", "fast", "1.5"):
        with pytest.raises(argparse.ArgumentTypeError,
                           match="chunk count >= 1 or 'auto'"):
            _super_chunk_arg(bad)
    assert _fraction_arg("0.25") == 0.25
    assert _fraction_arg("1") == 1.0
    for bad in ("0", "1.01", "-0.5", "half"):
        with pytest.raises(argparse.ArgumentTypeError):
            _fraction_arg(bad)
