"""EdgeStream engine invariants + seed-equivalence + kernel-vs-ref.

The GOLDEN table pins the byte-exact outputs of the *seed* (pre-EdgeStream)
implementations: the refactored loops were verified bit-identical to the
originals on these draws, so any hash drift here is a behaviour regression
in the streaming engine, not a tuning change (game-parameter tuning is
excluded by pinning the old game settings explicitly).
"""

import hashlib

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases, random_graph
from repro.core import S5PConfig, s5p_partition
from repro.core.baselines import (
    PARTITIONERS,
    grid_partition,
    grid_partition_multi_seed,
    hdrf_partition,
    hdrf_partition_batched,
)
from repro.core.clustering import cluster_stream
from repro.kernels.stream_scan import (
    greedy_chunk,
    greedy_init,
    hdrf_chunk,
    hdrf_init,
    stream_scan_tpu,
)
from repro.streaming import EdgeStream, run_scan

# sha256[:16] of the seed implementations' outputs (fixed seeds, k=4);
# s5p/clugp pin the seed game parameters (accept_prob=0.7, max_rounds=64)
GOLDEN = {
    (0, "2ps-l"): "f5393212295c0f8f",
    (0, "clugp"): "60a9846306744121",
    (0, "cluster"): "a48c05342e0a930c",
    (0, "greedy"): "97490d30834620fa",
    (0, "grid"): "b063fe989907f054",
    (0, "hdrf"): "b4ebed498be31d51",
    (0, "s5p"): "5c2abcabc60d546d",
    (1, "2ps-l"): "29fa606fc39ecb89",
    (1, "clugp"): "91007c09be2497b8",
    (1, "cluster"): "a95a7caaa58b87c0",
    (1, "greedy"): "ef351eb5d7f38e6e",
    (1, "grid"): "3e510945dc904318",
    (1, "hdrf"): "dd6c23e3a17a526d",
    (1, "s5p"): "173c8ab805ce8473",
    (2, "2ps-l"): "8d5bc28af74085f5",
    (2, "clugp"): "7aab297411a3ad1c",
    (2, "cluster"): "f149c90d163b5762",
    (2, "greedy"): "0f4e7b57f77cced7",
    (2, "grid"): "de1da85dd6f55a4f",
    (2, "hdrf"): "09d08477c2975e4e",
    (2, "s5p"): "92e66ab2e04f872c",
    (3, "2ps-l"): "de95d6fcd77695ef",
    (3, "clugp"): "be6f93f21b38c052",
    (3, "cluster"): "97790d5b0f81068f",
    (3, "greedy"): "38bba6186c2e0320",
    (3, "grid"): "b2fecc7d6e90d42c",
    (3, "hdrf"): "910bd85e9e563e8c",
    (3, "s5p"): "510862ce051ee123",
}


def _h(a) -> str:
    return hashlib.sha256(np.ascontiguousarray(np.asarray(a)).tobytes()).hexdigest()[:16]


# ----------------------------------------------------------- equivalence
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("name", ["greedy", "hdrf", "grid", "2ps-l"])
def test_seed_equivalence_baselines(seed, name):
    src, dst, n, _ = random_graph(seed)
    assert _h(PARTITIONERS[name](src, dst, n, 4, 0)) == GOLDEN[(seed, name)]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_seed_equivalence_clustering(seed):
    src, dst, n, _ = random_graph(seed)
    st = cluster_stream(src, dst, n, xi=3, kappa=50, chunk_size=64)
    got = _h(np.concatenate([np.asarray(st.v2c_h), np.asarray(st.v2c_t)]))
    assert got == GOLDEN[(seed, "cluster")]


@pytest.mark.parametrize("seed", [
    0, 1,
    pytest.param(2, marks=pytest.mark.slow),
    pytest.param(3, marks=pytest.mark.slow),
])
def test_seed_equivalence_s5p(seed):
    src, dst, n, _ = random_graph(seed)
    cfg = S5PConfig(k=4, use_cms=False, game_accept_prob=0.7,
                    game_max_rounds=64, seed=0)
    assert _h(s5p_partition(src, dst, n, cfg).parts) == GOLDEN[(seed, "s5p")]
    cfgc = S5PConfig(k=4, beta=float(2**30), one_stage=True, use_cms=False,
                     game_accept_prob=0.7, game_max_rounds=64, seed=0)
    assert _h(s5p_partition(src, dst, n, cfgc).parts) == GOLDEN[(seed, "clugp")]


# ------------------------------------------------- chunk-size invariance
@pytest.mark.parametrize("seed", list(cases(4)))
@pytest.mark.parametrize("name", ["greedy", "hdrf", "grid"])
def test_chunk_size_invariance_scans(seed, name):
    src, dst, n, label = random_graph(seed)
    if len(src) < 2:
        return
    ref = np.asarray(PARTITIONERS[name](src, dst, n, 4, 0, chunk_size=len(src)))
    for cs in (7, 64, len(src) + 13):
        got = np.asarray(PARTITIONERS[name](src, dst, n, 4, 0, chunk_size=cs))
        assert np.array_equal(ref, got), (label, cs)


@pytest.mark.parametrize("seed", [1, 3])
def test_chunk_size_invariance_s5p(seed):
    src, dst, n, _ = random_graph(seed)
    outs = [
        np.asarray(
            s5p_partition(src, dst, n, S5PConfig(k=4, use_cms=False,
                                                 chunk_size=cs)).parts
        )
        for cs in (37, 1 << 16)
    ]
    assert np.array_equal(outs[0], outs[1])


# --------------------------------------------------- replay determinism
def test_replay_determinism():
    src, dst, n, _ = random_graph(1)
    for ordering in ("natural", "shuffled", "dst-sorted", "windowed"):
        st = EdgeStream(src, dst, n, chunk_size=29, ordering=ordering,
                        seed=5, window=16)
        a = [(np.asarray(c.src), np.asarray(c.dst), c.start, c.n_valid)
             for c in st.chunks()]
        b = [(np.asarray(c.src), np.asarray(c.dst), c.start, c.n_valid)
             for c in st.chunks()]
        assert len(a) == len(b) == st.n_chunks
        for (s1, d1, o1, v1), (s2, d2, o2, v2) in zip(a, b):
            assert np.array_equal(s1, s2) and np.array_equal(d1, d2)
            assert o1 == o2 and v1 == v2
        # a freshly built stream with the same spec replays identically too
        st2 = EdgeStream(src, dst, n, chunk_size=29, ordering=ordering,
                         seed=5, window=16)
        c1 = next(iter(st.chunks()))
        c2 = next(iter(st2.chunks()))
        assert np.array_equal(np.asarray(c1.src), np.asarray(c2.src))


# ----------------------------------------------------- ordering plumbing
def test_ordering_permutations_and_scatter_back():
    src, dst, n, _ = random_graph(0)
    E = len(src)
    for ordering in ("shuffled", "dst-sorted", "windowed"):
        st = EdgeStream(src, dst, n, ordering=ordering, seed=3, window=32)
        order = st.order
        assert sorted(order.tolist()) == list(range(E)), ordering
        vals = jnp.asarray(np.arange(E)[order])  # stream-order payload
        back = np.asarray(st.scatter_back(vals))
        assert np.array_equal(back, np.arange(E)), ordering
        # extras ride along under the same permutation
        tag = np.arange(E, dtype=np.int32)
        got = np.concatenate(
            [np.asarray(c.extras[0][: c.n_valid]) for c in st.chunks(tag, pad=False)]
        )
        assert np.array_equal(got, tag[order]), ordering


def test_dst_sorted_is_monotone():
    src, dst, n, _ = random_graph(2)
    st = EdgeStream(src, dst, n, ordering="dst-sorted")
    d = np.concatenate([np.asarray(c.dst[: c.n_valid]) for c in st.chunks()])
    assert np.all(np.diff(d) >= 0)


def test_windowed_bounded_early_emission():
    """The buffer holds ≤ `window` edges, so no edge is emitted more than
    `window` output slots before its arrival position (the memory bound;
    late departure is unbounded by design — low-priority edges wait)."""
    src, dst, n, _ = random_graph(2)
    W = 8
    st = EdgeStream(src, dst, n, ordering="windowed", window=W)
    order = st.order
    for out_pos, arrival in enumerate(order.tolist()):
        assert out_pos >= arrival - W


@pytest.mark.slow
def test_windowed_quality_sweep():
    """The ROADMAP windowed-quality study (table in benchmarks/README.md):
    a window ≥ E reproduces dst-sorted exactly (the buffer never drains
    early, so emission is the stable dst sort), and some bounded window
    recovers the locality RF gain over natural arrival order."""
    from repro.core import replication_factor
    from repro.graphs.generators import community_graph

    k = 8
    src, dst, n = community_graph(4000, n_communities=64, avg_degree=8,
                                  p_intra=0.95, seed=0)
    E = len(src)
    parts, rf = {}, {}
    orderings = {
        "natural": EdgeStream(src, dst, n),
        "w256": EdgeStream(src, dst, n, ordering="windowed", window=256),
        "w4096": EdgeStream(src, dst, n, ordering="windowed", window=4096),
        "w65536": EdgeStream(src, dst, n, ordering="windowed", window=65536),
        "dst-sorted": EdgeStream(src, dst, n, ordering="dst-sorted"),
    }
    assert E < 65536  # so the largest window subsumes the whole stream
    for name, stream in orderings.items():
        parts[name] = np.asarray(hdrf_partition(src, dst, n, k, stream=stream))
        rf[name] = replication_factor(src, dst, parts[name], n_vertices=n, k=k)
    assert np.array_equal(parts["w65536"], parts["dst-sorted"])
    best_windowed = min(rf["w256"], rf["w4096"], rf["w65536"])
    assert best_windowed <= rf["natural"] + 0.02, rf


def test_partitioning_valid_under_any_ordering():
    src, dst, n, _ = random_graph(1)
    k = 4
    for ordering in ("shuffled", "dst-sorted", "windowed"):
        st = EdgeStream(src, dst, n, chunk_size=64, ordering=ordering, seed=2)
        parts = np.asarray(hdrf_partition(src, dst, n, k, stream=st))
        valid = src != dst
        assert np.all(parts[valid] >= 0) and np.all(parts[valid] < k)
        # parts are reported in arrival order: the self-loop mask lines up
        assert np.all(parts[~valid] == -1)


# -------------------------------------------------------- kernel vs ref
@pytest.mark.parametrize("seed", list(cases(4)))
@pytest.mark.parametrize("mode", ["greedy", "hdrf"])
def test_stream_scan_kernel_matches_ref(seed, mode):
    src, dst, n, label = random_graph(seed)
    if len(src) == 0:
        return
    k = 4
    if mode == "greedy":
        carry = greedy_init(n, k)
        (load, rep), ref_parts = greedy_chunk(carry, jnp.asarray(src), jnp.asarray(dst))
        pd0 = jnp.zeros((n,), jnp.int32)
        parts, load2, rep2, _ = stream_scan_tpu(
            src, dst, carry[0], carry[1].astype(jnp.int32), pd0, 0.0, mode="greedy")
    else:
        carry = hdrf_init(n, k)
        (load, rep, pd, _, _), ref_parts = hdrf_chunk(
            carry, jnp.asarray(src), jnp.asarray(dst))
        parts, load2, rep2, pd2 = stream_scan_tpu(
            src, dst, carry[0], carry[1].astype(jnp.int32), carry[2], carry[3],
            mode="hdrf")
        assert np.array_equal(np.asarray(pd), np.asarray(pd2))
    assert np.array_equal(np.asarray(ref_parts), np.asarray(parts)), label
    assert np.array_equal(np.asarray(load), np.asarray(load2))
    # the counted megakernel maintains exact replica counters in-kernel —
    # equality is now bitwise, not just the 0/1 scoring projection
    assert np.array_equal(np.asarray(rep), np.asarray(rep2))


def test_kernel_chunked_via_engine_matches_scan():
    """Kernel-backed chunk fn driven by run_scan == plain partitioner."""
    from repro.kernels.stream_scan import make_chunk_fn

    src, dst, n, _ = random_graph(1)
    k = 4
    st = EdgeStream(src, dst, n, chunk_size=53)
    parts, _ = run_scan(st, hdrf_init(n, k), make_chunk_fn("hdrf", use_kernel=True))
    ref = hdrf_partition(src, dst, n, k)
    assert np.array_equal(np.asarray(parts), np.asarray(ref))


# ------------------------------------------------------ batched engines
def test_hdrf_batched_multi_lambda():
    src, dst, n, _ = random_graph(0)
    k = 4
    lams = [0.5, 1.1, 4.0]
    batch = np.asarray(hdrf_partition_batched(src, dst, n, ks=[k] * 3, lams=lams))
    for i, lam in enumerate(lams):
        one = np.asarray(hdrf_partition(src, dst, n, k, lam=lam))
        assert np.array_equal(batch[i], one), lam


def test_hdrf_batched_multi_k():
    src, dst, n, _ = random_graph(2)
    ks = [2, 3, 4]
    batch = np.asarray(hdrf_partition_batched(src, dst, n, ks=ks))
    valid = src != dst
    for i, k in enumerate(ks):
        one = np.asarray(hdrf_partition(src, dst, n, k))
        assert np.array_equal(batch[i], one), k
        assert np.all(batch[i][valid] < k)


def test_edge_chunk_pipeline_step_addressable():
    """data-pipeline contract: chunk(step) is a pure function of step."""
    from repro.data.pipeline import EdgeChunkPipeline

    src, dst, n, _ = random_graph(0)
    pipe = EdgeChunkPipeline(src, dst, n, chunk_size=31, ordering="shuffled", seed=4)
    a = pipe(2)
    pipe2 = EdgeChunkPipeline(src, dst, n, chunk_size=31, ordering="shuffled", seed=4)
    b = pipe2(2)
    assert np.array_equal(np.asarray(a["src"]), np.asarray(b["src"]))
    assert a["start"] == b["start"] and a["n_valid"] == b["n_valid"]
    # wrapping replays the same chunk in the next epoch
    nc = pipe.stream.n_chunks
    c = pipe(2 + nc)
    assert np.array_equal(np.asarray(a["src"]), np.asarray(c["src"]))
    assert c["epoch"] == a["epoch"] + 1


def test_grid_multi_seed():
    src, dst, n, _ = random_graph(1)
    k = 4
    seeds = [0, 1, 7]
    batch = np.asarray(grid_partition_multi_seed(src, dst, n, k, seeds))
    for i, s in enumerate(seeds):
        assert np.array_equal(batch[i], np.asarray(grid_partition(src, dst, n, k, s)))
