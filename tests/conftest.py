import os
import sys

import pytest

# tests see the real device count (1 CPU); only the dry-run forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# Shared compiled-partitioner results.  Several tests exercise the same
# (partitioner, graph, k) combination; running each once per session keeps
# the jit caches warm and halves the scan/game compile churn in tier-1.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module", autouse=True)
def _drop_compiled_programs_between_modules():
    """Compiled XLA executables otherwise accumulate for the whole session
    (one process, ~450 tests, dozens of distinct (k, shape) game/scan
    traces); on CPU jaxlib that growth has ended in a segfault inside
    ``backend_compile`` late in the run.  Nearly all cache reuse happens
    within a module, so dropping programs at module boundaries bounds the
    growth at negligible recompile cost.  Session fixtures below memoize
    *results* (numpy arrays), not traces, and are unaffected.
    """
    yield
    import jax

    jax.clear_caches()


@pytest.fixture(scope="session")
def parts_cache():
    """Memoized ``get(name, graph_seed, k=4, part_seed=0) -> np.ndarray``."""
    import numpy as np

    from proptest import random_graph
    from repro.core.baselines import PARTITIONERS

    cache: dict = {}

    def get(name: str, graph_seed: int, k: int = 4, part_seed: int = 0):
        key = (name, graph_seed, k, part_seed)
        if key not in cache:
            src, dst, n, _ = random_graph(graph_seed)
            cache[key] = np.asarray(
                PARTITIONERS[name](src, dst, n, k, part_seed))
        return cache[key]

    return get


@pytest.fixture(scope="session")
def community_bench_graph():
    """The Table-3-style community graph shared by the paper-claim tests."""
    from repro.graphs.generators import community_graph

    return community_graph(2000, n_communities=32, avg_degree=8, seed=5)


@pytest.fixture(scope="session")
def s5p_exact_community(community_bench_graph):
    """One exact-Θ S5P run on the shared community graph (reused across
    the two-stage-vs-one-stage and CMS-vs-exact claims)."""
    from repro.core import S5PConfig, s5p_partition

    src, dst, n = community_bench_graph
    return s5p_partition(src, dst, n, S5PConfig(k=8, use_cms=False))
