"""Incremental re-partitioning subsystem: store, deltas, drift, refinement.

Layers:

1. *CarryStore* — save→restore is bit-identical for every PartitionerCarry
   implementation in the repo (hypothesis-gated fuzz + seeded fallback,
   matching tests/test_carry.py); corrupted (CRC-mismatched), config-hash-
   mismatched, wrong-consumer, stale-position and structure-mismatched
   checkpoints all **raise** instead of silently loading; keep-N GC.
2. *Warm == cold* — for the composition-exact consumers (degree, Θ sketch,
   Alg. 1 clustering, greedy, grid, Alg. 3 placement) a warm-start replay
   of the delta reproduces the cold run over prefix+delta **bit-
   identically** (carry and emitted parts).
3. *Golden anchor* — resuming a saved carry and replaying an *empty* delta
   reproduces the sequential golden hashes of tests/test_streaming.py.
4. *Shard append* — append(prefix)+append(delta) streams bit-identically
   to a one-shot write of the concatenation.
5. *Pipeline quality anchor* — on the community fixture, a 10 % delta with
   drift-triggered refinement lands within 5 % of the cold re-run's RF
   while replaying < 25 % of the folds a cold run costs.
6. *CLI e2e* — --save-carry / --resume-carry / --delta, including a
   ``file:`` OOC stream grown via shard append.  Slow lane: a larger
   two-delta drift/refinement band on R-MAT.
"""

import hashlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import random_graph
from test_carry import _fold_random, _make_carry_impls, _tree_equal
from repro.core import S5PConfig, replication_factor, s5p_partition
from repro.core.baselines import greedy_partition, grid_partition
from repro.core.clustering import ClusterCarry, DegreeCarry, compute_degrees
from repro.core.cms import SketchCarry
from repro.core.game import GameInputs, run_game
from repro.core.postprocess import AssignCarry
from repro.incremental import (
    CarryMismatchError,
    CarryStore,
    DeltaStream,
    cold_start,
    grow_carry,
    run_incremental,
    run_incremental_carry,
)
from repro.streaming import (
    EdgeStream,
    ShardedEdgeStream,
    append_shards,
    run_carry,
    run_parallel,
    write_shards,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

K = 4

CARRY_NAMES = sorted(_make_carry_impls(8).keys())


def _h(a) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(a)).tobytes()).hexdigest()[:16]


def _roundtrip(name, seed, n, tmp_path):
    pc, n_extras = _make_carry_impls(n)[name]
    rng = np.random.default_rng(seed)
    carry = _fold_random(pc, n_extras, n, rng)
    store = CarryStore(tmp_path / f"{name}-{seed}-{n}")
    store.save(carry, consumer=name, config={"n": n, "k": K},
               stream_pos=34)
    got, meta = store.load(like=pc.init(), consumer=name,
                           config={"n": n, "k": K})
    assert meta["stream_pos"] == 34
    assert _tree_equal(got, carry), name
    # dtypes survive too (bool bitmaps, uint32 sketch tables, f32 λ)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(carry)):
        assert np.asarray(a).dtype == np.asarray(b).dtype, name


# ======================================================== 1. CarryStore
@pytest.mark.parametrize("name", CARRY_NAMES)
def test_store_roundtrip_bitwise(name, tmp_path):
    _roundtrip(name, 0, 23, tmp_path)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(name=st_.sampled_from(CARRY_NAMES), seed=st_.integers(0, 255),
           n=st_.integers(2, 48))
    def test_store_roundtrip_fuzzed(name, seed, n, tmp_path_factory):
        _roundtrip(name, seed, n,
                   tmp_path_factory.mktemp("fuzz"))

else:

    @pytest.mark.parametrize("seed", [1, 2, 3])
    def test_store_roundtrip_seeded(seed, tmp_path):
        for name in CARRY_NAMES:
            _roundtrip(name, seed, 7 + 5 * seed, tmp_path)


def test_store_rejects_corruption(tmp_path):
    """A bit-flipped array fails the CRC verify instead of loading."""
    pc, _ = _make_carry_impls(16)["degree"]
    carry = _fold_random(pc, 0, 16, np.random.default_rng(0))
    store = CarryStore(tmp_path)
    path = store.save(carry, consumer="degree", config={}, stream_pos=34)
    npz = path / "arrays.npz"
    with np.load(npz) as z:
        arrays = {k: z[k].copy() for k in z.files}
    key = next(k for k in arrays if k != "meta")
    arrays[key].flat[0] += 1  # corrupt one count
    np.savez(npz, **arrays)
    with pytest.raises(IOError, match="corruption"):
        store.load(like=pc.init())


def test_store_rejects_mismatches(tmp_path):
    pc, _ = _make_carry_impls(16)["degree"]
    carry = _fold_random(pc, 0, 16, np.random.default_rng(0))
    store = CarryStore(tmp_path)
    store.save(carry, consumer="degree", config={"k": 4}, stream_pos=34)
    with pytest.raises(CarryMismatchError, match="consumer"):
        store.load(like=pc.init(), consumer="hdrf")
    with pytest.raises(CarryMismatchError, match="fingerprint"):
        store.load(like=pc.init(), config={"k": 8})
    with pytest.raises(CarryMismatchError, match="stream position"):
        store.load(like=pc.init(), max_stream_pos=33)
    # structural drift: a different consumer's treedef cannot assemble
    other, _ = _make_carry_impls(16)["hdrf"]
    with pytest.raises(CarryMismatchError, match="structure"):
        store.load(like=other.init())
    # matching everything loads fine
    got, _ = store.load(like=pc.init(), consumer="degree", config={"k": 4},
                        max_stream_pos=34)
    assert _tree_equal(got, carry)


def test_store_mid_stream_checkpoint_fallback(tmp_path):
    """A bounded load falls back to the furthest checkpoint that fits the
    stream instead of raising on the (too-new) latest one."""
    pc, _ = _make_carry_impls(8)["degree"]
    store = CarryStore(tmp_path)
    rng = np.random.default_rng(0)
    mid = _fold_random(pc, 0, 8, rng)
    store.save(mid, consumer="degree", config={}, stream_pos=10)
    store.save(_fold_random(pc, 0, 8, rng), consumer="degree", config={},
               stream_pos=20)
    got, meta = store.load(like=pc.init(), max_stream_pos=15)
    assert meta["stream_pos"] == 10
    assert _tree_equal(got, mid)
    with pytest.raises(CarryMismatchError, match="stream position"):
        store.load(like=pc.init(), max_stream_pos=5)  # nothing fits


def test_store_keep_n_gc_and_latest(tmp_path):
    pc, _ = _make_carry_impls(8)["degree"]
    store = CarryStore(tmp_path, keep=2)
    rng = np.random.default_rng(0)
    last = None
    for pos in (10, 20, 30, 40):
        last = _fold_random(pc, 0, 8, rng)
        store.save(last, consumer="degree", config={}, stream_pos=pos)
    assert store.steps() == [30, 40]  # keep-N dropped the oldest
    got, meta = store.load(like=pc.init())
    assert meta["stream_pos"] == 40  # latest = furthest-ingested
    assert _tree_equal(got, last)


# ================================================== 2. warm == cold
# consumers whose padding self-loops are complete no-ops compose exactly;
# hdrf is deliberately absent (padding feeds its partial degrees — the
# documented approximately-incremental case)
EXACT = ["degree", "sketch", "cluster", "greedy", "grid", "assign"]


@pytest.mark.parametrize("name", EXACT)
@pytest.mark.parametrize("graph_seed", [0, 1])
def test_warm_start_equals_cold_bitwise(name, graph_seed, tmp_path):
    src, dst, n, _ = random_graph(graph_seed)
    E = len(src)
    if E < 8:
        pytest.skip("graph too small to split")
    E0 = int(E * 0.7)
    pc, n_extras = _make_carry_impls(n)[name]
    extras = ()
    if n_extras:
        rng = np.random.default_rng(0)
        extras = (rng.integers(0, 2, E).astype(bool),
                  rng.integers(0, 8, E).astype(np.int32),
                  rng.integers(0, 8, E).astype(np.int32))
    pre_extras = tuple(e[:E0] for e in extras)
    d_extras = tuple(e[E0:] for e in extras)

    cs = 13  # deliberately unaligned with E0: padding sits mid-stream
    pre_parts, pre = run_carry(
        EdgeStream(src[:E0], dst[:E0], n, chunk_size=cs), pc, *pre_extras)
    store = CarryStore(tmp_path / name)
    store.save(pre, consumer=name, config={"n": n}, stream_pos=E0)
    restored, _ = store.load(like=pc.init(), consumer=name,
                             config={"n": n}, max_stream_pos=E)
    warm_parts, warm = run_incremental_carry(
        DeltaStream(src[E0:], dst[E0:], n, base_offset=E0, chunk_size=cs),
        pc, *d_extras, carry=restored)
    cold_parts, cold = run_carry(
        EdgeStream(src, dst, n, chunk_size=cs), pc, *extras)
    assert _tree_equal(warm, cold), name
    if cold_parts is not None:
        joined = np.concatenate([np.asarray(pre_parts),
                                 np.asarray(warm_parts)])
        assert np.array_equal(joined, np.asarray(cold_parts)), name


def test_warm_start_parallel_ingest_linear_carries(tmp_path):
    """run_parallel(carry=...) warm-starts exactly for SUM-only carries:
    the restored carry is the merge base, so any S agrees with cold."""
    src, dst, n, _ = random_graph(2)
    E = len(src)
    E0 = int(E * 0.6)
    ref = np.asarray(compute_degrees(jnp.asarray(src), jnp.asarray(dst), n))
    _, pre = run_carry(EdgeStream(src[:E0], dst[:E0], n, chunk_size=17),
                       DegreeCarry(n))
    for S in (1, 2, 4):
        _, warm = run_parallel(
            DeltaStream(src[E0:], dst[E0:], n, chunk_size=17),
            DegreeCarry(n), num_streams=S, super_chunk=2,
            backend="threads" if S > 1 else None, carry=pre)
        assert np.array_equal(np.asarray(warm), ref), S


def test_grow_carry_extends_by_identity():
    """Growing then folding == folding at the larger vertex count from
    scratch (the grown rows are the identity; grid's hash tables are
    per-vertex, so the old prefix is reproduced)."""
    src, dst, n, _ = random_graph(1)
    n_big = n + 13
    for name in ("greedy", "hdrf", "grid", "cluster", "degree"):
        pc_small, _ = _make_carry_impls(n)[name]
        pc_big, _ = _make_carry_impls(n_big)[name]
        grown = grow_carry(name, pc_small.init(), n, n_big, k=K)
        if name == "grid":
            # test fixture's grid uses custom row/col tables; only check
            # the real CLI construction (hash tables) via the driver tests
            continue
        assert _tree_equal(grown, pc_big.init()), name


# ==================================================== 3. golden anchor
# sha256[:16] golden hashes from tests/test_streaming.py: resuming a
# saved carry and replaying an EMPTY delta must reproduce them exactly
GOLDEN_EMPTY = {
    (0, "hdrf"): "b4ebed498be31d51",
    (1, "hdrf"): "dd6c23e3a17a526d",
    (0, "greedy"): "97490d30834620fa",
    (1, "greedy"): "ef351eb5d7f38e6e",
    (0, "s5p"): "5c2abcabc60d546d",
    (1, "s5p"): "173c8ab805ce8473",
}


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("name", ["greedy", "hdrf"])
def test_empty_delta_reproduces_goldens_scans(seed, name, tmp_path):
    src, dst, n, _ = random_graph(seed)
    store = tmp_path / name
    cold_start(store, name, src, dst, n, K)
    res = run_incremental(store, name, src, dst, n, K, save=False)
    assert res.n_delta_edges == 0 and res.edges_replayed == 0
    assert _h(res.parts) == GOLDEN_EMPTY[(seed, name)]


@pytest.mark.parametrize("seed", [0, 1])
def test_empty_delta_reproduces_goldens_s5p(seed, tmp_path):
    src, dst, n, _ = random_graph(seed)
    # pin the seed-era game parameters, exactly as test_streaming does
    cfg = S5PConfig(k=K, use_cms=False, game_accept_prob=0.7,
                    game_max_rounds=64, seed=0)
    store = tmp_path / "s5p"
    cold_start(store, "s5p", src, dst, n, K, s5p_config=cfg)
    res = run_incremental(store, "s5p", src, dst, n, K, s5p_config=cfg,
                          save=False)
    assert res.n_delta_edges == 0 and not res.refined
    assert _h(res.parts) == GOLDEN_EMPTY[(seed, "s5p")]


# ===================================================== 4. shard append
@pytest.mark.parametrize("shard_edges", [16, 64])
def test_append_shards_parity(shard_edges, tmp_path):
    """append(prefix)+append(delta) == one-shot write(prefix+delta):
    same manifest geometry, bit-identical chunks, same partitions."""
    src, dst, n, _ = random_graph(3)
    E = len(src)
    cut1, cut2 = int(E * 0.5), int(E * 0.8)

    one = write_shards(tmp_path / "one", src, dst, shard_edges=shard_edges,
                       n_vertices=n)
    grown = write_shards(tmp_path / "grown", src[:cut1], dst[:cut1],
                         shard_edges=shard_edges, n_vertices=n)
    append_shards(grown, src[cut1:cut2], dst[cut1:cut2])
    append_shards(grown, src[cut2:], dst[cut2:])

    import json
    m1 = json.loads(one.read_text())
    m2 = json.loads(grown.read_text())
    assert m1["n_edges"] == m2["n_edges"] == E
    assert [s["n_edges"] for s in m1["shards"]] == \
           [s["n_edges"] for s in m2["shards"]]
    for ordering in ("natural", "dst-sorted"):
        with ShardedEdgeStream(one, chunk_size=23, ordering=ordering) as a, \
             ShardedEdgeStream(grown, chunk_size=23, ordering=ordering) as b:
            for ca, cb in zip(a.chunks(), b.chunks()):
                assert np.array_equal(np.asarray(ca.src), np.asarray(cb.src))
                assert np.array_equal(np.asarray(ca.dst), np.asarray(cb.dst))
                assert ca.n_valid == cb.n_valid
    # and a partitioner fed from the grown directory matches in-memory
    with ShardedEdgeStream(grown, chunk_size=64) as st:
        from_disk = np.asarray(greedy_partition(src, dst, n, K, stream=st))
    assert np.array_equal(from_disk,
                          np.asarray(greedy_partition(src, dst, n, K,
                                                      chunk_size=64)))


def test_append_shards_validates_fields(tmp_path):
    src, dst, n, _ = random_graph(0)
    w = np.ones(len(src), np.float32)
    man = write_shards(tmp_path, src, dst, w, shard_edges=32,
                       field_names=["w"])
    with pytest.raises(ValueError, match="extra fields"):
        append_shards(man, src[:5], dst[:5])  # missing the w field
    with pytest.raises(ValueError, match="dtype"):
        append_shards(man, src[:5], dst[:5], np.ones(5, np.int64))
    with pytest.raises(ValueError, match="equal-length"):
        append_shards(man, src[:5], dst[:4], w[:5])
    # a valid append with extras works and grows the field
    append_shards(man, src[:5], dst[:5], w[:5])
    with ShardedEdgeStream(man) as st:
        assert st.n_edges == len(src) + 5
        fv = st.open_field("w")
        assert fv.shape[0] == len(src) + 5


# =========================================== 5. pipeline quality anchor
def test_incremental_s5p_quality_anchor(tmp_path):
    """10 % delta + drift-triggered refinement: RF within 5 % of the cold
    full re-run while replaying < 25 % of the folds a cold run costs."""
    from repro.graphs.generators import community_graph

    src, dst, n = community_graph(1200, n_communities=24, avg_degree=8,
                                  seed=5)
    E = len(src)
    E0 = int(E * 0.9)
    k = 8
    cfg = S5PConfig(k=k, use_cms=False, chunk_size=512,
                    drift_rf_threshold=0.0, refine_rounds=16)
    store = tmp_path / "s5p"
    cold_start(store, "s5p", src[:E0], dst[:E0], n, k, s5p_config=cfg)
    res = run_incremental(store, "s5p", src, dst, n, k, s5p_config=cfg,
                          save=False)
    assert res.refined  # threshold 0 ⇒ the delta triggers the game
    assert res.n_delta_edges == E - E0
    p = res.parts
    valid = src != dst
    assert p.shape == src.shape
    assert np.all(p[valid] >= 0) and np.all(p[valid] < k)
    assert np.all(p[~valid] == -1)
    # the paper-claim comparison: cold full re-run on prefix+delta
    cold = s5p_partition(src, dst, n, cfg)
    rf_cold = replication_factor(src, dst, cold.parts, n_vertices=n, k=k)
    assert res.rf <= rf_cold * 1.05, (res.rf, rf_cold)
    assert res.replay_fraction < 0.25, res.replay_fraction


def test_masked_game_freezes_non_movers():
    """move_mask semantics: frozen clusters keep their assignment exactly;
    movable ones reach a constrained equilibrium."""
    rng = np.random.default_rng(0)
    C, k = 40, 4
    sizes = rng.uniform(1, 10, C).astype(np.float32)
    pa, pb = np.triu_indices(C, 1)
    keep = rng.random(pa.size) < 0.2
    pa, pb = pa[keep].astype(np.int32), pb[keep].astype(np.int32)
    pw = rng.uniform(0.5, 3.0, pa.size).astype(np.float32)
    inputs = GameInputs(sizes=jnp.asarray(sizes), pair_a=jnp.asarray(pa),
                        pair_b=jnp.asarray(pb), pair_w=jnp.asarray(pw),
                        n_head=10, k=k)
    assign0 = (np.arange(C) % k).astype(np.int32)
    move = np.zeros(C, bool)
    move[::3] = True
    res = run_game(inputs, C, assign0=assign0, max_rounds=16,
                   leader_mask=np.arange(C) < 10, move_mask=move)
    out = np.asarray(res.assignment)
    assert np.array_equal(out[~move], assign0[~move])
    assert np.all((out >= 0) & (out < k))
    # all-frozen game is a no-op that converges immediately
    res0 = run_game(inputs, C, assign0=assign0, max_rounds=16,
                    leader_mask=np.arange(C) < 10,
                    move_mask=np.zeros(C, bool))
    assert np.array_equal(np.asarray(res0.assignment), assign0)
    assert bool(res0.converged)


# ============================================================ 6. CLI e2e
def test_incremental_cli_e2e_ooc_append(tmp_path):
    """--save-carry / --resume-carry against a file: stream grown via
    shard append, end to end through the CLI's run()."""
    from repro.launch import partition as cli

    g = tmp_path / "g"
    store = tmp_path / "carry"
    cli.write_shards_cli("rmat:9", str(g), 2048)
    rows = cli.run(f"file:{g}/manifest.json", K, "hdrf",
                   chunk_size=1024, save_carry=str(store))
    assert rows[0][0] == "hdrf"
    cli.write_shards_cli("rmat:8", str(g), 2048, append=True)
    res = cli.run(f"file:{g}/manifest.json", K, "hdrf",
                  chunk_size=1024, resume_carry=str(store))
    assert res.n_delta_edges > 0
    p, (src, dst) = res.parts, ShardedEdgeStream(
        g / "manifest.json").arrival_arrays()
    valid = src != dst
    assert p.shape == src.shape
    assert np.all(p[valid] >= 0) and np.all(p[valid] < K)
    # the grown bundle was persisted: resuming again sees an empty delta
    res2 = cli.run(f"file:{g}/manifest.json", K, "hdrf",
                   chunk_size=1024, resume_carry=str(store))
    assert res2.n_delta_edges == 0
    assert np.array_equal(res2.parts, res.parts)


def test_incremental_cli_delta_spec_and_validation(tmp_path):
    from repro.launch import partition as cli

    store = tmp_path / "carry"
    cli.run("toy", K, "greedy", save_carry=str(store))
    res = cli.run("toy", K, "greedy", resume_carry=str(store),
                  delta="rmat:5")
    assert res.n_delta_edges > 0
    with pytest.raises(ValueError, match="single --partitioner"):
        cli.run("toy", K, "greedy", compare=True, save_carry=str(store))
    with pytest.raises(ValueError, match="resume-carry"):
        cli.run("toy", K, "greedy", delta="rmat:5")
    with pytest.raises(ValueError, match="natural"):
        cli.run("toy", K, "greedy", ordering="shuffled",
                save_carry=str(store))
    with pytest.raises(ValueError, match="incremental bundle"):
        cli.run("toy", K, "hash", save_carry=str(tmp_path / "x"))
    # config fingerprint guards the resume (different k)
    with pytest.raises(CarryMismatchError):
        cli.run("toy", 8, "greedy", resume_carry=str(store))


def test_foreign_stream_rejected_by_prefix_crc(tmp_path):
    """config + position alone would admit any longer stream; the prefix
    CRC in the carry metadata catches a same-config foreign graph."""
    src, dst, n, _ = random_graph(0)
    store = tmp_path / "c"
    cold_start(store, "greedy", src, dst, n, K)
    other = np.array(src, np.int32)
    other[0] = (other[0] + 1) % n  # same length, different first edge
    full_src = np.concatenate([other, src[:3]])
    full_dst = np.concatenate([np.asarray(dst, np.int32), dst[:3]])
    with pytest.raises(CarryMismatchError, match="foreign"):
        run_incremental(store, "greedy", full_src, full_dst, n, K,
                        save=False)


def test_bench_discovery_only_accepts_full_names():
    from benchmarks.run import _module_names, discover

    names = _module_names()
    assert "incremental_bench" in names
    mods, broken = discover("incremental_bench")
    assert not broken and list(mods) == ["incremental"]
    mods2, _ = discover("incremental")
    assert list(mods2) == ["incremental"]
    assert discover("no-such-bench") == ({}, [])


# ====================================== slow lane: larger drift band
@pytest.mark.slow
def test_incremental_drift_quality_band_large(tmp_path):
    """Two successive 10 % deltas on a skewed R-MAT stream: the second
    resume replays only its own delta, cumulative drift stays inside the
    refinement band, and total replay stays ≪ two cold re-runs."""
    from repro.graphs import rmat_graph

    src, dst, n = rmat_graph(13, edge_factor=8, seed=11)
    src, dst = np.asarray(src, np.int32), np.asarray(dst, np.int32)
    E = len(src)
    c1, c2 = int(E * 0.8), int(E * 0.9)
    k = 8
    cfg = S5PConfig(k=k, chunk_size=1 << 14, drift_rf_threshold=0.02,
                    refine_rounds=16)
    store = tmp_path / "s5p"
    cold_start(store, "s5p", src[:c1], dst[:c1], n, k, s5p_config=cfg)
    r1 = run_incremental(store, "s5p", src[:c2], dst[:c2], n, k,
                         s5p_config=cfg)
    r2 = run_incremental(store, "s5p", src, dst, n, k, s5p_config=cfg,
                         save=False)
    assert r2.n_delta_edges == E - c2  # only the new suffix replayed
    cold = s5p_partition(src, dst, n, cfg)
    rf_cold = replication_factor(src, dst, cold.parts, n_vertices=n, k=k)
    # cumulative band: two warm hops stay within 10 % of one cold run
    assert r2.rf <= rf_cold * 1.10, (r1.rf, r2.rf, rf_cold)
    assert r1.replay_fraction < 0.25 and r2.replay_fraction < 0.25
    valid = src != dst
    p = r2.parts
    assert np.all(p[valid] >= 0) and np.all(p[valid] < k)
