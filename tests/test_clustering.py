"""Algorithm 1: the jitted scan must be bit-identical to the paper listing."""

import numpy as np
import pytest

from proptest import cases, random_graph
from repro.core import cluster_stream, compute_degrees, reference_cluster_python
from repro.core.clustering import compact_clusters
from repro.graphs import toy_graph_fig3


@pytest.mark.parametrize("seed", [
    s if s < 8 else pytest.param(s, marks=pytest.mark.slow)
    for s in cases(12)
])
def test_scan_matches_reference(seed):
    src, dst, n, label = random_graph(seed)
    if len(src) == 0:
        return
    rng = np.random.default_rng(seed)
    xi = int(rng.integers(1, 8))
    kappa = int(rng.integers(4, 2 * len(src) + 4))
    ref = reference_cluster_python(list(zip(src.tolist(), dst.tolist())), n, xi, kappa)
    st = cluster_stream(src, dst, n, xi=xi, kappa=kappa, chunk_size=max(len(src), 1))
    assert np.array_equal(np.asarray(st.v2c_h), ref["v2c_h"].astype(np.int32)), label
    assert np.array_equal(np.asarray(st.v2c_t), ref["v2c_t"].astype(np.int32)), label
    assert np.array_equal(np.asarray(st.ld), ref["ld"].astype(np.int32))
    assert int(st.next_h) == ref["next_h"]
    assert int(st.next_t) == ref["next_t"]


@pytest.mark.parametrize("seed", list(cases(4, 100)))
def test_chunked_equals_unchunked(seed):
    src, dst, n, _ = random_graph(seed)
    if len(src) < 8:
        return
    st1 = cluster_stream(src, dst, n, xi=3, kappa=50, chunk_size=len(src))
    st2 = cluster_stream(src, dst, n, xi=3, kappa=50, chunk_size=7)
    assert np.array_equal(np.asarray(st1.v2c_h), np.asarray(st2.v2c_h))
    assert np.array_equal(np.asarray(st1.v2c_t), np.asarray(st2.v2c_t))


def test_toy_graph_head_tail_split():
    """Paper Fig. 3/ξ: head vertices are exactly the high-degree ones."""
    src, dst, n = toy_graph_fig3()
    deg = compute_degrees(src, dst, n)
    xi = 2 * len(src) // n  # β=1 ⇒ ξ = avg degree = 2
    st = cluster_stream(src, dst, n, xi=xi, kappa=9)
    res = compact_clusters(st, deg, xi)
    head_vertices = set(np.nonzero(np.asarray(deg) > xi)[0].tolist())
    assert head_vertices == {0, 1, 2, 3, 6}
    # every head vertex has a head cluster; tail-only vertices don't
    v2ch = np.asarray(res.v2c_h)
    assert all(v2ch[v] >= 0 for v in head_vertices)
    assert all(v2ch[v] < 0 for v in range(n) if v not in head_vertices)
    assert res.n_head >= 1


def test_streaming_memory_contract():
    """Carry is O(|V|): arrays sized V / V+1 only."""
    src, dst, n = toy_graph_fig3()
    st = cluster_stream(src, dst, n, xi=2, kappa=9)
    assert st.v2c_h.shape == (n,)
    assert st.vol_h.shape == (n + 1,)
    assert st.ld.shape == (n,)
