"""Elastic scaling demo: lose a worker mid-run, re-partition with S5P,
reshard the checkpoint, keep training — the full DESIGN.md §5 flow.

    PYTHONPATH=src python examples/elastic_demo.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import S5PConfig, s5p_partition, replication_factor
from repro.graphs.datasets import cora_like
from repro.models import gnn as G
from repro.optim import AdamWConfig, make_train_step, init_state
from repro.runtime import ElasticController


def main():
    data = cora_like(seed=0)
    cfg = G.GCNConfig(n_layers=2, d_hidden=16, d_feat=1433, n_classes=7)
    state = init_state(G.gcn_init(cfg, jax.random.PRNGKey(0)))
    step = jax.jit(make_train_step(G.gcn_loss, cfg, AdamWConfig(lr=0.01)))
    batch = {
        "feats": jnp.asarray(data.features),
        "edge_src": jnp.asarray(data.src),
        "edge_dst": jnp.asarray(data.dst),
        "labels": jnp.asarray(data.labels),
    }

    def repartition(k):
        parts = s5p_partition(data.src, data.dst, data.n_vertices,
                              S5PConfig(k=k)).parts
        rf = replication_factor(data.src, data.dst, parts,
                                n_vertices=data.n_vertices, k=k)
        print(f"  S5P re-partitioned for k={k}: RF={rf:.3f}")
        return parts

    manager = CheckpointManager("/tmp/repro_elastic", keep=2, async_write=False)
    controller = ElasticController(
        manager,
        make_mesh=lambda n: jax.make_mesh((1,), ("data",)),  # 1 CPU here
        repartition=repartition,
    )

    # phase 1: 8 workers
    print("phase 1: k=8 workers")
    repartition(8)
    for i in range(20):
        state, metrics = step(state, batch)
    print(f"  20 steps, loss {float(metrics['loss']):.4f}")

    # a worker dies → resize to 7: checkpoint → remesh → re-partition → reshard
    print("worker lost → elastic resize to k=7")
    state, mesh, parts, at_step = controller.resize(state, 20, 7)
    for i in range(20):
        state, metrics = step(state, batch)
    print(f"  resumed from step {at_step}, 20 more steps, "
          f"loss {float(metrics['loss']):.4f}")
    assert np.isfinite(float(metrics["loss"]))
    print("elastic resize complete — no training state lost")


if __name__ == "__main__":
    main()
