"""End-to-end training driver: GCN node classification on a Cora-shaped
graph — data pipeline → model → AdamW → checkpointed fault-tolerant loop.

    PYTHONPATH=src python examples/train_gnn.py --steps 300
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.graphs.datasets import cora_like
from repro.models import gnn as G
from repro.optim import AdamWConfig, make_train_step, init_state
from repro.runtime import FaultInjector, FaultTolerantLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[150])
    args = ap.parse_args()

    data = cora_like(seed=0)
    cfg = G.GCNConfig(n_layers=2, d_hidden=16, d_feat=1433, n_classes=7)
    params = G.gcn_init(cfg, jax.random.PRNGKey(0))
    state = init_state(params)

    n = data.n_vertices
    rng = np.random.default_rng(0)
    train_mask = (rng.random(n) < 0.7).astype(np.float32)
    batch = {
        "feats": jnp.asarray(data.features),
        "edge_src": jnp.asarray(data.src),
        "edge_dst": jnp.asarray(data.dst),
        "labels": jnp.asarray(data.labels),
        "label_mask": jnp.asarray(train_mask),
    }
    step = jax.jit(make_train_step(G.gcn_loss, cfg, AdamWConfig(lr=0.01)))

    manager = CheckpointManager("/tmp/repro_gcn_ckpt", keep=2)
    loop = FaultTolerantLoop(step, lambda s: batch, manager, ckpt_every=50,
                             injector=FaultInjector(args.fail_at))
    t0 = time.time()
    state, n_steps, metrics = loop.run(state, args.steps)
    dt = time.time() - t0

    logits = G.gcn_forward(state.params, batch["feats"], batch["edge_src"],
                           batch["edge_dst"], n, cfg)
    pred = np.asarray(jnp.argmax(logits, -1))
    test = train_mask == 0
    acc = (pred[test] == np.asarray(data.labels)[test]).mean()
    print(f"trained {n_steps} steps in {dt:.1f}s "
          f"({dt / n_steps * 1e3:.1f} ms/step), "
          f"{loop.restarts} injected-failure restart(s)")
    print(f"final loss {float(metrics['loss'] if isinstance(metrics, dict) else 0):.4f}, "
          f"held-out accuracy {acc:.3f}")


if __name__ == "__main__":
    main()
