"""Quickstart: partition a graph with S5P and compare against baselines.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import S5PConfig, s5p_partition, replication_factor, load_balance
from repro.core.baselines import PARTITIONERS
from repro.graphs import toy_graph_fig3
from repro.graphs.generators import community_graph


def main():
    # 1. the paper's toy graph (Fig. 3), k = 3
    src, dst, n = toy_graph_fig3()
    out = s5p_partition(src, dst, n, S5PConfig(k=3))
    print(f"toy graph: {out.n_clusters} clusters "
          f"({out.n_head_clusters} head), game converged in "
          f"{out.game_rounds} round(s)")
    rf = replication_factor(src, dst, out.parts, n_vertices=n, k=3)
    print(f"toy graph RF = {rf:.3f}, balance = "
          f"{load_balance(out.parts, k=3):.2f}\n")

    # 2. a web-like community graph, S5P vs streaming baselines
    src, dst, n = community_graph(4000, n_communities=64, avg_degree=8, seed=0)
    print(f"community graph: |V|={n} |E|={len(src)}  (k=8)")
    for name in ("hash", "dbh", "hdrf", "2ps-l", "clugp", "s5p"):
        parts = PARTITIONERS[name](src, dst, n, 8)
        rf = replication_factor(src, dst, parts, n_vertices=n, k=8)
        print(f"  {name:8s} RF={rf:.3f}")


if __name__ == "__main__":
    main()
