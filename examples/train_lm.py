"""LM pretraining driver on the public API (reduced-size by default; pass
--d-model 768 --layers 12 for a ~100M-param run if you have the cycles).

    PYTHONPATH=src python examples/train_lm.py --steps 100
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import TokenPipeline, Prefetcher
from repro.models import lm as LM
from repro.optim import AdamWConfig, make_train_step, init_state
from repro.optim.schedules import cosine_schedule
from repro.runtime import FaultTolerantLoop, StragglerMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = LM.LMConfig(
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 2), n_kv_heads=max(args.d_model // 128, 1),
        d_head=64, d_ff=args.d_model * 4, vocab=8192, attn_chunk=128,
        dtype=jnp.float32,
    )
    print(f"model: {LM.count_params(cfg) / 1e6:.1f}M params")
    params = LM.init_params(cfg, jax.random.PRNGKey(0))
    state = init_state(params)
    opt = AdamWConfig(lr=3e-4, schedule=cosine_schedule(20, args.steps))
    step = jax.jit(make_train_step(LM.loss_fn, cfg, opt))

    pipe = Prefetcher(TokenPipeline(cfg.vocab, args.batch, args.seq, seed=1))
    pipe.start()
    monitor = StragglerMonitor()
    manager = CheckpointManager("/tmp/repro_lm_ckpt", keep=2)
    loop = FaultTolerantLoop(step, pipe, manager, ckpt_every=max(args.steps // 2, 10),
                             straggler_monitor=monitor)
    t0 = time.time()
    state, n_steps, metrics = loop.run(state, args.steps)
    dt = time.time() - t0
    pipe.stop()
    tok_s = args.batch * args.seq * n_steps / dt
    print(f"{n_steps} steps in {dt:.1f}s — {tok_s:,.0f} tokens/s, "
          f"final loss {float(metrics['loss']):.3f}")


if __name__ == "__main__":
    main()
