"""Distributed-graph-processing example: S5P feeding the GAS engine.

Reproduces the paper's §6.6 deployment story: partition with S5P vs hash,
run PageRank on the PowerGraph-style engine, report exact replica-sync
communication per superstep.

    PYTHONPATH=src python examples/pagerank_comm.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import S5PConfig, s5p_partition
from repro.core.baselines import hash_partition
from repro.gas import build_gas_graph, pagerank
from repro.graphs.generators import community_graph


def main():
    src, dst, n = community_graph(5000, n_communities=64, avg_degree=10, seed=1)
    k = 16
    print(f"graph |V|={n} |E|={len(src)}, {k} partitions, PageRank ×10\n")
    results = {}
    for name, parts in (
        ("hash", hash_partition(src, dst, n, k)),
        ("s5p", s5p_partition(src, dst, n, S5PConfig(k=k)).parts),
    ):
        g = build_gas_graph(src, dst, parts, n, k)
        vals, stats = pagerank(g, iterations=10)
        results[name] = (np.asarray(vals), stats.total_bytes())
        print(f"{name:5s} comm = {stats.total_bytes() / 1e6:.2f} MB "
              f"({stats.mirror_to_master_msgs} mirror msgs)")
    assert np.allclose(results["hash"][0], results["s5p"][0], rtol=1e-4), \
        "partitioning must not change the answer"
    red = 1 - results["s5p"][1] / results["hash"][1]
    print(f"\nS5P reduces PageRank communication by {red:.1%} "
          f"(identical results)")


if __name__ == "__main__":
    main()
