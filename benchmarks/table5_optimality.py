"""Table 5 analogue: approximation ratio α = RF / OPT on tiny graphs.

OPT by exhaustive enumeration over k^|E| assignments under the balance
constraint — feasible only at toy scale (the paper does the same)."""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import S5PConfig, replication_factor, s5p_partition
from repro.core.baselines import PARTITIONERS

from .common import emit, timed


def _optimal_rf(src, dst, n, k):
    E = len(src)
    best = np.inf
    cap = int(np.ceil(1.5 * E / k))
    for assign in itertools.product(range(k), repeat=E):
        counts = np.bincount(assign, minlength=k)
        if counts.max() > cap:
            continue
        reps = np.zeros((n, k), bool)
        reps[src, assign] = True
        reps[dst, assign] = True
        present = reps.any(1)
        rf = reps.sum() / max(present.sum(), 1)
        best = min(best, rf)
    return best


_TINY = {
    "G_alpha": ([(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (0, 3),
                 (1, 4), (2, 5)], 7),
    "G_beta": ([(0, 1), (0, 2), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6),
                (6, 7), (7, 0), (1, 5)], 8),
}


def run(quick: bool = True):
    k = 3
    for name, (edges, n) in _TINY.items():
        src = np.array([e[0] for e in edges], np.int32)
        dst = np.array([e[1] for e in edges], np.int32)
        opt, us_opt = timed(_optimal_rf, src, dst, n, k)
        emit(f"table5/{name}/opt", us_opt, f"RF={opt:.3f}")
        for m in ("hdrf", "clugp", "s5p"):
            parts, us = timed(PARTITIONERS[m], src, dst, n, k)
            rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
            from repro.core import load_balance
            bal = load_balance(parts, k=k)
            # α < 1 is possible only by violating the balance OPT enforces
            # (HDRF's soft balance degenerates at toy scale — see the bal col)
            emit(f"table5/{name}/{m}", us,
                 f"RF={rf:.3f};alpha={rf/opt:.3f};bal={bal:.2f}")
