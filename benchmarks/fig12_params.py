"""Fig. 12 analogue: parameter sensitivity — β (head/tail threshold) and
game batch size."""

from __future__ import annotations

from repro.core import S5PConfig, replication_factor, s5p_partition

from .common import emit, get_graph, timed


def run(quick: bool = True):
    src, dst, n = get_graph("social-like")
    k = 8
    betas = (0.5, 1.0, 2.0) if quick else (0.25, 0.5, 1.0, 2.0, 4.0, 8.0)
    for beta in betas:
        out, us = timed(s5p_partition, src, dst, n,
                        S5PConfig(k=k, beta=beta))
        rf = replication_factor(src, dst, out.parts, n_vertices=n, k=k)
        emit(f"fig12a/beta{beta}", us,
             f"RF={rf:.3f};head_clusters={out.n_head_clusters};"
             f"clusters={out.n_clusters}")
    for bs in (16, 64, 256):
        out, us = timed(s5p_partition, src, dst, n,
                        S5PConfig(k=k, game_batch_size=bs))
        rf = replication_factor(src, dst, out.parts, n_vertices=n, k=k)
        emit(f"fig12b/batch{bs}", us, f"RF={rf:.3f};rounds={out.game_rounds}")
