"""Fig. 11 analogue: deployment on the GAS engine — PageRank comm + runtime.

Communication is counted exactly (replica sync messages); "runtime" is the
modeled distributed time = max-shard compute + comm/bandwidth under the
paper's RTT sweep (we cannot wall-clock a 32-node docker cluster here, but
the comm volumes — the quantity the paper's speedups derive from — are
exact).
"""

from __future__ import annotations

from repro.core import S5PConfig, s5p_partition, replication_factor
from repro.core.baselines import PARTITIONERS
from repro.gas import build_gas_graph, pagerank
from repro.gas.engine import comm_stats

from .common import emit, get_graph, timed

METHODS = ("hash", "dbh", "hdrf", "2ps-l", "s5p")


def run(quick: bool = True):
    src, dst, n = get_graph("web-like")
    k = 8
    iters = 5
    base_comm = None
    for m in METHODS:
        parts = (s5p_partition(src, dst, n, S5PConfig(k=k)).parts
                 if m == "s5p" else PARTITIONERS[m](src, dst, n, k))
        g = build_gas_graph(src, dst, parts, n, k)
        (vals, stats), us = timed(pagerank, g, iters)
        comm = stats.total_bytes()
        rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
        if m == "hash":
            base_comm = comm
        red = (1 - comm / base_comm) * 100 if base_comm else 0.0
        # modeled distributed runtime: per-iter sync at 1 GB/s + 10 ms RTT
        t_model = iters * (comm / iters / 1e9 + 0.010)
        emit(f"fig11/pagerank/{m}", us,
             f"RF={rf:.3f};comm_B={comm};comm_reduction={red:.1f}%;"
             f"modeled_s={t_model:.3f}")
