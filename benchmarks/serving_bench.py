"""Living Fig. 11: PageRank served continuously over live partitions.

The static Fig. 11 reproduction (``fig11_pagerank.py``) partitions once
and runs PageRank once.  This bench runs the full serving loop instead:
a sliding-window churn stream keeps the partitions fresh (delta folds,
expiry retractions, drift-triggered refinement, cold restarts), every
step is published as an **atomic bundle swap**, and a GAS reader executes
super-steps and point queries against pinned versions throughout — so the
numbers are the deployment-shaped ones: replication factor → mirror-sync
bytes per super-step → query latency, per partitioner, under churn.

Two routing policies drive the *same* churn schedule and the *same*
controller/registry/server stack:

- **s5p** — :class:`repro.incremental.S5PWindowChain` (clustering +
  Stackelberg refinement, auto cold restart on ξ drift);
- **hdrf** — :class:`HdrfWindowChain` below: the HDRF scoring carry folds
  insertions and *retracts* expiries through the parallel lane-masked
  path (``run_retract(num_streams=2)``), i.e. the score-based streaming
  baseline upgraded with this repo's decremental machinery.

Substrate: hub-heavy **block R-MAT** (power-law hubs inside planted
communities — the web/social regime of the paper's corpus, where
clustering-based partitioners recover structure HDRF's degree scores
cannot see).  The acceptance gate asserts S5P's mirror-sync bytes per
super-step do not exceed HDRF's here.
"""

from __future__ import annotations

from types import SimpleNamespace

import numpy as np

from repro.core.metrics import load_balance, replication_factor
from repro.core.s5p import S5PConfig
from repro.graphs import block_rmat_graph
from repro.incremental import S5PWindowChain
from repro.kernels.stream_scan import HdrfCarry
from repro.serving import BundleRegistry, GASServer, ServingController
from repro.streaming import SlidingWindowStream, as_stream, run_carry, \
    run_retract

from .common import emit

SUPERSTEPS_PER_SWAP = 4
QUERY_BATCH = 32


class HdrfWindowChain:
    """Windowed HDRF routing — duck-types :class:`S5PWindowChain`.

    Insertions fold through the HDRF scoring carry (``run_carry``);
    expiries retract through the **parallel** decremental path
    (``run_retract`` with ``num_streams`` sharded lanes — bit-identical
    to sequential retraction by the carry group algebra).  The serving
    controller publishes its live window exactly as it does S5P's.
    """

    def __init__(self, src, dst, n_vertices: int, k: int,
                 window_edges: int, *, step_edges: int | None = None,
                 lam: float = 1.1, num_streams: int = 2, seed: int = 0):
        st = as_stream(src, dst, n_vertices, chunk_size=window_edges)
        self._sw = SlidingWindowStream(st, window_edges,
                                       step_edges=step_edges)
        self.n_vertices = int(st.n_vertices)
        self.window_edges = int(window_edges)
        self.config = SimpleNamespace(k=int(k), seed=seed)
        self.k = int(k)
        self.num_streams = int(num_streams)
        self.pc = HdrfCarry(self.n_vertices, self.k, lam)
        self.carry = self.pc.init()
        E = st.n_edges
        self._parts = np.full(E, -1, np.int32)  # arrival-indexed
        self._buf_src = np.empty(E, np.int32)
        self._buf_dst = np.empty(E, np.int32)
        self._events = self._sw.events()
        self.lo = 0
        self.hi = 0
        self.bundle = None  # duck field (no S5P bundle)

    def live_partition(self):
        if self.hi <= self.lo:
            return None
        sl = slice(self.lo, self.hi)
        return (self._buf_src[sl].copy(), self._buf_dst[sl].copy(),
                self._parts[sl].copy())

    def step(self):
        ev = next(self._events, None)
        if ev is None:
            return None
        B = ev.src.size
        if B:
            st = as_stream(ev.src, ev.dst, self.n_vertices, chunk_size=B)
            parts, self.carry = run_carry(st, self.pc, carry=self.carry)
            self._parts[ev.start:ev.start + B] = np.asarray(parts)
            self._buf_src[ev.start:ev.start + B] = ev.src
            self._buf_dst[ev.start:ev.start + B] = ev.dst
        if ev.expire_idx.size:
            D = int(ev.expire_idx.size)
            dstream = as_stream(ev.expire_src, ev.expire_dst,
                                self.n_vertices, chunk_size=D)
            self.carry = run_retract(
                dstream, self.pc, self._parts[ev.expire_idx],
                carry=self.carry, num_streams=self.num_streams)
            self._parts[ev.expire_idx] = -1
        self.lo, self.hi = ev.lo, ev.hi
        filling = self.hi < self.window_edges and self.hi < self._sw.n_edges
        rf = bal = 0.0
        if not filling:
            s, d, p = self.live_partition()
            rf = float(replication_factor(s, d, p, n_vertices=self.n_vertices,
                                          k=self.k))
            bal = float(load_balance(p, k=self.k))
        return SimpleNamespace(filling=filling, lo=self.lo, hi=self.hi,
                               rf=rf, balance=bal)


def _serve(chain, n_vertices: int, seed: int = 0):
    """Drive one chain through the full serving loop; return metrics."""
    registry = BundleRegistry()
    controller = ServingController(registry, chain)
    server = GASServer(registry)
    rng = np.random.default_rng(seed)
    last = -1
    while controller.step() is not None:
        if registry.current_version == last:  # filling — nothing published
            continue
        last = registry.current_version
        server.run(SUPERSTEPS_PER_SWAP)
        server.query_pagerank(rng.integers(0, n_vertices, QUERY_BATCH))
    steps = server.run_to_convergence(tol=1e-5, max_steps=50)
    return server, controller, steps


def run(quick: bool = True):
    if quick:
        src, dst, n = block_rmat_graph(block_scale=6, n_blocks=16,
                                       edge_factor=8, seed=0)
    else:
        src, dst, n = block_rmat_graph(block_scale=7, n_blocks=32,
                                       edge_factor=8, seed=0)
    E = src.size
    k = 8
    window = E // 2
    step = max(window // 3, 1)

    # warm the jit cache at the serving shapes (E_live = window) so the
    # first-measured method's query latency is not one-time compile cost
    from repro.serving import build_bundle
    wreg = BundleRegistry()
    wreg.publish(build_bundle(0, src[:window], dst[:window],
                              (src[:window] % k).astype(np.int32), n, k))
    warm = GASServer(wreg)
    warm.run(2)
    warm.query_pagerank(np.zeros(QUERY_BATCH, np.int64))

    results = {}
    for method in ("s5p", "hdrf"):
        if method == "s5p":
            cfg = S5PConfig(k=k, seed=0, chunk_size=window)
            chain = S5PWindowChain(src, dst, n, cfg, window,
                                   step_edges=step, auto_cold_restart=True)
        else:
            chain = HdrfWindowChain(src, dst, n, k, window,
                                    step_edges=step, num_streams=2)
        server, controller, conv_steps = _serve(chain, n)
        s = server.metrics.summary()
        assert s["swaps_observed"] >= 2, \
            f"{method}: need ≥2 atomic swaps under churn, saw " \
            f"{s['swaps_observed']}"
        assert controller.registry.active_pins == 0
        results[method] = s
        emit(f"serving/{method}",
             s["query_latency_us_mean"],
             f"RF={s['rf_final']:.3f};"
             f"bytes_per_superstep={s['sync_bytes_per_superstep']:.0f};"
             f"supersteps={s['supersteps']};swaps={s['swaps_observed']};"
             f"versions={controller.version};conv_steps={conv_steps}")

    ratio = (results["s5p"]["sync_bytes_per_superstep"]
             / max(results["hdrf"]["sync_bytes_per_superstep"], 1))
    emit("serving/s5p_vs_hdrf_bytes", 0.0, f"ratio={ratio:.3f}")
    assert ratio <= 1.0, \
        f"S5P mirror-sync bytes/superstep exceed HDRF's (ratio {ratio:.3f})"
