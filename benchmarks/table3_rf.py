"""Table 3 analogue: RF of the top streaming partitioners across graphs × k.

Paper claim: S5P ≤ every streaming baseline at equal τ, with the largest
margins on web-like (strong-community) graphs.
"""

from __future__ import annotations

from repro.core import load_balance, replication_factor
from repro.core.baselines import PARTITIONERS

from .common import GRAPHS, emit, get_graph, timed

METHODS = ("hdrf", "2ps-l", "clugp", "s5p")


def run(quick: bool = True):
    ks = (8,) if quick else (8, 16, 32)
    winners = 0
    cells = 0
    for gname in GRAPHS:
        src, dst, n = get_graph(gname)
        for k in ks:
            rfs = {}
            for m in METHODS:
                parts, us = timed(PARTITIONERS[m], src, dst, n, k)
                rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
                bal = load_balance(parts, k=k)
                rfs[m] = rf
                emit(f"table3/{gname}/k{k}/{m}", us,
                     f"RF={rf:.3f};bal={bal:.2f}")
            cells += 1
            best_baseline = min(v for m, v in rfs.items() if m != "s5p")
            if rfs["s5p"] <= best_baseline * 1.02:
                winners += 1
    emit("table3/summary", 0.0,
         f"s5p_best_or_tied={winners}/{cells}_cells")
