"""Out-of-core streaming: partition a multi-million-edge R-MAT from disk.

The paper's regime — the edge list does not fit in host memory — on a
machine where it would: the graph is written once as mmap-paged shards,
the arrays are dropped, and the HDRF scan runs purely through
:class:`ShardedEdgeStream` under a fixed host-memory budget (asserted
against the stream's byte-accounting hook).  ``--full`` runs the ~5M-edge
configuration; quick mode stays at the kernels-bench ≥1M-edge scale.
"""

from __future__ import annotations

import shutil
import tempfile

import numpy as np

from repro.core.baselines import hdrf_partition
from repro.graphs import rmat_graph
from repro.streaming import ShardedEdgeStream, write_shards

from .common import emit, timed

# host-memory budget for the stream's own allocations (far below the
# edge list: the quick graph is ~9 MB of edges, the full one ~42 MB)
STREAM_BUDGET_BYTES = 8 << 20


def run(quick: bool = True):
    scale, ef = (16, 17) if quick else (18, 20)  # ~1.1M / ~5.2M edges
    k = 8
    src, dst, n = rmat_graph(scale, edge_factor=ef, seed=0, dedup=False)
    E = len(src)
    edge_bytes = 8 * E

    tmp = tempfile.mkdtemp(prefix="oocbench-")
    try:
        _, us_w = timed(write_shards, tmp, src, dst, shard_edges=1 << 18,
                        n_vertices=n)
        emit(f"oocstream/write_shards/{E}", us_w,
             f"edges_per_s={E / (us_w / 1e6):.0f}")

        # in-memory reference on the same graph (overhead baseline);
        # warm the chunk-scan compile cache so both rows time steady state
        hdrf_partition(src[: 1 << 16], dst[: 1 << 16], n, k,
                       chunk_size=1 << 16)
        ref, us_mem = timed(
            lambda: np.asarray(hdrf_partition(src, dst, n, k,
                                              chunk_size=1 << 16)))
        emit(f"oocstream/hdrf_in_memory/{E}", us_mem,
             f"edges_per_s={E / (us_mem / 1e6):.0f}")

        del src, dst  # the read path below must not touch host arrays

        with ShardedEdgeStream(tmp, chunk_size=1 << 16) as st:
            parts, us_d = timed(
                lambda: np.asarray(hdrf_partition(None, None, n, k, stream=st)))
            peak = st.budget.peak_bytes
        assert peak <= STREAM_BUDGET_BYTES, (peak, STREAM_BUDGET_BYTES)
        assert np.array_equal(parts, ref), "disk scan diverged from in-memory"
        emit(f"oocstream/hdrf_from_disk/{E}", us_d,
             f"edges_per_s={E / (us_d / 1e6):.0f},peak_host_bytes={peak},"
             f"edge_list_frac={peak / edge_bytes:.4f}")

        # external reorder pass (dst-sorted merge) — the expensive ordering
        with ShardedEdgeStream(tmp, chunk_size=1 << 16,
                               ordering="dst-sorted") as st:
            _, us_o = timed(lambda: sum(c.n_valid for c in st.chunks()))
            emit(f"oocstream/dst_sorted_pass/{E}", us_o,
                 f"edges_per_s={E / (us_o / 1e6):.0f},"
                 f"peak_host_bytes={st.budget.peak_bytes}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
