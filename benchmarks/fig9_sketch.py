"""Fig. 9 analogue: CMS vs exact Θ store — time, memory, RF, ε sweep."""

from __future__ import annotations

from repro.core import S5PConfig, replication_factor, s5p_partition

from .common import emit, get_graph, timed


def run(quick: bool = True):
    src, dst, n = get_graph("social-like")
    k = 8

    exact, us_e = timed(s5p_partition, src, dst, n,
                        S5PConfig(k=k, use_cms=False))
    rf_e = replication_factor(src, dst, exact.parts, n_vertices=n, k=k)
    emit("fig9/exact-RBT-equivalent", us_e,
         f"RF={rf_e:.4f};mem_B={exact.aux['exact_count_bytes']}")

    for eps, nu in [(0.1, 0.01)] + ([] if quick else [(0.05, 0.01), (0.2, 0.05)]):
        cms, us_c = timed(
            s5p_partition, src, dst, n,
            S5PConfig(k=k, use_cms=True, cms_epsilon=eps, cms_nu=nu))
        rf_c = replication_factor(src, dst, cms.parts, n_vertices=n, k=k)
        ratio = exact.aux["exact_count_bytes"] / max(cms.aux["sketch_bytes"], 1)
        emit(f"fig9/cms-eps{eps}", us_c,
             f"RF={rf_c:.4f};mem_B={cms.aux['sketch_bytes']};"
             f"mem_reduction={ratio:.1f}x;rf_delta={(rf_c - rf_e) / rf_e:+.3%}")
