"""Kernel microbenchmarks: Pallas (interpret on CPU) + jnp reference timing.

On CPU the interpret-mode timing is NOT indicative of TPU performance —
the point of these rows is the call-count/shape coverage and the oracle
parity check; the TPU roofline for the same shapes comes from §Roofline.

The megakernel section additionally persists a stable ``BENCH_kernels.json``
(schema below): edges/s per mode × backend × chunk size for the oracle
``lax.scan`` carry vs the one-dispatch-per-chunk Pallas megakernel, plus
the dispatch accounting that is the CPU-side acceptance surface — one
``pallas_call`` per chunk against the oracle's ``chunk_size`` sequential
scan steps per chunk.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.graphs import powerlaw_graph, rmat_graph
from repro.kernels import stream_scan as ss
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.stream_scan import hdrf_chunk, hdrf_init, stream_scan_tpu
from repro.models.attention import flash_attention
from repro.streaming import EdgeStream, run_carry, run_scan, run_scan_batched

from .common import emit, timed

BENCH_JSON = "BENCH_kernels.json"


def run_stream_scan(quick: bool = True):
    """stream_scan: fused Pallas chunk step vs the ``lax.scan`` reference
    on a ≥1M-edge synthetic R-MAT stream (the paper's G₁-style skew)."""
    k = 8
    src, dst, n = rmat_graph(16, edge_factor=17, seed=0, dedup=False)
    E = len(src)
    stream = EdgeStream(src, dst, n, chunk_size=1 << 16)

    def ref_full():
        parts, _ = run_scan(stream, hdrf_init(n, k), hdrf_chunk)
        return parts.block_until_ready()

    ref_full()  # warm the chunk-scan compile cache
    _, us = timed(ref_full)
    emit(f"kernels/stream_scan_ref_hdrf/{E}", us,
         f"edges_per_s={E / (us / 1e6):.0f}")

    # batched engine: 4 λ-scenarios in one pass (vmapped carry)
    lams = [0.5, 1.0, 1.5, 4.0]
    carries = [hdrf_init(n, k, lam) for lam in lams]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)

    def ref_batched():
        parts, _ = run_scan_batched(stream, stacked, hdrf_chunk)
        return parts.block_until_ready()

    ref_batched()
    _, usb = timed(ref_batched)
    emit(f"kernels/stream_scan_ref_hdrf_batched4/{E}", usb,
         f"scenario_edges_per_s={4 * E / (usb / 1e6):.0f}")

    # Pallas kernel: interpret mode on CPU is correctness-only, so time a
    # bounded slice of chunks and report per-edge cost on the same graph
    ek = E if jax.default_backend() == "tpu" else 4096
    load = jnp.zeros((k,), jnp.int32)
    rep = jnp.zeros((n, k), jnp.int32)
    pd = jnp.zeros((n,), jnp.int32)

    def kern():
        parts, *_ = stream_scan_tpu(src[:ek], dst[:ek], load, rep, pd, 1.1,
                                    mode="hdrf")
        return parts.block_until_ready()

    kern()  # warm the kernel compile, like the ref path above
    _, usk = timed(kern)
    note = "" if jax.default_backend() == "tpu" else \
        f"interpret-mode(correctness-only),{ek}/{E}_edges"
    emit(f"kernels/stream_scan_pallas/{ek}", usk, note)


def _mk_carry(mode, n, k, use_kernel):
    if mode == "greedy":
        return ss.GreedyCarry(n, k, use_kernel=use_kernel)
    return ss.HdrfCarry(n, k, use_kernel=use_kernel)


def _bench_pair(stream, make_oracle, make_kernel, n_edges):
    """Time oracle vs megakernel over one stream; returns a row fragment."""

    def drive(pc):
        parts, res = run_carry(stream, pc)
        leaf = parts if parts is not None else jax.tree_util.tree_leaves(res)[0]
        return leaf.block_until_ready()

    pc_o = make_oracle()
    drive(pc_o)  # warm the oracle compile cache
    _, us_o = timed(drive, pc_o)

    pc_k = make_kernel()
    ss.reset_dispatch_count()
    drive(pc_k)  # warm — and count dispatches on a clean counter
    dispatches = ss.dispatch_count()
    _, us_k = timed(drive, pc_k)
    return {
        "oracle_edges_per_s": round(n_edges / (us_o / 1e6)),
        "kernel_edges_per_s": round(n_edges / (us_k / 1e6)),
        "speedup_vs_oracle": round(us_o / us_k, 3),
        "dispatches_per_run": dispatches,
    }


def run_megakernel(quick: bool = True):
    """Megakernel study: one pallas_call per chunk (insert path) for the
    scoring (greedy/HDRF), clustering (Alg. 1) and placement (Alg. 3)
    folds vs their ``lax.scan`` oracles, across chunk sizes.  Persists
    ``BENCH_kernels.json`` (stable schema v1)."""
    backend = jax.default_backend()
    compiled = backend == "tpu"
    execution = "compiled" if compiled else "interpret"
    k = 8
    E = (1 << 16) if (compiled or not quick) else 4096
    chunks = ([4096, 16384, 65536] if (compiled or not quick)
              else [1024, 4096])
    src, dst, n = powerlaw_graph(max(E // 8, 64), avg_degree=8.0, rho=2.2,
                                 seed=0)
    src, dst = src[:E], dst[:E]
    E = int(src.shape[0])

    rows = []
    for chunk in chunks:
        stream = EdgeStream(src, dst, n, chunk_size=chunk)
        n_chunks = -(-E // chunk)
        for mode in ("greedy", "hdrf"):
            frag = _bench_pair(
                stream,
                lambda: _mk_carry(mode, n, k, False),
                lambda: _mk_carry(mode, n, k, True),
                E,
            )
            rows.append({
                "kernel": "scoring", "mode": mode, "execution": execution,
                "backend": backend, "chunk_size": chunk, "edges": E,
                "chunks": n_chunks, "oracle_scan_steps_per_run": E,
                "path": ss.select_path(n, k, chunk, mode=mode), **frag,
            })
            emit(f"kernels/mega_{mode}_{execution}/{chunk}",
                 1e6 * E / max(frag["kernel_edges_per_s"], 1),
                 f"edges_per_s={frag['kernel_edges_per_s']},"
                 f"dispatches={frag['dispatches_per_run']}/{n_chunks}_chunks")

        from repro.core.clustering import ClusterCarry, compute_degrees

        deg = compute_degrees(src, dst, n)
        ckw = dict(xi=max(int(2 * E / max(n, 1)), 1), kappa=max(E // k, 2))
        frag = _bench_pair(
            stream,
            lambda: ClusterCarry(deg, n, use_kernel=False, **ckw),
            lambda: ClusterCarry(deg, n, use_kernel=True, **ckw),
            E,
        )
        rows.append({
            "kernel": "cluster", "mode": "s5p", "execution": execution,
            "backend": backend, "chunk_size": chunk, "edges": E,
            "chunks": n_chunks, "oracle_scan_steps_per_run": E,
            "path": ss.select_path(n, 1, chunk, consumer="cluster"), **frag,
        })
        emit(f"kernels/mega_cluster_{execution}/{chunk}",
             1e6 * E / max(frag["kernel_edges_per_s"], 1),
             f"edges_per_s={frag['kernel_edges_per_s']},"
             f"dispatches={frag['dispatches_per_run']}/{n_chunks}_chunks")

        from repro.core.postprocess import AssignCarry

        import numpy as np

        rng = np.random.default_rng(0)
        n_cl = 64
        c2p = jnp.asarray(rng.integers(0, k, n_cl), jnp.int32)
        cu = jnp.asarray(rng.integers(0, n_cl, E), jnp.int32)
        cv = jnp.asarray(rng.integers(0, n_cl, E), jnp.int32)
        head = jnp.asarray(rng.integers(0, 2, E), jnp.int32)
        L = max(E // k, 1)
        astream = EdgeStream(src, dst, n, chunk_size=chunk)

        def adrive(pc):
            parts, _ = run_carry(astream, pc, head, cu, cv)
            return parts.block_until_ready()

        pc_o = AssignCarry(k, L, c2p, use_kernel=False)
        adrive(pc_o)
        _, us_o = timed(adrive, pc_o)
        pc_k = AssignCarry(k, L, c2p, use_kernel=True)
        ss.reset_dispatch_count()
        adrive(pc_k)
        dispatches = ss.dispatch_count()
        _, us_k = timed(adrive, pc_k)
        rows.append({
            "kernel": "assign", "mode": "alg3", "execution": execution,
            "backend": backend, "chunk_size": chunk, "edges": E,
            "chunks": n_chunks, "oracle_scan_steps_per_run": E,
            "path": ss.select_path(0, k, chunk, consumer="assign"),
            "oracle_edges_per_s": round(E / (us_o / 1e6)),
            "kernel_edges_per_s": round(E / (us_k / 1e6)),
            "speedup_vs_oracle": round(us_o / us_k, 3),
            "dispatches_per_run": dispatches,
        })
        emit(f"kernels/mega_assign_{execution}/{chunk}",
             1e6 * E / max(round(E / (us_k / 1e6)), 1),
             f"edges_per_s={round(E / (us_k / 1e6))},"
             f"dispatches={dispatches}/{n_chunks}_chunks")

    doc = {
        "schema": 1,
        "backend": backend,
        "execution": execution,
        "vmem_budget": ss.vmem_budget(),
        "dispatch_contract": {
            "kernel_dispatches_per_chunk": 1,
            "oracle_scan_steps_per_chunk": "chunk_size",
        },
        "rows": rows,
    }
    Path(BENCH_JSON).write_text(json.dumps(doc, indent=2, sort_keys=True)
                                + "\n")
    emit("kernels/mega_json", 0.0, f"wrote={BENCH_JSON},rows={len(rows)}")


def run(quick: bool = True):
    B, S, H, KV, hd = 1, 512, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    f = jax.jit(lambda *a: flash_attention(*a, True, None, 128, 128))
    f(q, k, v, pos, pos).block_until_ready()
    _, us = timed(lambda: f(q, k, v, pos, pos).block_until_ready())
    flops = 4 * B * H * S * S * hd * 0.5  # causal
    emit("kernels/flash_attention_jnp/512", us, f"gflops={flops / us / 1e3:.2f}")

    _, us2 = timed(lambda: flash_attention_tpu(q, k, v, pos, pos).block_until_ready())
    emit("kernels/flash_attention_pallas_interp/512", us2,
         "interpret-mode(correctness-only)")

    run_stream_scan(quick)
    run_megakernel(quick)
