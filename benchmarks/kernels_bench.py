"""Kernel microbenchmarks: Pallas (interpret on CPU) + jnp reference timing.

On CPU the interpret-mode timing is NOT indicative of TPU performance —
the point of these rows is the call-count/shape coverage and the oracle
parity check; the TPU roofline for the same shapes comes from §Roofline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_tpu
from repro.models.attention import flash_attention

from .common import emit, timed


def run(quick: bool = True):
    B, S, H, KV, hd = 1, 512, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    f = jax.jit(lambda *a: flash_attention(*a, True, None, 128, 128))
    f(q, k, v, pos, pos).block_until_ready()
    _, us = timed(lambda: f(q, k, v, pos, pos).block_until_ready())
    flops = 4 * B * H * S * S * hd * 0.5  # causal
    emit("kernels/flash_attention_jnp/512", us, f"gflops={flops / us / 1e3:.2f}")

    _, us2 = timed(lambda: flash_attention_tpu(q, k, v, pos, pos).block_until_ready())
    emit("kernels/flash_attention_pallas_interp/512", us2,
         "interpret-mode(correctness-only)")
