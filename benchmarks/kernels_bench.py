"""Kernel microbenchmarks: Pallas (interpret on CPU) + jnp reference timing.

On CPU the interpret-mode timing is NOT indicative of TPU performance —
the point of these rows is the call-count/shape coverage and the oracle
parity check; the TPU roofline for the same shapes comes from §Roofline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graphs import rmat_graph
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.stream_scan import hdrf_chunk, hdrf_init, stream_scan_tpu
from repro.models.attention import flash_attention
from repro.streaming import EdgeStream, run_scan, run_scan_batched

from .common import emit, timed


def run_stream_scan(quick: bool = True):
    """stream_scan: fused Pallas chunk step vs the ``lax.scan`` reference
    on a ≥1M-edge synthetic R-MAT stream (the paper's G₁-style skew)."""
    k = 8
    src, dst, n = rmat_graph(16, edge_factor=17, seed=0, dedup=False)
    E = len(src)
    stream = EdgeStream(src, dst, n, chunk_size=1 << 16)

    def ref_full():
        parts, _ = run_scan(stream, hdrf_init(n, k), hdrf_chunk)
        return parts.block_until_ready()

    ref_full()  # warm the chunk-scan compile cache
    _, us = timed(ref_full)
    emit(f"kernels/stream_scan_ref_hdrf/{E}", us,
         f"edges_per_s={E / (us / 1e6):.0f}")

    # batched engine: 4 λ-scenarios in one pass (vmapped carry)
    lams = [0.5, 1.0, 1.5, 4.0]
    carries = [hdrf_init(n, k, lam) for lam in lams]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)

    def ref_batched():
        parts, _ = run_scan_batched(stream, stacked, hdrf_chunk)
        return parts.block_until_ready()

    ref_batched()
    _, usb = timed(ref_batched)
    emit(f"kernels/stream_scan_ref_hdrf_batched4/{E}", usb,
         f"scenario_edges_per_s={4 * E / (usb / 1e6):.0f}")

    # Pallas kernel: interpret mode on CPU is correctness-only, so time a
    # bounded slice of chunks and report per-edge cost on the same graph
    ek = E if jax.default_backend() == "tpu" else 4096
    load = jnp.zeros((k,), jnp.int32)
    rep = jnp.zeros((n, k), jnp.int32)
    pd = jnp.zeros((n,), jnp.int32)

    def kern():
        parts, *_ = stream_scan_tpu(src[:ek], dst[:ek], load, rep, pd, 1.1,
                                    mode="hdrf")
        return parts.block_until_ready()

    kern()  # warm the kernel compile, like the ref path above
    _, usk = timed(kern)
    note = "" if jax.default_backend() == "tpu" else \
        f"interpret-mode(correctness-only),{ek}/{E}_edges"
    emit(f"kernels/stream_scan_pallas/{ek}", usk, note)


def run(quick: bool = True):
    B, S, H, KV, hd = 1, 512, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    f = jax.jit(lambda *a: flash_attention(*a, True, None, 128, 128))
    f(q, k, v, pos, pos).block_until_ready()
    _, us = timed(lambda: f(q, k, v, pos, pos).block_until_ready())
    flops = 4 * B * H * S * S * hd * 0.5  # causal
    emit("kernels/flash_attention_jnp/512", us, f"gflops={flops / us / 1e3:.2f}")

    _, us2 = timed(lambda: flash_attention_tpu(q, k, v, pos, pos).block_until_ready())
    emit("kernels/flash_attention_pallas_interp/512", us2,
         "interpret-mode(correctness-only)")

    run_stream_scan(quick)
