"""Hybrid quality/memory frontier: RF vs host budget (repro.hybrid).

HEP's central claim, reproduced at container scale: between the pure-
streaming partitioner (budget 0) and the fully in-memory one (budget ≥
edge list) lies a *frontier* — each extra byte of resident core buys
replication quality.  The sweep runs ``run_hybrid`` on a hub-heavy block
R-MAT at budget fractions 0 → 100 % of the core-record cost of the whole
edge list and gates three invariants the driver guarantees by
construction:

- **monotone**: RF is non-increasing as the budget grows (a larger
  budget evaluates a superset of refinement candidates with an identical
  prefix);
- **dominates streaming**: hybrid RF ≤ pure-streaming RF at every
  non-zero budget rung (the incumbent is the pure-streaming run itself);
- **caged**: the peak ``HostBudget`` high-water mark never exceeds the
  requested budget (hard-cap accounting with ladder retreat).

Writes ``BENCH_hybrid.json`` (own-file idiom like ``kernels_bench``)
with the full frontier, and emits one ROWS line per rung.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .common import emit, timed

from repro.core.s5p import S5PConfig
from repro.graphs import block_rmat_graph
from repro.hybrid import CORE_EDGE_BYTES, run_hybrid

BENCH_JSON = "BENCH_hybrid.json"

# budget rungs as fractions of the whole edge list's core-record cost
FRACTIONS = (0.0, 0.05, 0.15, 0.3, 0.6, 1.0)


def sweep(quick: bool = True):
    scale = 7 if quick else 9
    src, dst, n = block_rmat_graph(block_scale=scale, n_blocks=8,
                                   edge_factor=8, seed=0)
    E = int(np.asarray(src).shape[0])
    cfg = S5PConfig(k=8, seed=0, chunk_size=1 << 14)
    full_bytes = E * CORE_EDGE_BYTES * 2  # headroom past every record

    rows = []
    prev_rf = None
    rf_streaming = None
    for frac in FRACTIONS:
        budget = int(frac * full_bytes)
        res, us = timed(run_hybrid, (src, dst, n), cfg, host_budget=budget)
        if rf_streaming is None:
            rf_streaming = res.rf_streaming
        # --- the three frontier gates ---
        if budget > 0:
            assert res.peak_budget_bytes <= budget, (
                f"budget gate: peak {res.peak_budget_bytes} > {budget}")
            assert res.rf <= rf_streaming + 1e-9, (
                f"dominance gate: {res.rf} > streaming {rf_streaming}")
        if prev_rf is not None:
            assert res.rf <= prev_rf + 1e-9, (
                f"monotone gate: {res.rf} > {prev_rf} at frac={frac}")
        prev_rf = res.rf
        rows.append({
            "budget_fraction": frac,
            "budget_bytes": budget,
            "mode": res.mode,
            "xi_star": int(res.xi_star) if res.mode != "streaming" else None,
            "core_edges": res.core_edges,
            "core_fraction": round(res.core_edges / max(E, 1), 4),
            "rf": round(res.rf, 6),
            "rf_streaming": round(res.rf_streaming, 6),
            "balance": round(res.balance, 4),
            "peak_budget_bytes": res.peak_budget_bytes,
            "accepted_levels": list(res.accepted_levels),
            "game_rounds": res.game_rounds,
            "plan_est_core_edges": res.plan.est_core_edges,
            "seconds": round(us / 1e6, 2),
        })
        emit(f"hybrid/frontier/{frac:g}", us,
             f"mode={res.mode},rf={res.rf:.4f},"
             f"core={res.core_edges},peak={res.peak_budget_bytes}B")

    doc = {
        "schema": 1,
        "graph": {"kind": "block_rmat", "scale": scale, "n_blocks": 8,
                  "edge_factor": 8, "edges": E, "vertices": int(n)},
        "k": cfg.k,
        "core_edge_bytes": CORE_EDGE_BYTES,
        "gates": {
            "monotone_rf": True,
            "hybrid_le_streaming": True,
            "peak_le_budget": True,
        },
        "rows": rows,
    }
    Path(BENCH_JSON).write_text(json.dumps(doc, indent=2, sort_keys=True)
                                + "\n")
    emit("hybrid/json", 0.0, f"wrote={BENCH_JSON},rows={len(rows)}")
    return rows


def run(quick: bool = True):
    sweep(quick)
