"""Table 4 analogue: game-based partitioning methods — RF / time / memory.

In scope: S5P's two-stage Stackelberg game vs the one-stage simultaneous
game (CLUGP-style) vs the edge-level game without clustering.  (RMGP /
MDSGP / CVSP are O(|V|³)-class algorithms the paper also dominates by
orders of magnitude; reproducing them is out of scope — noted in
EXPERIMENTS.md.)  Memory = persistent structure bytes (cluster tables +
Θ store), mirroring the paper's accounting.
"""

from __future__ import annotations

import numpy as np

from repro.core import S5PConfig, replication_factor, s5p_partition
from repro.core import game as _game
from repro.core.metrics import partition_loads

from .common import emit, get_graph, timed


def _edge_level_game(src, dst, n, k):
    """Every edge is a player (paper's 'w/o clustering' arm) — O(|E|²)
    adjacency, so only feasible small; here via vertex-shared pairs."""
    import jax.numpy as jnp

    E = len(src)
    sizes = np.ones(E, np.float32)
    by_v: dict[int, list[int]] = {}
    for e, (u, v) in enumerate(zip(src.tolist(), dst.tolist())):
        by_v.setdefault(u, []).append(e)
        by_v.setdefault(v, []).append(e)
    pa, pb = [], []
    for es in by_v.values():
        for i in range(len(es)):
            for j in range(i + 1, len(es)):
                pa.append(es[i])
                pb.append(es[j])
    inputs = _game.GameInputs(
        sizes=jnp.asarray(sizes), pair_a=jnp.asarray(pa, jnp.int32),
        pair_b=jnp.asarray(pb, jnp.int32),
        pair_w=jnp.ones(len(pa), jnp.float32), n_head=E, k=k,
    )
    res = _game.run_game(inputs, E, batch_size=max(16, E // 8), max_rounds=32)
    return res.assignment, len(pa)


def run(quick: bool = True):
    src, dst, n = get_graph("social-like")
    if quick:
        keep = min(len(src), 4000)
        src, dst = src[:keep], dst[:keep]
    k = 8

    out, us = timed(s5p_partition, src, dst, n, S5PConfig(k=k))
    rf = replication_factor(src, dst, out.parts, n_vertices=n, k=k)
    mem = out.aux["sketch_bytes"] + out.n_clusters * 8
    emit("table4/s5p-stackelberg", us, f"RF={rf:.3f};mem_B={mem};rounds={out.game_rounds}")

    out1, us1 = timed(s5p_partition, src, dst, n,
                      S5PConfig(k=k, one_stage=True))
    rf1 = replication_factor(src, dst, out1.parts, n_vertices=n, k=k)
    emit("table4/one-stage-game", us1, f"RF={rf1:.3f};rounds={out1.game_rounds}")

    (parts_e, n_pairs), us_e = timed(_edge_level_game, src, dst, n, k)
    rfe = replication_factor(src, dst, parts_e, n_vertices=n, k=k)
    emit("table4/edge-level-game", us_e,
         f"RF={rfe:.3f};pairs={n_pairs};mem_B={n_pairs * 12}")
