"""Benchmark driver: one module per paper table/figure or system study.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--out F]``
prints ``name,us_per_call,derived`` CSV rows and writes every row from the
run into one merged JSON file (default ``BENCH_RESULTS.json``).

Modules are auto-discovered: every ``benchmarks/*.py`` exposing a
``run(quick: bool)`` callable is a bench module (no manual registry to
forget when adding one); its ``--only`` alias is the module name up to
the first underscore (``table3_rf`` → ``table3``, ``oocstream_bench`` →
``oocstream``, ``parallel_ingest`` → ``parallel``).
"""

import argparse
import importlib
import json
import pkgutil
import sys
import traceback
from pathlib import Path


def discover() -> tuple[dict, list]:
    """Map alias → module for every bench module in this package.

    Returns ``(modules, broken)`` — a module that fails at *import* time
    lands in ``broken`` instead of crashing the driver, so one WIP file
    cannot take down the whole nightly sweep."""
    pkg_dir = Path(__file__).resolve().parent
    modules, broken = {}, []
    for info in sorted(pkgutil.iter_modules([str(pkg_dir)]),
                       key=lambda i: i.name):
        if info.name in ("run", "common") or info.name.startswith("_"):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{info.name}")
        except Exception:
            traceback.print_exc()
            broken.append(info.name)
            continue
        if not callable(getattr(mod, "run", None)):
            continue
        alias = info.name.split("_")[0]
        if alias in modules:  # alias collision: fall back to the full name
            alias = info.name
        modules[alias] = mod
    return modules, broken


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="BENCH_RESULTS.json",
                    help="merged JSON output path ('' disables)")
    args = ap.parse_args()

    from . import common

    modules, failed = discover()
    if args.only and args.only not in modules:
        ap.error(f"unknown bench {args.only!r}; one of {sorted(modules)}")
    print("name,us_per_call,derived")
    ran = []
    for name, mod in modules.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run(quick=not args.full)
            ran.append(name)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if args.out:
        merged = {
            "quick": not args.full,
            "modules_ran": ran,
            "modules_failed": failed,
            "rows": [
                {"name": n, "us_per_call": us, "derived": derived}
                for n, us, derived in common.ROWS
            ],
        }
        Path(args.out).write_text(json.dumps(merged, indent=1))
        print(f"[bench] wrote {len(common.ROWS)} rows to {args.out}",
              file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
