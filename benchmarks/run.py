"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]``
prints ``name,us_per_call,derived`` CSV rows.
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (fig7_components, fig9_sketch, fig11_pagerank, fig12_params,
                   fig13_skewness, kernels_bench, oocstream_bench, roofline,
                   table3_rf, table4_game, table5_optimality, windowed_quality)

    modules = {
        "table3": table3_rf, "table4": table4_game, "table5": table5_optimality,
        "fig7": fig7_components, "fig9": fig9_sketch, "fig11": fig11_pagerank,
        "fig12": fig12_params, "fig13": fig13_skewness,
        "kernels": kernels_bench, "roofline": roofline,
        "oocstream": oocstream_bench, "windowed": windowed_quality,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
