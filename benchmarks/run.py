"""Benchmark driver: one module per paper table/figure or system study.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--out F]``
prints ``name,us_per_call,derived`` CSV rows and writes every row from the
run into one merged JSON file (default ``BENCH_RESULTS.json``).

Modules are auto-discovered: every ``benchmarks/*.py`` exposing a
``run(quick: bool)`` callable is a bench module (no manual registry to
forget when adding one).  ``--only`` accepts either the full module name
(``incremental_bench``) or its alias — the name up to the first
underscore (``table3_rf`` → ``table3``, ``oocstream_bench`` →
``oocstream``, ``parallel_ingest`` → ``parallel``) — and filters *before
import*, so one bench re-runs without paying (or risking) every other
module's import.
"""

import argparse
import importlib
import json
import pkgutil
import sys
import traceback
from pathlib import Path


def _module_names() -> list[str]:
    """Candidate bench module names, no imports performed."""
    pkg_dir = Path(__file__).resolve().parent
    return sorted(
        info.name for info in pkgutil.iter_modules([str(pkg_dir)])
        if info.name not in ("run", "common") and not info.name.startswith("_")
    )


def discover(only: str | None = None) -> tuple[dict, list]:
    """Map alias → module for every bench module in this package.

    Returns ``(modules, broken)`` — a module that fails at *import* time
    lands in ``broken`` instead of crashing the driver, so one WIP file
    cannot take down the whole nightly sweep.  ``only`` (an alias or a
    full module name) filters before import."""
    modules, broken = {}, []
    for name in _module_names():
        alias = name.split("_")[0]
        # with a filter, exactly one module runs: a full-name match, or
        # the first importable holder of the alias (never both of two
        # modules that happen to share a prefix)
        if only is not None and name != only and not (
                alias == only and alias not in modules):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
        except Exception:
            traceback.print_exc()
            broken.append(name)
            continue
        if not callable(getattr(mod, "run", None)):
            continue
        if alias in modules:  # alias collision: fall back to the full name
            alias = name
        modules[alias] = mod
    return modules, broken


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run a single bench module (alias or full name)")
    ap.add_argument("--out", default="BENCH_RESULTS.json",
                    help="merged JSON output path ('' disables)")
    args = ap.parse_args()

    from . import common

    modules, failed = discover(args.only)
    if args.only and not modules and not failed:
        names = _module_names()
        aliases = sorted({n.split("_")[0] for n in names})
        ap.error(f"unknown bench {args.only!r}; aliases {aliases} "
                 f"or full names {names}")
    print("name,us_per_call,derived")
    ran = []
    for name, mod in modules.items():
        try:
            mod.run(quick=not args.full)
            ran.append(name)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if args.out:
        merged = {
            "quick": not args.full,
            "modules_ran": ran,
            "modules_failed": failed,
            "rows": [
                {"name": n, "us_per_call": us, "derived": derived}
                for n, us, derived in common.ROWS
            ],
        }
        Path(args.out).write_text(json.dumps(merged, indent=1))
        print(f"[bench] wrote {len(common.ROWS)} rows to {args.out}",
              file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
