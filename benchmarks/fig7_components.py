"""Fig. 7 analogue: component ablations.

(a/b) vertex-table clustering vs edge-centric clustering (space/time);
(c)   gaming with vs without clustering (RF across k);
(d)   two-stage Stackelberg vs one-stage simultaneous game (RF across k).
"""

from __future__ import annotations

import numpy as np

from repro.core import S5PConfig, replication_factor, s5p_partition
from repro.core.clustering import cluster_stream

from .common import emit, get_graph, timed


def run(quick: bool = True):
    src, dst, n = get_graph("social-like")
    ks = (8,) if quick else (8, 64, 256)

    # (a/b) S5P vertex-table clustering footprint vs O(|E|) edge-centric
    st, us = timed(cluster_stream, src, dst, n, xi=5, kappa=2 * len(src) // 8)
    vertex_bytes = sum(int(np.prod(x.shape)) * 4 for x in
                       (st.v2c_h, st.v2c_t, st.vol_h, st.vol_t, st.ld))
    edge_bytes = len(src) * 2 * 4  # edge-centric keeps per-edge labels
    emit("fig7ab/s5p-clustering", us,
         f"state_B={vertex_bytes};edge_centric_B={edge_bytes};"
         f"ratio={edge_bytes / vertex_bytes:.2f}")

    for k in ks:
        with_c = s5p_partition(src, dst, n, S5PConfig(k=k))
        rf_с = replication_factor(src, dst, with_c.parts, n_vertices=n, k=k)
        emit(f"fig7c/k{k}/with-clustering", 0.0, f"RF={rf_с:.3f}")

        one = s5p_partition(src, dst, n, S5PConfig(k=k, one_stage=True))
        rf1 = replication_factor(src, dst, one.parts, n_vertices=n, k=k)
        emit(f"fig7d/k{k}/one-stage", 0.0, f"RF={rf1:.3f}")
        emit(f"fig7d/k{k}/two-stage", 0.0,
             f"RF={rf_с:.3f};improvement={100 * (rf1 - rf_с) / max(rf1, 1e-9):.1f}%")
