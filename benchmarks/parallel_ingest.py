"""Parallel-ingest scaling study: quality-neutral S-way lanes (ISSUE 10).

The gate graph is the hub-heavy block R-MAT (planted communities, R-MAT
skew inside each): hub-sharded lanes must hold the S=8 replication
factor inside the ``RF_BAND`` of the sequential S5P drive — measured
here and **asserted**, then committed as ``BENCH_parallel.json`` so the
nightly lane catches regressions.  Speedup is bounded by
``min(S, host cores)``, so the ≥ 2× wall-clock gate only arms on a
≥ 4-core host (the 1–2-core dev containers measure, but don't assert).

Alongside the gate, the full quality surface: HDRF swept over
S ∈ {2, 4, 8} × shard ∈ {range, rr, hub} × super_chunk ∈ {1, 8, auto},
each row reporting RF relative to the sequential drive — the table
benchmarks/README.md quotes.  The linear-merge degree carry rides along
as an exactness assert (its parallel ingest is exact by algebra).

Quick mode runs the ~62k-edge block-scale-8 graph; ``--full`` doubles
the per-block scale (~123k edges).
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core import replication_factor
from repro.core.baselines import hdrf_partition
from repro.core.clustering import DegreeCarry, compute_degrees
from repro.core.s5p import S5PConfig, s5p_partition
from repro.graphs import block_rmat_graph
from repro.streaming import EdgeStream, run_parallel

from .common import emit

BENCH_JSON = "BENCH_parallel.json"
SWEEP_S = (2, 4, 8)
SHARDS = ("range", "rr", "hub")
CADENCES = (1, 8, "auto")
RF_BAND = 1.05  # S=8 hub/auto RF must stay within this × sequential
SPEEDUP_GATE = 2.0  # armed only on >= 4-core hosts
GATE_S = 8


def _rf(src, dst, parts, n, k):
    return float(replication_factor(src, dst, np.asarray(parts),
                                    n_vertices=n, k=k))


def run(quick: bool = True):
    bs = 8 if quick else 9
    k, cs = 8, 2048
    src, dst, n = block_rmat_graph(block_scale=bs, n_blocks=32,
                                   edge_factor=16, seed=1)
    E = len(src)
    cores = os.cpu_count() or 1
    stream = EdgeStream(src, dst, n, chunk_size=cs)
    rows: list[dict] = []

    # ---- S5P gate: sequential vs S=8 hub-sharded auto-cadence ----
    def s5p(**kw):
        cfg = S5PConfig(k=k, chunk_size=cs, seed=0, **kw)
        t0 = time.perf_counter()
        out = s5p_partition(src, dst, n, cfg)
        return out, time.perf_counter() - t0

    out_seq, t_seq = s5p(num_streams=1)
    rf_seq = _rf(src, dst, out_seq.parts, n, k)
    emit(f"parallel_ingest/s5p_S1/{E}", t_seq * 1e6,
         f"rf={rf_seq:.4f},edges_per_s={E / t_seq:.0f},cores={cores}")

    out_hub, t_hub = s5p(num_streams=GATE_S, shard="hub", super_chunk="auto")
    rf_hub = _rf(src, dst, out_hub.parts, n, k)
    ratio = rf_hub / rf_seq
    speedup = t_seq / t_hub
    # the placement pass's realized cadence (captured by s5p itself —
    # last_ingest_stats() here would see the touch-up's replay drive)
    ingest = out_hub.aux.get("parallel_ingest", {})
    tu = out_hub.aux.get("touch_up", {})
    emit(f"parallel_ingest/s5p_S{GATE_S}_hub_auto/{E}", t_hub * 1e6,
         f"rf={rf_hub:.4f},rf_vs_seq={ratio:.3f},speedup={speedup:.2f},"
         f"touch_up_moved={tu.get('moved_clusters', 0)}")
    assert ratio <= RF_BAND, (
        f"S={GATE_S} hub/auto RF {rf_hub:.4f} is {ratio:.3f}x the "
        f"sequential {rf_seq:.4f} — outside the {RF_BAND}x quality band")
    speedup_armed = cores >= 4
    if speedup_armed:
        assert speedup >= SPEEDUP_GATE, (
            f"S={GATE_S} hub/auto speedup {speedup:.2f}x under the "
            f"{SPEEDUP_GATE}x gate on a {cores}-core host")

    # ---- HDRF quality surface: S × shard × cadence ----
    t0 = time.perf_counter()
    hdrf_seq = np.asarray(hdrf_partition(None, None, n, k, stream=stream))
    t_hdrf_seq = time.perf_counter() - t0
    rf_hdrf_seq = _rf(src, dst, hdrf_seq, n, k)
    emit(f"parallel_ingest/hdrf_S1/{E}", t_hdrf_seq * 1e6,
         f"rf={rf_hdrf_seq:.4f},edges_per_s={E / t_hdrf_seq:.0f}")
    for S in SWEEP_S:
        for shard in SHARDS:
            for cadence in CADENCES:
                t0 = time.perf_counter()
                parts = np.asarray(hdrf_partition(
                    None, None, n, k, stream=stream, num_streams=S,
                    shard=shard, super_chunk=cadence))
                t_par = time.perf_counter() - t0
                valid = src != dst
                assert (parts[valid] >= 0).all() and (parts[valid] < k).all()
                rf = _rf(src, dst, parts, n, k)
                rows.append({
                    "partitioner": "hdrf", "S": S, "shard": shard,
                    "super_chunk": cadence, "rf": round(rf, 6),
                    "rf_vs_seq": round(rf / rf_hdrf_seq, 4),
                    "speedup": round(t_hdrf_seq / t_par, 3),
                    "seconds": round(t_par, 3),
                })
                emit(f"parallel_ingest/hdrf_S{S}_{shard}_{cadence}/{E}",
                     t_par * 1e6,
                     f"rf={rf:.4f},rf_vs_seq={rf / rf_hdrf_seq:.3f},"
                     f"speedup={t_hdrf_seq / t_par:.2f}")

    # ---- linear-merge carry: parallel degree ingest is exact ----
    deg_ref = np.asarray(compute_degrees(src, dst, n))
    t0 = time.perf_counter()
    _, deg = run_parallel(stream, DegreeCarry(n), num_streams=8,
                          shard="hub", super_chunk="auto", backend="threads")
    t_deg = time.perf_counter() - t0
    assert np.array_equal(np.asarray(deg), deg_ref), \
        "parallel degree ingest diverged (SUM merge must be exact)"
    emit(f"parallel_ingest/degrees_S8_hub/{E}", t_deg * 1e6,
         f"edges_per_s={E / t_deg:.0f},exact=1")

    doc = {
        "schema": 1,
        "graph": {"kind": "block_rmat", "block_scale": bs, "n_blocks": 32,
                  "edge_factor": 16, "seed": 1, "edges": E,
                  "vertices": int(n)},
        "k": k,
        "chunk_size": cs,
        "cores": cores,
        "gates": {
            "rf_band": RF_BAND,
            "rf_band_holds": bool(ratio <= RF_BAND),
            "speedup_gate": SPEEDUP_GATE,
            "speedup_gate_armed": bool(speedup_armed),
            "speedup_gate_holds": bool(speedup >= SPEEDUP_GATE)
            if speedup_armed else None,
        },
        "s5p": {
            "rf_seq": round(rf_seq, 6),
            "rf_hub_auto": round(rf_hub, 6),
            "rf_vs_seq": round(ratio, 4),
            "speedup": round(speedup, 3),
            "S": GATE_S,
            "cadence_schedule": list(ingest.get("schedule", [])),
            "touch_up": {key: tu[key] for key in
                         ("contested_clusters", "moved_clusters")
                         if key in tu},
        },
        "hdrf_seq_rf": round(rf_hdrf_seq, 6),
        "rows": rows,
    }
    Path(BENCH_JSON).write_text(json.dumps(doc, indent=2, sort_keys=True)
                                + "\n")
    emit("parallel_ingest/json", 0.0, f"wrote={BENCH_JSON},rows={len(rows)}")
    return rows
