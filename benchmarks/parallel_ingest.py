"""Parallel-ingest scaling study: S sharded sub-streams vs one sequential.

The ROADMAP "Distributed streams" regime on a ≥ 1M-edge R-MAT stream:
HDRF ingests through ``run_parallel`` at S ∈ {1, 2, 4, 8} (threads
backend — S host workers sharing the compiled chunk step; see
``repro.streaming.parallel`` for why forced host "devices" cannot help on
CPU), reporting wall-clock speedup over the sequential driver and the
replication-factor cost of S-way carry staleness.  The linear-merge
carries (degree precompute) are swept too — their parallel ingest is
*exact*, so the row doubles as a correctness assert.

Wall-clock speedup is bounded by ``min(S, host cores)``: this container
has 2 cores, so the curve saturates near 2× — on a ≥ 8-core host the
S=8 row is where the ≥ 2× HEP-style claim lands.  Quick mode runs the
~1.1M-edge scale-16 R-MAT; ``--full`` the ~2.2M-edge scale-17.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import replication_factor
from repro.core.baselines import hdrf_partition
from repro.core.clustering import DegreeCarry, compute_degrees
from repro.graphs import rmat_graph
from repro.streaming import EdgeStream, run_parallel

from .common import emit

SWEEP = (1, 2, 4, 8)
SUPER_CHUNK = 8


def run(quick: bool = True):
    scale, ef = (16, 17) if quick else (17, 17)  # ~1.1M / ~2.2M edges
    k = 8
    cs = 1 << 16
    src, dst, n = rmat_graph(scale, edge_factor=ef, seed=0, dedup=False)
    E = len(src)
    stream = EdgeStream(src, dst, n, chunk_size=cs)
    cores = os.cpu_count() or 1

    # warm the chunk-step compile cache so every row times steady state
    hdrf_partition(src[: 2 * cs], dst[: 2 * cs], n, k, chunk_size=cs)

    t0 = time.perf_counter()
    seq = np.asarray(hdrf_partition(None, None, n, k, stream=stream))
    t_seq = time.perf_counter() - t0
    rf_seq = replication_factor(src, dst, seq, n_vertices=n, k=k)
    emit(f"parallel_ingest/hdrf_S1/{E}", t_seq * 1e6,
         f"edges_per_s={E / t_seq:.0f},rf={rf_seq:.4f},speedup=1.00,"
         f"cores={cores}")

    for S in SWEEP[1:]:
        t0 = time.perf_counter()
        parts = np.asarray(hdrf_partition(
            None, None, n, k, stream=stream, num_streams=S,
            super_chunk=SUPER_CHUNK))
        t_par = time.perf_counter() - t0
        valid = src != dst
        assert (parts[valid] >= 0).all() and (parts[valid] < k).all()
        rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
        emit(f"parallel_ingest/hdrf_S{S}/{E}", t_par * 1e6,
             f"edges_per_s={E / t_par:.0f},rf={rf:.4f},"
             f"speedup={t_seq / t_par:.2f},rf_vs_seq={rf / rf_seq:.3f}")

    # linear-merge carry: parallel degree ingest is exact by algebra
    deg_ref = np.asarray(compute_degrees(src, dst, n))
    t0 = time.perf_counter()
    _, deg = run_parallel(stream, DegreeCarry(n), num_streams=8,
                          super_chunk=SUPER_CHUNK, backend="threads")
    t_deg = time.perf_counter() - t0
    assert np.array_equal(np.asarray(deg), deg_ref), \
        "parallel degree ingest diverged (SUM merge must be exact)"
    emit(f"parallel_ingest/degrees_S8/{E}", t_deg * 1e6,
         f"edges_per_s={E / t_deg:.0f},exact=1")
