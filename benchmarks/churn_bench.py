"""Churn study: sliding-window S5P vs cold re-partition of the window.

The decremental-carry question: when a partitioner tracks the *last W
edges* of an R-MAT stream (insert a step batch, expire the oldest,
drift-triggered masked refinement in between), how much replication-
factor quality does the continuously-maintained partition give up against
re-running S5P cold on exactly the live window — and what does a churn
step cost relative to that cold run?

Churn rate = step/W: each event replaces that fraction of the window.
Higher rates stress the approximate parts of the retraction (cluster
volumes subtract at current clusters; ξ/κ stay frozen) harder per step.

Rows: ``churn/s5p/w<W>/r<rate>`` with derived
``rf_warm=<mean-over-steady-steps> rf_cold=<cold-on-final-window>
ratio=<warm/cold> refined=<n> rolled=<n> compacted=<n> cold_restart=<n>``
plus a per-step wall-clock column.  The quality acceptance band
(ratio ≤ 1.10) is pinned by the slow-lane ``test_sliding_window_quality``
in tests/test_window.py; timings on this container are load-noisy.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import S5PConfig, replication_factor, s5p_partition
from repro.graphs import rmat_graph
from repro.incremental import s5p_sliding_window

from . import common


def _bench_rate(src, dst, n, k, W, step, cfg):
    t0 = time.perf_counter()
    hist, bundle = s5p_sliding_window(src, dst, n, cfg, W, step_edges=step)
    t_warm = time.perf_counter() - t0
    # steady state: windows at full width (skip the fill-up prefix)
    steady = [h for h in hist
              if h.hi - h.lo == W and not h.filling] or hist[-1:]
    rf_warm = float(np.mean([h.rf for h in steady]))
    last = hist[-1]
    ws, wd = src[last.lo:last.hi], dst[last.lo:last.hi]
    t0 = time.perf_counter()
    cold = s5p_partition(ws, wd, n, cfg)
    t_cold = time.perf_counter() - t0
    rf_cold = float(replication_factor(ws, wd, cold.parts,
                                       n_vertices=n, k=k))
    rf_final = float(last.rf)
    common.emit(
        f"churn/s5p/w{W}/r{step / W:.2f}",
        t_warm / max(len(hist), 1) * 1e6,  # µs per churn step
        f"rf_warm={rf_warm:.3f} rf_final={rf_final:.3f} "
        f"rf_cold={rf_cold:.3f} ratio={rf_warm / max(rf_cold, 1e-9):.3f} "
        f"ratio_final={rf_final / max(rf_cold, 1e-9):.3f} "
        f"steps={len(hist)} refined={sum(h.refined for h in hist)} "
        f"rolled={sum(h.rolled_back for h in hist)} "
        f"compacted={sum(h.n_compacted for h in hist)} "
        f"cold_restart={sum(h.needs_cold_restart for h in hist)} "
        f"t_cold={t_cold:.1f}s",
    )


def run(quick: bool = True) -> None:
    scale = 12 if quick else 15
    k = 8
    src, dst, n = rmat_graph(scale, edge_factor=8, seed=11)
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    E = len(src)
    W = E // 2
    cfg = S5PConfig(k=k, chunk_size=1 << 16, drift_rf_threshold=0.02,
                    drift_churn_threshold=0.20, refine_rounds=16)
    common.emit(f"churn/graph/rmat{scale}", 0.0, f"E={E} V={n} W={W}")
    rates = (0.125, 0.25) if quick else (0.0625, 0.125, 0.25, 0.5)
    for rate in rates:
        _bench_rate(src, dst, n, k, W, max(int(W * rate), 1), cfg)


if __name__ == "__main__":
    run(quick=True)
