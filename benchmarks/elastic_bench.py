"""Elastic resize study: warm k→k′ reshard vs cold re-partition.

Production clusters resize (ROADMAP "Elastic re-partitioning"); the
question is what a warm :func:`repro.elastic.reshard_bundle` costs in
quality and buys in migration against the obvious alternative — throw
the bundle away and re-partition cold at k′ (O(|E|) replay **and** 100 %
edge migration).  For grow (32→48) and shrink (32→16) this bench
reports, per graph:

- ``rf_ratio`` — warm-reshard RF over cold-k′ RF (gate: ≤ 1.10×);
- ``migrated`` — fraction of live edges whose partition changed
  (gate: < 100 %, i.e. strictly better than the cold restart; in
  practice grow migrates only what the game relocates onto the new
  partitions, shrink the displaced remainder plus game moves);
- wall time of the reshard vs the cold run.

The second half is the ingest-path recovery drill: a parallel-ingest
lane is killed mid-super-chunk (``LaneFaultInjector``) and the drive
recovers through ``run_parallel(on_lane_failure="replay")`` from
:class:`~repro.incremental.store.CarryStore` checkpoints — the gate
asserts the recovered final parts are **bit-identical** to the unkilled
drive.
"""

from __future__ import annotations

import tempfile

import numpy as np

from repro.core.metrics import replication_factor
from repro.core.s5p import S5PConfig
from repro.elastic import reshard_bundle
from repro.incremental import s5p_cold_bundle
from repro.incremental.store import CarryStore
from repro.kernels.stream_scan import GreedyCarry
from repro.runtime import LaneFaultInjector
from repro.streaming import EdgeStream, run_parallel

from .common import emit, get_graph, timed

K_BASE = 32
RF_RATIO_GATE = 1.10


def _resize_study(name, src, dst, n, k_new):
    cfg = S5PConfig(k=K_BASE, seed=0, chunk_size=1 << 14)
    (_, bundle), warm_build_us = timed(s5p_cold_bundle, src, dst, n, cfg)
    (out), reshard_us = timed(reshard_bundle, bundle, cfg, k_new, src, dst)
    _, _, res = out

    cfg_cold = S5PConfig(k=k_new, seed=0, chunk_size=1 << 14)
    (cold_out, _), cold_us = timed(s5p_cold_bundle, src, dst, n, cfg_cold)
    rf_cold = float(replication_factor(src, dst,
                                       np.asarray(cold_out.parts, np.int32),
                                       n_vertices=n, k=k_new))
    rf_ratio = res.rf / max(rf_cold, 1e-9)
    emit(f"elastic/reshard/{name}/k{K_BASE}->{k_new}", reshard_us,
         f"rf={res.rf:.3f};rf_cold={rf_cold:.3f};"
         f"rf_ratio={rf_ratio:.3f};migrated={res.migrated_fraction:.3f};"
         f"displaced={res.n_displaced};moved_clusters={res.moved_clusters};"
         f"balance={res.balance:.3f};cold_us={cold_us:.0f}")
    assert rf_ratio <= RF_RATIO_GATE, \
        f"{name} k{K_BASE}->{k_new}: warm reshard RF {res.rf:.3f} is " \
        f"{rf_ratio:.3f}x cold (gate {RF_RATIO_GATE}x)"
    assert res.migrated_fraction < 1.0, \
        f"{name} k{K_BASE}->{k_new}: migrated everything — no better " \
        f"than the cold restart"
    return rf_ratio


def _kill_a_lane_drill(src, dst, n, k=8):
    def drive(**kw):
        st = EdgeStream(src, dst, n, chunk_size=1 << 10)
        parts, _ = run_parallel(st, GreedyCarry(n, k), num_streams=4,
                                super_chunk=2, backend="threads", **kw)
        return np.asarray(parts)

    p_clean, clean_us = timed(drive)
    # kill points must lie on their lanes: range sharding deals chunk c
    # to lane c // ceil(C/4)
    C = -(-src.size // (1 << 10))
    q = -(-C // 4)
    kills = [(1, q + 1), (2, 2 * q + 1)]
    with tempfile.TemporaryDirectory() as d:
        inj = LaneFaultInjector(fail_at=kills)
        assert all(c // q == lane for lane, c in kills)  # on their lanes
        p_killed, killed_us = timed(
            drive, on_lane_failure="replay", lane_injector=inj,
            carry_store=CarryStore(d))
        # both lanes die in the same super-chunk, so fire order races
        assert sorted(inj.fired) == sorted(kills), "kills never fired"
    identical = bool(np.array_equal(p_clean, p_killed))
    emit("elastic/kill_a_lane_recovery", killed_us,
         f"bit_identical={identical};kills=2;clean_us={clean_us:.0f};"
         f"overhead={killed_us / max(clean_us, 1):.2f}x")
    assert identical, "replayed drive diverged from the unkilled one"


def run(quick: bool = True):
    graphs = ["social-like"] if quick else ["web-like", "social-like",
                                            "powerlaw"]
    for name in graphs:
        src, dst, n = get_graph(name)
        for k_new in (48, 16):  # grow and shrink from the same bundle
            _resize_study(name, src, dst, n, k_new)
    src, dst, n = get_graph("social-like")
    _kill_a_lane_drill(src, dst, n)
