"""Fig. 13 analogue: RF vs graph skewness (R-MAT sweep G₁→G₃ style).

Paper claim: baselines degrade faster than S5P as skew increases."""

from __future__ import annotations

from repro.core import S5PConfig, replication_factor, s5p_partition
from repro.core.baselines import PARTITIONERS
from repro.graphs import graph_skewness, rmat_graph

from .common import emit, timed


def run(quick: bool = True):
    k = 8
    factors = (4, 8, 16) if quick else (4, 8, 16, 32)
    deltas = {}
    for m in ("hdrf", "2ps-l", "s5p"):
        rfs = []
        for ef in factors:
            src, dst, n = rmat_graph(11, edge_factor=ef, seed=ef)
            rho, r1, r2, _ = graph_skewness(src, dst, n)
            parts = (s5p_partition(src, dst, n, S5PConfig(k=k)).parts
                     if m == "s5p" else PARTITIONERS[m](src, dst, n, k))
            rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
            rfs.append(rf)
            emit(f"fig13/ef{ef}/{m}", 0.0,
                 f"RF={rf:.3f};rho={rho:.2f};pearson1={r1:.3f}")
        deltas[m] = rfs[-1] - rfs[0]
    emit("fig13/summary", 0.0,
         ";".join(f"{m}_rf_growth={d:+.3f}" for m, d in deltas.items()))
