"""Shared benchmark plumbing: timers, CSV rows, graph suite.

Scale note: the paper's graphs are up to 3.7B edges; this container is one
CPU core, so the suite reproduces every *comparison* at proportionally
reduced sizes (10³–10⁵ edges) with the same generators/skews.  Rows print
as ``name,us_per_call,derived`` per the harness contract.
"""

from __future__ import annotations

import time
from typing import Callable

from repro.graphs import powerlaw_graph, rmat_graph
from repro.graphs.generators import community_graph

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


GRAPHS = {
    # name: (generator, kwargs, paper analogue)
    "web-like": (community_graph,
                 dict(n_vertices=4000, n_communities=64, avg_degree=8,
                      p_intra=0.95, rho=2.0, seed=0), "IT/UK-style web graph"),
    "social-like": (community_graph,
                    dict(n_vertices=3000, n_communities=24, avg_degree=10,
                         p_intra=0.8, rho=2.2, seed=1), "OK/LJ-style social"),
    "powerlaw": (powerlaw_graph,
                 dict(n_vertices=3000, avg_degree=8, rho=2.2, seed=2),
                 "configuration-model control"),
}


def get_graph(name: str):
    gen, kw, _ = GRAPHS[name]
    return gen(**kw)
