"""Roofline table from the dry-run artifacts (results/dryrun.json).

One row per (arch × shape × mesh): the three terms in ms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.
"""

from __future__ import annotations

import json
from pathlib import Path

from .common import emit

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun.json"


def run(quick: bool = True):
    if not RESULTS.exists():
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return
    rows = json.loads(RESULTS.read_text())
    for r in rows:
        if not r.get("ok"):
            continue
        if "skipped" in r:
            emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                 "SKIP:" + r["skipped"][:40])
            continue
        dom = max(("t_compute", "t_memory", "t_collective"), key=lambda k: r[k])
        emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r[dom] * 1e6,  # the dominant term is the modeled step time
            f"compute_ms={r['t_compute'] * 1e3:.1f};"
            f"memory_ms={r['t_memory'] * 1e3:.1f};"
            f"collective_ms={r['t_collective'] * 1e3:.1f};"
            f"bottleneck={r['bottleneck']};"
            f"useful_flops={r['useful_flops_ratio']:.2f};"
            f"roofline_frac={r['roofline_fraction']:.3f};"
            f"GiB_per_dev={r['bytes_per_device'] / 2**30:.2f}",
        )
