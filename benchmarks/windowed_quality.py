"""Windowed-ordering quality study (ROADMAP item; Patwary et al. 2019).

The ``windowed`` EdgeStream ordering buys dst-locality with a bounded
buffer of ``window`` edges.  This sweep measures what that locality is
worth in partition quality: replication factor of HDRF and Greedy under
window ∈ {256, 4096, 65536}, bracketed by ``natural`` (window → 1) and
``dst-sorted`` (window → ∞), on the community and R-MAT graphs.  The
resulting table lives in ``benchmarks/README.md``.
"""

from __future__ import annotations

import numpy as np

from repro.core import replication_factor
from repro.core.baselines import greedy_partition, hdrf_partition
from repro.graphs import rmat_graph
from repro.graphs.generators import community_graph
from repro.streaming import EdgeStream

from .common import emit, timed

WINDOWS = (256, 4096, 65536)


def graphs(quick: bool):
    nv = 4000 if quick else 12000
    yield "community", community_graph(nv, n_communities=64, avg_degree=8,
                                       p_intra=0.95, seed=0)
    yield "rmat", rmat_graph(13 if quick else 15, edge_factor=8, seed=0,
                             dedup=False)


def sweep(src, dst, n, k=8):
    """(ordering label, stream) pairs from no reorder to full dst sort."""
    yield "natural", EdgeStream(src, dst, n)
    for w in WINDOWS:
        yield f"w{w}", EdgeStream(src, dst, n, ordering="windowed", window=w)
    yield "dst-sorted", EdgeStream(src, dst, n, ordering="dst-sorted")


def run(quick: bool = True):
    k = 8
    for gname, (src, dst, n) in graphs(quick):
        E = len(src)
        for oname, stream in sweep(src, dst, n, k):
            for pname, fn in (("hdrf", hdrf_partition),
                              ("greedy", greedy_partition)):
                parts, us = timed(
                    lambda: np.asarray(fn(src, dst, n, k, stream=stream)))
                rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
                emit(f"windowed_quality/{gname}-{E}/{oname}/{pname}", us,
                     f"rf={rf:.4f}")
