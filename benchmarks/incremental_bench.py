"""Incremental re-partitioning: warm-start replay vs cold full re-run.

The Le Merrer & Trédan question, asked of this repo's subsystem: for a
growing R-MAT stream, how much of a full re-partition's wall-clock does a
warm-start replay of only the delta save, and how much replication-factor
quality does it give up?  Deltas of 1 % / 5 % / 10 % of the stream are
split off the tail; the warm path restores the prefix carry from a
CarryStore, replays the delta, and (for S5P) runs the drift-triggered
masked-game refinement (threshold 0 ⇒ always refine — the quality-anchor
regime).

Rows: ``incremental/<name>/d<pct>`` with derived
``speedup=<cold/warm> rf_warm rf_cold replay=<fraction of cold's folds>``.
Timings on this container are load-noisy (see benchmarks/README.md);
the speedup column is the comparison, the replay column is the invariant.
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core import S5PConfig, replication_factor, s5p_partition
from repro.core.baselines import hdrf_partition
from repro.graphs import rmat_graph
from repro.incremental import cold_start, run_incremental

from . import common


def _cold_run(name, src, dst, n, k, cfg):
    t0 = time.perf_counter()
    if name == "s5p":
        cold_parts = s5p_partition(src, dst, n, cfg).parts
    else:
        cold_parts = hdrf_partition(src, dst, n, k,
                                    chunk_size=cfg.chunk_size)
    t_cold = time.perf_counter() - t0
    rf_cold = replication_factor(src, dst, cold_parts, n_vertices=n, k=k)
    return t_cold, rf_cold


def _bench_one(name, src, dst, n, k, frac, cfg, t_cold, rf_cold):
    E = len(src)
    E0 = int(E * (1.0 - frac))

    with tempfile.TemporaryDirectory() as store:
        cold_start(store, name, src[:E0], dst[:E0], n, k,
                   chunk_size=cfg.chunk_size, s5p_config=cfg)
        t0 = time.perf_counter()
        res = run_incremental(store, name, src, dst, n, k,
                              chunk_size=cfg.chunk_size, s5p_config=cfg,
                              save=False)
        t_warm = time.perf_counter() - t0
    common.emit(
        f"incremental/{name}/d{int(frac * 100)}",
        t_warm * 1e6,
        f"speedup={t_cold / max(t_warm, 1e-9):.1f}x "
        f"rf_warm={res.rf:.3f} rf_cold={rf_cold:.3f} "
        f"replay={res.replay_fraction:.1%} refined={res.refined}",
    )


def run(quick: bool = True) -> None:
    scale = 13 if quick else 17  # full: ~1M-edge R-MAT (paper-style skew)
    k = 8
    src, dst, n = rmat_graph(scale, edge_factor=8, seed=7)
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    cfg = S5PConfig(k=k, chunk_size=1 << 16, drift_rf_threshold=0.0,
                    refine_rounds=16)
    common.emit(f"incremental/graph/rmat{scale}", 0.0,
                f"E={len(src)} V={n}")
    for name in ("hdrf", "s5p"):
        # one cold full re-run per partitioner — the shared baseline every
        # delta fraction is compared against (it also warms the jit caches
        # the warm path reuses, so the speedup column is not compile skew)
        t_cold, rf_cold = _cold_run(name, src, dst, n, k, cfg)
        for frac in ((0.10,) if quick else (0.01, 0.05, 0.10)):
            _bench_one(name, src, dst, n, k, frac, cfg, t_cold, rf_cold)


if __name__ == "__main__":
    run(quick=True)
