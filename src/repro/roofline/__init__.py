from .analysis import analyze_compiled, parse_hlo_costs, HW  # noqa: F401
