"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory     = HLO_bytes / HBM_bw               (per chip)
    collective = collective_bytes / link_bw       (per chip)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI
per link (constants from the brief).

Why not just ``compiled.cost_analysis()``: XLA reports a ``while`` body
**once**, so a scanned 48-layer transformer under-counts ~48×.  This
module parses the optimized (scheduled) HLO text into its computation
graph and accumulates flops / HBM bytes / collective bytes
**hierarchically**, multiplying each while body by its trip count
(recovered from the loop condition's comparison constant).

Scheduled HLO prints operand *names* without inline types, so a module-
wide symbol table (instruction name → shape) is built first.

Accounting rules:
- flops: ``dot`` instructions — 2 × |out| × contracted size (including
  dots inside fusions);
- HBM bytes: operand + result sizes of top-level instructions in
  non-fused computations (the fusion boundary is XLA's HBM-traffic unit);
- collective bytes: operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (+ async ``-start``).
All sizes are post-SPMD per-device (the HLO module is the per-device
program), matching the brief's per-chip denominators.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

__all__ = ["HW", "V5E", "parse_hlo_costs", "analyze_compiled"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link


V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_NAME_RE = re.compile(r"%([\w.\-]+)")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call",
}


def _type_bytes(type_text: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    return sum(
        _shape_prod(dims) * _DTYPE_BYTES.get(dt, 0)
        for dt, dims in _SHAPE_RE.findall(type_text)
    )


def _shape_prod(dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _balanced_parens(s: str, start: int) -> tuple[str, int]:
    """s[start] == '(' → (contents, index past the closing paren)."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return s[start + 1:i], i + 1
    return s[start + 1:], len(s)


def _parse_instr(line: str):
    """'  [ROOT] %name = TYPE opcode(operands), attrs' → parts or None.

    TYPE may be a parenthesized tuple containing spaces (while/tuple ops).
    """
    eq = line.find(" = ")
    if eq < 0 or not line.startswith(" "):
        return None
    name = line[:eq].strip()
    if name.startswith("ROOT"):
        name = name[4:].strip()
    name = name.lstrip("%")
    rest = line[eq + 3:]
    if not rest:
        return None
    if rest[0] == "(":  # tuple type
        type_text, pos = _balanced_parens(rest, 0)
        type_text = "(" + type_text + ")"
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_text, pos = rest[:sp], sp
    mo = _OP_RE.match(rest, pos)
    if not mo:
        return None
    op = mo.group(1)
    operands, after = _balanced_parens(rest, mo.end() - 1)
    attrs = rest[after:]
    return name, type_text, op, operands, attrs


def parse_hlo_costs(hlo: str, attn_block: tuple[int, int] | None = None
                    ) -> dict[str, float]:
    """Loop-aware flops / HBM bytes / collective bytes from optimized HLO.

    ``attn_block=(q_chunk, kv_chunk)``: additionally report
    ``hbm_bytes_vmem_adj`` — the memory term with attention score-block
    buffers excluded (any instruction whose result's trailing dims are the
    (q, kv) block).  XLA-CPU materializes those tiles through HBM-visible
    fusions; the Pallas TPU flash kernel (kernels/flash_attention) keeps
    them in VMEM, so the adjusted number is the TPU-faithful model
    (both are reported; EXPERIMENTS.md §Roofline states which is which).
    """
    # ---- pass 0: split computations & build the symbol table ----
    comps: dict[str, list[str]] = {}
    entry: str | None = None
    cur: str | None = None
    symbols: dict[str, str] = {}  # instr name → result type text
    for line in hlo.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        comps[cur].append(line)
        im = _parse_instr(line)
        if im:
            symbols[im[0]] = im[1]

    if entry is None or not comps:
        return {"flops": 0.0, "hbm_bytes": 0.0, "hbm_bytes_vmem_adj": 0.0,
                "collective_bytes": 0.0, "max_trip": 1.0, "n_collectives": 0}

    # ---- which computations are fusion/reducer bodies (bytes internal) ----
    # a `call` target is a real computation (XLA-CPU wraps parallel loop
    # fusions in one) — its top-level instructions do hit HBM, so only
    # fusion/reducer referencers mark their callee as byte-internal
    fused: set[str] = set()
    for lines in comps.values():
        for ln in lines:
            im = _parse_instr(ln)
            if im and im[2] == "call":
                continue
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", ln):
                fused.add(m.group(1))

    # Per fused computation: traffic multiplier per parameter index.  A
    # "wide" loop-invariant parameter that is only *sliced/gathered* inside
    # the fusion contributes its slice sizes, not its full size — otherwise
    # stacked scan weights would be charged L× per step.
    fusion_param_traffic: dict[str, dict[int, float]] = {}
    for fname in fused:
        lines = comps.get(fname, ())
        pname_to_idx: dict[str, int] = {}
        for ln in lines:
            im = _parse_instr(ln)
            if im and im[2] == "parameter":
                mi = re.match(r"\s*(\d+)", im[3]) or re.search(r"parameter\((\d+)\)", ln)
                idx = int(mi.group(1)) if mi else len(pname_to_idx)
                pname_to_idx[im[0]] = idx
        traffic: dict[int, float] = {}
        consumers: dict[str, list[tuple[str, str]]] = {p: [] for p in pname_to_idx}
        for ln in lines:
            im = _parse_instr(ln)
            if not im:
                continue
            nm, rt, op, operands, _ = im
            for p in _NAME_RE.findall(operands):
                if p in consumers:
                    consumers[p].append((op, rt))
        for p, idx in pname_to_idx.items():
            uses = consumers.get(p, [])
            full = _type_bytes(symbols.get(p, ""))
            if uses and all(op in ("dynamic-slice", "slice", "gather")
                            for op, _ in uses):
                traffic[idx] = sum(_type_bytes(rt) for _, rt in uses)
            else:
                traffic[idx] = full
        fusion_param_traffic[fname] = traffic

    def trip_count(cond_name: str) -> float:
        best = 1.0
        for ln in comps.get(cond_name, ()):
            for m in re.finditer(r"constant\((\d+)\)", ln):
                best = max(best, float(m.group(1)))
        return best

    # ---- per-computation local costs + call edges ----
    def _is_attn_block(type_text: str) -> bool:
        """Flash-attention VMEM-resident tiles: score blocks (…, qc, kvc)
        and the online-softmax accumulator / p·v blocks (…, qc, hd).  The
        Pallas kernel holds both in VMEM scratch; XLA-CPU routes them
        through HBM-visible buffers."""
        if attn_block is None:
            return False
        qc, kc, hd = attn_block
        shapes = _SHAPE_RE.findall(type_text)
        if not shapes:
            return False
        dims = [int(d) for d in shapes[0][1].split(",") if d]
        return (len(dims) >= 4 and dims[-2] in (qc, kc)
                and dims[-1] in (qc, kc, hd))

    local: dict[str, dict[str, float]] = {}
    calls: dict[str, list[tuple[str, float]]] = {}
    n_coll = 0
    for name, lines in comps.items():
        flops = hbm = coll = 0.0
        hbm_adj = 0.0
        edges: list[tuple[str, float]] = []
        count_bytes = name not in fused
        for ln in lines:
            im = _parse_instr(ln)
            if not im:
                continue
            _, result_t, op, operands, attrs = im
            operand_names = _NAME_RE.findall(operands)
            if op == "fusion":
                mf0 = re.search(r"calls=%?([\w.\-]+)", attrs)
                tmap = fusion_param_traffic.get(mf0.group(1), {}) if mf0 else {}
                operand_bytes = sum(
                    tmap.get(i, _type_bytes(symbols.get(nm, "")))
                    for i, nm in enumerate(operand_names)
                )
            elif op in ("dynamic-slice", "slice"):
                operand_bytes = 0.0  # traffic ≈ result
            elif op == "dynamic-update-slice":
                # in-place: traffic ≈ update operand (+ indices, negligible)
                operand_bytes = (
                    _type_bytes(symbols.get(operand_names[1], ""))
                    if len(operand_names) > 1 else 0.0
                )
            elif op == "gather":
                operand_bytes = sum(  # rows touched ≈ result; indices read
                    _type_bytes(symbols.get(nm, "")) for nm in operand_names[1:]
                )
            elif op == "scatter":
                operand_bytes = 2.0 * sum(
                    _type_bytes(symbols.get(nm, "")) for nm in operand_names[1:]
                )
            else:
                operand_bytes = sum(
                    _type_bytes(symbols.get(nm, "")) for nm in operand_names
                )
            if op == "dot":
                out = 0.0
                mm = _SHAPE_RE.findall(result_t)
                if mm:
                    out = _shape_prod(mm[0][1])
                contracted = 1.0
                mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", attrs)
                if mc and operand_names:
                    lhs_t = symbols.get(operand_names[0], "")
                    lm = _SHAPE_RE.findall(lhs_t)
                    if lm:
                        lhs_dims = [int(d) for d in lm[0][1].split(",") if d]
                        for i in mc.group(1).split(","):
                            if i and int(i) < len(lhs_dims):
                                contracted *= lhs_dims[int(i)]
                flops += 2.0 * out * contracted
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", attrs)
                mc2 = re.search(r"condition=%?([\w.\-]+)", attrs)
                if mb:
                    t = trip_count(mc2.group(1)) if mc2 else 1.0
                    edges.append((mb.group(1), t))
            elif op == "fusion":
                mf = re.search(r"calls=%?([\w.\-]+)", attrs)
                if mf:
                    edges.append((mf.group(1), 1.0))
            elif op == "call":
                mf = re.search(r"to_apply=%?([\w.\-]+)", attrs)
                if mf:
                    edges.append((mf.group(1), 1.0))
            elif op == "conditional":
                for mf in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w.\-]+))",
                                      attrs):
                    blob = mf.group(1) or mf.group(2) or ""
                    for nm in _NAME_RE.findall(blob) or re.findall(r"[\w.\-]+", blob):
                        edges.append((nm, 1.0))
            if any(op.startswith(c) for c in _COLLECTIVES):
                if not op.endswith("-done"):
                    coll += operand_bytes
                    n_coll += 1
            if (count_bytes and op not in _SKIP_BYTES_OPS
                    and not op.endswith("-done") and not op.endswith("-start")):
                result_bytes = (
                    0.0 if op in ("dynamic-update-slice", "scatter")
                    else _type_bytes(result_t)
                )
                hbm += result_bytes + operand_bytes
                # VMEM-adjusted: drop attention score-block buffers
                attn_bytes = sum(
                    _type_bytes(symbols.get(nm, "")) for nm in operand_names
                    if _is_attn_block(symbols.get(nm, ""))
                )
                adj_result = 0.0 if _is_attn_block(result_t) else result_bytes
                hbm_adj += adj_result + max(operand_bytes - attn_bytes, 0.0)
        local[name] = {"flops": flops, "hbm": hbm, "coll": coll,
                       "hbm_adj": hbm_adj}
        calls[name] = edges

    memo: dict[str, dict[str, float]] = {}

    def total(name: str, depth: int = 0) -> dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in local or depth > 128:
            return {"flops": 0.0, "hbm": 0.0, "coll": 0.0, "hbm_adj": 0.0}
        memo[name] = {"flops": 0.0, "hbm": 0.0, "coll": 0.0, "hbm_adj": 0.0}
        acc = dict(local[name])
        for callee, mult in calls.get(name, ()):
            sub = total(callee, depth + 1)
            acc["flops"] += mult * sub["flops"]
            acc["hbm"] += mult * sub["hbm"]
            acc["coll"] += mult * sub["coll"]
            acc["hbm_adj"] += mult * sub["hbm_adj"]
        memo[name] = acc
        return acc

    t = total(entry)
    max_trip = max(
        [m for edges in calls.values() for _, m in edges] + [1.0]
    )
    return {
        "flops": t["flops"],
        "hbm_bytes": t["hbm"],
        "hbm_bytes_vmem_adj": t["hbm_adj"],
        "collective_bytes": t["coll"],
        "max_trip": max_trip,
        "n_collectives": n_coll,
    }


def analyze_compiled(cell, lowered, compiled, mesh, hw: HW = V5E) -> dict[str, Any]:
    """Roofline terms for one dry-run cell (per-chip convention)."""
    n_chips = mesh.devices.size
    hlo = compiled.as_text()
    attn_block = getattr(cell, "attn_block", None)
    costs = parse_hlo_costs(hlo, attn_block=attn_block)
    ca = compiled.cost_analysis() or {}
    raw_flops = float(ca.get("flops", 0.0))
    flops_dev = costs["flops"]
    hbm_dev = costs["hbm_bytes"]
    coll_dev = costs["collective_bytes"]
    t_compute = flops_dev / hw.peak_flops
    t_memory = hbm_dev / hw.hbm_bw
    t_collective = coll_dev / hw.ici_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    bottleneck = max(terms, key=terms.get)
    model_flops = float(cell.model_flops)
    hlo_total = flops_dev * n_chips
    return {
        "n_chips": int(n_chips),
        "hlo_flops_per_device": flops_dev,
        "hlo_flops_raw_costanalysis": raw_flops,
        "hbm_bytes_per_device": hbm_dev,
        "collective_bytes_per_device": coll_dev,
        "n_collectives": costs["n_collectives"],
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_memory_vmem_adj": costs.get("hbm_bytes_vmem_adj", costs["hbm_bytes"]) / hw.hbm_bw,
        "t_collective": t_collective,
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / hlo_total if hlo_total else 0.0,
        "roofline_fraction": t_compute / max(max(terms.values()), 1e-30),
        "max_while_trip": costs["max_trip"],
    }
