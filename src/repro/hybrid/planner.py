"""Budget planner: size the resident skew core from a CMS degree sketch.

The hybrid regime needs one number before any edge is retained: the core
degree threshold ξ* such that the resident core state — spilled core edge
records, the counted replica / cluster tables they drag along, and the
refinement fold state — fits a caller-supplied byte budget.  Computing ξ*
exactly would need the full degree distribution, which for an out-of-core
graph is itself a |V|-sized array we may not want to keep; instead the
planner sizes the core **online** from a count-min sketch of vertex
degrees (one streamed pass, the same mergeable-CMS machinery as the Θ
statistics pass) plus a deterministic stride-sample of edges.

Two properties the driver's acceptance gates lean on:

- **one-sided safety** — CMS point queries over-estimate degrees, so the
  sampled per-edge min-degree over-estimates too, so the predicted core
  size at any threshold is an over-estimate: a plan that fits the budget
  on paper tends to fit in practice (and the driver's hard-capped
  :class:`~repro.streaming.HostBudget` catches the residual sampling
  error by bumping ξ* one ladder level up);
- **budget-independent ladder** — candidate thresholds are quantiles of
  the sampled min-degree at *fixed* core fractions, so a larger budget's
  refinement ladder extends a smaller budget's ladder rather than
  replacing it.  Every pass of the ladder is computed identically at
  every budget that reaches it, which makes the quality/memory frontier
  monotone by construction (see ``driver.run_hybrid``).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..core.cms import (CMSketch, cms_query, make_sketch, pair_key,
                        suggest_params, vertex_key)
from ..streaming import REPLICATED, SUM, PartitionerCarry, as_stream, run_parallel

__all__ = [
    "BudgetPlan",
    "CORE_EDGE_BYTES",
    "CORE_FRACTIONS",
    "DegreeSketchCarry",
    "build_degree_sketch",
    "plan_budget",
]

_INT32_MAX = 2**31 - 1

# One resident core-edge record: src(4) + dst(4) + arrival index(8) +
# cluster tags cu/cv(4+4) + min endpoint degree(4) + head flag(1).
CORE_EDGE_BYTES = 29

# Fixed per-plan overhead charged against the budget besides edge records:
# the k-vector core load + per-cluster move masks + numpy object slack.
PLAN_FIXED_BYTES = 4096

# Candidate core fractions, smallest first.  The threshold ladder is these
# fractions' min-degree quantiles; a budget admits a *prefix-closed* set.
CORE_FRACTIONS = (1 / 64, 1 / 32, 1 / 16, 1 / 8, 1 / 4, 1 / 2, 1.0)


class BudgetPlan(NamedTuple):
    """Resident-core sizing decision for one hybrid run."""

    budget_bytes: int        # requested host budget (0 ⇒ pure streaming)
    mode: str                # "streaming" | "hybrid" | "in_memory"
    xi_star: int             # core threshold: resident iff min end-degree > ξ*
    ladder: tuple[int, ...]  # descending refine thresholds, last == ξ*
    est_core_edges: int      # sketch-estimated resident edge count at ξ*
    est_core_bytes: int      # … and its byte cost (records + fixed overhead)
    total_edges: int
    sample_edges: int        # stride-sample size the quantiles came from
    sketch_bytes: int        # planner's own CMS footprint (not budgeted)

    @property
    def resident(self) -> bool:
        return self.mode != "streaming"


def _vertex_key(v) -> jnp.ndarray:
    """uint32 sketch key for a single vertex id (see ``cms.vertex_key``)."""
    return vertex_key(v)


class DegreeSketchCarry(PartitionerCarry):
    """Vertex-degree pass as a carry: a CMS over per-vertex keys.

    Each valid edge increments both endpoints' cells, so a point query
    over-estimates deg(v) one-sidedly — exactly the conservative direction
    the budget planner wants.  The sketch is linear (table SUM, seeds
    replicated), so sharded parallel ingest merges exactly, like the Θ
    pass's :class:`~repro.core.cms.SketchCarry`.
    """

    emits_parts = False
    supports_retract = True
    retract_exact = True
    merge_ops = (SUM, REPLICATED)

    def __init__(self, width: int, depth: int, seed: int = 0):
        self.width = int(width)
        self.depth = int(depth)
        self.seed = int(seed)

    def init(self) -> CMSketch:
        return make_sketch(self.width, self.depth, seed=self.seed)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        from ..core.cms import cms_update

        counts = ((jnp.arange(src.shape[0]) < n_valid) & (src != dst))
        counts = counts.astype(jnp.uint32)
        carry = cms_update(carry, _vertex_key(src), counts)
        carry = cms_update(carry, _vertex_key(dst), counts)
        return carry, None

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        from ..core.cms import cms_retract

        counts = ((jnp.arange(src.shape[0]) < n_valid) & (src != dst))
        counts = counts.astype(jnp.int32)
        carry = cms_retract(carry, _vertex_key(src), counts)
        carry = cms_retract(carry, _vertex_key(dst), counts)
        return carry


def build_degree_sketch(
    src,
    dst,
    n_vertices: int,
    *,
    epsilon: float = 0.1,
    nu: float = 0.01,
    seed: int = 0,
    stream=None,
    chunk_size: int = 1 << 16,
    num_streams: int = 1,
    super_chunk: int = 8,
) -> CMSketch:
    """One streamed pass building the planner's degree sketch.

    Width scales with √|V| on top of the paper's w = ⌈e/ε⌉ so collision
    error stays sub-linear in the vertex count (the same scaling the Θ
    pass applies over √C).
    """
    w, d = suggest_params(epsilon, nu)
    width = w * max(1, int(math.sqrt(max(int(n_vertices), 1))))
    stream = as_stream(src, dst, stream=stream, chunk_size=chunk_size)
    carry = DegreeSketchCarry(width, d, seed=seed)
    _, sketch = run_parallel(
        stream, carry, num_streams=num_streams, super_chunk=super_chunk)
    return sketch


def plan_budget(
    src,
    dst,
    n_vertices: int,
    budget_bytes: int | None,
    *,
    stream=None,
    epsilon: float = 0.1,
    nu: float = 0.01,
    seed: int = 0,
    chunk_size: int = 1 << 16,
    num_streams: int = 1,
    super_chunk: int = 8,
    max_sample: int = 1 << 16,
    safety: float = 0.9,
) -> BudgetPlan:
    """Choose ξ* (and the refinement ladder) for a byte budget.

    ``budget_bytes`` of ``None`` or ≤ 0 degrades to the pure-streaming
    plan (no resident core, empty ladder); a budget covering the whole
    edge list yields the fully in-memory plan (ξ* = 0: every valid edge
    is core).  In between, ξ* is the smallest candidate threshold whose
    sketch-estimated core fits ``budget_bytes × safety``.
    """
    E = int(np.asarray(src).shape[0])
    budget = 0 if budget_bytes is None else max(int(budget_bytes), 0)

    def _plan(mode, xi_star, ladder, est_edges, sample_m, sketch_mem):
        return BudgetPlan(
            budget_bytes=budget, mode=mode, xi_star=int(xi_star),
            ladder=tuple(int(t) for t in ladder),
            est_core_edges=int(est_edges),
            est_core_bytes=int(est_edges) * CORE_EDGE_BYTES + PLAN_FIXED_BYTES,
            total_edges=E, sample_edges=int(sample_m),
            sketch_bytes=int(sketch_mem),
        )

    if budget <= 0 or E == 0:
        return _plan("streaming", _INT32_MAX, (), 0, 0, 0)

    sketch = build_degree_sketch(
        src, dst, n_vertices,
        epsilon=epsilon, nu=nu, seed=seed, stream=stream,
        chunk_size=chunk_size, num_streams=num_streams,
        super_chunk=super_chunk)

    # deterministic stride sample of the edge list (arrival order)
    stride = max(1, E // max(1, int(max_sample)))
    idx = np.arange(0, E, stride, dtype=np.int64)
    s_src = np.asarray(src)[idx]
    s_dst = np.asarray(dst)[idx]
    deg_u = np.asarray(cms_query(sketch, _vertex_key(jnp.asarray(s_src))))
    deg_v = np.asarray(cms_query(sketch, _vertex_key(jnp.asarray(s_dst))))
    emin = np.minimum(deg_u, deg_v).astype(np.int64)
    emin[s_src == s_dst] = 0  # self-loops never join the core
    m = int(emin.size)

    # budget-independent candidate thresholds: min-degree quantiles at the
    # fixed core fractions (descending thresholds as fractions grow)
    emin_desc = np.sort(emin)[::-1]
    thresholds = []
    for f in CORE_FRACTIONS:
        if f >= 1.0:
            thresholds.append(0)  # whole graph: every valid edge is core
            continue
        pos = max(int(math.ceil(f * m)) - 1, 0)
        thresholds.append(int(emin_desc[pos]))

    # estimated resident cost at each threshold (one-sided over-estimate)
    affordable = budget * float(safety)
    chosen = -1
    est_at = []
    for t in thresholds:
        frac = float(np.mean(emin > t)) if t > 0 else 1.0
        est_edges = int(math.ceil(frac * E))
        est_at.append(est_edges)
        if est_edges * CORE_EDGE_BYTES + PLAN_FIXED_BYTES <= affordable:
            chosen = len(est_at) - 1

    # a budget that covers the whole edge list is in-memory outright
    if budget >= E * CORE_EDGE_BYTES + PLAN_FIXED_BYTES:
        chosen = len(thresholds) - 1

    if chosen < 0:
        return _plan("streaming", _INT32_MAX, (), 0, m,
                     sketch.memory_bytes())

    # ladder: thresholds for every admitted fraction, deduped in order —
    # a prefix of any larger budget's ladder by construction
    ladder: list[int] = []
    for t in thresholds[: chosen + 1]:
        if not ladder or t < ladder[-1]:
            ladder.append(t)
    xi_star = ladder[-1]
    mode = "in_memory" if xi_star == 0 else "hybrid"
    return _plan(mode, xi_star, ladder, est_at[chosen], m,
                 sketch.memory_bytes())
