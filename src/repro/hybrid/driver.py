"""``run_hybrid`` — one budget-bounded pass: resident core + streamed tail.

Control flow (all passes replay the same EdgeStream):

1. **baseline** — the unmodified pure-streaming S5P pipeline runs first;
   its parts/c2p/load are the incumbent.  A zero budget returns exactly
   this (bit-identical to :func:`~repro.core.s5p.s5p_partition`).
2. **plan** — :func:`~repro.hybrid.planner.plan_budget` picks ξ* and the
   refinement ladder from a CMS degree sketch (budget-independent
   thresholds, see planner docs).
3. **spill** — core edges (min endpoint degree > ξ*) spill to a resident
   :class:`~repro.hybrid.refiner.CoreBuffer`, every allocation charged
   against a **hard-capped** :class:`~repro.streaming.HostBudget`; a
   :class:`~repro.streaming.BudgetExceededError` (sketch under-estimate)
   retreats ξ* one ladder level up and re-spills.
4. **refine** — for each ladder level ℓ (descending): the masked
   Stackelberg game frees the clusters level-ℓ core edges touch, and the
   candidate map is scored by *composing* the placement — core records
   placed resident first (megakernel Alg. 3), then the tail streamed
   through :class:`~repro.hybrid.refiner.TailAssignCarry` seeded with the
   core's load vector.  A candidate is kept iff its composed RF strictly
   improves the incumbent.
5. **bundle** — the winner packs into a standard warm
   :func:`~repro.incremental.pack_warm_bundle`, so incremental deltas,
   deletions, elastic resharding and the serving loop all consume a
   hybrid run exactly like a cold one.

Monotonicity by construction: ladder levels, their games and their
seeds depend only on the level's position in the budget-independent
ladder — a larger budget evaluates a strict superset of candidates with
an identical prefix, and accept-iff-better can only keep or improve the
incumbent.  Hence RF(budget) is non-increasing and every non-zero rung
is ≤ the pure-streaming RF, deterministically, which is exactly what
``benchmarks/hybrid_bench.py`` gates on.
"""

from __future__ import annotations

import time
from typing import Any, NamedTuple

import jax.numpy as jnp
import numpy as np

from ..core import game as _game
from ..core.metrics import load_balance, replication_factor
from ..core.s5p import S5PConfig, S5POutput, s5p_partition
from ..incremental.pipeline import (
    IncrementalResult,
    pack_warm_bundle,
    s5p_apply_delta,
)
from ..streaming import BudgetExceededError, HostBudget, run_parallel
from ..streaming.engine import as_stream
from .planner import PLAN_FIXED_BYTES, BudgetPlan, plan_budget
from .refiner import CoreBuffer, TailAssignCarry, core_move_mask, place_core, \
    refine_core_game

__all__ = ["HybridResult", "HybridServingChain", "run_hybrid"]


class HybridResult(NamedTuple):
    """What one hybrid run produced (and what pure streaming would have)."""

    parts: np.ndarray          # (E,) int32, arrival order
    k: int
    mode: str                  # plan mode after spill retries
    plan: BudgetPlan
    xi_star: int               # effective core threshold after retries
    rf: float
    balance: float
    rf_streaming: float        # the pure-streaming incumbent's quality
    balance_streaming: float
    accepted_levels: tuple[int, ...]  # ladder levels that improved RF
    game_rounds: int           # masked-game rounds spent refining
    core_edges: int            # resident records actually spilled
    peak_budget_bytes: int     # HostBudget high-water mark (≤ budget)
    budget_bytes: int          # the requested cap
    bundle: dict               # standard warm bundle (pack_warm_bundle)
    timings: dict[str, float]


def _materialize(stream_or_edges):
    """(src, dst, n, stream) from an EdgeStream / OOC stream / triple."""
    s = stream_or_edges
    if isinstance(s, tuple):
        src, dst, n = s
        return np.asarray(src, np.int32), np.asarray(dst, np.int32), int(n), None
    if hasattr(s, "arrival_arrays"):  # ShardedEdgeStream pages from disk
        src, dst = s.arrival_arrays()
    else:
        src, dst = s.src, s.dst
    return (np.asarray(src, np.int32), np.asarray(dst, np.int32),
            int(s.n_vertices), s)


def _spill_core(src, dst, degrees, v2c_h, v2c_t, xi: int, threshold: int,
                budget: HostBudget, chunk_size: int) -> tuple[CoreBuffer, int]:
    """One bounded pass collecting core records, charging as it goes.

    Returns ``(core, charged_bytes)``; on :class:`BudgetExceededError`
    everything charged so far is released before re-raising, so the
    caller can retreat to a stricter threshold with clean accounting.
    """
    E = int(src.shape[0])
    cols: list[CoreBuffer] = []
    charged = 0
    try:
        for start in range(0, E, max(int(chunk_size), 1)):
            sl = slice(start, start + chunk_size)
            s, d = src[sl], dst[sl]
            du, dv = degrees[s], degrees[d]
            dmin = np.minimum(du, dv).astype(np.int32)
            m = (dmin > threshold) & (s != d)
            if not m.any():
                continue
            is_head = (du > xi) & (dv > xi)
            cu = np.where(is_head, v2c_h[s], v2c_t[s]).astype(np.int32)
            cv = np.where(is_head, v2c_h[d], v2c_t[d]).astype(np.int32)
            rec = CoreBuffer(
                src=s[m], dst=d[m],
                arrival=(start + np.nonzero(m)[0]).astype(np.int64),
                cu=cu[m], cv=cv[m], deg_min=dmin[m], head=is_head[m])
            budget.charge(rec.nbytes())
            charged += rec.nbytes()
            cols.append(rec)
    except BudgetExceededError:
        budget.release(charged)
        raise
    if not cols:
        empty = CoreBuffer(*(np.zeros(0, dt) for dt in
                             (np.int32, np.int32, np.int64, np.int32,
                              np.int32, np.int32, bool)))
        return empty, charged
    return CoreBuffer(*(np.concatenate(f) for f in zip(*cols))), charged


def run_hybrid(stream, config: S5PConfig, *,
               host_budget: int | None = None) -> HybridResult:
    """Partition under a host-memory budget: resident skew core + tail.

    ``stream`` is an :class:`~repro.streaming.EdgeStream` (including the
    out-of-core :class:`~repro.streaming.ShardedEdgeStream`) or an
    ``(src, dst, n_vertices)`` triple.  ``host_budget`` (bytes) overrides
    ``config.host_budget``; 0/None degrades to pure streaming, a budget
    covering the whole edge list runs fully in-memory.
    """
    src, dst, n_vertices, es = _materialize(stream)
    budget = host_budget if host_budget is not None else config.host_budget
    budget = 0 if budget is None else max(int(budget), 0)
    k = config.k
    timings: dict[str, float] = {}

    # ---- pass 0: the pure-streaming incumbent (bit-identical to s5p) ----
    base = s5p_partition(src, dst, n_vertices, config, stream=es)
    internals = base.aux.get("incremental")
    if internals is None:
        raise ValueError("hybrid run produced no pipeline state "
                         "(no valid edges)")
    res = internals["compact"]
    degrees_np = np.asarray(internals["degrees"], np.int32)
    v2c_h = np.asarray(res.v2c_h, np.int32)
    v2c_t = np.asarray(res.v2c_t, np.int32)
    C = int(res.n_clusters)

    parts_best = np.asarray(base.parts, np.int32)
    c2p_best = np.asarray(base.cluster_assignment, np.int32)
    load_best = internals["load"]
    rf_streaming = replication_factor(src, dst, parts_best,
                                      n_vertices=n_vertices, k=k)
    bal_streaming = load_balance(parts_best, k=k)
    rf_best, bal_best = rf_streaming, bal_streaming

    # ---- plan: size the resident core for the budget ----
    t0 = time.perf_counter()
    plan = plan_budget(
        src, dst, n_vertices, budget, stream=es,
        epsilon=config.cms_epsilon, nu=config.cms_nu, seed=config.seed,
        chunk_size=config.chunk_size, num_streams=config.num_streams,
        super_chunk=config.super_chunk)
    timings["plan"] = time.perf_counter() - t0

    acct = HostBudget(limit_bytes=budget if budget > 0 else None)

    def _result(mode, xi_star, ladder_used, accepted, rounds, core_edges,
                charged):
        bundle = pack_warm_bundle(
            src, dst, n_vertices, config,
            state=internals["cluster_state"], res=res,
            degrees=internals["degrees"], sizes=internals["sizes"],
            pair_a=internals["pair_a"], pair_b=internals["pair_b"],
            pair_w=internals["pair_w"], c2p=c2p_best, parts=parts_best,
            load=load_best, xi=base.xi, kappa=base.kappa,
            sketch=base.aux.get("sketch"))
        acct.release(charged)  # resident records die with this frame
        return HybridResult(
            parts=parts_best, k=k, mode=mode, plan=plan,
            xi_star=int(xi_star), rf=float(rf_best), balance=float(bal_best),
            rf_streaming=float(rf_streaming),
            balance_streaming=float(bal_streaming),
            accepted_levels=tuple(accepted), game_rounds=int(rounds),
            core_edges=int(core_edges),
            peak_budget_bytes=int(acct.peak_bytes), budget_bytes=budget,
            bundle=bundle, timings=timings)

    if not plan.resident or C == 0:
        return _result("streaming", plan.xi_star, (), (), 0, 0, 0)

    # ---- spill the core, retreating up the ladder on a hard-cap hit ----
    t0 = time.perf_counter()
    ladder = list(plan.ladder)
    core = None
    charged = 0
    acct.charge(PLAN_FIXED_BYTES)
    charged += PLAN_FIXED_BYTES
    while ladder:
        try:
            core, spilled = _spill_core(
                src, dst, degrees_np, v2c_h, v2c_t, base.xi, ladder[-1],
                acct, config.chunk_size)
            charged += spilled
            break
        except BudgetExceededError:
            ladder.pop()  # strictly fewer resident edges next try
            core = None
    timings["spill"] = time.perf_counter() - t0
    if core is None or core.n_edges == 0:
        return _result("streaming", plan.xi_star, (), (), 0, 0, charged)
    xi_star = ladder[-1]
    mode = "in_memory" if xi_star == 0 else "hybrid"

    # ---- refinement ladder: masked game + composed re-scoring ----
    t0 = time.perf_counter()
    comb_is_head = (np.ones(C, bool) if config.one_stage
                    else np.arange(C) < res.n_head)
    inputs = _game.GameInputs(
        sizes=jnp.asarray(internals["sizes"], jnp.float32),
        pair_a=jnp.asarray(internals["pair_a"]),
        pair_b=jnp.asarray(internals["pair_b"]),
        pair_w=jnp.asarray(internals["pair_w"], jnp.float32),
        n_head=res.n_head, k=k)
    accepted: list[int] = []
    rounds = 0
    for i, level in enumerate(ladder):
        sub = core.select(np.asarray(core.deg_min) > level)
        if sub.n_edges == 0:
            continue
        move_mask = core_move_mask(sub, C)
        if not move_mask.any():
            continue
        game = refine_core_game(
            inputs, C, c2p_best,
            leader_mask=comb_is_head, move_mask=move_mask,
            rounds=config.refine_rounds or config.game_max_rounds,
            accept_prob=config.game_accept_prob,
            seed=config.seed + 101 + i,
            batch_size=config.game_batch_size)
        rounds += int(game.rounds)
        c2p_cand = np.asarray(game.assignment, np.int32)
        # composed placement: core resident first, tail streamed after,
        # both against one shared capacity L
        core_parts, core_load = place_core(
            sub, c2p_cand, k, base.max_load, n_vertices,
            chunk_size=config.chunk_size, use_kernel=config.use_kernel,
            vmem_budget=config.vmem_budget)
        tail = TailAssignCarry(
            k, base.max_load, jnp.asarray(c2p_cand),
            degrees=degrees_np, v2c_h=v2c_h, v2c_t=v2c_t,
            xi=base.xi, core_threshold=level,
            use_kernel=config.use_kernel, vmem_budget=config.vmem_budget)
        tail_stream = as_stream(src, dst, stream=es,
                                chunk_size=config.chunk_size)
        tail_parts, tail_load = run_parallel(
            tail_stream, tail, num_streams=config.num_streams,
            super_chunk=config.super_chunk, carry=core_load)
        parts_cand = np.asarray(tail_parts, np.int32).copy()
        parts_cand[sub.arrival] = core_parts
        rf_cand = replication_factor(src, dst, parts_cand,
                                     n_vertices=n_vertices, k=k)
        if rf_cand < rf_best - 1e-12:
            rf_best = rf_cand
            bal_best = load_balance(parts_cand, k=k)
            parts_best, c2p_best, load_best = parts_cand, c2p_cand, tail_load
            accepted.append(int(level))
    timings["refine"] = time.perf_counter() - t0

    return _result(mode, xi_star, tuple(ladder), accepted, rounds,
                   core.n_edges, charged)


class _HybridStep(NamedTuple):
    """The first serving step of a hybrid chain (duck-typed record)."""

    rf: float
    balance: float
    refined: bool = False
    filling: bool = False


class HybridServingChain:
    """Serve a hybrid bundle through the standard ServingController.

    Duck-typed like :class:`~repro.incremental.S5PWindowChain`: the first
    ``step()`` publishes the hybrid partition itself; each later step
    absorbs one queued insertion batch through the ordinary warm-bundle
    delta path — proof by construction that a hybrid run's bundle is a
    first-class citizen of the incremental/serving stack.
    """

    def __init__(self, result: HybridResult, config: S5PConfig, src, dst,
                 n_vertices: int, deltas=()):
        self.bundle: dict | None = dict(result.bundle)
        self.config = config
        self.n_vertices = int(n_vertices)
        self._full_src = np.asarray(src, np.int32)
        self._full_dst = np.asarray(dst, np.int32)
        self._first = _HybridStep(rf=result.rf, balance=result.balance)
        self._emitted = False
        self._deltas = list(deltas)

    @property
    def lo(self) -> int:
        return 0

    @property
    def hi(self) -> int:
        return int(self.bundle["stream_pos"])

    def live_partition(self):
        b = self.bundle
        arrival = np.asarray(b["arrival"], np.int64)
        alive = np.asarray(b["alive"], bool)
        return (self._full_src[arrival[alive]],
                self._full_dst[arrival[alive]],
                np.asarray(b["parts"], np.int32)[alive])

    def step(self) -> "_HybridStep | IncrementalResult | None":
        if not self._emitted:
            self._emitted = True
            return self._first
        if not self._deltas:
            return None
        dsrc, ddst = self._deltas.pop(0)
        pos = int(self.bundle["stream_pos"])
        self._full_src = np.concatenate(
            [self._full_src, np.asarray(dsrc, np.int32)])
        self._full_dst = np.concatenate(
            [self._full_dst, np.asarray(ddst, np.int32)])
        self.n_vertices = max(
            self.n_vertices,
            int(max(self._full_src.max(), self._full_dst.max())) + 1)
        self.bundle, rec = s5p_apply_delta(
            self.bundle, self.config, self._full_src, self._full_dst, pos)
        return rec
