"""In-memory core refinement + the core-aware streamed tail carry.

Two halves of the hybrid composition live here:

- :func:`place_core` / :func:`refine_core_game` — the retained
  high-degree core is held resident and refined with passes of the
  existing masked Stackelberg game (``core.game`` reused as the
  in-memory NE-style refiner: only clusters the core level touches may
  deviate, every other player is frozen context), each candidate
  re-scored through the megakernel-backed Alg. 3 carry over the resident
  records;
- :class:`TailAssignCarry` — the streamed remainder.  It is the standard
  :class:`~repro.core.postprocess.AssignCarry` (same O(k) load carry,
  same SUM merge, so ``run_parallel`` lanes work unchanged) except that
  the per-edge extras (head flag, endpoint clusters) are *derived inside
  the chunk step* from resident O(|V|) tables instead of riding the
  stream, and edges belonging to the resident core are masked to padding
  self-loops — they were already placed in-memory, so the tail pass must
  neither place nor load-charge them.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..core import game as _game
from ..core.postprocess import AssignCarry
from ..streaming import EdgeStream, run_carry

__all__ = [
    "CoreBuffer",
    "TailAssignCarry",
    "core_move_mask",
    "place_core",
    "refine_core_game",
]


class CoreBuffer(NamedTuple):
    """Resident records of the spilled high-degree core (host numpy).

    ``arrival`` is each edge's index in the *arrival-ordered* edge list,
    so core placements scatter straight into the final parts vector;
    ``deg_min`` (min endpoint degree) lets one spill at ξ* serve every
    refinement level ℓ ≥ ξ* by masking (``deg_min > ℓ``).
    """

    src: np.ndarray       # (M,) int32
    dst: np.ndarray       # (M,) int32
    arrival: np.ndarray   # (M,) int64 — position in arrival order
    cu: np.ndarray        # (M,) int32 — endpoint cluster (combined id)
    cv: np.ndarray        # (M,) int32
    deg_min: np.ndarray   # (M,) int32 — min(deg(u), deg(v))
    head: np.ndarray      # (M,) bool  — Alg. 3 head-edge flag

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in self)

    def select(self, mask: np.ndarray) -> "CoreBuffer":
        return CoreBuffer(*(a[mask] for a in self))


class TailAssignCarry(AssignCarry):
    """Alg. 3 over the streamed tail of a hybrid run.

    Extras are computed per chunk from resident tables (exact degrees +
    the compacted head/tail cluster maps — O(|V|) pipeline state the pure
    streaming run keeps anyway), and core edges (both endpoint degrees
    above ``core_threshold``) are rewritten to ``(0, 0)`` self-loops so
    the underlying scan treats them as padding: part −1, no load charge.
    The merge contract is inherited (load vector, SUM), so S-way parallel
    ingest over the tail works exactly like the pure-streaming pass.
    """

    def __init__(self, k: int, max_load: int, c2p, *, degrees, v2c_h,
                 v2c_t, xi: int, core_threshold: int,
                 use_kernel: bool | None = None,
                 vmem_budget: int | None = None):
        super().__init__(k, max_load, c2p, use_kernel=use_kernel,
                         vmem_budget=vmem_budget)
        self.degrees = jnp.asarray(degrees, jnp.int32)
        self.v2c_h = jnp.asarray(v2c_h, jnp.int32)
        self.v2c_t = jnp.asarray(v2c_t, jnp.int32)
        self.xi = jnp.int32(xi)
        self.core_threshold = jnp.int32(core_threshold)

    def _tag_chunk(self, src, dst):
        deg_u = self.degrees[src]
        deg_v = self.degrees[dst]
        is_core = (deg_u > self.core_threshold) & (deg_v > self.core_threshold)
        h = (deg_u > self.xi) & (deg_v > self.xi)
        cu = jnp.where(h, self.v2c_h[src], self.v2c_t[src])
        cv = jnp.where(h, self.v2c_h[dst], self.v2c_t[dst])
        return is_core, h, jnp.maximum(cu, 0), jnp.maximum(cv, 0)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        is_core, h, cu, cv = self._tag_chunk(src, dst)
        zero = jnp.zeros_like(src)
        src = jnp.where(is_core, zero, src)
        dst = jnp.where(is_core, zero, dst)
        return super().step_chunk(carry, src, dst, n_valid, h, cu, cv)

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        is_core, _, _, _ = self._tag_chunk(src, dst)
        zero = jnp.zeros_like(src)
        src = jnp.where(is_core, zero, src)
        dst = jnp.where(is_core, zero, dst)
        return super().retract_chunk(carry, src, dst, n_valid, parts)


def place_core(core: CoreBuffer, c2p, k: int, max_load: int,
               n_vertices: int, *, chunk_size: int = 1 << 16,
               use_kernel: bool | None = None,
               vmem_budget: int | None = None):
    """Place the resident core records under Alg. 3 (megakernel-backed).

    Returns ``(parts, load)`` for the core edges in buffer order — the
    load vector then seeds the tail pass so the composed placement
    respects one shared capacity L across both halves.
    """
    if core.n_edges == 0:
        return (np.zeros(0, np.int32),
                jnp.zeros((int(k),), jnp.int32))
    stream = EdgeStream(core.src, core.dst, n_vertices,
                        chunk_size=min(chunk_size, max(core.n_edges, 1)))
    pc = AssignCarry(k, max_load, jnp.asarray(c2p, jnp.int32),
                     use_kernel=use_kernel, vmem_budget=vmem_budget)
    parts, load = run_carry(
        stream, pc,
        jnp.asarray(core.head),
        jnp.maximum(jnp.asarray(core.cu, jnp.int32), 0),
        jnp.maximum(jnp.asarray(core.cv, jnp.int32), 0))
    return np.asarray(parts, np.int32), load


def core_move_mask(core: CoreBuffer, n_clusters: int) -> np.ndarray:
    """Movable-player mask: clusters with at least one resident core edge.

    The refinement game at a ladder level frees exactly the clusters that
    level's core touches; the rest of the equilibrium is frozen context —
    the same "refine only what was touched" shape the incremental path
    uses for delta refinement.
    """
    mask = np.zeros(int(n_clusters), bool)
    for c in (core.cu, core.cv):
        c = np.asarray(c)
        c = c[(c >= 0) & (c < n_clusters)]
        mask[c] = True
    return mask


def refine_core_game(inputs: "_game.GameInputs", n_clusters: int, c2p,
                     *, leader_mask, move_mask, rounds: int,
                     accept_prob: float, seed: int,
                     batch_size: int) -> "_game.GameResult":
    """One masked-game refinement pass over the resident core's clusters.

    Thin wrapper over :func:`repro.core.game.run_game`: ``assign0`` is
    the incumbent map, only ``move_mask`` players deviate, and the
    leader/follower split comes from the combined-id head mask — the
    two-stage Stackelberg structure is preserved inside the core.
    """
    bs = _game.default_batch_size(batch_size, n_clusters)
    return _game.run_game(
        inputs, n_clusters,
        batch_size=bs, max_rounds=max(int(rounds), 1),
        accept_prob=accept_prob, assign0=np.asarray(c2p, np.int32),
        seed=seed, leader_mask=np.asarray(leader_mask, bool),
        move_mask=np.asarray(move_mask, bool))
