"""Memory-budget hybrid partitioner — in-memory skew core + streamed tail.

The HEP regime (Mayer & Jacobsen, "Hybrid Edge Partitioner"; PAPERS.md):
spend a bounded slice of host memory on an in-memory partition of the
high-degree core — the skewed minority of edges that dominates replication
quality — and stream the low-degree remainder.  This package allocates a
caller-supplied **byte budget** between the two halves the repo already
owns (the Θ/ξ skew separator + CMS sketches, and the out-of-core
`ShardedEdgeStream` with `HostBudget` accounting):

- :mod:`planner` — :func:`plan_budget` sizes the resident core online
  from a CMS degree sketch and picks the core threshold ξ* that fits the
  budget (budget 0 ⇒ pure streaming; a budget covering the whole edge
  list ⇒ fully in-memory);
- :mod:`refiner` — the retained core is refined with multiple passes of
  the masked Stackelberg game (the game reused as the in-memory NE-style
  refiner) and re-scored through the megakernel-backed Alg. 3 carry;
- :mod:`driver` — :func:`run_hybrid` makes the budget-bounded pass:
  core edges spill to a resident buffer charged against a hard-capped
  :class:`~repro.streaming.HostBudget`, tail edges stream through the
  existing Alg. 3 carry seeded with the core's load vector, and the
  result packs into a standard warm bundle so incremental deltas,
  elastic resharding and serving all keep working.

One knob — ``S5PConfig.host_budget`` / ``--host-budget`` — sweeps
pure-streaming → hybrid → fully in-memory.
"""

from .planner import (  # noqa: F401
    BudgetPlan,
    CORE_EDGE_BYTES,
    build_degree_sketch,
    plan_budget,
)
from .refiner import TailAssignCarry, core_move_mask, place_core  # noqa: F401
from .driver import (  # noqa: F401
    HybridResult,
    HybridServingChain,
    run_hybrid,
)

__all__ = [
    "BudgetPlan",
    "CORE_EDGE_BYTES",
    "build_degree_sketch",
    "plan_budget",
    "TailAssignCarry",
    "core_move_mask",
    "place_core",
    "HybridResult",
    "HybridServingChain",
    "run_hybrid",
]
