"""Neighbor sampling for large-graph minibatch GNN training (minibatch_lg).

A real fanout sampler (GraphSAGE-style, e.g. 15-10): seed nodes →
uniformly sample up to ``fanout[h]`` neighbors per hop from a CSR adjacency,
emitting a padded subgraph with fixed shapes so the jitted train step never
recompiles.  Runs on host (numpy) and feeds the device pipeline — the same
split production GNN systems use (sampler on CPU, model on accelerator).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["CSRGraph", "build_csr", "SampledSubgraph", "NeighborSampler"]


class CSRGraph(NamedTuple):
    indptr: np.ndarray  # (V+1,)
    indices: np.ndarray  # (E,)
    n_vertices: int


def build_csr(src: np.ndarray, dst: np.ndarray, n_vertices: int,
              symmetrize: bool = True) -> CSRGraph:
    if symmetrize:
        s = np.concatenate([src, dst])
        d = np.concatenate([dst, src])
    else:
        s, d = src, dst
    order = np.argsort(s, kind="stable")
    s, d = s[order], d[order]
    indptr = np.zeros(n_vertices + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(indptr=indptr, indices=d.astype(np.int32), n_vertices=n_vertices)


class SampledSubgraph(NamedTuple):
    """Fixed-shape padded subgraph for one minibatch."""

    nodes: np.ndarray  # (max_nodes,) global node ids (padded with 0)
    node_mask: np.ndarray  # (max_nodes,) bool
    edge_src: np.ndarray  # (max_edges,) local indices into `nodes`
    edge_dst: np.ndarray  # (max_edges,)
    edge_mask: np.ndarray  # (max_edges,) bool
    seed_count: int  # seeds occupy nodes[:seed_count]


class NeighborSampler:
    """Uniform fanout sampler with fixed padded output shapes."""

    def __init__(self, graph: CSRGraph, fanouts: tuple[int, ...], batch_nodes: int,
                 seed: int = 0):
        self.graph = graph
        self.fanouts = tuple(fanouts)
        self.batch_nodes = batch_nodes
        self.rng = np.random.default_rng(seed)
        # fixed budget: seeds + seeds*f1 + seeds*f1*f2 + ...
        n = batch_nodes
        self.max_nodes = batch_nodes
        self.max_edges = 0
        for f in self.fanouts:
            e = n * f
            self.max_edges += e
            n = e
            self.max_nodes += e

    def sample(self, seeds: np.ndarray | None = None) -> SampledSubgraph:
        g = self.graph
        if seeds is None:
            seeds = self.rng.choice(g.n_vertices, size=self.batch_nodes, replace=False)
        seeds = np.asarray(seeds, np.int64)

        nodes: list[np.ndarray] = [seeds]
        local_of: dict[int, int] = {int(v): i for i, v in enumerate(seeds)}
        e_src: list[int] = []
        e_dst: list[int] = []
        frontier = seeds
        for f in self.fanouts:
            deg = g.indptr[frontier + 1] - g.indptr[frontier]
            next_frontier = []
            for v, dv in zip(frontier, deg):
                if dv == 0:
                    continue
                start = g.indptr[v]
                take = min(f, int(dv))
                picks = self.rng.choice(int(dv), size=take, replace=False)
                nbrs = g.indices[start + picks]
                lv = local_of[int(v)]
                for nb in nbrs:
                    nbi = int(nb)
                    li = local_of.get(nbi)
                    if li is None:
                        li = len(local_of)
                        local_of[nbi] = li
                        next_frontier.append(nbi)
                    # message flows neighbor → center
                    e_src.append(li)
                    e_dst.append(lv)
            frontier = np.asarray(next_frontier, np.int64)
            if frontier.size:
                nodes.append(frontier)
            if frontier.size == 0:
                break

        all_nodes = np.concatenate(nodes) if len(nodes) > 1 else nodes[0]
        n_real = all_nodes.size
        n_edges = len(e_src)
        out_nodes = np.zeros(self.max_nodes, np.int32)
        out_nodes[:n_real] = all_nodes[: self.max_nodes]
        node_mask = np.zeros(self.max_nodes, bool)
        node_mask[: min(n_real, self.max_nodes)] = True
        es = np.zeros(self.max_edges, np.int32)
        ed = np.zeros(self.max_edges, np.int32)
        emask = np.zeros(self.max_edges, bool)
        ne = min(n_edges, self.max_edges)
        es[:ne] = np.asarray(e_src[:ne], np.int32)
        ed[:ne] = np.asarray(e_dst[:ne], np.int32)
        emask[:ne] = True
        return SampledSubgraph(
            nodes=out_nodes, node_mask=node_mask, edge_src=es, edge_dst=ed,
            edge_mask=emask, seed_count=self.batch_nodes,
        )
