from .generators import (  # noqa: F401
    rmat_graph,
    block_rmat_graph,
    powerlaw_graph,
    community_graph,
    erdos_renyi_graph,
    toy_graph_fig3,
    graph_skewness,
)
from .sampler import NeighborSampler, build_csr  # noqa: F401
from .datasets import cora_like, ogbn_products_like, molecule_batch  # noqa: F401
