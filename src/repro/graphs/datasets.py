"""Synthetic datasets shaped like the assigned GNN benchmark graphs.

The container is offline, so we generate graphs with the *exact* assigned
statistics (node/edge/feature counts) and matching degree skew:

- ``cora_like``            — 2,708 nodes / 10,556 edges / 1,433 features
- ``ogbn_products_like``   — 2,449,029 nodes / 61,859,140 edges / 100 feats
                             (feature matrix is produced lazily per-chunk)
- ``molecule_batch``       — batched small molecular graphs (30 nodes / 64
                             edges each) with 3-D coordinates for
                             SchNet/EGNN/DimeNet
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .generators import powerlaw_graph

__all__ = ["GraphData", "cora_like", "ogbn_products_like", "molecule_batch"]


class GraphData(NamedTuple):
    src: np.ndarray
    dst: np.ndarray
    n_vertices: int
    features: np.ndarray | None  # (V, F) or None for lazy
    labels: np.ndarray | None
    n_classes: int


def cora_like(seed: int = 0) -> GraphData:
    n, m, f, c = 2708, 10556 // 2, 1433, 7  # 10,556 directed = 5,278 undirected
    src, dst, _ = powerlaw_graph(n, avg_degree=2 * m / n, rho=2.5, seed=seed)
    src, dst = src[:m], dst[:m]
    rng = np.random.default_rng(seed + 1)
    feats = (rng.random((n, f)) < 0.012).astype(np.float32)  # sparse bag-of-words
    # labels derive from features (+ noise) so held-out accuracy is learnable
    w = rng.standard_normal((f, c))
    labels = (feats @ w + 0.5 * rng.standard_normal((n, c))).argmax(1).astype(np.int32)
    return GraphData(src, dst, n, feats, labels, c)


def ogbn_products_like(seed: int = 0, scale: float = 1.0) -> GraphData:
    """Product co-purchase-shaped graph.  ``scale`` < 1 shrinks for tests."""
    n = int(2_449_029 * scale)
    m = int(61_859_140 // 2 * scale)
    src, dst, _ = powerlaw_graph(n, avg_degree=2 * m / n, rho=2.3, seed=seed)
    src, dst = src[:m], dst[:m]
    rng = np.random.default_rng(seed + 1)
    labels = rng.integers(0, 47, n).astype(np.int32)
    return GraphData(src, dst, n, None, labels, 47)  # features generated lazily


def products_features(nodes: np.ndarray, d_feat: int = 100, seed: int = 0) -> np.ndarray:
    """Deterministic per-node features (hash-seeded) — lazy materialization."""
    out = np.empty((nodes.size, d_feat), np.float32)
    for i, v in enumerate(np.asarray(nodes, np.int64)):
        r = np.random.default_rng(seed * 1_000_003 + int(v))
        out[i] = r.standard_normal(d_feat).astype(np.float32)
    return out


class MoleculeBatch(NamedTuple):
    positions: np.ndarray  # (B, N, 3)
    species: np.ndarray  # (B, N) int32 atomic numbers
    edge_src: np.ndarray  # (B, E) intra-molecule edges
    edge_dst: np.ndarray  # (B, E)
    energies: np.ndarray  # (B,) regression target


def molecule_batch(batch: int = 128, n_atoms: int = 30, n_edges: int = 64,
                   seed: int = 0) -> MoleculeBatch:
    rng = np.random.default_rng(seed)
    pos = rng.standard_normal((batch, n_atoms, 3)).astype(np.float32) * 2.0
    species = rng.integers(1, 10, (batch, n_atoms)).astype(np.int32)
    # connect nearest neighbors until n_edges per molecule
    es = np.zeros((batch, n_edges), np.int32)
    ed = np.zeros((batch, n_edges), np.int32)
    for b in range(batch):
        d = np.linalg.norm(pos[b][:, None] - pos[b][None, :], axis=-1)
        np.fill_diagonal(d, np.inf)
        flat = np.argsort(d, axis=None)[: n_edges]
        es[b] = (flat // n_atoms).astype(np.int32)
        ed[b] = (flat % n_atoms).astype(np.int32)
    # synthetic smooth target: sum of pairwise Gaussians (learnable)
    en = np.zeros(batch, np.float32)
    for b in range(batch):
        d = np.linalg.norm(pos[b][es[b]] - pos[b][ed[b]], axis=-1)
        en[b] = np.exp(-d).sum()
    return MoleculeBatch(pos, species, es, ed, en)
