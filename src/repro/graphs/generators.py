"""Synthetic graph generators for the paper's evaluation suite.

- :func:`rmat_graph` — R-MAT (Chakrabarti 2004) recursive generator, the
  TrillionG-style sampler the paper uses for its G₁…G₆ skewness sweep.
  Implemented vectorized: every edge draws its quadrant bits for all
  ``log2(V)`` levels at once.
- :func:`powerlaw_graph` — Chung-Lu style power-law degree sequence.
- :func:`erdos_renyi_graph` — non-skewed control.
- :func:`toy_graph_fig3` — the 12-vertex/14-edge worked example of paper
  Figure 3 (used by the unit tests to pin Algorithm-1 behaviour).
- :func:`graph_skewness` — (ρ, ρ₁, ρ₂, ρ₃) of paper §2.3.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "rmat_graph",
    "block_rmat_graph",
    "powerlaw_graph",
    "community_graph",
    "erdos_renyi_graph",
    "toy_graph_fig3",
    "graph_skewness",
]


def rmat_graph(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    dedup: bool = True,
):
    """R-MAT: V = 2**scale vertices, E ≈ edge_factor·V edges.

    Larger (a − d) skews the degree distribution harder; the paper's
    G₁…G₃/G₄…G₆ groups vary edge_factor at fixed V to increase skew.
    Returns (src, dst, n_vertices) as int32 numpy arrays.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    d = 1.0 - a - b - c
    if d < 0:
        raise ValueError("a + b + c must be <= 1")
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    # vectorized recursive quadrant descent
    for _ in range(scale):
        r = rng.random(m)
        right = (r >= a + c).astype(np.int64) if False else None
        # quadrant probabilities: [a | b; c | d] over (src_bit, dst_bit)
        sbit = (r >= a + b).astype(np.int64)  # bottom half ⇒ src bit 1
        r2 = rng.random(m)
        p_right = np.where(sbit == 0, b / max(a + b, 1e-12), d / max(c + d, 1e-12))
        dbit = (r2 < p_right).astype(np.int64)
        src = (src << 1) | sbit
        dst = (dst << 1) | dbit
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if dedup:
        key = np.minimum(src, dst) * n + np.maximum(src, dst)
        _, idx = np.unique(key, return_index=True)
        idx.sort()  # preserve stream order of first occurrence
        src, dst = src[idx], dst[idx]
    return src.astype(np.int32), dst.astype(np.int32), n


def block_rmat_graph(
    block_scale: int = 7,
    n_blocks: int = 32,
    edge_factor: int = 8,
    a: float = 0.65,
    b: float = 0.12,
    c: float = 0.12,
    inter_frac: float = 0.08,
    seed: int = 0,
):
    """Hub-heavy R-MAT with planted block structure.

    Each of ``n_blocks`` communities is an independent R-MAT of
    ``2**block_scale`` vertices (skewed hard via ``a``), plus
    ``inter_frac``·E uniformly random inter-block edges; vertex ids are
    globally permuted so the blocks are invisible to a streaming
    partitioner.  This is the web/social regime of the paper's corpus —
    power-law hubs *inside* strong communities — where clustering-based
    partitioners (S5P/2PS-L) recover the blocks and beat score-based HDRF;
    a single global R-MAT (no communities) is the adversarial case where
    they don't.  The serving benchmark uses this as its churn substrate.
    Returns (src, dst, n_vertices).
    """
    rng = np.random.default_rng(seed)
    bs = 1 << block_scale
    n = bs * n_blocks
    srcs, dsts = [], []
    for blk in range(n_blocks):
        s, d, _ = rmat_graph(block_scale, edge_factor, a=a, b=b, c=c,
                             seed=seed * 7919 + blk)
        srcs.append(s.astype(np.int64) + blk * bs)
        dsts.append(d.astype(np.int64) + blk * bs)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    m_inter = int(inter_frac * src.size)
    isrc = rng.integers(0, n, m_inter)
    idst = rng.integers(0, n, m_inter)
    keep = isrc != idst
    src = np.concatenate([src, isrc[keep]])
    dst = np.concatenate([dst, idst[keep]])
    # hide the blocks: relabel vertices and shuffle arrival order
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    order = rng.permutation(src.size)
    return src[order].astype(np.int32), dst[order].astype(np.int32), n


def powerlaw_graph(n_vertices: int, avg_degree: float = 8.0, rho: float = 2.2,
                   seed: int = 0, dedup: bool = True):
    """Chung-Lu expected-degree power-law graph: f(d) ∝ d^(−ρ)."""
    rng = np.random.default_rng(seed)
    # sample degree weights from a Pareto-ish tail
    w = (rng.pareto(rho - 1.0, n_vertices) + 1.0)
    w *= avg_degree / w.mean()
    m = int(n_vertices * avg_degree / 2)
    p = w / w.sum()
    src = rng.choice(n_vertices, size=m, p=p).astype(np.int64)
    dst = rng.choice(n_vertices, size=m, p=p).astype(np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if dedup:
        key = np.minimum(src, dst) * np.int64(n_vertices) + np.maximum(src, dst)
        _, idx = np.unique(key, return_index=True)
        idx.sort()
        src, dst = src[idx], dst[idx]
    return src.astype(np.int32), dst.astype(np.int32), n_vertices


def community_graph(
    n_vertices: int,
    n_communities: int = 32,
    avg_degree: float = 8.0,
    rho: float = 2.2,
    p_intra: float = 0.9,
    seed: int = 0,
    dedup: bool = True,
):
    """Degree-corrected SBM: power-law degrees + planted communities.

    This is the structure of the paper's web/social graphs (strong locality
    + heavy skew) — the regime where clustering-refinement partitioners
    (2PS-L / CLUGP / S5P) beat score-based ones (HDRF).  A pure Chung-Lu
    graph has *no* communities and is the adversarial case for clustering.
    """
    rng = np.random.default_rng(seed)
    w = rng.pareto(rho - 1.0, n_vertices) + 1.0
    w *= avg_degree / w.mean()
    comm = rng.integers(0, n_communities, n_vertices)
    # bucket vertices by community for intra-draws
    order = np.argsort(comm, kind="stable")
    comm_sorted = comm[order]
    starts = np.searchsorted(comm_sorted, np.arange(n_communities))
    stops = np.searchsorted(comm_sorted, np.arange(n_communities), side="right")
    m = int(n_vertices * avg_degree / 2)
    p_global = w / w.sum()
    src = np.empty(m, np.int64)
    dst = np.empty(m, np.int64)
    intra = rng.random(m) < p_intra
    # endpoint 1 ~ degree-weighted global draw
    src[:] = rng.choice(n_vertices, size=m, p=p_global)
    # endpoint 2: same community (degree-weighted within) or global
    dst_global = rng.choice(n_vertices, size=m, p=p_global)
    dst[:] = dst_global
    for c in range(n_communities):
        members = order[starts[c]:stops[c]]
        if members.size < 2:
            continue
        sel = intra & (comm[src] == c)
        cnt = int(sel.sum())
        if cnt == 0:
            continue
        pw = w[members] / w[members].sum()
        dst[sel] = rng.choice(members, size=cnt, p=pw)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if dedup:
        key = np.minimum(src, dst) * np.int64(n_vertices) + np.maximum(src, dst)
        _, idx = np.unique(key, return_index=True)
        idx.sort()
        src, dst = src[idx], dst[idx]
    return src.astype(np.int32), dst.astype(np.int32), n_vertices


def erdos_renyi_graph(n_vertices: int, avg_degree: float = 8.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    m = int(n_vertices * avg_degree / 2)
    src = rng.integers(0, n_vertices, m)
    dst = rng.integers(0, n_vertices, m)
    keep = src != dst
    return src[keep].astype(np.int32), dst[keep].astype(np.int32), n_vertices


def toy_graph_fig3():
    """Paper Figure 3: 12 vertices, 14 edges; index = stream arrival order.

    Edge numbers in the figure give the arrival sequence (e1 … e14).  The
    figure names a few explicitly (e4(v2,v7), e5(v1,v2), e6(v0,v1),
    e14(v3,v6)); the remaining edges complete a consistent head/tail split
    with head vertices {v0, v1, v2, v3} for ξ = ⌊2·14/12⌋ = 2.
    """
    edges = [
        (0, 4),   # e1  tail (gives v0 head degree, per the e6 narrative)
        (5, 6),   # e2  tail
        (6, 7),   # e3  tail
        (2, 7),   # e4  (paper)
        (1, 2),   # e5  head (paper: d(v1)=5, d(v2)=6 context)
        (0, 1),   # e6  head (paper)
        (1, 3),   # e7
        (2, 3),   # e8
        (0, 2),   # e9
        (1, 8),   # e10
        (2, 9),   # e11
        (1, 10),  # e12
        (2, 11),  # e13
        (3, 6),   # e14 (paper)
    ]
    src = np.array([e[0] for e in edges], np.int32)
    dst = np.array([e[1] for e in edges], np.int32)
    return src, dst, 12


def graph_skewness(src, dst, n_vertices: int):
    """(ρ, ρ₁, ρ₂, ρ₃) per paper §2.3."""
    deg = np.bincount(src, minlength=n_vertices) + np.bincount(dst, minlength=n_vertices)
    deg = deg[deg > 0].astype(np.float64)
    # regression-based ρ: fit log f(d) = -ρ log d + c over observed degrees
    vals, counts = np.unique(deg, return_counts=True)
    mask = (vals > 0) & (counts > 0)
    x = np.log(vals[mask])
    y = np.log(counts[mask])
    rho = float(-np.polyfit(x, y, 1)[0]) if x.size >= 2 else float("nan")
    sigma = deg.std()
    mean = deg.mean()
    vals_i = vals.astype(np.int64)
    mode = float(vals_i[np.argmax(counts)])
    median = float(np.median(deg))
    rho1 = float((mean - mode) / sigma) if sigma > 0 else 0.0
    rho2 = float(3 * (mean - median) / sigma) if sigma > 0 else 0.0
    rho3 = int(src.shape[0] - (3 * n_vertices - 6))
    return rho, rho1, rho2, rho3
