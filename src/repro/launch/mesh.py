"""Production mesh definitions (required by the multi-pod dry-run).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and everything else (smoke tests, benches) sees the real device
count.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (CI / unit tests)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (4, 2):
        if n % m == 0 and n >= m:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
