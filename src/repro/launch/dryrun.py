import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
- the sharding config is coherent (SPMD partitioner accepts it);
- it fits (``compiled.memory_analysis()`` per-device bytes);
- the cost terms for §Roofline (``cost_analysis()`` + collective bytes
  parsed from the optimized HLO, with while-body trip-count correction for
  the scanned layer stack — see repro/roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import REGISTRY
from ..roofline.analysis import analyze_compiled
from ..sharding import AxisRules, DEFAULT_RULES, use_rules
from .cells import build_cell
from .mesh import make_production_mesh


def lower_cell(cell, mesh, rules_map=None):
    """Lower + compile one cell on ``mesh``; returns (lowered, compiled)."""
    from jax.sharding import NamedSharding

    mapping = dict(DEFAULT_RULES)
    mapping.update(cell.rules)
    if rules_map:
        mapping.update(rules_map)
    rules = AxisRules(mesh, mapping)

    def shard(axes_tree):
        return jax.tree.map(
            lambda axes: NamedSharding(mesh, rules.resolve(*axes)),
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(a, (str, type(None))) for a in x),
        )

    in_shardings = (shard(cell.state_axes), *[shard(a) for a in cell.batch_axes])
    out_shardings = shard(cell.out_axes) if cell.out_axes is not None else None
    with use_rules(mesh, mapping):
        jitted = jax.jit(cell.step_fn, in_shardings=in_shardings,
                         out_shardings=out_shardings,
                         donate_argnums=cell.donate or ())
        lowered = jitted.lower(cell.state_shape, *cell.batch_shape)
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape: str, multi_pod: bool, rules_map=None,
             verbose: bool = True) -> dict:
    t0 = time.time()
    cell = build_cell(arch, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, compiled = lower_cell(cell, mesh, rules_map)
    mem = compiled.memory_analysis()
    result = analyze_compiled(cell, lowered, compiled, mesh)
    result.update(
        arch=arch, shape=shape,
        mesh="2x16x16" if multi_pod else "16x16",
        compile_s=round(time.time() - t0, 1),
        bytes_per_device=int(
            mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes
        ),
        argument_bytes=int(mem.argument_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
        ok=True,
    )
    if verbose:
        print(f"[dryrun] {arch} × {shape} × {result['mesh']}: "
              f"{result['bytes_per_device']/2**30:.2f} GiB/dev, "
              f"compute {result['t_compute']*1e3:.2f} ms, "
              f"memory {result['t_memory']*1e3:.2f} ms, "
              f"collective {result['t_collective']*1e3:.2f} ms "
              f"→ {result['bottleneck']} ({result['compile_s']}s compile)",
              flush=True)
    return result


def iter_cells():
    for arch_name, arch in REGISTRY.items():
        for shape_name in arch.shapes:
            if shape_name in arch.skips:
                yield arch_name, shape_name, arch.skips[shape_name]
            else:
                yield arch_name, shape_name, None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = []
    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    if out_path.exists():
        results = json.loads(out_path.read_text())
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    cells = (
        list(iter_cells()) if args.all else [(args.arch, args.shape, None)]
    )
    for arch, shape, skip in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            if (arch, shape, mesh_name) in done:
                continue
            if skip:
                results.append(dict(arch=arch, shape=shape, mesh=mesh_name,
                                    ok=True, skipped=skip))
                print(f"[dryrun] {arch} × {shape}: SKIP ({skip})", flush=True)
                continue
            try:
                results.append(run_cell(arch, shape, multi))
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                results.append(dict(arch=arch, shape=shape, mesh=mesh_name,
                                    ok=False, error=f"{type(e).__name__}: {e}"))
            out_path.write_text(json.dumps(results, indent=1))

    n_ok = sum(r["ok"] for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} cells OK → {out_path}")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
