"""Serving driver: batched decode / recsys scoring / live graph serving.

``python -m repro.launch.serve --arch mixtral-8x7b --tokens 32`` runs
prefill + a decode loop on the smoke config (CPU); on a TPU mesh the same
code path serves the full config under the serve sharding rules.

``python -m repro.launch.serve --graph block-rmat --window 4096`` instead
runs the live partition-serving loop: a sliding-window S5P chain churns in
a background ingest thread, each step published as an atomic
partition-bundle swap, while a GAS PageRank reader executes super-steps
and point queries over the pinned versions (see ``repro.serving``).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..models import lm as LM
from ..models import recsys as R


def serve_lm(arch: str, prompt_len: int = 32, gen_tokens: int = 16,
             batch: int = 2, smoke: bool = True, seed: int = 0):
    spec = get_arch(arch)
    cfg = spec.smoke_config if smoke else spec.config
    key = jax.random.PRNGKey(seed)
    params = LM.init_params(cfg, key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab,
                                 dtype=jnp.int32)
    max_seq = prompt_len + gen_tokens
    prefill_jit = jax.jit(lambda p, t: LM.prefill(p, t, cfg, max_seq=max_seq))
    decode_jit = jax.jit(lambda p, c, t, pos: LM.decode_step(p, c, t, pos, cfg))
    t0 = time.time()
    logits, cache = prefill_jit(params, prompts)
    toks = jnp.argmax(logits, axis=-1)
    out = [toks]
    for i in range(gen_tokens - 1):
        pos = jnp.full((batch,), prompt_len + i, jnp.int32)
        logits, cache = decode_jit(params, cache, toks, pos)
        toks = jnp.argmax(logits, axis=-1)
        out.append(toks)
    seqs = jnp.stack(out, axis=1)
    jax.block_until_ready(seqs)
    dt = time.time() - t0
    print(f"[serve] {arch}: {batch}×{gen_tokens} tokens in {dt:.2f}s "
          f"({dt / gen_tokens * 1e3:.1f} ms/token)")
    return seqs


def serve_recsys(arch: str = "xdeepfm", batch: int = 64, smoke: bool = True,
                 seed: int = 0):
    spec = get_arch(arch)
    cfg = spec.smoke_config if smoke else spec.config
    key = jax.random.PRNGKey(seed)
    params = R.xdeepfm_init(cfg, key)
    cols = [jax.random.randint(jax.random.fold_in(key, f), (batch,), 0, v,
                               dtype=jnp.int32) for f, v in enumerate(cfg.vocabs())]
    ids = jnp.stack(cols, axis=1)
    fwd = jax.jit(lambda p, x: R.xdeepfm_forward(p, x, cfg))
    t0 = time.time()
    scores = fwd(params, ids)
    jax.block_until_ready(scores)
    print(f"[serve] {arch}: scored {batch} in {(time.time()-t0)*1e3:.1f} ms")
    return scores


def serve_graph(graph: str = "block-rmat", k: int = 8,
                window_edges: int = 4096, step_edges: int | None = None,
                supersteps_per_swap: int = 4, queries_per_swap: int = 2,
                auto_cold_restart: bool = True, background: bool = False,
                seed: int = 0, verbose: bool = True):
    """Live partition-serving loop: churn + GAS super-steps + queries.

    Builds a sliding-window S5P chain over ``graph``'s edge stream and a
    :class:`~repro.serving.ServingController` that publishes each step's
    live window as an atomic :class:`~repro.serving.PartitionBundle`
    swap.  A :class:`~repro.serving.GASServer` interleaves PageRank
    super-steps and point queries against the pinned versions — with
    ``background=True`` the ingest runs on its own thread and the reader
    free-runs against whatever version is current (the deployment shape);
    otherwise churn and compute interleave deterministically.  Returns
    ``(server, controller)`` for inspection.
    """
    from ..core.s5p import S5PConfig
    from ..graphs import block_rmat_graph, community_graph
    from ..incremental import S5PWindowChain
    from ..serving import BundleRegistry, GASServer, ServingController

    if graph == "block-rmat":
        src, dst, n = block_rmat_graph(block_scale=6, n_blocks=16,
                                       edge_factor=8, seed=seed)
    else:
        src, dst, n = community_graph(4096, n_communities=32, seed=seed)
    cfg = S5PConfig(k=k, seed=seed, chunk_size=max(window_edges, 1024))
    chain = S5PWindowChain(src, dst, n, cfg, window_edges,
                           step_edges=step_edges,
                           auto_cold_restart=auto_cold_restart)
    registry = BundleRegistry()
    controller = ServingController(registry, chain)
    server = GASServer(registry)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    if background:
        controller.start(throttle_s=0.001)
        while not controller.done.is_set() or registry.current is None:
            if server.superstep() is None:
                time.sleep(0.001)
                continue
            server.query_pagerank(rng.integers(0, n, 16))
            if controller.done.is_set():
                break
        controller.join()
    else:
        while controller.step() is not None:
            if registry.current is None:
                continue  # window still filling
            for _ in range(supersteps_per_swap):
                server.superstep()
            for _ in range(queries_per_swap):
                server.query_pagerank(rng.integers(0, n, 16))
    server.run_to_convergence()
    if verbose:
        s = server.metrics.summary()
        print(f"[serve] graph={graph} V={n} E={src.size} k={k} "
              f"window={window_edges}")
        print(f"[serve] versions={controller.version} "
              f"swaps_observed={s['swaps_observed']} "
              f"supersteps={s['supersteps']} "
              f"bytes/superstep={s['sync_bytes_per_superstep']:.0f} "
              f"rf={s['rf_final']:.3f} "
              f"query_lat={s['query_latency_us_mean']:.0f}us "
              f"wall={time.time() - t0:.1f}s")
    return server, controller


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--graph", default=None,
                    help="serve a live-partitioned graph instead of a "
                         "model: block-rmat | community")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--window", type=int, default=4096)
    ap.add_argument("--step-edges", type=int, default=None)
    ap.add_argument("--background", action="store_true",
                    help="run ingest on a background thread (free-running "
                         "reader) instead of deterministic interleave")
    ap.add_argument("--no-cold-restart", action="store_true")
    args = ap.parse_args()
    if args.graph is not None:
        serve_graph(args.graph, k=args.k, window_edges=args.window,
                    step_edges=args.step_edges, background=args.background,
                    auto_cold_restart=not args.no_cold_restart)
    elif get_arch(args.arch).family == "recsys":
        serve_recsys(args.arch, batch=args.batch)
    else:
        serve_lm(args.arch, gen_tokens=args.tokens, batch=args.batch)


if __name__ == "__main__":
    main()
