"""Serving driver: batched decode / recsys scoring from the public API.

``python -m repro.launch.serve --arch mixtral-8x7b --tokens 32`` runs
prefill + a decode loop on the smoke config (CPU); on a TPU mesh the same
code path serves the full config under the serve sharding rules.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..models import lm as LM
from ..models import recsys as R


def serve_lm(arch: str, prompt_len: int = 32, gen_tokens: int = 16,
             batch: int = 2, smoke: bool = True, seed: int = 0):
    spec = get_arch(arch)
    cfg = spec.smoke_config if smoke else spec.config
    key = jax.random.PRNGKey(seed)
    params = LM.init_params(cfg, key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab,
                                 dtype=jnp.int32)
    max_seq = prompt_len + gen_tokens
    prefill_jit = jax.jit(lambda p, t: LM.prefill(p, t, cfg, max_seq=max_seq))
    decode_jit = jax.jit(lambda p, c, t, pos: LM.decode_step(p, c, t, pos, cfg))
    t0 = time.time()
    logits, cache = prefill_jit(params, prompts)
    toks = jnp.argmax(logits, axis=-1)
    out = [toks]
    for i in range(gen_tokens - 1):
        pos = jnp.full((batch,), prompt_len + i, jnp.int32)
        logits, cache = decode_jit(params, cache, toks, pos)
        toks = jnp.argmax(logits, axis=-1)
        out.append(toks)
    seqs = jnp.stack(out, axis=1)
    jax.block_until_ready(seqs)
    dt = time.time() - t0
    print(f"[serve] {arch}: {batch}×{gen_tokens} tokens in {dt:.2f}s "
          f"({dt / gen_tokens * 1e3:.1f} ms/token)")
    return seqs


def serve_recsys(arch: str = "xdeepfm", batch: int = 64, smoke: bool = True,
                 seed: int = 0):
    spec = get_arch(arch)
    cfg = spec.smoke_config if smoke else spec.config
    key = jax.random.PRNGKey(seed)
    params = R.xdeepfm_init(cfg, key)
    cols = [jax.random.randint(jax.random.fold_in(key, f), (batch,), 0, v,
                               dtype=jnp.int32) for f, v in enumerate(cfg.vocabs())]
    ids = jnp.stack(cols, axis=1)
    fwd = jax.jit(lambda p, x: R.xdeepfm_forward(p, x, cfg))
    t0 = time.time()
    scores = fwd(params, ids)
    jax.block_until_ready(scores)
    print(f"[serve] {arch}: scored {batch} in {(time.time()-t0)*1e3:.1f} ms")
    return scores


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()
    if get_arch(args.arch).family == "recsys":
        serve_recsys(args.arch, batch=args.batch)
    else:
        serve_lm(args.arch, gen_tokens=args.tokens, batch=args.batch)


if __name__ == "__main__":
    main()
