"""Cell construction: (architecture × input shape) → lowerable step.

A *cell* bundles everything the dry-run / smoke tests / trainers need:

- ``step_fn``      — the jittable step (train_step or serve_step);
- ``state_shape``  / ``batch_shape`` — abstract ShapeDtypeStructs (no
  allocation; the dry-run lowers directly from these);
- ``state_axes``   / ``batch_axes`` — logical sharding axes per leaf,
  resolved against the active mesh by repro.sharding;
- ``rules``        — per-cell logical→mesh overrides (e.g. long_500k maps
  the rolling KV window over every axis, batch=1 cells unmap "batch");
- ``init_state`` / ``make_batch`` — concrete constructors for smoke tests
  and the example trainers;
- ``model_flops`` — analytic FLOPs per step for §Roofline.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ArchSpec, get_arch
from ..models import gnn as G
from ..models import lm as LM
from ..models import recsys as R
from ..optim import AdamWConfig, adamw_update, init_state as opt_init, make_train_step
from ..sharding import DEFAULT_RULES

__all__ = ["Cell", "build_cell", "SMOKE_OVERRIDES"]

f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    state_shape: Any  # pytree of ShapeDtypeStruct or None (serve cells)
    batch_shape: tuple  # positional args after state
    state_axes: Any
    batch_axes: tuple
    rules: dict
    init_state: Callable[[jax.Array], Any]
    make_batch: Callable[[jax.Array], tuple]
    model_flops: float
    donate: tuple = ()
    out_axes: Any = None  # logical sharding for outputs (None ⇒ XLA's choice)
    attn_block: Any = None  # (q_chunk, kv_chunk) for VMEM-adjusted memory


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _axes_like(tree, fn):
    """Map (path, leaf) → logical axes tuple over a pytree."""
    return jax.tree_util.tree_map_with_path(fn, tree)


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path
    )


# ---------------------------------------------------------------------------
# logical axes per family
# ---------------------------------------------------------------------------


def _lm_param_axes(path, leaf):
    p = _path_str(path)
    nd = len(leaf.shape)
    if "embed" in p:
        return ("mlp", "fsdp")
    if "lm_head" in p:
        return ("fsdp", "mlp")
    if any(k in p for k in ("wq", "wk", "wv")):
        return (None, "fsdp", "mlp")
    if "wo" in p:
        return (None, "mlp", "fsdp")
    if any(k in p for k in ("bq", "bk", "bv")):
        return (None, "mlp")
    if "router" in p:
        return (None, "fsdp", None)
    if any(k in p for k in ("w_gate", "w_up")):
        return (None, "expert", "fsdp", "mlp") if nd == 4 else (None, "fsdp", "mlp")
    if "w_down" in p:
        return (None, "expert", "mlp", "fsdp") if nd == 4 else (None, "mlp", "fsdp")
    return (None,) * nd


def _rec_param_axes(path, leaf):
    p = _path_str(path)
    nd = len(leaf.shape)
    if "tables" in p and nd == 2:
        return ("rows", None)
    return (None,) * nd


def _replicated_axes(path, leaf):
    return (None,) * len(leaf.shape)


def _state_axes(params_axes):
    """TrainState(params, mu, nu, step) axes from a params axes tree."""
    from ..optim.adamw import TrainState

    return TrainState(params=params_axes, mu=params_axes, nu=params_axes, step=())


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_cell(arch: ArchSpec, shape_name: str, shp: dict, cfg: LM.LMConfig) -> Cell:
    S, B = shp["seq"], shp["batch"]
    opt = AdamWConfig()
    kind = shp["kind"]
    n_tok = B * S
    attn_block = (min(cfg.attn_chunk // 2, max(S, 8)),
                  min(cfg.attn_chunk, max(S, 8)), cfg.d_head)

    if kind == "lm_train":
        step = make_train_step(LM.loss_fn, cfg, opt)
        params_s = jax.eval_shape(lambda: LM.init_params(cfg, jax.random.PRNGKey(0)))
        state_s = jax.eval_shape(lambda: opt_init_from(params_s))
        batch_s = ({"tokens": _sds((B, S), i32), "targets": _sds((B, S), i32)},)
        p_axes = _axes_like(params_s, _lm_param_axes)
        batch_axes = ({"tokens": ("batch", "seq"), "targets": ("batch", "seq")},)
        # tokens fully sharded: batch over (data, model), sequence over pod —
        # the remat stash is structurally 512-way sharded (DESIGN.md §5)
        train_rules = {"batch": ("data", "model"), "seq": ("pod",)}

        def init_state(key):
            return opt_init(LM.init_params(cfg, key))

        def make_batch(key):
            t = jax.random.randint(key, (B, S), 0, cfg.vocab, dtype=i32)
            return ({"tokens": t, "targets": jnp.roll(t, -1, axis=1)},)

        return Cell(
            arch=arch.name, shape=shape_name, kind=kind, step_fn=step,
            state_shape=state_s, batch_shape=batch_s,
            state_axes=_state_axes(p_axes), batch_axes=batch_axes,
            rules=train_rules, init_state=init_state, make_batch=make_batch,
            model_flops=LM.model_flops(cfg, n_tok, train=True),
            donate=(0,), attn_block=attn_block,
        )

    params_s = jax.eval_shape(lambda: LM.init_params(cfg, jax.random.PRNGKey(0)))
    p_axes = _axes_like(params_s, _lm_param_axes)
    serve_rules = {"fsdp": ()}  # serving: TP only, no per-layer weight gather

    if kind == "lm_prefill":
        def step(params, tokens):
            return LM.prefill(params, tokens, cfg, max_seq=S)

        batch_s = (_sds((B, S), i32),)
        batch_axes = (("batch", None),)

        def init_state(key):
            return LM.init_params(cfg, key)

        def make_batch(key):
            return (jax.random.randint(key, (B, S), 0, cfg.vocab, dtype=i32),)

        W = min(S, cfg.sliding_window) if cfg.sliding_window else S
        cache_out_axes = {
            "k": (None, "batch", "kv_seq", None, None),
            "v": (None, "batch", "kv_seq", None, None),
            "pos": (None, "batch", "kv_seq"),
        }
        return Cell(
            arch=arch.name, shape=shape_name, kind=kind, step_fn=step,
            state_shape=params_s, batch_shape=batch_s, state_axes=p_axes,
            batch_axes=batch_axes, rules={**serve_rules, "kv_seq": ("model",)},
            init_state=init_state, make_batch=make_batch,
            model_flops=LM.model_flops(cfg, n_tok, train=False),
            out_axes=(("batch", None), cache_out_axes),
            attn_block=attn_block,
        )

    # decode: one token against a seq_len cache (rolling window under SWA)
    W = min(S, cfg.sliding_window) if cfg.sliding_window else S
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.d_head

    def step(params, cache, tokens, pos):
        return LM.decode_step(params, cache, tokens, pos, cfg)

    cache_s = {
        "k": _sds((L, B, W, KV, hd), cfg.dtype),
        "v": _sds((L, B, W, KV, hd), cfg.dtype),
        "pos": _sds((L, B, W), i32),
    }
    batch_s = (cache_s, _sds((B,), i32), _sds((B,), i32))
    cache_axes = {
        "k": (None, "batch", "kv_seq", None, None),
        "v": (None, "batch", "kv_seq", None, None),
        "pos": (None, "batch", "kv_seq"),
    }
    batch_axes = (cache_axes, ("batch",), ("batch",))
    rules = dict(serve_rules)
    rules["kv_seq"] = ("model",)
    if B == 1:  # long_500k: latency cell — spread the window over everything
        rules["batch"] = ()
        rules["kv_seq"] = ("pod", "data", "model")

    def init_state(key):
        return LM.init_params(cfg, key)

    def make_batch(key):
        cache = LM.init_cache(cfg, B, S)
        # pretend the cache is fully prefilled
        pos0 = jnp.broadcast_to(jnp.arange(W, dtype=i32), (L, B, W))
        cache["pos"] = pos0 + (S - W)
        toks = jax.random.randint(key, (B,), 0, cfg.vocab, dtype=i32)
        return (cache, toks, jnp.full((B,), S, i32))

    return Cell(
        arch=arch.name, shape=shape_name, kind=kind, step_fn=step,
        state_shape=params_s, batch_shape=batch_s, state_axes=p_axes,
        batch_axes=batch_axes, rules=rules, init_state=init_state,
        make_batch=make_batch,
        model_flops=LM.model_flops(cfg, B, train=False),
        donate=(1,),
    )


def opt_init_from(params_shapes):
    """eval_shape-compatible TrainState construction."""
    from ..optim.adamw import TrainState

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params_shapes)
    return TrainState(params=jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                          params_shapes),
                      mu=zeros, nu=zeros, step=jnp.int32(0))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

_GNN_FNS = {
    "gcn-cora": (G.gcn_init, G.gcn_loss),
    "schnet": (G.schnet_init, G.schnet_loss),
    "egnn": (G.egnn_init, G.egnn_loss),
    "dimenet": (G.dimenet_init, G.dimenet_loss),
}


def _pad512(n: int) -> int:
    """Explicit in_shardings need exact divisibility — pad counts to the
    512-chip lcm (padding is masked; the data pipeline pads identically)."""
    return -(-n // 512) * 512


def _gnn_batch_spec(arch: ArchSpec, shp: dict, cfg) -> tuple[dict, dict]:
    """(abstract batch, logical axes) for one GNN shape."""
    kind = shp["kind"]
    name = arch.name
    if kind == "gnn_full":
        V = _pad512(shp["n_nodes"])
        E = _pad512(shp["n_edges"] // 2)  # assigned counts are directed
        b: dict[str, Any] = {
            "edge_src": _sds((E,), i32), "edge_dst": _sds((E,), i32),
            "edge_mask": _sds((E,), f32), "node_mask": _sds((V,), f32),
        }
        a: dict[str, Any] = {"edge_src": ("edges",), "edge_dst": ("edges",),
                             "edge_mask": ("edges",), "node_mask": ("nodes",)}
        if name == "gcn-cora":
            b["feats"] = _sds((V, shp["d_feat"]), f32)
            b["labels"] = _sds((V,), i32)
            b["label_mask"] = _sds((V,), f32)
            a |= {"feats": ("nodes", None), "labels": ("nodes",),
                  "label_mask": ("nodes",)}
        else:
            b["species"] = _sds((V,), i32)
            b["positions"] = _sds((V, 3), f32)
            b["targets"] = _sds((1,), f32)
            a |= {"species": ("nodes",), "positions": ("nodes", None),
                  "targets": (None,)}
            if name == "dimenet":
                T = 2 * E
                b |= {"tri_kj": _sds((T,), i32), "tri_ji": _sds((T,), i32),
                      "tri_mask": _sds((T,), f32)}
                a |= {"tri_kj": ("edges",), "tri_ji": ("edges",),
                      "tri_mask": ("edges",)}
        return b, a
    if kind == "gnn_minibatch":
        seeds, fan = shp["batch_nodes"], shp["fanout"]
        n = seeds
        max_nodes, max_edges = seeds, 0
        for f in fan:
            n *= f
            max_edges += n
            max_nodes += n
        max_nodes = _pad512(max_nodes)
        max_edges = _pad512(max_edges)
        b = {
            "edge_src": _sds((max_edges,), i32), "edge_dst": _sds((max_edges,), i32),
            "edge_mask": _sds((max_edges,), f32), "node_mask": _sds((max_nodes,), f32),
        }
        a = {"edge_src": ("edges",), "edge_dst": ("edges",),
             "edge_mask": ("edges",), "node_mask": ("nodes",)}
        if name == "gcn-cora":
            b |= {"feats": _sds((max_nodes, shp["d_feat"]), f32),
                  "labels": _sds((max_nodes,), i32),
                  "label_mask": _sds((max_nodes,), f32)}
            a |= {"feats": ("nodes", None), "labels": ("nodes",),
                  "label_mask": ("nodes",)}
        else:
            b |= {"species": _sds((max_nodes,), i32),
                  "positions": _sds((max_nodes, 3), f32),
                  "targets": _sds((1,), f32)}
            a |= {"species": ("nodes",), "positions": ("nodes", None),
                  "targets": (None,)}
            if name == "dimenet":
                T = 2 * max_edges
                b |= {"tri_kj": _sds((T,), i32), "tri_ji": _sds((T,), i32),
                      "tri_mask": _sds((T,), f32)}
                a |= {"tri_kj": ("edges",), "tri_ji": ("edges",),
                      "tri_mask": ("edges",)}
        return b, a
    # molecule: batched small graphs, flattened with graph_idx
    Bm, N, Em = shp["batch"], shp["n_nodes"], shp["n_edges"]
    V, E = _pad512(Bm * N), _pad512(Bm * Em)
    b = {
        "edge_src": _sds((E,), i32), "edge_dst": _sds((E,), i32),
        "edge_mask": _sds((E,), f32), "node_mask": _sds((V,), f32),
        "graph_idx": _sds((V,), i32), "n_graphs": Bm,
    }
    a = {"edge_src": ("edges",), "edge_dst": ("edges",),
         "edge_mask": ("edges",), "node_mask": ("nodes",),
         "graph_idx": ("nodes",), "n_graphs": None}
    if name == "gcn-cora":
        b |= {"feats": _sds((V, cfg.d_feat), f32), "labels": _sds((Bm,), f32)}
        a |= {"feats": ("nodes", None), "labels": (None,)}
    else:
        b |= {"species": _sds((V,), i32), "positions": _sds((V, 3), f32),
              "targets": _sds((Bm,), f32)}
        a |= {"species": ("nodes",), "positions": ("nodes", None),
              "targets": (None,)}
        if name == "dimenet":
            T = 4 * E
            b |= {"tri_kj": _sds((T,), i32), "tri_ji": _sds((T,), i32),
                  "tri_mask": _sds((T,), f32)}
            a |= {"tri_kj": ("edges",), "tri_ji": ("edges",), "tri_mask": ("edges",)}
    return b, a


def _gnn_flops(arch: ArchSpec, shp: dict, cfg) -> float:
    """Analytic per-step training FLOPs (fwd+bwd ≈ 3× fwd matmuls)."""
    kind = shp["kind"]
    if kind == "gnn_full":
        V, E = shp["n_nodes"], shp["n_edges"] // 2
    elif kind == "gnn_minibatch":
        seeds, fan = shp["batch_nodes"], shp["fanout"]
        n, E, V = seeds, 0, seeds
        for f in fan:
            n *= f
            E += n
            V += n
    else:
        V = shp["batch"] * shp["n_nodes"]
        E = shp["batch"] * shp["n_edges"]
    name = arch.name
    if name == "gcn-cora":
        d_in = shp.get("d_feat", 16)
        fwd = 2 * V * d_in * cfg.d_hidden + 2 * V * cfg.d_hidden * cfg.n_classes + 4 * E * cfg.d_hidden
    elif name == "schnet":
        d = cfg.d_hidden
        fwd = cfg.n_interactions * (2 * E * cfg.n_rbf * d + 2 * E * d * d + 4 * V * d * d) + 2 * E * cfg.n_rbf
    elif name == "egnn":
        d = cfg.d_hidden
        fwd = cfg.n_layers * (2 * E * (2 * d + 1) * d + 2 * E * d * d + 4 * V * d * d)
    else:  # dimenet
        d = cfg.d_hidden
        T = (4 if kind == "gnn_molecule" else 2) * E
        fwd = cfg.n_blocks * (
            2 * E * d * d  # w_src
            + T * cfg.n_bilinear * d * d * 2  # bilinear einsum
            + 4 * E * d * d  # post mlp
        ) + 2 * E * cfg.n_radial * d
    return 3.0 * fwd


def _gnn_cell(arch: ArchSpec, shape_name: str, shp: dict, cfg) -> Cell:
    init_fn, loss = _GNN_FNS[arch.name]
    opt = AdamWConfig()
    kind = shp["kind"]

    if arch.name == "gcn-cora":
        # first-layer width is a dataset property: follow the shape's d_feat
        d_feat = shp.get("d_feat", cfg.d_feat)
        if kind == "gnn_molecule":
            d_feat = cfg.d_feat
        cfg = dataclasses.replace(cfg, d_feat=d_feat)

    if arch.name == "gcn-cora" and kind == "gnn_molecule":
        # graph-level regression head over pooled node outputs
        def loss(params, batch, cfg):  # noqa: F811
            out = G.gcn_forward(params, batch["feats"], batch["edge_src"],
                                batch["edge_dst"], batch["feats"].shape[0], cfg,
                                batch.get("edge_mask"))
            if "node_mask" in batch:
                out = out * batch["node_mask"][:, None]
            pooled = jax.ops.segment_sum(out, batch["graph_idx"],
                                         num_segments=batch["n_graphs"])
            pred = jnp.mean(pooled, axis=-1)
            return jnp.mean(jnp.square(pred - batch["labels"])), {}

    step = make_train_step(loss, cfg, opt)
    params_s = jax.eval_shape(lambda: init_fn(cfg, jax.random.PRNGKey(0)))
    state_s = jax.eval_shape(lambda: opt_init_from(params_s))
    p_axes = _axes_like(params_s, _replicated_axes)
    batch, axes = _gnn_batch_spec(arch, shp, cfg)
    static = {k: v for k, v in batch.items() if not hasattr(v, "shape")}
    batch_arrs = {k: v for k, v in batch.items() if hasattr(v, "shape")}
    arr_axes = {k: axes[k] for k in batch_arrs}

    def step_wrapped(state, b):
        return step(state, {**b, **static})

    def init_state(key):
        return opt_init(init_fn(cfg, key))

    def make_batch(key):
        ks = jax.random.split(key, 8)
        out = {}
        for i, (k, sds) in enumerate(sorted(batch_arrs.items())):
            if sds.dtype == i32:
                n_nodes = batch_arrs.get("node_mask", batch_arrs["edge_src"]).shape[0]
                hi = {"edge_src": n_nodes, "edge_dst": n_nodes,
                      "species": 10, "labels": 4,
                      "graph_idx": static.get("n_graphs", 1)}.get(k, 4)
                if k.startswith("tri_"):
                    hi = batch_arrs["edge_src"].shape[0]
                out[k] = jax.random.randint(ks[i % 8], sds.shape, 0, max(hi, 1),
                                            dtype=i32)
            else:
                out[k] = jax.random.normal(ks[i % 8], sds.shape, dtype=sds.dtype)
        for k in ("edge_mask", "node_mask", "label_mask", "tri_mask"):
            if k in out:
                out[k] = jnp.ones_like(out[k])
        return (out,)

    return Cell(
        arch=arch.name, shape=shape_name, kind=kind, step_fn=step_wrapped,
        state_shape=state_s, batch_shape=(batch_arrs,),
        state_axes=_state_axes(p_axes), batch_axes=(arr_axes,),
        rules={}, init_state=init_state, make_batch=make_batch,
        model_flops=_gnn_flops(arch, shp, cfg), donate=(0,),
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _rec_cell(arch: ArchSpec, shape_name: str, shp: dict, cfg) -> Cell:
    opt = AdamWConfig()
    kind = shp["kind"]
    B = shp["batch"]
    params_s = jax.eval_shape(lambda: R.xdeepfm_init(cfg, jax.random.PRNGKey(0)))
    p_axes = _axes_like(params_s, _rec_param_axes)
    vocabs = cfg.vocabs()

    def rand_ids(key, batch):
        cols = [
            jax.random.randint(jax.random.fold_in(key, f), (batch, 1), 0, v, dtype=i32)
            for f, v in enumerate(vocabs)
        ]
        return jnp.concatenate(cols, axis=1)

    # analytic flops: CIN dominates
    m, D = cfg.n_fields, cfg.embed_dim
    cin_f = 0
    h_prev = m
    for h in cfg.cin_layers:
        cin_f += 2 * B * h_prev * m * D + 2 * B * h_prev * m * h * D
        h_prev = h
    mlp_f = 0
    d_in = m * D
    for d_out in cfg.mlp_dims:
        mlp_f += 2 * B * d_in * d_out
        d_in = d_out
    fwd = cin_f + mlp_f

    if kind == "rec_train":
        step = make_train_step(R.xdeepfm_loss, cfg, opt)
        state_s = jax.eval_shape(lambda: opt_init_from(params_s))
        batch_s = ({"field_ids": _sds((B, cfg.n_fields), i32),
                    "labels": _sds((B,), f32)},)
        batch_axes = ({"field_ids": ("batch", None), "labels": ("batch",)},)

        def init_state(key):
            return opt_init(R.xdeepfm_init(cfg, key))

        def make_batch(key):
            k1, k2 = jax.random.split(key)
            return ({"field_ids": rand_ids(k1, B),
                     "labels": (jax.random.uniform(k2, (B,)) < 0.3).astype(f32)},)

        return Cell(
            arch=arch.name, shape=shape_name, kind=kind, step_fn=step,
            state_shape=state_s, batch_shape=batch_s,
            state_axes=_state_axes(p_axes), batch_axes=batch_axes, rules={},
            init_state=init_state, make_batch=make_batch, model_flops=3.0 * fwd,
            donate=(0,),
        )

    if kind == "rec_serve":
        def step(params, ids):
            return R.xdeepfm_forward(params, ids, cfg)

        batch_s = (_sds((B, cfg.n_fields), i32),)
        batch_axes = (("batch", None),)

        def init_state(key):
            return R.xdeepfm_init(cfg, key)

        def make_batch(key):
            return (rand_ids(key, B),)

        return Cell(
            arch=arch.name, shape=shape_name, kind=kind, step_fn=step,
            state_shape=params_s, batch_shape=batch_s, state_axes=p_axes,
            batch_axes=batch_axes, rules={}, init_state=init_state,
            make_batch=make_batch, model_flops=fwd,
        )

    # retrieval: 1 query × n_candidates batched dot + top-k
    N = shp["n_candidates"]

    def step(params, ids, cand):
        return R.retrieval_scores(params, ids, cand, cfg, top_k=100)

    batch_s = (_sds((B, cfg.n_fields), i32), _sds((N, cfg.embed_dim), f32))
    batch_axes = (("batch", None), ("rows", None))
    rules = {"batch": ()} if B == 1 else {}

    def init_state(key):
        return R.xdeepfm_init(cfg, key)

    def make_batch(key):
        k1, k2 = jax.random.split(key)
        return (rand_ids(k1, B), jax.random.normal(k2, (N, cfg.embed_dim), f32))

    return Cell(
        arch=arch.name, shape=shape_name, kind=kind, step_fn=step,
        state_shape=params_s, batch_shape=batch_s, state_axes=p_axes,
        batch_axes=batch_axes, rules=rules, init_state=init_state,
        make_batch=make_batch, model_flops=2.0 * B * N * cfg.embed_dim,
    )


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

SMOKE_OVERRIDES = {
    "lm_train": dict(seq=64, batch=2),
    "lm_prefill": dict(seq=64, batch=2),
    "lm_decode": dict(seq=128, batch=2),
    "gnn_full": dict(n_nodes=128, n_edges=512, d_feat=32),
    "gnn_minibatch": dict(n_nodes=512, n_edges=2048, batch_nodes=8,
                          fanout=(3, 2), d_feat=32),
    "gnn_molecule": dict(batch=4, n_nodes=8, n_edges=12),
    "rec_train": dict(batch=16),
    "rec_serve": dict(batch=16),
    "rec_retrieval": dict(batch=1, n_candidates=512),
}


def build_cell(arch_name: str, shape_name: str, smoke: bool = False) -> Cell:
    arch = get_arch(arch_name)
    if shape_name in arch.skips:
        raise ValueError(
            f"{arch_name} × {shape_name} is a documented skip: {arch.skips[shape_name]}"
        )
    shp = dict(arch.shapes[shape_name])
    cfg = arch.smoke_config if smoke else arch.config
    if smoke:
        shp.update({k: v for k, v in SMOKE_OVERRIDES[shp["kind"]].items() if k in shp
                    or k in ("seq", "batch", "n_nodes", "n_edges", "d_feat",
                             "batch_nodes", "fanout", "n_candidates")})
        if arch.family == "gnn" and arch.name == "gcn-cora":
            shp["d_feat"] = cfg.d_feat
    if arch.family == "lm":
        return _lm_cell(arch, shape_name, shp, cfg)
    if arch.family == "gnn":
        return _gnn_cell(arch, shape_name, shp, cfg)
    return _rec_cell(arch, shape_name, shp, cfg)
