"""Partitioning driver: the paper's system as a CLI.

  python -m repro.launch.partition --graph rmat:16 --k 32 --partitioner s5p
  python -m repro.launch.partition --graph community:4000 --k 8 --compare

Out-of-core (mmap-paged edge shards; see ``repro.streaming.oocstream``):

  # convert any synthetic spec to a shard directory
  python -m repro.launch.partition --graph rmat:18 --write-shards /data/g18 \
      --shard-edges 1048576
  # partition straight from disk shards — edges page in chunk by chunk
  python -m repro.launch.partition --graph file:/data/g18/manifest.json \
      --k 32 --partitioner hdrf --ordering windowed

Parallel ingest (S sharded sub-streams per pass, carries merged every
--super-chunk chunks; see ``repro.streaming.parallel``):

  python -m repro.launch.partition --graph rmat:17 --k 8 \
      --partitioner hdrf --num-streams 8 --super-chunk 8
  # quality-neutral lanes: pin each hub's edges to one lane and let the
  # merge cadence adapt to carry contention
  python -m repro.launch.partition --graph rmat:17 --k 8 \
      --partitioner hdrf --num-streams 8 --shard-mode hub --super-chunk auto

Memory-budget hybrid (resident high-degree core + streamed tail; see
``repro.hybrid``).  ``--hybrid`` alone sizes the budget from available
host memory (``--budget-fraction`` of it); ``--host-budget`` pins it:

  python -m repro.launch.partition --graph rmat:18 --k 32 --hybrid
  python -m repro.launch.partition --graph rmat:18 --k 32 --host-budget 2G

Incremental re-partitioning (warm-start replay of only the new edges; see
``repro.incremental``):

  # cold run, persist the carry bundle
  python -m repro.launch.partition --graph community:4000 --k 8 \
      --partitioner s5p --save-carry /data/carry
  # absorb an insertion batch against the saved carry (drift-triggered
  # refinement past --drift-threshold)
  python -m repro.launch.partition --graph community:4000 --k 8 \
      --partitioner s5p --resume-carry /data/carry --delta rmat:10
  # delete edges against the saved carry: the oldest 10 %, a seeded
  # random 5 %, or the most recent 2000 (exact counted retraction for
  # greedy/hdrf/grid; tombstoned + drift-refined for s5p)
  python -m repro.launch.partition --graph community:4000 --k 8 \
      --partitioner s5p --resume-carry /data/carry --delete first:0.1
  python -m repro.launch.partition --graph community:4000 --k 8 \
      --partitioner hdrf --resume-carry /data/carry --delete frac:0.05

Sliding-window streaming (track the last W edges continuously; see
``repro.streaming.window`` + ``repro.incremental.s5p_sliding_window``):

  python -m repro.launch.partition --graph rmat:14 --k 8 \
      --partitioner s5p --window-edges 65536 --window-step 8192
  # out-of-core flavor: grow the shard directory in place, then resume —
  # the delta is everything past the carry's recorded stream position
  python -m repro.launch.partition --graph rmat:12 --write-shards /data/g \
      --shard-edges 65536
  python -m repro.launch.partition --graph file:/data/g/manifest.json \
      --k 8 --partitioner hdrf --save-carry /data/carry
  python -m repro.launch.partition --graph rmat:10 --write-shards /data/g \
      --append
  python -m repro.launch.partition --graph file:/data/g/manifest.json \
      --k 8 --partitioner hdrf --resume-carry /data/carry
"""

from __future__ import annotations

import argparse
import inspect
import time

import numpy as np

from ..core import replication_factor, load_balance, gas_comm_bytes
from ..core.baselines import PARTITIONERS
from ..graphs import rmat_graph, powerlaw_graph, toy_graph_fig3
from ..graphs.generators import community_graph


def load_graph(spec: str, seed: int = 0):
    kind, _, arg = spec.partition(":")
    if kind == "rmat":
        return rmat_graph(int(arg or 14), edge_factor=8, seed=seed)
    if kind == "powerlaw":
        return powerlaw_graph(int(arg or 10000), seed=seed)
    if kind == "community":
        return community_graph(int(arg or 4000), seed=seed)
    if kind == "toy":
        return toy_graph_fig3()
    if kind == "file":
        raise ValueError("file: specs are opened by run(); use the CLI or "
                         "open_sharded_stream() directly")
    raise ValueError(f"unknown graph spec {spec!r}")


def open_sharded_stream(manifest: str, *, chunk_size: int = 1 << 16,
                        ordering: str = "natural", seed: int = 0,
                        window: int = 4096):
    """Open a ``file:<manifest>`` spec as a mmap-paged ShardedEdgeStream."""
    from ..streaming import ShardedEdgeStream

    return ShardedEdgeStream(manifest, chunk_size=chunk_size,
                             ordering=ordering, seed=seed, window=window)


def write_shards_cli(graph: str, out_dir: str, shard_edges: int,
                     seed: int = 0, append: bool = False) -> str:
    """``--write-shards`` converter: synthetic spec → shard directory.

    With ``append=True`` the spec's edges grow an existing shard directory
    in place (same chunk layout as a one-shot write of the concatenation —
    see :func:`repro.streaming.append_shards`).
    """
    from ..streaming import append_shards, write_shards

    src, dst, n = load_graph(graph, seed)
    t0 = time.time()
    if append:
        # append keeps the manifest's own shard size; --shard-edges is
        # a write-time knob only
        mpath = append_shards(out_dir, src, dst)
        print(f"appended {len(src)} edges ({n} vertices) to {mpath}  "
              f"[{time.time() - t0:.1f}s]")
    else:
        mpath = write_shards(out_dir, src, dst, shard_edges=shard_edges,
                             n_vertices=n)
        print(f"wrote {len(src)} edges ({n} vertices) as shards of "
              f"{shard_edges} to {mpath}  [{time.time() - t0:.1f}s]")
    return str(mpath)


_BYTE_SUFFIXES = {"K": 1 << 10, "M": 1 << 20, "G": 1 << 30, "T": 1 << 40}


def parse_bytes(spec: str) -> int:
    """``--host-budget`` spec → bytes: plain int, or ``512M`` / ``2G`` /
    ``64KB`` (binary suffixes, case-insensitive, optional trailing B)."""
    s = str(spec).strip().upper()
    if s.endswith("B") and len(s) > 1 and not s[:-1].isdigit():
        s = s[:-1]
    mult = 1
    if s and s[-1] in _BYTE_SUFFIXES:
        mult = _BYTE_SUFFIXES[s[-1]]
        s = s[:-1]
    try:
        value = int(s)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected bytes like 1048576, 512M or 2G, got {spec!r}")
    if value < 0:
        raise argparse.ArgumentTypeError(f"byte budget must be >= 0, got {spec!r}")
    return value * mult


def _parse_meminfo_available(text: str) -> int | None:
    """``/proc/meminfo`` text → available bytes (``MemAvailable`` line,
    falling back to ``MemFree``), or None when neither parses."""
    free = None
    for line in text.splitlines():
        key, _, rest = line.partition(":")
        key = key.strip()
        if key not in ("MemAvailable", "MemFree"):
            continue
        fields = rest.split()
        if not fields or not fields[0].isdigit():
            continue
        value = int(fields[0])
        unit = fields[1].upper() if len(fields) > 1 else "KB"
        mult = {"B": 1, "KB": 1 << 10, "MB": 1 << 20, "GB": 1 << 30}.get(unit)
        if mult is None:
            continue
        if key == "MemAvailable":
            return value * mult
        free = value * mult
    return free


def detect_available_memory() -> int | None:
    """Available host memory in bytes, or None when undetectable.

    ``/proc/meminfo``'s MemAvailable first (counts reclaimable cache, the
    honest answer on Linux), then the portable
    ``os.sysconf(SC_AVPHYS_PAGES) * SC_PAGE_SIZE``.  No new deps.
    """
    import os

    try:
        with open("/proc/meminfo") as fh:
            avail = _parse_meminfo_available(fh.read())
        if avail is not None:
            return avail
    except OSError:
        pass
    try:
        pages = os.sysconf("SC_AVPHYS_PAGES")
        page_size = os.sysconf("SC_PAGE_SIZE")
    except (ValueError, OSError, AttributeError):
        return None
    if pages <= 0 or page_size <= 0:
        return None
    return int(pages) * int(page_size)


def auto_host_budget(fraction: float = 0.5) -> int:
    """Size ``--host-budget`` from available memory (``--hybrid`` with no
    explicit budget): ``fraction`` of what the host reports as available."""
    if not 0 < fraction <= 1:
        raise ValueError(
            f"budget_fraction must be in (0, 1], got {fraction}")
    avail = detect_available_memory()
    if avail is None:
        raise RuntimeError(
            "could not detect available host memory (/proc/meminfo and "
            "os.sysconf both unavailable); pass --host-budget explicitly")
    return int(avail * fraction)


def _parse_delete(spec: str, n_edges: int, seed: int) -> np.ndarray:
    """``--delete`` spec → arrival indices.

    ``first:X`` / ``last:X`` — the oldest / most recent X edges (a count,
    or a fraction when X < 1); ``frac:F`` — a seeded random fraction.
    """
    kind, _, arg = spec.partition(":")
    try:
        x = float(arg)
    except ValueError:
        raise ValueError(f"--delete {spec!r}: expected a number after ':'")
    if kind in ("first", "last"):
        count = int(round(x * n_edges)) if 0 < x < 1 else int(x)
        count = max(0, min(count, n_edges))
        return (np.arange(count, dtype=np.int64) if kind == "first"
                else np.arange(n_edges - count, n_edges, dtype=np.int64))
    if kind == "frac":
        if not 0 <= x <= 1:
            raise ValueError(f"--delete frac: needs a fraction in [0, 1]")
        rng = np.random.default_rng(seed + 0x5EED)
        count = int(round(x * n_edges))
        return np.sort(rng.choice(n_edges, size=count, replace=False)
                       ).astype(np.int64)
    raise ValueError(
        f"unknown --delete spec {spec!r}; one of first:X | last:X | frac:F")


def run(graph: str, k: int, partitioner: str = "s5p", seed: int = 0,
        compare: bool = False, *, chunk_size: int = 1 << 16,
        ordering: str = "natural", window: int = 4096,
        num_streams: int = 1, super_chunk: int | str = 8,
        shard: str = "range",
        save_carry: str | None = None, resume_carry: str | None = None,
        delta: str | None = None, delete: str | None = None,
        drift_threshold: float | None = None,
        refine_rounds: int | None = None,
        xi_refresh_threshold: float | None = None,
        window_edges: int | None = None, window_step: int | None = None,
        resize_k: int | None = None, host_budget: int | None = None,
        hybrid: bool = False, budget_fraction: float = 0.5):
    for pname, v in (("k", k), ("chunk_size", chunk_size), ("window", window),
                     ("num_streams", num_streams)):
        if v < 1:
            raise ValueError(f"{pname} must be >= 1, got {v}")
    if isinstance(super_chunk, str):
        if super_chunk != "auto":
            raise ValueError(
                f"super_chunk must be >= 1 or 'auto', got {super_chunk!r}")
    elif super_chunk < 1:
        raise ValueError(f"super_chunk must be >= 1, got {super_chunk}")
    if shard not in ("range", "rr", "round-robin", "hub"):
        raise ValueError(f"shard must be one of range | rr | round-robin | "
                         f"hub, got {shard!r}")
    if hybrid and host_budget is None:
        host_budget = auto_host_budget(budget_fraction)
        print(f"[hybrid] auto-sized --host-budget: {host_budget} bytes "
              f"({budget_fraction:.0%} of available host memory)")
    if host_budget is not None:
        if partitioner != "s5p":
            raise ValueError("--host-budget drives the s5p hybrid pipeline; "
                             "use --partitioner s5p")
        if (compare or window_edges is not None or resize_k is not None
                or resume_carry or delta or delete):
            raise ValueError("--host-budget runs a single hybrid partition; "
                             "drop --compare/--window-edges/--resize-k/"
                             "carry-resume flags (--save-carry combines)")
    if resize_k is not None:
        if compare or window_edges is not None or resume_carry or delta or delete:
            raise ValueError("--resize-k runs a single cold partition "
                             "followed by an elastic reshard; drop "
                             "--compare/--window-edges/carry flags")
    stream = None
    if graph.startswith("file:"):
        stream = open_sharded_stream(graph[5:], chunk_size=chunk_size,
                                     ordering=ordering, seed=seed,
                                     window=window)
        n = stream.n_vertices
        # metrics are per-edge aggregates — the one deliberate O(E)
        # materialization in this driver (the partition scans themselves
        # page from disk through the stream)
        src, dst = stream.arrival_arrays()
    else:
        src, dst, n = load_graph(graph, seed)
    if num_streams > 1:
        # a lane count or super-chunk longer than the stream used to
        # degenerate silently (clamped lanes / a single merge); reject it
        # like the other stream args instead
        n_chunks = max(-(-len(src) // chunk_size), 1)
        if num_streams > n_chunks:
            raise ValueError(
                f"num_streams must be <= the stream's chunk count "
                f"({n_chunks} chunks of {chunk_size}), got {num_streams}")
        rounds = -(-n_chunks // num_streams)
        if not isinstance(super_chunk, str) and super_chunk > rounds:
            raise ValueError(
                f"super_chunk must be <= the {rounds} chunks each of the "
                f"{num_streams} sub-streams ingests (else it degenerates "
                f"to a single merge), got {super_chunk}")
    if window_edges is not None:
        if compare:
            raise ValueError("--window-edges runs a single partitioner, "
                             "not --compare")
        if num_streams > 1:
            raise ValueError("--window-edges is sequential (the per-step "
                             "delta/retract batches are not sharded); drop "
                             "--num-streams")
        for flag, val in (("--save-carry", save_carry),
                          ("--resume-carry", resume_carry),
                          ("--delta", delta), ("--delete", delete)):
            if val:
                raise ValueError(
                    f"{flag} does not combine with --window-edges (the "
                    "window loop manages its own bundle in memory)")
        try:
            return _run_window_cli(
                src, dst, n, k, partitioner, seed, window_edges, window_step,
                stream=stream, chunk_size=chunk_size, ordering=ordering,
                drift_threshold=drift_threshold,
                refine_rounds=refine_rounds,
                xi_refresh_threshold=xi_refresh_threshold)
        finally:
            if stream is not None:
                stream.close()
    if host_budget is not None:
        try:
            return _run_hybrid_cli(
                src, dst, n, k, seed, host_budget, stream=stream,
                chunk_size=chunk_size, ordering=ordering,
                num_streams=num_streams, super_chunk=super_chunk,
                shard=shard, refine_rounds=refine_rounds,
                save_carry=save_carry)
        finally:
            if stream is not None:
                stream.close()
    if resize_k is not None:
        try:
            return _run_resize_cli(
                src, dst, n, k, resize_k, partitioner, seed,
                chunk_size=chunk_size, drift_threshold=drift_threshold,
                refine_rounds=refine_rounds,
                xi_refresh_threshold=xi_refresh_threshold)
        finally:
            if stream is not None:
                stream.close()
    if save_carry or resume_carry or delta or delete:
        try:
            return _run_incremental_cli(
                graph, src, dst, n, k, partitioner, seed, compare,
                stream=stream, chunk_size=chunk_size, ordering=ordering,
                num_streams=num_streams, super_chunk=super_chunk,
                shard=shard,
                save_carry=save_carry, resume_carry=resume_carry,
                delta=delta, delete=delete,
                drift_threshold=drift_threshold,
                refine_rounds=refine_rounds,
                xi_refresh_threshold=xi_refresh_threshold)
        finally:
            if stream is not None:
                stream.close()
    names = list(PARTITIONERS) if compare else [partitioner]
    rows = []
    for name in names:
        fn = PARTITIONERS[name]
        kw = {}
        params = inspect.signature(fn).parameters
        takes_stream = "stream" in params
        if stream is not None and takes_stream:
            kw["stream"] = stream
        elif "chunk_size" in params:
            kw["chunk_size"] = chunk_size
        if num_streams > 1 and "num_streams" in params:
            kw["num_streams"] = num_streams
            kw["super_chunk"] = super_chunk
            if "shard" in params:
                kw["shard"] = shard
        t0 = time.time()
        parts = fn(src, dst, n, k, seed, **kw)
        dt = time.time() - t0
        rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
        bal = load_balance(parts, k=k)
        comm = gas_comm_bytes(src, dst, parts, n_vertices=n, k=k)
        rows.append((name, rf, bal, comm, dt))
        # partitioners without a stream= parameter run on the materialized
        # arrays in natural arrival order — flag them so a file:-graph
        # comparison table is honest about which rows paged from disk (and
        # which saw the requested --ordering)
        note = "" if stream is None or takes_stream else "  [in-memory, natural]"
        print(f"{name:10s} RF={rf:7.3f} balance={bal:5.2f} "
              f"gas_comm={comm/1e6:8.2f} MB/iter  {dt:6.1f}s{note}")
    if stream is not None:
        peak = stream.budget.peak_bytes
        print(f"[oocstream] peak stream-host bytes (stream-backed rows): "
              f"{peak} ({peak / max(8 * len(src), 1):.1%} of the edge list)")
        stream.close()
    return rows


def _s5p_cfg(k, seed, chunk_size, ordering, num_streams, super_chunk,
             drift_threshold, refine_rounds, xi_refresh_threshold,
             shard="range"):
    import dataclasses

    from ..core import S5PConfig

    cfg = S5PConfig(k=k, seed=seed, chunk_size=chunk_size, ordering=ordering,
                    num_streams=num_streams, super_chunk=super_chunk,
                    shard=shard)
    overrides = {}
    if drift_threshold is not None:
        overrides["drift_rf_threshold"] = drift_threshold
    if refine_rounds is not None:
        overrides["refine_rounds"] = refine_rounds
    if xi_refresh_threshold is not None:
        overrides["xi_refresh_threshold"] = xi_refresh_threshold
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def _run_window_cli(src, dst, n, k, partitioner, seed, window_edges,
                    window_step, *, stream, chunk_size, ordering,
                    drift_threshold, refine_rounds, xi_refresh_threshold):
    """``--window-edges`` flow: continuous sliding-window partitioning."""
    from ..incremental import s5p_sliding_window

    if partitioner != "s5p":
        raise ValueError("--window-edges drives the s5p pipeline; use "
                         "--partitioner s5p (scan partitioners delete via "
                         "--resume-carry --delete)")
    if ordering != "natural":
        raise ValueError("sliding windows are defined over arrival order; "
                         "drop --ordering")
    cfg = _s5p_cfg(k, seed, chunk_size, ordering, 1, 8, drift_threshold,
                   refine_rounds, xi_refresh_threshold)
    t0 = time.time()
    history, _ = s5p_sliding_window(src, dst, n, cfg, window_edges,
                                    step_edges=window_step, stream=stream)
    dt = time.time() - t0
    for st_ in history:
        flags = "".join((
            "F" if st_.filling else "-",
            "R" if st_.refined else "-",
            "B" if st_.rolled_back else "-",
            "C" if st_.n_compacted else "-",
            "X" if st_.needs_cold_restart else "-",
        ))
        print(f"step {st_.step:4d} window=[{st_.lo},{st_.hi}) "
              f"RF={st_.rf:7.3f} balance={st_.balance:5.2f} "
              f"+{st_.n_inserted}/-{st_.n_retracted} churn={st_.churn:.2f} "
              f"xi_drift={st_.xi_drift:.2f} [{flags}]")
    print(f"[window] {len(history)} steps, {dt:.1f}s total "
          f"({dt / max(len(history), 1):.2f}s/step)")
    return history


def _run_hybrid_cli(src, dst, n, k, seed, host_budget, *, stream,
                    chunk_size, ordering, num_streams, super_chunk,
                    shard, refine_rounds, save_carry):
    """``--host-budget`` flow: memory-budget hybrid partition (s5p).

    Budget 0 degrades to the pure-streaming pipeline; a budget covering
    the edge list runs fully in-memory; anything between holds the
    high-degree core resident (``repro.hybrid``).  ``--save-carry``
    persists the hybrid warm bundle exactly like a cold run's.
    """
    import dataclasses

    from ..hybrid import run_hybrid

    cfg = _s5p_cfg(k, seed, chunk_size, ordering, num_streams, super_chunk,
                   None, refine_rounds, None, shard)
    cfg = dataclasses.replace(cfg, host_budget=int(host_budget))
    t0 = time.time()
    res = run_hybrid(stream if stream is not None else (src, dst, n), cfg)
    dt = time.time() - t0
    pct = res.peak_budget_bytes / max(host_budget, 1)
    print(f"{'hybrid':10s} RF={res.rf:7.3f} balance={res.balance:5.2f} "
          f"mode={res.mode} core={res.core_edges} "
          f"streamRF={res.rf_streaming:7.3f} "
          f"peak={res.peak_budget_bytes}B ({pct:.0%} of budget) "
          f"rounds={res.game_rounds}  {dt:6.1f}s")
    if save_carry:
        from ..incremental.driver import _prefix_crc
        from ..incremental import CarryStore, s5p_identity_config

        E = int(np.asarray(src).shape[0])
        store = CarryStore(save_carry)
        path = store.save(
            res.bundle, consumer="s5p", config=s5p_identity_config(cfg),
            stream_pos=E,
            extra_meta={"n_vertices": int(n),
                        "prefix_crc": _prefix_crc(src, dst, E)})
        print(f"[hybrid] carry→{path}")
    return res


def _run_resize_cli(src, dst, n, k, k_new, partitioner, seed, *,
                    chunk_size, drift_threshold, refine_rounds,
                    xi_refresh_threshold):
    """``--resize-k`` flow: cold partition at k, elastic reshard to k′.

    The operational shape this models: a cluster resize arrives while a
    partition is live, and instead of a cold re-partition at k′ (full
    stream replay + 100 % migration) the bundle is re-homed with bounded
    migration (``repro.elastic``).  Prints RF before/after and the
    migrated-edge fraction.
    """
    from ..elastic import reshard_bundle
    from ..incremental.pipeline import s5p_cold_bundle

    if partitioner != "s5p":
        raise ValueError("--resize-k reshards the s5p warm bundle; use "
                         "--partitioner s5p (scan carries reshard via "
                         "repro.elastic.reshard_scan_carry)")
    cfg = _s5p_cfg(k, seed, chunk_size, "natural", 1, 8, drift_threshold,
                   refine_rounds, xi_refresh_threshold)
    t0 = time.time()
    _, bundle = s5p_cold_bundle(src, dst, n, cfg)
    t_cold = time.time() - t0
    rf0 = float(bundle["rf_baseline"])
    t0 = time.time()
    _, _, res = reshard_bundle(bundle, cfg, k_new, src, dst)
    t_resize = time.time() - t0
    print(f"{partitioner:10s} k={k} RF={rf0:7.3f}  [{t_cold:.1f}s cold]")
    print(f"resize →k={k_new} RF={res.rf:7.3f} balance={res.balance:5.2f} "
          f"migrated={res.migrated_fraction:.1%} "
          f"({res.migrated_edges}/{res.n_live} edges, "
          f"{res.n_displaced} displaced, {res.moved_clusters} clusters "
          f"moved, {res.game_rounds} rounds)  [{t_resize:.1f}s]")
    return res


def _run_incremental_cli(graph, src, dst, n, k, partitioner, seed, compare,
                         *, stream, chunk_size, ordering, num_streams,
                         super_chunk, shard, save_carry, resume_carry, delta,
                         delete, drift_threshold, refine_rounds,
                         xi_refresh_threshold):
    """``--save-carry`` / ``--resume-carry`` / ``--delta`` / ``--delete``."""
    from ..incremental import cold_start, run_incremental

    if compare:
        raise ValueError("carry flows need a single --partitioner, "
                         "not --compare")
    if delta and not resume_carry:
        raise ValueError("--delta needs --resume-carry (an insertion batch "
                         "is replayed against a saved carry)")
    if delete and not resume_carry:
        raise ValueError("--delete needs --resume-carry (deletions retract "
                         "against a saved carry)")
    if ordering != "natural":
        raise ValueError(
            "incremental carries assume natural (insertion-order) streams; "
            f"a {ordering!r} reordering permutes the whole grown stream and "
            "has no stable prefix to resume from")
    if delta:
        dsrc, ddst, dn = load_graph(delta, seed + 1)
        src = np.concatenate([np.asarray(src, np.int32),
                              np.asarray(dsrc, np.int32)])
        dst = np.concatenate([np.asarray(dst, np.int32),
                              np.asarray(ddst, np.int32)])
        n = max(n, dn)
    cfg = _s5p_cfg(k, seed, chunk_size, ordering, num_streams, super_chunk,
                   drift_threshold, refine_rounds, xi_refresh_threshold,
                   shard)

    if resume_carry:
        delete_idx = _parse_delete(delete, len(src), seed) if delete else None
        t0 = time.time()
        res = run_incremental(
            resume_carry, partitioner, src, dst, n, k, seed=seed,
            chunk_size=chunk_size, s5p_config=cfg, delete=delete_idx,
            num_streams=num_streams, super_chunk=super_chunk, save=True,
            save_dir=save_carry)
        dt = time.time() - t0
        cold_note = (" NEEDS-COLD-RESTART"
                     if res.needs_cold_restart else "")
        print(f"{partitioner:10s} RF={res.rf:7.3f} balance={res.balance:5.2f} "
              f"delta={res.n_delta_edges} deleted={res.n_retracted} "
              f"replay={res.replay_fraction:.1%} "
              f"drift={res.rf_drift:+.3f} churn={res.churn:.2f} "
              f"refined={res.refined} rolled_back={res.rolled_back} "
              f"rounds={res.game_rounds}  {dt:6.1f}s{cold_note}")
        return res
    t0 = time.time()
    parts, path = cold_start(save_carry, partitioner, src, dst, n, k,
                             seed=seed, chunk_size=chunk_size,
                             s5p_config=cfg, stream=stream,
                             num_streams=num_streams,
                             super_chunk=super_chunk)
    dt = time.time() - t0
    rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
    bal = load_balance(parts, k=k)
    print(f"{partitioner:10s} RF={rf:7.3f} balance={bal:5.2f} "
          f"carry→{path}  {dt:6.1f}s")
    return [(partitioner, rf, bal, None, dt)]


def _positive_int(value: str) -> int:
    """argparse type: reject non-positive sizes at the CLI boundary with a
    clear message instead of a numpy traceback from deep inside a stream."""
    try:
        iv = int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected an integer, got {value!r}")
    if iv < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {iv}")
    return iv


def _super_chunk_arg(value: str):
    """argparse type for ``--super-chunk``: a positive chunk count, or
    ``auto`` for the adaptive cadence controller."""
    if value.strip().lower() == "auto":
        return "auto"
    try:
        return _positive_int(value)
    except argparse.ArgumentTypeError:
        raise argparse.ArgumentTypeError(
            f"expected a chunk count >= 1 or 'auto', got {value!r}")


def _fraction_arg(value: str) -> float:
    """argparse type for ``--budget-fraction``: a float in (0, 1]."""
    try:
        fv = float(value)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected a fraction, got {value!r}")
    if not 0 < fv <= 1:
        raise argparse.ArgumentTypeError(
            f"must be a fraction in (0, 1], got {value!r}")
    return fv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="community:4000",
                    help="rmat:S | powerlaw:N | community:N | toy | "
                         "file:<shard manifest.json>")
    ap.add_argument("--k", type=_positive_int, default=8)
    ap.add_argument("--partitioner", default="s5p", choices=list(PARTITIONERS))
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-size", type=_positive_int, default=1 << 16,
                    help="device-resident edges per chunk (also the "
                         "parallel-ingest sharding granularity)")
    ap.add_argument("--ordering", default="natural",
                    choices=("natural", "shuffled", "dst-sorted", "windowed"),
                    help="stream arrival order (file: graphs)")
    ap.add_argument("--window", type=_positive_int, default=4096,
                    help="windowed-ordering buffer (file: graphs)")
    ap.add_argument("--num-streams", type=_positive_int, default=1,
                    help="parallel-ingest sub-streams per pass (1 = "
                         "sequential, bit-identical)")
    ap.add_argument("--super-chunk", type=_super_chunk_arg, default=8,
                    help="chunks each sub-stream ingests between carry "
                         "merges, or 'auto' for the adaptive cadence "
                         "controller (merge every chunk while contested, "
                         "geometric backoff as the tables warm; state-only "
                         "passes fold isolated and merge once) — parallel "
                         "ingest only")
    ap.add_argument("--shard-mode", default="range",
                    choices=("range", "rr", "round-robin", "hub"),
                    help="how edges are dealt onto the --num-streams lanes: "
                         "contiguous chunk ranges (range), interleaved "
                         "chunks (rr), or hub-pinned edge routing (hub: an "
                         "online CMS degree sketch pins every hub's edges "
                         "to one rendezvous-hashed lane — the "
                         "quality-neutral mode on power-law graphs)")
    ap.add_argument("--write-shards", default=None, metavar="DIR",
                    help="convert --graph to edge shards in DIR and exit")
    ap.add_argument("--shard-edges", type=_positive_int, default=1 << 20,
                    help="edges per shard for --write-shards")
    ap.add_argument("--append", action="store_true",
                    help="with --write-shards: grow the existing shard "
                         "directory in place instead of writing fresh")
    ap.add_argument("--save-carry", default=None, metavar="DIR",
                    help="persist the partitioner's warm-start carry "
                         "bundle to DIR (greedy/hdrf/grid/s5p)")
    ap.add_argument("--resume-carry", default=None, metavar="DIR",
                    help="warm-start from the carry in DIR; the delta is "
                         "everything past its recorded stream position "
                         "(grow file: graphs via --write-shards --append) "
                         "plus any --delta batch")
    ap.add_argument("--delta", default=None, metavar="SPEC",
                    help="insertion batch (same specs as --graph) appended "
                         "to the stream before resuming")
    ap.add_argument("--delete", default=None, metavar="SPEC",
                    help="deletion batch against a resumed carry: first:X | "
                         "last:X (count, or fraction when X < 1) | frac:F "
                         "(seeded random fraction)")
    ap.add_argument("--window-edges", type=_positive_int, default=None,
                    help="sliding-window mode: continuously partition the "
                         "last W edges of the stream (s5p)")
    ap.add_argument("--window-step", type=_positive_int, default=None,
                    help="edges admitted per sliding-window step "
                         "(default: min(chunk-size, window-edges))")
    ap.add_argument("--drift-threshold", type=float, default=None,
                    help="relative RF drift that triggers game refinement "
                         "on resume (s5p; default from S5PConfig)")
    ap.add_argument("--refine-rounds", type=int, default=None,
                    help="refinement budget in Stackelberg rounds "
                         "(s5p; 0 disables)")
    ap.add_argument("--resize-k", type=_positive_int, default=None,
                    help="elastic resize: cold-partition at --k, then "
                         "reshard the warm bundle onto this partition "
                         "count with bounded migration (s5p)")
    ap.add_argument("--host-budget", type=parse_bytes, default=None,
                    metavar="BYTES",
                    help="memory-budget hybrid mode: host bytes spendable "
                         "on a resident high-degree core (accepts 512M / "
                         "2G suffixes; 0 = pure streaming; s5p only)")
    ap.add_argument("--hybrid", action="store_true",
                    help="memory-budget hybrid mode with the budget "
                         "auto-sized from available host memory "
                         "(--budget-fraction of /proc/meminfo "
                         "MemAvailable, falling back to os.sysconf); "
                         "--host-budget overrides")
    ap.add_argument("--budget-fraction", type=_fraction_arg, default=0.5,
                    help="fraction of detected available memory --hybrid "
                         "spends on the resident core (default 0.5)")
    ap.add_argument("--xi-refresh-threshold", type=float, default=None,
                    help="relative ξ/κ drift past which a warm chain "
                         "reports needs_cold_restart (s5p; default from "
                         "S5PConfig)")
    args = ap.parse_args()
    if args.append and not args.write_shards:
        ap.error("--append only makes sense with --write-shards DIR")
    if args.write_shards:
        write_shards_cli(args.graph, args.write_shards, args.shard_edges,
                         args.seed, append=args.append)
        return
    run(args.graph, args.k, args.partitioner, args.seed, args.compare,
        chunk_size=args.chunk_size, ordering=args.ordering,
        window=args.window, num_streams=args.num_streams,
        super_chunk=args.super_chunk, shard=args.shard_mode,
        save_carry=args.save_carry,
        resume_carry=args.resume_carry, delta=args.delta,
        delete=args.delete, drift_threshold=args.drift_threshold,
        refine_rounds=args.refine_rounds,
        xi_refresh_threshold=args.xi_refresh_threshold,
        window_edges=args.window_edges, window_step=args.window_step,
        resize_k=args.resize_k, host_budget=args.host_budget,
        hybrid=args.hybrid, budget_fraction=args.budget_fraction)


if __name__ == "__main__":
    main()
