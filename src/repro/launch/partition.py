"""Partitioning driver: the paper's system as a CLI.

  python -m repro.launch.partition --graph rmat:16 --k 32 --partitioner s5p
  python -m repro.launch.partition --graph community:4000 --k 8 --compare
"""

from __future__ import annotations

import argparse
import time

from ..core import replication_factor, load_balance, gas_comm_bytes
from ..core.baselines import PARTITIONERS
from ..graphs import rmat_graph, powerlaw_graph, toy_graph_fig3
from ..graphs.generators import community_graph


def load_graph(spec: str, seed: int = 0):
    kind, _, arg = spec.partition(":")
    if kind == "rmat":
        return rmat_graph(int(arg or 14), edge_factor=8, seed=seed)
    if kind == "powerlaw":
        return powerlaw_graph(int(arg or 10000), seed=seed)
    if kind == "community":
        return community_graph(int(arg or 4000), seed=seed)
    if kind == "toy":
        return toy_graph_fig3()
    raise ValueError(f"unknown graph spec {spec!r}")


def run(graph: str, k: int, partitioner: str = "s5p", seed: int = 0,
        compare: bool = False):
    src, dst, n = load_graph(graph, seed)
    names = list(PARTITIONERS) if compare else [partitioner]
    rows = []
    for name in names:
        t0 = time.time()
        parts = PARTITIONERS[name](src, dst, n, k, seed)
        dt = time.time() - t0
        rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
        bal = load_balance(parts, k=k)
        comm = gas_comm_bytes(src, dst, parts, n_vertices=n, k=k)
        rows.append((name, rf, bal, comm, dt))
        print(f"{name:10s} RF={rf:7.3f} balance={bal:5.2f} "
              f"gas_comm={comm/1e6:8.2f} MB/iter  {dt:6.1f}s")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="community:4000")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--partitioner", default="s5p", choices=list(PARTITIONERS))
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.graph, args.k, args.partitioner, args.seed, args.compare)


if __name__ == "__main__":
    main()
