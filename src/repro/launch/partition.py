"""Partitioning driver: the paper's system as a CLI.

  python -m repro.launch.partition --graph rmat:16 --k 32 --partitioner s5p
  python -m repro.launch.partition --graph community:4000 --k 8 --compare

Out-of-core (mmap-paged edge shards; see ``repro.streaming.oocstream``):

  # convert any synthetic spec to a shard directory
  python -m repro.launch.partition --graph rmat:18 --write-shards /data/g18 \
      --shard-edges 1048576
  # partition straight from disk shards — edges page in chunk by chunk
  python -m repro.launch.partition --graph file:/data/g18/manifest.json \
      --k 32 --partitioner hdrf --ordering windowed

Parallel ingest (S sharded sub-streams per pass, carries merged every
--super-chunk chunks; see ``repro.streaming.parallel``):

  python -m repro.launch.partition --graph rmat:17 --k 8 \
      --partitioner hdrf --num-streams 8 --super-chunk 8
"""

from __future__ import annotations

import argparse
import inspect
import time

from ..core import replication_factor, load_balance, gas_comm_bytes
from ..core.baselines import PARTITIONERS
from ..graphs import rmat_graph, powerlaw_graph, toy_graph_fig3
from ..graphs.generators import community_graph


def load_graph(spec: str, seed: int = 0):
    kind, _, arg = spec.partition(":")
    if kind == "rmat":
        return rmat_graph(int(arg or 14), edge_factor=8, seed=seed)
    if kind == "powerlaw":
        return powerlaw_graph(int(arg or 10000), seed=seed)
    if kind == "community":
        return community_graph(int(arg or 4000), seed=seed)
    if kind == "toy":
        return toy_graph_fig3()
    if kind == "file":
        raise ValueError("file: specs are opened by run(); use the CLI or "
                         "open_sharded_stream() directly")
    raise ValueError(f"unknown graph spec {spec!r}")


def open_sharded_stream(manifest: str, *, chunk_size: int = 1 << 16,
                        ordering: str = "natural", seed: int = 0,
                        window: int = 4096):
    """Open a ``file:<manifest>`` spec as a mmap-paged ShardedEdgeStream."""
    from ..streaming import ShardedEdgeStream

    return ShardedEdgeStream(manifest, chunk_size=chunk_size,
                             ordering=ordering, seed=seed, window=window)


def write_shards_cli(graph: str, out_dir: str, shard_edges: int,
                     seed: int = 0) -> str:
    """``--write-shards`` converter: synthetic spec → shard directory."""
    from ..streaming import write_shards

    src, dst, n = load_graph(graph, seed)
    t0 = time.time()
    mpath = write_shards(out_dir, src, dst, shard_edges=shard_edges,
                         n_vertices=n)
    print(f"wrote {len(src)} edges ({n} vertices) as shards of "
          f"{shard_edges} to {mpath}  [{time.time() - t0:.1f}s]")
    return str(mpath)


def run(graph: str, k: int, partitioner: str = "s5p", seed: int = 0,
        compare: bool = False, *, chunk_size: int = 1 << 16,
        ordering: str = "natural", window: int = 4096,
        num_streams: int = 1, super_chunk: int = 8):
    for pname, v in (("k", k), ("chunk_size", chunk_size), ("window", window),
                     ("num_streams", num_streams), ("super_chunk", super_chunk)):
        if v < 1:
            raise ValueError(f"{pname} must be >= 1, got {v}")
    stream = None
    if graph.startswith("file:"):
        stream = open_sharded_stream(graph[5:], chunk_size=chunk_size,
                                     ordering=ordering, seed=seed,
                                     window=window)
        n = stream.n_vertices
        # metrics are per-edge aggregates — the one deliberate O(E)
        # materialization in this driver (the partition scans themselves
        # page from disk through the stream)
        src, dst = stream.arrival_arrays()
    else:
        src, dst, n = load_graph(graph, seed)
    names = list(PARTITIONERS) if compare else [partitioner]
    rows = []
    for name in names:
        fn = PARTITIONERS[name]
        kw = {}
        params = inspect.signature(fn).parameters
        takes_stream = "stream" in params
        if stream is not None and takes_stream:
            kw["stream"] = stream
        elif "chunk_size" in params:
            kw["chunk_size"] = chunk_size
        if num_streams > 1 and "num_streams" in params:
            kw["num_streams"] = num_streams
            kw["super_chunk"] = super_chunk
        t0 = time.time()
        parts = fn(src, dst, n, k, seed, **kw)
        dt = time.time() - t0
        rf = replication_factor(src, dst, parts, n_vertices=n, k=k)
        bal = load_balance(parts, k=k)
        comm = gas_comm_bytes(src, dst, parts, n_vertices=n, k=k)
        rows.append((name, rf, bal, comm, dt))
        # partitioners without a stream= parameter run on the materialized
        # arrays in natural arrival order — flag them so a file:-graph
        # comparison table is honest about which rows paged from disk (and
        # which saw the requested --ordering)
        note = "" if stream is None or takes_stream else "  [in-memory, natural]"
        print(f"{name:10s} RF={rf:7.3f} balance={bal:5.2f} "
              f"gas_comm={comm/1e6:8.2f} MB/iter  {dt:6.1f}s{note}")
    if stream is not None:
        peak = stream.budget.peak_bytes
        print(f"[oocstream] peak stream-host bytes (stream-backed rows): "
              f"{peak} ({peak / max(8 * len(src), 1):.1%} of the edge list)")
        stream.close()
    return rows


def _positive_int(value: str) -> int:
    """argparse type: reject non-positive sizes at the CLI boundary with a
    clear message instead of a numpy traceback from deep inside a stream."""
    try:
        iv = int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected an integer, got {value!r}")
    if iv < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {iv}")
    return iv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="community:4000",
                    help="rmat:S | powerlaw:N | community:N | toy | "
                         "file:<shard manifest.json>")
    ap.add_argument("--k", type=_positive_int, default=8)
    ap.add_argument("--partitioner", default="s5p", choices=list(PARTITIONERS))
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-size", type=_positive_int, default=1 << 16,
                    help="device-resident edges per chunk (also the "
                         "parallel-ingest sharding granularity)")
    ap.add_argument("--ordering", default="natural",
                    choices=("natural", "shuffled", "dst-sorted", "windowed"),
                    help="stream arrival order (file: graphs)")
    ap.add_argument("--window", type=_positive_int, default=4096,
                    help="windowed-ordering buffer (file: graphs)")
    ap.add_argument("--num-streams", type=_positive_int, default=1,
                    help="parallel-ingest sub-streams per pass (1 = "
                         "sequential, bit-identical)")
    ap.add_argument("--super-chunk", type=_positive_int, default=8,
                    help="chunks each sub-stream ingests between carry "
                         "merges (parallel ingest only)")
    ap.add_argument("--write-shards", default=None, metavar="DIR",
                    help="convert --graph to edge shards in DIR and exit")
    ap.add_argument("--shard-edges", type=_positive_int, default=1 << 20,
                    help="edges per shard for --write-shards")
    args = ap.parse_args()
    if args.write_shards:
        write_shards_cli(args.graph, args.write_shards, args.shard_edges,
                         args.seed)
        return
    run(args.graph, args.k, args.partitioner, args.seed, args.compare,
        chunk_size=args.chunk_size, ordering=args.ordering,
        window=args.window, num_streams=args.num_streams,
        super_chunk=args.super_chunk)


if __name__ == "__main__":
    main()
