"""Training driver: ``python -m repro.launch.train --arch <id> --shape <s>``.

Composes the cell (model + step), the data pipeline, AdamW, the
fault-tolerant loop, checkpointing, and the straggler monitor.  With
``--smoke`` the reduced config runs on CPU (the examples use this to train
a ~100M-token-scale model for a few hundred steps).
"""

from __future__ import annotations

import argparse
import logging
import time

import jax

from ..checkpoint import CheckpointManager
from ..runtime import FaultInjector, FaultTolerantLoop, StragglerMonitor
from .cells import build_cell

log = logging.getLogger(__name__)


def train(arch: str, shape: str, steps: int = 100, smoke: bool = True,
          ckpt_dir: str = "/tmp/repro_ckpt", ckpt_every: int = 50,
          fail_at: tuple[int, ...] = (), seed: int = 0, log_every: int = 10):
    cell = build_cell(arch, shape, smoke=smoke)
    key = jax.random.PRNGKey(seed)
    state = cell.init_state(key)
    step_jit = jax.jit(cell.step_fn, donate_argnums=cell.donate or ())

    def data_fn(step: int):
        return cell.make_batch(jax.random.fold_in(key, step))

    manager = CheckpointManager(f"{ckpt_dir}/{arch}_{shape}", keep=3)
    monitor = StragglerMonitor(n_shards=1)
    loop = FaultTolerantLoop(
        lambda s, b: step_jit(s, *b), data_fn, manager,
        ckpt_every=ckpt_every, injector=FaultInjector(fail_at),
        straggler_monitor=monitor,
    )
    t0 = time.time()
    state, step, metrics = loop.run(state, steps)
    dt = time.time() - t0
    out = {k: float(v) for k, v in metrics.items()} if isinstance(metrics, dict) else {}
    print(f"[train] {arch} × {shape}: {step} steps in {dt:.1f}s "
          f"({dt / max(step,1) * 1e3:.1f} ms/step) metrics={out} "
          f"restarts={loop.restarts}")
    return state, out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()
    train(args.arch, args.shape, steps=args.steps, smoke=not args.full,
          ckpt_every=args.ckpt_every, fail_at=tuple(args.fail_at))


if __name__ == "__main__":
    main()
