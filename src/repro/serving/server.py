"""The read side: GAS super-steps and queries over pinned bundle versions.

:class:`GASServer` executes vertex programs continuously over whatever
partition version the :class:`~repro.serving.bundle.BundleRegistry`
currently publishes.  Every super-step pins exactly one version for its
whole duration — gather, mirror→master sync, apply — so a concurrent swap
can never feed it mixed-version routing state; the swap takes effect at
the *next* step boundary.  Vertex state (the PageRank value vector) is
**carried across swaps** (:func:`~repro.gas.engine.carry_values`): the
super-step is replica-exact and hence partition-invariant, so warm values
stay meaningful under a new cut and re-converge in a handful of steps
instead of restarting cold — the "absorb new partitions cheaply" half of
the re-partitioning-for-stream-computation framing.

The metrics pipe of the living Fig.-11 reproduction runs through here:
each super-step records the pinned version's replication factor and its
**mirror-sync bytes** (from the GAS engine's exact byte counters), and
each query records wall-clock latency — RF → bytes-on-the-wire → query
latency, per version.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..gas import carry_values, comm_stats, label_propagation, pagerank_step
from .bundle import BundleRegistry, PartitionBundle

__all__ = ["GASServer", "ServingMetrics", "SuperstepRecord"]


class SuperstepRecord(NamedTuple):
    """One GAS super-step as observed by the server."""

    step: int
    version: int  # bundle version the step was pinned to
    swapped: bool  # first step on a new version
    sync_bytes: int  # mirror⇄master volume of this step
    rf: float
    n_edges: int


@dataclass
class ServingMetrics:
    """Accumulated serving telemetry (the RF → bytes → latency pipe)."""

    supersteps: list[SuperstepRecord] = field(default_factory=list)
    query_latency_us: list[float] = field(default_factory=list)
    swaps_observed: int = 0

    @property
    def total_sync_bytes(self) -> int:
        return sum(r.sync_bytes for r in self.supersteps)

    @property
    def n_supersteps(self) -> int:
        return len(self.supersteps)

    def bytes_per_superstep(self) -> float:
        return self.total_sync_bytes / max(self.n_supersteps, 1)

    def mean_query_latency_us(self) -> float:
        return float(np.mean(self.query_latency_us)) \
            if self.query_latency_us else 0.0

    def summary(self) -> dict:
        rfs = [r.rf for r in self.supersteps]
        return {
            "supersteps": self.n_supersteps,
            "swaps_observed": self.swaps_observed,
            "sync_bytes_total": self.total_sync_bytes,
            "sync_bytes_per_superstep": self.bytes_per_superstep(),
            "rf_final": rfs[-1] if rfs else 0.0,
            "queries": len(self.query_latency_us),
            "query_latency_us_mean": self.mean_query_latency_us(),
        }


class GASServer:
    """Continuous GAS execution over the registry's live versions."""

    def __init__(self, registry: BundleRegistry):
        self.registry = registry
        self.values: jax.Array | None = None  # carried vertex state
        self.metrics = ServingMetrics()
        self._step = 0
        self._last_version = -1

    # ----------------------------------------------------------- compute
    def superstep(self) -> SuperstepRecord | None:
        """One pinned PageRank super-step; ``None`` before first publish."""
        with self.registry.pin() as bundle:
            if bundle is None:
                return None
            swapped = bundle.version != self._last_version
            if swapped and self._last_version >= 0:
                self.metrics.swaps_observed += 1
            self._last_version = bundle.version
            if self.values is None:
                self.values = jnp.ones((bundle.n_vertices,), jnp.float32)
            else:
                self.values = carry_values(self.values, bundle.n_vertices)
            self.values = pagerank_step(bundle.gas, self.values,
                                        bundle.out_deg_inv)
            rec = SuperstepRecord(
                step=self._step, version=bundle.version, swapped=swapped,
                sync_bytes=bundle.bytes_per_superstep(),
                rf=bundle.rf, n_edges=bundle.n_edges)
        self._step += 1
        self.metrics.supersteps.append(rec)
        return rec

    def run(self, n_supersteps: int) -> list[SuperstepRecord]:
        """Run ``n`` super-steps (skipping while nothing is published)."""
        out = []
        for _ in range(n_supersteps):
            rec = self.superstep()
            if rec is not None:
                out.append(rec)
        return out

    # ----------------------------------------------------------- queries
    def query_pagerank(self, vertices) -> np.ndarray:
        """Read the carried PageRank values for ``vertices`` (timed)."""
        t0 = time.perf_counter()
        with self.registry.pin() as bundle:
            if bundle is None or self.values is None:
                out = np.zeros(len(vertices), np.float32)
            else:
                out = np.asarray(
                    self.values[jnp.asarray(vertices, jnp.int32)])
        self.metrics.query_latency_us.append(
            (time.perf_counter() - t0) * 1e6)
        return out

    def query_components(self, iterations: int = 5) -> np.ndarray | None:
        """Label-propagation components on the pinned version (timed)."""
        t0 = time.perf_counter()
        with self.registry.pin() as bundle:
            if bundle is None:
                return None
            labels, _ = label_propagation(bundle.gas, iterations)
            out = np.asarray(labels)
        self.metrics.query_latency_us.append(
            (time.perf_counter() - t0) * 1e6)
        return out

    def query_gnn(self, params, feats, cfg, vertices=None):
        """GNN inference over the pinned version's live edges (timed).

        Runs :func:`repro.models.gnn.gcn_forward` on the bundle's edge
        list — the same live window the GAS programs execute over — and
        returns logits for ``vertices`` (all vertices by default).
        """
        from ..models.gnn import gcn_forward

        t0 = time.perf_counter()
        with self.registry.pin() as bundle:
            if bundle is None:
                return None
            logits = gcn_forward(
                params, feats, jnp.asarray(bundle.src),
                jnp.asarray(bundle.dst), bundle.n_vertices, cfg)
            if vertices is not None:
                logits = logits[jnp.asarray(vertices, jnp.int32)]
            out = np.asarray(logits)
        self.metrics.query_latency_us.append(
            (time.perf_counter() - t0) * 1e6)
        return out

    # ------------------------------------------------------- convergence
    def run_to_convergence(self, tol: float = 1e-6, max_steps: int = 200
                           ) -> int:
        """Super-step until the value vector moves < ``tol`` (∞-norm).

        Used after the final swap to compare served state against a
        from-scratch run on the same window; returns steps taken.
        """
        for i in range(max_steps):
            prev = self.values
            self.superstep()
            if prev is not None and self.values is not None \
                    and prev.shape == self.values.shape:
                delta = float(jnp.max(jnp.abs(self.values - prev)))
                if delta < tol:
                    return i + 1
        return max_steps

    @staticmethod
    def comm_of(bundle: PartitionBundle):
        return comm_stats(bundle.gas)
