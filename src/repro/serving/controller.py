"""The ingest→refine→swap controller of the serving loop.

:class:`ServingController` sits between a windowed partitioner chain and
the :class:`~repro.serving.bundle.BundleRegistry`: each :meth:`step`
applies one churn event through the chain (delta fold, expiry retraction,
drift-triggered refinement, cluster-id / edge-slot compaction, and — the
``needs_cold_restart`` fix — the automatic cold re-partition), snapshots
the resulting live window, and **publishes** it as the next
:class:`~repro.serving.bundle.PartitionBundle` version.  Readers never
see any of the intermediate states: the chain's mutable bundle is private
to the controller, and only the end-of-step snapshot is swapped in, at
the step boundary, via the registry's atomic publish.

The chain is duck-typed — anything with ``step() -> record | None``,
``live_partition() -> (src, dst, parts) | None`` and ``lo``/``hi``
coordinates serves (the S5P chain is
:class:`~repro.incremental.driver.S5PWindowChain`; the serving benchmark
drives an HDRF scoring-carry chain through the same controller).

Run it synchronously (:meth:`step` / :meth:`run` — deterministic, what
the tests drive) or as the background ingest thread of a live service
(:meth:`start` / :meth:`stop` / :meth:`join`): the GAS readers keep
serving pinned versions while the controller churns — a cold re-partition
happens *in the controller*, off the readers' path, and lands as one more
atomic swap.  Mid-stream the cold restart is reached through the chain's
``auto_cold_restart``; :meth:`request_cold_restart` forces the same
re-partition between events (the knob a drift dashboard would pull).
"""

from __future__ import annotations

import threading
import time

import numpy as np

from .bundle import BundleRegistry, build_bundle

__all__ = ["ServingController"]


class ServingController:
    """Drive a window chain and publish each step's live partition."""

    def __init__(self, registry: BundleRegistry, chain, *,
                 origin_hook=None):
        self.registry = registry
        self.chain = chain
        self.history: list = []
        self._origin_hook = origin_hook
        self._version = 0
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self.done = threading.Event()
        # serializes every chain/bundle mutation: the background ingest
        # thread's step() vs. request_cold_restart()/resize() from the
        # control plane — out-of-band mutations land exactly at a step
        # boundary, never inside one
        self._lock = threading.RLock()

    # ------------------------------------------------------------ stepping
    def _origin_of(self, rec) -> str:
        if self._origin_hook is not None:
            return self._origin_hook(rec)
        if self._version == 0:
            # nothing published yet: whatever the step also did, this
            # bundle IS the cold partition the window fill produced
            return "cold"
        if getattr(rec, "cold_restarted", False):
            return "cold-restart"
        if getattr(rec, "rolled_back", False):
            return "rollback"
        if getattr(rec, "refined", False):
            return "refine"
        return "delta"

    def step(self):
        """One churn event → at most one published version.

        Returns the chain's step record, or ``None`` when the stream is
        exhausted.  Fill-phase events publish nothing (there is no
        partition to serve yet).
        """
        with self._lock:
            rec = self.chain.step()
            if rec is None:
                self.done.set()
                return None
            self.history.append(rec)
            if getattr(rec, "filling", False):
                return rec
            snap = self.chain.live_partition()
            if snap is None:
                return rec
            src, dst, parts = snap
            # provenance first: _origin_of keys "cold" off the version
            # count *before* this publish (version 0 ⇒ nothing published
            # yet ⇒ this very bundle is the cold one)
            origin = self._origin_of(rec)
            self._version += 1
            self.registry.publish(build_bundle(
                self._version, src, dst, parts,
                self.chain.n_vertices, self.chain.config.k,
                lo=self.chain.lo, hi=self.chain.hi,
                rf=float(getattr(rec, "rf", 0.0)),
                balance=float(getattr(rec, "balance", 0.0)),
                origin=origin))
            return rec

    def run(self):
        """Drain the whole churn schedule synchronously."""
        while self.step() is not None:
            pass
        return self.history

    def request_cold_restart(self) -> bool:
        """Force a cold re-partition of the current live window now.

        The serving-side answer to ``needs_cold_restart`` when the chain
        was built with ``auto_cold_restart=False``: re-partition from
        scratch in the controller (readers keep serving the pinned
        version meanwhile) and publish the result as an atomic swap at
        this step boundary.  Returns False while the window is filling.

        Safe against a live background ingest thread: the controller
        lock holds the restart until the in-flight ``step()`` commits, so
        the chain's bundle and the version counter are never mutated
        mid-step.
        """
        from ..incremental import s5p_cold_restart

        with self._lock:
            chain = self.chain
            if chain.bundle is None:
                return False
            bundle, res = s5p_cold_restart(chain.bundle, chain.config,
                                           chain.seen_src, chain.seen_dst)
            chain.bundle = bundle
            snap = chain.live_partition()
            src, dst, parts = snap
            self._version += 1
            self.registry.publish(build_bundle(
                self._version, src, dst, parts,
                chain.n_vertices, chain.config.k,
                lo=chain.lo, hi=chain.hi, rf=res.rf, balance=res.balance,
                origin="cold-restart"))
            return True

    def resize(self, k_new: int):
        """Elastic resize: reshard the live window onto ``k_new``
        partitions and publish it as one more atomic bundle swap.

        Delegates to the chain's ``resize`` (bounded-migration
        :func:`repro.elastic.reshard_bundle` for the S5P chain); readers
        keep serving the pinned k-era version until the swap lands, and
        subsequent churn steps ingest — and publish — at k′.  Returns the
        chain's resize result (``None`` while the window is filling: the
        new k applies from the cold start instead).
        """
        with self._lock:
            res = self.chain.resize(k_new)
            if res is None:
                return None
            src, dst, parts = self.chain.live_partition()
            self._version += 1
            self.registry.publish(build_bundle(
                self._version, src, dst, parts,
                self.chain.n_vertices, self.chain.config.k,
                lo=self.chain.lo, hi=self.chain.hi,
                rf=float(res.rf), balance=float(res.balance),
                origin="resize"))
            return res

    # ---------------------------------------------------------- background
    def start(self, *, throttle_s: float = 0.0,
              max_lag: int | None = None) -> None:
        """Run the churn schedule on a background ingest thread.

        ``throttle_s`` sleeps between events — a crude arrival-rate model
        that gives readers time to observe intermediate versions.

        ``max_lag`` adds reader **backpressure**: before each event the
        ingest thread blocks while the newest published version is more
        than ``max_lag`` ahead of the oldest version a reader still pins
        (``registry.wait_reader_lag``).  A slow reader therefore bounds
        how far ingest can run ahead of it — the registry's double-buffer
        degenerates to at most ``max_lag + 1`` retained versions instead
        of unboundedly outpacing the reader.  Idle registries (no pins)
        never throttle; ``stop()`` wakes a blocked wait via its poll
        timeout.
        """
        if self._thread is not None:
            raise RuntimeError("controller already started")
        if max_lag is not None and max_lag < 0:
            raise ValueError("max_lag must be >= 0")
        self._stop.clear()

        def ingest():
            try:
                while not self._stop.is_set():
                    if max_lag is not None:
                        # bounded waits so a stop() during backpressure
                        # still terminates the thread promptly
                        while not self._stop.is_set() and not \
                                self.registry.wait_reader_lag(
                                    max_lag, timeout=0.05):
                            pass
                        if self._stop.is_set():
                            break
                    if self.step() is None:
                        break
                    if throttle_s:
                        time.sleep(throttle_s)
            finally:
                self.done.set()

        self._thread = threading.Thread(target=ingest, name="serving-ingest",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()

    def join(self, timeout: float | None = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    @property
    def version(self) -> int:
        return self._version

    @property
    def n_live_edges(self) -> int:
        snap = self.chain.live_partition()
        return 0 if snap is None else int(np.asarray(snap[0]).shape[0])
