"""Versioned partition bundles + the atomic-swap registry.

The serving loop's routing state is a :class:`PartitionBundle` — an
**immutable snapshot** of one partitioned graph version: the live window's
edges, their partition assignment, the prebuilt GAS vertex-cut layout and
its cached per-vertex scratch (``out_deg_inv``), plus the provenance and
quality metrics the metrics pipe reports.  Immutability is what makes the
swap trivial to get right: the ingest side never mutates a published
bundle, so "atomic" reduces to an atomic reference swap.

:class:`BundleRegistry` is that swap point, RCU-style with explicit pins:

- **writers** call :meth:`~BundleRegistry.publish` — one reference
  assignment under the registry lock; every later :meth:`pin` sees the
  new version in full;
- **readers** wrap each super-step in ``with registry.pin() as bundle:``
  — the bundle they get is one consistent version for the whole step
  (edges, parts, layout and scratch all from the same snapshot; a
  concurrent publish cannot tear it), which is exactly the "no reader
  ever observes mixed-version parts" contract the churn tests pin via
  per-bundle fingerprints;
- versions are **refcounted**: a superseded version stays valid for the
  readers still pinning it and is retired once the last pin drops
  (``versions_retired`` counts them — the double-buffer in steady state
  holds the current version plus at most the one in-flight readers hold).
"""

from __future__ import annotations

import threading
import zlib
from contextlib import contextmanager
from typing import NamedTuple

import jax
import numpy as np

from ..gas import GASGraph, build_gas_graph, comm_stats, out_degree_inv

__all__ = ["PartitionBundle", "BundleRegistry", "build_bundle"]


class PartitionBundle(NamedTuple):
    """One immutable partitioned-graph version (see module docstring)."""

    version: int
    src: np.ndarray  # (E_live,) int32 — the live window's edges
    dst: np.ndarray  # (E_live,)
    parts: np.ndarray  # (E_live,) int32 — their partition assignment
    n_vertices: int
    k: int
    gas: GASGraph  # prebuilt vertex-cut layout of exactly these edges
    out_deg_inv: jax.Array  # cached per-vertex scratch for pagerank_step
    lo: int  # window coordinates: arrivals [lo, hi)
    hi: int
    rf: float
    balance: float
    origin: str  # "cold" | "delta" | "refine" | "cold-restart" | "resize" | ...
    fingerprint: int  # CRC over (version, src, dst, parts)

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def bytes_per_superstep(self, bytes_per_value: int = 8) -> int:
        """Mirror-sync volume of one GAS super-step on this version."""
        return comm_stats(self.gas).total_bytes(bytes_per_value)

    def check(self) -> None:
        """Assert the snapshot is internally consistent (untorn)."""
        got = _fingerprint(self.version, self.src, self.dst, self.parts)
        if got != self.fingerprint:
            raise AssertionError(
                f"bundle v{self.version} is torn: fingerprint "
                f"{got:#010x} != {self.fingerprint:#010x}")


def _fingerprint(version: int, src, dst, parts) -> int:
    crc = zlib.crc32(np.int64(version).tobytes())
    for arr in (src, dst, parts):
        crc = zlib.crc32(np.ascontiguousarray(arr, np.int32).tobytes(), crc)
    return crc


def build_bundle(version: int, src, dst, parts, n_vertices: int, k: int, *,
                 lo: int = 0, hi: int = 0, rf: float = 0.0,
                 balance: float = 0.0, origin: str = "cold",
                 ) -> PartitionBundle:
    """Snapshot a routing table into a servable :class:`PartitionBundle`.

    Copies the inputs (the snapshot must not alias ingest-side buffers),
    builds the GAS layout once, and caches the per-vertex scratch — so
    readers pay zero per-superstep setup.
    """
    src = np.array(src, np.int32)
    dst = np.array(dst, np.int32)
    parts = np.array(parts, np.int32)
    gas = build_gas_graph(src, dst, parts, n_vertices, k)
    return PartitionBundle(
        version=int(version), src=src, dst=dst, parts=parts,
        n_vertices=int(n_vertices), k=int(k), gas=gas,
        out_deg_inv=out_degree_inv(gas), lo=int(lo), hi=int(hi),
        rf=float(rf), balance=float(balance), origin=str(origin),
        fingerprint=_fingerprint(version, src, dst, parts))


class BundleRegistry:
    """RCU-style publish/pin registry (see module docstring)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._current: PartitionBundle | None = None
        self._pins: dict[int, int] = {}  # version → active pin count
        self.swap_count = 0  # publishes that replaced a previous version
        self.versions_retired = 0  # superseded versions whose pins drained

    def publish(self, bundle: PartitionBundle) -> None:
        """Atomically make ``bundle`` the version new pins will see."""
        with self._cond:
            prev = self._current
            self._current = bundle
            if prev is not None:
                self.swap_count += 1
                if self._pins.get(prev.version, 0) == 0:
                    self.versions_retired += 1
            self._cond.notify_all()

    @property
    def current(self) -> PartitionBundle | None:
        """The latest published bundle (unpinned peek — metrics only)."""
        with self._lock:
            return self._current

    @property
    def current_version(self) -> int:
        with self._lock:
            return -1 if self._current is None else self._current.version

    @contextmanager
    def pin(self):
        """Pin the current version for the duration of one super-step.

        Yields ``None`` when nothing has been published yet.  The pinned
        bundle stays valid across concurrent publishes; its version is
        retired only after the last pin drops.
        """
        with self._lock:
            bundle = self._current
            if bundle is not None:
                self._pins[bundle.version] = \
                    self._pins.get(bundle.version, 0) + 1
        try:
            yield bundle
        finally:
            if bundle is not None:
                with self._cond:
                    n = self._pins[bundle.version] - 1
                    if n:
                        self._pins[bundle.version] = n
                    else:
                        del self._pins[bundle.version]
                        cur = self._current
                        if cur is None or cur.version != bundle.version:
                            self.versions_retired += 1
                    # a drop can shrink reader lag — wake throttled writers
                    self._cond.notify_all()

    def oldest_pinned_version(self) -> int:
        """The oldest version a reader still pins (−1 when none are)."""
        with self._lock:
            return min(self._pins) if self._pins else -1

    def reader_lag(self) -> int:
        """Published-ahead distance: newest version − oldest pinned.

        0 when nothing is published or no reader pins anything — an idle
        registry never counts as lagging.
        """
        with self._lock:
            if self._current is None or not self._pins:
                return 0
            return self._current.version - min(self._pins)

    def wait_reader_lag(self, max_lag: int, timeout: float | None = None
                        ) -> bool:
        """Block until ``reader_lag() <= max_lag`` (writer backpressure).

        Pin releases and publishes both notify, so a throttled ingest
        thread wakes exactly when the slowest reader catches up.
        """
        def _ok():
            if self._current is None or not self._pins:
                return True
            return self._current.version - min(self._pins) <= max_lag

        with self._cond:
            return self._cond.wait_for(_ok, timeout)

    def wait_version(self, version: int, timeout: float | None = None
                     ) -> bool:
        """Block until a bundle with ``version`` or newer is published."""
        with self._cond:
            return self._cond.wait_for(
                lambda: (self._current is not None
                         and self._current.version >= version), timeout)

    @property
    def active_pins(self) -> int:
        with self._lock:
            return sum(self._pins.values())
