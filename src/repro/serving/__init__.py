"""Live partition serving: versioned bundles, atomic swaps, GAS readers."""

from .bundle import BundleRegistry, PartitionBundle, build_bundle  # noqa: F401
from .controller import ServingController  # noqa: F401
from .server import GASServer, ServingMetrics, SuperstepRecord  # noqa: F401

__all__ = [
    "BundleRegistry",
    "GASServer",
    "PartitionBundle",
    "ServingController",
    "ServingMetrics",
    "SuperstepRecord",
    "build_bundle",
]
