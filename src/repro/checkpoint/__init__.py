from .manager import CheckpointManager, save_checkpoint, restore_checkpoint  # noqa: F401
from .reshard import reshard_state  # noqa: F401
