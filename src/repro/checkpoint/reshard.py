"""Elastic restart: re-place a host checkpoint onto any mesh.

Checkpoints are stored as full (unsharded) host arrays, so resharding is
placement-only: given the target mesh + sharding tree, ``jax.device_put``
each leaf.  This is what lets a run checkpointed on 2×16×16 resume on
16×16 (pod loss) or on a test mesh — and what the elastic controller uses
after S5P re-partitions the graph for a new worker count.
"""

from __future__ import annotations

import jax

__all__ = ["reshard_state"]


def reshard_state(host_state, shardings):
    """host_state: pytree of numpy arrays; shardings: matching pytree of
    jax.sharding.Sharding (or None ⇒ default placement)."""
    def put(x, s):
        return jax.device_put(x, s) if s is not None else jax.device_put(x)

    return jax.tree.map(put, host_state, shardings)
