"""Fault-tolerant checkpointing: atomic, versioned, async, keep-N.

No orbax in this container, so the manager is built on npz + msgpack with
the invariants a production manager must have:

- **atomic commit**: write to ``step_XXXX.tmp/`` then ``os.rename`` — a
  crash mid-write never corrupts the latest checkpoint;
- **self-describing**: the pytree structure is stored as a msgpack
  treedef-path list, so restore works without the model object;
- **keep-N GC** with an optional keep-every-K "permanent" cadence;
- **async writer**: snapshot to host (device_get) on the training thread,
  serialize on a worker thread — the step loop never blocks on disk;
- **integrity check**: per-array CRC32 recorded and verified on restore.

Restore returns plain numpy trees; ``reshard.py`` re-places them onto any
mesh (elastic restart across different topologies).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint"]


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str | Path, step: int, state) -> Path:
    """Atomic single-checkpoint write.  Returns the committed path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    host_state = jax.device_get(state)
    arrays = {}
    manifest = {"step": step, "keys": [], "crc": {}, "dtypes": {}}
    for key, leaf in _flatten_with_paths(host_state):
        arr = np.asarray(leaf)
        manifest["dtypes"][key] = str(arr.dtype)
        if arr.dtype.itemsize == 2 and arr.dtype.kind == "V" or \
                str(arr.dtype) == "bfloat16":
            arr = arr.view(np.uint16)  # npz has no bf16; view-preserving
        arrays[key.replace("/", "__")] = arr
        manifest["keys"].append(key)
        manifest["crc"][key] = zlib.crc32(np.ascontiguousarray(arr).tobytes())
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def restore_checkpoint(directory: str | Path, step: int | None = None,
                       like=None, verify: bool = True):
    """Restore the given (or latest) step as a pytree.

    ``like`` (optional) supplies the treedef: leaves are filled by path.
    Without it a flat {path: array} dict is returned.
    """
    directory = Path(directory)
    steps = sorted(
        int(p.name.split("_")[1]) for p in directory.glob("step_*")
        if not p.name.endswith(".tmp")
    )
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = step if step is not None else steps[-1]
    path = directory / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        flat = {k: z[k.replace('/', '__')] for k in manifest["keys"]}
    for k, dt in manifest.get("dtypes", {}).items():
        if dt == "bfloat16" and flat[k].dtype == np.uint16:
            import ml_dtypes
            flat[k] = flat[k].view(ml_dtypes.bfloat16)
    if verify:
        for k, arr in flat.items():
            raw = arr.view(np.uint16) if str(arr.dtype) == "bfloat16" else arr
            crc = zlib.crc32(np.ascontiguousarray(raw).tobytes())
            if crc != manifest["crc"][k]:
                raise IOError(f"checkpoint corruption at {k} (crc mismatch)")
    if like is None:
        return flat, step
    paths_leaves = _flatten_with_paths(like)
    leaves = [flat[k] for k, _ in paths_leaves]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """keep-N manager with an async writer thread."""

    def __init__(self, directory: str | Path, keep: int = 3,
                 keep_every: int | None = None, async_write: bool = True):
        self.directory = Path(directory)
        self.keep = keep
        self.keep_every = keep_every
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, state) -> None:
        host_state = jax.device_get(state)  # snapshot before returning
        self.wait()

        def work():
            try:
                save_checkpoint(self.directory, step, host_state)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, like=None, step: int | None = None):
        self.wait()
        return restore_checkpoint(self.directory, step=step, like=like)

    def steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1]) for p in self.directory.glob("step_*")
            if not p.name.endswith(".tmp")
        )

    def _gc(self) -> None:
        steps = self.steps()
        protect = set(steps[-self.keep:]) if self.keep else set(steps)
        if self.keep_every:
            protect |= {s for s in steps if s % self.keep_every == 0}
        for s in steps:
            if s not in protect:
                shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)
