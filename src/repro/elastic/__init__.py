"""Elastic k→k′ re-partitioning with bounded migration.

Production clusters resize; "(Re)partitioning for stream-enabled
computation" (Le Merrer & Trédan, PAPERS.md) frames the resize as a
migration-cost problem, and "Hybrid Edge Partitioner" (Mayer & Jacobsen)
shows quality survives when only a bounded core is re-placed.  This
package is that trade implemented on the S5P warm-start substrate:

- :func:`reshard_bundle` maps an S5P carry bundle onto a new partition
  count.  Every edge whose partition survives **keeps its placement**;
  only the displaced remainder (partitions ≥ k′ on shrink, plus the edges
  of clusters the game chose to relocate) is re-placed.  Which clusters
  relocate is decided by the masked Stackelberg game with a
  **migration-cost term** in the payoff (``core.game``'s ``move_cost``):
  a cluster moves only when the equilibrium gain at k′ exceeds the cost
  of shipping its edges.
- :func:`reshard_scan_carry` does the same for the scoring-baseline scan
  carries (greedy / HDRF): grow pads the k-dimensioned columns, shrink
  retracts the displaced edges through the group algebra and re-scans
  only them at k′.
- :func:`reshard_carry` dispatches on what it is handed.

The serving loop publishes the result as one more atomic bundle swap
(``ServingController.resize``); the runtime's ``ElasticController`` calls
it in place of a cold re-partition when its job is graph-shaped.
"""

from .reshard import (  # noqa: F401
    ReshardResult,
    reshard_bundle,
    reshard_carry,
    reshard_scan_carry,
)

__all__ = ["ReshardResult", "reshard_bundle", "reshard_scan_carry",
           "reshard_carry"]
