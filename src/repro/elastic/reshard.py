"""Bounded-migration k→k′ resharding of S5P bundles and scan carries.

The operation behind an elastic resize: the cluster changes shape, the
partition count must follow, and a cold re-partition at k′ — O(|E|)
stream replay plus 100 % edge migration — is exactly what the warm-start
substrate lets us avoid.  :func:`reshard_bundle` re-settles only the
cluster→partition game (O(C), no stream replay) under a migration-cost
payoff, then re-places only the edges whose placement actually died:

- **grow** (k′ > k): every placement survives; the game decides which
  clusters are worth relocating onto the new empty partitions, each
  paying ``move_cost ∝ |c_i|`` (its edge-shipping bill) to leave home.
- **shrink** (k′ < k): edges on partitions ≥ k′ are displaced and *must*
  move (their clusters re-home with no migration penalty — ``home = -1``
  makes the penalty uniform, hence neutral); surviving clusters may also
  relocate, but only if the gain at k′ beats their migration cost.

Everything else — Alg. 1 clustering state, degrees, Θ sheets, the CMS,
per-edge cluster tags, slot/arrival coordinates — is k-independent and
carries over untouched, so the resharded bundle drops back into the same
window chain / CarryStore slot and keeps absorbing deltas at k′.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..core import game as _game
from ..core.metrics import load_balance, replication_factor
from ..core.postprocess import AssignCarry
from ..core.s5p import S5PConfig
from ..incremental.pipeline import (
    _INT32_MAX,
    _invalidate_journal,
    _least_loaded_fill,
    ensure_slot_index,
)
from ..streaming import EdgeStream, run_carry, run_retract

__all__ = ["ReshardResult", "reshard_bundle", "reshard_scan_carry",
           "reshard_carry"]


class ReshardResult(NamedTuple):
    """What a resize cost and what it bought."""

    k_old: int
    k_new: int
    rf: float  # replication factor at k_new
    balance: float  # load balance at k_new
    n_live: int  # live placed edges at reshard time
    migrated_edges: int  # live edges whose partition changed
    n_displaced: int  # live edges whose old partition no longer exists
    moved_clusters: int  # clusters the game relocated
    game_rounds: int

    @property
    def migrated_fraction(self) -> float:
        return self.migrated_edges / max(self.n_live, 1)


def _noop_result(k: int, rf: float, bal: float, n_live: int) -> ReshardResult:
    return ReshardResult(k_old=k, k_new=k, rf=rf, balance=bal,
                         n_live=n_live, migrated_edges=0, n_displaced=0,
                         moved_clusters=0, game_rounds=0)


def reshard_bundle(bundle: dict, config: S5PConfig, k_new: int,
                   full_src, full_dst, *, move_cost_scale: float = 1.0,
                   ) -> tuple[dict, S5PConfig, ReshardResult]:
    """Map an S5P warm bundle onto ``k_new`` partitions, migrating as few
    edges as the balance constraint allows.

    ``full_src``/``full_dst`` are the arrival-indexed stream prefix the
    bundle is keyed on (``S5PWindowChain.seen_src/seen_dst``); only the
    displaced slots are ever gathered from them.  ``move_cost_scale``
    scales the per-cluster migration penalty ``|c_i| / k′`` — 0 recovers
    the unconstrained re-settle (most migration, best RF), large values
    freeze every survivor in place (zero migration beyond the displaced
    set).  Returns ``(bundle, config_at_k_new, result)``; the input
    bundle is not mutated.
    """
    k_old = int(config.k)
    if k_new < 1:
        raise ValueError(f"k_new must be >= 1, got {k_new}")
    b = ensure_slot_index(dict(bundle))
    new_config = dataclasses.replace(config, k=int(k_new))

    arrival = np.asarray(b["arrival"], np.int64)
    full_src = np.asarray(full_src, np.int32)
    full_dst = np.asarray(full_dst, np.int32)
    slot_src = full_src[arrival]
    slot_dst = full_dst[arrival]
    old_parts = np.asarray(b["parts"], np.int32)
    alive = np.asarray(b["alive"], bool)
    placed = alive & (old_parts >= 0)
    n_live = int(np.count_nonzero(placed))

    if k_new == k_old:
        return b, new_config, _noop_result(
            k_old, float(b["rf_baseline"]), float(b["balance_baseline"]),
            n_live)

    sizes = np.asarray(b["sizes"], np.float32)
    comb_is_head = np.asarray(b["comb_is_head"], bool)
    C = int(sizes.shape[0])
    old_c2p = np.asarray(b["c2p"], np.int32)

    # ---- seat the displaced clusters, keep everyone else home --------
    displaced_c = old_c2p >= k_new  # never true on grow
    c2p0 = old_c2p.copy()
    c2p0[displaced_c] = -1
    disp_ids = np.nonzero(displaced_c)[0]
    # big clusters first: successive least-loaded seating packs better
    disp_ids = disp_ids[np.argsort(-sizes[disp_ids], kind="stable")]
    c2p0 = _least_loaded_fill(sizes, c2p0, disp_ids, int(k_new))

    # ---- the migration-cost Stackelberg game -------------------------
    # A cluster's bill for leaving home is its edge volume over k′ — the
    # same normalization as the game's communication term, so the two
    # trade in one currency.  Displaced clusters have no home to defend.
    home = np.where(displaced_c, -1, old_c2p).astype(np.int32)
    move_cost = np.where(
        displaced_c, 0.0,
        float(move_cost_scale) * sizes / float(k_new)).astype(np.float32)
    move_mask = sizes > 0
    game_rounds = 0
    if np.any(move_mask):
        inputs = _game.GameInputs(
            sizes=jnp.asarray(sizes),
            pair_a=jnp.asarray(b["pair_a"], jnp.int32),
            pair_b=jnp.asarray(b["pair_b"], jnp.int32),
            pair_w=jnp.asarray(b["pair_w"], jnp.float32),
            n_head=0, k=int(k_new))
        bs = _game.default_batch_size(config.game_batch_size, C)
        res = _game.run_game(
            inputs, C, batch_size=bs, max_rounds=config.game_max_rounds,
            accept_prob=config.game_accept_prob, assign0=c2p0,
            seed=config.seed + 2, leader_mask=comb_is_head,
            move_mask=move_mask, move_cost=move_cost, home=home)
        c2p_new = np.asarray(res.assignment, np.int32)
        game_rounds = int(res.rounds)
    else:
        c2p_new = c2p0
    moved_c = c2p_new != old_c2p
    # empty clusters ride along as metadata; seat them in-range so later
    # deltas that revive them place against a valid map
    oob = c2p_new >= k_new
    if np.any(oob):
        c2p_new = np.where(oob, c2p_new % k_new, c2p_new).astype(np.int32)

    # ---- bounded migration: keep survivors, re-place the rest --------
    edge_cu = np.asarray(b["edge_cu"], np.int32)
    edge_cv = np.asarray(b["edge_cv"], np.int32)
    edge_head = np.asarray(b["edge_head"], bool)
    affected = placed & (
        (old_parts >= k_new)
        | ((edge_cu >= 0) & moved_c[np.maximum(edge_cu, 0)])
        | ((edge_cv >= 0) & moved_c[np.maximum(edge_cv, 0)]))
    kept = placed & ~affected
    load64 = np.zeros(int(k_new), np.int64)
    np.add.at(load64, old_parts[kept], 1)
    max_load = (_INT32_MAX if config.bounded
                else int(math.ceil(config.tau * max(n_live, 1) / k_new)))
    parts = old_parts.copy()
    aidx = np.nonzero(affected)[0]
    if aidx.size:
        re_stream = EdgeStream(slot_src[aidx], slot_dst[aidx],
                               int(np.asarray(b["degrees"]).shape[0]),
                               chunk_size=config.chunk_size)
        ac = AssignCarry(int(k_new), max_load, jnp.asarray(c2p_new))
        re_parts, load = run_carry(
            re_stream, ac, jnp.asarray(edge_head[aidx]),
            jnp.asarray(np.maximum(edge_cu[aidx], 0)),
            jnp.asarray(np.maximum(edge_cv[aidx], 0)),
            carry=jnp.asarray(load64.astype(np.int32)))
        parts[aidx] = np.asarray(re_parts, np.int32)
        load = np.asarray(load, np.int32)
    else:
        load = load64.astype(np.int32)

    n_vertices = int(np.asarray(b["degrees"]).shape[0])
    rf = float(replication_factor(slot_src, slot_dst, parts,
                                  n_vertices=n_vertices, k=int(k_new)))
    bal = float(load_balance(parts, k=int(k_new)))
    migrated = int(np.count_nonzero(placed & (parts != old_parts)))
    n_displaced = int(np.count_nonzero(placed & (old_parts >= k_new)))

    b["c2p"] = c2p_new
    b["load"] = load
    b["parts"] = parts
    b["touched"] = np.zeros(C, bool)
    b["rf_baseline"] = np.float64(rf)
    b["balance_baseline"] = np.float64(bal)
    # κ is k-dependent (≈ 2E/k′ unbounded): leaving the k-era value in
    # place would trip needs_cold_restart on the very next delta
    if not config.bounded:
        b["kappa"] = np.int32(
            min(max(int(math.ceil(2.0 * n_live / k_new)), 2), _INT32_MAX))
    # the journal snapshots k-era c2p/load — a rollback across a resize
    # would resurrect out-of-range partitions
    _invalidate_journal(b)

    result = ReshardResult(
        k_old=k_old, k_new=int(k_new), rf=rf, balance=bal, n_live=n_live,
        migrated_edges=migrated, n_displaced=n_displaced,
        moved_clusters=int(np.count_nonzero(moved_c & (sizes > 0))),
        game_rounds=game_rounds)
    return b, new_config, result


# ---------------------------------------------------------------------------
# scan carries (greedy / HDRF)
# ---------------------------------------------------------------------------


def _resize_cols(arr: np.ndarray, k_new: int) -> np.ndarray:
    """Pad (grow) or slice (shrink) the trailing k axis with zeros."""
    k_old = arr.shape[-1]
    if k_new <= k_old:
        return arr[..., :k_new]
    pad = [(0, 0)] * (arr.ndim - 1) + [(0, k_new - k_old)]
    return np.pad(arr, pad)


def reshard_scan_carry(pc, carry, k_new: int, src, dst, parts, *,
                       chunk_size: int = 1 << 16,
                       ) -> tuple[object, np.ndarray, ReshardResult]:
    """Reshard a greedy/HDRF carry (and its recorded parts) onto k′.

    ``pc`` is the **k′-dimensioned** consumer (``GreedyCarry(V, k′)`` /
    ``HdrfCarry(V, k′)``); ``carry`` its k-era state; ``src``/``dst``/
    ``parts`` the edges the carry accounts for.  Grow pads the
    k-dimensioned columns with zeros (no placement changes at all);
    shrink retracts the displaced edges through the group algebra, slices
    the columns, and re-scans only the displaced edges at k′.  Grid
    carries are structurally k-bound (hashed row/col tables) and raise.
    """
    from ..kernels.stream_scan import ops as _ops
    from ..kernels.stream_scan import ref as _ref

    if isinstance(pc, _ops.GridCarry):
        raise ValueError(
            "grid carries hash vertices into a fixed k grid; a resize "
            "re-hashes every edge — use a cold re-partition")
    if not isinstance(pc, (_ops.GreedyCarry, _ops.HdrfCarry)):
        raise ValueError(f"cannot reshard carry for {type(pc).__name__}")

    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    parts = np.asarray(parts, np.int32)
    k_old = int(np.asarray(carry[0]).shape[0])
    k_new = int(k_new)
    n_live = int(np.count_nonzero(parts >= 0))
    n_vertices = pc.n_vertices

    if k_new == k_old:
        rf = float(replication_factor(src, dst, parts,
                                      n_vertices=n_vertices, k=k_old))
        bal = float(load_balance(parts, k=k_old))
        return carry, parts, _noop_result(k_old, rf, bal, n_live)

    displaced = parts >= k_new  # empty on grow
    didx = np.nonzero(displaced)[0]
    work = carry
    if didx.size:
        # subtract the dead partitions' accounting while the carry is
        # still k-dimensioned — COUNTED/SUM fields retract exactly
        del_stream = EdgeStream(src[didx], dst[didx], n_vertices,
                                chunk_size=chunk_size)
        work = run_retract(del_stream, pc, jnp.asarray(parts[didx]),
                           carry=work)

    load = jnp.asarray(_resize_cols(np.asarray(work[0]), k_new))
    rep = jnp.asarray(_resize_cols(np.asarray(work[1]), k_new))
    if isinstance(pc, _ops.HdrfCarry):
        fresh = _ref.hdrf_init(n_vertices, k_new, float(np.asarray(work[3])))
        work = (load, rep, work[2], work[3], fresh[4])
    else:
        work = (load, rep)

    new_parts = parts.copy()
    if didx.size:
        re_stream = EdgeStream(src[didx], dst[didx], n_vertices,
                               chunk_size=chunk_size)
        re_parts, work = run_carry(re_stream, pc, carry=work)
        new_parts[didx] = np.asarray(re_parts, np.int32)

    rf = float(replication_factor(src, dst, new_parts,
                                  n_vertices=n_vertices, k=k_new))
    bal = float(load_balance(new_parts, k=k_new))
    migrated = int(np.count_nonzero((parts >= 0) & (new_parts != parts)))
    return work, new_parts, ReshardResult(
        k_old=k_old, k_new=k_new, rf=rf, balance=bal, n_live=n_live,
        migrated_edges=migrated, n_displaced=int(didx.size),
        moved_clusters=0, game_rounds=0)


def reshard_carry(state, k_new: int, *args, **kwargs):
    """Dispatch: S5P bundle dict → :func:`reshard_bundle` (pass ``config,
    k_new, full_src, full_dst``); scan consumer → :func:`reshard_scan_carry`
    (pass ``carry, k_new, src, dst, parts``)."""
    if isinstance(state, dict) and "c2p" in state:
        config = args[0] if args else kwargs.pop("config")
        rest = args[1:] if args else ()
        return reshard_bundle(state, config, k_new, *rest, **kwargs)
    return reshard_scan_carry(state, kwargs.pop("carry"), k_new,
                              *args, **kwargs)
