"""Logical-axis sharding: one place where tensor dims map to mesh axes.

Models annotate activations/params with *logical* axis names
(``constrain(x, "batch", None, "mlp")``).  The active :class:`AxisRules`
(set by the launcher / dry-run) resolves logical names to mesh axes and
applies ``with_sharding_constraint``; with no rules active (unit tests,
single device) annotations are no-ops.

Default production rules for the (pod, data, model) mesh:

  batch    → ("pod", "data")   # DP over pods × data
  fsdp     → "data"            # weight shard that is all-gathered at use
  mlp      → "model"           # TP: d_ff, vocab, experts' hidden
  heads    → "model"           # TP over attention heads (when divisible)
  kv_seq   → "model"           # decode KV split (flash-decoding style)
  expert   → "model"           # EP when n_experts % |model| == 0
  rows     → "model"           # recsys embedding-table rows
  edges    → ("pod", "data")   # GNN edge shards (S5P-aligned)
  nodes    → ("pod", "data")   # GNN node shards
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "DEFAULT_RULES", "use_rules", "constrain", "named_sharding", "logical_spec"]

_state = threading.local()


class AxisRules:
    def __init__(self, mesh: Mesh | None, mapping: Mapping[str, Any]):
        self.mesh = mesh
        self.mapping = dict(mapping)

    def resolve(self, *logical: str | None) -> P:
        axes = []
        used: set[str] = set()
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            mapped = self.mapping.get(name)
            if mapped is None:
                axes.append(None)
                continue
            if isinstance(mapped, str):
                mapped = (mapped,)
            avail = tuple(
                a for a in mapped
                if a not in used and (self.mesh is None or a in self.mesh.axis_names)
            )
            for a in avail:
                used.add(a)
            axes.append(avail if len(avail) != 1 else avail[0])
            if not avail:
                axes[-1] = None
        return P(*axes)


DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_seq": ("model",),
    # EP needs n_experts % |model axis| == 0 — the assigned Mixtral configs
    # have 8 experts on a 16-wide model axis, so the production default is
    # TP over d_ff; meshes that divide can map expert → "model" (tests do)
    "expert": (),
    "rows": ("model",),
    "edges": ("pod", "data"),
    "nodes": ("pod", "data"),
    "seq": (),  # unsharded by default; SP maps this to ("model",)
    "stash": ("model",),  # layer-boundary activation stash (remat residuals)
}


def current_rules() -> AxisRules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh | None, mapping: Mapping[str, Any] | None = None):
    """Activate sharding rules for model tracing (None mesh ⇒ no-op rules)."""
    prev = getattr(_state, "rules", None)
    _state.rules = AxisRules(mesh, mapping or DEFAULT_RULES) if mesh is not None else None
    try:
        yield _state.rules
    finally:
        _state.rules = prev


def logical_spec(*logical: str | None) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.resolve(*logical)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate ``x`` with the resolved sharding (no-op without rules)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.resolve(*logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def named_sharding(mesh: Mesh, *logical: str | None,
                   mapping: Mapping[str, Any] | None = None) -> NamedSharding:
    rules = AxisRules(mesh, mapping or DEFAULT_RULES)
    return NamedSharding(mesh, rules.resolve(*logical))
