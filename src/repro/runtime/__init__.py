from .fault import FaultTolerantLoop, FaultInjector, LaneFaultInjector  # noqa: F401
from .straggler import StragglerMonitor  # noqa: F401
from .elastic import ElasticController, ElasticPartition  # noqa: F401
