from .fault import FaultTolerantLoop, FaultInjector  # noqa: F401
from .straggler import StragglerMonitor  # noqa: F401
from .elastic import ElasticController  # noqa: F401
