"""Elastic scaling: grow/shrink the worker set without losing the run.

The elastic flow on resize (node failure or capacity change):
  1. quiesce + checkpoint (host arrays — mesh-independent by design);
  2. build the new mesh;
  3. **re-partition with S5P** when the job is graph-shaped — warm, via
     :func:`repro.elastic.reshard_bundle`: the paper's one-pass streaming
     property makes even a cold re-partition O(|E|), but the bounded-
     migration reshard moves only the displaced edges (DESIGN.md §5);
  4. reshard the checkpoint onto the new mesh and resume.
"""

from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..checkpoint.reshard import reshard_state

__all__ = ["ElasticController", "ElasticPartition"]


class ElasticPartition:
    """The graph-shaped job's routing state under elastic resizes.

    Wraps an S5P warm bundle (plus the arrival-indexed stream prefix it
    is keyed on) and re-homes it across partition counts with bounded
    migration.  :meth:`resize` reshards in place and returns the
    :class:`~repro.elastic.ReshardResult`; :attr:`parts` is the live
    arrival-indexed assignment at the current k.
    """

    def __init__(self, bundle: dict, config, full_src, full_dst):
        self.bundle = bundle
        self.config = config
        self.full_src = np.asarray(full_src, np.int32)
        self.full_dst = np.asarray(full_dst, np.int32)

    @property
    def k(self) -> int:
        return int(self.config.k)

    @property
    def parts(self) -> np.ndarray:
        from ..incremental.pipeline import _scatter_parts, ensure_slot_index

        b = ensure_slot_index(self.bundle)
        parts = np.where(np.asarray(b["alive"], bool),
                         np.asarray(b["parts"], np.int32), -1)
        return _scatter_parts(parts.astype(np.int32),
                              np.asarray(b["arrival"], np.int64),
                              int(b["stream_pos"]))

    def resize(self, k_new: int):
        from ..elastic import reshard_bundle

        self.bundle, self.config, res = reshard_bundle(
            self.bundle, self.config, k_new, self.full_src, self.full_dst)
        return res


class ElasticController:
    """Checkpoint → new mesh → re-partition → reshard → resume.

    ``partition`` (an :class:`ElasticPartition`) takes precedence over the
    legacy ``repartition`` hook: the resize re-homes the existing bundle
    with bounded migration instead of partitioning the graph cold.
    """

    def __init__(self, manager: CheckpointManager,
                 make_mesh: Callable[[int], object],
                 make_shardings: Callable[[object], object] | None = None,
                 repartition: Callable[[int], object] | None = None,
                 partition: ElasticPartition | None = None):
        self.manager = manager
        self.make_mesh = make_mesh
        self.make_shardings = make_shardings
        self.repartition = repartition
        self.partition = partition

    def resize(self, state, step: int, new_size: int):
        """Returns ``(new_state, mesh, parts, step)``.

        ``parts`` is the warm reshard's
        :class:`~repro.elastic.ReshardResult` when a ``partition`` is
        attached, the ``repartition`` hook's return value otherwise
        (``None`` with neither).
        """
        self.manager.save(step, state)
        self.manager.wait()
        mesh = self.make_mesh(new_size)
        host_state, step = self.manager.restore(like=state)
        shardings = self.make_shardings(mesh) if self.make_shardings else None
        new_state = (reshard_state(host_state, shardings)
                     if shardings is not None else jax.device_put(host_state))
        if self.partition is not None:
            parts = self.partition.resize(new_size)
        else:
            parts = self.repartition(new_size) if self.repartition else None
        return new_state, mesh, parts, step
