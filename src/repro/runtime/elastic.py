"""Elastic scaling: grow/shrink the worker set without losing the run.

The elastic flow on resize (node failure or capacity change):
  1. quiesce + checkpoint (host arrays — mesh-independent by design);
  2. build the new mesh;
  3. **re-partition with S5P** when the job is graph-shaped — the paper's
     one-pass streaming property makes re-partitioning O(|E|) with O(|V|)
     memory, which is why a streaming partitioner is the right choice for
     elastic graph systems (DESIGN.md §5);
  4. reshard the checkpoint onto the new mesh and resume.
"""

from __future__ import annotations

from typing import Callable

import jax

from ..checkpoint import CheckpointManager
from ..checkpoint.reshard import reshard_state

__all__ = ["ElasticController"]


class ElasticController:
    def __init__(self, manager: CheckpointManager,
                 make_mesh: Callable[[int], object],
                 make_shardings: Callable[[object], object] | None = None,
                 repartition: Callable[[int], object] | None = None):
        self.manager = manager
        self.make_mesh = make_mesh
        self.make_shardings = make_shardings
        self.repartition = repartition

    def resize(self, state, step: int, new_size: int):
        """Checkpoint → new mesh → (optional S5P re-partition) → reshard."""
        self.manager.save(step, state)
        self.manager.wait()
        mesh = self.make_mesh(new_size)
        host_state, step = self.manager.restore(like=state)
        shardings = self.make_shardings(mesh) if self.make_shardings else None
        new_state = (reshard_state(host_state, shardings)
                     if shardings is not None else jax.device_put(host_state))
        parts = self.repartition(new_size) if self.repartition else None
        return new_state, mesh, parts, step
