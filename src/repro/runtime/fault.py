"""Fault-tolerant training loop: checkpoint/restart with failure injection.

At thousand-node scale, *something* is always failing; the framework
contract is: any step may raise (preemption, ICI link flap, host OOM) and
the run resumes from the last committed checkpoint with **bit-identical**
state (tests verify exact-resume equality).

``FaultInjector`` deterministically raises at configured steps — used by
tests and the chaos example to prove the recovery path, the same way the
paper uses PUMBA to inject network faults into PowerGraph (§6.6).
``LaneFaultInjector`` is its parallel-ingest sibling: it kills a named
ingest lane at a named chunk, which ``run_parallel(on_lane_failure=
"replay")`` must survive bit-identically.
"""

from __future__ import annotations

import logging
import time
from typing import Any, Callable, Iterable

import jax
import numpy as np

from ..checkpoint import CheckpointManager

log = logging.getLogger(__name__)

__all__ = ["FaultInjector", "LaneFaultInjector", "FaultTolerantLoop"]


class FaultInjector:
    """Raises RuntimeError at the given steps (once each)."""

    def __init__(self, fail_at: Iterable[int] = ()):
        self.fail_at = set(fail_at)

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


class LaneFaultInjector:
    """Kill parallel-ingest lanes at named (lane, chunk) points (once each).

    Plugged into :func:`repro.streaming.run_parallel` via
    ``lane_injector=`` — the raise lands inside the lane's fold, mid-
    super-chunk, which is exactly the window where a worker death loses
    uncommitted carry state.

    Replay contract under hub sharding (``shard="hub"``): a replayed lane
    re-folds exactly its own pinned chunk registry from the last committed
    merge base, so every hub's edges stay on the lane the rendezvous hash
    pinned them to and the recovered drive is bit-identical to the
    undisturbed one.  Lane *handoff* (straggler mitigation) is the one
    path allowed to move a pin: it re-slices at a whole-hub boundary and
    moves the affected hubs' ``pin_map`` entries with the range — a hub's
    edges are never split across two lanes, failed or not.
    """

    def __init__(self, fail_at: Iterable[tuple[int, int]] = ()):
        self.fail_at = {(int(lane), int(chunk)) for lane, chunk in fail_at}
        self.fired: list[tuple[int, int]] = []

    def check(self, lane: int, chunk_id: int) -> None:
        key = (int(lane), int(chunk_id))
        if key in self.fail_at:
            self.fail_at.discard(key)
            self.fired.append(key)
            raise RuntimeError(
                f"injected lane {lane} failure at chunk {chunk_id}")


class FaultTolerantLoop:
    """Run train_step with periodic checkpoints and automatic restart.

    step_fn(state, batch) → (state, metrics); data_fn(step) → batch must be
    step-addressable (deterministic replay from any step — our pipelines
    fold the step into the PRNG key, so resume is bitwise).

    ``shard_fn(step) → shard`` attributes each step's wall time to a lane
    for the :class:`~repro.runtime.straggler.StragglerMonitor` (data-
    parallel loops typically map ``step % n_shards``); without it every
    step is charged to shard 0 and per-lane detection is off.
    """

    def __init__(self, step_fn: Callable, data_fn: Callable[[int], Any],
                 manager: CheckpointManager, ckpt_every: int = 50,
                 max_restarts: int = 8, injector: FaultInjector | None = None,
                 straggler_monitor=None,
                 shard_fn: Callable[[int], int] | None = None):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.manager = manager
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.straggler_monitor = straggler_monitor
        self.shard_fn = shard_fn
        self.restarts = 0

    def run(self, state, n_steps: int, start_step: int = 0):
        # snapshot the entry state: a failure *before the first
        # checkpoint* must replay from scratch — restarting with the
        # crashed run's mutated state would silently double-apply steps
        init_state = jax.tree.map(np.copy, jax.device_get(state))
        step = start_step
        metrics = {}
        while step < n_steps:
            try:
                while step < n_steps:
                    batch = self.data_fn(step)
                    if self.injector is not None:
                        self.injector.check(step)
                    t0 = time.perf_counter()
                    state, metrics = self.step_fn(state, batch)
                    jax.block_until_ready(metrics)
                    if self.straggler_monitor is not None:
                        shard = (self.shard_fn(step)
                                 if self.shard_fn is not None else 0)
                        self.straggler_monitor.record(
                            step, time.perf_counter() - t0, shard=shard)
                    step += 1
                    if step % self.ckpt_every == 0:
                        self.manager.save(step, state)
            except (RuntimeError, OSError) as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restarting from checkpoint", step, e)
                try:
                    state, step = self.manager.restore(like=state)
                except FileNotFoundError:
                    # no checkpoint yet: restart from the *entry* state
                    state = jax.device_put(init_state)
                    step = start_step
        self.manager.save(step, state)
        self.manager.wait()
        return state, step, metrics
