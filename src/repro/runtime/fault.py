"""Fault-tolerant training loop: checkpoint/restart with failure injection.

At thousand-node scale, *something* is always failing; the framework
contract is: any step may raise (preemption, ICI link flap, host OOM) and
the run resumes from the last committed checkpoint with **bit-identical**
state (tests verify exact-resume equality).

``FaultInjector`` deterministically raises at configured steps — used by
tests and the chaos example to prove the recovery path, the same way the
paper uses PUMBA to inject network faults into PowerGraph (§6.6).
"""

from __future__ import annotations

import logging
import time
from typing import Any, Callable, Iterable

import jax

from ..checkpoint import CheckpointManager

log = logging.getLogger(__name__)

__all__ = ["FaultInjector", "FaultTolerantLoop"]


class FaultInjector:
    """Raises RuntimeError at the given steps (once each)."""

    def __init__(self, fail_at: Iterable[int] = ()):
        self.fail_at = set(fail_at)

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


class FaultTolerantLoop:
    """Run train_step with periodic checkpoints and automatic restart.

    step_fn(state, batch) → (state, metrics); data_fn(step) → batch must be
    step-addressable (deterministic replay from any step — our pipelines
    fold the step into the PRNG key, so resume is bitwise).
    """

    def __init__(self, step_fn: Callable, data_fn: Callable[[int], Any],
                 manager: CheckpointManager, ckpt_every: int = 50,
                 max_restarts: int = 8, injector: FaultInjector | None = None,
                 straggler_monitor=None):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.manager = manager
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.straggler_monitor = straggler_monitor
        self.restarts = 0

    def run(self, state, n_steps: int, start_step: int = 0):
        step = start_step
        metrics = {}
        while step < n_steps:
            try:
                while step < n_steps:
                    batch = self.data_fn(step)
                    if self.injector is not None:
                        self.injector.check(step)
                    t0 = time.perf_counter()
                    state, metrics = self.step_fn(state, batch)
                    jax.block_until_ready(metrics)
                    if self.straggler_monitor is not None:
                        self.straggler_monitor.record(step, time.perf_counter() - t0)
                    step += 1
                    if step % self.ckpt_every == 0:
                        self.manager.save(step, state)
            except (RuntimeError, OSError) as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restarting from checkpoint", step, e)
                try:
                    state, step = self.manager.restore(like=state)
                except FileNotFoundError:
                    step = start_step  # no checkpoint yet: restart from scratch
        self.manager.save(step, state)
        self.manager.wait()
        return state, step, metrics
