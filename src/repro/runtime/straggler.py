"""Straggler detection and mitigation.

Per-shard step-time EMAs; a shard whose EMA exceeds ``threshold ×`` the
fleet median is flagged.  Mitigation hooks:

- **data rebalance**: hand back a fraction of the straggler's stream range
  (for the S5P partitioner this is a *local* fix — Algorithm 3's load
  vector caps the receiving partitions, so quality bounds survive).
  :func:`repro.streaming.run_parallel` drives this live: pass it a
  monitor and at each super-chunk boundary :meth:`rebalance_plan` moves a
  tail cut of every straggler lane's remaining chunk range to the fastest
  lane.  Under hub sharding the cut is taken at a whole-hub boundary and
  the moved hubs' pin-map entries travel with it (an edge of a pinned hub
  is never served by two lanes — the invariant the quality argument and
  lane-death replay both rest on);
- **checkpoint-and-exclude**: at persistent stragglers the elastic
  controller (elastic.py) reshapes the mesh without the slow host.
"""

from __future__ import annotations

from collections import defaultdict

import numpy as np

__all__ = ["StragglerMonitor"]


class StragglerMonitor:
    def __init__(self, n_shards: int = 1, ema: float = 0.9,
                 threshold: float = 1.5):
        self.n_shards = n_shards
        self.ema = ema
        self.threshold = threshold
        self.times: dict[int, float] = defaultdict(float)
        self.history: list[tuple[int, int, float]] = []  # (step, shard, dt)

    def record(self, step: int, dt: float, shard: int = 0) -> None:
        shard = int(shard)
        # auto-grow: callers that discover lanes dynamically (the parallel
        # ingest path) shouldn't have to pre-size the fleet
        self.n_shards = max(self.n_shards, shard + 1)
        prev = self.times[shard]
        self.times[shard] = dt if prev == 0 else self.ema * prev + (1 - self.ema) * dt
        self.history.append((step, shard, dt))

    def stragglers(self) -> list[int]:
        if not self.times:
            return []
        vals = np.array([self.times[s] for s in range(self.n_shards)])
        med = np.median(vals[vals > 0]) if (vals > 0).any() else 0.0
        if med == 0:
            return []
        return [s for s in range(self.n_shards) if self.times[s] > self.threshold * med]

    def rebalance_plan(self, shard_ranges: list[tuple[int, int]],
                       give_frac: float = 0.25):
        """Move ``give_frac`` of each straggler's stream range to the
        fastest shard.  Returns the new ranges (edges are stream offsets —
        a pure metadata move, no data reshuffle needed for re-streaming)."""
        slow = set(self.stragglers())
        if not slow or not self.times:
            return shard_ranges
        fastest = min(range(self.n_shards), key=lambda s: self.times[s] or 1e9)
        out = list(shard_ranges)
        for s in slow:
            if s == fastest or s >= len(out):
                continue
            lo, hi = out[s]
            cut = int((hi - lo) * give_frac)
            out[s] = (lo, hi - cut)
            flo, fhi = out[fastest]
            # fastest absorbs the tail range (contiguity not required)
            out[fastest] = (flo, fhi + cut)
        return out
