"""Oracle chunk functions: the seed ``lax.scan`` scoring scans, extracted.

Each function processes one EdgeStream chunk and threads the partitioner
carry; the per-edge state transitions are the seed implementations of
``core.baselines`` moved here verbatim, so the refactored partitioners are
bit-identical to the originals (pinned by the golden hashes in
``tests/test_streaming.py``).

Carries are plain tuples of arrays so they vmap cleanly: scenario
parameters that vary across a batch (HDRF λ, the active-partition mask for
padded multi-k runs) live *inside* the carry, not in the closure — one
compiled chunk function serves every scenario in a batch.

Decremental representation: the replica "bitmaps" are **counted** — int32
per-(vertex, partition) occupancy counters that OR-project (``> 0``) for
scoring.  The projection is bit-identical to the old boolean bitmap on
insert-only streams (every score reads the projection, never the raw
count), and the counters form an abelian group, so the ``*_retract_chunk``
functions below subtract an edge's accounting exactly: when the last edge
that replicated v on partition p is deleted the counter hits 0 and the
replica vanishes.  Retraction is order-independent (pure scatter-
subtract), so it is vectorized — no scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "greedy_init",
    "greedy_chunk",
    "greedy_retract_chunk",
    "hdrf_init",
    "hdrf_chunk",
    "hdrf_retract_chunk",
    "grid_init",
    "grid_chunk",
    "grid_retract_chunk",
    "cluster_chunk_oracle",
    "assign_chunk_oracle",
]

_INF_I32 = jnp.int32(2**30)
_HDRF_EPS = 1e-3


def _retract_masks(src, dst, n_valid, parts):
    """(weights, safe parts) for a deletion chunk: only real (index <
    n_valid), non-self-loop, actually-placed (parts >= 0) edges count."""
    w = ((jnp.arange(src.shape[0]) < n_valid) & (src != dst)
         & (parts >= 0)).astype(jnp.int32)
    return w, jnp.maximum(parts, 0)


# ---------------------------------------------------------------- greedy
def greedy_init(n_vertices: int, k: int):
    """(load (k,), rep (V, k) counted replica table)."""
    return (
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((n_vertices, k), jnp.int32),
    )


@jax.jit
def greedy_chunk(carry, src, dst):
    """PowerGraph Greedy: 4-case replica-aware assignment (one chunk)."""

    def step(carry, e):
        load, rep = carry
        u, v = e
        au = rep[u] > 0
        av = rep[v] > 0
        both = au & av
        either = au | av
        case1 = jnp.any(both)
        case2 = jnp.any(au) & jnp.any(av)
        case3 = jnp.any(either)
        mask = jnp.where(
            case1, both, jnp.where(case2, either, jnp.where(case3, either, True))
        )
        score = jnp.where(mask, load, _INF_I32)
        pick = jnp.argmin(score).astype(jnp.int32)
        valid = u != v
        w = jnp.where(valid, 1, 0)
        load = load.at[pick].add(w)
        rep = rep.at[u, pick].add(w)
        rep = rep.at[v, pick].add(w)
        return (load, rep), jnp.where(valid, pick, -1)

    return jax.lax.scan(step, carry, (src, dst))


@jax.jit
def greedy_retract_chunk(carry, src, dst, n_valid, parts):
    """Exact inverse of :func:`greedy_chunk`'s accounting for these edges."""
    load, rep = carry
    w, p = _retract_masks(src, dst, n_valid, parts)
    load = load - jax.ops.segment_sum(w, p, num_segments=load.shape[0])
    rep = rep.at[src, p].add(-w)
    rep = rep.at[dst, p].add(-w)
    return (load, rep)


# ----------------------------------------------------------------- hdrf
def hdrf_init(n_vertices: int, k: int, lam: float = 1.1, k_active: int | None = None):
    """(load, rep counted replica table, pd partial degrees, λ,
    active-partition mask).

    ``k_active < k`` pads the carry for multi-k batched runs: inactive
    lanes never win the argmax, so a batch of different partition counts
    shares one compiled engine at ``k = max(ks)``.
    """
    if k_active is None:
        k_active = k
    return (
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((n_vertices, k), jnp.int32),
        jnp.zeros((n_vertices,), jnp.int32),
        jnp.float32(lam),
        jnp.arange(k) < k_active,
    )


@jax.jit
def hdrf_chunk(carry, src, dst):
    """HDRF (partial-degree variant, as published) over one chunk."""

    def step(carry, e):
        load, rep, pd, lam, kmask = carry
        u, v = e
        pd = pd.at[u].add(1)
        pd = pd.at[v].add(1)
        du = pd[u].astype(jnp.float32)
        dv = pd[v].astype(jnp.float32)
        theta_u = du / (du + dv)
        theta_v = 1.0 - theta_u
        g_u = jnp.where(rep[u] > 0, 1.0 + (1.0 - theta_u), 0.0)
        g_v = jnp.where(rep[v] > 0, 1.0 + (1.0 - theta_v), 0.0)
        loadf = load.astype(jnp.float32)
        maxl = jnp.max(jnp.where(kmask, loadf, -jnp.inf))
        minl = jnp.min(jnp.where(kmask, loadf, jnp.inf))
        bal = (maxl - loadf) / (_HDRF_EPS + maxl - minl)
        score = jnp.where(kmask, g_u + g_v + lam * bal, -jnp.inf)
        pick = jnp.argmax(score).astype(jnp.int32)
        valid = u != v
        w = jnp.where(valid, 1, 0)
        load = load.at[pick].add(w)
        rep = rep.at[u, pick].add(w)
        rep = rep.at[v, pick].add(w)
        return (load, rep, pd, lam, kmask), jnp.where(valid, pick, -1)

    return jax.lax.scan(step, carry, (src, dst))


@jax.jit
def hdrf_retract_chunk(carry, src, dst, n_valid, parts):
    """Exact inverse of :func:`hdrf_chunk`'s accounting for these edges.

    Partial degrees subtract for every real entry (including self-loops),
    mirroring the forward scan's unconditional ``pd`` update; load and
    replica counters only for placed edges.  The forward scan's *padding*
    contribution to ``pd`` (a documented chunk-seam approximation) is
    never retracted — deletion batches are chunked independently of how
    the edges originally arrived.
    """
    load, rep, pd, lam, kmask = carry
    real = (jnp.arange(src.shape[0]) < n_valid).astype(jnp.int32)
    n = pd.shape[0]
    pd = pd - jax.ops.segment_sum(real, src, num_segments=n)
    pd = pd - jax.ops.segment_sum(real, dst, num_segments=n)
    w, p = _retract_masks(src, dst, n_valid, parts)
    load = load - jax.ops.segment_sum(w, p, num_segments=load.shape[0])
    rep = rep.at[src, p].add(-w)
    rep = rep.at[dst, p].add(-w)
    return (load, rep, pd, lam, kmask)


# ----------------------------------------------------------------- grid
def grid_init(load_k: int, row: jax.Array, col: jax.Array, n_cols: int):
    """(load, per-vertex hashed row/col, #grid-columns)."""
    return (
        jnp.zeros((load_k,), jnp.int32),
        jnp.asarray(row, jnp.int32),
        jnp.asarray(col, jnp.int32),
        jnp.int32(n_cols),
    )


@jax.jit
def grid_chunk(carry, src, dst):
    """Grid/constrained candidate partitioning, least-loaded pick."""

    def step(carry, e):
        load, row, col, c = carry
        u, v = e
        cand1 = row[u] * c + col[v]
        cand2 = row[v] * c + col[u]
        pick = jnp.where(load[cand1] <= load[cand2], cand1, cand2)
        valid = u != v
        load = load.at[pick].add(jnp.where(valid, 1, 0))
        return (load, row, col, c), jnp.where(valid, pick, -1)

    return jax.lax.scan(step, carry, (src, dst))


@jax.jit
def grid_retract_chunk(carry, src, dst, n_valid, parts):
    """Exact inverse of :func:`grid_chunk`'s accounting for these edges."""
    load, row, col, c = carry
    w, p = _retract_masks(src, dst, n_valid, parts)
    load = load - jax.ops.segment_sum(w, p, num_segments=load.shape[0])
    return (load, row, col, c)


# --------------------------------------------------- cluster / assign oracles
# The bit-parity references for the Algorithm-1 / Algorithm-3 megakernels
# are the core scans themselves; these thin wrappers re-export them behind
# lazy imports (``core.baselines`` imports this package at module level,
# so the kernels package must never import ``core`` at module level).


def cluster_chunk_oracle(state, src, dst, degrees, *, xi, kappa,
                         global_tail=False):
    """``core.clustering.cluster_chunk`` on a 10-leaf state tuple.

    Takes/returns plain leaf tuples (same contract as
    :func:`..kernel.cluster_scan`) so parity tests compare like for like.
    """
    from ...core.clustering import ClusterState, cluster_chunk

    out = cluster_chunk(ClusterState(*state), src, dst, degrees,
                        xi=xi, kappa=kappa, global_tail=global_tail)
    return tuple(out)


def assign_chunk_oracle(load, max_load, src, dst, is_head_edge, cu, cv, c2p,
                        *, k):
    """``core.postprocess._assign_chunk`` — the Algorithm-3 scan oracle."""
    from ...core.postprocess import _assign_chunk

    return _assign_chunk(load, max_load, src, dst, is_head_edge, cu, cv,
                         c2p, k=k)
