"""Pallas TPU megakernel: the whole S5P chunk step in one dispatch.

One ``pallas_call`` per stream chunk covers the entire inner loop of the
streaming partitioners — insert *and* retract.  Layout (the
``PrefetchScalarGridSpec`` pipelining idiom):

- the grid is blocked over the chunk's edges (``block`` edges per step);
  per-edge operands (recorded parts in, parts out) ride blocked
  ``BlockSpec``s so the pipeline double-buffers their DMA, while the edge
  endpoint ids are **scalar-prefetched** (SMEM) — the serial scan indexes
  them with scalar loads ahead of the compute stream;
- revisited state (the load vector, the **counted** replica table, HDRF
  partial degrees) lives in VMEM blocks with constant index maps, so it
  stays resident across grid steps and is written back once;
- ``input_output_aliases`` donates every state input to its output, so a
  dispatch updates state in place instead of copying it per call;
- a ``sign`` operand (+1 insert / -1 retract) reuses the same kernel for
  deletion: the counted replica table is an abelian group, so retraction
  is the same scatter arithmetic with negated weights and the recorded
  per-edge parts standing in for the scored pick.

Three kernels share the layout: the greedy/HDRF scoring scan
(:func:`scoring_scan`), the Algorithm-1 clustering fold
(:func:`cluster_scan`), and the Algorithm-3 placement pass
(:func:`assign_scan`).  When the per-vertex state exceeds the VMEM
budget, :func:`scoring_scan` switches to a **tiled** variant: the replica
table (and partial degrees) stay HBM-resident (``memory_space=ANY``) and
the kernel gathers/scatters single rows with ``pl.load`` / ``pl.store``
— slower per edge, still one dispatch per chunk.  ``ops.py`` owns the
fused → tiled → oracle degradation ladder.

Per-edge math mirrors ``ref.py`` (and ``core.clustering`` /
``core.postprocess``) expression-for-expression, so interpret mode is
bit-identical to the oracles — asserted by tests/test_kernels.py and the
pinned goldens in tests/test_streaming.py.

Padding contract: wrappers pad the chunk to a multiple of ``block`` with
``(0, 0)`` self-loops and ``parts = -1``; a ``limit`` scalar (insert: the
passed chunk length, matching the oracles' unconditional handling of the
chunk's own padding; retract: ``n_valid``) masks everything past it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "DEFAULT_BLOCK",
    "assign_scan",
    "cluster_scan",
    "dispatch_count",
    "reset_dispatch_count",
    "scoring_scan",
    "stream_scan_tpu",
]

DEFAULT_BLOCK = 512
_INF_I32 = 2**30  # python int: jnp constants may not be captured by kernels

# Dispatch accounting: one increment per pallas_call issued.  The bench
# uses this to demonstrate the 1-dispatch-per-chunk contract (the oracle
# re-materializes the carry per edge inside its scan).
_DISPATCHES = 0


def dispatch_count() -> int:
    return _DISPATCHES


def reset_dispatch_count() -> None:
    global _DISPATCHES
    _DISPATCHES = 0


def _bump_dispatch() -> None:
    global _DISPATCHES
    _DISPATCHES += 1


def _resolve(block, n, interpret):
    """(block, pad, interpret) for an n-edge chunk."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    blk = min(block or DEFAULT_BLOCK, max(n, 1))
    return blk, (-n) % blk, interpret


def _pad_edges(src, dst, parts, pad):
    """Pad with (0, 0) self-loops / -1 parts — guaranteed no-ops."""
    if pad:
        src = jnp.pad(src, (0, pad))
        dst = jnp.pad(dst, (0, pad))
    if parts is None:
        pin = jnp.full((src.shape[0],), -1, jnp.int32)
    elif pad:
        pin = jnp.pad(jnp.asarray(parts, jnp.int32), (0, pad),
                      constant_values=-1)
    else:
        pin = jnp.asarray(parts, jnp.int32)
    return src, dst, pin


def _edge_spec(block):
    return pl.BlockSpec((block,), lambda i, *_: (i,))


def _const_spec(shape):
    return pl.BlockSpec(shape, lambda i, *_: tuple(0 for _ in shape))


_ANY_SPEC = pl.BlockSpec(memory_space=pltpu.ANY)


# ===================================================================
# greedy / HDRF scoring scan
# ===================================================================


def _scoring_kernel(meta_ref, src_ref, dst_ref, pin_ref, *refs,
                    mode, eps, k, block, tiled):
    if mode == "hdrf":
        (load_in, _rep_in, _pd_in, lam_in,
         parts_ref, load_ref, rep_ref, pd_ref) = refs
    else:
        load_in, _rep_in, parts_ref, load_ref, rep_ref = refs
        pd_ref = lam_in = None
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        load_ref[...] = load_in[...]
        if not tiled:
            rep_ref[...] = _rep_in[...]
            if mode == "hdrf":
                pd_ref[...] = _pd_in[...]

    limit = meta_ref[0]
    sign = meta_ref[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)[0, :]

    def row(ref, u):
        if tiled:
            return pl.load(ref, (pl.dslice(u, 1), slice(None)))[0, :]
        return ref[u, :]

    def row_add(ref, u, delta):
        if tiled:
            fresh = pl.load(ref, (pl.dslice(u, 1), slice(None)))[0, :]
            pl.store(ref, (pl.dslice(u, 1), slice(None)),
                     (fresh + delta)[None, :])
        else:
            ref[u, :] = ref[u, :] + delta

    def scalar_add(ref, u, delta):
        if tiled:
            fresh = pl.load(ref, (pl.dslice(u, 1), slice(None)))[0, 0]
            pl.store(ref, (pl.dslice(u, 1), slice(None)),
                     (fresh + delta)[None, None])
        else:
            ref[u, 0] = ref[u, 0] + delta

    def scalar_get(ref, u):
        if tiled:
            return pl.load(ref, (pl.dslice(u, 1), slice(None)))[0, 0]
        return ref[u, 0]

    def body(e, _):
        g = i * block + e
        u = src_ref[g]
        v = dst_ref[g]
        real = g < limit
        is_ins = sign > 0
        p_ret = pin_ref[e]
        load = load_ref[0, :]
        if mode == "hdrf":
            # the oracle bumps pd unconditionally (self-loops and the
            # chunk's own padding included) *before* scoring
            pdw = jnp.where(real, sign, 0)
            scalar_add(pd_ref, u, pdw)
            scalar_add(pd_ref, v, pdw)
            du = scalar_get(pd_ref, u).astype(jnp.float32)
            dv = scalar_get(pd_ref, v).astype(jnp.float32)
            ru = row(rep_ref, u) > 0
            rv = row(rep_ref, v) > 0
            theta_u = du / (du + dv)
            theta_v = 1.0 - theta_u
            g_u = jnp.where(ru, 1.0 + (1.0 - theta_u), 0.0)
            g_v = jnp.where(rv, 1.0 + (1.0 - theta_v), 0.0)
            loadf = load.astype(jnp.float32)
            maxl = jnp.max(loadf)
            minl = jnp.min(loadf)
            bal = (maxl - loadf) / (eps + maxl - minl)
            score = g_u + g_v + lam_in[0, 0] * bal
            pick_ins = jnp.argmax(score).astype(jnp.int32)
        else:
            ru = row(rep_ref, u) > 0
            rv = row(rep_ref, v) > 0
            both = ru & rv
            either = ru | rv
            case1 = jnp.any(both)
            case2 = jnp.any(ru) & jnp.any(rv)
            case3 = jnp.any(either)
            mask = jnp.where(
                case1, both,
                jnp.where(case2, either, jnp.where(case3, either, True)))
            score = jnp.where(mask, load, _INF_I32)
            pick_ins = jnp.argmin(score).astype(jnp.int32)
        pick = jnp.where(is_ins, pick_ins, jnp.maximum(p_ret, 0))
        placed = real & (u != v) & jnp.where(is_ins, True, p_ret >= 0)
        w = jnp.where(placed, sign, 0)
        hit = jnp.where(iota == pick, w, 0)
        load_ref[0, :] = load + hit
        row_add(rep_ref, u, hit)
        row_add(rep_ref, v, hit)
        parts_ref[e] = jnp.where(
            is_ins, jnp.where(real & (u != v), pick_ins, -1), p_ret)
        return 0

    jax.lax.fori_loop(0, block, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("mode", "eps", "block", "tiled",
                                    "interpret"))
def _scoring_call(meta, src, dst, pin, *state, mode, eps, block, tiled,
                  interpret):
    Epad = src.shape[0]
    V, k = state[1].shape
    table = _ANY_SPEC if tiled else _const_spec((V, k))
    col = _ANY_SPEC if tiled else _const_spec((V, 1))
    in_specs = [_edge_spec(block), _const_spec((1, k)), table]
    out_specs = [_edge_spec(block), _const_spec((1, k)), table]
    out_shape = [
        jax.ShapeDtypeStruct((Epad,), jnp.int32),
        jax.ShapeDtypeStruct((1, k), jnp.int32),
        jax.ShapeDtypeStruct((V, k), jnp.int32),
    ]
    # aliasing indices count the scalar-prefetch args (meta, src, dst)
    aliases = {3: 0, 4: 1, 5: 2}
    if mode == "hdrf":
        in_specs += [col, _const_spec((1, 1))]
        out_specs += [col]
        out_shape += [jax.ShapeDtypeStruct((V, 1), jnp.int32)]
        aliases[6] = 3
    kernel = functools.partial(_scoring_kernel, mode=mode, eps=eps, k=k,
                               block=block, tiled=tiled)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(Epad // block,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(meta, src, dst, pin, *state)


def scoring_scan(src, dst, load, rep, pd=None, lam=None, *, mode: str,
                 sign: int = 1, parts=None, n_valid=None, eps: float = 1e-3,
                 block: int | None = None, tiled: bool = False,
                 interpret: bool | None = None):
    """One fused greedy/HDRF chunk — insert (``sign=+1``) or retract
    (``sign=-1``, with the recorded per-edge ``parts`` and ``n_valid``).

    src/dst: (E,) int32; load: (k,) int32; rep: (V, k) int32 **counted**
    replica table; pd: (V,) int32 partial degrees (HDRF only); lam:
    scalar f32.  Returns ``(parts (E,), load, rep, pd)`` (``pd`` None for
    greedy).  ``tiled=True`` keeps rep/pd HBM-resident (``ANY``) for
    tables past the VMEM budget.
    """
    if mode not in ("greedy", "hdrf"):
        raise ValueError(f"unknown mode {mode!r}")
    if sign not in (1, -1):
        raise ValueError(f"sign must be +1 or -1, got {sign!r}")
    if sign < 0 and (n_valid is None or parts is None):
        raise ValueError("retract needs n_valid and recorded parts")
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    load = jnp.asarray(load, jnp.int32)
    rep = jnp.asarray(rep, jnp.int32)
    E = src.shape[0]
    V, k = rep.shape
    if mode == "hdrf":
        pd = jnp.asarray(pd, jnp.int32)
    if E == 0:
        return jnp.zeros((0,), jnp.int32), load, rep, pd
    blk, pad, interpret = _resolve(block, E, interpret)
    src, dst, pin = _pad_edges(src, dst, parts, pad)
    limit = jnp.asarray(E if sign > 0 else n_valid, jnp.int32)
    meta = jnp.stack([limit, jnp.int32(sign)])
    state = (load.reshape(1, k), rep)
    if mode == "hdrf":
        state += (pd.reshape(V, 1), jnp.asarray(lam, jnp.float32).reshape(1, 1))
    _bump_dispatch()
    out = _scoring_call(meta, src, dst, pin, *state, mode=mode,
                        eps=float(eps), block=blk, tiled=bool(tiled),
                        interpret=interpret)
    if mode == "hdrf":
        parts_out, load2, rep2, pd2 = out
        return parts_out[:E], load2[0], rep2, pd2[:, 0]
    parts_out, load2, rep2 = out
    return parts_out[:E], load2[0], rep2, None


def stream_scan_tpu(src, dst, load, rep, pd, lam, *, mode: str,
                    eps: float = 1e-3, interpret: bool | None = None):
    """Back-compat single-chunk insert surface (seed API).

    Same contract as the original whole-array kernel, now running the
    blocked megakernel; ``rep`` is the counted replica table and comes
    back with exact counters (the seed version wrote a saturated 0/1
    projection).  Returns ``(parts, load, rep, pd)``.
    """
    parts, load2, rep2, pd2 = scoring_scan(
        src, dst, load, rep, pd if mode == "hdrf" else None, lam,
        mode=mode, sign=1, eps=eps, interpret=interpret)
    if pd2 is None:
        pd2 = jnp.asarray(pd, jnp.int32)
    return parts, load2, rep2, pd2


# ===================================================================
# Algorithm 1 clustering fold
# ===================================================================


def _cluster_kernel(meta_ref, src_ref, dst_ref, deg_in, *refs,
                    xi, kappa, global_tail, block):
    state_in = refs[:10]
    (v2ch, v2ct, volh, volt, ld, nexth, nextt, cnth, cntt, alloch) = refs[10:]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        for dst_ref_, src_ref_ in zip(
                (v2ch, v2ct, volh, volt, ld, nexth, nextt, cnth, cntt,
                 alloch), state_in):
            dst_ref_[...] = src_ref_[...]

    limit = meta_ref[0]
    sink = volh.shape[0] - 1  # masked-write sink slot (static)

    def body(e, _):
        g = i * block + e
        u = src_ref[g]
        v = dst_ref[g]
        real = g < limit
        du = deg_in[u, 0]
        dv = deg_in[v, 0]
        is_head = (du > xi) & (dv > xi)
        valid = real & (u != v)

        # ---------------- head branch (global-degree volumes) ----------
        cu = v2ch[u, 0]
        cv = v2ch[v, 0]
        new_u = cu < 0
        new_v = cv < 0
        h_on = is_head & valid
        nh = nexth[0, 0]
        cu2 = jnp.where(new_u, nh, cu)
        nh = nh + jnp.where(h_on & new_u, 1, 0)
        cv2 = jnp.where(new_v, nh, cv)
        nh = nh + jnp.where(h_on & new_v, 1, 0)
        nexth[0, 0] = nh
        idx = jnp.where(h_on & new_u, cu2, sink)
        volh[idx, 0] = volh[idx, 0] + jnp.where(h_on & new_u, du, 0)
        idx = jnp.where(h_on & new_v, cv2, sink)
        volh[idx, 0] = volh[idx, 0] + jnp.where(h_on & new_v, dv, 0)
        cnth[u, 0] = cnth[u, 0] + jnp.where(h_on, 1, 0)
        cnth[v, 0] = cnth[v, 0] + jnp.where(h_on, 1, 0)
        alloch[u, 0] = alloch[u, 0] + jnp.where(h_on & new_u, du, 0)
        alloch[v, 0] = alloch[v, 0] + jnp.where(h_on & new_v, dv, 0)
        v2ch[u, 0] = jnp.where(h_on, cu2, v2ch[u, 0])
        v2ch[v, 0] = jnp.where(h_on, cv2, v2ch[v, 0])
        vu = volh[cu2, 0]
        vv = volh[cv2, 0]
        both_small = (vu < kappa) & (vv < kappa) & (cu2 != cv2)
        score_u = vu - du
        score_v = vv - dv
        u_is_i = score_u <= score_v  # tie → u (matches reference)
        ci = jnp.where(u_is_i, cu2, cv2)
        cj = jnp.where(u_is_i, cv2, cu2)
        i_vtx = jnp.where(u_is_i, u, v)
        di = jnp.where(u_is_i, du, dv)
        can_mig = h_on & both_small & (volh[cj, 0] + di < kappa)
        idx = jnp.where(can_mig, cj, sink)
        volh[idx, 0] = volh[idx, 0] + jnp.where(can_mig, di, 0)
        idx = jnp.where(can_mig, ci, sink)
        volh[idx, 0] = volh[idx, 0] + jnp.where(can_mig, -di, 0)
        v2ch[i_vtx, 0] = jnp.where(can_mig, cj, v2ch[i_vtx, 0])

        # ---------------- tail branch (local-degree volumes) -----------
        t_on = (~is_head) & valid
        tu = v2ct[u, 0]
        tv = v2ct[v, 0]
        tnew_u = tu < 0
        tnew_v = tv < 0
        nt = nextt[0, 0]
        tu2 = jnp.where(tnew_u, nt, tu)
        nt = nt + jnp.where(t_on & tnew_u, 1, 0)
        tv2 = jnp.where(tnew_v, nt, tv)
        nt = nt + jnp.where(t_on & tnew_v, 1, 0)
        nextt[0, 0] = nt
        if global_tail:
            idx = jnp.where(t_on & tnew_u, tu2, sink)
            volt[idx, 0] = volt[idx, 0] + jnp.where(t_on & tnew_u, du, 0)
            idx = jnp.where(t_on & tnew_v, tv2, sink)
            volt[idx, 0] = volt[idx, 0] + jnp.where(t_on & tnew_v, dv, 0)
        else:
            idx = jnp.where(t_on, tu2, sink)
            volt[idx, 0] = volt[idx, 0] + jnp.where(t_on, 1, 0)
            idx = jnp.where(t_on, tv2, sink)
            volt[idx, 0] = volt[idx, 0] + jnp.where(t_on, 1, 0)
            ld[u, 0] = ld[u, 0] + jnp.where(t_on, 1, 0)
            ld[v, 0] = ld[v, 0] + jnp.where(t_on, 1, 0)
        v2ct[u, 0] = jnp.where(t_on, tu2, v2ct[u, 0])
        v2ct[v, 0] = jnp.where(t_on, tv2, v2ct[v, 0])
        cntt[u, 0] = cntt[u, 0] + jnp.where(t_on, 1, 0)
        cntt[v, 0] = cntt[v, 0] + jnp.where(t_on, 1, 0)
        tvu = volt[tu2, 0]
        tvv = volt[tv2, 0]
        t_small = (tvu < kappa) & (tvv < kappa) & (tu2 != tv2)
        tu_is_i = tvu <= tvv
        tci = jnp.where(tu_is_i, tu2, tv2)
        tcj = jnp.where(tu_is_i, tv2, tu2)
        ti = jnp.where(tu_is_i, u, v)
        ldi = deg_in[ti, 0] if global_tail else ld[ti, 0]
        t_mig = t_on & t_small
        if global_tail:
            t_mig = t_mig & (volt[tcj, 0] + ldi < kappa)
        idx = jnp.where(t_mig, tcj, sink)
        volt[idx, 0] = volt[idx, 0] + jnp.where(t_mig, ldi, 0)
        idx = jnp.where(t_mig, tci, sink)
        volt[idx, 0] = volt[idx, 0] + jnp.where(t_mig, -ldi, 0)
        v2ct[ti, 0] = jnp.where(t_mig, tcj, v2ct[ti, 0])
        return 0

    jax.lax.fori_loop(0, block, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("xi", "kappa", "global_tail", "block",
                                    "interpret"))
def _cluster_call(meta, src, dst, degrees, *state, xi, kappa, global_tail,
                  block, interpret):
    V = degrees.shape[0]
    shapes = [(V, 1), (V, 1), (V + 1, 1), (V + 1, 1), (V, 1), (1, 1),
              (1, 1), (V, 1), (V, 1), (V, 1)]
    state_specs = [_const_spec(s) for s in shapes]
    kernel = functools.partial(_cluster_kernel, xi=xi, kappa=kappa,
                               global_tail=global_tail, block=block)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(src.shape[0] // block,),
        in_specs=[_const_spec((V, 1))] + state_specs,
        out_specs=list(state_specs),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(s, jnp.int32) for s in shapes],
        input_output_aliases={4 + i: i for i in range(10)},
        interpret=interpret,
    )(meta, src, dst, degrees, *state)


def cluster_scan(state, src, dst, degrees, *, xi: int, kappa: int,
                 global_tail: bool = False, block: int | None = None,
                 interpret: bool | None = None):
    """One fused Algorithm-1 chunk (insert path).

    ``state`` is the 10-leaf ``ClusterState`` tuple (plain arrays — this
    module cannot import ``core``); returns the updated leaves in the
    same order.  Per-edge transitions mirror
    ``core.clustering._edge_step`` expression-for-expression.
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    E = src.shape[0]
    if E == 0:
        return tuple(state)
    blk, pad, interpret = _resolve(block, E, interpret)
    src, dst, _ = _pad_edges(src, dst, None, pad)
    (v2c_h, v2c_t, vol_h, vol_t, ld, next_h, next_t, cnt_h, cnt_t,
     alloc_h) = (jnp.asarray(s, jnp.int32) for s in state)
    V = ld.shape[0]
    meta = jnp.stack([jnp.int32(E), jnp.int32(1)])
    packed = (v2c_h.reshape(V, 1), v2c_t.reshape(V, 1),
              vol_h.reshape(V + 1, 1), vol_t.reshape(V + 1, 1),
              ld.reshape(V, 1), next_h.reshape(1, 1), next_t.reshape(1, 1),
              cnt_h.reshape(V, 1), cnt_t.reshape(V, 1),
              alloc_h.reshape(V, 1))
    _bump_dispatch()
    out = _cluster_call(meta, src, dst,
                        jnp.asarray(degrees, jnp.int32).reshape(V, 1),
                        *packed, xi=int(xi), kappa=int(kappa),
                        global_tail=bool(global_tail), block=blk,
                        interpret=interpret)
    return (out[0][:, 0], out[1][:, 0], out[2][:, 0], out[3][:, 0],
            out[4][:, 0], out[5][0, 0], out[6][0, 0], out[7][:, 0],
            out[8][:, 0], out[9][:, 0])


# ===================================================================
# Algorithm 3 placement pass
# ===================================================================


def _assign_kernel(meta_ref, src_ref, dst_ref, head_ref, pcu_ref, pcv_ref,
                   pin_ref, load_in, parts_ref, load_ref, *, k, block):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        load_ref[...] = load_in[...]

    limit = meta_ref[0]
    sign = meta_ref[1]
    cap = meta_ref[2]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)[0, :]

    def body(e, _):
        g = i * block + e
        u = src_ref[g]
        v = dst_ref[g]
        real = g < limit
        is_ins = sign > 0
        head = head_ref[g] != 0
        pcu = pcu_ref[g]
        pcv = pcv_ref[g]
        load = load_ref[0, :]
        lu = jnp.sum(jnp.where(iota == pcu, load, 0))
        lv = jnp.sum(jnp.where(iota == pcv, load, 0))
        over_u = lu >= cap
        over_v = lv >= cap
        room = load < cap
        any_room = jnp.any(room)
        first_room = jnp.argmax(room).astype(jnp.int32)
        # integer-equal to the oracle's k-1-argmax(room[::-1]) whenever
        # any_room holds (the only case the value is consumed)
        last_room = jnp.max(jnp.where(room, iota, -1)).astype(jnp.int32)
        fallback = jnp.argmin(load).astype(jnp.int32)
        overflow_choice = jnp.where(
            any_room, jnp.where(head, first_room, last_room), fallback)
        endpoint_choice = jnp.where(lu > lv, pcv, pcu)
        part_ins = jnp.where(over_u & over_v, overflow_choice,
                             endpoint_choice)
        p_ret = pin_ref[e]
        pick = jnp.where(is_ins, part_ins, jnp.maximum(p_ret, 0))
        placed = real & (u != v) & jnp.where(is_ins, True, p_ret >= 0)
        w = jnp.where(placed, sign, 0)
        load_ref[0, :] = load + jnp.where(iota == pick, w, 0)
        parts_ref[e] = jnp.where(
            is_ins, jnp.where(real & (u != v), part_ins, -1), p_ret)
        return 0

    jax.lax.fori_loop(0, block, body, 0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _assign_call(meta, src, dst, head, pcu, pcv, pin, load, *, block,
                 interpret):
    Epad = src.shape[0]
    k = load.shape[1]
    kernel = functools.partial(_assign_kernel, k=k, block=block)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(Epad // block,),
        in_specs=[_edge_spec(block), _const_spec((1, k))],
        out_specs=[_edge_spec(block), _const_spec((1, k))],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Epad,), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        input_output_aliases={6: 0, 7: 1},
        interpret=interpret,
    )(meta, src, dst, head, pcu, pcv, pin, load)


def assign_scan(load, src, dst, is_head_edge, pcu, pcv, *, max_load,
                sign: int = 1, parts=None, n_valid=None,
                block: int | None = None, interpret: bool | None = None):
    """One fused Algorithm-3 chunk — insert or retract.

    ``pcu``/``pcv`` are the endpoint **partition** ids (``c2p`` gathered
    outside, exactly as the oracle does).  Returns ``(parts, load)``.
    Mirrors ``core.postprocess._assign_chunk`` / ``_retract_load``.
    """
    if sign not in (1, -1):
        raise ValueError(f"sign must be +1 or -1, got {sign!r}")
    if sign < 0 and (n_valid is None or parts is None):
        raise ValueError("retract needs n_valid and recorded parts")
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    load = jnp.asarray(load, jnp.int32)
    E = src.shape[0]
    k = load.shape[0]
    if E == 0:
        return jnp.zeros((0,), jnp.int32), load
    blk, pad, interpret = _resolve(block, E, interpret)
    head = jnp.asarray(is_head_edge, jnp.int32)
    pcu = jnp.asarray(pcu, jnp.int32)
    pcv = jnp.asarray(pcv, jnp.int32)
    if pad:
        head = jnp.pad(head, (0, pad))
        pcu = jnp.pad(pcu, (0, pad))
        pcv = jnp.pad(pcv, (0, pad))
    src, dst, pin = _pad_edges(src, dst, parts, pad)
    limit = jnp.asarray(E if sign > 0 else n_valid, jnp.int32)
    meta = jnp.stack([limit, jnp.int32(sign),
                      jnp.asarray(max_load, jnp.int32)])
    _bump_dispatch()
    parts_out, load2 = _assign_call(meta, src, dst, head, pcu, pcv, pin,
                                    load.reshape(1, k), block=blk,
                                    interpret=interpret)
    return parts_out[:E], load2[0]
