"""Pallas TPU kernel: fused streaming scoring scan (one chunk per call).

The hot step shared by the replica-aware streaming partitioners (Greedy,
HDRF) is, per edge: gather both endpoints' replica-bitmap rows, score the
k partitions, argmin/argmax-pick, then update the load vector and the two
bitmap rows.  The ``lax.scan`` path materializes a fresh O(k|V|) carry per
step for XLA to DCE; here the whole chunk runs as one kernel with the
bitmap, load vector, and partial degrees resident in VMEM scratch-free
output buffers and a single sequential ``fori_loop`` over the chunk's
edges (the scan is inherently serial — the win is fusion, not
parallelism: one kernel launch, zero carry re-materialization).

Layout: row vectors are (1, k) (lane axis last, TPU-friendly); the
replica bitmap is (V, k) int32 0/1; partial degrees (V, 1).  The chunk's
edge ids and the state must fit VMEM — ``ops.py`` gates on a budget and
falls back to the oracle above it.

State is copied input→output once at kernel start, then updated in place;
per-edge math mirrors ``ref.py`` expression-for-expression so interpret
mode is bit-identical to the oracle (asserted by tests/test_streaming.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stream_scan_tpu"]

_INF_I32 = 2**30  # python int: jnp constants may not be captured by kernels


def _scan_kernel(src_ref, dst_ref, load_in, rep_in, pd_in, lam_ref,
                 parts_ref, load_ref, rep_ref, pd_ref, *, mode, eps, k):
    load_ref[...] = load_in[...]
    rep_ref[...] = rep_in[...]
    pd_ref[...] = pd_in[...]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(e, _):
        u = src_ref[e]
        v = dst_ref[e]
        valid = u != v
        load = load_ref[0, :]
        ru = rep_ref[u, :] > 0
        rv = rep_ref[v, :] > 0
        if mode == "hdrf":
            pd_ref[u, 0] = pd_ref[u, 0] + 1
            pd_ref[v, 0] = pd_ref[v, 0] + 1
            du = pd_ref[u, 0].astype(jnp.float32)
            dv = pd_ref[v, 0].astype(jnp.float32)
            theta_u = du / (du + dv)
            theta_v = 1.0 - theta_u
            g_u = jnp.where(ru, 1.0 + (1.0 - theta_u), 0.0)
            g_v = jnp.where(rv, 1.0 + (1.0 - theta_v), 0.0)
            loadf = load.astype(jnp.float32)
            maxl = jnp.max(loadf)
            minl = jnp.min(loadf)
            bal = (maxl - loadf) / (eps + maxl - minl)
            score = g_u + g_v + lam_ref[0, 0] * bal
            pick = jnp.argmax(score).astype(jnp.int32)
        else:  # greedy
            both = ru & rv
            either = ru | rv
            case1 = jnp.any(both)
            case2 = jnp.any(ru) & jnp.any(rv)
            case3 = jnp.any(either)
            mask = jnp.where(
                case1, both, jnp.where(case2, either, jnp.where(case3, either, True))
            )
            score = jnp.where(mask, load, _INF_I32)
            pick = jnp.argmin(score).astype(jnp.int32)
        hit = (iota[0, :] == pick) & valid
        load_ref[0, :] = load + hit.astype(jnp.int32)
        rep_ref[u, :] = jnp.maximum(rep_ref[u, :], hit.astype(jnp.int32))
        rep_ref[v, :] = jnp.maximum(rep_ref[v, :], hit.astype(jnp.int32))
        parts_ref[e] = jnp.where(valid, pick, -1)
        return 0

    jax.lax.fori_loop(0, src_ref.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret"))
def _stream_scan_call(src, dst, load, rep, pd, lam, *, mode, eps, interpret):
    """Jitted pallas_call dispatch — one trace per (shape, mode), so chunked
    streams reuse the compiled kernel instead of re-tracing per chunk."""
    E = src.shape[0]
    V, k = rep.shape
    kernel = functools.partial(_scan_kernel, mode=mode, eps=eps, k=k)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((E,), lambda t: (0,)),
            pl.BlockSpec((E,), lambda t: (0,)),
            pl.BlockSpec((1, k), lambda t: (0, 0)),
            pl.BlockSpec((V, k), lambda t: (0, 0)),
            pl.BlockSpec((V, 1), lambda t: (0, 0)),
            pl.BlockSpec((1, 1), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((E,), lambda t: (0,)),
            pl.BlockSpec((1, k), lambda t: (0, 0)),
            pl.BlockSpec((V, k), lambda t: (0, 0)),
            pl.BlockSpec((V, 1), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E,), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
            jax.ShapeDtypeStruct((V, k), jnp.int32),
            jax.ShapeDtypeStruct((V, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        src,
        dst,
        load.reshape(1, k),
        rep,
        pd.reshape(V, 1),
        lam.reshape(1, 1),
    )


def stream_scan_tpu(src, dst, load, rep, pd, lam, *, mode: str,
                    eps: float = 1e-3, interpret: bool | None = None):
    """Run one fused scoring-scan chunk.

    src/dst: (E,) int32; load: (k,) int32; rep: (V, k) int32 0/1 bitmap;
    pd: (V,) int32 partial degrees (ignored for mode="greedy");
    lam: scalar f32 (HDRF λ).  Returns (parts (E,), load, rep, pd).
    """
    if mode not in ("greedy", "hdrf"):
        raise ValueError(f"unknown mode {mode!r}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    parts, load2, rep2, pd2 = _stream_scan_call(
        jnp.asarray(src, jnp.int32),
        jnp.asarray(dst, jnp.int32),
        jnp.asarray(load, jnp.int32),
        jnp.asarray(rep, jnp.int32),
        jnp.asarray(pd, jnp.int32),
        jnp.asarray(lam, jnp.float32),
        mode=mode, eps=eps, interpret=interpret,
    )
    return parts, load2[0], rep2, pd2[:, 0]
