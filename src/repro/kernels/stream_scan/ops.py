"""Dispatch layer: pick the fused Pallas chunk step or the scan oracle.

``make_chunk_fn(mode)`` returns a chunk function with the engine contract
``(carry, src, dst) -> (carry, parts)``.  On TPU (state within the VMEM
budget) it runs the fused kernel; on CPU — where Pallas interpret mode is
correctness-only — it runs the compiled ``lax.scan`` oracle.  Both paths
produce bit-identical parts (tests/test_streaming.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import stream_scan_tpu
from . import ref as _ref

__all__ = ["make_chunk_fn", "kernel_fits"]

_VMEM_STATE_BUDGET = 8 << 20  # bytes of bitmap+chunk state the kernel may hold


def kernel_fits(n_vertices: int, k: int, chunk_size: int) -> bool:
    state = n_vertices * k * 4 + n_vertices * 4 + 2 * chunk_size * 4
    return state <= _VMEM_STATE_BUDGET


def _greedy_kernel_chunk(carry, src, dst):
    load, rep = carry
    if not kernel_fits(rep.shape[0], rep.shape[1], src.shape[0]):
        return _ref.greedy_chunk(carry, src, dst)  # VMEM-gated fallback
    parts, load2, rep2, _ = stream_scan_tpu(
        src, dst, load, rep.astype(jnp.int32),
        jnp.zeros((rep.shape[0],), jnp.int32), jnp.float32(0.0), mode="greedy",
    )
    return (load2, rep2 > 0), parts


def _hdrf_kernel_chunk(carry, src, dst):
    load, rep, pd, lam, kmask = carry
    if not kernel_fits(rep.shape[0], rep.shape[1], src.shape[0]):
        return _ref.hdrf_chunk(carry, src, dst)  # VMEM-gated fallback
    parts, load2, rep2, pd2 = stream_scan_tpu(
        src, dst, load, rep.astype(jnp.int32), pd, lam, mode="hdrf",
    )
    return (load2, rep2 > 0, pd2, lam, kmask), parts


def make_chunk_fn(mode: str, *, use_kernel: bool | None = None):
    """Chunk function for ``streaming.run_scan``.

    ``use_kernel=None`` auto-selects: the fused kernel on TPU, the oracle
    scan elsewhere (interpret-mode Pallas is orders slower than XLA's
    compiled scan on CPU).  The kernel path does not implement the padded
    multi-k mask, so batched multi-k runs must use the oracle.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if mode == "greedy":
        return _greedy_kernel_chunk if use_kernel else _ref.greedy_chunk
    if mode == "hdrf":
        return _hdrf_kernel_chunk if use_kernel else _ref.hdrf_chunk
    if mode == "grid":
        return _ref.grid_chunk  # O(k) carry — no bitmap, nothing to fuse
    raise ValueError(f"unknown mode {mode!r}")
