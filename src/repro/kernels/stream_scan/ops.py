"""Dispatch layer: pick the fused Pallas chunk step or the scan oracle.

``make_chunk_fn(mode)`` returns a chunk function with the engine contract
``(carry, src, dst) -> (carry, parts)``.  On TPU (state within the VMEM
budget) it runs the fused kernel; on CPU — where Pallas interpret mode is
correctness-only — it runs the compiled ``lax.scan`` oracle.  Both paths
produce bit-identical parts (tests/test_streaming.py).

The scoring baselines' :class:`~repro.streaming.carry.PartitionerCarry`
implementations live here too (``GreedyCarry`` / ``HdrfCarry`` /
``GridCarry``): they wrap the oracle/kernel dispatch as ``step_chunk`` and
declare the parallel-ingest merge algebra — counted replica tables and
loads/partial degrees SUM, scenario constants (λ, k-mask, grid tables)
replicated — so oracle and kernel stay in lockstep behind one protocol
surface.  All three implement :meth:`~repro.streaming.carry
.PartitionerCarry.retract_chunk` **exactly**: given the per-edge parts
recorded at insertion, deleting an edge subtracts precisely the load /
replica-count / partial-degree accounting its insertion added.

Kernel note: the fused kernel scores against the OR-projection (``> 0``)
of the counted replica table — which is all scoring ever reads — and
writes back a saturated 0/1 table; the wrapper therefore keeps the exact
counters itself with one vectorized scatter-add over the chunk's picks,
so kernel and oracle paths maintain identical counted state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...streaming.carry import COUNTED, REPLICATED, SUM, PartitionerCarry
from .kernel import stream_scan_tpu
from . import ref as _ref

__all__ = ["make_chunk_fn", "kernel_fits", "GreedyCarry", "HdrfCarry",
           "GridCarry"]

_VMEM_STATE_BUDGET = 8 << 20  # bytes of bitmap+chunk state the kernel may hold


def kernel_fits(n_vertices: int, k: int, chunk_size: int) -> bool:
    state = n_vertices * k * 4 + n_vertices * 4 + 2 * chunk_size * 4
    return state <= _VMEM_STATE_BUDGET


@jax.jit
def _recount(rep, src, dst, parts):
    """Fold a chunk's picks into the counted replica table (kernel path)."""
    w = ((src != dst) & (parts >= 0)).astype(jnp.int32)
    p = jnp.maximum(parts, 0)
    rep = rep.at[src, p].add(w)
    rep = rep.at[dst, p].add(w)
    return rep


def _greedy_kernel_chunk(carry, src, dst):
    load, rep = carry
    if not kernel_fits(rep.shape[0], rep.shape[1], src.shape[0]):
        return _ref.greedy_chunk(carry, src, dst)  # VMEM-gated fallback
    parts, load2, _, _ = stream_scan_tpu(
        src, dst, load, rep,
        jnp.zeros((rep.shape[0],), jnp.int32), jnp.float32(0.0), mode="greedy",
    )
    return (load2, _recount(rep, src, dst, parts)), parts


def _hdrf_kernel_chunk(carry, src, dst):
    load, rep, pd, lam, kmask = carry
    if not kernel_fits(rep.shape[0], rep.shape[1], src.shape[0]):
        return _ref.hdrf_chunk(carry, src, dst)  # VMEM-gated fallback
    parts, load2, _, pd2 = stream_scan_tpu(
        src, dst, load, rep, pd, lam, mode="hdrf",
    )
    return (load2, _recount(rep, src, dst, parts), pd2, lam, kmask), parts


def make_chunk_fn(mode: str, *, use_kernel: bool | None = None):
    """Chunk function for ``streaming.run_scan``.

    ``use_kernel=None`` auto-selects: the fused kernel on TPU, the oracle
    scan elsewhere (interpret-mode Pallas is orders slower than XLA's
    compiled scan on CPU).  The kernel path does not implement the padded
    multi-k mask, so batched multi-k runs must use the oracle.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if mode == "greedy":
        return _greedy_kernel_chunk if use_kernel else _ref.greedy_chunk
    if mode == "hdrf":
        return _hdrf_kernel_chunk if use_kernel else _ref.hdrf_chunk
    if mode == "grid":
        return _ref.grid_chunk  # O(k) carry — no bitmap, nothing to fuse
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# PartitionerCarry implementations (oracle/kernel dispatch behind one surface)
# ---------------------------------------------------------------------------


class GreedyCarry(PartitionerCarry):
    """PowerGraph Greedy as a carry: (load SUM, replica counters COUNTED)."""

    merge_ops = (SUM, COUNTED)
    supports_retract = True
    retract_exact = True

    def __init__(self, n_vertices: int, k: int, *, use_kernel: bool | None = None):
        self.n_vertices = int(n_vertices)
        self.k = int(k)
        self._chunk_fn = make_chunk_fn("greedy", use_kernel=use_kernel)

    def init(self):
        return _ref.greedy_init(self.n_vertices, self.k)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        return self._chunk_fn(carry, src, dst)

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        return _ref.greedy_retract_chunk(carry, src, dst, n_valid, parts)


class HdrfCarry(PartitionerCarry):
    """HDRF as a carry: (load SUM, replica counters COUNTED, partial
    degrees SUM, λ replicated, active-partition mask replicated)."""

    merge_ops = (SUM, COUNTED, SUM, REPLICATED, REPLICATED)
    supports_retract = True
    retract_exact = True

    def __init__(self, n_vertices: int, k: int, lam: float = 1.1, *,
                 k_active: int | None = None, use_kernel: bool | None = None):
        self.n_vertices = int(n_vertices)
        self.k = int(k)
        self.lam = float(lam)
        self.k_active = k_active
        self._chunk_fn = make_chunk_fn("hdrf", use_kernel=use_kernel)

    def init(self):
        return _ref.hdrf_init(self.n_vertices, self.k, self.lam,
                              k_active=self.k_active)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        return self._chunk_fn(carry, src, dst)

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        return _ref.hdrf_retract_chunk(carry, src, dst, n_valid, parts)


class GridCarry(PartitionerCarry):
    """Grid partitioning as a carry: (load SUM, row/col/#cols replicated)."""

    merge_ops = (SUM, REPLICATED, REPLICATED, REPLICATED)
    supports_retract = True
    retract_exact = True

    def __init__(self, k: int, row, col, n_cols: int):
        self.k = int(k)
        self.row = row
        self.col = col
        self.n_cols = int(n_cols)

    def init(self):
        return _ref.grid_init(self.k, self.row, self.col, self.n_cols)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        return _ref.grid_chunk(carry, src, dst)

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        return _ref.grid_retract_chunk(carry, src, dst, n_valid, parts)
