"""Dispatch layer: the fused → tiled → oracle degradation ladder.

``select_path`` picks, per (state size, chunk size), how a chunk runs:

- **fused** — the whole per-vertex state fits the VMEM budget; one
  blocked-grid megakernel dispatch per chunk with the state VMEM-resident
  across grid steps;
- **tiled** — the replica table (and HDRF partial degrees) would blow the
  budget; same single dispatch, but the table stays HBM-resident and the
  kernel gathers/scatters rows manually (``pl.load``/``pl.store``);
- **oracle** — even the edge-id prefetch doesn't fit (or the consumer has
  no kernel variant): the jitted ``lax.scan`` reference.

The budget resolves explicit argument → ``REPRO_VMEM_BUDGET`` env var →
8 MiB default, and the chosen path is logged once per (consumer, mode,
path) per process (``reset_path_log`` re-arms it, e.g. for tests).

The scoring baselines' :class:`~repro.streaming.carry.PartitionerCarry`
implementations live here too (``GreedyCarry`` / ``HdrfCarry`` /
``GridCarry``): they wrap the ladder dispatch as ``step_chunk`` /
``retract_chunk`` and declare the parallel-ingest merge algebra — counted
replica tables COUNTED, loads/partial degrees SUM, scenario constants
(λ, k-mask, grid tables) replicated — so oracle and kernel stay in
lockstep behind one protocol surface.  Since the counted megakernel,
**retraction is the same kernel invoked with ``sign=-1``**: the replica
counters update in-kernel (the seed's separate ``_recount`` scatter-add
patch is gone), and deleting an edge subtracts exactly the load /
replica-count / partial-degree accounting its insertion added.
"""

from __future__ import annotations

import logging
import os

import jax
import jax.numpy as jnp

from ...streaming.carry import COUNTED, REPLICATED, SUM, PartitionerCarry
from .kernel import scoring_scan
from . import ref as _ref

__all__ = [
    "DEFAULT_VMEM_BUDGET",
    "GreedyCarry",
    "GridCarry",
    "HdrfCarry",
    "VMEM_BUDGET_ENV",
    "cluster_state_bytes",
    "kernel_fits",
    "make_chunk_fn",
    "reset_path_log",
    "scoring_state_bytes",
    "select_path",
    "vmem_budget",
]

DEFAULT_VMEM_BUDGET = 8 << 20
VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET"

_log = logging.getLogger(__name__)
_logged_paths: set[tuple] = set()


def vmem_budget(explicit: int | None = None) -> int:
    """Resolve the VMEM budget: explicit arg → env var → 8 MiB default."""
    if explicit is not None:
        return int(explicit)
    env = os.environ.get(VMEM_BUDGET_ENV)
    if env:
        return int(env)
    return DEFAULT_VMEM_BUDGET


def scoring_state_bytes(n_vertices: int, k: int, mode: str = "hdrf") -> int:
    """VMEM-resident state of the fused scoring kernel (int32 bytes)."""
    pd = n_vertices * 4 if mode == "hdrf" else 0
    return n_vertices * k * 4 + k * 4 + pd


def cluster_state_bytes(n_vertices: int) -> int:
    """VMEM-resident state of the fused Algorithm-1 kernel: 8 (V,) leaves,
    2 (V+1,) volume arrays, the degree table, 2 scalar id counters."""
    return (11 * n_vertices + 4) * 4


def _ids_bytes(chunk_size: int) -> int:
    return 2 * chunk_size * 4  # scalar-prefetched src + dst


def select_path(n_vertices: int, k: int, chunk_size: int, *,
                mode: str = "hdrf", budget: int | None = None,
                consumer: str = "stream_scan") -> str:
    """Pick ``"fused" | "tiled" | "oracle"`` for one chunk and log the
    choice once per run."""
    b = vmem_budget(budget)
    ids = _ids_bytes(chunk_size)
    if consumer == "cluster":
        state = cluster_state_bytes(n_vertices)
        path = "fused" if state + ids <= b else "oracle"
    else:
        state = scoring_state_bytes(n_vertices, k, mode)
        if state + ids <= b:
            path = "fused"
        elif ids + k * 4 <= b:
            path = "tiled"
        else:
            path = "oracle"
    key = (consumer, mode, path)
    if key not in _logged_paths:
        _logged_paths.add(key)
        _log.info(
            "%s[%s]: %s path (state %.1f KiB + ids %.1f KiB, budget %.1f MiB)",
            consumer, mode, path, state / 1024, ids / 1024, b / (1 << 20))
    return path


def reset_path_log() -> None:
    """Re-arm the once-per-run path logging (used by tests)."""
    _logged_paths.clear()


def kernel_fits(n_vertices: int, k: int, chunk_size: int, *,
                mode: str = "hdrf", budget: int | None = None) -> bool:
    """Back-compat gate: does the *fused* path fit the VMEM budget?"""
    state = scoring_state_bytes(n_vertices, k, mode)
    return state + _ids_bytes(chunk_size) <= vmem_budget(budget)


# ---------------------------------------------------------------------------
# ladder-dispatching chunk functions (engine contract)
# ---------------------------------------------------------------------------


def _greedy_kernel_chunk(carry, src, dst, *, budget=None):
    load, rep = carry
    path = select_path(rep.shape[0], rep.shape[1], src.shape[0],
                       mode="greedy", budget=budget)
    if path == "oracle":
        return _ref.greedy_chunk(carry, src, dst)
    parts, load2, rep2, _ = scoring_scan(
        src, dst, load, rep, mode="greedy", tiled=(path == "tiled"))
    return (load2, rep2), parts


def _greedy_kernel_retract(carry, src, dst, n_valid, parts, *, budget=None):
    load, rep = carry
    path = select_path(rep.shape[0], rep.shape[1], src.shape[0],
                       mode="greedy", budget=budget)
    if path == "oracle":
        return _ref.greedy_retract_chunk(carry, src, dst, n_valid, parts)
    _, load2, rep2, _ = scoring_scan(
        src, dst, load, rep, mode="greedy", sign=-1, parts=parts,
        n_valid=n_valid, tiled=(path == "tiled"))
    return (load2, rep2)


def _hdrf_kernel_chunk(carry, src, dst, *, budget=None):
    load, rep, pd, lam, kmask = carry
    path = select_path(rep.shape[0], rep.shape[1], src.shape[0],
                       mode="hdrf", budget=budget)
    if path == "oracle":
        return _ref.hdrf_chunk(carry, src, dst)
    parts, load2, rep2, pd2 = scoring_scan(
        src, dst, load, rep, pd, lam, mode="hdrf", tiled=(path == "tiled"))
    return (load2, rep2, pd2, lam, kmask), parts


def _hdrf_kernel_retract(carry, src, dst, n_valid, parts, *, budget=None):
    load, rep, pd, lam, kmask = carry
    path = select_path(rep.shape[0], rep.shape[1], src.shape[0],
                       mode="hdrf", budget=budget)
    if path == "oracle":
        return _ref.hdrf_retract_chunk(carry, src, dst, n_valid, parts)
    _, load2, rep2, pd2 = scoring_scan(
        src, dst, load, rep, pd, lam, mode="hdrf", sign=-1, parts=parts,
        n_valid=n_valid, tiled=(path == "tiled"))
    return (load2, rep2, pd2, lam, kmask)


def _auto_use_kernel(use_kernel: bool | None) -> bool:
    """None → the fused kernel on TPU, the oracle scan elsewhere
    (interpret-mode Pallas is orders slower than XLA's compiled scan)."""
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return bool(use_kernel)


def make_chunk_fn(mode: str, *, use_kernel: bool | None = None,
                  vmem_budget: int | None = None):
    """Chunk function for ``streaming.run_scan``.

    The kernel path does not implement the padded multi-k mask, so
    batched multi-k runs must use the oracle.
    """
    kern = _auto_use_kernel(use_kernel)
    if mode == "greedy":
        if kern:
            return lambda c, s, d: _greedy_kernel_chunk(c, s, d,
                                                        budget=vmem_budget)
        return _ref.greedy_chunk
    if mode == "hdrf":
        if kern:
            return lambda c, s, d: _hdrf_kernel_chunk(c, s, d,
                                                      budget=vmem_budget)
        return _ref.hdrf_chunk
    if mode == "grid":
        return _ref.grid_chunk  # O(k) carry — no replica table, nothing to fuse
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# PartitionerCarry implementations (oracle/kernel dispatch behind one surface)
# ---------------------------------------------------------------------------


class GreedyCarry(PartitionerCarry):
    """PowerGraph Greedy as a carry: (load SUM, replica counters COUNTED)."""

    merge_ops = (SUM, COUNTED)
    supports_retract = True
    retract_exact = True

    def __init__(self, n_vertices: int, k: int, *,
                 use_kernel: bool | None = None,
                 vmem_budget: int | None = None):
        self.n_vertices = int(n_vertices)
        self.k = int(k)
        self._use_kernel = _auto_use_kernel(use_kernel)
        self._budget = vmem_budget

    def init(self):
        return _ref.greedy_init(self.n_vertices, self.k)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        if self._use_kernel:
            return _greedy_kernel_chunk(carry, src, dst, budget=self._budget)
        return _ref.greedy_chunk(carry, src, dst)

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        if self._use_kernel:
            return _greedy_kernel_retract(carry, src, dst, n_valid, parts,
                                          budget=self._budget)
        return _ref.greedy_retract_chunk(carry, src, dst, n_valid, parts)


class HdrfCarry(PartitionerCarry):
    """HDRF as a carry: (load SUM, replica counters COUNTED, partial
    degrees SUM, λ replicated, active-partition mask replicated).

    The kernel scores without the padded multi-k mask, so a carry with
    ``k_active < k`` always runs the oracle."""

    merge_ops = (SUM, COUNTED, SUM, REPLICATED, REPLICATED)
    supports_retract = True
    retract_exact = True

    def __init__(self, n_vertices: int, k: int, lam: float = 1.1, *,
                 k_active: int | None = None,
                 use_kernel: bool | None = None,
                 vmem_budget: int | None = None):
        self.n_vertices = int(n_vertices)
        self.k = int(k)
        self.lam = float(lam)
        self.k_active = k_active
        masked = k_active is not None and int(k_active) != int(k)
        self._use_kernel = _auto_use_kernel(use_kernel) and not masked
        self._budget = vmem_budget

    def init(self):
        return _ref.hdrf_init(self.n_vertices, self.k, self.lam,
                              k_active=self.k_active)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        if self._use_kernel:
            return _hdrf_kernel_chunk(carry, src, dst, budget=self._budget)
        return _ref.hdrf_chunk(carry, src, dst)

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        if self._use_kernel:
            return _hdrf_kernel_retract(carry, src, dst, n_valid, parts,
                                        budget=self._budget)
        return _ref.hdrf_retract_chunk(carry, src, dst, n_valid, parts)


class GridCarry(PartitionerCarry):
    """Grid partitioning as a carry: (load SUM, row/col/#cols replicated)."""

    merge_ops = (SUM, REPLICATED, REPLICATED, REPLICATED)
    supports_retract = True
    retract_exact = True

    def __init__(self, k: int, row, col, n_cols: int):
        self.k = int(k)
        self.row = row
        self.col = col
        self.n_cols = int(n_cols)

    def init(self):
        return _ref.grid_init(self.k, self.row, self.col, self.n_cols)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        return _ref.grid_chunk(carry, src, dst)

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        return _ref.grid_retract_chunk(carry, src, dst, n_valid, parts)
