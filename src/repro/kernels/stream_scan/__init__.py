"""Fused streaming megakernels (scoring scan, Alg. 1 fold, Alg. 3 place).

kernel.py — the blocked-grid Pallas megakernels (one dispatch per chunk,
insert and retract via a ``sign`` operand); ops.py — the fused → tiled →
oracle degradation ladder plus the engine-facing carries; ref.py — the
seed ``lax.scan`` oracles (bit-identical contract).
"""

from .kernel import (  # noqa: F401
    DEFAULT_BLOCK,
    assign_scan,
    cluster_scan,
    dispatch_count,
    reset_dispatch_count,
    scoring_scan,
    stream_scan_tpu,
)
from .ops import (  # noqa: F401
    DEFAULT_VMEM_BUDGET,
    VMEM_BUDGET_ENV,
    GreedyCarry,
    GridCarry,
    HdrfCarry,
    cluster_state_bytes,
    kernel_fits,
    make_chunk_fn,
    reset_path_log,
    scoring_state_bytes,
    select_path,
    vmem_budget,
)
from .ref import (  # noqa: F401
    assign_chunk_oracle,
    cluster_chunk_oracle,
    greedy_chunk,
    greedy_init,
    greedy_retract_chunk,
    grid_chunk,
    grid_init,
    grid_retract_chunk,
    hdrf_chunk,
    hdrf_init,
    hdrf_retract_chunk,
)
