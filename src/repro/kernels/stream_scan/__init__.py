"""Fused streaming scoring-scan kernel (replica bitmap + score + load).

kernel.py — the Pallas kernel; ops.py — engine-facing dispatch with CPU
fallback; ref.py — the seed ``lax.scan`` oracles (bit-identical contract).
"""

from .kernel import stream_scan_tpu  # noqa: F401
from .ops import (  # noqa: F401
    GreedyCarry,
    GridCarry,
    HdrfCarry,
    kernel_fits,
    make_chunk_fn,
)
from .ref import (  # noqa: F401
    greedy_chunk,
    greedy_init,
    greedy_retract_chunk,
    grid_chunk,
    grid_init,
    grid_retract_chunk,
    hdrf_chunk,
    hdrf_init,
    hdrf_retract_chunk,
)
