from .ops import cms_update_kernel, cms_query_kernel  # noqa: F401
