"""Drop-in CMSketch ops backed by the Pallas kernel."""

from __future__ import annotations

from ...core.cms import CMSketch
from .kernel import cms_query_tpu, cms_update_tpu

__all__ = ["cms_update_kernel", "cms_query_kernel"]


def cms_update_kernel(sketch: CMSketch, keys, counts=None) -> CMSketch:
    delta = cms_update_tpu(keys, sketch.seeds, sketch.width, sketch.depth, counts)
    return CMSketch(table=sketch.table + delta, seeds=sketch.seeds)


def cms_query_kernel(sketch: CMSketch, keys):
    return cms_query_tpu(sketch.table, keys, sketch.seeds)
