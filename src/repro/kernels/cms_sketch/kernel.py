"""Pallas TPU kernel for batched count-min-sketch update / query.

The paper's §4.4 hot loop: every streamed edge posts its cluster-pair key
into a (d × w) sketch.  TPU adaptation: per key block, the per-row column
histogram is built with a **one-hot compare against a column iota** and
reduced on the VPU — no scatter (TPU-hostile) anywhere:

    update:  table[r] += Σ_n  (cols[r, n] == iota_w)
    query:   est[n]    = min_r Σ_w table[r] · (cols[r, n] == iota_w)

Grid: key blocks; the (d, w) table block is revisited every step
(accumulator output).  Hashing is the same uint32 avalanche as
``repro.core.cms`` (bit-exact — tests compare against it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cms_update_tpu", "cms_query_tpu"]

# plain ints: jnp constants at module scope would be captured closures,
# which pallas kernels reject — cast at use instead
_GOLDEN = 0x9E3779B1
_MIX1 = 0x85EBCA6B
_MIX2 = 0xC2B2AE35


def _avalanche(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_MIX1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_MIX2)
    h = h ^ (h >> 16)
    return h


def _cols(keys, seeds, width):
    """(n,) keys × (d,) seeds → (d, n) int32 columns."""
    h = _avalanche(keys[None, :] ^ seeds[:, None] * jnp.uint32(_GOLDEN))
    return (h % jnp.uint32(width)).astype(jnp.int32)


def _update_kernel(keys_ref, counts_ref, seeds_ref, table_ref, *, width, n_blocks):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        table_ref[...] = jnp.zeros_like(table_ref)

    keys = keys_ref[...]
    counts = counts_ref[...].astype(jnp.uint32)
    seeds = seeds_ref[...]
    cols = _cols(keys, seeds, width)  # (d, n)
    iota = jax.lax.broadcasted_iota(jnp.int32, (width,), 0)
    # histogram per row: (d, w) += Σ_n onehot(cols) · counts
    onehot = (cols[:, :, None] == iota[None, None, :]).astype(jnp.uint32)
    table_ref[...] += jnp.sum(onehot * counts[None, :, None], axis=1)


def _query_kernel(keys_ref, seeds_ref, table_ref, out_ref, *, width):
    keys = keys_ref[...]
    seeds = seeds_ref[...]
    cols = _cols(keys, seeds, width)  # (d, n)
    iota = jax.lax.broadcasted_iota(jnp.int32, (width,), 0)
    onehot = (cols[:, :, None] == iota[None, None, :]).astype(jnp.uint32)
    vals = jnp.sum(onehot * table_ref[...][:, None, :], axis=2)  # (d, n)
    out_ref[...] = jnp.min(vals, axis=0)


def cms_update_tpu(keys, seeds, width, depth, counts=None, *, block=1024,
                   interpret=None):
    """keys: (N,) uint32 → (depth, width) uint32 table."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = keys.shape[0]
    if counts is None:
        counts = jnp.ones((n,), jnp.uint32)
    pad = (-n) % block
    if pad:
        keys = jnp.pad(keys, (0, pad))
        counts = jnp.pad(counts, (0, pad))
    n_blocks = keys.shape[0] // block
    kernel = functools.partial(_update_kernel, width=width, n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((depth,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((depth, width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.uint32),
        interpret=interpret,
    )(keys, counts, seeds)


def cms_query_tpu(table, keys, seeds, *, block=1024, interpret=None):
    """Point queries: (N,) keys → (N,) uint32 min-estimates."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    depth, width = table.shape
    n = keys.shape[0]
    pad = (-n) % block
    if pad:
        keys = jnp.pad(keys, (0, pad))
    n_blocks = keys.shape[0] // block
    kernel = functools.partial(_query_kernel, width=width)
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((depth,), lambda i: (0,)),
            pl.BlockSpec((depth, width), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block,), jnp.uint32),
        interpret=interpret,
    )(keys, seeds, table)
    return out[:n]
