"""Oracle: repro.core.cms is the reference implementation (same hashing)."""

from __future__ import annotations

import jax.numpy as jnp

from ...core.cms import CMSketch, cms_query, cms_update


def update_ref(keys, seeds, width, depth, counts=None):
    sk = CMSketch(table=jnp.zeros((depth, width), jnp.uint32), seeds=seeds)
    sk = cms_update(sk, keys, counts)
    return sk.table


def query_ref(table, keys, seeds):
    return cms_query(CMSketch(table=table, seeds=seeds), keys)
