"""Jit wrapper: model layout (B, S, H, hd) ↔ kernel layout (B·KV, S, G·hd).

On CPU (this container) ``interpret=True`` executes the kernel body in
Python for validation; on TPU the same call compiles to Mosaic.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd

__all__ = ["flash_attention_tpu"]


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention_tpu(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                        block_q=128, block_k=128, interpret=None):
    """q: (B, S, H, hd); k/v: (B, T, KV, hd); positions (B, S)/(B, T)."""
    if interpret is None:
        interpret = _is_cpu()
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    # fold (B, KV) and group the G q-heads per kv head
    qk = q.reshape(B, S, KV, G, hd).transpose(0, 2, 1, 3, 4).reshape(
        B * KV, S, G * hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    qp = jnp.repeat(q_pos, KV, axis=0).reshape(B * KV, S) if q_pos.shape[0] == B \
        else q_pos
    kp = jnp.repeat(kv_pos, KV, axis=0).reshape(B * KV, T) if kv_pos.shape[0] == B \
        else kv_pos
    # pad to block multiples
    pads = (-S) % block_q
    padt = (-T) % block_k
    if pads:
        qk = jnp.pad(qk, ((0, 0), (0, pads), (0, 0)))
        qp = jnp.pad(qp, ((0, 0), (0, pads)), constant_values=-(2**30))
    if padt:
        kk = jnp.pad(kk, ((0, 0), (0, padt), (0, 0)))
        vk = jnp.pad(vk, ((0, 0), (0, padt), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, padt)), constant_values=-(2**30))
    out = flash_attention_fwd(
        qk, kk, vk, qp, kp, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    out = out[:, :S]
    return out.reshape(B, KV, S, G, hd).transpose(0, 2, 1, 3, 4).reshape(
        B, S, H, hd)
