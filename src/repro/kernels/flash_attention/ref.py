"""Pure-jnp oracle for the flash-attention kernel: direct softmax attention."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, q_pos, kv_pos, *, causal=True, window=None):
    """q: (BK, S, G·hd); k/v: (BK, T, hd) — same grouped layout as the kernel."""
    BK, S, Ghd = q.shape
    T, hd = k.shape[1], k.shape[2]
    g = Ghd // hd
    qh = q.reshape(BK, S * g, hd).astype(jnp.float32) * (hd ** -0.5)
    s = jnp.einsum("bqh,bth->bqt", qh, k.astype(jnp.float32))
    qp = jnp.repeat(q_pos, g, axis=1)  # (BK, S*g)
    dp = qp[:, :, None] - kv_pos[:, None, :]
    ok = kv_pos[:, None, :] >= 0
    if causal:
        ok = ok & (dp >= 0)
    if window is not None:
        ok = ok & (dp < window)
    s = jnp.where(ok, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bqt,bth->bqh", p, v.astype(jnp.float32))
    return out.reshape(BK, S, Ghd).astype(q.dtype)
