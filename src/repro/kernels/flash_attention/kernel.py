"""Pallas TPU flash-attention kernel (forward).

Grid: (batch·kv_head, q_blocks, kv_blocks) — the kv axis is innermost, so
the output block is revisited across kv steps and the online-softmax
running state lives in VMEM scratch (the canonical TPU flash layout).

BlockSpecs tile everything into VMEM:
  q:   (1, block_q, G·hd)     — one (batch, kv-head) group's q block
  k/v: (1, block_k, hd)
  out: (1, block_q, G·hd)

MXU alignment: block_q/block_k multiples of 128 (the wrapper pads), hd is
128 for every assigned LM arch.  Masking (causal / sliding window /
padding) is computed from block-relative iotas — no mask tensor is ever
materialized in HBM.

Backward uses the custom-VJP recompute path of
``repro.models.attention`` (same math); the kernel accelerates the
forward hot loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd"]

_NEG = -1e30


def _fa_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, causal, window, block_q, block_k,
               n_kv_blocks, scale):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (block_q, G*hd)
    k = k_ref[0]  # (block_k, hd)
    v = v_ref[0]
    hd = k.shape[-1]
    g = q.shape[-1] // hd
    qh = (q.reshape(block_q * g, hd) * scale).astype(q.dtype)
    s = jax.lax.dot_general(
        qh, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (block_q*g, block_k)

    qp = qpos_ref[0]  # (block_q,)
    kp = kpos_ref[0]  # (block_k,)
    qp = jnp.repeat(qp, g)  # (block_q*g,) — rows grouped per query
    dp = qp[:, None] - kp[None, :]
    ok = kp[None, :] >= 0
    if causal:
        ok = ok & (dp >= 0)
    if window is not None:
        ok = ok & (dp < window)
    s = jnp.where(ok, s, _NEG)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (block_q*g, hd)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_i == n_kv_blocks - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = out.reshape(block_q, g * hd).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                        block_q=128, block_k=128, interpret=False):
    """q: (BK, S, G·hd) grouped per (batch × kv-head); k/v: (BK, T, hd).

    The ops.py wrapper folds (B, H, KV) into this layout and unfolds the
    result.  S % block_q == 0 and T % block_k == 0 (wrapper pads).
    """
    BK, S, Ghd = q.shape
    T, hd = k.shape[1], k.shape[2]
    g = Ghd // hd
    n_q = S // block_q
    n_kv = T // block_k
    scale = hd ** -0.5

    kernel = functools.partial(
        _fa_kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, n_kv_blocks=n_kv, scale=scale,
    )
    grid = (BK, n_q, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),  # q_pos
            pl.BlockSpec((1, block_k), lambda b, i, j: (b, j)),  # kv_pos
            pl.BlockSpec((1, block_q, Ghd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Ghd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BK, S, Ghd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q * g, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q * g, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q_pos, kv_pos, q, k, v)
