from .ops import flash_attention_tpu  # noqa: F401
from .ref import attention_ref  # noqa: F401
