"""Pallas TPU kernels (pl.pallas_call + BlockSpec) for the hot paths.

Each subpackage: kernel.py (the Pallas kernel), ops.py (jit wrapper with
interpret-mode fallback on CPU), ref.py (pure-jnp oracle for tests).
"""
