"""Oracle: the jnp CIN layer from the model (identical math)."""

from ...models.recsys import _cin_layer


def cin_layer_ref(xk, x0, w):
    return _cin_layer(xk, x0, w)
