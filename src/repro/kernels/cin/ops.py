"""Jit wrapper for the CIN Pallas kernel."""

from functools import partial

import jax

from .kernel import cin_layer_tpu

__all__ = ["cin_layer_kernel"]


@partial(jax.jit, static_argnames=("batch_block", "interpret"))
def cin_layer_kernel(xk, x0, w, *, batch_block=256, interpret=None):
    return cin_layer_tpu(xk, x0, w, batch_block=batch_block, interpret=interpret)
