from .ops import cin_layer_kernel  # noqa: F401
from .ref import cin_layer_ref  # noqa: F401
