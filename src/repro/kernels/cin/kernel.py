"""Pallas TPU kernel for the xDeepFM CIN layer.

CIN step: ``out[b, h', d] = Σ_{h,m} W[h·m, h'] · xk[b,h,d] · x0[b,m,d]``.

TPU adaptation: grid over (batch tiles × embed-dim columns).  Per step the
(bt, Hk) × (bt, m) outer product along one embed column is flattened to a
(bt, Hk·m) matrix and contracted with W on the **MXU** — the op becomes a
dense GEMM per embedding column, which is exactly how the original 1×1-conv
formulation maps to a systolic array.  Blocks: xk (bt, Hk, 1), x0 (bt, m, 1),
W (Hk·m, H') resident, out (bt, H', 1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cin_layer_tpu"]


def _cin_kernel(xk_ref, x0_ref, w_ref, o_ref):
    xk = xk_ref[..., 0].astype(jnp.float32)  # (bt, Hk)
    x0 = x0_ref[..., 0].astype(jnp.float32)  # (bt, m)
    z = xk[:, :, None] * x0[:, None, :]  # (bt, Hk, m)
    bt = z.shape[0]
    zf = z.reshape(bt, -1)  # (bt, Hk·m)
    out = jax.lax.dot_general(
        zf, w_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bt, H')
    o_ref[...] = out[..., None].astype(o_ref.dtype)


def cin_layer_tpu(xk, x0, w, *, batch_block=256, interpret=None):
    """xk: (B, Hk, D); x0: (B, m, D); w: (Hk·m, H') → (B, H', D)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, Hk, D = xk.shape
    m = x0.shape[1]
    Hn = w.shape[1]
    pad = (-B) % batch_block
    if pad:
        xk = jnp.pad(xk, ((0, pad), (0, 0), (0, 0)))
        x0 = jnp.pad(x0, ((0, pad), (0, 0), (0, 0)))
    nb = xk.shape[0] // batch_block
    out = pl.pallas_call(
        _cin_kernel,
        grid=(nb, D),
        in_specs=[
            pl.BlockSpec((batch_block, Hk, 1), lambda b, d: (b, 0, d)),
            pl.BlockSpec((batch_block, m, 1), lambda b, d: (b, 0, d)),
            pl.BlockSpec((Hk * m, Hn), lambda b, d: (0, 0)),
        ],
        out_specs=pl.BlockSpec((batch_block, Hn, 1), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((nb * batch_block, Hn, D), xk.dtype),
        interpret=interpret,
    )(xk, x0, w)
    return out[:B]
