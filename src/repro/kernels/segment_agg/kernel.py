"""Pallas TPU kernel: fused gather → weight → segment-sum (GNN aggregation).

The message-passing primitive ``out[dst] += w_e · x[src]`` with edges
**pre-sorted by destination** (CSR order — the data pipeline emits this).
TPU adaptation (taxonomy §GNN / GE-SpMM): no scatter — each grid step owns
one *destination-row tile* and reduces its own edge bucket:

  grid step t:
    edges [t·eb, (t+1)·eb) — a fixed-size bucket whose dst rows all fall in
    [t·rb, (t+1)·rb)  (host-side bucketing pads with masked edges);
    gather x[src] rows one edge at a time (dynamic scalar VMEM indexing),
    accumulate into a (rb, d) VMEM scratch via a local one-hot reduce,
    write the tile once.

The feature table block must fit VMEM, so ops.py tiles the feature dim and
falls back to ``jax.ops.segment_sum`` above the VMEM node budget (the
fallback *is* the oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_agg_tpu"]


def _seg_kernel(src_ref, dstloc_ref, w_ref, x_ref, o_ref, *, edge_block,
                row_block, d):
    # gather per edge; accumulate with one-hot reduce over the local rows
    rows = jax.lax.broadcasted_iota(jnp.int32, (row_block,), 0)
    acc = jnp.zeros((row_block, d), jnp.float32)

    def body(j, acc):
        s = src_ref[j]
        dl = dstloc_ref[j]
        wj = w_ref[j]
        xrow = x_ref[s, :].astype(jnp.float32) * wj
        onehot = (rows == dl).astype(jnp.float32)  # dl < 0 ⇒ no row matches
        return acc + onehot[:, None] * xrow[None, :]

    acc = jax.lax.fori_loop(0, edge_block, body, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def segment_agg_tpu(x, src, dst_local, w, n_rows, *, edge_block, row_block,
                    interpret=None):
    """x: (V, d) feature table (fits one VMEM block); src: (E,) int32;
    dst_local: (E,) int32 — dst − tile_base, or −1 for padding;
    w: (E,) f32 edge weights.  E = n_tiles·edge_block, n_rows = n_tiles·row_block.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    V, d = x.shape
    n_tiles = n_rows // row_block
    kernel = functools.partial(_seg_kernel, edge_block=edge_block,
                               row_block=row_block, d=d)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((edge_block,), lambda t: (t,)),
            pl.BlockSpec((edge_block,), lambda t: (t,)),
            pl.BlockSpec((edge_block,), lambda t: (t,)),
            pl.BlockSpec((V, d), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, d), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, d), x.dtype),
        interpret=interpret,
    )(src, dst_local, w, x)
