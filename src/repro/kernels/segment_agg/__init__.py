from .ops import segment_aggregate  # noqa: F401
from .ref import segment_agg_ref  # noqa: F401
