"""Wrapper: host-side dst bucketing + kernel dispatch (+ jnp fallback).

``segment_aggregate`` takes an arbitrary edge list; it sorts by dst,
buckets edges into fixed-size tiles aligned with destination-row tiles,
and calls the Pallas kernel.  Above the VMEM node budget it falls back to
the oracle (documented: the kernel targets the molecule/minibatch regime).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .kernel import segment_agg_tpu
from .ref import segment_agg_ref

__all__ = ["segment_aggregate"]

_VMEM_NODE_BUDGET = 8192  # rows × d ≲ VMEM (16 MB) at d ≤ 256 f32


def segment_aggregate(x, src, dst, w=None, n_rows=None, *, row_block=128,
                      interpret=None):
    n_rows = n_rows or int(x.shape[0])
    if w is None:
        w = jnp.ones(src.shape, jnp.float32)
    if int(x.shape[0]) > _VMEM_NODE_BUDGET:
        return segment_agg_ref(x, src, dst, w, n_rows)

    # ---- host-side bucketing (part of the data pipeline in production) ----
    src_np = np.asarray(src)
    dst_np = np.asarray(dst)
    w_np = np.asarray(w)
    order = np.argsort(dst_np, kind="stable")
    src_np, dst_np, w_np = src_np[order], dst_np[order], w_np[order]
    n_tiles = -(-n_rows // row_block)
    rows_padded = n_tiles * row_block
    tile_of_edge = dst_np // row_block
    counts = np.bincount(tile_of_edge, minlength=n_tiles)
    edge_block = max(int(counts.max()), 1)
    # pad each tile's bucket to edge_block with masked edges
    E = n_tiles * edge_block
    bsrc = np.zeros(E, np.int32)
    bdst_local = np.full(E, -1, np.int32)
    bw = np.zeros(E, np.float32)
    offs = np.concatenate([[0], np.cumsum(counts)])
    for t in range(n_tiles):
        a, b = offs[t], offs[t + 1]
        k = b - a
        bsrc[t * edge_block:t * edge_block + k] = src_np[a:b]
        bdst_local[t * edge_block:t * edge_block + k] = dst_np[a:b] - t * row_block
        bw[t * edge_block:t * edge_block + k] = w_np[a:b]

    out = segment_agg_tpu(
        x, jnp.asarray(bsrc), jnp.asarray(bdst_local), jnp.asarray(bw),
        rows_padded, edge_block=edge_block, row_block=row_block,
        interpret=interpret,
    )
    return out[:n_rows]
