"""Oracle: plain gather + segment_sum."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_agg_ref(x, src, dst, w, n_rows):
    """out[dst] += w · x[src]; dst < 0 rows are dropped (padding)."""
    msg = x[src].astype(jnp.float32) * w[:, None]
    msg = jnp.where((dst >= 0)[:, None], msg, 0.0)
    return jax.ops.segment_sum(
        msg, jnp.maximum(dst, 0), num_segments=n_rows
    ).astype(x.dtype)
