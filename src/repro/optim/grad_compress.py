"""Gradient compression for bandwidth-bound data parallelism.

Two schemes, both with the standard distributed-optimization structure:

- :func:`topk_compress_update` — top-k sparsification with **error
  feedback** (Stich et al.): the residual of the dropped coordinates is
  carried into the next step, which preserves convergence.  In a mesh run
  the compressed (values, indices) are what crosses the DP axis instead of
  the dense gradient (k/n of the bytes).
- :func:`int8_compress` — stochastic-rounding int8 quantization with a
  per-tensor scale (1/4 of bf16 bytes on the wire); the dequantized
  all-reduce is exact in expectation.

Exposed as optimizer wrappers so the train loop composes them under the
same ``make_train_step`` contract; tests check the error-feedback
telescoping identity and quantization unbiasedness.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["topk_compress_update", "int8_compress", "CompressState"]


class CompressState(NamedTuple):
    residual: Any  # error-feedback memory, same tree as grads


def init_compress_state(params) -> CompressState:
    return CompressState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def _topk_mask(x: jax.Array, frac: float) -> jax.Array:
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def topk_compress_update(grads, state: CompressState, frac: float = 0.01):
    """Returns (compressed_grads, new_state, wire_bytes_fraction).

    compressed = top-k(grad + residual); residual' = input − compressed.
    """

    def one(g, r):
        g = g.astype(jnp.float32) + r
        mask = _topk_mask(g, frac)
        sent = g * mask
        return sent, g - sent

    pairs = jax.tree.map(one, grads, state.residual)
    sent = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    # wire cost: k values + k indices vs n values
    wire_frac = frac * (4 + 4) / 4
    return sent, CompressState(residual=resid), wire_frac


def int8_compress(g: jax.Array, key) -> tuple[jax.Array, jax.Array]:
    """Stochastic-rounding int8: returns (q (int8), scale).  Unbiased."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    scaled = g32 / scale
    low = jnp.floor(scaled)
    p_up = scaled - low
    up = jax.random.uniform(key, g.shape) < p_up
    q = (low + up.astype(jnp.float32)).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
