"""AdamW with fp32 master moments, global-norm clipping, LR schedules.

Plain-pytree implementation (no optax in this container).  Moments carry
the *same sharding tree* as the parameters — with FSDP rules every
optimizer tensor is fully sharded (ZeRO-equivalent), which is what keeps
the 14B configs inside v5e HBM at 512 chips.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "TrainState", "init_state", "adamw_update", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Callable[[jax.Array], jax.Array] | None = None


class TrainState(NamedTuple):
    params: Any
    mu: Any
    nu: Any
    step: jax.Array


def init_state(params) -> TrainState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), step=jnp.int32(0))


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(state: TrainState, grads, cfg: AdamWConfig) -> TrainState:
    step = state.step + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree.map(upd, state.params, grads, state.mu, state.nu)
    params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return TrainState(params=params, mu=mu, nu=nu, step=step)


def make_train_step(loss_fn: Callable, model_cfg, opt_cfg: AdamWConfig):
    """loss_fn(params, batch, model_cfg) → (loss, metrics)."""

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch, model_cfg
        )
        new_state = adamw_update(state, grads, opt_cfg)
        out = dict(metrics)
        out["loss"] = loss
        return new_state, out

    return train_step
