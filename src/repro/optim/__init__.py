from .adamw import AdamWConfig, TrainState, init_state, adamw_update, make_train_step  # noqa: F401
from .schedules import cosine_schedule, linear_warmup  # noqa: F401
from .grad_compress import topk_compress_update, int8_compress  # noqa: F401
