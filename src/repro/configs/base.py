"""Architecture registry: ArchSpec ties a model config to its shape set.

Every assigned architecture gets one module defining an ``ARCH`` spec with
the exact published config, a reduced smoke config (CPU-runnable), its
input-shape cells, and any documented skips (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

__all__ = ["ArchSpec", "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES"]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str  # "lm" | "gnn" | "recsys"
    config: Any
    smoke_config: Any
    shapes: Mapping[str, Mapping[str, Any]]
    skips: Mapping[str, str] = dataclasses.field(default_factory=dict)
    notes: str = ""

    def runnable_shapes(self):
        return {k: v for k, v in self.shapes.items() if k not in self.skips}


# The assigned shape sets (identical within each family).
LM_SHAPES = {
    "train_4k": dict(kind="lm_train", seq=4096, batch=256),
    "prefill_32k": dict(kind="lm_prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="lm_decode", seq=32768, batch=128),
    "long_500k": dict(kind="lm_decode", seq=524288, batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": dict(kind="gnn_full", n_nodes=2708, n_edges=10556, d_feat=1433),
    "minibatch_lg": dict(kind="gnn_minibatch", n_nodes=232_965,
                         n_edges=114_615_892, batch_nodes=1024, fanout=(15, 10),
                         d_feat=602),
    "ogb_products": dict(kind="gnn_full", n_nodes=2_449_029, n_edges=61_859_140,
                         d_feat=100),
    "molecule": dict(kind="gnn_molecule", n_nodes=30, n_edges=64, batch=128),
}

RECSYS_SHAPES = {
    "train_batch": dict(kind="rec_train", batch=65536),
    "serve_p99": dict(kind="rec_serve", batch=512),
    "serve_bulk": dict(kind="rec_serve", batch=262144),
    "retrieval_cand": dict(kind="rec_retrieval", batch=1, n_candidates=1_000_000),
}
