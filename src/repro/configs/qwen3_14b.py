"""Qwen3-14B: 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.

qk_norm + GQA [hf:Qwen/Qwen3-8B family].  Full attention ⇒ long_500k skip.
"""
from ..models.lm import LMConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    name="qwen3-14b",
    family="lm",
    config=LMConfig(
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
        d_ff=17408, vocab=151936, qk_norm=True, rope_theta=1e6,
    ),
    smoke_config=LMConfig(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab=512, qk_norm=True, rope_theta=1e6, attn_chunk=64,
    ),
    shapes=LM_SHAPES,
    skips={"long_500k": "pure full attention — no sub-quadratic path (DESIGN.md §4)"},
)
