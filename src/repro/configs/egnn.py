"""EGNN: n_layers=4 d_hidden=64, E(n)-equivariant [arXiv:2102.09844]."""
from ..models.gnn import EGNNConfig
from .base import ArchSpec, GNN_SHAPES

ARCH = ArchSpec(
    name="egnn",
    family="gnn",
    config=EGNNConfig(n_layers=4, d_hidden=64),
    smoke_config=EGNNConfig(n_layers=2, d_hidden=16),
    shapes=GNN_SHAPES,
)
