"""Qwen2.5-14B: 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.

GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B family].  Pure full attention ⇒
long_500k is a documented skip (DESIGN.md §4).
"""
from ..models.lm import LMConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    name="qwen2.5-14b",
    family="lm",
    config=LMConfig(
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
        d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1e6,
    ),
    smoke_config=LMConfig(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab=512, qkv_bias=True, rope_theta=1e6, attn_chunk=64,
    ),
    shapes=LM_SHAPES,
    skips={"long_500k": "pure full attention — no sub-quadratic path (DESIGN.md §4)"},
)
