"""Mixtral-8x7B: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention [arXiv:2401.04088].

SWA ⇒ long_500k RUNS (rolling window-sized KV cache).
"""
from ..models.lm import LMConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    name="mixtral-8x7b",
    family="lm",
    config=LMConfig(
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=14336, vocab=32000, sliding_window=4096, n_experts=8, top_k=2,
        rope_theta=1e6,
    ),
    smoke_config=LMConfig(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab=512, sliding_window=32, n_experts=4, top_k=2,
        rope_theta=1e6, attn_chunk=64,
    ),
    shapes=LM_SHAPES,
)
