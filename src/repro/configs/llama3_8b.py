"""Llama-3-8B: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

GQA, 128k vocab [arXiv:2407.21783].  Full attention ⇒ long_500k skipped.
"""
from ..models.lm import LMConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    name="llama3-8b",
    family="lm",
    config=LMConfig(
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=14336, vocab=128256, rope_theta=5e5,
    ),
    smoke_config=LMConfig(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab=512, rope_theta=5e5, attn_chunk=64,
    ),
    shapes=LM_SHAPES,
    skips={"long_500k": "pure full attention — no sub-quadratic path (DESIGN.md §4)"},
)
