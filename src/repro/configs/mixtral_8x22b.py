"""Mixtral-8x22B: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from ..models.lm import LMConfig
from .base import ArchSpec, LM_SHAPES

ARCH = ArchSpec(
    name="mixtral-8x22b",
    family="lm",
    config=LMConfig(
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=16384, vocab=32768, sliding_window=4096, n_experts=8, top_k=2,
        rope_theta=1e6,
    ),
    smoke_config=LMConfig(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab=512, sliding_window=32, n_experts=4, top_k=2,
        rope_theta=1e6, attn_chunk=64,
    ),
    shapes=LM_SHAPES,
)
