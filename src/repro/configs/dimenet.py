"""DimeNet: n_blocks=6 d_hidden=128 bilinear=8 spherical=7 radial=6
[arXiv:2003.03123].  Triplet lists are capped at 2·|E| for the large
full-graph shapes (budgeted gather, DESIGN.md)."""
from ..models.gnn import DimeNetConfig
from .base import ArchSpec, GNN_SHAPES

ARCH = ArchSpec(
    name="dimenet",
    family="gnn",
    config=DimeNetConfig(n_blocks=6, d_hidden=128, n_bilinear=8,
                         n_spherical=7, n_radial=6),
    smoke_config=DimeNetConfig(n_blocks=2, d_hidden=16, n_bilinear=4,
                               n_spherical=3, n_radial=4),
    shapes=GNN_SHAPES,
)
