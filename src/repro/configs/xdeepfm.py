"""xDeepFM: 39 sparse fields, embed_dim=10, CIN 200-200-200, MLP 400-400
[arXiv:1803.05170]."""
from ..models.recsys import XDeepFMConfig
from .base import ArchSpec, RECSYS_SHAPES

ARCH = ArchSpec(
    name="xdeepfm",
    family="recsys",
    config=XDeepFMConfig(n_fields=39, embed_dim=10, cin_layers=(200, 200, 200),
                         mlp_dims=(400, 400)),
    smoke_config=XDeepFMConfig(n_fields=6, embed_dim=4, cin_layers=(8, 8),
                               mlp_dims=(16, 16),
                               field_vocabs=(64, 32, 32, 16, 16, 16)),
    shapes=RECSYS_SHAPES,
)
