"""--arch registry: one module per assigned architecture."""
from .base import ArchSpec  # noqa: F401

from . import (  # noqa: F401
    qwen2_5_14b, llama3_8b, qwen3_14b, mixtral_8x7b, mixtral_8x22b,
    schnet, egnn, dimenet, gcn_cora, xdeepfm,
)

REGISTRY = {
    m.ARCH.name: m.ARCH
    for m in (
        qwen2_5_14b, llama3_8b, qwen3_14b, mixtral_8x7b, mixtral_8x22b,
        schnet, egnn, dimenet, gcn_cora, xdeepfm,
    )
}


def get_arch(name: str) -> ArchSpec:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]
