"""SchNet: n_interactions=3 d_hidden=64 rbf=300 cutoff=10 [arXiv:1706.08566]."""
from ..models.gnn import SchNetConfig
from .base import ArchSpec, GNN_SHAPES

ARCH = ArchSpec(
    name="schnet",
    family="gnn",
    config=SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0),
    smoke_config=SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=16, cutoff=10.0),
    shapes=GNN_SHAPES,
    notes="non-molecular shapes use synthetic 3-D positions (point-cloud reading)",
)
