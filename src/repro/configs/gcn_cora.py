"""GCN (Cora): 2 layers d_hidden=16 sym-normalized mean agg [arXiv:1609.02907]."""
from ..models.gnn import GCNConfig
from .base import ArchSpec, GNN_SHAPES

ARCH = ArchSpec(
    name="gcn-cora",
    family="gnn",
    config=GCNConfig(n_layers=2, d_hidden=16, d_feat=1433, n_classes=7),
    smoke_config=GCNConfig(n_layers=2, d_hidden=8, d_feat=32, n_classes=4),
    shapes=GNN_SHAPES,
)
