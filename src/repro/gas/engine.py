"""PowerGraph-style GAS (Gather-Apply-Scatter) engine over a vertex cut.

This is the downstream consumer the paper deploys S5P into (§6.6): a
distributed graph-processing engine where each partition holds an edge set
and *replicas* of every incident vertex.  Per GAS super-step:

  1. local gather:   per-partition ``segment_sum`` of edge messages into
                     the local replicas;
  2. replica→master: every mirror sends its partial accumulator to the
                     master copy (**network**, counted);
  3. apply:          master applies the vertex program;
  4. master→mirror:  new vertex values broadcast back to mirrors
                     (**network**, counted).

Replication factor therefore *is* the communication cost driver — the
paper's Fig. 11 shows PageRank comm/runtime tracking RF, which this engine
reproduces exactly (byte counting, not wall-clock simulation).

Two execution modes:
- single-host reference (partitions = segments of one device array);
- ``shard_map`` mode (partitions ↔ mesh devices; mirror sync becomes a
  masked ``psum`` — the real distributed dataflow; see
  core/distributed.py for the partitioning-side pipeline).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GASGraph", "CommStats", "build_gas_graph", "pagerank",
           "pagerank_step", "out_degree_inv", "carry_values",
           "label_propagation", "comm_stats"]


class GASGraph(NamedTuple):
    """Vertex-cut layout: edges grouped by partition + replica tables."""

    src: jax.Array  # (E,) int32, grouped by partition
    dst: jax.Array  # (E,)
    edge_part: jax.Array  # (E,) int32
    part_offsets: np.ndarray  # (k+1,) edge ranges per partition
    replica_mask: jax.Array  # (V, k) bool — v has a replica in p
    masters: jax.Array  # (V,) int32 — master partition per vertex
    n_vertices: int
    k: int


class CommStats(NamedTuple):
    mirror_to_master_msgs: int
    master_to_mirror_msgs: int

    def total_bytes(self, bytes_per_value: int = 8) -> int:
        return (self.mirror_to_master_msgs + self.master_to_mirror_msgs) * bytes_per_value


def build_gas_graph(src, dst, parts, n_vertices: int, k: int) -> GASGraph:
    src = np.asarray(src)
    dst = np.asarray(dst)
    parts = np.asarray(parts)
    valid = parts >= 0
    src, dst, parts = src[valid], dst[valid], parts[valid]
    order = np.argsort(parts, kind="stable")
    src, dst, parts = src[order], dst[order], parts[order]
    offsets = np.zeros(k + 1, np.int64)
    np.add.at(offsets, parts + 1, 1)
    offsets = np.cumsum(offsets)
    mask = np.zeros((n_vertices, k), bool)
    mask[src, parts] = True
    mask[dst, parts] = True
    # master = lowest-id partition holding the vertex (PowerGraph hashes;
    # any deterministic choice works — comm counts are choice-invariant)
    has = mask.any(axis=1)
    masters = np.where(has, mask.argmax(axis=1), 0).astype(np.int32)
    return GASGraph(
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        edge_part=jnp.asarray(parts, jnp.int32),
        part_offsets=offsets,
        replica_mask=jnp.asarray(mask),
        masters=jnp.asarray(masters),
        n_vertices=n_vertices,
        k=k,
    )


def comm_stats(g: GASGraph) -> CommStats:
    """Per-superstep replica sync volume (each mirror ⇄ master once)."""
    replicas = jnp.sum(g.replica_mask, axis=1)
    mirrors = int(jnp.sum(jnp.maximum(replicas - 1, 0)))
    return CommStats(mirror_to_master_msgs=mirrors, master_to_mirror_msgs=mirrors)


@partial(jax.jit, static_argnames=("n_vertices", "k"))
def _gas_superstep(src, dst, edge_part, replica_mask, values, out_deg_inv,
                   *, n_vertices: int, k: int):
    """One gather-apply-scatter round of PageRank, replica-exact.

    The per-partition local gather uses partition-local accumulators
    (vertex × partition), then the mirror→master reduction collapses them —
    numerically identical to the distributed execution, so the byte counts
    and the results both match a real deployment.
    """
    # gather: each edge contributes value[src]/outdeg[src] to dst's replica
    # in the edge's own partition
    contrib = values[src] * out_deg_inv[src]
    flat_idx = dst * k + edge_part
    local = jax.ops.segment_sum(contrib, flat_idx, num_segments=n_vertices * k)
    local = local.reshape(n_vertices, k)
    # mirror→master: sum partial accumulators (the network reduction)
    total = jnp.sum(jnp.where(replica_mask, local, 0.0), axis=1)
    # apply
    new_values = 0.15 + 0.85 * total
    # master→mirror broadcast is implicit (values are read next round)
    return new_values


def label_propagation(g: GASGraph, iterations: int = 5) -> tuple[jax.Array, CommStats]:
    """Connected components via min-label propagation on the vertex cut.

    Same replica-sync structure as PageRank (gather=min instead of sum) —
    a second GAS program demonstrating the engine is algorithm-generic.
    """
    labels = jnp.arange(g.n_vertices, dtype=jnp.int32)
    big = jnp.int32(2**30)

    @partial(jax.jit, static_argnames=())
    def step(labels):
        flat = g.dst * g.k + g.edge_part
        lmin = jax.ops.segment_min(labels[g.src], flat,
                                   num_segments=g.n_vertices * g.k)
        lmin = lmin.reshape(g.n_vertices, g.k)
        flat2 = g.src * g.k + g.edge_part
        rmin = jax.ops.segment_min(labels[g.dst], flat2,
                                   num_segments=g.n_vertices * g.k)
        rmin = rmin.reshape(g.n_vertices, g.k)
        local = jnp.minimum(jnp.where(g.replica_mask, lmin, big),
                            jnp.where(g.replica_mask, rmin, big))
        return jnp.minimum(labels, jnp.min(local, axis=1))

    for _ in range(iterations):
        labels = step(labels)
    per = comm_stats(g)
    return labels, CommStats(per.mirror_to_master_msgs * iterations,
                             per.master_to_mirror_msgs * iterations)


def out_degree_inv(g: GASGraph) -> jax.Array:
    """``1/outdeg`` per vertex (0 for sinks) — the PageRank edge weight.

    Computed once per graph version; a serving loop caches it alongside
    the layout and reuses it every super-step until the next swap.
    """
    ones = jnp.ones_like(g.src, dtype=jnp.float32)
    out_deg = jax.ops.segment_sum(ones, g.src, num_segments=g.n_vertices)
    return jnp.where(out_deg > 0, 1.0 / jnp.maximum(out_deg, 1.0), 0.0)


def pagerank_step(g: GASGraph, values: jax.Array,
                  out_deg_inv: jax.Array | None = None) -> jax.Array:
    """One PageRank super-step from ``values`` — the serving-loop unit.

    Unlike :func:`pagerank` (cold values, fixed iteration count), this is
    the incremental surface: a continuously-serving engine carries
    ``values`` across calls — and across partition-bundle swaps, since
    the super-step is replica-exact and therefore partition-invariant;
    only the *comm cost* of the sync depends on the cut.  Comm for the
    step is :func:`comm_stats` of the graph it ran on.
    """
    if out_deg_inv is None:
        out_deg_inv = out_degree_inv(g)
    return _gas_superstep(
        g.src, g.dst, g.edge_part, g.replica_mask, values, out_deg_inv,
        n_vertices=g.n_vertices, k=g.k)


def carry_values(values, n_vertices: int, fill: float = 1.0) -> jax.Array:
    """Carry a vertex-state vector across a bundle swap.

    Vertices shared by both versions keep their converged state (PageRank
    is a contraction, so warm values re-converge in few steps); vertices
    the new version introduces start at ``fill``; a shrunken table is
    truncated.
    """
    values = jnp.asarray(values, jnp.float32)
    n_old = values.shape[0]
    if n_vertices == n_old:
        return values
    if n_vertices < n_old:
        return values[:n_vertices]
    pad = jnp.full((n_vertices - n_old,), fill, jnp.float32)
    return jnp.concatenate([values, pad])


def pagerank(g: GASGraph, iterations: int = 10) -> tuple[jax.Array, CommStats]:
    """PageRank on the vertex-cut layout + exact per-superstep comm stats."""
    out_deg_inv = out_degree_inv(g)
    values = jnp.ones((g.n_vertices,), jnp.float32)
    for _ in range(iterations):
        values = pagerank_step(g, values, out_deg_inv)
    per_step = comm_stats(g)
    stats = CommStats(
        mirror_to_master_msgs=per_step.mirror_to_master_msgs * iterations,
        master_to_mirror_msgs=per_step.master_to_mirror_msgs * iterations,
    )
    return values, stats
