from .engine import (  # noqa: F401
    CommStats,
    GASGraph,
    build_gas_graph,
    carry_values,
    comm_stats,
    label_propagation,
    out_degree_inv,
    pagerank,
    pagerank_step,
)
