from .engine import GASGraph, build_gas_graph, pagerank, CommStats  # noqa: F401
