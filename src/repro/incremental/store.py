"""CarryStore — durable, validated persistence for partitioner carries.

A carry checkpoint is the atomic npz+CRC commit of ``checkpoint.manager``
(treedef-path manifest, per-array CRC32, tmp-dir + ``os.rename``) with one
addition: a **metadata leaf**.  The store wraps every carry as
``{"meta": <json as uint8>, "carry": <pytree>}`` before saving, so the
consumer name, a config fingerprint, and the stream position travel
*inside* the same atomic commit as the arrays — a crash can never split a
carry from its provenance, and the CRC layer covers the metadata too.

Validation on load is strict by construction: a carry written under a
different consumer, a different config fingerprint, an incompatible
stream position, or a different **carry representation generation**
(``repro.streaming.carry.CARRY_REPR`` — a pre-refactor monotone-bitmap
checkpoint must not seed the counted algebra) **raises**
:class:`CarryMismatchError` instead of silently seeding a warm start with
foreign state.  (A corrupted checkpoint already raises ``IOError`` from
the CRC verify underneath.)

Steps are keyed by **stream position** (edges ingested when the carry was
taken), so ``load()`` with no step resumes from the furthest-ingested
carry and mid-stream checkpoints coexist naturally with end-of-stream
ones.  Keep-N GC bounds the directory like ``CheckpointManager`` does.
"""

from __future__ import annotations

import hashlib
import json
import shutil
from pathlib import Path
from typing import Any, Mapping

import jax
import numpy as np

from ..checkpoint.manager import (
    _flatten_with_paths,
    restore_checkpoint,
    save_checkpoint,
)
from ..streaming.carry import CARRY_REPR

__all__ = ["CarryStore", "CarryMismatchError", "config_fingerprint"]

_META_KEY = "meta"
_CARRY_KEY = "carry"
_FORMAT = 1


class CarryMismatchError(ValueError):
    """A persisted carry exists but must not seed this warm start."""


def config_fingerprint(config: Mapping[str, Any]) -> str:
    """Order-insensitive 16-hex fingerprint of a config mapping.

    Values must be JSON-serializable; floats/ints/strings/bools/None and
    nested lists/dicts all hash stably.
    """
    blob = json.dumps(config, sort_keys=True, separators=(",", ":"),
                      default=_json_default)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _json_default(o):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    return str(o)


def _meta_to_leaf(meta: dict) -> np.ndarray:
    return np.frombuffer(
        json.dumps(meta, sort_keys=True, default=_json_default).encode(),
        np.uint8).copy()


def _leaf_to_meta(arr: np.ndarray) -> dict:
    return json.loads(np.asarray(arr, np.uint8).tobytes().decode())


class CarryStore:
    """keep-N store of validated carry checkpoints under one directory."""

    def __init__(self, directory, keep: int = 3):
        self.directory = Path(directory)
        self.keep = int(keep)

    # ------------------------------------------------------------- write
    def save(self, carry, *, consumer: str, config: Mapping[str, Any],
             stream_pos: int, extra_meta: Mapping[str, Any] | None = None,
             step: int | None = None) -> Path:
        """Persist ``carry`` atomically.  Returns the committed path.

        ``consumer`` names the PartitionerCarry implementation (or the
        pipeline) that produced the state; ``config`` is the scenario
        mapping whose fingerprint guards the restore; ``stream_pos`` is
        the number of edges ingested when the carry was taken (and the
        default step key).
        """
        meta = {
            "format": _FORMAT,
            "carry_repr": CARRY_REPR,
            "consumer": str(consumer),
            "config_hash": config_fingerprint(config),
            "config": dict(config),
            "stream_pos": int(stream_pos),
        }
        if extra_meta:
            meta.update(extra_meta)
        state = {_META_KEY: _meta_to_leaf(meta),
                 _CARRY_KEY: jax.device_get(carry)}
        path = save_checkpoint(self.directory, int(
            step if step is not None else stream_pos), state)
        self._gc()
        return path

    # -------------------------------------------------------------- read
    def load(self, like=None, *, consumer: str | None = None,
             config: Mapping[str, Any] | None = None,
             max_stream_pos: int | None = None,
             step: int | None = None, verify: bool = True):
        """Restore ``(carry, meta)`` from the given (default: latest) step.

        - ``consumer``/``config`` given ⇒ the stored metadata must match
          (fingerprint equality for config) or :class:`CarryMismatchError`.
        - ``max_stream_pos`` given ⇒ the carry's stream position must not
          exceed it (a carry taken *past* the current stream cannot seed
          a replay of it).
        - ``like`` given ⇒ the carry is unflattened into that treedef
          (leaves matched by path; any structural drift raises).  Without
          it a flat ``{path: array}`` dict is returned.
        """
        if step is None and max_stream_pos is not None:
            # steps are keyed by stream position (the save default), so a
            # mid-stream checkpoint can seed a shorter stream even after
            # later end-of-stream saves: take the furthest step that fits
            fitting = [s for s in self.steps() if s <= max_stream_pos]
            if fitting:
                step = fitting[-1]
            # else fall through to the latest; the metadata check below
            # reports the stale/foreign position with full context
        flat, _ = restore_checkpoint(self.directory, step=step, like=None,
                                     verify=verify)
        if _META_KEY not in flat:
            raise CarryMismatchError(
                f"checkpoint under {self.directory} is not a carry "
                "checkpoint (no metadata leaf)")
        meta = _leaf_to_meta(flat.pop(_META_KEY))
        if meta.get("format") != _FORMAT:
            raise CarryMismatchError(
                f"unsupported carry format {meta.get('format')!r}")
        if meta.get("carry_repr") != CARRY_REPR:
            # a checkpoint from the pre-refactor monotone (OR/MAX bitmap)
            # representation: its replica tables are booleans and its
            # cluster state has no membership counters — restoring it
            # into the counted algebra would silently mis-account every
            # later retraction, so refuse loudly instead.
            raise CarryMismatchError(
                f"carry was written under representation "
                f"{meta.get('carry_repr')!r} but this build speaks the "
                f"counted (group-structured) representation {CARRY_REPR}; "
                "re-run the cold start to produce a compatible carry")
        if consumer is not None and meta["consumer"] != consumer:
            raise CarryMismatchError(
                f"carry was written by consumer {meta['consumer']!r}, "
                f"refusing to seed {consumer!r}")
        if config is not None:
            want = config_fingerprint(config)
            if meta["config_hash"] != want:
                raise CarryMismatchError(
                    f"carry config fingerprint {meta['config_hash']} != "
                    f"{want} for the requested config "
                    f"(stored: {meta.get('config')})")
        if max_stream_pos is not None and meta["stream_pos"] > max_stream_pos:
            raise CarryMismatchError(
                f"carry was taken at stream position {meta['stream_pos']} "
                f"but the current stream holds only {max_stream_pos} edges "
                "(stale or foreign stream)")
        prefix = _CARRY_KEY + "/"
        carry_flat = {k[len(prefix):] if k.startswith(prefix) else k: v
                      for k, v in flat.items()}
        if like is None:
            return carry_flat, meta
        paths_leaves = _flatten_with_paths({_CARRY_KEY: like})
        try:
            leaves = [flat_lookup(carry_flat, k, prefix) for k, _ in paths_leaves]
        except KeyError as e:
            raise CarryMismatchError(
                f"carry structure mismatch: stored checkpoint has no leaf "
                f"{e.args[0]!r} for the requested treedef") from None
        if len(carry_flat) != len(paths_leaves):
            raise CarryMismatchError(
                f"carry structure mismatch: stored checkpoint has "
                f"{len(carry_flat)} leaves, requested treedef expects "
                f"{len(paths_leaves)}")
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves), meta

    # ------------------------------------------------------------- admin
    def steps(self) -> list[int]:
        if not self.directory.exists():
            return []
        return sorted(
            int(p.name.split("_")[1]) for p in self.directory.glob("step_*")
            if not p.name.endswith(".tmp")
        )

    def _gc(self) -> None:
        steps = self.steps()
        if self.keep and len(steps) > self.keep:
            for s in steps[:-self.keep]:
                shutil.rmtree(self.directory / f"step_{s:08d}",
                              ignore_errors=True)


def flat_lookup(carry_flat: dict, full_key: str, prefix: str):
    """Leaf for a ``carry/...`` manifest path from the stripped flat dict."""
    key = full_key[len(prefix):] if full_key.startswith(prefix) else full_key
    if key not in carry_flat:
        raise KeyError(full_key)
    return carry_flat[key]
