"""Top-level incremental driver: cold start → CarryStore → warm replays.

Two bundle flavors behind one ``save``/``resume`` surface:

- **scan partitioners** (greedy / hdrf / grid) — the bundle is the scoring
  carry plus the per-edge parts; a delta replay is one
  :func:`~repro.incremental.delta.run_incremental_carry` fold (greedy and
  grid compose exactly; HDRF approximately — tail-chunk padding feeds its
  partial-degree estimates, see ``repro.incremental`` docs);
- **s5p** — the full pipeline bundle of
  :mod:`~repro.incremental.pipeline`, with drift-triggered masked-game
  refinement.

``cold_start`` runs the partitioner from scratch and persists the bundle;
``run_incremental`` restores the latest bundle (validated by consumer
name + config fingerprint + stream position), replays only the suffix the
store has not seen, and optionally persists the grown bundle.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np

from ..checkpoint.manager import _flatten_with_paths
from ..core.metrics import load_balance, replication_factor
from ..core.s5p import S5PConfig
from ..kernels import stream_scan as _scan
from .delta import DeltaStream, grow_carry, run_incremental_carry
from .pipeline import (
    IncrementalResult,
    s5p_apply_delta,
    s5p_cold_bundle,
    s5p_identity_config,
)
from .store import CarryStore

__all__ = ["SCAN_PARTITIONERS", "cold_start", "run_incremental"]

SCAN_PARTITIONERS = ("greedy", "hdrf", "grid")
INCREMENTAL_PARTITIONERS = SCAN_PARTITIONERS + ("s5p",)


def _scan_carry(name: str, n_vertices: int, k: int, seed: int,
                lam: float = 1.1):
    if name == "greedy":
        return _scan.GreedyCarry(n_vertices, k)
    if name == "hdrf":
        return _scan.HdrfCarry(n_vertices, k, lam)
    if name == "grid":
        from ..core.baselines import _grid_dims, _grid_rowcol

        _, c = _grid_dims(k)
        row, col = _grid_rowcol(n_vertices, k, c, seed)
        return _scan.GridCarry(k, row, col, c)
    raise ValueError(f"{name!r} is not a scan partitioner")


def _scan_identity_config(name: str, k: int, seed: int,
                          lam: float = 1.1) -> dict:
    cfg: dict[str, Any] = {"partitioner": name, "k": k, "seed": seed}
    if name == "hdrf":
        cfg["lam"] = lam
    return cfg


def _metrics(src, dst, parts, n, k):
    return (float(replication_factor(src, dst, parts, n_vertices=n, k=k)),
            float(load_balance(parts, k=k)))


def _prefix_crc(src, dst, n_edges: int) -> int:
    """CRC32 of the first ``n_edges`` edges — the stream-identity check
    that catches a *longer* foreign stream (config + position alone would
    happily replay an unrelated graph's suffix against the carry)."""
    import zlib

    crc = zlib.crc32(np.ascontiguousarray(src[:n_edges], np.int32).tobytes())
    return zlib.crc32(
        np.ascontiguousarray(dst[:n_edges], np.int32).tobytes(), crc)


def _check_prefix(meta, full_src, full_dst):
    want = meta.get("prefix_crc")
    if want is None:
        return
    got = _prefix_crc(full_src, full_dst, int(meta["stream_pos"]))
    if got != want:
        from .store import CarryMismatchError

        raise CarryMismatchError(
            f"the current stream's first {meta['stream_pos']} edges do not "
            "match the edges this carry was built on (foreign stream)")


def cold_start(store_dir, partitioner: str, src, dst, n_vertices: int,
               k: int, *, seed: int = 0, chunk_size: int = 1 << 16,
               s5p_config: S5PConfig | None = None, stream=None,
               num_streams: int = 1, super_chunk: int = 8,
               keep: int = 3):
    """Run ``partitioner`` from scratch and persist its warm-start bundle.

    Returns ``(parts, store_path)``.
    """
    if partitioner not in INCREMENTAL_PARTITIONERS:
        raise ValueError(
            f"partitioner {partitioner!r} has no incremental bundle; one of "
            f"{INCREMENTAL_PARTITIONERS}")
    store = CarryStore(store_dir, keep=keep)
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    E = int(src.shape[0])
    if partitioner == "s5p":
        config = s5p_config if s5p_config is not None else S5PConfig(
            k=k, seed=seed, chunk_size=chunk_size)
        out, bundle = s5p_cold_bundle(src, dst, n_vertices, config,
                                      stream=stream)
        store.save(bundle, consumer="s5p", config=s5p_identity_config(config),
                   stream_pos=E,
                   extra_meta={"n_vertices": int(n_vertices),
                               "prefix_crc": _prefix_crc(src, dst, E)})
        return np.asarray(out.parts), store.directory
    pc = _scan_carry(partitioner, n_vertices, k, seed)
    from ..streaming import as_stream, run_parallel

    st = as_stream(src, dst, n_vertices, stream=stream,
                   chunk_size=chunk_size)
    parts, carry = run_parallel(st, pc, num_streams=num_streams,
                                super_chunk=super_chunk)
    parts = np.asarray(parts, np.int32)
    store.save({"scan": carry, "parts": parts}, consumer=partitioner,
               config=_scan_identity_config(partitioner, k, seed),
               stream_pos=E,
               extra_meta={"n_vertices": int(n_vertices),
                           "prefix_crc": _prefix_crc(src, dst, E)})
    return parts, store.directory


def run_incremental(store_dir, partitioner: str, full_src, full_dst,
                    n_vertices: int, k: int, *, seed: int = 0,
                    chunk_size: int = 1 << 16,
                    s5p_config: S5PConfig | None = None,
                    num_streams: int = 1, super_chunk: int = 8,
                    save: bool = True, save_dir=None,
                    keep: int = 3) -> IncrementalResult:
    """Warm-start ``partitioner`` on the suffix the store has not seen.

    ``full_src``/``full_dst`` are the **whole** stream in arrival order;
    the delta is everything past the persisted bundle's stream position.
    The restored bundle is validated (consumer, config fingerprint, stream
    position) — any mismatch raises
    :class:`~repro.incremental.store.CarryMismatchError` instead of
    silently replaying against foreign state.  The grown bundle is saved
    back to ``save_dir`` (default: the same store) unless ``save=False``.
    """
    if partitioner not in INCREMENTAL_PARTITIONERS:
        raise ValueError(
            f"partitioner {partitioner!r} has no incremental bundle; one of "
            f"{INCREMENTAL_PARTITIONERS}")
    load_store = CarryStore(store_dir, keep=keep)
    store = (load_store if save_dir is None
             else CarryStore(save_dir, keep=keep))
    full_src = np.asarray(full_src, np.int32)
    full_dst = np.asarray(full_dst, np.int32)
    E_total = int(full_src.shape[0])
    if partitioner == "s5p":
        config = s5p_config if s5p_config is not None else S5PConfig(
            k=k, seed=seed, chunk_size=chunk_size)
        bundle, meta = load_store.load(consumer="s5p",
                                  config=s5p_identity_config(config),
                                  max_stream_pos=E_total)
        _check_prefix(meta, full_src, full_dst)
        bundle, result = s5p_apply_delta(bundle, config, full_src, full_dst,
                                         meta["stream_pos"])
        if save:
            store.save(bundle, consumer="s5p",
                       config=s5p_identity_config(config),
                       stream_pos=E_total,
                       extra_meta={"n_vertices": int(
                           bundle["degrees"].shape[0]),
                           "prefix_crc": _prefix_crc(full_src, full_dst,
                                                     E_total)})
        return result

    config = _scan_identity_config(partitioner, k, seed)
    flat, meta = load_store.load(consumer=partitioner, config=config,
                            max_stream_pos=E_total)
    _check_prefix(meta, full_src, full_dst)
    E0 = int(meta["stream_pos"])
    n_old = int(meta.get("n_vertices", n_vertices))
    prefix_parts = np.asarray(flat.pop("parts"), np.int32)
    # reassemble the scoring carry from its path-keyed leaves (the same
    # path-string scheme the checkpoint manager saved them under)
    proto = _scan_carry(partitioner, n_old, k, seed).init()
    keys = [key for key, _ in _flatten_with_paths({"scan": proto})]
    carry = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(proto), [flat[key] for key in keys])
    dsrc = full_src[E0:]
    ddst = full_dst[E0:]
    E_delta = E_total - E0
    n_new = n_vertices
    if E_delta:
        n_new = max(n_old, int(max(dsrc.max(), ddst.max())) + 1, n_vertices)
    carry = grow_carry(partitioner, carry, n_old, n_new, k=k, seed=seed)
    parts = prefix_parts
    if E_delta:
        pc = _scan_carry(partitioner, n_new, k, seed)
        stream = DeltaStream(dsrc, ddst, n_new, base_offset=E0,
                             chunk_size=chunk_size)
        delta_parts, carry = run_incremental_carry(
            stream, pc, carry=carry, num_streams=num_streams,
            super_chunk=super_chunk)
        parts = np.concatenate([prefix_parts,
                                np.asarray(delta_parts, np.int32)])
    rf, bal = _metrics(full_src, full_dst, parts, n_new, k)
    if save:
        store.save({"scan": carry, "parts": parts}, consumer=partitioner,
                   config=config, stream_pos=E_total,
                   extra_meta={"n_vertices": int(n_new),
                               "prefix_crc": _prefix_crc(full_src, full_dst,
                                                         E_total)})
    return IncrementalResult(
        parts=parts, rf=rf, balance=bal, refined=False, rf_drift=0.0,
        balance_drift=0.0, edges_replayed=E_delta,
        full_replay_cost=E_total, game_rounds=0, n_new_clusters=0,
        n_delta_edges=E_delta)
