"""Top-level incremental driver: cold start → CarryStore → warm replays.

Two bundle flavors behind one ``save``/``resume`` surface:

- **scan partitioners** (greedy / hdrf / grid) — the bundle is the scoring
  carry plus the per-edge parts and alive mask; a delta replay is one
  :func:`~repro.incremental.delta.run_incremental_carry` fold (greedy and
  grid compose exactly; HDRF approximately — tail-chunk padding feeds its
  partial-degree estimates, see ``repro.incremental`` docs), and a
  **deletion** is one :func:`~repro.streaming.run_retract` drive — the
  counted carries subtract the deleted edges' accounting exactly, given
  the stored per-edge parts;
- **s5p** — the full pipeline bundle of
  :mod:`~repro.incremental.pipeline`, with drift-triggered masked-game
  refinement, version-rollback deletions and the ξ/κ refresh signal.

``cold_start`` runs the partitioner from scratch and persists the bundle;
``run_incremental`` restores the latest bundle (validated by consumer
name + config fingerprint + stream position + carry representation),
replays only the suffix the store has not seen, applies any requested
deletions, and optionally persists the grown bundle.
:func:`s5p_sliding_window` composes the same machinery with
:class:`~repro.streaming.window.SlidingWindowStream` to track the last W
edges of a stream continuously.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import numpy as np

from ..checkpoint.manager import _flatten_with_paths
from ..core.metrics import load_balance, replication_factor
from ..core.s5p import S5PConfig
from ..kernels import stream_scan as _scan
from .delta import DeltaStream, grow_carry, run_incremental_carry
from .pipeline import (
    IncrementalResult,
    compact_bundle,
    compact_edge_slots,
    s5p_apply_delta,
    s5p_apply_deletion,
    s5p_cold_bundle,
    s5p_cold_restart,
    s5p_identity_config,
)
from .store import CarryStore

__all__ = ["SCAN_PARTITIONERS", "cold_start", "run_incremental",
           "s5p_sliding_window", "S5PWindowChain", "WindowStep"]

SCAN_PARTITIONERS = ("greedy", "hdrf", "grid")
INCREMENTAL_PARTITIONERS = SCAN_PARTITIONERS + ("s5p",)


def _scan_carry(name: str, n_vertices: int, k: int, seed: int,
                lam: float = 1.1):
    if name == "greedy":
        return _scan.GreedyCarry(n_vertices, k)
    if name == "hdrf":
        return _scan.HdrfCarry(n_vertices, k, lam)
    if name == "grid":
        from ..core.baselines import _grid_dims, _grid_rowcol

        _, c = _grid_dims(k)
        row, col = _grid_rowcol(n_vertices, k, c, seed)
        return _scan.GridCarry(k, row, col, c)
    raise ValueError(f"{name!r} is not a scan partitioner")


def _scan_identity_config(name: str, k: int, seed: int,
                          lam: float = 1.1) -> dict:
    cfg: dict[str, Any] = {"partitioner": name, "k": k, "seed": seed}
    if name == "hdrf":
        cfg["lam"] = lam
    return cfg


def _metrics(src, dst, parts, n, k):
    return (float(replication_factor(src, dst, parts, n_vertices=n, k=k)),
            float(load_balance(parts, k=k)))


def _prefix_crc(src, dst, n_edges: int) -> int:
    """CRC32 of the first ``n_edges`` edges — the stream-identity check
    that catches a *longer* foreign stream (config + position alone would
    happily replay an unrelated graph's suffix against the carry)."""
    import zlib

    crc = zlib.crc32(np.ascontiguousarray(src[:n_edges], np.int32).tobytes())
    return zlib.crc32(
        np.ascontiguousarray(dst[:n_edges], np.int32).tobytes(), crc)


def _check_prefix(meta, full_src, full_dst):
    want = meta.get("prefix_crc")
    if want is None:
        return
    got = _prefix_crc(full_src, full_dst, int(meta["stream_pos"]))
    if got != want:
        from .store import CarryMismatchError

        raise CarryMismatchError(
            f"the current stream's first {meta['stream_pos']} edges do not "
            "match the edges this carry was built on (foreign stream)")


def cold_start(store_dir, partitioner: str, src, dst, n_vertices: int,
               k: int, *, seed: int = 0, chunk_size: int = 1 << 16,
               s5p_config: S5PConfig | None = None, stream=None,
               num_streams: int = 1, super_chunk: int = 8,
               keep: int = 3):
    """Run ``partitioner`` from scratch and persist its warm-start bundle.

    Returns ``(parts, store_path)``.
    """
    if partitioner not in INCREMENTAL_PARTITIONERS:
        raise ValueError(
            f"partitioner {partitioner!r} has no incremental bundle; one of "
            f"{INCREMENTAL_PARTITIONERS}")
    store = CarryStore(store_dir, keep=keep)
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    E = int(src.shape[0])
    if partitioner == "s5p":
        config = s5p_config if s5p_config is not None else S5PConfig(
            k=k, seed=seed, chunk_size=chunk_size)
        out, bundle = s5p_cold_bundle(src, dst, n_vertices, config,
                                      stream=stream)
        store.save(bundle, consumer="s5p", config=s5p_identity_config(config),
                   stream_pos=E,
                   extra_meta={"n_vertices": int(n_vertices),
                               "prefix_crc": _prefix_crc(src, dst, E)})
        return np.asarray(out.parts), store.directory
    pc = _scan_carry(partitioner, n_vertices, k, seed)
    from ..streaming import as_stream, run_parallel

    st = as_stream(src, dst, n_vertices, stream=stream,
                   chunk_size=chunk_size)
    parts, carry = run_parallel(st, pc, num_streams=num_streams,
                                super_chunk=super_chunk)
    parts = np.asarray(parts, np.int32)
    store.save({"scan": carry, "parts": parts,
                "alive": np.ones(E, bool)}, consumer=partitioner,
               config=_scan_identity_config(partitioner, k, seed),
               stream_pos=E,
               extra_meta={"n_vertices": int(n_vertices),
                           "prefix_crc": _prefix_crc(src, dst, E)})
    return parts, store.directory


def run_incremental(store_dir, partitioner: str, full_src, full_dst,
                    n_vertices: int, k: int, *, seed: int = 0,
                    chunk_size: int = 1 << 16,
                    s5p_config: S5PConfig | None = None,
                    num_streams: int = 1, super_chunk: int = 8,
                    delete=None,
                    save: bool = True, save_dir=None,
                    keep: int = 3) -> IncrementalResult:
    """Warm-start ``partitioner`` on the suffix the store has not seen.

    ``full_src``/``full_dst`` are the **whole** stream in arrival order;
    the delta is everything past the persisted bundle's stream position,
    and ``delete`` (optional) names arrival indices to retract after the
    insertion replay — tombstoned in place (their parts become ``-1``),
    their accounting subtracted through the counted carry algebra.  The
    restored bundle is validated (consumer, config fingerprint, stream
    position, carry representation) — any mismatch raises
    :class:`~repro.incremental.store.CarryMismatchError` instead of
    silently replaying against foreign state.  The grown bundle is saved
    back to ``save_dir`` (default: the same store) unless ``save=False``.
    """
    if partitioner not in INCREMENTAL_PARTITIONERS:
        raise ValueError(
            f"partitioner {partitioner!r} has no incremental bundle; one of "
            f"{INCREMENTAL_PARTITIONERS}")
    load_store = CarryStore(store_dir, keep=keep)
    store = (load_store if save_dir is None
             else CarryStore(save_dir, keep=keep))
    full_src = np.asarray(full_src, np.int32)
    full_dst = np.asarray(full_dst, np.int32)
    E_total = int(full_src.shape[0])
    if partitioner == "s5p":
        config = s5p_config if s5p_config is not None else S5PConfig(
            k=k, seed=seed, chunk_size=chunk_size)
        bundle, meta = load_store.load(consumer="s5p",
                                  config=s5p_identity_config(config),
                                  max_stream_pos=E_total)
        _check_prefix(meta, full_src, full_dst)
        bundle, result = s5p_apply_delta(bundle, config, full_src, full_dst,
                                         meta["stream_pos"])
        if delete is not None and len(delete):
            bundle, dres = s5p_apply_deletion(bundle, config, full_src,
                                              full_dst, delete)
            result = dres._replace(
                edges_replayed=result.edges_replayed + dres.edges_replayed,
                game_rounds=result.game_rounds + dres.game_rounds,
                refined=result.refined or dres.refined,
                n_new_clusters=result.n_new_clusters,
                n_delta_edges=result.n_delta_edges)
        if save:
            # key the save on the *stream* position, not the slot count:
            # slot compaction shrinks the per-edge arrays without moving
            # the stream, and a rollback moves both
            pos = int(bundle["stream_pos"])  # ≤ E_total
            store.save(bundle, consumer="s5p",
                       config=s5p_identity_config(config),
                       stream_pos=pos,
                       extra_meta={"n_vertices": int(
                           bundle["degrees"].shape[0]),
                           "prefix_crc": _prefix_crc(full_src, full_dst,
                                                     pos)})
        return result

    config = _scan_identity_config(partitioner, k, seed)
    flat, meta = load_store.load(consumer=partitioner, config=config,
                            max_stream_pos=E_total)
    _check_prefix(meta, full_src, full_dst)
    E0 = int(meta["stream_pos"])
    n_old = int(meta.get("n_vertices", n_vertices))
    prefix_parts = np.asarray(flat.pop("parts"), np.int32)
    alive = np.asarray(flat.pop("alive"), bool)
    # reassemble the scoring carry from its path-keyed leaves (the same
    # path-string scheme the checkpoint manager saved them under)
    proto = _scan_carry(partitioner, n_old, k, seed).init()
    keys = [key for key, _ in _flatten_with_paths({"scan": proto})]
    carry = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(proto), [flat[key] for key in keys])
    dsrc = full_src[E0:]
    ddst = full_dst[E0:]
    E_delta = E_total - E0
    n_new = n_vertices
    if E_delta:
        n_new = max(n_old, int(max(dsrc.max(), ddst.max())) + 1, n_vertices)
    carry = grow_carry(partitioner, carry, n_old, n_new, k=k, seed=seed)
    pc = _scan_carry(partitioner, n_new, k, seed)
    parts = prefix_parts
    if E_delta:
        stream = DeltaStream(dsrc, ddst, n_new, base_offset=E0,
                             chunk_size=chunk_size)
        delta_parts, carry = run_incremental_carry(
            stream, pc, carry=carry, num_streams=num_streams,
            super_chunk=super_chunk)
        parts = np.concatenate([prefix_parts,
                                np.asarray(delta_parts, np.int32)])
        alive = np.concatenate([alive, np.ones(E_delta, bool)])
    n_retracted = 0
    if delete is not None and len(delete):
        idx = np.unique(np.asarray(delete, np.int64))
        if idx[0] < 0 or idx[-1] >= E_total:
            raise ValueError(
                f"deletion indices must lie in [0, {E_total})")
        if not alive[idx].all():
            raise ValueError("deletion names edges that are already deleted")
        from ..streaming import run_retract

        back = DeltaStream(full_src[idx], full_dst[idx], n_new, sign=-1,
                           chunk_size=chunk_size)
        carry = run_retract(back, pc, parts[idx], carry=carry,
                            num_streams=num_streams, super_chunk=super_chunk)
        parts = parts.copy()
        parts[idx] = -1
        alive = alive.copy()
        alive[idx] = False
        n_retracted = int(idx.size)
    rf, bal = _metrics(full_src, full_dst, parts, n_new, k)
    if save:
        store.save({"scan": carry, "parts": parts, "alive": alive},
                   consumer=partitioner,
                   config=config, stream_pos=E_total,
                   extra_meta={"n_vertices": int(n_new),
                               "prefix_crc": _prefix_crc(full_src, full_dst,
                                                         E_total)})
    return IncrementalResult(
        parts=parts, rf=rf, balance=bal, refined=False, rf_drift=0.0,
        balance_drift=0.0, edges_replayed=E_delta + n_retracted,
        full_replay_cost=E_total, game_rounds=0, n_new_clusters=0,
        n_delta_edges=E_delta, n_retracted=n_retracted)


# ---------------------------------------------------------------------------
# sliding-window S5P: track the last W edges continuously
# ---------------------------------------------------------------------------


class WindowStep(NamedTuple):
    """Per-step record of a sliding-window run."""

    step: int
    lo: int  # live window after the step: arrival indices [lo, hi)
    hi: int
    rf: float
    balance: float
    refined: bool
    rolled_back: bool
    n_inserted: int
    n_retracted: int
    churn: float
    needs_cold_restart: bool
    xi_drift: float
    n_compacted: int  # combined ids dropped by compaction this step
    filling: bool = False  # window not yet full — no partition maintained
    cold_restarted: bool = False  # acted on needs_cold_restart this step
    n_slots_freed: int = 0  # dead per-edge slots dropped this step


class S5PWindowChain:
    """Stepwise sliding-window S5P: one churn event per :meth:`step`.

    The engine behind :func:`s5p_sliding_window` (which just drains it)
    and the live serving controller (which publishes a bundle snapshot
    after each step).  Each step admits the next ``step_edges`` arrivals
    (:func:`~repro.incremental.pipeline.s5p_apply_delta`), retracts the
    expired batch (:func:`~repro.incremental.pipeline.s5p_apply_deletion`),
    then runs the maintenance ladder:

    - **cold restart** — with ``auto_cold_restart=True`` the chain *acts*
      on the drift monitor's ``needs_cold_restart`` signal instead of
      just reporting it: the live window is re-partitioned from scratch
      (:func:`~repro.incremental.pipeline.s5p_cold_restart`), refreshing
      the frozen ξ/κ thresholds and CMS width at current scale;
    - **cluster-id compaction** — when the append-only combined id space
      exceeds ``compact_factor ×`` its last-known live size,
      :func:`~repro.incremental.pipeline.compact_bundle` renumbers it
      (``compact_factor <= 0`` disables);
    - **slot compaction** — when the per-edge arrays hold more than
      ``slot_compact_factor ×`` the live edge count,
      :func:`~repro.incremental.pipeline.compact_edge_slots` frees the
      tombstones, bounding bundle memory by O(live window) instead of
      O(arrivals) (``slot_compact_factor <= 0`` disables).

    The chain cold-starts when the window **first fills** (fill-phase
    events are recorded as ``filling`` steps without a partition) so the
    frozen clustering closure is sized for a full window rather than the
    first step batch.
    """

    def __init__(self, src, dst, n_vertices: int, config: S5PConfig,
                 window_edges: int, *, step_edges: int | None = None,
                 stream=None, compact_factor: float = 2.0,
                 slot_compact_factor: float = 4.0,
                 auto_cold_restart: bool = False):
        from ..streaming import SlidingWindowStream, as_stream

        st = as_stream(src, dst, n_vertices, stream=stream,
                       chunk_size=config.chunk_size)
        self.config = config
        self.window_edges = int(window_edges)
        self.compact_factor = float(compact_factor)
        self.slot_compact_factor = float(slot_compact_factor)
        self.auto_cold_restart = bool(auto_cold_restart)
        self._sw = SlidingWindowStream(st, window_edges,
                                       step_edges=step_edges)
        self.n_vertices = int(st.n_vertices)
        self.n_steps = self._sw.n_steps
        # arrival prefix [0, hi), filled in place per event — one O(E)
        # buffer for the whole run instead of O(E²) re-concatenation (for
        # OOC streams this is the driver's single deliberate
        # materialization; the apply/retract calls index it by arrival)
        self._buf_src = np.empty(st.n_edges, np.int32)
        self._buf_dst = np.empty(st.n_edges, np.int32)
        self.bundle: dict | None = None
        self._c_live_known = 1
        self._events = self._sw.events()
        self._i = 0
        self.lo = 0
        self.hi = 0

    @property
    def seen_src(self) -> np.ndarray:
        """Arrivals [0, hi) — the stream prefix the bundle is keyed on."""
        return self._buf_src[:self.hi]

    @property
    def seen_dst(self) -> np.ndarray:
        return self._buf_dst[:self.hi]

    def live_edges(self) -> tuple[np.ndarray, np.ndarray]:
        """The live window's edges, in slot order (empty while filling)."""
        if self.bundle is None:
            z = np.zeros(0, np.int32)
            return z, z
        alive = np.asarray(self.bundle["alive"], bool)
        arr = np.asarray(self.bundle["arrival"], np.int64)[alive]
        return self._buf_src[arr], self._buf_dst[arr]

    def live_partition(self):
        """``(src, dst, parts)`` of the live window, in slot order.

        The routing-table snapshot a serving loop publishes: fresh arrays
        each call (gathered out of the ring buffer / bundle), so a
        published snapshot is never mutated by later steps.  ``None``
        while the window is still filling.
        """
        if self.bundle is None:
            return None
        alive = np.asarray(self.bundle["alive"], bool)
        arr = np.asarray(self.bundle["arrival"], np.int64)[alive]
        parts = np.asarray(self.bundle["parts"], np.int32)[alive]
        return self._buf_src[arr], self._buf_dst[arr], parts

    def step(self) -> WindowStep | None:
        """Apply the next churn event; ``None`` when the stream is done."""
        ev = next(self._events, None)
        if ev is None:
            return None
        i = self._i
        self._i += 1
        self._buf_src[ev.start:ev.hi] = ev.src
        self._buf_dst[ev.start:ev.hi] = ev.dst
        self.lo, self.hi = ev.lo, ev.hi
        seen_src = self._buf_src[:ev.hi]
        seen_dst = self._buf_dst[:ev.hi]
        config = self.config
        if (self.bundle is None and ev.hi < self.window_edges
                and i < self.n_steps - 1):
            # window still filling: no partition yet, just accumulate
            return WindowStep(
                step=i, lo=ev.lo, hi=ev.hi, rf=0.0, balance=0.0,
                refined=False, rolled_back=False,
                n_inserted=int(ev.src.shape[0]), n_retracted=0,
                churn=0.0, needs_cold_restart=False, xi_drift=0.0,
                n_compacted=0, filling=True)
        if self.bundle is None:
            # first full window (or the stream ended short of one):
            # cold-start on everything seen, then retract any already-
            # expired prefix (only possible when step_edges > window)
            _, bundle = s5p_cold_bundle(seen_src, seen_dst,
                                        self.n_vertices, config)
            rf = float(bundle["rf_baseline"])
            bal = float(bundle["balance_baseline"])
            refined = rolled_back = needs_cold = False
            churn = xi_drift = 0.0
            n_ret = 0
            if ev.expire_idx.size:
                bundle, res = s5p_apply_deletion(bundle, config, seen_src,
                                                 seen_dst, ev.expire_idx)
                rf, bal = res.rf, res.balance
                refined, churn = res.refined, res.churn
                xi_drift = res.xi_drift
                needs_cold = res.needs_cold_restart
                n_ret = int(ev.expire_idx.size)
            self._c_live_known = max(int(bundle["comb_is_head"].shape[0]), 1)
        else:
            bundle, res = s5p_apply_delta(self.bundle, config, seen_src,
                                          seen_dst, ev.start)
            n_ret = 0
            refined = res.refined
            if ev.expire_idx.size:
                bundle, dres = s5p_apply_deletion(bundle, config, seen_src,
                                                  seen_dst, ev.expire_idx)
                # the step refined if *either* phase did — dropping the
                # insertion's flag would undercount game spend in the
                # history the churn bench reports
                refined = refined or dres.refined
                res = dres
                n_ret = int(ev.expire_idx.size)
            rf, bal = res.rf, res.balance
            rolled_back = res.rolled_back
            churn, xi_drift = res.churn, res.xi_drift
            needs_cold = res.needs_cold_restart

        cold_restarted = False
        if needs_cold and self.auto_cold_restart:
            try:
                bundle, cres = s5p_cold_restart(bundle, config, seen_src,
                                                seen_dst)
            except ValueError:
                pass  # live set degenerate (no valid edge) — keep serving
            else:
                rf, bal = cres.rf, cres.balance
                cold_restarted = True
                self._c_live_known = max(
                    int(bundle["comb_is_head"].shape[0]), 1)
        n_comp = 0
        if self.compact_factor > 0 and not cold_restarted:
            C1 = int(np.asarray(bundle["comb_is_head"]).shape[0])
            if C1 > self.compact_factor * self._c_live_known:
                bundle, n_comp = compact_bundle(bundle, config)
                self._c_live_known = max(
                    int(np.asarray(bundle["comb_is_head"]).shape[0]), 1)
        n_freed = 0
        if self.slot_compact_factor > 0:
            n_slots = int(np.asarray(bundle["parts"]).shape[0])
            n_live = int(np.count_nonzero(np.asarray(bundle["alive"])))
            if n_slots > self.slot_compact_factor * max(n_live, 1):
                bundle, n_freed = compact_edge_slots(bundle)
        self.bundle = bundle
        return WindowStep(
            step=i, lo=ev.lo, hi=ev.hi, rf=float(rf), balance=float(bal),
            refined=bool(refined), rolled_back=bool(rolled_back),
            n_inserted=int(ev.src.shape[0]), n_retracted=n_ret,
            churn=float(churn), needs_cold_restart=bool(needs_cold),
            xi_drift=float(xi_drift), n_compacted=int(n_comp),
            cold_restarted=cold_restarted, n_slots_freed=int(n_freed))

    def resize(self, k_new: int):
        """Elastic k→k′: reshard the live bundle onto ``k_new`` partitions.

        Bounded migration via :func:`repro.elastic.reshard_bundle` — edges
        whose partition survives keep their placement; the chain's config
        follows to k′ so subsequent steps ingest against the new count.
        Returns the :class:`~repro.elastic.ReshardResult`, or ``None``
        while the window is still filling (nothing to reshard — the cold
        start will simply run at the updated k).
        """
        from ..elastic import reshard_bundle

        if self.bundle is None:
            self.config = dataclasses.replace(self.config, k=int(k_new))
            return None
        bundle, config, res = reshard_bundle(
            self.bundle, self.config, k_new, self.seen_src, self.seen_dst)
        self.bundle = bundle
        self.config = config
        return res

    def steps(self):
        """Iterate the remaining churn schedule."""
        while True:
            rec = self.step()
            if rec is None:
                return
            yield rec


def s5p_sliding_window(src, dst, n_vertices: int, config: S5PConfig,
                       window_edges: int, *, step_edges: int | None = None,
                       stream=None, compact_factor: float = 2.0,
                       slot_compact_factor: float = 4.0,
                       auto_cold_restart: bool = False):
    """Maintain an S5P partition of the **last ``window_edges`` edges**.

    Drains an :class:`S5PWindowChain` over the arrival stream (see the
    class docstring for the per-event semantics: delta fold → expiry
    retraction → auto cold restart → cluster-id / edge-slot compaction).
    Expiry retractions count toward the drift trigger, so sustained churn
    keeps re-settling the clusters through the masked Stackelberg game.

    Returns ``(history, bundle)`` — one :class:`WindowStep` per event and
    the final bundle.  The bundle's per-edge arrays are **slot**-indexed:
    ``bundle["arrival"]`` maps each slot to its global arrival index, and
    slots whose edges expired may have been freed by slot compaction.
    """
    chain = S5PWindowChain(
        src, dst, n_vertices, config, window_edges, step_edges=step_edges,
        stream=stream, compact_factor=compact_factor,
        slot_compact_factor=slot_compact_factor,
        auto_cold_restart=auto_cold_restart)
    history = list(chain.steps())
    return history, chain.bundle
