"""Delta ingestion: replay only the new edges against a restored carry.

A warm-start replay is pure function composition: every streaming consumer
folds its carry edge-by-edge, so ``fold(fold(init, prefix), delta) ==
fold(init, prefix + delta)`` whenever the step closure (degrees, ξ, κ, λ,
grid tables, c2p) is held fixed and padding self-loops are no-ops.
:class:`DeltaStream` wraps an insertion batch as a standard
:class:`~repro.streaming.stream.EdgeStream` (so orderings, chunking,
parallel ingest and the out-of-core machinery all apply unchanged), and
:func:`run_incremental_carry` drives any PartitionerCarry over it from a
saved carry instead of ``init()``.

Vertex-set growth: an insertion batch may name vertices the base run never
saw.  :func:`grow_carry` widens a consumer's carry to a larger vertex
count — new rows are the identity (unassigned ``-1`` tables, ``False``
bitmap rows, zero volumes/degrees), so growth commutes with folding and
costs nothing semantically.  Replicated per-vertex tables (the grid
row/col hashes) are recomputed; the per-vertex hash is independent, so the
old prefix is reproduced bit-identically.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..streaming import EdgeStream, run_carry, run_parallel
from ..streaming.stream import DEFAULT_CHUNK

__all__ = ["DeltaStream", "run_incremental_carry", "grow_carry"]


class DeltaStream(EdgeStream):
    """A churn batch as a standard EdgeStream, tagged ``sign`` ±1.

    ``sign=+1`` (default) is an insertion batch; ``sign=-1`` a deletion
    batch — the drivers fold the former through ``step_chunk`` and the
    latter through ``retract_chunk``.  ``base_offset`` records where the
    batch sits in the logical full stream (for insertions: the number of
    edges ingested before it) — provenance a caller can read back instead
    of threading the split point alongside the stream.  Default ordering
    is ``natural`` — insertion order is the stream order of a dynamic
    graph (and retraction is order-independent anyway).
    """

    def __init__(self, src, dst, n_vertices: int | None = None, *,
                 base_offset: int = 0, sign: int = +1,
                 chunk_size: int = DEFAULT_CHUNK,
                 ordering: str = "natural", seed: int = 0,
                 window: int = 4096):
        if base_offset < 0:
            raise ValueError("base_offset must be >= 0")
        if sign not in (+1, -1):
            raise ValueError(f"sign must be +1 or -1, got {sign}")
        super().__init__(src, dst, n_vertices, chunk_size=chunk_size,
                         ordering=ordering, seed=seed, window=window)
        self.base_offset = int(base_offset)
        self.sign = int(sign)


def run_incremental_carry(stream, pc, *extras, carry, num_streams: int = 1,
                          super_chunk: int = 8):
    """Drive ``pc`` over ``stream`` seeded with a restored ``carry``.

    Same return contract as :func:`~repro.streaming.engine.run_carry`:
    ``(delta_parts | None, pc.finalize(final_carry))``.  ``num_streams >
    1`` shards the delta through :func:`~repro.streaming.run_parallel`
    with the restored carry as the merge base.
    """
    if num_streams > 1:
        return run_parallel(stream, pc, *extras, num_streams=num_streams,
                            super_chunk=super_chunk, carry=carry)
    return run_carry(stream, pc, *extras, carry=carry)


# ---------------------------------------------------------------------------
# vertex-set growth
# ---------------------------------------------------------------------------


def _pad_rows(arr, n_new: int, fill):
    arr = np.asarray(arr)
    if n_new <= arr.shape[0]:
        return arr
    pad = np.full((n_new - arr.shape[0],) + arr.shape[1:], fill, arr.dtype)
    return np.concatenate([arr, pad])


def grow_carry(consumer: str, carry, n_old: int, n_new: int, *,
               k: int | None = None, seed: int = 0):
    """Widen a consumer's carry from ``n_old`` to ``n_new`` vertices.

    Identity extension per field class: assignment tables pad with ``-1``,
    counted replica/membership tables with ``0``, volumes/degrees with
    ``0``; O(k) and scalar fields pass through.  ``consumer`` ∈ {greedy,
    hdrf, grid, cluster, degree, sketch, assign} — the repo's streaming
    consumers.
    """
    if n_new < n_old:
        raise ValueError(f"cannot shrink a carry ({n_new} < {n_old})")
    if n_new == n_old:
        return carry
    if consumer == "degree":
        return jnp.asarray(_pad_rows(carry, n_new, 0))
    if consumer == "greedy":
        load, rep = carry
        return (load, jnp.asarray(_pad_rows(rep, n_new, 0)))
    if consumer == "hdrf":
        load, rep, pd, lam, kmask = carry
        return (load, jnp.asarray(_pad_rows(rep, n_new, 0)),
                jnp.asarray(_pad_rows(pd, n_new, 0)), lam, kmask)
    if consumer == "grid":
        from ..core.baselines import _grid_dims, _grid_rowcol

        load = carry[0]
        if k is None:
            k = int(np.asarray(load).shape[0])
        _, c = _grid_dims(k)
        row, col = _grid_rowcol(n_new, k, c, seed)
        return (load, row, col, carry[3])
    if consumer == "cluster":
        from ..core.clustering import ClusterState

        st = carry
        # vol arrays are cluster-indexed with a trailing masked-write sink
        # slot that provably stays 0 (masked adds write +0) — growing keeps
        # the old sink slot as a regular (zero) cluster slot and appends a
        # fresh sink.
        return ClusterState(
            v2c_h=jnp.asarray(_pad_rows(st.v2c_h, n_new, -1)),
            v2c_t=jnp.asarray(_pad_rows(st.v2c_t, n_new, -1)),
            vol_h=jnp.asarray(_pad_rows(st.vol_h, n_new + 1, 0)),
            vol_t=jnp.asarray(_pad_rows(st.vol_t, n_new + 1, 0)),
            ld=jnp.asarray(_pad_rows(st.ld, n_new, 0)),
            next_h=st.next_h,
            next_t=st.next_t,
            cnt_h=jnp.asarray(_pad_rows(st.cnt_h, n_new, 0)),
            cnt_t=jnp.asarray(_pad_rows(st.cnt_t, n_new, 0)),
            alloc_h=jnp.asarray(_pad_rows(st.alloc_h, n_new, 0)),
        )
    if consumer in ("sketch", "assign"):
        return carry  # no per-vertex state
    raise ValueError(f"unknown consumer {consumer!r}")
