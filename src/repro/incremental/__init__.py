"""Incremental re-partitioning: carry checkpoints, delta streams, drift-
triggered game refinement.

The paper's S5P is a one-shot streaming partitioner; real deployments see
graphs that keep growing.  Le Merrer & Trédan ("(Re)partitioning for
stream-enabled computation") observed that replaying only the *new* edges
against retained partitioner state recovers most of the quality of a full
re-run at a fraction of its cost — and PR 3's
:class:`~repro.streaming.carry.PartitionerCarry` protocol is exactly the
retained state this needs: a warm-start replay is ``run_carry`` (or
``run_parallel``) seeded with a previous carry instead of ``init()``.

Why carry-merge semantics make warm starts sound
------------------------------------------------
Every consumer's carry declares per-field merge ops (SUM / COUNTED /
REPLICATED since the decremental refactor), and those same laws govern
incremental replay:

- **SUM fields** (degrees, loads, cluster volumes, HDRF partial degrees,
  Θ count-min tables, assignment tables as sums of transitions) are
  linear: state(prefix + delta) = state(prefix) + state(delta).  Folding
  the delta onto the restored carry *is* that sum — and because a group
  has inverses, **deleting** an edge folds the negated delta instead
  (``retract_chunk`` / :func:`~repro.streaming.run_retract`).
- **COUNTED fields** (replica/membership occupancy counters, standing in
  for the old monotone OR bitmaps) OR-project (``> 0``) for scoring and
  subtract exactly: the counter reaching 0 is the tombstone-free way an
  assignment or replica vanishes when its last edge is deleted.
- **REPLICATED fields** (λ, grid hash tables, the k-mask) are scenario
  constants; the config fingerprint in the
  :class:`~repro.incremental.store.CarryStore` guarantees they match —
  and its ``carry_repr`` check rejects pre-refactor monotone checkpoints.

Exactly vs approximately incremental
------------------------------------
Sequential folding is function composition, so
``fold(fold(init, prefix), delta) == fold(init, prefix + delta)``
**bit-identically** whenever (a) the step closure is held fixed and (b)
chunk-padding self-loops are true no-ops.  Concretely:

- **exact**: the degree precompute, the Θ sketch pass, Alg. 1 clustering
  (under frozen degrees/ξ/κ), greedy and grid scans, and Alg. 3 placement
  (under a frozen cluster→partition map and capacity) — all of these mask
  ``(0, 0)`` padding entirely;
- **approximate**: HDRF (its partial-degree estimates count padding
  self-loops at chunk seams, exactly as a cold run's own tail padding
  does — the divergence is bounded by one vertex-0 count per seam), and
  the *pipeline-level* S5P warm start, where ξ/κ freeze at base-run
  values, old edges keep their placement and size/Θ attributions while
  the graph grows, and the CMS stays sized for the base cluster count.

The pipeline approximations are the price of not replaying the prefix;
their cumulative quality decay is what :class:`~repro.incremental.drift.
DriftMonitor` tracks, and a drift past threshold triggers a **bounded
masked Stackelberg game** (``core.game`` with ``leader_mask``/
``move_mask``) over only the clusters the deltas touched, followed by
re-placement of only the moved clusters' edges — the per-edge cluster
tags in the bundle make those edges addressable without a stream replay.

Pieces
------
- :class:`CarryStore` — atomic npz+CRC persistence of any carry with
  consumer/config/stream-position validation and keep-N GC;
- :class:`DeltaStream` / :func:`run_incremental_carry` /
  :func:`grow_carry` — an insertion batch as a standard EdgeStream,
  warm-start drivers, vertex-set growth;
- :class:`DriftMonitor` — the refinement trigger;
- :mod:`pipeline` — the S5P bundle (build + delta application);
- :mod:`driver` — ``cold_start`` / ``run_incremental`` over scan
  partitioners and the S5P pipeline (the CLI's ``--save-carry`` /
  ``--resume-carry`` / ``--delta`` backend).
"""

from .delta import DeltaStream, grow_carry, run_incremental_carry  # noqa: F401
from .drift import DriftDecision, DriftMonitor, RefreshDecision  # noqa: F401
from .driver import (  # noqa: F401
    INCREMENTAL_PARTITIONERS,
    SCAN_PARTITIONERS,
    S5PWindowChain,
    WindowStep,
    cold_start,
    run_incremental,
    s5p_sliding_window,
)
from .pipeline import (  # noqa: F401
    JOURNAL_PREFIX,
    IncrementalResult,
    compact_bundle,
    compact_edge_slots,
    ensure_slot_index,
    pack_warm_bundle,
    s5p_apply_delta,
    s5p_apply_deletion,
    s5p_cold_bundle,
    s5p_cold_restart,
    s5p_identity_config,
)
from .store import CarryMismatchError, CarryStore, config_fingerprint  # noqa: F401

__all__ = [
    "CarryStore",
    "CarryMismatchError",
    "config_fingerprint",
    "DeltaStream",
    "run_incremental_carry",
    "grow_carry",
    "DriftMonitor",
    "DriftDecision",
    "RefreshDecision",
    "IncrementalResult",
    "s5p_cold_bundle",
    "pack_warm_bundle",
    "s5p_apply_delta",
    "s5p_apply_deletion",
    "s5p_cold_restart",
    "compact_bundle",
    "compact_edge_slots",
    "ensure_slot_index",
    "s5p_identity_config",
    "cold_start",
    "run_incremental",
    "s5p_sliding_window",
    "S5PWindowChain",
    "WindowStep",
    "SCAN_PARTITIONERS",
    "INCREMENTAL_PARTITIONERS",
]
