"""Drift monitoring: decide when a delta has degraded quality enough to
spend a refinement game on it — and when a warm chain should stop
patching and re-run cold.

The monitor tracks replication factor and balance against a *baseline*
(the last full run or the last refinement point).  Quality decays
monotonically-ish under pure warm-start replay — old edges keep their
placement while the graph underneath them changes — so the signal is a
simple relative drift:

    rf_drift      = (rf_now − rf_baseline) / rf_baseline
    balance_drift = balance_now − balance_baseline

Deletions add a third channel: every **retraction** (deleted or expired
edge) is counted toward the same trigger, because retraction leaves
approximate state behind (cluster volumes subtract at the vertex's
*current* cluster, not its insertion-time one) even when RF momentarily
improves.  ``churn = retracted_since_baseline / live_edges`` trips the
refinement at ``churn_threshold`` regardless of the RF signal.

Refinement triggers when any channel exceeds its threshold.  The baseline
(and the touched-cluster set that scopes the refinement game, and the
retraction counter) resets after a refinement, so repeated small deltas
accumulate toward a trigger instead of each hiding under the threshold —
the Le Merrer & Trédan observation that replay quality decays with
*cumulative* churn volume, not per batch.

Full-refresh policy (the ROADMAP follow-on): refinement re-settles
clusters but the clustering thresholds ξ (head/tail split) and κ (volume
cap) stay frozen at base-run values — after enough churn the *frozen
closure itself* is wrong, and no amount of game rounds fixes a stale
head/tail classification.  :meth:`DriftMonitor.refresh_check` compares
the thresholds a cold run would choose *today* against the frozen ones
and raises ``needs_cold_restart`` once the relative drift of either
passes ``xi_refresh_threshold`` — a cheap O(1) trigger for "stop
patching, re-run cold" that long warm chains consult after every delta.
"""

from __future__ import annotations

from typing import NamedTuple

__all__ = ["DriftMonitor", "DriftDecision", "RefreshDecision"]


class DriftDecision(NamedTuple):
    refine: bool
    rf_drift: float
    balance_drift: float
    churn: float = 0.0


class RefreshDecision(NamedTuple):
    needs_cold_restart: bool
    xi_drift: float
    kappa_drift: float


def _rel_drift(now: float, base: float) -> float:
    return abs(float(now) - float(base)) / max(abs(float(base)), 1.0)


class DriftMonitor:
    """Threshold trigger over (RF, balance, churn) drift since baseline.

    ``rf_threshold <= 0`` makes every delta trigger (useful for forcing
    refinement in tests/benchmarks); ``float("inf")`` disables it.
    ``retracted`` seeds the cumulative retraction counter (restored from
    a persisted bundle); call :meth:`note_retractions` as deletions are
    applied.
    """

    def __init__(self, baseline_rf: float, baseline_balance: float, *,
                 rf_threshold: float = 0.05,
                 balance_threshold: float = 0.10,
                 churn_threshold: float = 0.25,
                 retracted: int = 0):
        self.baseline_rf = float(baseline_rf)
        self.baseline_balance = float(baseline_balance)
        self.rf_threshold = float(rf_threshold)
        self.balance_threshold = float(balance_threshold)
        self.churn_threshold = float(churn_threshold)
        self.retracted = int(retracted)

    def note_retractions(self, n: int) -> None:
        """Count ``n`` retracted (deleted/expired) edges toward drift."""
        self.retracted += int(n)

    def check(self, rf: float, balance: float,
              live_edges: int | None = None) -> DriftDecision:
        rf_drift = (rf - self.baseline_rf) / max(self.baseline_rf, 1e-12)
        bal_drift = balance - self.baseline_balance
        churn = (self.retracted / max(int(live_edges), 1)
                 if live_edges is not None else 0.0)
        # threshold <= 0 is the unconditional trigger even when drift is
        # negative (RF can *drop* when a delta adds many fresh vertices)
        refine = (self.rf_threshold <= 0
                  or rf_drift >= self.rf_threshold
                  or bal_drift >= self.balance_threshold
                  or churn >= self.churn_threshold)
        return DriftDecision(bool(refine), float(rf_drift), float(bal_drift),
                             float(churn))

    def rebase(self, rf: float, balance: float) -> None:
        """Reset the baseline (after a refinement or a full re-run)."""
        self.baseline_rf = float(rf)
        self.baseline_balance = float(balance)
        self.retracted = 0

    # ------------------------------------------------- full-refresh policy
    @staticmethod
    def refresh_check(xi_frozen: float, kappa_frozen: float,
                      xi_now: float, kappa_now: float, *,
                      xi_refresh_threshold: float = 0.5) -> RefreshDecision:
        """Should this warm chain re-run cold?

        ``xi_now``/``kappa_now`` are the thresholds a cold run over the
        *current live* graph would pick (β·avg-degree and 2|E|/k); the
        frozen values are what the chain is still classifying with.
        Either drifting past ``xi_refresh_threshold`` (relative) raises
        the signal.  Purely advisory — the caller decides when to act.
        """
        xd = _rel_drift(xi_now, xi_frozen)
        kd = _rel_drift(kappa_now, kappa_frozen)
        needs = (xd > xi_refresh_threshold) or (kd > xi_refresh_threshold)
        return RefreshDecision(bool(needs), float(xd), float(kd))
