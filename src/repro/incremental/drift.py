"""Drift monitoring: decide when a delta has degraded quality enough to
spend a refinement game on it.

The monitor tracks replication factor and balance against a *baseline*
(the last full run or the last refinement point).  Quality decays
monotonically-ish under pure warm-start replay — old edges keep their
placement while the graph underneath them changes — so the signal is a
simple relative drift:

    rf_drift      = (rf_now − rf_baseline) / rf_baseline
    balance_drift = balance_now − balance_baseline

Refinement triggers when either exceeds its threshold.  The baseline (and
the touched-cluster set that scopes the refinement game) resets after a
refinement, so repeated small deltas accumulate toward a trigger instead
of each hiding under the threshold — the Le Merrer & Trédan observation
that replay quality decays with *cumulative* insertion volume, not per
batch.
"""

from __future__ import annotations

from typing import NamedTuple

__all__ = ["DriftMonitor", "DriftDecision"]


class DriftDecision(NamedTuple):
    refine: bool
    rf_drift: float
    balance_drift: float


class DriftMonitor:
    """Threshold trigger over (RF, balance) drift since the last baseline.

    ``rf_threshold <= 0`` makes every delta trigger (useful for forcing
    refinement in tests/benchmarks); ``float("inf")`` disables it.
    """

    def __init__(self, baseline_rf: float, baseline_balance: float, *,
                 rf_threshold: float = 0.05,
                 balance_threshold: float = 0.10):
        self.baseline_rf = float(baseline_rf)
        self.baseline_balance = float(baseline_balance)
        self.rf_threshold = float(rf_threshold)
        self.balance_threshold = float(balance_threshold)

    def check(self, rf: float, balance: float) -> DriftDecision:
        rf_drift = (rf - self.baseline_rf) / max(self.baseline_rf, 1e-12)
        bal_drift = balance - self.baseline_balance
        # threshold <= 0 is the unconditional trigger even when drift is
        # negative (RF can *drop* when a delta adds many fresh vertices)
        refine = (self.rf_threshold <= 0
                  or rf_drift >= self.rf_threshold
                  or bal_drift >= self.balance_threshold)
        return DriftDecision(bool(refine), float(rf_drift), float(bal_drift))

    def rebase(self, rf: float, balance: float) -> None:
        """Reset the baseline (after a refinement or a full re-run)."""
        self.baseline_rf = float(rf)
        self.baseline_balance = float(balance)
