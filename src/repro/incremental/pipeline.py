"""Warm-start S5P: the full pipeline as an incrementally-maintained bundle.

The cold run's aux internals (``S5POutput.aux["incremental"]``) are packed
into a flat **carry bundle** — every piece of state the three passes of
Fig. 2 would otherwise recompute from scratch:

======================  =====================================================
``degrees``             global degree table (exactly incremental: SUM)
``v2c_h/v2c_t/...``     raw Algorithm-1 :class:`ClusterState` (sequential
                        fold — composition-exact under frozen ξ/κ)
``raw2comb_h/_t``       raw → **stable combined** cluster ids.  Unlike
                        ``compact_clusters`` (which renumbers from scratch
                        and would shift every tail id when a head cluster
                        appears), new clusters append at the end — so the
                        pair list, c2p and per-edge cluster tags stay valid
                        across deltas.
``comb_is_head``        leader set per combined id (the masked game's
                        ``leader_mask`` — new head clusters are leaders too,
                        even though their ids sit past the old tail block)
``sizes/pair_*``        cluster sizes + Θ adjacency in combined ids
``theta_table/seeds``   the CMS (linear ⇒ delta updates are exact)
``c2p/load/parts``      game assignment, Alg.-3 load vector, per-edge parts
``edge_cu/cv/head``     per-edge cluster tags — what lets refinement find
                        the edges of a moved cluster *without* replaying
                        the stream
``touched``             clusters touched since the last refinement baseline
======================  =====================================================

Exact vs approximate (the warm-start semantics):

- **exact** — degrees, the Θ sketch, the Alg.-1 fold itself, and Alg.-3
  placement of the delta (composition: fold(prefix→carry, delta) ==
  fold(prefix+delta) under the frozen closure);
- **approximate** — ξ/κ/``max_load`` freeze at base-run values (ξ, κ) or
  recompute from the grown |E| (``max_load``), old edges keep their
  placement and their size/Θ attributions even when their vertices migrate
  during the replay, and CMS width stays sized for the base cluster count.
  This is precisely the quality decay the drift monitor watches; past the
  threshold a **bounded masked Stackelberg game** re-settles only the
  touched clusters and re-places only the moved clusters' edges.

Deletions (the decremental refactor):

- every edge of the bundle is **tombstoned, not removed**: ``alive`` masks
  the per-edge records, a deleted edge's ``parts`` entry becomes ``-1``
  (which every metric already ignores), and the group-structured carries
  subtract the edge's accounting — degrees, Θ sheets, sizes and the Alg.-3
  load exactly (stored per-edge cluster tags, including the *alt*
  memberships, make the retraction self-contained), the Alg.-1 fold
  approximately (:func:`repro.core.clustering.cluster_retract_chunk`);
- the bundle is **versioned**: each insertion snapshots the O(|V|+C+P+k)
  fields it is about to mutate (the per-edge arrays only ever append, so
  truncation restores them).  Deleting exactly the last-inserted batch
  rolls the version back — ``insert(δ)`` then ``delete(δ)`` restores the
  pre-δ carry **bitwise** (pinned by tests/test_carry.py) as long as the
  insertion did not trigger a refinement (refinement rewrites old edges'
  parts, which invalidates the journal);
- any other deletion takes the decremental path above, counts its
  retractions toward the drift trigger, and — combined with
  :class:`~repro.streaming.window.SlidingWindowStream` — yields a
  partitioner that continuously tracks the last W edges;
- :func:`compact_bundle` renumbers the append-only combined cluster id
  space (deletions orphan ids that would otherwise accumulate forever)
  and rewrites the pair list and per-edge tags.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..core import clustering as _cl
from ..core import game as _game
from ..core.cms import CMSketch, cms_query, cms_retract, cms_update, pair_key
from ..core.metrics import load_balance, replication_factor
from ..core.postprocess import AssignCarry
from ..core.s5p import S5PConfig, S5POutput, s5p_partition
from ..streaming import EdgeStream, run_carry
from .delta import DeltaStream, grow_carry, run_incremental_carry
from .drift import DriftMonitor

__all__ = ["IncrementalResult", "s5p_identity_config", "s5p_cold_bundle",
           "pack_warm_bundle",
           "s5p_apply_delta", "s5p_apply_deletion", "compact_bundle",
           "compact_edge_slots", "ensure_slot_index", "s5p_cold_restart",
           "JOURNAL_PREFIX"]

_INT32_MAX = 2**31 - 1


class IncrementalResult(NamedTuple):
    """What one delta application did (and what it would have cost cold)."""

    # (stream_pos,) int32, arrival-indexed — full assignment after the
    # delta; deleted edges (tombstoned or slot-compacted away) are −1
    parts: np.ndarray
    rf: float
    balance: float
    refined: bool
    rf_drift: float
    balance_drift: float
    edges_replayed: int  # consumer-fold records processed by the warm path
    full_replay_cost: int  # the cold re-run's fold count (4 passes × E)
    game_rounds: int  # settlement + refinement rounds spent
    n_new_clusters: int
    n_delta_edges: int
    n_retracted: int = 0  # edges deleted/expired by this application
    churn: float = 0.0  # cumulative retraction fraction at the drift check
    needs_cold_restart: bool = False  # ξ/κ refresh policy (advisory)
    xi_drift: float = 0.0  # relative drift of the frozen ξ from live value
    kappa_drift: float = 0.0
    rolled_back: bool = False  # deletion was served by a version rollback

    @property
    def replay_fraction(self) -> float:
        return self.edges_replayed / max(self.full_replay_cost, 1)


def s5p_identity_config(config: S5PConfig) -> dict:
    """The config fields a carry must agree on to seed a warm start.

    Execution knobs (chunk_size, num_streams, game batching, drift
    thresholds) are deliberately excluded — they change how a replay runs,
    not what state means.
    """
    return {
        "k": config.k, "tau": config.tau, "beta": config.beta,
        "use_cms": config.use_cms, "cms_epsilon": config.cms_epsilon,
        "cms_nu": config.cms_nu, "bounded": config.bounded,
        "one_stage": config.one_stage, "seed": config.seed,
        "ordering": config.ordering,
    }


# ---------------------------------------------------------------------------
# cold start → bundle
# ---------------------------------------------------------------------------


def _raw_to_comb(raw_table: np.ndarray, comb_table: np.ndarray,
                 n_raw: int) -> np.ndarray:
    """Reconstruct the raw→combined id map from the two per-vertex tables
    (``compact_clusters`` applies it consistently, so a scatter recovers it)."""
    out = np.full(max(n_raw, 1), -1, np.int32)
    mask = raw_table >= 0
    out[raw_table[mask]] = comb_table[mask]
    return out


def s5p_cold_bundle(src, dst, n_vertices: int, config: S5PConfig, *,
                    stream=None) -> tuple[S5POutput, dict]:
    """Run S5P cold and pack the warm-start bundle from its internals."""
    out = s5p_partition(src, dst, n_vertices, config, stream=stream)
    internals = out.aux.get("incremental")
    if internals is None:  # degenerate no-valid-edge graphs skip the passes
        raise ValueError("cold run produced no pipeline state to carry "
                         "(no valid edges)")
    bundle = pack_warm_bundle(
        src, dst, n_vertices, config,
        state=internals["cluster_state"], res=internals["compact"],
        degrees=internals["degrees"], sizes=internals["sizes"],
        pair_a=internals["pair_a"], pair_b=internals["pair_b"],
        pair_w=internals["pair_w"], c2p=out.cluster_assignment,
        parts=out.parts, load=internals["load"], xi=out.xi,
        kappa=out.kappa, sketch=out.aux.get("sketch"))
    return out, bundle


def pack_warm_bundle(src, dst, n_vertices: int, config: S5PConfig, *,
                     state: _cl.ClusterState, res: _cl.ClusterResult,
                     degrees, sizes, pair_a, pair_b, pair_w, c2p, parts,
                     load, xi: int, kappa: int, sketch=None) -> dict:
    """Pack pipeline internals + a final (c2p, parts, load) into the flat
    warm-start carry bundle.

    Shared by the cold run (:func:`s5p_cold_bundle`) and the hybrid
    memory-budget driver (:func:`repro.hybrid.run_hybrid`), whose refined
    assignment replaces the cold game's — everything downstream (deltas,
    deletions, resharding, serving snapshots) treats the two identically.
    """
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    degrees = np.asarray(degrees, np.int32)

    v2c_h = np.asarray(state.v2c_h)
    v2c_t = np.asarray(state.v2c_t)
    raw2comb_h = _raw_to_comb(v2c_h, np.asarray(res.v2c_h), int(state.next_h))
    raw2comb_t = _raw_to_comb(v2c_t, np.asarray(res.v2c_t), int(state.next_t))
    C = res.n_clusters
    # one_stage (Fig. 7d ablation) makes every cluster a leader in the
    # cold game; the warm settle/refine games must keep that semantics
    comb_is_head = (np.ones(C, bool) if config.one_stage
                    else np.arange(C) < res.n_head)

    parts = np.asarray(parts, np.int32)
    is_head_e = (degrees[src] > xi) & (degrees[dst] > xi)
    comb_h = np.asarray(res.v2c_h)
    comb_t = np.asarray(res.v2c_t)
    e_cu = np.where(is_head_e, comb_h[src], comb_t[src]).astype(np.int32)
    e_cv = np.where(is_head_e, comb_h[dst], comb_t[dst]).astype(np.int32)
    # the *other*-table memberships of each endpoint — the cross-type Θ
    # channels of cluster_statistics, stored so a deletion can retract
    # exactly the pair keys its insertion contributed
    e_alt_u = np.where(is_head_e, comb_t[src], comb_h[src]).astype(np.int32)
    e_alt_v = np.where(is_head_e, comb_t[dst], comb_h[dst]).astype(np.int32)
    invalid = src == dst
    for arr in (e_cu, e_cv, e_alt_u, e_alt_v):
        arr[invalid] = -1

    rf = replication_factor(src, dst, parts, n_vertices=n_vertices,
                            k=config.k)
    bal = load_balance(parts, k=config.k)

    bundle = {
        "degrees": degrees,
        "v2c_h": v2c_h.astype(np.int32),
        "v2c_t": v2c_t.astype(np.int32),
        "vol_h": np.asarray(state.vol_h, np.int32),
        "vol_t": np.asarray(state.vol_t, np.int32),
        "ld": np.asarray(state.ld, np.int32),
        "next_h": np.int32(state.next_h),
        "next_t": np.int32(state.next_t),
        "cnt_h": np.asarray(state.cnt_h, np.int32),
        "cnt_t": np.asarray(state.cnt_t, np.int32),
        "alloc_h": np.asarray(state.alloc_h, np.int32),
        "raw2comb_h": raw2comb_h,
        "raw2comb_t": raw2comb_t,
        "comb_is_head": comb_is_head,
        "sizes": np.asarray(sizes, np.float32),
        "pair_a": np.asarray(pair_a, np.int32),
        "pair_b": np.asarray(pair_b, np.int32),
        "pair_w": np.asarray(pair_w, np.float32),
        "c2p": np.asarray(c2p, np.int32),
        "load": np.asarray(load, np.int32),
        "parts": parts,
        "edge_cu": e_cu,
        "edge_cv": e_cv,
        "edge_alt_u": e_alt_u,
        "edge_alt_v": e_alt_v,
        "edge_head": np.asarray(is_head_e, bool),
        "alive": np.ones(parts.shape[0], bool),
        # slot ↔ arrival decoupling: per-edge arrays are indexed by *slot*;
        # ``arrival[slot]`` is the global arrival index that slot holds.
        # Cold bundles start with the identity map; ``compact_edge_slots``
        # drops dead slots, after which the two spaces diverge (the
        # sorted ``arrival`` array IS the stable old→new index map).
        "arrival": np.arange(parts.shape[0], dtype=np.int64),
        "stream_pos": np.int64(parts.shape[0]),
        "touched": np.zeros(C, bool),
        "retracted": np.int64(0),
        "journal_valid": np.bool_(False),
        "journal_pos": np.int64(-1),
        "journal_slots": np.int64(-1),
        "xi": np.int32(xi),
        "kappa": np.int32(kappa),
        "rf_baseline": np.float64(rf),
        "balance_baseline": np.float64(bal),
    }
    if sketch is not None:
        bundle["theta_table"] = np.asarray(sketch.table)
        bundle["theta_seeds"] = np.asarray(sketch.seeds)
    return bundle


# ---------------------------------------------------------------------------
# delta application
# ---------------------------------------------------------------------------


def _comb_of(raw: np.ndarray, remap: np.ndarray) -> np.ndarray:
    return np.where(raw >= 0, remap[np.maximum(raw, 0)], -1).astype(np.int32)


def _scatter_parts(parts: np.ndarray, arrival: np.ndarray,
                   stream_pos: int) -> np.ndarray:
    """Slot-indexed parts → arrival-indexed (compacted arrivals are −1)."""
    full = np.full(int(stream_pos), -1, np.int32)
    full[arrival] = parts
    return full


def _unpack_cluster_state(b: dict) -> _cl.ClusterState:
    """The bundle's raw Algorithm-1 fields as a live ClusterState."""
    return _cl.ClusterState(
        v2c_h=jnp.asarray(b["v2c_h"]), v2c_t=jnp.asarray(b["v2c_t"]),
        vol_h=jnp.asarray(b["vol_h"]), vol_t=jnp.asarray(b["vol_t"]),
        ld=jnp.asarray(b["ld"]), next_h=jnp.int32(b["next_h"]),
        next_t=jnp.int32(b["next_t"]), cnt_h=jnp.asarray(b["cnt_h"]),
        cnt_t=jnp.asarray(b["cnt_t"]), alloc_h=jnp.asarray(b["alloc_h"]))


def _pack_cluster_state(b: dict, state: _cl.ClusterState,
                        next_h: int, next_t: int) -> None:
    b.update(
        v2c_h=np.asarray(state.v2c_h, np.int32),
        v2c_t=np.asarray(state.v2c_t, np.int32),
        vol_h=np.asarray(state.vol_h, np.int32),
        vol_t=np.asarray(state.vol_t, np.int32),
        ld=np.asarray(state.ld, np.int32),
        next_h=np.int32(next_h), next_t=np.int32(next_t),
        cnt_h=np.asarray(state.cnt_h, np.int32),
        cnt_t=np.asarray(state.cnt_t, np.int32),
        alloc_h=np.asarray(state.alloc_h, np.int32),
    )


# ---------------------------------------------------------------------------
# bundle versioning (the journal a last-batch deletion rolls back to)
# ---------------------------------------------------------------------------

JOURNAL_PREFIX = "prev__"

#: the O(|V| + C + P + k) fields an insertion may mutate in place.  The
#: per-edge arrays (parts, edge tags, alive) only ever *append* during an
#: insertion, so the rollback restores them by truncating to the
#: journaled stream position — no copy needed.
_JOURNALED = (
    "degrees", "v2c_h", "v2c_t", "vol_h", "vol_t", "ld", "next_h", "next_t",
    "cnt_h", "cnt_t", "alloc_h", "raw2comb_h", "raw2comb_t", "comb_is_head",
    "sizes", "pair_a", "pair_b", "pair_w", "c2p", "load", "touched",
    "theta_table", "theta_seeds", "rf_baseline", "balance_baseline",
    "retracted", "stream_pos",
)

_PER_EDGE = ("parts", "edge_cu", "edge_cv", "edge_alt_u", "edge_alt_v",
             "edge_head", "alive", "arrival")


def ensure_slot_index(b: dict) -> dict:
    """Synthesize the slot→arrival index for pre-compaction bundles.

    Bundles persisted before slot compaction existed have per-edge arrays
    indexed directly by arrival position; their implicit map is the
    identity and their stream position is the slot count.  Mutates and
    returns ``b``.
    """
    if "arrival" not in b:
        n_slots = int(np.asarray(b["parts"]).shape[0])
        b["arrival"] = np.arange(n_slots, dtype=np.int64)
        b["stream_pos"] = np.int64(n_slots)
        b["journal_slots"] = np.int64(b.get("journal_pos", -1))
    return b


def _write_journal(b: dict, stream_pos: int) -> None:
    """Snapshot the mutable small fields: the bundle's previous version."""
    for key in _JOURNALED:
        if key in b:
            b[JOURNAL_PREFIX + key] = np.copy(b[key])
    b["journal_pos"] = np.int64(stream_pos)
    b["journal_slots"] = np.int64(np.asarray(b["parts"]).shape[0])
    b["journal_valid"] = np.bool_(True)


def _invalidate_journal(b: dict) -> None:
    b["journal_valid"] = np.bool_(False)
    b["journal_slots"] = np.int64(-1)
    for key in _JOURNALED:
        b.pop(JOURNAL_PREFIX + key, None)


def _rollback(b: dict) -> None:
    """Restore the journaled version: small fields from their snapshots,
    per-edge arrays by truncation to the journaled slot count."""
    pos = int(b.get("journal_slots", b["journal_pos"]))
    for key in _JOURNALED:
        jkey = JOURNAL_PREFIX + key
        if jkey in b:
            b[key] = b.pop(jkey)
        elif key in ("theta_table", "theta_seeds"):
            continue  # exact-Θ bundles have no sketch to restore
    for key in _PER_EDGE:
        b[key] = np.asarray(b[key])[:pos]
    b["journal_valid"] = np.bool_(False)
    b["journal_pos"] = np.int64(-1)
    b["journal_slots"] = np.int64(-1)


def _refresh_decision(b: dict, config: S5PConfig, degrees: np.ndarray,
                      e_live: int):
    """ξ/κ full-refresh policy: compare the frozen clustering thresholds
    with what a cold run over the *live* graph would choose today.

    Uses the same denominator convention as the cold run (the full
    vertex-table size, isolated vertices included) so the drift is
    exactly 0 immediately after a cold start and moves only with real
    |E|/|V| change — not with the isolated-vertex count."""
    n = int(degrees.shape[0])
    avg_deg = 2.0 * e_live / max(n, 1)
    xi_now = min(int(config.beta * avg_deg), _INT32_MAX - 1)
    kappa_now = (_INT32_MAX if config.bounded
                 else max(int(math.ceil(2.0 * e_live / config.k)), 2))
    return DriftMonitor.refresh_check(
        float(b["xi"]), float(b["kappa"]), float(xi_now), float(kappa_now),
        xi_refresh_threshold=config.xi_refresh_threshold)


def _least_loaded_fill(sizes, c2p, new_ids, k):
    """Deterministic initial partition for newly-allocated clusters:
    successively least-loaded by size-weighted partition loads."""
    loads = np.zeros(k, np.float64)
    placed = c2p >= 0
    np.add.at(loads, c2p[placed], sizes[placed])
    for cid in new_ids:
        p = int(np.argmin(loads))
        c2p[cid] = p
        loads[p] += sizes[cid]
    return c2p


def _pair_union(pa, pb, da, db, n_comb):
    """Union of the stored structural pair list with the delta's pairs."""
    key_old = pa.astype(np.int64) * (n_comb + 1) + pb
    key_new = da.astype(np.int64) * (n_comb + 1) + db
    keys = np.unique(np.concatenate([key_old, key_new]))
    return ((keys // (n_comb + 1)).astype(np.int32),
            (keys % (n_comb + 1)).astype(np.int32))


def _merge_exact_counts(pa, pb, pw, da, db, dcount, n_comb):
    """Exact-Θ merge: old per-pair counts + the delta's occurrences."""
    key_old = pa.astype(np.int64) * (n_comb + 1) + pb
    key_new = da.astype(np.int64) * (n_comb + 1) + db
    keys, inv = np.unique(np.concatenate([key_old, key_new]),
                          return_inverse=True)
    w = np.zeros(keys.size, np.float64)
    np.add.at(w, inv, np.concatenate([pw.astype(np.float64), dcount]))
    return ((keys // (n_comb + 1)).astype(np.int32),
            (keys % (n_comb + 1)).astype(np.int32),
            w.astype(np.float32))


def s5p_apply_delta(bundle: dict, config: S5PConfig, full_src, full_dst,
                    stream_pos: int) -> tuple[dict, IncrementalResult]:
    """Absorb ``full[stream_pos:]`` into the bundle; maybe refine.

    ``full_src``/``full_dst`` are the whole stream in arrival order
    (prefix the bundle was built on + the insertion batch).  Returns the
    updated bundle and an :class:`IncrementalResult`.  Mutates a copy —
    the input bundle dict is not modified.
    """
    b = ensure_slot_index(dict(bundle))
    full_src = np.asarray(full_src, np.int32)
    full_dst = np.asarray(full_dst, np.int32)
    E_total = int(full_src.shape[0])
    E0 = int(stream_pos)
    if E0 > E_total:
        raise ValueError(f"carry stream position {E0} is past the stream "
                         f"({E_total} edges)")
    if E0 != int(b["stream_pos"]):
        raise ValueError(
            f"bundle was built at stream position {int(b['stream_pos'])} "
            f"but the delta claims position {E0}")
    dsrc = full_src[E0:]
    ddst = full_dst[E0:]
    E_delta = E_total - E0
    k = config.k
    xi = int(b["xi"])
    kappa = int(b["kappa"])
    full_cost = 4 * E_total  # degree + Alg.1 + Θ + Alg.3 folds of a cold run

    # per-edge arrays are slot-indexed; gather the slots' edges once so
    # metrics and refinement see exactly the edges the slots hold
    arrival0 = np.asarray(b["arrival"], np.int64)
    slot_src = full_src[arrival0]
    slot_dst = full_dst[arrival0]

    n_old = int(b["degrees"].shape[0])
    if E_delta == 0:
        parts = np.asarray(b["parts"], np.int32)
        rf = replication_factor(slot_src, slot_dst, parts,
                                n_vertices=n_old, k=k)
        bal = load_balance(parts, k=k)
        res = IncrementalResult(
            parts=_scatter_parts(parts, arrival0, E0), rf=float(rf),
            balance=float(bal), refined=False,
            rf_drift=0.0, balance_drift=0.0, edges_replayed=0,
            full_replay_cost=full_cost, game_rounds=0, n_new_clusters=0,
            n_delta_edges=0)
        return b, res

    # version the bundle before the first mutation: deleting exactly this
    # batch later rolls straight back to the snapshot (bitwise)
    _write_journal(b, E0)

    # ---- vertex-set growth -------------------------------------------
    n_new = n_old
    if E_delta:
        n_new = max(n_old, int(max(dsrc.max(), ddst.max())) + 1)
    degrees = np.zeros(n_new, np.int32)
    degrees[:n_old] = b["degrees"]
    np.add.at(degrees, dsrc, 1)  # exact SUM update (self-loops count,
    np.add.at(degrees, ddst, 1)  # matching compute_degrees on the cold run)

    state = _unpack_cluster_state(b)
    state = grow_carry("cluster", state, n_old, n_new)

    # ---- Alg. 1 replay over the delta (frozen ξ/κ, fresh degrees) ----
    delta_stream = DeltaStream(dsrc, ddst, n_new, base_offset=E0,
                               chunk_size=config.chunk_size)
    pc = _cl.ClusterCarry(jnp.asarray(degrees), n_new, xi=xi, kappa=kappa,
                          global_tail=config.bounded)
    _, state = run_incremental_carry(
        delta_stream, pc, carry=state, num_streams=config.num_streams,
        super_chunk=config.super_chunk)

    # ---- stable combined ids for any newly-allocated clusters --------
    v2c_h = np.asarray(state.v2c_h)
    v2c_t = np.asarray(state.v2c_t)
    next_h = int(state.next_h)
    next_t = int(state.next_t)
    r2c_h = np.full(max(next_h, 1), -1, np.int32)
    r2c_h[:b["raw2comb_h"].shape[0]] = b["raw2comb_h"]
    r2c_t = np.full(max(next_t, 1), -1, np.int32)
    r2c_t[:b["raw2comb_t"].shape[0]] = b["raw2comb_t"]
    C0 = int(b["comb_is_head"].shape[0])
    used_h = np.unique(v2c_h[v2c_h >= 0])
    used_t = np.unique(v2c_t[v2c_t >= 0])
    new_h = used_h[r2c_h[used_h] < 0]
    new_t = used_t[r2c_t[used_t] < 0]
    r2c_h[new_h] = C0 + np.arange(new_h.size, dtype=np.int32)
    r2c_t[new_t] = C0 + new_h.size + np.arange(new_t.size, dtype=np.int32)
    C1 = C0 + new_h.size + new_t.size
    comb_is_head = np.concatenate([
        b["comb_is_head"], np.ones(new_h.size, bool),
        np.ones(new_t.size, bool) if config.one_stage
        else np.zeros(new_t.size, bool)])
    sizes = np.concatenate([b["sizes"],
                            np.zeros(C1 - C0, np.float32)]).astype(np.float32)
    c2p = np.concatenate([b["c2p"], np.full(C1 - C0, -1, np.int32)])
    touched = np.concatenate([b["touched"], np.ones(C1 - C0, bool)])

    # ---- per-edge cluster tags for the delta (combined ids) ----------
    u64 = dsrc.astype(np.int64)
    v64 = ddst.astype(np.int64)
    valid = dsrc != ddst
    head_e = (degrees[u64] > xi) & (degrees[v64] > xi)
    ch_u = _comb_of(v2c_h[u64], r2c_h)
    ct_u = _comb_of(v2c_t[u64], r2c_t)
    ch_v = _comb_of(v2c_h[v64], r2c_h)
    ct_v = _comb_of(v2c_t[v64], r2c_t)
    cu = np.where(head_e, ch_u, ct_u).astype(np.int32)
    cv = np.where(head_e, ch_v, ct_v).astype(np.int32)
    cu[~valid] = -1
    cv[~valid] = -1
    alt_u = np.where(head_e, ct_u, ch_u).astype(np.int32)
    alt_v = np.where(head_e, ct_v, ch_v).astype(np.int32)
    alt_u[~valid] = -1
    alt_v[~valid] = -1
    for arr in (cu, cv):
        t = arr[arr >= 0]
        if t.size:
            touched[t] = True

    # ---- cluster sizes (same ½/1 attribution as cluster_statistics) --
    internal = (cu == cv) & valid & (cu >= 0)
    boundary = (cu != cv) & valid & (cu >= 0) & (cv >= 0)
    sizes64 = sizes.astype(np.float64)
    np.add.at(sizes64, cu[internal], 1.0)
    np.add.at(sizes64, cu[boundary], 0.5)
    np.add.at(sizes64, cv[boundary], 0.5)
    sizes = sizes64.astype(np.float32)

    # ---- Θ update: the three membership pair sets of the delta -------
    a_parts, b_parts = [], []
    for a, bb, ok in ((cu, cv, valid), (alt_u, cv, valid & (alt_u >= 0)),
                      (cu, alt_v, valid & (alt_v >= 0))):
        ok = ok & (a != bb) & (a >= 0) & (bb >= 0)
        a_parts.append(np.minimum(a, bb)[ok])
        b_parts.append(np.maximum(a, bb)[ok])
    da = np.concatenate(a_parts).astype(np.int32)
    db = np.concatenate(b_parts).astype(np.int32)
    if config.use_cms and "theta_table" in b:
        sketch = CMSketch(table=jnp.asarray(b["theta_table"]),
                          seeds=jnp.asarray(b["theta_seeds"]))
        if da.size:
            sketch = cms_update(sketch, pair_key(jnp.asarray(da),
                                                 jnp.asarray(db)))
        pa, pb = _pair_union(b["pair_a"], b["pair_b"], da, db, C1)
        pw = np.asarray(cms_query(sketch, pair_key(
            jnp.asarray(pa), jnp.asarray(pb)))).astype(np.float32)
        b["theta_table"] = np.asarray(sketch.table)
        b["theta_seeds"] = np.asarray(sketch.seeds)
    else:
        duniq, dcount = (np.empty(0, np.int64), np.empty(0, np.float64))
        if da.size:
            key = da.astype(np.int64) * (C1 + 1) + db
            duniq, dcount = np.unique(key, return_counts=True)
            dcount = dcount.astype(np.float64)
        pa, pb, pw = _merge_exact_counts(
            b["pair_a"], b["pair_b"], b["pair_w"],
            (duniq // (C1 + 1)).astype(np.int32),
            (duniq % (C1 + 1)).astype(np.int32), dcount, C1)

    # ---- settle new clusters (masked game over just them) ------------
    game_rounds = 0
    n_new_clusters = C1 - C0
    # the settle and refine games share inputs: the cluster graph after
    # this delta (sizes/Θ are fixed; only c2p moves between the two)
    inputs = _game.GameInputs(
        sizes=jnp.asarray(sizes), pair_a=jnp.asarray(pa),
        pair_b=jnp.asarray(pb), pair_w=jnp.asarray(pw), n_head=0, k=k)
    bs = _game.default_batch_size(config.game_batch_size, C1)
    if n_new_clusters:
        c2p = _least_loaded_fill(sizes, c2p, range(C0, C1), k)
        # refine_rounds == 0 means "no game rounds at all" (pure replay):
        # new clusters then keep the least-loaded fill
        if config.refine_rounds > 0:
            new_mask = np.zeros(C1, bool)
            new_mask[C0:] = True
            settle = _game.run_game(
                inputs, C1, batch_size=bs,
                max_rounds=min(4, config.refine_rounds),
                accept_prob=config.game_accept_prob, assign0=c2p,
                seed=config.seed, leader_mask=comb_is_head,
                move_mask=new_mask & (sizes > 0))
            c2p = np.asarray(settle.assignment)
            game_rounds += int(settle.rounds)

    # ---- Alg. 3: place only the delta edges (warm load vector) -------
    # capacity follows the *live* edge count (tombstoned edges hold no
    # load); identical to τ·E_total/k on insert-only streams
    e_live_in = int(np.count_nonzero(b["alive"])) + E_delta
    max_load = (_INT32_MAX if config.bounded
                else int(math.ceil(config.tau * e_live_in / k)))
    ac = AssignCarry(k, max_load, jnp.asarray(c2p))
    delta_parts, load = run_carry(
        delta_stream, ac, jnp.asarray(head_e), jnp.asarray(np.maximum(cu, 0)),
        jnp.asarray(np.maximum(cv, 0)), carry=jnp.asarray(b["load"]))
    parts = np.concatenate([b["parts"],
                            np.asarray(delta_parts, np.int32)])
    edge_cu = np.concatenate([b["edge_cu"], cu])
    edge_cv = np.concatenate([b["edge_cv"], cv])
    edge_alt_u = np.concatenate([b["edge_alt_u"], alt_u])
    edge_alt_v = np.concatenate([b["edge_alt_v"], alt_v])
    edge_head = np.concatenate([b["edge_head"], head_e])
    alive = np.concatenate([b["alive"], np.ones(E_delta, bool)])
    arrival = np.concatenate([arrival0,
                              np.arange(E0, E_total, dtype=np.int64)])
    slot_src = np.concatenate([slot_src, dsrc])
    slot_dst = np.concatenate([slot_dst, ddst])
    load = np.asarray(load, np.int32)
    edges_replayed = 4 * E_delta

    # ---- drift check → bounded refinement ----------------------------
    e_live = int(np.count_nonzero(alive))
    rf = float(replication_factor(slot_src, slot_dst, parts,
                                  n_vertices=n_new, k=k))
    bal = float(load_balance(parts, k=k))
    monitor = DriftMonitor(
        float(b["rf_baseline"]), float(b["balance_baseline"]),
        rf_threshold=config.drift_rf_threshold,
        balance_threshold=config.drift_balance_threshold,
        churn_threshold=config.drift_churn_threshold,
        retracted=int(b.get("retracted", 0)))
    decision = monitor.check(rf, bal, live_edges=e_live)
    refined = False
    if decision.refine and config.refine_rounds > 0 and C1 > 0:
        c2p, parts, load, rounds, replayed, rf, bal = _refine_pass(
            config, inputs, C1, bs, c2p, comb_is_head, touched, sizes,
            parts, load, edge_cu, edge_cv, edge_head,
            slot_src, slot_dst, n_new, max_load, rf, bal)
        game_rounds += rounds
        edges_replayed += replayed
        refined = True
        touched = np.zeros(C1, bool)
        monitor.rebase(rf, bal)

    # ---- pack the grown bundle ---------------------------------------
    _pack_cluster_state(b, state, next_h, next_t)
    b.update(
        degrees=degrees,
        raw2comb_h=r2c_h, raw2comb_t=r2c_t,
        comb_is_head=comb_is_head, sizes=sizes,
        pair_a=pa, pair_b=pb, pair_w=pw,
        c2p=c2p.astype(np.int32), load=load, parts=parts,
        edge_cu=edge_cu, edge_cv=edge_cv,
        edge_alt_u=edge_alt_u, edge_alt_v=edge_alt_v,
        edge_head=edge_head, alive=alive, arrival=arrival,
        stream_pos=np.int64(E_total),
        touched=touched,
        retracted=np.int64(monitor.retracted),
        rf_baseline=np.float64(monitor.baseline_rf),
        balance_baseline=np.float64(monitor.baseline_balance),
    )
    if refined:
        # refinement re-placed old edges' parts — truncation can no
        # longer restore the previous version, so drop the journal
        _invalidate_journal(b)
    refresh = _refresh_decision(b, config, degrees, e_live)
    result = IncrementalResult(
        parts=_scatter_parts(parts, arrival, E_total), rf=rf, balance=bal,
        refined=refined,
        rf_drift=decision.rf_drift, balance_drift=decision.balance_drift,
        edges_replayed=edges_replayed, full_replay_cost=full_cost,
        game_rounds=game_rounds, n_new_clusters=int(n_new_clusters),
        n_delta_edges=E_delta, churn=decision.churn,
        needs_cold_restart=refresh.needs_cold_restart,
        xi_drift=refresh.xi_drift, kappa_drift=refresh.kappa_drift)
    return b, result


def _refine_pass(config, inputs, C1, bs, c2p, comb_is_head, touched, sizes,
                 parts, load, edge_cu, edge_cv, edge_head,
                 full_src, full_dst, n_vertices, max_load, rf, bal,
                 move_mask=None):
    """The drift-triggered masked Stackelberg refinement, shared by the
    insertion and deletion paths: re-settle the touched clusters (or the
    caller's wider ``move_mask`` — the churn trigger passes every live
    cluster, a full re-settle of the O(C) game at no stream-replay cost),
    then lift and re-place only the moved clusters' **live** edges
    (tombstoned edges have ``parts == -1`` and never re-enter).  Returns
    ``(c2p, parts, load, rounds, n_replayed, rf, bal)``."""
    k = config.k
    if move_mask is None:
        move_mask = touched & (sizes > 0)
    refine = _game.run_game(
        inputs, C1, batch_size=bs, max_rounds=config.refine_rounds,
        accept_prob=config.game_accept_prob, assign0=c2p,
        seed=config.seed + 1, leader_mask=comb_is_head,
        move_mask=move_mask)
    c2p_new = np.asarray(refine.assignment)
    rounds = int(refine.rounds)
    replayed = 0
    moved = np.nonzero(c2p_new != c2p)[0]
    if moved.size:
        moved_mask = np.zeros(C1, bool)
        moved_mask[moved] = True
        ok = parts >= 0
        aff = ok & (moved_mask[np.maximum(edge_cu, 0)]
                    | moved_mask[np.maximum(edge_cv, 0)])
        # lift the affected edges' load, then re-place just them in
        # arrival order against the new cluster→partition map
        load64 = load.astype(np.int64)
        np.subtract.at(load64, parts[aff], 1)
        aidx = np.nonzero(aff)[0]
        re_stream = EdgeStream(full_src[aidx], full_dst[aidx], n_vertices,
                               chunk_size=config.chunk_size)
        ac = AssignCarry(k, max_load, jnp.asarray(c2p_new))
        re_parts, load = run_carry(
            re_stream, ac, jnp.asarray(edge_head[aidx]),
            jnp.asarray(np.maximum(edge_cu[aidx], 0)),
            jnp.asarray(np.maximum(edge_cv[aidx], 0)),
            carry=jnp.asarray(load64.astype(np.int32)))
        parts = parts.copy()
        parts[aidx] = np.asarray(re_parts, np.int32)
        load = np.asarray(load, np.int32)
        replayed = int(aidx.size)
        rf = float(replication_factor(full_src, full_dst, parts,
                                      n_vertices=n_vertices, k=k))
        bal = float(load_balance(parts, k=k))
    return c2p_new, parts, load, rounds, replayed, rf, bal


# ---------------------------------------------------------------------------
# deletion application
# ---------------------------------------------------------------------------


def s5p_apply_deletion(bundle: dict, config: S5PConfig, full_src, full_dst,
                       delete_idx) -> tuple[dict, IncrementalResult]:
    """Delete the edges at arrival indices ``delete_idx`` from the bundle.

    Two regimes:

    - **version rollback** — the deleted set is exactly the last-inserted
      batch and the bundle's journal is intact: restore the snapshot; the
      result is bitwise the pre-insertion carry (``rolled_back=True``).
    - **decremental retraction** — tombstone the edges (``alive`` false,
      ``parts`` −1), subtract their degree / size / Θ / load accounting
      exactly from the stored per-edge tags, retract the Alg.-1 fold
      approximately (:func:`~repro.core.clustering.cluster_retract_chunk`
      with the stored insertion-time head flags), count the retractions
      toward drift, and run the masked refinement game when any drift
      channel trips.

    Returns ``(bundle, IncrementalResult)``; the input bundle is not
    modified.  After a rollback the bundle covers fewer edges — callers
    persisting it should key the save on ``len(bundle["parts"])``.
    """
    b = ensure_slot_index(dict(bundle))
    full_src = np.asarray(full_src, np.int32)
    full_dst = np.asarray(full_dst, np.int32)
    E_total = int(b["stream_pos"])
    if int(full_src.shape[0]) < E_total:
        raise ValueError(
            f"bundle covers {E_total} edges but the stream holds only "
            f"{full_src.shape[0]}")
    k = config.k
    full_cost = 4 * E_total
    idx = np.unique(np.asarray(delete_idx, np.int64))
    n_vertices = int(np.asarray(b["degrees"]).shape[0])
    arrival = np.asarray(b["arrival"], np.int64)
    slot_src = full_src[arrival]
    slot_dst = full_dst[arrival]
    if idx.size == 0:
        parts = np.asarray(b["parts"], np.int32)
        rf = float(replication_factor(slot_src, slot_dst,
                                      parts, n_vertices=n_vertices, k=k))
        bal = float(load_balance(parts, k=k))
        return b, IncrementalResult(
            parts=_scatter_parts(parts, arrival, E_total), rf=rf,
            balance=bal, refined=False, rf_drift=0.0,
            balance_drift=0.0, edges_replayed=0, full_replay_cost=full_cost,
            game_rounds=0, n_new_clusters=0, n_delta_edges=0)
    if idx[0] < 0 or idx[-1] >= E_total:
        raise ValueError(
            f"deletion indices must lie in [0, {E_total}); got "
            f"[{idx[0]}, {idx[-1]}]")
    # map global arrival indices → live slots (compacted slots are gone:
    # deleting one of their arrivals is a double delete)
    slot_idx = np.searchsorted(arrival, idx)
    hit = np.zeros(idx.size, bool)
    if arrival.size:
        inb = slot_idx < arrival.size
        hit[inb] = arrival[slot_idx[inb]] == idx[inb]
    alive = np.asarray(b["alive"], bool)
    if not hit.all() or not alive[slot_idx[hit]].all():
        raise ValueError("deletion names edges that are already deleted")
    D = int(idx.size)

    # ---- version rollback: exactly the last-inserted batch -----------
    jpos = int(b.get("journal_pos", -1))
    if (bool(b.get("journal_valid", False)) and jpos >= 0
            and D == E_total - jpos
            and int(idx[0]) == jpos and int(idx[-1]) == E_total - 1):
        _rollback(b)
        parts = np.asarray(b["parts"], np.int32)
        arrival_rb = np.asarray(b["arrival"], np.int64)
        n_rb = int(np.asarray(b["degrees"]).shape[0])
        rf = float(replication_factor(full_src[arrival_rb],
                                      full_dst[arrival_rb],
                                      parts, n_vertices=n_rb, k=k))
        bal = float(load_balance(parts, k=k))
        return b, IncrementalResult(
            parts=_scatter_parts(parts, arrival_rb, jpos), rf=rf,
            balance=bal, refined=False, rf_drift=0.0,
            balance_drift=0.0, edges_replayed=0, full_replay_cost=full_cost,
            game_rounds=0, n_new_clusters=0, n_delta_edges=0,
            n_retracted=D, rolled_back=True)

    # ---- decremental retraction --------------------------------------
    dsrc = full_src[idx]
    ddst = full_dst[idx]
    degrees_pre = np.asarray(b["degrees"], np.int32)
    degrees = degrees_pre.copy()
    np.subtract.at(degrees, dsrc, 1)  # exact inverse of the insertion's
    np.subtract.at(degrees, ddst, 1)  # unconditional degree counting

    state = _cl.cluster_retract_chunk(
        _unpack_cluster_state(b), jnp.asarray(dsrc), jnp.asarray(ddst),
        D, is_head=jnp.asarray(np.asarray(b["edge_head"], bool)[slot_idx]))

    cu = np.asarray(b["edge_cu"])[slot_idx]
    cv = np.asarray(b["edge_cv"])[slot_idx]
    au = np.asarray(b["edge_alt_u"])[slot_idx]
    av = np.asarray(b["edge_alt_v"])[slot_idx]
    C1 = int(np.asarray(b["comb_is_head"]).shape[0])

    # sizes: subtract the same ½/1 attribution insertion added
    sizes64 = np.asarray(b["sizes"], np.float64).copy()
    ok = (cu >= 0) & (cv >= 0)
    internal = ok & (cu == cv)
    boundary = ok & (cu != cv)
    np.subtract.at(sizes64, cu[internal], 1.0)
    np.subtract.at(sizes64, cu[boundary], 0.5)
    np.subtract.at(sizes64, cv[boundary], 0.5)
    sizes = sizes64.astype(np.float32)

    # Θ retraction: the same three membership pair sets insertion added
    a_parts, b_parts = [], []
    for a, bb in ((cu, cv), (au, cv), (cu, av)):
        okm = (a >= 0) & (bb >= 0) & (a != bb)
        a_parts.append(np.minimum(a, bb)[okm])
        b_parts.append(np.maximum(a, bb)[okm])
    da = np.concatenate(a_parts).astype(np.int32)
    db = np.concatenate(b_parts).astype(np.int32)
    pa = np.asarray(b["pair_a"], np.int32)
    pb = np.asarray(b["pair_b"], np.int32)
    if config.use_cms and "theta_table" in b:
        sketch = CMSketch(table=jnp.asarray(b["theta_table"]),
                          seeds=jnp.asarray(b["theta_seeds"]))
        if da.size:
            sketch = cms_retract(sketch, pair_key(jnp.asarray(da),
                                                  jnp.asarray(db)))
        pw = np.asarray(cms_query(sketch, pair_key(
            jnp.asarray(pa), jnp.asarray(pb)))).astype(np.float32)
        b["theta_table"] = np.asarray(sketch.table)
        b["theta_seeds"] = np.asarray(sketch.seeds)
    else:
        duniq, dcount = (np.empty(0, np.int64), np.empty(0, np.float64))
        if da.size:
            key = da.astype(np.int64) * (C1 + 1) + db
            duniq, dcount = np.unique(key, return_counts=True)
            dcount = dcount.astype(np.float64)
        pa, pb, pw = _merge_exact_counts(
            pa, pb, np.asarray(b["pair_w"], np.float32),
            (duniq // (C1 + 1)).astype(np.int32),
            (duniq % (C1 + 1)).astype(np.int32), -dcount, C1)

    # load / parts / alive tombstones — exact
    parts = np.asarray(b["parts"], np.int32).copy()
    placed = parts[slot_idx] >= 0
    load64 = np.asarray(b["load"], np.int64).copy()
    np.subtract.at(load64, parts[slot_idx][placed], 1)
    load = load64.astype(np.int32)
    parts[slot_idx] = -1
    alive = alive.copy()
    alive[slot_idx] = False
    touched = np.asarray(b["touched"], bool).copy()
    for arr in (cu, cv):
        t = arr[arr >= 0]
        if t.size:
            touched[t] = True

    edge_cu = np.asarray(b["edge_cu"])
    edge_cv = np.asarray(b["edge_cv"])
    edge_head = np.asarray(b["edge_head"], bool)
    c2p = np.asarray(b["c2p"], np.int32)
    comb_is_head = np.asarray(b["comb_is_head"], bool)
    edges_replayed = D  # one retraction fold per deleted edge

    # ---- drift check (retractions count) → bounded refinement --------
    e_live = int(np.count_nonzero(alive))
    rf = float(replication_factor(slot_src, slot_dst,
                                  parts, n_vertices=n_vertices, k=k))
    bal = float(load_balance(parts, k=k))
    monitor = DriftMonitor(
        float(b["rf_baseline"]), float(b["balance_baseline"]),
        rf_threshold=config.drift_rf_threshold,
        balance_threshold=config.drift_balance_threshold,
        churn_threshold=config.drift_churn_threshold,
        retracted=int(b.get("retracted", 0)))
    monitor.note_retractions(D)
    decision = monitor.check(rf, bal, live_edges=e_live)
    refined = False
    game_rounds = 0
    max_load = (_INT32_MAX if config.bounded
                else int(math.ceil(config.tau * max(e_live, 1) / k)))
    if decision.refine and config.refine_rounds > 0 and C1 > 0:
        inputs = _game.GameInputs(
            sizes=jnp.asarray(sizes), pair_a=jnp.asarray(pa),
            pair_b=jnp.asarray(pb), pair_w=jnp.asarray(pw), n_head=0, k=k)
        bs = _game.default_batch_size(config.game_batch_size, C1)
        # churn-tripped refinements re-settle *every* live cluster: the
        # O(C) game is cheap next to any replay, and sustained expiry
        # degrades clusters the touched set no longer names
        move_mask = (sizes > 0) if decision.churn >= monitor.churn_threshold \
            else touched & (sizes > 0)
        c2p, parts, load, rounds, replayed, rf, bal = _refine_pass(
            config, inputs, C1, bs, c2p, comb_is_head, touched, sizes,
            parts, load, edge_cu, edge_cv, edge_head,
            slot_src, slot_dst, n_vertices, max_load,
            rf, bal, move_mask=move_mask)
        game_rounds += rounds
        edges_replayed += replayed
        refined = True
        touched = np.zeros(C1, bool)
        monitor.rebase(rf, bal)

    # ---- pack ---------------------------------------------------------
    _pack_cluster_state(b, state, int(b["next_h"]), int(b["next_t"]))
    b.update(
        degrees=degrees, sizes=sizes, pair_a=pa, pair_b=pb, pair_w=pw,
        c2p=c2p.astype(np.int32), load=load, parts=parts, alive=alive,
        touched=touched, retracted=np.int64(monitor.retracted),
        rf_baseline=np.float64(monitor.baseline_rf),
        balance_baseline=np.float64(monitor.baseline_balance),
    )
    # any decremental deletion desynchronizes the journal snapshot
    _invalidate_journal(b)
    refresh = _refresh_decision(b, config, degrees, e_live)
    result = IncrementalResult(
        parts=_scatter_parts(parts, arrival, E_total), rf=rf, balance=bal,
        refined=refined,
        rf_drift=decision.rf_drift, balance_drift=decision.balance_drift,
        edges_replayed=edges_replayed, full_replay_cost=full_cost,
        game_rounds=game_rounds, n_new_clusters=0, n_delta_edges=0,
        n_retracted=D, churn=decision.churn,
        needs_cold_restart=refresh.needs_cold_restart,
        xi_drift=refresh.xi_drift, kappa_drift=refresh.kappa_drift)
    return b, result


# ---------------------------------------------------------------------------
# carry compaction (the append-only combined id space, renumbered)
# ---------------------------------------------------------------------------


def compact_bundle(bundle: dict, config: S5PConfig) -> tuple[dict, int]:
    """Renumber the combined cluster id space, dropping dead ids.

    The warm chain only ever *appends* combined ids (that is what keeps
    the pair list and per-edge tags stable across deltas), so after heavy
    deletion churn the id space holds clusters no live edge or vertex
    references.  This pass builds the live id set — ids tagged by any
    live edge plus ids any vertex's counted membership still maps to —
    renumbers them densely (order-preserving, so head-before-tail
    blocks survive), and rewrites every id-indexed structure: the
    raw→combined remaps, sizes / c2p / touched / leader mask, the Θ pair
    list, and the per-edge tags (dead edges' tags become −1).  The CMS,
    hashed over old ids, is re-materialized by re-inserting each live
    pair at its current estimated weight — estimates stay one-sided.
    Returns ``(bundle, n_dropped)``; invalidates the rollback journal.
    """
    b = dict(bundle)
    C1 = int(np.asarray(b["comb_is_head"]).shape[0])
    alive = np.asarray(b["alive"], bool)
    state = _unpack_cluster_state(b)
    eff_h, eff_t = (np.asarray(x) for x in state.effective())
    r2c_h = np.asarray(b["raw2comb_h"], np.int32)
    r2c_t = np.asarray(b["raw2comb_t"], np.int32)

    live = np.zeros(C1, bool)
    for tags in (np.asarray(b["edge_cu"])[alive],
                 np.asarray(b["edge_cv"])[alive],
                 np.asarray(b["edge_alt_u"])[alive],
                 np.asarray(b["edge_alt_v"])[alive]):
        t = tags[tags >= 0]
        if t.size:
            live[t] = True
    for raw, remap in ((eff_h, r2c_h), (eff_t, r2c_t)):
        r = raw[raw >= 0]
        if r.size:
            comb = remap[r]
            comb = comb[comb >= 0]
            live[comb] = True

    n_live = int(np.count_nonzero(live))
    n_dropped = C1 - n_live
    if n_dropped == 0:
        return b, 0
    remap = np.full(C1 + 1, -1, np.int32)  # trailing slot: -1 passthrough
    remap[:C1][live] = np.arange(n_live, dtype=np.int32)

    def _retag(arr):
        arr = np.asarray(arr, np.int32)
        return np.where(arr >= 0, remap[np.maximum(arr, 0)], -1).astype(np.int32)

    b["raw2comb_h"] = _retag(r2c_h)
    b["raw2comb_t"] = _retag(r2c_t)
    b["comb_is_head"] = np.asarray(b["comb_is_head"], bool)[live]
    b["sizes"] = np.asarray(b["sizes"], np.float32)[live]
    b["c2p"] = np.asarray(b["c2p"], np.int32)[live]
    b["touched"] = np.asarray(b["touched"], bool)[live]
    for key in ("edge_cu", "edge_cv", "edge_alt_u", "edge_alt_v"):
        b[key] = _retag(b[key])

    # pair list: drop pairs with a dead endpoint, renumber the rest
    pa = _retag(b["pair_a"])
    pb = _retag(b["pair_b"])
    pw = np.asarray(b["pair_w"], np.float32)
    keep = (pa >= 0) & (pb >= 0)
    pa, pb, pw = pa[keep], pb[keep], pw[keep]
    lo = np.minimum(pa, pb)
    hi = np.maximum(pa, pb)
    order = np.argsort(lo.astype(np.int64) * (n_live + 1) + hi, kind="stable")
    b["pair_a"], b["pair_b"], b["pair_w"] = lo[order], hi[order], pw[order]

    if config.use_cms and "theta_table" in b:
        # the sketch hashes ids — rebuild it over the renumbered pairs at
        # their current estimated weights (still a one-sided estimate),
        # resized for the live cluster count (a chain cold-started on a
        # small prefix otherwise keeps that prefix's narrow width forever)
        from ..core.cms import suggest_params

        old = CMSketch(table=jnp.asarray(b["theta_table"]),
                       seeds=jnp.asarray(b["theta_seeds"]))
        w, _d = suggest_params(config.cms_epsilon, config.cms_nu)
        width = w * max(1, int(math.isqrt(max(n_live, 1))))
        fresh = CMSketch(
            table=jnp.zeros((old.table.shape[0], width), old.table.dtype),
            seeds=old.seeds)
        if b["pair_a"].size:
            fresh = cms_update(
                fresh, pair_key(jnp.asarray(b["pair_a"]),
                                jnp.asarray(b["pair_b"])),
                jnp.asarray(b["pair_w"], jnp.uint32))
        b["theta_table"] = np.asarray(fresh.table)
        b["theta_seeds"] = np.asarray(fresh.seeds)
        b["pair_w"] = np.asarray(cms_query(fresh, pair_key(
            jnp.asarray(b["pair_a"]), jnp.asarray(b["pair_b"])))
        ).astype(np.float32)

    _invalidate_journal(b)
    return b, n_dropped


# ---------------------------------------------------------------------------
# edge-slot compaction (free the tombstoned per-edge records)
# ---------------------------------------------------------------------------


def compact_edge_slots(bundle: dict) -> tuple[dict, int]:
    """Drop dead per-edge slots, freeing the tombstones for real.

    Deletions tombstone per-edge records (``alive`` false, ``parts`` −1)
    but keep the slots, so a long-lived window's per-edge arrays grow with
    *arrivals*, not with the live set.  This pass gathers every per-edge
    array down to the live slots.  The **stable old→new index map** is the
    surviving ``arrival`` array itself: slot ``i`` of the compacted bundle
    holds the edge whose global arrival index is ``arrival[i]``, and
    ``stream_pos`` (plus the CarryStore's prefix CRC, both keyed on global
    arrival counts) is untouched — so resumed / out-of-core streams and
    persisted checkpoints remain valid, and later deletions still name
    global arrival indices (mapped to slots by binary search).

    Returns ``(bundle, n_freed)``; the input is not modified.  The
    rollback journal is invalidated — truncation can no longer restore a
    pre-compaction version.
    """
    b = ensure_slot_index(dict(bundle))
    alive = np.asarray(b["alive"], bool)
    n_freed = int(alive.size - np.count_nonzero(alive))
    if n_freed == 0:
        return b, 0
    for key in _PER_EDGE:
        b[key] = np.asarray(b[key])[alive]
    _invalidate_journal(b)
    return b, n_freed


# ---------------------------------------------------------------------------
# cold restart (the ξ/κ refresh the drift monitor asks for)
# ---------------------------------------------------------------------------


def s5p_cold_restart(bundle: dict, config: S5PConfig, full_src,
                     full_dst) -> tuple[dict, IncrementalResult]:
    """Re-partition the bundle's live edge set from scratch.

    This is the action behind ``needs_cold_restart``: the warm chain
    froze ξ/κ (and the CMS width) at its cold start, and
    :func:`~repro.incremental.drift.DriftMonitor.refresh_check` fires
    once the live degree distribution has drifted past them.  The restart
    replays only the **live** window — dead arrivals are gone for good —
    re-deriving thresholds, sketches, clusters and placements at current
    scale, and keeps the stream coordinates (``arrival``, ``stream_pos``)
    so the new bundle drops into the same chain / CarryStore slot.

    Returns ``(bundle, result)`` with ``result.edges_replayed`` equal to
    the full cold cost (``replay_fraction == 1``).  Raises ``ValueError``
    if the live set holds no valid (non-self-loop) edge.
    """
    b = ensure_slot_index(dict(bundle))
    full_src = np.asarray(full_src, np.int32)
    full_dst = np.asarray(full_dst, np.int32)
    alive = np.asarray(b["alive"], bool)
    arrival = np.asarray(b["arrival"], np.int64)[alive]
    stream_pos = int(b["stream_pos"])
    lsrc = full_src[arrival]
    ldst = full_dst[arrival]
    # keep the vertex table: values/carries sized to it stay aligned
    n_vertices = int(np.asarray(b["degrees"]).shape[0])
    _, nb = s5p_cold_bundle(lsrc, ldst, n_vertices, config)
    nb["arrival"] = arrival
    nb["stream_pos"] = np.int64(stream_pos)
    parts = np.asarray(nb["parts"], np.int32)
    cost = 4 * int(arrival.size)
    result = IncrementalResult(
        parts=_scatter_parts(parts, arrival, stream_pos),
        rf=float(nb["rf_baseline"]), balance=float(nb["balance_baseline"]),
        refined=False, rf_drift=0.0, balance_drift=0.0,
        edges_replayed=cost, full_replay_cost=max(cost, 1),
        game_rounds=0, n_new_clusters=int(nb["comb_is_head"].shape[0]),
        n_delta_edges=0)
    return nb, result
