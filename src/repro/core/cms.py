"""Count-Min Sketch (CMS) for inter-cluster edge counts (paper §4.4).

The paper stores ``Θ(c_i, c_j)`` — the number of graph edges spanning cluster
``c_i`` and cluster ``c_j`` — in a count-min sketch instead of an exact
red-black tree, trading a one-sided, probabilistically-bounded overestimate
for a ``w × d`` memory footprint (w = ⌈e/ε⌉, d = ⌈ln 1/ν⌉).

TPU adaptation: the paper hashes the *string concatenation* of two cluster
ids.  TPUs have no strings, so we hash the ordered integer pair with a
xxhash-style 32-bit avalanche mix, one independent seed per sketch row.  All
arithmetic is uint32 and jit/vmap/scan-friendly.  The sketch is *mergeable*
(element-wise sum), which is what lets the distributed pipeline combine
per-shard sketches with a single ``psum`` (see core/distributed.py).

Signed counting (the decremental refactor): the uint32 table is the group
ℤ/2³² — :func:`cms_update` accepts **negative** counts (two's-complement
wrap), so :func:`cms_retract` subtracts a deleted key's contribution
exactly.  As long as retractions only remove previously-inserted keys,
every cell's true value stays non-negative and point queries remain the
usual one-sided overestimates; insert-only behaviour is bit-identical to
the monotone sketch.

A Pallas TPU kernel for the batched update/query hot loop lives in
``repro.kernels.cms_sketch``; this module is the reference implementation
and the small-input path.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..streaming.carry import REPLICATED, SUM, PartitionerCarry

__all__ = [
    "CMSketch",
    "SketchCarry",
    "make_sketch",
    "pair_key",
    "vertex_key",
    "cms_update",
    "cms_retract",
    "cms_query",
    "cms_merge",
    "suggest_params",
]

_GOLDEN = jnp.uint32(0x9E3779B1)
_MIX1 = jnp.uint32(0x85EBCA6B)
_MIX2 = jnp.uint32(0xC2B2AE35)


class CMSketch(NamedTuple):
    """A count-min sketch: ``table[d, w]`` of uint32 counts + row seeds."""

    table: jax.Array  # (d, w) uint32
    seeds: jax.Array  # (d,) uint32

    @property
    def depth(self) -> int:
        return self.table.shape[0]

    @property
    def width(self) -> int:
        return self.table.shape[1]

    def memory_bytes(self) -> int:
        return self.table.size * 4 + self.seeds.size * 4


def suggest_params(epsilon: float = 0.1, nu: float = 0.01) -> tuple[int, int]:
    """Paper §4.4: w = ⌈e/ε⌉, d = ⌈ln(1/ν)⌉ (ε=0.1, ν=0.01 ⇒ w=28, d=5)."""
    w = math.ceil(math.e / epsilon)
    d = math.ceil(math.log(1.0 / nu))
    return w, d


def make_sketch(width: int, depth: int, seed: int = 0) -> CMSketch:
    seeds = jax.random.randint(
        jax.random.PRNGKey(seed), (depth,), 1, 2**31 - 1, dtype=jnp.int32
    ).astype(jnp.uint32)
    return CMSketch(table=jnp.zeros((depth, width), dtype=jnp.uint32), seeds=seeds)


def _avalanche(h: jax.Array) -> jax.Array:
    """xxhash/murmur-style 32-bit finalizer: full avalanche on uint32."""
    h = h ^ (h >> 16)
    h = h * _MIX1
    h = h ^ (h >> 13)
    h = h * _MIX2
    h = h ^ (h >> 16)
    return h


def pair_key(a: jax.Array, b: jax.Array) -> jax.Array:
    """Order-insensitive uint32 key for a cluster-id pair.

    The paper concatenates the two id strings; we mix ``(min, max)`` so that
    (a, b) and (b, a) — the same undirected cluster adjacency — collide on
    purpose.
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    lo = jnp.minimum(a, b)
    hi = jnp.maximum(a, b)
    h = lo * _GOLDEN
    h = _avalanche(h ^ hi)
    return h


def vertex_key(v: jax.Array) -> jax.Array:
    """uint32 sketch key for a single vertex id (degenerate pair key).

    Shared by every per-vertex degree sketch — the hybrid budget planner's
    :class:`~repro.hybrid.planner.DegreeSketchCarry` and the hub-routing
    plan in :class:`~repro.streaming.parallel.ParallelEdgeStream` — so
    their estimates agree on what a "hub" is."""
    v = jnp.asarray(v)
    return pair_key(v, v)


def _row_cols(keys: jax.Array, seeds: jax.Array, width: int) -> jax.Array:
    """Column index for every (row, key): shape (d, n)."""
    # broadcast: (d, 1) seeds vs (n,) keys
    h = _avalanche(keys[None, :] ^ seeds[:, None] * _GOLDEN)
    return (h % jnp.uint32(width)).astype(jnp.int32)


@partial(jax.jit, static_argnames=())
def cms_update(sketch: CMSketch, keys: jax.Array, counts: jax.Array | None = None) -> CMSketch:
    """Add ``counts`` (default 1) at ``keys``; batched, scatter-add per row.

    ``counts`` may be signed — negative entries subtract in ℤ/2³²
    (two's-complement wrap), which is how deletions retract exactly."""
    if counts is None:
        counts = jnp.ones_like(keys, dtype=jnp.uint32)
    counts = counts.astype(jnp.uint32)
    cols = _row_cols(keys, sketch.seeds, sketch.width)  # (d, n)
    d = sketch.table.shape[0]
    rows = jnp.broadcast_to(jnp.arange(d, dtype=jnp.int32)[:, None], cols.shape)
    table = sketch.table.at[rows.reshape(-1), cols.reshape(-1)].add(
        jnp.broadcast_to(counts[None, :], cols.shape).reshape(-1)
    )
    return CMSketch(table=table, seeds=sketch.seeds)


@partial(jax.jit, static_argnames=())
def cms_query(sketch: CMSketch, keys: jax.Array) -> jax.Array:
    """Point query: min over rows — one-sided (over-)estimate of the count."""
    cols = _row_cols(keys, sketch.seeds, sketch.width)  # (d, n)
    vals = jnp.take_along_axis(sketch.table, cols, axis=1)  # (d, n)
    return jnp.min(vals, axis=0)


def cms_merge(a: CMSketch, b: CMSketch) -> CMSketch:
    """Merge two sketches built with identical seeds (element-wise sum)."""
    return CMSketch(table=a.table + b.table, seeds=a.seeds)


def cms_retract(sketch: CMSketch, keys: jax.Array,
                counts: jax.Array | None = None) -> CMSketch:
    """Subtract ``counts`` (default 1) at ``keys`` — the exact inverse of
    the same :func:`cms_update` (the table is the group ℤ/2³²)."""
    if counts is None:
        counts = jnp.ones_like(keys, dtype=jnp.int32)
    return cms_update(sketch, keys, -counts.astype(jnp.int32))


class SketchCarry(PartitionerCarry):
    """The Θ statistics pass as a carry: a CMS over cluster-pair keys.

    The stream's (src, dst) are *cluster-id pairs*, not graph edges; each
    valid pair increments the sketch at its order-insensitive key.  The
    sketch is linear, so the parallel-ingest merge (table SUM, row seeds
    replicated) is **exact** — sharded Θ ingestion loses nothing, which is
    precisely why the paper's choice of summary distributes (cf.
    ``core.distributed`` Phase 2's one-``psum`` sketch merge).
    """

    emits_parts = False
    supports_retract = True
    retract_exact = True  # ℤ/2³² is a group — subtraction is exact
    merge_ops = (SUM, REPLICATED)  # CMSketch leaves: table, seeds

    def __init__(self, width: int, depth: int, seed: int = 0):
        self.width = int(width)
        self.depth = int(depth)
        self.seed = int(seed)

    def init(self) -> CMSketch:
        return make_sketch(self.width, self.depth, seed=self.seed)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        counts = (jnp.arange(src.shape[0]) < n_valid).astype(jnp.uint32)
        return cms_update(carry, pair_key(src, dst), counts), None

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        counts = (jnp.arange(src.shape[0]) < n_valid).astype(jnp.int32)
        return cms_retract(carry, pair_key(src, dst), counts)
