"""Edge-placement postprocessing (paper Algorithm 3).

After the game fixes the cluster→partition map ``C2P``, a final streaming
pass assigns every edge to a concrete partition under the hard capacity
``L = ⌈τ|E|/k⌉``:

- both endpoint partitions over capacity → skew-aware overflow: **head**
  edges take the *first* partition with room, **tail** edges the *last*
  (minimizing the spread of head vertices across partitions, per §4.3);
- otherwise the *less-loaded* of the two endpoint partitions (Alg. 3
  lines 9-10; the prose says "larger size" but the listing places into
  the smaller — we follow the listing, which is the balance-preserving
  reading; recorded in DESIGN.md).

Implemented as a jitted ``lax.scan`` with an O(k) carry (the load vector),
streamed in chunks like Algorithm 1.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..streaming.carry import SUM, PartitionerCarry

__all__ = ["AssignCarry", "assign_edges", "assign_edges_stream"]


@partial(jax.jit, static_argnames=("k",))
def _assign_chunk(load, max_load, src, dst, is_head_edge, cu, cv, c2p, *, k: int):
    """One streamed chunk of Algorithm 3.  Returns (load, parts)."""
    arange = jnp.arange(k, dtype=jnp.int32)
    L = max_load

    def step(load, edge):
        head, pcu, pcv, valid = edge
        over_u = load[pcu] >= L
        over_v = load[pcv] >= L
        room = load < L
        any_room = jnp.any(room)
        first_room = jnp.argmax(room).astype(jnp.int32)
        last_room = (k - 1 - jnp.argmax(room[::-1])).astype(jnp.int32)
        fallback = jnp.argmin(load).astype(jnp.int32)
        overflow_choice = jnp.where(
            any_room, jnp.where(head, first_room, last_room), fallback
        )
        # lines 9-10: more-loaded endpoint loses; tie → P_u (line 10 'else')
        endpoint_choice = jnp.where(load[pcu] > load[pcv], pcv, pcu)
        part = jnp.where(over_u & over_v, overflow_choice, endpoint_choice)
        load = load + jnp.where(valid, (arange == part).astype(load.dtype), 0)
        return load, jnp.where(valid, part, -1)

    pcu = c2p[cu]
    pcv = c2p[cv]
    valid = src != dst
    load, parts = jax.lax.scan(step, load, (is_head_edge, pcu, pcv, valid))
    return load, parts


class AssignCarry(PartitionerCarry):
    """Algorithm 3 as a carry: the O(k) load vector (SUM merge).

    Per-edge extras (head flag, endpoint clusters) ride the chunk; the
    cluster→partition map and capacity are replicated closure constants.
    Under parallel ingest each sub-stream places its edges against a load
    vector that is ``super_chunk`` chunks stale at worst — the bounded-
    staleness regime of ``core.distributed`` Phase 4.
    """

    merge_ops = (SUM,)
    supports_retract = True
    retract_exact = True

    def __init__(self, k: int, max_load: int, c2p: jax.Array, *,
                 use_kernel: bool | None = None,
                 vmem_budget: int | None = None):
        self.k = int(k)
        self.max_load = jnp.int32(max_load)
        self.c2p = c2p
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self._use_kernel = bool(use_kernel)
        self._vmem_budget = vmem_budget

    def init(self) -> jax.Array:
        return jnp.zeros((self.k,), jnp.int32)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        h, a, b = extras
        if self._use_kernel:
            # lazy import (core.baselines ↔ kernels layering, see clustering)
            from ..kernels import stream_scan as _scan

            _scan.select_path(0, self.k, src.shape[0], consumer="assign",
                              budget=self._vmem_budget)  # path logging
            parts, load = _scan.assign_scan(
                carry, src, dst, h, self.c2p[a], self.c2p[b],
                max_load=self.max_load)
            return load, parts
        load, parts = _assign_chunk(carry, self.max_load, src, dst, h, a, b,
                                    self.c2p, k=self.k)
        return load, parts

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        if self._use_kernel:
            from ..kernels import stream_scan as _scan

            zeros = jnp.zeros_like(src)
            _, load = _scan.assign_scan(
                carry, src, dst, zeros, zeros, zeros,
                max_load=self.max_load, sign=-1, parts=parts,
                n_valid=n_valid)
            return load
        return _retract_load(carry, src, dst, n_valid, parts)


@jax.jit
def _retract_load(load, src, dst, n_valid, parts):
    """Exact inverse of a chunk's load accounting (one unit per placed edge)."""
    w = ((jnp.arange(src.shape[0]) < n_valid) & (src != dst)
         & (parts >= 0)).astype(jnp.int32)
    return load - jax.ops.segment_sum(w, jnp.maximum(parts, 0),
                                      num_segments=load.shape[0])


def assign_edges_stream(
    src: jax.Array,
    dst: jax.Array,
    is_head_edge: jax.Array,
    cu: jax.Array,
    cv: jax.Array,
    c2p: jax.Array,
    k: int,
    max_load: int,
    *,
    chunk_size: int = 1 << 16,
    stream=None,
    num_streams: int = 1,
    super_chunk: int | str = 8,
    shard: str = "range",
    use_kernel: bool | None = None,
    vmem_budget: int | None = None,
):
    """Algorithm 3 over the full stream.  Returns (parts (E,), load (k,)).

    The per-edge attributes (head flag, endpoint clusters) ride along the
    EdgeStream as extras, so a reordered stream keeps them aligned; parts
    come back in arrival order either way.  ``num_streams > 1`` places S
    sharded sub-streams in parallel with load-vector all-reduces every
    ``super_chunk`` chunks (``num_streams=1`` is bit-identical sequential).
    """
    from ..streaming import as_stream, run_parallel

    stream = as_stream(src, dst, stream=stream, chunk_size=chunk_size)
    pc = AssignCarry(k, max_load, c2p, use_kernel=use_kernel,
                     vmem_budget=vmem_budget)
    parts, load = run_parallel(
        stream, pc, is_head_edge, cu, cv,
        num_streams=num_streams, super_chunk=super_chunk, shard=shard)
    return parts, load


def assign_edges(
    src,
    dst,
    is_head_edge,
    cu,
    cv,
    c2p,
    k: int,
    max_load: int,
):
    """Single-shot convenience wrapper (no chunking)."""
    return assign_edges_stream(
        src, dst, is_head_edge, cu, cv, c2p, k, max_load,
        chunk_size=max(int(src.shape[0]), 1),
    )
