"""Edge-placement postprocessing (paper Algorithm 3).

After the game fixes the cluster→partition map ``C2P``, a final streaming
pass assigns every edge to a concrete partition under the hard capacity
``L = ⌈τ|E|/k⌉``:

- both endpoint partitions over capacity → skew-aware overflow: **head**
  edges take the *first* partition with room, **tail** edges the *last*
  (minimizing the spread of head vertices across partitions, per §4.3);
- otherwise the *less-loaded* of the two endpoint partitions (Alg. 3
  lines 9-10; the prose says "larger size" but the listing places into
  the smaller — we follow the listing, which is the balance-preserving
  reading; recorded in DESIGN.md).

Implemented as a jitted ``lax.scan`` with an O(k) carry (the load vector),
streamed in chunks like Algorithm 1.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["assign_edges", "assign_edges_stream"]


@partial(jax.jit, static_argnames=("k",))
def _assign_chunk(load, max_load, src, dst, is_head_edge, cu, cv, c2p, *, k: int):
    """One streamed chunk of Algorithm 3.  Returns (load, parts)."""
    arange = jnp.arange(k, dtype=jnp.int32)
    L = max_load

    def step(load, edge):
        head, pcu, pcv, valid = edge
        over_u = load[pcu] >= L
        over_v = load[pcv] >= L
        room = load < L
        any_room = jnp.any(room)
        first_room = jnp.argmax(room).astype(jnp.int32)
        last_room = (k - 1 - jnp.argmax(room[::-1])).astype(jnp.int32)
        fallback = jnp.argmin(load).astype(jnp.int32)
        overflow_choice = jnp.where(
            any_room, jnp.where(head, first_room, last_room), fallback
        )
        # lines 9-10: more-loaded endpoint loses; tie → P_u (line 10 'else')
        endpoint_choice = jnp.where(load[pcu] > load[pcv], pcv, pcu)
        part = jnp.where(over_u & over_v, overflow_choice, endpoint_choice)
        load = load + jnp.where(valid, (arange == part).astype(load.dtype), 0)
        return load, jnp.where(valid, part, -1)

    pcu = c2p[cu]
    pcv = c2p[cv]
    valid = src != dst
    load, parts = jax.lax.scan(step, load, (is_head_edge, pcu, pcv, valid))
    return load, parts


def assign_edges_stream(
    src: jax.Array,
    dst: jax.Array,
    is_head_edge: jax.Array,
    cu: jax.Array,
    cv: jax.Array,
    c2p: jax.Array,
    k: int,
    max_load: int,
    *,
    chunk_size: int = 1 << 16,
):
    """Algorithm 3 over the full stream.  Returns (parts (E,), load (k,))."""
    load = jnp.zeros((k,), jnp.int32)
    ml = jnp.int32(max_load)
    n = src.shape[0]
    outs = []
    for start in range(0, n, chunk_size):
        stop = min(start + chunk_size, n)
        sl = slice(start, stop)
        s, d, h, a, b = src[sl], dst[sl], is_head_edge[sl], cu[sl], cv[sl]
        if s.shape[0] < chunk_size and start > 0:
            pad = chunk_size - s.shape[0]
            z = jnp.zeros((pad,), jnp.int32)
            s = jnp.concatenate([s, z])
            d = jnp.concatenate([d, z])  # self-loops ⇒ masked out
            h = jnp.concatenate([h, jnp.zeros((pad,), h.dtype)])
            a = jnp.concatenate([a, z])
            b = jnp.concatenate([b, z])
        load, parts = _assign_chunk(load, ml, s, d, h, a, b, c2p, k=k)
        outs.append(parts[: stop - start])
    return jnp.concatenate(outs), load


def assign_edges(
    src,
    dst,
    is_head_edge,
    cu,
    cv,
    c2p,
    k: int,
    max_load: int,
):
    """Single-shot convenience wrapper (no chunking)."""
    return assign_edges_stream(
        src, dst, is_head_edge, cu, cv, c2p, k, max_load,
        chunk_size=max(int(src.shape[0]), 1),
    )
