"""Skewness-aware streaming graph clustering (paper Algorithm 1).

Edges arrive as a stream.  Each edge is classified *head* (both endpoints
have global degree > ξ) or *tail* (otherwise) and drives an
allocate/migrate update on one of two vertex→cluster tables:

- ``V2C_H`` (head): cluster volumes tracked in **global-degree** units;
- ``V2C_T`` (tail): volumes in **local-degree** units (1 per edge arrival).

Migration merges the lighter endpoint's cluster into the heavier one when
the receiving cluster stays under the volume cap κ = 2|E|/k.

TPU adaptation (recorded in DESIGN.md §2): the paper's per-edge loop with
early-exit branches becomes a ``jax.lax.scan`` with branchless
``jnp.where`` state transitions.  The carry is strictly O(|V|):
two V2C tables, two volume arrays (≤ |V| + 1 slots each; the trailing slot
is a write sink for masked updates), one local-degree array, two id
counters.  The state transitions are bit-identical to the sequential
algorithm — ``tests/test_clustering.py`` checks the scan against a
pure-Python transcription of Algorithm 1 on randomized streams.

Global degrees come from a one-pass precompute (same contract as 2PS-L;
the paper's head-cluster volume updates explicitly use global degrees).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..streaming.carry import MAX, SUM, PartitionerCarry

__all__ = [
    "ClusterState",
    "ClusterResult",
    "ClusterCarry",
    "DegreeCarry",
    "init_state",
    "cluster_chunk",
    "cluster_stream",
    "compact_clusters",
    "reference_cluster_python",
]


class ClusterState(NamedTuple):
    """Carry of the clustering scan.  All arrays are O(|V|)."""

    v2c_h: jax.Array  # (V,) int32, -1 = unassigned
    v2c_t: jax.Array  # (V,) int32, -1 = unassigned
    vol_h: jax.Array  # (V + 1,) int32 head-cluster volumes (global-degree units)
    vol_t: jax.Array  # (V + 1,) int32 tail-cluster volumes (local-degree units)
    ld: jax.Array  # (V,) int32 streaming local degree
    next_h: jax.Array  # () int32 next head cluster id
    next_t: jax.Array  # () int32 next tail cluster id


class ClusterResult(NamedTuple):
    """Compacted output of clustering (input to the Stackelberg game)."""

    v2c: jax.Array  # (V,) combined cluster id per vertex's *primary* table
    v2c_h: jax.Array  # (V,) head cluster id in combined id space (-1 if none)
    v2c_t: jax.Array  # (V,) tail cluster id in combined id space (-1 if none)
    n_head: int  # number of head clusters (ids [0, n_head))
    n_clusters: int  # total clusters; tail ids in [n_head, n_clusters)
    is_head_vertex: jax.Array  # (V,) bool


def init_state(n_vertices: int) -> ClusterState:
    v = n_vertices
    return ClusterState(
        v2c_h=jnp.full((v,), -1, jnp.int32),
        v2c_t=jnp.full((v,), -1, jnp.int32),
        vol_h=jnp.zeros((v + 1,), jnp.int32),
        vol_t=jnp.zeros((v + 1,), jnp.int32),
        ld=jnp.zeros((v,), jnp.int32),
        next_h=jnp.int32(0),
        next_t=jnp.int32(0),
    )


def _edge_step(state: ClusterState, edge, *, degrees, xi, kappa, global_tail=False):
    """One Algorithm-1 step.  ``edge`` = (u, v); branchless.

    ``global_tail=True`` is the S5P-B variant (§5.3): tail clusters also use
    allocation-time *global* degrees for volumes and migration amounts.
    """
    u, v = edge
    sink = state.vol_h.shape[0] - 1  # masked-write sink slot
    du = degrees[u]
    dv = degrees[v]
    is_head = (du > xi) & (dv > xi)
    valid = u != v  # self loops are no-ops (paper graphs are simple)

    # ---------------- head branch (global-degree volumes) ----------------
    cu = state.v2c_h[u]
    cv = state.v2c_h[v]
    new_u = cu < 0
    new_v = cv < 0
    h_on = is_head & valid
    # allocation: new ids, volume += global degree of the joining vertex
    cu2 = jnp.where(new_u, state.next_h, cu)
    next_h = state.next_h + jnp.where(h_on & new_u, 1, 0).astype(jnp.int32)
    cv2 = jnp.where(new_v, next_h, cv)
    next_h = next_h + jnp.where(h_on & new_v, 1, 0).astype(jnp.int32)
    vol_h = state.vol_h
    vol_h = vol_h.at[jnp.where(h_on & new_u, cu2, sink)].add(
        jnp.where(h_on & new_u, du, 0)
    )
    vol_h = vol_h.at[jnp.where(h_on & new_v, cv2, sink)].add(
        jnp.where(h_on & new_v, dv, 0)
    )
    v2c_h = state.v2c_h
    v2c_h = v2c_h.at[u].set(jnp.where(h_on, cu2, v2c_h[u]))
    v2c_h = v2c_h.at[v].set(jnp.where(h_on, cv2, v2c_h[v]))
    # migration (lines 5-11): only when both volumes < κ
    vu = vol_h[cu2]
    vv = vol_h[cv2]
    both_small = (vu < kappa) & (vv < kappa) & (cu2 != cv2)
    # i = argmin_z vol(C[z]) - d(z); j = other
    score_u = vu - du
    score_v = vv - dv
    u_is_i = score_u <= score_v  # tie → u (deterministic; matches reference)
    ci = jnp.where(u_is_i, cu2, cv2)
    cj = jnp.where(u_is_i, cv2, cu2)
    i_vtx = jnp.where(u_is_i, u, v)
    di = jnp.where(u_is_i, du, dv)
    can_migrate = h_on & both_small & (vol_h[cj] + di < kappa)
    vol_h = vol_h.at[jnp.where(can_migrate, cj, sink)].add(jnp.where(can_migrate, di, 0))
    vol_h = vol_h.at[jnp.where(can_migrate, ci, sink)].add(jnp.where(can_migrate, -di, 0))
    v2c_h = v2c_h.at[i_vtx].set(jnp.where(can_migrate, cj, v2c_h[i_vtx]))

    # ---------------- tail branch (local-degree volumes) ----------------
    t_on = (~is_head) & valid
    tu = state.v2c_t[u]
    tv = state.v2c_t[v]
    tnew_u = tu < 0
    tnew_v = tv < 0
    tu2 = jnp.where(tnew_u, state.next_t, tu)
    next_t = state.next_t + jnp.where(t_on & tnew_u, 1, 0).astype(jnp.int32)
    tv2 = jnp.where(tnew_v, next_t, tv)
    next_t = next_t + jnp.where(t_on & tnew_v, 1, 0).astype(jnp.int32)
    vol_t = state.vol_t
    ld = state.ld
    if global_tail:
        # S5P-B: allocation-time global-degree volumes (mirrors head branch)
        vol_t = vol_t.at[jnp.where(t_on & tnew_u, tu2, sink)].add(
            jnp.where(t_on & tnew_u, du, 0)
        )
        vol_t = vol_t.at[jnp.where(t_on & tnew_v, tv2, sink)].add(
            jnp.where(t_on & tnew_v, dv, 0)
        )
    else:
        # Update vol(·) by 1 and ld(·) by 1 for both endpoints (lines 14-15).
        vol_t = vol_t.at[jnp.where(t_on, tu2, sink)].add(jnp.where(t_on, 1, 0))
        vol_t = vol_t.at[jnp.where(t_on, tv2, sink)].add(jnp.where(t_on, 1, 0))
        ld = ld.at[u].add(jnp.where(t_on, 1, 0))
        ld = ld.at[v].add(jnp.where(t_on, 1, 0))
    v2c_t = state.v2c_t.at[u].set(jnp.where(t_on, tu2, state.v2c_t[u]))
    v2c_t = v2c_t.at[v].set(jnp.where(t_on, tv2, v2c_t[v]))
    # migration (lines 16-21): i = argmin vol; move ld(i) units
    tvu = vol_t[tu2]
    tvv = vol_t[tv2]
    t_small = (tvu < kappa) & (tvv < kappa) & (tu2 != tv2)
    tu_is_i = tvu <= tvv
    tci = jnp.where(tu_is_i, tu2, tv2)
    tcj = jnp.where(tu_is_i, tv2, tu2)
    ti_vtx = jnp.where(tu_is_i, u, v)
    ldi = degrees[ti_vtx] if global_tail else ld[ti_vtx]
    t_mig = t_on & t_small
    if global_tail:
        t_mig = t_mig & (vol_t[tcj] + ldi < kappa)
    vol_t = vol_t.at[jnp.where(t_mig, tcj, sink)].add(jnp.where(t_mig, ldi, 0))
    vol_t = vol_t.at[jnp.where(t_mig, tci, sink)].add(jnp.where(t_mig, -ldi, 0))
    v2c_t = v2c_t.at[ti_vtx].set(jnp.where(t_mig, tcj, v2c_t[ti_vtx]))

    return ClusterState(
        v2c_h=v2c_h,
        v2c_t=v2c_t,
        vol_h=vol_h,
        vol_t=vol_t,
        ld=ld,
        next_h=next_h,
        next_t=next_t,
    )


@partial(jax.jit, static_argnames=("xi", "kappa", "global_tail"))
def cluster_chunk(
    state: ClusterState,
    src: jax.Array,
    dst: jax.Array,
    degrees: jax.Array,
    *,
    xi: int,
    kappa: int,
    global_tail: bool = False,
) -> ClusterState:
    """Process one chunk of the edge stream through Algorithm 1."""

    def body(s, e):
        return (
            _edge_step(s, e, degrees=degrees, xi=xi, kappa=kappa, global_tail=global_tail),
            (),
        )

    state, _ = jax.lax.scan(body, state, (src, dst))
    return state


class ClusterCarry(PartitionerCarry):
    """Algorithm 1 as a :class:`~repro.streaming.carry.PartitionerCarry`.

    Carry = :class:`ClusterState`.  Merge semantics for parallel ingest:
    vertex→cluster tables and the id counters are monotone (``-1`` =
    unassigned, so MAX prefers any assignment and resolves cross-worker
    conflicts deterministically); cluster volumes and local degrees are
    additive (SUM of per-worker deltas).  State-only — no per-edge parts.
    """

    emits_parts = False
    # ClusterState leaf order: v2c_h, v2c_t, vol_h, vol_t, ld, next_h, next_t
    merge_ops = (MAX, MAX, SUM, SUM, SUM, MAX, MAX)

    def __init__(self, degrees: jax.Array, n_vertices: int, *, xi: int,
                 kappa: int, global_tail: bool = False):
        self.degrees = degrees
        self.n_vertices = int(n_vertices)
        self.xi = int(xi)
        self.kappa = int(kappa)
        self.global_tail = bool(global_tail)

    def init(self) -> ClusterState:
        return init_state(self.n_vertices)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        return cluster_chunk(
            carry, src, dst, self.degrees, xi=self.xi, kappa=self.kappa,
            global_tail=self.global_tail,
        ), None


class DegreeCarry(PartitionerCarry):
    """One-pass global degree precompute as a carry (deg SUM; state-only).

    Padding is masked via ``n_valid`` (real (0, 0) self-loops *do* count
    toward vertex 0's degree, exactly as :func:`compute_degrees` counts
    them — padding entries must not)."""

    emits_parts = False
    merge_ops = (SUM,)

    def __init__(self, n_vertices: int):
        self.n_vertices = int(n_vertices)

    def init(self) -> jax.Array:
        return jnp.zeros((self.n_vertices,), jnp.int32)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        return _degree_chunk(carry, src, dst, n_valid), None

    def finalize(self, carry):
        return carry.astype(jnp.int32)


@jax.jit
def _degree_chunk(deg, src, dst, n_valid):
    w = (jnp.arange(src.shape[0]) < n_valid).astype(jnp.int32)
    n = deg.shape[0]
    deg = deg + jax.ops.segment_sum(w, src, num_segments=n)
    deg = deg + jax.ops.segment_sum(w, dst, num_segments=n)
    return deg


def cluster_stream(
    src: jax.Array,
    dst: jax.Array,
    n_vertices: int,
    *,
    xi: int,
    kappa: int,
    chunk_size: int = 1 << 16,
    global_tail: bool = False,
    stream=None,
    num_streams: int = 1,
    super_chunk: int = 8,
) -> ClusterState:
    """Run Algorithm 1 over the whole stream in fixed-size device chunks.

    Only the O(|V|) carry persists between chunks — the streaming memory
    contract.  Degrees are the one-pass global precompute.  An existing
    :class:`repro.streaming.EdgeStream` (e.g. with a non-natural ordering)
    may be passed instead of raw arrays.  ``num_streams > 1`` ingests S
    sharded sub-streams in parallel with :class:`ClusterCarry` merges every
    ``super_chunk`` chunks (``num_streams=1`` is bit-identical sequential).
    """
    from ..streaming import as_stream, run_parallel

    stream = as_stream(src, dst, n_vertices, stream=stream,
                       chunk_size=chunk_size)
    # host-resident streams get the one-call vectorized precompute; streams
    # without full arrays (out-of-core) take the chunked pass — the two are
    # bit-identical (integer segment sums commute)
    src_full = getattr(stream, "src", None)
    if src_full is not None:
        degrees = compute_degrees(jnp.asarray(src_full, jnp.int32),
                                  jnp.asarray(stream.dst, jnp.int32),
                                  stream.n_vertices)
    else:
        degrees = compute_degrees_stream(stream)
    pc = ClusterCarry(degrees, stream.n_vertices, xi=xi, kappa=kappa,
                      global_tail=global_tail)
    _, state = run_parallel(stream, pc, num_streams=num_streams,
                            super_chunk=super_chunk)
    return state


def compute_degrees(src: jax.Array, dst: jax.Array, n_vertices: int) -> jax.Array:
    ones = jnp.ones_like(src)
    deg = jax.ops.segment_sum(ones, src, num_segments=n_vertices)
    deg = deg + jax.ops.segment_sum(ones, dst, num_segments=n_vertices)
    return deg.astype(jnp.int32)


def compute_degrees_stream(stream, num_streams: int = 1,
                           super_chunk: int = 8) -> jax.Array:
    """The one-pass global degree precompute, chunk by chunk — O(|V|) carry,
    so it runs on out-of-core streams too.  Integer segment sums commute,
    so the result is bit-identical to :func:`compute_degrees` on the full
    arrays (padding entries are masked out, not counted as self-loops) —
    and, for the same reason, to any ``num_streams``/``super_chunk``."""
    from ..streaming import run_parallel

    _, deg = run_parallel(stream, DegreeCarry(stream.n_vertices),
                          num_streams=num_streams, super_chunk=super_chunk)
    return deg


def compact_clusters(state: ClusterState, degrees: jax.Array, xi: int) -> ClusterResult:
    """Renumber head/tail clusters into one dense combined id space.

    Head clusters keep ids [0, n_head); tail clusters are shifted to
    [n_head, n_head + n_tail).  A vertex's *primary* cluster is its head
    cluster if it has one (head vertices lead), else its tail cluster.
    """
    v2c_h = np.asarray(state.v2c_h)
    v2c_t = np.asarray(state.v2c_t)
    deg = np.asarray(degrees)

    used_h = np.unique(v2c_h[v2c_h >= 0])
    used_t = np.unique(v2c_t[v2c_t >= 0])
    remap_h = np.full(int(state.next_h) + 1, -1, np.int32)
    remap_h[used_h] = np.arange(used_h.size, dtype=np.int32)
    remap_t = np.full(int(state.next_t) + 1, -1, np.int32)
    remap_t[used_t] = np.arange(used_t.size, dtype=np.int32) + used_h.size

    out_h = np.where(v2c_h >= 0, remap_h[np.maximum(v2c_h, 0)], -1).astype(np.int32)
    out_t = np.where(v2c_t >= 0, remap_t[np.maximum(v2c_t, 0)], -1).astype(np.int32)
    primary = np.where(out_h >= 0, out_h, out_t).astype(np.int32)
    is_head_vertex = deg > xi

    return ClusterResult(
        v2c=jnp.asarray(primary),
        v2c_h=jnp.asarray(out_h),
        v2c_t=jnp.asarray(out_t),
        n_head=int(used_h.size),
        n_clusters=int(used_h.size + used_t.size),
        is_head_vertex=jnp.asarray(is_head_vertex),
    )


# ---------------------------------------------------------------------------
# Pure-Python transcription of Algorithm 1 — the oracle for property tests.
# ---------------------------------------------------------------------------


def reference_cluster_python(edges, n_vertices, xi, kappa):
    """Direct sequential transcription of paper Algorithm 1 (line numbers in
    comments refer to the paper listing).  Returns plain numpy state."""
    v2c_h = np.full(n_vertices, -1, np.int64)
    v2c_t = np.full(n_vertices, -1, np.int64)
    vol_h = np.zeros(n_vertices + 1, np.int64)
    vol_t = np.zeros(n_vertices + 1, np.int64)
    ld = np.zeros(n_vertices, np.int64)
    deg = np.zeros(n_vertices, np.int64)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    next_h = 0
    next_t = 0
    for u, v in edges:
        if u == v:
            continue
        if deg[u] > xi and deg[v] > xi:  # head edge
            if v2c_h[u] < 0:  # line 3: assign new id
                v2c_h[u] = next_h
                next_h += 1
                vol_h[v2c_h[u]] += deg[u]  # line 4: update vol by d(u)
            if v2c_h[v] < 0:
                v2c_h[v] = next_h
                next_h += 1
                vol_h[v2c_h[v]] += deg[v]
            cu, cv = v2c_h[u], v2c_h[v]
            if vol_h[cu] < kappa and vol_h[cv] < kappa and cu != cv:  # line 5
                # line 6: i = argmin vol(C[z]) - d(z); tie → u
                if vol_h[cu] - deg[u] <= vol_h[cv] - deg[v]:
                    i_vtx, ci, cj, di = u, cu, cv, deg[u]
                else:
                    i_vtx, ci, cj, di = v, cv, cu, deg[v]
                if vol_h[cj] + di < kappa:  # line 8
                    vol_h[cj] += di
                    vol_h[ci] -= di
                    v2c_h[i_vtx] = cj
        else:  # tail edge
            if v2c_t[u] < 0:  # line 13
                v2c_t[u] = next_t
                next_t += 1
            if v2c_t[v] < 0:
                v2c_t[v] = next_t
                next_t += 1
            vol_t[v2c_t[u]] += 1  # line 14: update vol by 1
            vol_t[v2c_t[v]] += 1
            ld[u] += 1  # line 15: update ld by 1
            ld[v] += 1
            tu, tv = v2c_t[u], v2c_t[v]
            if vol_t[tu] < kappa and vol_t[tv] < kappa and tu != tv:  # line 16
                if vol_t[tu] <= vol_t[tv]:  # line 17: i = argmin vol; tie → u
                    i_vtx, ci, cj = u, tu, tv
                else:
                    i_vtx, ci, cj = v, tv, tu
                ldi = ld[i_vtx]
                vol_t[cj] += ldi  # lines 19-21 (unconditional in listing)
                vol_t[ci] -= ldi
                v2c_t[i_vtx] = cj
    return dict(
        v2c_h=v2c_h, v2c_t=v2c_t, vol_h=vol_h, vol_t=vol_t, ld=ld,
        next_h=next_h, next_t=next_t, deg=deg,
    )
