"""Skewness-aware streaming graph clustering (paper Algorithm 1).

Edges arrive as a stream.  Each edge is classified *head* (both endpoints
have global degree > ξ) or *tail* (otherwise) and drives an
allocate/migrate update on one of two vertex→cluster tables:

- ``V2C_H`` (head): cluster volumes tracked in **global-degree** units;
- ``V2C_T`` (tail): volumes in **local-degree** units (1 per edge arrival).

Migration merges the lighter endpoint's cluster into the heavier one when
the receiving cluster stays under the volume cap κ = 2|E|/k.

TPU adaptation (recorded in DESIGN.md §2): the paper's per-edge loop with
early-exit branches becomes a ``jax.lax.scan`` with branchless
``jnp.where`` state transitions.  The carry is strictly O(|V|):
two V2C tables, two volume arrays (≤ |V| + 1 slots each; the trailing slot
is a write sink for masked updates), one local-degree array, two id
counters — plus, since the decremental refactor, two **membership
counters** (head/tail edge incidences per vertex; a vertex's assignment
projects to "unassigned" when its counter returns to 0 — counted
tombstones) and the head **allocation contribution** (the global degree
added to ``vol_h`` when the vertex was allocated, so orphaning a head
vertex can subtract exactly what its allocation added).  The insert-path
state transitions are bit-identical to the sequential algorithm —
``tests/test_clustering.py`` checks the scan against a pure-Python
transcription of Algorithm 1 on randomized streams.

Deletion (:meth:`ClusterCarry.retract_chunk`) is the documented
*approximate* retraction: membership counters and local degrees subtract
exactly, tail volumes subtract at the vertex's **current** cluster, and a
head vertex whose counter hits 0 hands back its allocation contribution —
but migrations are history-dependent, so volumes drift boundedly under
churn.  The drift monitor + masked-game refinement of
``repro.incremental`` are the quality backstop, exactly as for warm-start
insertion replay.

Global degrees come from a one-pass precompute (same contract as 2PS-L;
the paper's head-cluster volume updates explicitly use global degrees).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..streaming.carry import COUNTED, SUM, PartitionerCarry

__all__ = [
    "ClusterState",
    "ClusterResult",
    "ClusterCarry",
    "DegreeCarry",
    "init_state",
    "cluster_chunk",
    "cluster_retract_chunk",
    "cluster_stream",
    "compact_clusters",
    "reference_cluster_python",
]


class ClusterState(NamedTuple):
    """Carry of the clustering scan.  All arrays are O(|V|)."""

    v2c_h: jax.Array  # (V,) int32, -1 = unassigned
    v2c_t: jax.Array  # (V,) int32, -1 = unassigned
    vol_h: jax.Array  # (V + 1,) int32 head-cluster volumes (global-degree units)
    vol_t: jax.Array  # (V + 1,) int32 tail-cluster volumes (local-degree units)
    ld: jax.Array  # (V,) int32 streaming local degree
    next_h: jax.Array  # () int32 next head cluster id
    next_t: jax.Array  # () int32 next tail cluster id
    cnt_h: jax.Array  # (V,) int32 counted head-edge incidences (membership)
    cnt_t: jax.Array  # (V,) int32 counted tail-edge incidences (membership)
    alloc_h: jax.Array  # (V,) int32 vol_h contribution added at allocation

    def effective(self) -> tuple[jax.Array, jax.Array]:
        """(v2c_h, v2c_t) with dead entries projected to ``-1``.

        Dead = membership counter ≤ 0 (every incident edge deleted) or an
        out-of-range id (the clamped resolution of a cross-worker merge
        conflict).  On insert-only sequential streams the projection is
        the identity on assigned entries — an assignment always arrives
        with its first incidence — which is what keeps the golden hashes
        unchanged.
        """
        ok_h = (self.cnt_h > 0) & (self.v2c_h >= 0) & (self.v2c_h < self.next_h)
        ok_t = (self.cnt_t > 0) & (self.v2c_t >= 0) & (self.v2c_t < self.next_t)
        return (jnp.where(ok_h, self.v2c_h, -1),
                jnp.where(ok_t, self.v2c_t, -1))


class ClusterResult(NamedTuple):
    """Compacted output of clustering (input to the Stackelberg game)."""

    v2c: jax.Array  # (V,) combined cluster id per vertex's *primary* table
    v2c_h: jax.Array  # (V,) head cluster id in combined id space (-1 if none)
    v2c_t: jax.Array  # (V,) tail cluster id in combined id space (-1 if none)
    n_head: int  # number of head clusters (ids [0, n_head))
    n_clusters: int  # total clusters; tail ids in [n_head, n_clusters)
    is_head_vertex: jax.Array  # (V,) bool


def init_state(n_vertices: int) -> ClusterState:
    v = n_vertices
    return ClusterState(
        v2c_h=jnp.full((v,), -1, jnp.int32),
        v2c_t=jnp.full((v,), -1, jnp.int32),
        vol_h=jnp.zeros((v + 1,), jnp.int32),
        vol_t=jnp.zeros((v + 1,), jnp.int32),
        ld=jnp.zeros((v,), jnp.int32),
        next_h=jnp.int32(0),
        next_t=jnp.int32(0),
        cnt_h=jnp.zeros((v,), jnp.int32),
        cnt_t=jnp.zeros((v,), jnp.int32),
        alloc_h=jnp.zeros((v,), jnp.int32),
    )


def _edge_step(state: ClusterState, edge, *, degrees, xi, kappa, global_tail=False):
    """One Algorithm-1 step.  ``edge`` = (u, v); branchless.

    ``global_tail=True`` is the S5P-B variant (§5.3): tail clusters also use
    allocation-time *global* degrees for volumes and migration amounts.
    """
    u, v = edge
    sink = state.vol_h.shape[0] - 1  # masked-write sink slot
    du = degrees[u]
    dv = degrees[v]
    is_head = (du > xi) & (dv > xi)
    valid = u != v  # self loops are no-ops (paper graphs are simple)

    # ---------------- head branch (global-degree volumes) ----------------
    cu = state.v2c_h[u]
    cv = state.v2c_h[v]
    new_u = cu < 0
    new_v = cv < 0
    h_on = is_head & valid
    # allocation: new ids, volume += global degree of the joining vertex
    cu2 = jnp.where(new_u, state.next_h, cu)
    next_h = state.next_h + jnp.where(h_on & new_u, 1, 0).astype(jnp.int32)
    cv2 = jnp.where(new_v, next_h, cv)
    next_h = next_h + jnp.where(h_on & new_v, 1, 0).astype(jnp.int32)
    vol_h = state.vol_h
    vol_h = vol_h.at[jnp.where(h_on & new_u, cu2, sink)].add(
        jnp.where(h_on & new_u, du, 0)
    )
    vol_h = vol_h.at[jnp.where(h_on & new_v, cv2, sink)].add(
        jnp.where(h_on & new_v, dv, 0)
    )
    # counted membership + the allocation contribution deletions hand back
    cnt_h = state.cnt_h
    cnt_h = cnt_h.at[u].add(jnp.where(h_on, 1, 0))
    cnt_h = cnt_h.at[v].add(jnp.where(h_on, 1, 0))
    alloc_h = state.alloc_h
    alloc_h = alloc_h.at[u].add(jnp.where(h_on & new_u, du, 0))
    alloc_h = alloc_h.at[v].add(jnp.where(h_on & new_v, dv, 0))
    v2c_h = state.v2c_h
    v2c_h = v2c_h.at[u].set(jnp.where(h_on, cu2, v2c_h[u]))
    v2c_h = v2c_h.at[v].set(jnp.where(h_on, cv2, v2c_h[v]))
    # migration (lines 5-11): only when both volumes < κ
    vu = vol_h[cu2]
    vv = vol_h[cv2]
    both_small = (vu < kappa) & (vv < kappa) & (cu2 != cv2)
    # i = argmin_z vol(C[z]) - d(z); j = other
    score_u = vu - du
    score_v = vv - dv
    u_is_i = score_u <= score_v  # tie → u (deterministic; matches reference)
    ci = jnp.where(u_is_i, cu2, cv2)
    cj = jnp.where(u_is_i, cv2, cu2)
    i_vtx = jnp.where(u_is_i, u, v)
    di = jnp.where(u_is_i, du, dv)
    can_migrate = h_on & both_small & (vol_h[cj] + di < kappa)
    vol_h = vol_h.at[jnp.where(can_migrate, cj, sink)].add(jnp.where(can_migrate, di, 0))
    vol_h = vol_h.at[jnp.where(can_migrate, ci, sink)].add(jnp.where(can_migrate, -di, 0))
    v2c_h = v2c_h.at[i_vtx].set(jnp.where(can_migrate, cj, v2c_h[i_vtx]))

    # ---------------- tail branch (local-degree volumes) ----------------
    t_on = (~is_head) & valid
    tu = state.v2c_t[u]
    tv = state.v2c_t[v]
    tnew_u = tu < 0
    tnew_v = tv < 0
    tu2 = jnp.where(tnew_u, state.next_t, tu)
    next_t = state.next_t + jnp.where(t_on & tnew_u, 1, 0).astype(jnp.int32)
    tv2 = jnp.where(tnew_v, next_t, tv)
    next_t = next_t + jnp.where(t_on & tnew_v, 1, 0).astype(jnp.int32)
    vol_t = state.vol_t
    ld = state.ld
    if global_tail:
        # S5P-B: allocation-time global-degree volumes (mirrors head branch)
        vol_t = vol_t.at[jnp.where(t_on & tnew_u, tu2, sink)].add(
            jnp.where(t_on & tnew_u, du, 0)
        )
        vol_t = vol_t.at[jnp.where(t_on & tnew_v, tv2, sink)].add(
            jnp.where(t_on & tnew_v, dv, 0)
        )
    else:
        # Update vol(·) by 1 and ld(·) by 1 for both endpoints (lines 14-15).
        vol_t = vol_t.at[jnp.where(t_on, tu2, sink)].add(jnp.where(t_on, 1, 0))
        vol_t = vol_t.at[jnp.where(t_on, tv2, sink)].add(jnp.where(t_on, 1, 0))
        ld = ld.at[u].add(jnp.where(t_on, 1, 0))
        ld = ld.at[v].add(jnp.where(t_on, 1, 0))
    v2c_t = state.v2c_t.at[u].set(jnp.where(t_on, tu2, state.v2c_t[u]))
    v2c_t = v2c_t.at[v].set(jnp.where(t_on, tv2, v2c_t[v]))
    cnt_t = state.cnt_t
    cnt_t = cnt_t.at[u].add(jnp.where(t_on, 1, 0))
    cnt_t = cnt_t.at[v].add(jnp.where(t_on, 1, 0))
    # migration (lines 16-21): i = argmin vol; move ld(i) units
    tvu = vol_t[tu2]
    tvv = vol_t[tv2]
    t_small = (tvu < kappa) & (tvv < kappa) & (tu2 != tv2)
    tu_is_i = tvu <= tvv
    tci = jnp.where(tu_is_i, tu2, tv2)
    tcj = jnp.where(tu_is_i, tv2, tu2)
    ti_vtx = jnp.where(tu_is_i, u, v)
    ldi = degrees[ti_vtx] if global_tail else ld[ti_vtx]
    t_mig = t_on & t_small
    if global_tail:
        t_mig = t_mig & (vol_t[tcj] + ldi < kappa)
    vol_t = vol_t.at[jnp.where(t_mig, tcj, sink)].add(jnp.where(t_mig, ldi, 0))
    vol_t = vol_t.at[jnp.where(t_mig, tci, sink)].add(jnp.where(t_mig, -ldi, 0))
    v2c_t = v2c_t.at[ti_vtx].set(jnp.where(t_mig, tcj, v2c_t[ti_vtx]))

    return ClusterState(
        v2c_h=v2c_h,
        v2c_t=v2c_t,
        vol_h=vol_h,
        vol_t=vol_t,
        ld=ld,
        next_h=next_h,
        next_t=next_t,
        cnt_h=cnt_h,
        cnt_t=cnt_t,
        alloc_h=alloc_h,
    )


@partial(jax.jit, static_argnames=("xi", "kappa", "global_tail"))
def cluster_chunk(
    state: ClusterState,
    src: jax.Array,
    dst: jax.Array,
    degrees: jax.Array,
    *,
    xi: int,
    kappa: int,
    global_tail: bool = False,
) -> ClusterState:
    """Process one chunk of the edge stream through Algorithm 1."""

    def body(s, e):
        return (
            _edge_step(s, e, degrees=degrees, xi=xi, kappa=kappa, global_tail=global_tail),
            (),
        )

    state, _ = jax.lax.scan(body, state, (src, dst))
    return state


def cluster_retract_chunk(
    state: ClusterState,
    src: jax.Array,
    dst: jax.Array,
    n_valid,
    degrees: jax.Array | None = None,
    *,
    xi: int | None = None,
    is_head: jax.Array | None = None,
) -> ClusterState:
    """Retract one chunk of **deleted** edges from the clustering carry.

    Order-independent decremental accounting (no scan): membership
    counters and streaming local degrees subtract exactly; tail volumes
    subtract one unit per endpoint at the vertex's *current* tail cluster
    (bounded staleness when the vertex migrated since insertion); a head
    vertex orphaned by this chunk (counter reaches 0) hands its recorded
    allocation contribution back to its current head cluster and resets
    to unassigned, so a re-inserted head edge re-allocates it cleanly.

    Head/tail classification: pass the per-edge ``is_head`` flags recorded
    at insertion time when available (the S5P bundle stores them — the
    retraction then mirrors exactly what insertion accounted), else the
    frozen-ξ classification against ``degrees`` (which should be the
    pre-deletion table so both sides see the same degrees).
    """
    if is_head is None:
        if degrees is None or xi is None:
            raise ValueError("need either is_head flags or (degrees, xi)")
        is_head = (degrees[src] > xi) & (degrees[dst] > xi)
    return _cluster_retract(state, src, dst, jnp.int32(n_valid),
                            jnp.asarray(is_head))


@jax.jit
def _cluster_retract(state, src, dst, n_valid, is_head):
    V = state.ld.shape[0]
    sink = state.vol_h.shape[0] - 1
    real = jnp.arange(src.shape[0]) < n_valid
    valid = real & (src != dst)
    h = (valid & is_head).astype(jnp.int32)
    t = (valid & ~is_head).astype(jnp.int32)

    cnt_h = state.cnt_h
    cnt_h = cnt_h - jax.ops.segment_sum(h, src, num_segments=V)
    cnt_h = cnt_h - jax.ops.segment_sum(h, dst, num_segments=V)
    cnt_t = state.cnt_t
    cnt_t = cnt_t - jax.ops.segment_sum(t, src, num_segments=V)
    cnt_t = cnt_t - jax.ops.segment_sum(t, dst, num_segments=V)
    ld = state.ld
    ld = ld - jax.ops.segment_sum(t, src, num_segments=V)
    ld = ld - jax.ops.segment_sum(t, dst, num_segments=V)

    # tail volumes: one unit per endpoint at the current tail cluster
    vol_t = state.vol_t
    for vtx, w in ((src, t), (dst, t)):
        c = state.v2c_t[vtx]
        on = (w > 0) & (c >= 0)
        vol_t = vol_t.at[jnp.where(on, c, sink)].add(-on.astype(jnp.int32))

    # head orphans: hand back the allocation contribution, reset the id
    orphan = (cnt_h <= 0) & (state.cnt_h > 0) & (state.v2c_h >= 0)
    vol_h = state.vol_h.at[jnp.where(orphan, state.v2c_h, sink)].add(
        jnp.where(orphan, -state.alloc_h, 0))
    alloc_h = jnp.where(orphan, 0, state.alloc_h)
    v2c_h = jnp.where(orphan, -1, state.v2c_h)
    # tail orphans: volumes already subtracted per incidence — reset the id
    orphan_t = (cnt_t <= 0) & (state.cnt_t > 0) & (state.v2c_t >= 0)
    v2c_t = jnp.where(orphan_t, -1, state.v2c_t)

    return ClusterState(
        v2c_h=v2c_h, v2c_t=v2c_t, vol_h=vol_h, vol_t=vol_t, ld=ld,
        next_h=state.next_h, next_t=state.next_t,
        cnt_h=cnt_h, cnt_t=cnt_t, alloc_h=alloc_h,
    )


class ClusterCarry(PartitionerCarry):
    """Algorithm 1 as a :class:`~repro.streaming.carry.PartitionerCarry`.

    Carry = :class:`ClusterState`.  Merge semantics for parallel ingest
    are pure group ops: volumes, local degrees and the id counters are
    additive (SUM of per-worker deltas against the shared merge base);
    the vertex→cluster tables merge as SUM-of-transitions — when a single
    worker reassigned a vertex the telescoped sum *is* that worker's
    value (the overwhelmingly common case under chunk-range sharding);
    membership counters are COUNTED.  When two workers concurrently
    reassign the *same* vertex within one super-chunk the telescoped sum
    would be a fabricated id (out-of-range sums project to unassigned,
    in-range ones alias an unrelated cluster), so the two v2c leaves are
    flagged :attr:`~repro.streaming.carry.PartitionerCarry.pick_first`:
    concurrent reassignments resolve to the lowest-lane writer's id — a
    *real* cluster some lane chose — instead of the telescoped sum.
    Parallel cluster ingest is still approximate by design (the loser
    lane's volume deltas were accrued against its own id), but membership
    is never garbage; hub-sharded lanes (``shard="hub"``) additionally
    make every hub single-writer, shrinking the conflict set to
    cross-lane tail vertices.  The slow-lane 8-device band test pins the
    quality envelope, and the group structure is what buys exact
    deletions everywhere else.  State-only — no per-edge parts.
    """

    emits_parts = False
    supports_retract = True
    retract_exact = False  # migrations are history-dependent (see module doc)
    # ClusterState leaf order: v2c_h, v2c_t, vol_h, vol_t, ld, next_h,
    # next_t, cnt_h, cnt_t, alloc_h
    merge_ops = (SUM, SUM, SUM, SUM, SUM, SUM, SUM, COUNTED, COUNTED, SUM)
    pick_first = (0, 1)  # v2c_h, v2c_t: keep a real id under contention

    def __init__(self, degrees: jax.Array, n_vertices: int, *, xi: int,
                 kappa: int, global_tail: bool = False,
                 use_kernel: bool | None = None,
                 vmem_budget: int | None = None):
        self.degrees = degrees
        self.n_vertices = int(n_vertices)
        self.xi = int(xi)
        self.kappa = int(kappa)
        self.global_tail = bool(global_tail)
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self._use_kernel = bool(use_kernel)
        self._vmem_budget = vmem_budget

    def init(self) -> ClusterState:
        return init_state(self.n_vertices)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        if self._use_kernel:
            # lazy import: core.baselines imports the kernels package at
            # module level, so the reverse edge must stay function-local
            from ..kernels import stream_scan as _scan

            path = _scan.select_path(
                self.n_vertices, 1, src.shape[0], consumer="cluster",
                budget=self._vmem_budget)
            if path == "fused":
                leaves = _scan.cluster_scan(
                    tuple(carry), src, dst, self.degrees, xi=self.xi,
                    kappa=self.kappa, global_tail=self.global_tail)
                return ClusterState(*leaves), None
        return cluster_chunk(
            carry, src, dst, self.degrees, xi=self.xi, kappa=self.kappa,
            global_tail=self.global_tail,
        ), None

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        return cluster_retract_chunk(carry, src, dst, n_valid, self.degrees,
                                     xi=self.xi)

    def occupancy_contest(self, before, after) -> float:
        """Membership churn between consecutive merge bases.

        The COUNTED occupancy default saturates almost immediately here
        (membership *counters* go nonzero on first touch and stay), which
        would let auto cadence back off while vertices are still hopping
        between clusters — exactly the window where concurrent
        reassignments degrade quality.  Measure reassignment instead:
        the fraction of assigned vertices whose cluster id moved
        (assigned→assigned with a different id) across the two v2c
        tables.  Fresh assignments (unassigned→id) are growth, not
        contention, and don't count."""
        changed = active = 0
        for b, a in ((before.v2c_h, after.v2c_h),
                     (before.v2c_t, after.v2c_t)):
            changed += int(jnp.sum((b >= 0) & (a >= 0) & (a != b)))
            active += int(jnp.sum(a >= 0))
        return changed / max(active, 1)


class DegreeCarry(PartitionerCarry):
    """One-pass global degree precompute as a carry (deg SUM; state-only).

    Padding is masked via ``n_valid`` (real (0, 0) self-loops *do* count
    toward vertex 0's degree, exactly as :func:`compute_degrees` counts
    them — padding entries must not)."""

    emits_parts = False
    supports_retract = True
    retract_exact = True
    merge_ops = (SUM,)

    def __init__(self, n_vertices: int):
        self.n_vertices = int(n_vertices)

    def init(self) -> jax.Array:
        return jnp.zeros((self.n_vertices,), jnp.int32)

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        return _degree_chunk(carry, src, dst, n_valid), None

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        return carry - _degree_chunk(jnp.zeros_like(carry), src, dst, n_valid)

    def finalize(self, carry):
        return carry.astype(jnp.int32)


@jax.jit
def _degree_chunk(deg, src, dst, n_valid):
    w = (jnp.arange(src.shape[0]) < n_valid).astype(jnp.int32)
    n = deg.shape[0]
    deg = deg + jax.ops.segment_sum(w, src, num_segments=n)
    deg = deg + jax.ops.segment_sum(w, dst, num_segments=n)
    return deg


def cluster_stream(
    src: jax.Array,
    dst: jax.Array,
    n_vertices: int,
    *,
    xi: int,
    kappa: int,
    chunk_size: int = 1 << 16,
    global_tail: bool = False,
    stream=None,
    num_streams: int = 1,
    super_chunk: int | str = 8,
    shard: str = "range",
    use_kernel: bool | None = None,
    vmem_budget: int | None = None,
) -> ClusterState:
    """Run Algorithm 1 over the whole stream in fixed-size device chunks.

    Only the O(|V|) carry persists between chunks — the streaming memory
    contract.  Degrees are the one-pass global precompute.  An existing
    :class:`repro.streaming.EdgeStream` (e.g. with a non-natural ordering)
    may be passed instead of raw arrays.  ``num_streams > 1`` ingests S
    sharded sub-streams in parallel with :class:`ClusterCarry` merges every
    ``super_chunk`` chunks (``num_streams=1`` is bit-identical sequential).
    """
    from ..streaming import as_stream, run_parallel

    stream = as_stream(src, dst, n_vertices, stream=stream,
                       chunk_size=chunk_size)
    # host-resident streams get the one-call vectorized precompute; streams
    # without full arrays (out-of-core) take the chunked pass — the two are
    # bit-identical (integer segment sums commute)
    src_full = getattr(stream, "src", None)
    if src_full is not None:
        degrees = compute_degrees(jnp.asarray(src_full, jnp.int32),
                                  jnp.asarray(stream.dst, jnp.int32),
                                  stream.n_vertices)
    else:
        degrees = compute_degrees_stream(stream)
    pc = ClusterCarry(degrees, stream.n_vertices, xi=xi, kappa=kappa,
                      global_tail=global_tail, use_kernel=use_kernel,
                      vmem_budget=vmem_budget)
    _, state = run_parallel(stream, pc, num_streams=num_streams,
                            super_chunk=super_chunk, shard=shard)
    return state


def compute_degrees(src: jax.Array, dst: jax.Array, n_vertices: int) -> jax.Array:
    ones = jnp.ones_like(src)
    deg = jax.ops.segment_sum(ones, src, num_segments=n_vertices)
    deg = deg + jax.ops.segment_sum(ones, dst, num_segments=n_vertices)
    return deg.astype(jnp.int32)


def compute_degrees_stream(stream, num_streams: int = 1,
                           super_chunk: int = 8) -> jax.Array:
    """The one-pass global degree precompute, chunk by chunk — O(|V|) carry,
    so it runs on out-of-core streams too.  Integer segment sums commute,
    so the result is bit-identical to :func:`compute_degrees` on the full
    arrays (padding entries are masked out, not counted as self-loops) —
    and, for the same reason, to any ``num_streams``/``super_chunk``."""
    from ..streaming import run_parallel

    _, deg = run_parallel(stream, DegreeCarry(stream.n_vertices),
                          num_streams=num_streams, super_chunk=super_chunk)
    return deg


def compact_clusters(state: ClusterState, degrees: jax.Array, xi: int) -> ClusterResult:
    """Renumber head/tail clusters into one dense combined id space.

    Head clusters keep ids [0, n_head); tail clusters are shifted to
    [n_head, n_head + n_tail).  A vertex's *primary* cluster is its head
    cluster if it has one (head vertices lead), else its tail cluster.
    Works on the counted projection, so vertices orphaned by deletions
    (membership counter 0) drop out of the id space here.
    """
    eff_h, eff_t = state.effective()
    v2c_h = np.asarray(eff_h)
    v2c_t = np.asarray(eff_t)
    deg = np.asarray(degrees)

    used_h = np.unique(v2c_h[v2c_h >= 0])
    used_t = np.unique(v2c_t[v2c_t >= 0])
    remap_h = np.full(int(state.next_h) + 1, -1, np.int32)
    remap_h[used_h] = np.arange(used_h.size, dtype=np.int32)
    remap_t = np.full(int(state.next_t) + 1, -1, np.int32)
    remap_t[used_t] = np.arange(used_t.size, dtype=np.int32) + used_h.size

    out_h = np.where(v2c_h >= 0, remap_h[np.maximum(v2c_h, 0)], -1).astype(np.int32)
    out_t = np.where(v2c_t >= 0, remap_t[np.maximum(v2c_t, 0)], -1).astype(np.int32)
    primary = np.where(out_h >= 0, out_h, out_t).astype(np.int32)
    is_head_vertex = deg > xi

    return ClusterResult(
        v2c=jnp.asarray(primary),
        v2c_h=jnp.asarray(out_h),
        v2c_t=jnp.asarray(out_t),
        n_head=int(used_h.size),
        n_clusters=int(used_h.size + used_t.size),
        is_head_vertex=jnp.asarray(is_head_vertex),
    )


# ---------------------------------------------------------------------------
# Pure-Python transcription of Algorithm 1 — the oracle for property tests.
# ---------------------------------------------------------------------------


def reference_cluster_python(edges, n_vertices, xi, kappa):
    """Direct sequential transcription of paper Algorithm 1 (line numbers in
    comments refer to the paper listing).  Returns plain numpy state."""
    v2c_h = np.full(n_vertices, -1, np.int64)
    v2c_t = np.full(n_vertices, -1, np.int64)
    vol_h = np.zeros(n_vertices + 1, np.int64)
    vol_t = np.zeros(n_vertices + 1, np.int64)
    ld = np.zeros(n_vertices, np.int64)
    deg = np.zeros(n_vertices, np.int64)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    next_h = 0
    next_t = 0
    for u, v in edges:
        if u == v:
            continue
        if deg[u] > xi and deg[v] > xi:  # head edge
            if v2c_h[u] < 0:  # line 3: assign new id
                v2c_h[u] = next_h
                next_h += 1
                vol_h[v2c_h[u]] += deg[u]  # line 4: update vol by d(u)
            if v2c_h[v] < 0:
                v2c_h[v] = next_h
                next_h += 1
                vol_h[v2c_h[v]] += deg[v]
            cu, cv = v2c_h[u], v2c_h[v]
            if vol_h[cu] < kappa and vol_h[cv] < kappa and cu != cv:  # line 5
                # line 6: i = argmin vol(C[z]) - d(z); tie → u
                if vol_h[cu] - deg[u] <= vol_h[cv] - deg[v]:
                    i_vtx, ci, cj, di = u, cu, cv, deg[u]
                else:
                    i_vtx, ci, cj, di = v, cv, cu, deg[v]
                if vol_h[cj] + di < kappa:  # line 8
                    vol_h[cj] += di
                    vol_h[ci] -= di
                    v2c_h[i_vtx] = cj
        else:  # tail edge
            if v2c_t[u] < 0:  # line 13
                v2c_t[u] = next_t
                next_t += 1
            if v2c_t[v] < 0:
                v2c_t[v] = next_t
                next_t += 1
            vol_t[v2c_t[u]] += 1  # line 14: update vol by 1
            vol_t[v2c_t[v]] += 1
            ld[u] += 1  # line 15: update ld by 1
            ld[v] += 1
            tu, tv = v2c_t[u], v2c_t[v]
            if vol_t[tu] < kappa and vol_t[tv] < kappa and tu != tv:  # line 16
                if vol_t[tu] <= vol_t[tv]:  # line 17: i = argmin vol; tie → u
                    i_vtx, ci, cj = u, tu, tv
                else:
                    i_vtx, ci, cj = v, tv, tu
                ldi = ld[i_vtx]
                vol_t[cj] += ldi  # lines 19-21 (unconditional in listing)
                vol_t[ci] -= ldi
                v2c_t[i_vtx] = cj
    return dict(
        v2c_h=v2c_h, v2c_t=v2c_t, vol_h=vol_h, vol_t=vol_t, ld=ld,
        next_h=next_h, next_t=next_t, deg=deg,
    )
