"""Partitioning-quality metrics: replication factor, balance, comm volume.

``RF = Σ_v |P(v)| / |V|`` (paper Eq. 1) where ``P(v)`` is the set of
partitions holding at least one edge incident to v.  We materialize the
vertex×partition replica bitmap (O(k|V|) — the same bound the paper's
Algorithm 3 replication matrix uses) with two scatter-ORs.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "replica_matrix",
    "replication_factor",
    "load_balance",
    "partition_loads",
    "rf_by_degree",
    "gas_comm_bytes",
]


@partial(jax.jit, static_argnames=("n_vertices", "k"))
def replica_matrix(src, dst, parts, *, n_vertices: int, k: int) -> jax.Array:
    """(V, k) bool: vertex v has a replica in partition p."""
    mat = jnp.zeros((n_vertices, k), jnp.bool_)
    valid = parts >= 0
    p = jnp.maximum(parts, 0)
    mat = mat.at[src, p].max(valid)
    mat = mat.at[dst, p].max(valid)
    return mat


def replication_factor(src, dst, parts, *, n_vertices: int, k: int) -> float:
    """Vertices with no assigned edge don't count toward |V| (isolated)."""
    mat = replica_matrix(src, dst, parts, n_vertices=n_vertices, k=k)
    replicas = jnp.sum(mat, axis=1)
    present = replicas > 0
    denom = jnp.maximum(jnp.sum(present), 1)
    return float(jnp.sum(replicas) / denom)


@partial(jax.jit, static_argnames=("k",))
def partition_loads(parts, *, k: int) -> jax.Array:
    valid = (parts >= 0).astype(jnp.int32)
    return jax.ops.segment_sum(valid, jnp.maximum(parts, 0), num_segments=k)


def load_balance(parts, *, k: int) -> float:
    """Relative imbalance: k·max_i |p_i| / |E| (paper Eq. 2 LHS)."""
    loads = partition_loads(parts, k=k)
    n = int(jnp.sum(loads))
    return float(k * jnp.max(loads) / max(n, 1))


def rf_by_degree(src, dst, parts, *, n_vertices: int, k: int):
    """Average replication per degree value — the degree-distribution form of
    Eq. (1); used for the paper's Fig. 8-style skew analysis."""
    mat = replica_matrix(src, dst, parts, n_vertices=n_vertices, k=k)
    replicas = np.asarray(jnp.sum(mat, axis=1))
    ones = jnp.ones_like(src)
    deg = jax.ops.segment_sum(ones, src, num_segments=n_vertices)
    deg = np.asarray(deg + jax.ops.segment_sum(ones, dst, num_segments=n_vertices))
    out: dict[int, tuple[float, int]] = {}
    for d in np.unique(deg[deg > 0]):
        sel = deg == d
        out[int(d)] = (float(replicas[sel].mean()), int(sel.sum()))
    return out


def gas_comm_bytes(src, dst, parts, *, n_vertices: int, k: int,
                   bytes_per_value: int = 8, iterations: int = 1) -> int:
    """Per-iteration GAS sync volume implied by a vertex-cut partitioning.

    Each replica of v sends its partial gather to the master copy and
    receives the applied value back: 2·(|P(v)|−1) messages of one value —
    exactly the PowerGraph delta-caching-off cost model the paper's Fig. 11
    communication numbers measure.
    """
    mat = replica_matrix(src, dst, parts, n_vertices=n_vertices, k=k)
    replicas = jnp.sum(mat, axis=1)
    msgs = jnp.sum(jnp.maximum(replicas - 1, 0))
    return int(msgs) * 2 * bytes_per_value * iterations
