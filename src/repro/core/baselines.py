"""Streaming vertex-cut baselines the paper compares against (§6.2).

All are single-pass streaming partitioners over the same edge-stream
contract as S5P.  Scoring/sequential ones (Greedy, HDRF, Grid) run as
jitted ``lax.scan`` with O(k|V|) carry (the replica bitmap — the same
asymptotics as their reference C++ implementations).  Hash/DBH are
one-shot vectorized.

- Hash:   p = h(eid) mod k                                    [random]
- DBH:    hash the lower-(global-)degree endpoint             [Xie et al. 2014]
- Grid:   candidate cells = row∪col of each endpoint's hashed
          cell; pick least-loaded intersection cell           [GraphBuilder 2013]
- Greedy: PowerGraph's 4-case replica-aware heuristic         [Gonzalez 2012]
- HDRF:   degree-weighted replica score + balance term        [Petroni 2015]
- 2PS-L-style: Holl-ish global-degree clustering + linear
          cluster placement + streaming refinement            [Mayer 2022]
- CLUGP-style: local-degree clustering + ONE-stage
          simultaneous cluster game + postprocess             [Kong 2022]

The 2PS-L / CLUGP entries are faithful *reimplementations of the published
algorithmsʼ structure* (clustering-refinement), not the authors' binaries;
they double as the paper's Fig. 7 ablations (CLUGP-style == S5P with
``one_stage`` game and local-degree-only clustering).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import clustering as _cl
from . import game as _game
from . import postprocess as _post
from .s5p import S5PConfig, s5p_partition

__all__ = [
    "hash_partition",
    "dbh_partition",
    "grid_partition",
    "greedy_partition",
    "hdrf_partition",
    "two_ps_partition",
    "clugp_partition",
    "PARTITIONERS",
]

_GOLD = np.uint32(0x9E3779B1)


def _hash32(x: jax.Array, seed: int = 0) -> jax.Array:
    h = x.astype(jnp.uint32) * jnp.uint32(_GOLD) ^ jnp.uint32(
        (seed * 0x85EBCA6B + 1) % (2**32))
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B3C6D)
    h = h ^ (h >> 12)
    return h


def hash_partition(src, dst, n_vertices, k, seed=0):
    eid = jnp.arange(src.shape[0], dtype=jnp.int32)
    return (_hash32(eid, seed) % jnp.uint32(k)).astype(jnp.int32)


def dbh_partition(src, dst, n_vertices, k, seed=0):
    """Degree-Based Hashing: cut the lower-degree endpoint."""
    deg = _cl.compute_degrees(src, dst, n_vertices)
    pick_src = deg[src] <= deg[dst]
    v = jnp.where(pick_src, src, dst)
    return (_hash32(v, seed) % jnp.uint32(k)).astype(jnp.int32)


def _grid_dims(k: int) -> tuple[int, int]:
    r = int(math.isqrt(k))
    while k % r:
        r -= 1
    return r, k // r


def grid_partition(src, dst, n_vertices, k, seed=0):
    """Grid/constrained candidate partitioning, sequential least-loaded pick."""
    r, c = _grid_dims(k)
    cell = (_hash32(jnp.arange(n_vertices, dtype=jnp.int32), seed) % jnp.uint32(k)).astype(
        jnp.int32
    )
    row = cell // c
    col = cell % c

    @partial(jax.jit, static_argnames=())
    def run(src, dst, row, col):
        def step(load, e):
            u, v = e
            # candidate set: grid intersection of u's row/col with v's —
            # cells (row_u, col_v) and (row_v, col_u); degenerate → own cell
            cand1 = row[u] * c + col[v]
            cand2 = row[v] * c + col[u]
            pick = jnp.where(load[cand1] <= load[cand2], cand1, cand2)
            valid = u != v
            load = load.at[pick].add(jnp.where(valid, 1, 0))
            return load, jnp.where(valid, pick, -1)

        return jax.lax.scan(step, jnp.zeros((k,), jnp.int32), (src, dst))

    _, parts = run(src, dst, row, col)
    return parts


def greedy_partition(src, dst, n_vertices, k, seed=0):
    """PowerGraph Greedy: 4-case replica-aware assignment."""

    @partial(jax.jit, static_argnames=())
    def run(src, dst):
        inf = jnp.int32(2**30)

        def step(carry, e):
            load, rep = carry  # rep: (V, k) bool replica bitmap
            u, v = e
            au = rep[u]
            av = rep[v]
            both = au & av
            either = au | av
            case1 = jnp.any(both)
            case2 = jnp.any(au) & jnp.any(av)
            case3 = jnp.any(either)
            # candidate mask per case; case4 = all partitions
            mask = jnp.where(
                case1, both, jnp.where(case2, either, jnp.where(case3, either, True))
            )
            score = jnp.where(mask, load, inf)
            pick = jnp.argmin(score).astype(jnp.int32)
            valid = u != v
            load = load.at[pick].add(jnp.where(valid, 1, 0))
            rep = rep.at[u, pick].max(valid)
            rep = rep.at[v, pick].max(valid)
            return (load, rep), jnp.where(valid, pick, -1)

        init = (jnp.zeros((k,), jnp.int32), jnp.zeros((n_vertices, k), jnp.bool_))
        (_, _), parts = jax.lax.scan(step, init, (src, dst))
        return parts

    return run(src, dst)


def hdrf_partition(src, dst, n_vertices, k, seed=0, lam: float = 1.1, eps: float = 1e-3):
    """High-Degree Replicated First (partial-degree variant, as published)."""

    @partial(jax.jit, static_argnames=())
    def run(src, dst):
        def step(carry, e):
            load, rep, pd = carry
            u, v = e
            pd = pd.at[u].add(1)
            pd = pd.at[v].add(1)
            du = pd[u].astype(jnp.float32)
            dv = pd[v].astype(jnp.float32)
            theta_u = du / (du + dv)
            theta_v = 1.0 - theta_u
            g_u = jnp.where(rep[u], 1.0 + (1.0 - theta_u), 0.0)  # (k,)
            g_v = jnp.where(rep[v], 1.0 + (1.0 - theta_v), 0.0)
            maxl = jnp.max(load).astype(jnp.float32)
            minl = jnp.min(load).astype(jnp.float32)
            bal = (maxl - load.astype(jnp.float32)) / (eps + maxl - minl)
            score = g_u + g_v + lam * bal
            pick = jnp.argmax(score).astype(jnp.int32)
            valid = u != v
            load = load.at[pick].add(jnp.where(valid, 1, 0))
            rep = rep.at[u, pick].max(valid)
            rep = rep.at[v, pick].max(valid)
            return (load, rep, pd), jnp.where(valid, pick, -1)

        init = (
            jnp.zeros((k,), jnp.int32),
            jnp.zeros((n_vertices, k), jnp.bool_),
            jnp.zeros((n_vertices,), jnp.int32),
        )
        (_, _, _), parts = jax.lax.scan(step, init, (src, dst))
        return parts

    return run(src, dst)


def two_ps_partition(src, dst, n_vertices, k, seed=0):
    """2PS-L-style: global-degree streaming clustering, then linear
    cluster placement (first-fit decreasing) + streaming second pass."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    E = int(src.shape[0])
    deg = _cl.compute_degrees(src, dst, n_vertices)
    kappa = max(int(math.ceil(2.0 * E / k)), 2)
    # xi = -1 ⇒ every edge is a 'head' edge ⇒ single global-degree table
    state = _cl.cluster_stream(src, dst, n_vertices, xi=-1, kappa=kappa)
    res = _cl.compact_clusters(state, deg, -1)
    c_of = res.v2c  # every vertex has a head cluster here
    # cluster sizes in edges (by source attribution)
    cu = c_of[src]
    cv = c_of[dst]
    csize = np.asarray(
        jax.ops.segment_sum(jnp.ones((E,), jnp.float32), jnp.maximum(cu, 0),
                            num_segments=max(res.n_clusters, 1))
    )
    # first-fit decreasing placement under capacity τ|E|/k
    cap = math.ceil(1.05 * E / k)
    order = np.argsort(-csize, kind="stable")
    c2p = np.zeros(max(res.n_clusters, 1), np.int32)
    loads = np.zeros(k, np.int64)
    for c in order:
        fits = loads + csize[c] <= cap
        p = int(np.argmax(fits)) if fits.any() else int(np.argmin(loads))
        c2p[c] = p
        loads[p] += csize[c]
    # streaming second pass: place each edge at the less-loaded endpoint
    # partition under the hard cap (reuses the Alg. 3 scan machinery)
    max_load = int(math.ceil(1.0 * E / k))
    parts, _ = _post.assign_edges_stream(
        src, dst, jnp.zeros((E,), jnp.bool_), jnp.maximum(cu, 0),
        jnp.maximum(cv, 0), jnp.asarray(c2p), k, max_load,
    )
    return parts


def clugp_partition(src, dst, n_vertices, k, seed=0):
    """CLUGP-style: local-degree clustering + one-stage simultaneous game.

    Realized as S5P with ``one_stage=True`` and ξ = ∞ (all edges take the
    local-degree tail path) — the clustering-refinement skeleton CLUGP
    shares, minus the Stackelberg (leader/follower) structure.
    """
    cfg = S5PConfig(k=k, beta=float(2**30), one_stage=True, use_cms=False, seed=seed)
    return s5p_partition(src, dst, n_vertices, cfg).parts


def _s5p(src, dst, n_vertices, k, seed=0):
    return s5p_partition(src, dst, n_vertices, S5PConfig(k=k, seed=seed)).parts


def _s5p_exact(src, dst, n_vertices, k, seed=0):
    return s5p_partition(
        src, dst, n_vertices, S5PConfig(k=k, use_cms=False, seed=seed)
    ).parts


PARTITIONERS = {
    "hash": hash_partition,
    "dbh": dbh_partition,
    "grid": grid_partition,
    "greedy": greedy_partition,
    "hdrf": hdrf_partition,
    "2ps-l": two_ps_partition,
    "clugp": clugp_partition,
    "s5p": _s5p,
    "s5p-exact": _s5p_exact,
}
