"""Streaming vertex-cut baselines the paper compares against (§6.2).

All are single-pass streaming partitioners over the same edge-stream
contract as S5P.  Scoring/sequential ones (Greedy, HDRF, Grid) run as
jitted ``lax.scan`` with O(k|V|) carry (the counted replica table — the
same asymptotics as their reference C++ implementations' bitmaps; the
int32 counters OR-project for scoring, identically, and additionally
support exact edge deletion via ``retract_chunk`` — see
``repro.kernels.stream_scan`` and ``repro.incremental``).  Hash/DBH are
one-shot vectorized.

- Hash:   p = h(eid) mod k                                    [random]
- DBH:    hash the lower-(global-)degree endpoint             [Xie et al. 2014]
- Grid:   candidate cells = row∪col of each endpoint's hashed
          cell; pick least-loaded intersection cell           [GraphBuilder 2013]
- Greedy: PowerGraph's 4-case replica-aware heuristic         [Gonzalez 2012]
- HDRF:   degree-weighted replica score + balance term        [Petroni 2015]
- 2PS-L-style: Holl-ish global-degree clustering + linear
          cluster placement + streaming refinement            [Mayer 2022]
- CLUGP-style: local-degree clustering + ONE-stage
          simultaneous cluster game + postprocess             [Kong 2022]

The 2PS-L / CLUGP entries are faithful *reimplementations of the published
algorithmsʼ structure* (clustering-refinement), not the authors' binaries;
they double as the paper's Fig. 7 ablations (CLUGP-style == S5P with
``one_stage`` game and local-degree-only clustering).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from . import clustering as _cl
from . import postprocess as _post
from .s5p import S5PConfig, s5p_partition
from ..kernels import stream_scan as _scan
from ..streaming import as_stream, run_parallel, run_scan_batched

__all__ = [
    "hash_partition",
    "dbh_partition",
    "grid_partition",
    "greedy_partition",
    "hdrf_partition",
    "hdrf_partition_batched",
    "grid_partition_multi_seed",
    "two_ps_partition",
    "clugp_partition",
    "PARTITIONERS",
]

_GOLD = np.uint32(0x9E3779B1)


def _hash32(x: jax.Array, seed: int = 0) -> jax.Array:
    h = x.astype(jnp.uint32) * jnp.uint32(_GOLD) ^ jnp.uint32(
        (seed * 0x85EBCA6B + 1) % (2**32))
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B3C6D)
    h = h ^ (h >> 12)
    return h


def hash_partition(src, dst, n_vertices, k, seed=0):
    eid = jnp.arange(src.shape[0], dtype=jnp.int32)
    return (_hash32(eid, seed) % jnp.uint32(k)).astype(jnp.int32)


def dbh_partition(src, dst, n_vertices, k, seed=0):
    """Degree-Based Hashing: cut the lower-degree endpoint."""
    deg = _cl.compute_degrees(src, dst, n_vertices)
    pick_src = deg[src] <= deg[dst]
    v = jnp.where(pick_src, src, dst)
    return (_hash32(v, seed) % jnp.uint32(k)).astype(jnp.int32)


def _grid_dims(k: int) -> tuple[int, int]:
    r = int(math.isqrt(k))
    while k % r:
        r -= 1
    return r, k // r


def _grid_rowcol(n_vertices, k, c, seed):
    cell = (_hash32(jnp.arange(n_vertices, dtype=jnp.int32), seed) % jnp.uint32(k)).astype(
        jnp.int32
    )
    return cell // c, cell % c


def grid_partition(src, dst, n_vertices, k, seed=0, *, stream=None,
                   chunk_size=None, num_streams=1, super_chunk=8,
                   shard="range"):
    """Grid/constrained candidate partitioning, sequential least-loaded pick.

    Candidate set: grid intersection of u's row/col with v's — cells
    (row_u, col_v) and (row_v, col_u); degenerate → own cell.
    """
    _, c = _grid_dims(k)
    row, col = _grid_rowcol(n_vertices, k, c, seed)
    st = as_stream(src, dst, n_vertices, stream=stream, chunk_size=chunk_size)
    parts, _ = run_parallel(st, _scan.GridCarry(k, row, col, c),
                            num_streams=num_streams, super_chunk=super_chunk,
                            shard=shard)
    return parts


def grid_partition_multi_seed(src, dst, n_vertices, k, seeds, *, stream=None,
                              chunk_size=None):
    """Vmapped multi-seed grid: one compiled engine, |seeds| scenarios.

    Returns (len(seeds), E) parts — each row identical to
    ``grid_partition(..., seed=s)``.
    """
    _, c = _grid_dims(k)
    carries = [_scan.grid_init(k, *_grid_rowcol(n_vertices, k, c, s), c) for s in seeds]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
    st = as_stream(src, dst, n_vertices, stream=stream, chunk_size=chunk_size)
    parts, _ = run_scan_batched(st, stacked, _scan.grid_chunk)
    return parts


def greedy_partition(src, dst, n_vertices, k, seed=0, *, stream=None,
                     chunk_size=None, use_kernel=None, vmem_budget=None,
                     num_streams=1, super_chunk=8, shard="range"):
    """PowerGraph Greedy: 4-case replica-aware assignment."""
    st = as_stream(src, dst, n_vertices, stream=stream, chunk_size=chunk_size)
    pc = _scan.GreedyCarry(n_vertices, k, use_kernel=use_kernel,
                           vmem_budget=vmem_budget)
    parts, _ = run_parallel(st, pc, num_streams=num_streams,
                            super_chunk=super_chunk, shard=shard)
    return parts


def hdrf_partition(src, dst, n_vertices, k, seed=0, lam: float = 1.1, *,
                   stream=None, chunk_size=None, use_kernel=None,
                   vmem_budget=None, num_streams=1, super_chunk=8,
                   shard="range"):
    """High-Degree Replicated First (partial-degree variant, as published)."""
    st = as_stream(src, dst, n_vertices, stream=stream, chunk_size=chunk_size)
    pc = _scan.HdrfCarry(n_vertices, k, lam, use_kernel=use_kernel,
                         vmem_budget=vmem_budget)
    parts, _ = run_parallel(st, pc, num_streams=num_streams,
                            super_chunk=super_chunk, shard=shard)
    return parts


def hdrf_partition_batched(src, dst, n_vertices, ks, lams=None, *,
                           stream=None, chunk_size=None):
    """Vmapped multi-scenario HDRF: a batch over partition counts (padded
    to max(ks), inactive lanes masked out of the argmax) and optionally λ
    values (``lams[i]`` per scenario; default 1.1 — sweep λ at fixed k by
    passing ``ks=[k]*len(lams)``).

    Returns (B, E) parts where B = len(ks); scenario i uses ``ks[i]``
    partitions and ``lams[i]``.  One compiled engine serves the whole
    batch — the multi-k / multi-λ sweep of the paper's Fig. 12 in a
    single stream pass.
    """
    if not ks:
        raise ValueError("ks must name at least one partition count")
    if lams is None:
        lams = [1.1] * len(ks)
    if len(ks) != len(lams):
        raise ValueError("ks and lams length mismatch")
    kmax = max(ks)
    carries = [
        _scan.hdrf_init(n_vertices, kmax, lam, k_active=k)
        for k, lam in zip(ks, lams)
    ]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
    st = as_stream(src, dst, n_vertices, stream=stream, chunk_size=chunk_size)
    parts, _ = run_scan_batched(st, stacked, _scan.hdrf_chunk)
    return parts


def two_ps_partition(src, dst, n_vertices, k, seed=0):
    """2PS-L-style: global-degree streaming clustering, then linear
    cluster placement (first-fit decreasing) + streaming second pass."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    E = int(src.shape[0])
    deg = _cl.compute_degrees(src, dst, n_vertices)
    kappa = max(int(math.ceil(2.0 * E / k)), 2)
    # xi = -1 ⇒ every edge is a 'head' edge ⇒ single global-degree table
    state = _cl.cluster_stream(src, dst, n_vertices, xi=-1, kappa=kappa)
    res = _cl.compact_clusters(state, deg, -1)
    c_of = res.v2c  # every vertex has a head cluster here
    # cluster sizes in edges (by source attribution)
    cu = c_of[src]
    cv = c_of[dst]
    csize = np.asarray(
        jax.ops.segment_sum(jnp.ones((E,), jnp.float32), jnp.maximum(cu, 0),
                            num_segments=max(res.n_clusters, 1))
    )
    # first-fit decreasing placement under capacity τ|E|/k
    cap = math.ceil(1.05 * E / k)
    order = np.argsort(-csize, kind="stable")
    c2p = np.zeros(max(res.n_clusters, 1), np.int32)
    loads = np.zeros(k, np.int64)
    for c in order:
        fits = loads + csize[c] <= cap
        p = int(np.argmax(fits)) if fits.any() else int(np.argmin(loads))
        c2p[c] = p
        loads[p] += csize[c]
    # streaming second pass: place each edge at the less-loaded endpoint
    # partition under the hard cap (reuses the Alg. 3 scan machinery)
    max_load = int(math.ceil(1.0 * E / k))
    parts, _ = _post.assign_edges_stream(
        src, dst, jnp.zeros((E,), jnp.bool_), jnp.maximum(cu, 0),
        jnp.maximum(cv, 0), jnp.asarray(c2p), k, max_load,
    )
    return parts


def clugp_partition(src, dst, n_vertices, k, seed=0):
    """CLUGP-style: local-degree clustering + one-stage simultaneous game.

    Realized as S5P with ``one_stage=True`` and ξ = ∞ (all edges take the
    local-degree tail path) — the clustering-refinement skeleton CLUGP
    shares, minus the Stackelberg (leader/follower) structure.
    """
    cfg = S5PConfig(k=k, beta=float(2**30), one_stage=True, use_cms=False, seed=seed)
    return s5p_partition(src, dst, n_vertices, cfg).parts


def _s5p(src, dst, n_vertices, k, seed=0, *, stream=None, chunk_size=None,
         num_streams=1, super_chunk=8, shard="range"):
    cfg = S5PConfig(k=k, seed=seed, chunk_size=chunk_size or 1 << 16,
                    num_streams=num_streams, super_chunk=super_chunk,
                    shard=shard)
    return s5p_partition(src, dst, n_vertices, cfg, stream=stream).parts


def _s5p_exact(src, dst, n_vertices, k, seed=0, *, stream=None,
               chunk_size=None, num_streams=1, super_chunk=8, shard="range"):
    cfg = S5PConfig(k=k, use_cms=False, seed=seed,
                    chunk_size=chunk_size or 1 << 16,
                    num_streams=num_streams, super_chunk=super_chunk,
                    shard=shard)
    return s5p_partition(src, dst, n_vertices, cfg, stream=stream).parts


PARTITIONERS = {
    "hash": hash_partition,
    "dbh": dbh_partition,
    "grid": grid_partition,
    "greedy": greedy_partition,
    "hdrf": hdrf_partition,
    "2ps-l": two_ps_partition,
    "clugp": clugp_partition,
    "s5p": _s5p,
    "s5p-exact": _s5p_exact,
}
