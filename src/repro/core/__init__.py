"""S5P core: the paper's contribution (clustering + Stackelberg game)."""

from .cms import CMSketch, make_sketch, cms_update, cms_query, cms_merge, pair_key  # noqa: F401
from .clustering import (  # noqa: F401
    cluster_stream,
    cluster_chunk,
    compact_clusters,
    compute_degrees,
    reference_cluster_python,
)
from .game import GameInputs, GameResult, run_game, best_response_gap  # noqa: F401
from .postprocess import assign_edges, assign_edges_stream  # noqa: F401
from .s5p import S5PConfig, S5POutput, s5p_partition  # noqa: F401
from .metrics import (  # noqa: F401
    replication_factor,
    load_balance,
    partition_loads,
    gas_comm_bytes,
)
from .baselines import PARTITIONERS  # noqa: F401
