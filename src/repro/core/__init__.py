"""S5P core: the paper's contribution (clustering + Stackelberg game)."""

from .cms import (  # noqa: F401
    CMSketch,
    SketchCarry,
    cms_merge,
    cms_query,
    cms_update,
    make_sketch,
    pair_key,
)
from .clustering import (  # noqa: F401
    ClusterCarry,
    DegreeCarry,
    cluster_stream,
    cluster_chunk,
    compact_clusters,
    compute_degrees,
    reference_cluster_python,
)
from .game import GameInputs, GameResult, run_game, best_response_gap  # noqa: F401
from .postprocess import AssignCarry, assign_edges, assign_edges_stream  # noqa: F401
from .s5p import S5PConfig, S5POutput, s5p_partition  # noqa: F401
from .metrics import (  # noqa: F401
    replication_factor,
    load_balance,
    partition_loads,
    gas_comm_bytes,
)
from .baselines import PARTITIONERS  # noqa: F401
