"""S5P — Skewness-aware Streaming Vertex-cut Partitioner (the paper's system).

Pipeline (paper Fig. 2):

  edge stream ──Alg.1──▶ head/tail clusters ──Alg.2──▶ cluster→partition
              ──Alg.3──▶ edge→partition  (+ RF / balance metrics)

Variants exposed here:
- ``S5P``            — the full system (CMS-backed Θ counts by default);
- ``S5P (exact Θ)``  — red-black-tree-equivalent exact counts (Fig. 9 ablation);
- ``S5P-B``          — bounded variant of §5.3 (global degrees everywhere,
                       no κ cap, no maxLoad) with the Theorem-2 RF bound;
- ``one_stage=True`` — single-stage simultaneous game (Fig. 7d ablation).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import clustering as _cl
from . import game as _game
from . import postprocess as _post
from .cms import CMSketch, SketchCarry, cms_query, pair_key, suggest_params
from .. import streaming as _stream

__all__ = ["S5PConfig", "S5POutput", "s5p_partition", "cluster_statistics"]

_INT32_MAX = 2**31 - 1


@dataclasses.dataclass(frozen=True)
class S5PConfig:
    k: int
    tau: float = 1.0  # balance threshold (paper uses 1.0)
    beta: float = 1.0  # ξ = β · avg_degree (paper recommends β = 1)
    use_cms: bool = True
    cms_epsilon: float = 0.1
    cms_nu: float = 0.01
    game_batch_size: int = 256
    game_max_rounds: int = 96
    # 0.9 damping converges to measurably better equilibria than the seed's
    # 0.7 (multi-seed mean RF beats HDRF on community graphs — Table 3)
    game_accept_prob: float = 0.9
    chunk_size: int = 1 << 16
    ordering: str = "natural"  # EdgeStream arrival order (§6.5 robustness)
    bounded: bool = False  # S5P-B (§5.3)
    one_stage: bool = False  # Fig. 7d ablation: no leader/follower split
    seed: int = 0
    # parallel ingest (HEP/CuSP regime): S sharded sub-streams per pass,
    # carry all-reduced every super_chunk chunks; 1 = sequential (exact).
    # super_chunk may be "auto" (adaptive merge cadence) and shard picks
    # the lane layout ("range" / "round-robin" / "hub" — hub-pinned edge
    # routing, the quality-neutral mode); see streaming.parallel.
    num_streams: int = 1
    super_chunk: int | str = 8
    shard: str = "range"
    # post-ingest touch-up (S > 1 only): one bounded masked-game pass over
    # clusters whose membership was written by ≥ 2 lanes, re-placing only
    # the moved clusters' edges (budget = refine_rounds)
    touch_up: bool = True
    # incremental re-partitioning (repro.incremental): relative RF /
    # absolute balance drift past which a delta triggers game refinement,
    # and the refinement budget in Stackelberg rounds (0 disables)
    drift_rf_threshold: float = 0.05
    drift_balance_threshold: float = 0.10
    refine_rounds: int = 16
    # decremental churn: fraction of live edges retracted (deleted or
    # window-expired) since the last baseline that triggers refinement
    # even when RF has not visibly drifted — retraction leaves the
    # approximate cluster volumes behind regardless of the RF signal
    drift_churn_threshold: float = 0.25
    # full-refresh policy: relative drift of the frozen ξ (or κ) from the
    # values a cold run over the live graph would choose, past which the
    # warm chain raises needs_cold_restart (advisory — see drift.py)
    xi_refresh_threshold: float = 0.5
    # megakernel dispatch: None = auto (fused Pallas path on TPU, oracle
    # scan elsewhere); vmem_budget overrides the fused/tiled/oracle ladder
    # gate (falls back to REPRO_VMEM_BUDGET env, then 8 MiB)
    use_kernel: bool | None = None
    vmem_budget: int | None = None
    # hybrid memory-budget mode (repro.hybrid): host bytes the partitioner
    # may spend on a resident high-degree core (HEP regime).  None/0 keeps
    # the pure-streaming pipeline; run_hybrid's host_budget= overrides.
    host_budget: int | None = None


@dataclasses.dataclass
class S5POutput:
    parts: jax.Array  # (E,) int32 edge → partition
    k: int
    n_clusters: int
    n_head_clusters: int
    game_rounds: int
    game_converged: bool
    xi: int
    kappa: int
    max_load: int
    cluster_assignment: np.ndarray  # (C,) cluster → partition
    timings: dict[str, float]
    aux: dict[str, Any]


def _edge_clusters(src, dst, res: _cl.ClusterResult, degrees, xi):
    """Per-edge (cu, cv, is_head_edge) from the compacted tables."""
    is_head = (degrees[src] > xi) & (degrees[dst] > xi)
    cu = jnp.where(is_head, res.v2c_h[src], res.v2c_t[src])
    cv = jnp.where(is_head, res.v2c_h[dst], res.v2c_t[dst])
    return cu, cv, is_head


def cluster_statistics(
    src,
    dst,
    res: _cl.ClusterResult,
    degrees,
    xi: int,
    *,
    use_cms: bool,
    cms_epsilon: float,
    cms_nu: float,
    seed: int,
    chunk_size: int = 1 << 18,
    num_streams: int = 1,
    super_chunk: int = 8,
):
    """Stream pass 2: cluster sizes + inter-cluster adjacency Θ.

    Sizes: an internal edge (cu == cv) contributes 1 to its cluster; a
    boundary edge contributes ½ to each side (postprocess will place it at
    one of the two — ½ is its expectation, keeping Σ|c| = |E|).

    Θ counts: streamed into a count-min sketch (paper §4.4) or kept exact.
    The *structural* pair list (which clusters are adjacent) is deduped
    host-side; CMS replaces only the count storage — the paper's claim (and
    our Fig. 9 benchmark) is about count-map memory, which dominates.

    Cross-type adjacency: a head vertex belongs to *both* a head cluster and
    (if it ever appears in a tail edge) a tail cluster.  An edge spans every
    pair of endpoint memberships (paper §4.3's Θ over C_H ∪ C_T) — this is
    the channel through which leader (head-cluster) moves steer followers;
    without it the two stages of the Stackelberg game would decouple.
    """
    C = res.n_clusters
    cu, cv, is_head = _edge_clusters(src, dst, res, degrees, xi)
    valid = src != dst
    internal = (cu == cv) & valid
    boundary = (cu != cv) & valid

    sizes = jax.ops.segment_sum(
        jnp.where(internal, 1.0, 0.0), jnp.maximum(cu, 0), num_segments=C
    )
    sizes = sizes + jax.ops.segment_sum(
        jnp.where(boundary, 0.5, 0.0), jnp.maximum(cu, 0), num_segments=C
    )
    sizes = sizes + jax.ops.segment_sum(
        jnp.where(boundary, 0.5, 0.0), jnp.maximum(cv, 0), num_segments=C
    )

    # membership cross-product pairs: primary (cu, cv) + the other-type
    # memberships of each endpoint (−1 ⇒ absent)
    hu, hv = res.v2c_h[src], res.v2c_h[dst]
    tu, tv = res.v2c_t[src], res.v2c_t[dst]
    alt_u = jnp.where(is_head, tu, hu)  # u's membership in the *other* table
    alt_v = jnp.where(is_head, tv, hv)
    pair_sets = [
        (cu, cv, valid),
        (alt_u, cv, valid & (alt_u >= 0)),
        (cu, alt_v, valid & (alt_v >= 0)),
    ]
    a_parts, b_parts = [], []
    for a, b, ok in pair_sets:
        ok = ok & (a != b) & (a >= 0) & (b >= 0)
        a_parts.append(np.asarray(jnp.where(ok, jnp.minimum(a, b), C)))
        b_parts.append(np.asarray(jnp.where(ok, jnp.maximum(a, b), C)))
    a_np = np.concatenate(a_parts)
    b_np = np.concatenate(b_parts)
    keys = a_np.astype(np.int64) * (C + 1) + b_np
    uniq, counts = np.unique(keys[a_np < C], return_counts=True)
    pa = (uniq // (C + 1)).astype(np.int32)
    pb = (uniq % (C + 1)).astype(np.int32)

    sketch_mem = 0
    if use_cms:
        w, d = suggest_params(cms_epsilon, cms_nu)
        # the Θ pass is itself an EdgeStream (over cluster-pair ids) driven
        # by a SketchCarry; the sketch is linear, so parallel ingest of the
        # pair stream merges exactly (table SUM)
        pair_stream = _stream.EdgeStream(
            a_np[a_np < C], b_np[a_np < C], C + 1, chunk_size=chunk_size
        )
        theta = SketchCarry(w * max(1, int(math.sqrt(C))), d, seed=seed)
        # the pair stream always shards by range: the sketch is linear, so
        # lane merges are exact regardless of routing — hub pinning buys
        # nothing here and would re-sketch degrees of cluster-pair ids
        _, sketch = _stream.run_parallel(
            pair_stream, theta, num_streams=num_streams,
            super_chunk=super_chunk)
        pw = cms_query(sketch, pair_key(jnp.asarray(pa), jnp.asarray(pb))).astype(jnp.float32)
        sketch_mem = sketch.memory_bytes()
    else:
        sketch = None
        pw = jnp.asarray(counts, jnp.float32)

    exact_mem = int(uniq.size) * (8 + 4)  # RBT-equivalent: key + count per pair
    return sizes, jnp.asarray(pa), jnp.asarray(pb), pw, {
        "n_pairs": int(uniq.size),
        "sketch_bytes": sketch_mem,
        "exact_count_bytes": exact_mem,
        "counts_exact": counts,
        "sketch": sketch,
    }


def s5p_partition(src, dst, n_vertices: int, config: S5PConfig,
                  stream: "_stream.EdgeStream | None" = None) -> S5POutput:
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    E = int(src.shape[0])
    k = config.k
    timings: dict[str, float] = {}

    # one EdgeStream, replayed by every pass (Fig. 2's single-stream pipeline)
    if stream is None:
        stream = _stream.EdgeStream(
            src, dst, n_vertices, chunk_size=config.chunk_size,
            ordering=config.ordering, seed=config.seed,
        )

    degrees = _cl.compute_degrees(src, dst, n_vertices)
    avg_deg = 2.0 * E / max(n_vertices, 1)
    xi = min(int(config.beta * avg_deg), _INT32_MAX - 1)
    kappa = _INT32_MAX if config.bounded else max(int(math.ceil(2.0 * E / k)), 2)

    # ---- Phase 1: skewness-aware streaming clustering (Alg. 1) ----
    t0 = time.perf_counter()
    state = _cl.cluster_stream(
        src, dst, n_vertices, xi=xi, kappa=kappa,
        global_tail=config.bounded, stream=stream,
        num_streams=config.num_streams, super_chunk=config.super_chunk,
        shard=config.shard,
        use_kernel=config.use_kernel, vmem_budget=config.vmem_budget,
    )
    res = _cl.compact_clusters(state, degrees, xi)
    timings["clustering"] = time.perf_counter() - t0

    if res.n_clusters == 0:  # degenerate: no valid edges
        return S5POutput(
            parts=jnp.full((E,), -1, jnp.int32), k=k, n_clusters=0,
            n_head_clusters=0, game_rounds=0, game_converged=True, xi=xi,
            kappa=kappa, max_load=0, cluster_assignment=np.zeros(0, np.int32),
            timings=timings, aux={},
        )

    # ---- Phase 2: Stackelberg game (Alg. 2) ----
    t0 = time.perf_counter()
    sizes, pa, pb, pw, stats = cluster_statistics(
        src, dst, res, degrees, xi,
        use_cms=config.use_cms, cms_epsilon=config.cms_epsilon,
        cms_nu=config.cms_nu, seed=config.seed,
        num_streams=config.num_streams, super_chunk=config.super_chunk,
    )
    n_head = res.n_clusters if config.one_stage else res.n_head
    inputs = _game.GameInputs(
        sizes=sizes.astype(jnp.float32), pair_a=pa, pair_b=pb,
        pair_w=pw.astype(jnp.float32), n_head=n_head, k=k,
    )
    bs = _game.default_batch_size(config.game_batch_size, res.n_clusters)
    game = _game.run_game(
        inputs, res.n_clusters,
        batch_size=bs, max_rounds=config.game_max_rounds,
        accept_prob=config.game_accept_prob, seed=config.seed,
    )
    timings["game"] = time.perf_counter() - t0

    # ---- Phase 3: postprocess (Alg. 3) ----
    t0 = time.perf_counter()
    max_load = _INT32_MAX if config.bounded else int(math.ceil(config.tau * E / k))
    cu, cv, is_head = _edge_clusters(src, dst, res, degrees, xi)
    parts, load = _post.assign_edges_stream(
        src, dst, is_head, jnp.maximum(cu, 0), jnp.maximum(cv, 0),
        game.assignment, k, max_load, stream=stream,
        num_streams=config.num_streams, super_chunk=config.super_chunk,
        shard=config.shard,
        use_kernel=config.use_kernel, vmem_budget=config.vmem_budget,
    )
    timings["postprocess"] = time.perf_counter() - t0
    ingest = _stream.last_ingest_stats()  # the placement pass's drive
    if ingest is not None:
        stats["parallel_ingest"] = ingest.as_dict()

    # ---- post-ingest touch-up (parallel quality recovery) ----
    c2p = np.asarray(game.assignment)
    if (config.num_streams > 1 and config.touch_up
            and config.refine_rounds > 0 and res.n_clusters > 1):
        t0 = time.perf_counter()
        parts, load, c2p, tu_stats = _touch_up(
            src, dst, n_vertices, config, stream, res, inputs, bs,
            cu, cv, is_head, sizes, parts, load, c2p, k, max_load)
        timings["touch_up"] = time.perf_counter() - t0
        stats["touch_up"] = tu_stats

    # pipeline internals for warm starts (repro.incremental builds its
    # carry bundle from these instead of re-deriving them): O(|V| + C + P
    # + k) state, no per-edge arrays beyond what parts already is
    stats["incremental"] = {
        "cluster_state": state,
        "degrees": degrees,
        "compact": res,
        "sizes": sizes,
        "pair_a": pa,
        "pair_b": pb,
        "pair_w": pw,
        "load": load,
    }

    return S5POutput(
        parts=parts,
        k=k,
        n_clusters=res.n_clusters,
        n_head_clusters=res.n_head,
        game_rounds=int(game.rounds),
        game_converged=bool(game.converged),
        xi=xi,
        kappa=kappa,
        max_load=max_load,
        cluster_assignment=c2p,
        timings=timings,
        aux=stats,
    )


def _touch_up(src, dst, n_vertices, config, stream, res, inputs, bs,
              cu, cv, is_head, sizes, parts, load, c2p, k, max_load):
    """One bounded masked-game pass over the clusters whose membership was
    written by ≥ 2 ingest lanes — the only clusters whose carry state could
    have gone stale across lanes — then re-place exactly those clusters'
    edges (the ``_refine_pass`` recipe of ``repro.incremental``): lift the
    moved edges out of the load vector and replay them in arrival order
    against the refined cluster→partition table."""
    C = res.n_clusters
    # provenance: which lane folded each edge (the plan is deterministic,
    # so rebuilding it gives exactly the lanes the ingest used — no need
    # to have carried per-edge lane ids through the passes)
    ps = _stream.ParallelEdgeStream(stream, config.num_streams,
                                    shard=config.shard)
    lanes = ps.edge_lanes()
    cu_np = np.asarray(cu)
    cv_np = np.asarray(cv)
    valid = np.asarray(src != dst)
    c_all = np.concatenate([cu_np[valid], cv_np[valid]])
    l_all = np.concatenate([lanes[valid], lanes[valid]])
    ok = c_all >= 0
    mn = np.full(C, np.iinfo(np.int32).max, np.int64)
    mx = np.full(C, -1, np.int64)
    np.minimum.at(mn, c_all[ok], l_all[ok])
    np.maximum.at(mx, c_all[ok], l_all[ok])
    contested = (mx > mn)  # touched by ≥ 2 lanes
    move_mask = contested & (np.asarray(sizes) > 0)
    stats = {"contested_clusters": int(contested.sum()), "moved_clusters": 0,
             "replayed_edges": 0, "rounds": 0}
    if not move_mask.any():
        return parts, load, c2p, stats
    refined = _game.run_game(
        inputs, C, batch_size=bs, max_rounds=config.refine_rounds,
        accept_prob=config.game_accept_prob, assign0=jnp.asarray(c2p),
        seed=config.seed + 1,
        leader_mask=np.arange(C) < inputs.n_head,
        move_mask=move_mask,
    )
    stats["rounds"] = int(refined.rounds)
    c2p_new = np.asarray(refined.assignment)
    moved = np.flatnonzero(c2p_new != c2p)
    stats["moved_clusters"] = int(moved.size)
    if not moved.size:
        return parts, load, c2p, stats
    moved_mask = np.zeros(C, bool)
    moved_mask[moved] = True
    aff = valid & (moved_mask[np.maximum(cu_np, 0)]
                   | moved_mask[np.maximum(cv_np, 0)])
    aidx = np.flatnonzero(aff)
    stats["replayed_edges"] = int(aidx.size)
    parts_np = np.asarray(parts).copy()
    load64 = np.asarray(load).astype(np.int64)
    np.subtract.at(load64, parts_np[aidx], 1)
    re_stream = _stream.EdgeStream(
        np.asarray(src)[aidx], np.asarray(dst)[aidx], n_vertices,
        chunk_size=config.chunk_size)
    ac = _post.AssignCarry(k, max_load, jnp.asarray(c2p_new),
                           use_kernel=config.use_kernel,
                           vmem_budget=config.vmem_budget)
    re_parts, load = _stream.run_carry(
        re_stream, ac,
        jnp.asarray(np.asarray(is_head)[aidx]),
        jnp.asarray(np.maximum(cu_np[aidx], 0)),
        jnp.asarray(np.maximum(cv_np[aidx], 0)),
        carry=jnp.asarray(load64.astype(np.int32)))
    parts_np[aidx] = np.asarray(re_parts)
    return jnp.asarray(parts_np), load, c2p_new, stats
