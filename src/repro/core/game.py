"""Two-stage Stackelberg game for cluster→partition assignment (Alg. 2).

Players are the head/tail clusters produced by Algorithm 1.  Each round has
two stages: **leaders** (head clusters) best-respond first, then
**followers** (tail clusters), per the two-stage Stackelberg structure.
Best-response dynamics run until a pure Nash equilibrium (no player moves)
or ``max_rounds``.

Cost of cluster i choosing partition p (paper Eq. 6):

    S_i(p) = (δ/k)·|c_i|·|p| + (F_i(p) + |c_i|)/k
    F_i(p) = Σ_j Θ(c_i, c_j)·1[p ≠ P(c_j)]  =  deg_i − W[i, p]
    W[i, p] = Σ_{j : P(c_j)=p} Θ(c_i, c_j)

TPU adaptation (DESIGN.md §2): the paper parallelizes best responses over
*batches of clusters* with a thread pool; we realize the identical batch
semantics as **vectorized argmin over the cluster axis** — one (batch × k)
cost matrix per batch, with ``W`` recomputed from the cluster-adjacency
edge list by scatter-add.  Within a batch all players move simultaneously
(as in the paper); across batches moves are sequential.  The whole game is
a single jitted ``lax.while_loop``.

Θ counts come either from the exact cluster-adjacency weights or from a
count-min sketch query (paper §4.4) — the caller chooses (see s5p.py).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "GameInputs",
    "GameResult",
    "init_assignment",
    "compute_delta",
    "default_batch_size",
    "run_game",
    "best_response_gap",
]


def default_batch_size(requested: int, n_clusters: int) -> int:
    """Clamp a requested game batch to ≲ C/8 (floor 16): near-simultaneous
    sweeps over a small player set cycle — the potential argument needs
    mostly-sequential moves.  One policy shared by the cold pipeline and
    the incremental settle/refine games so warm dynamics match cold."""
    return max(16, min(int(requested), n_clusters // 8))


class GameInputs(NamedTuple):
    sizes: jax.Array  # (C,) float32 — edge-volume of each cluster
    pair_a: jax.Array  # (P,) int32 — cluster adjacency: endpoint a
    pair_b: jax.Array  # (P,) int32 — endpoint b (a < b; padded rows a=b=C_pad)
    pair_w: jax.Array  # (P,) float32 — Θ(a, b) (exact or CMS estimate)
    n_head: int  # leaders are cluster ids [0, n_head)
    k: int


class GameResult(NamedTuple):
    assignment: jax.Array  # (C,) int32 cluster → partition
    rounds: jax.Array  # () int32 rounds until convergence
    converged: jax.Array  # () bool


def init_assignment(sizes: np.ndarray, k: int) -> np.ndarray:
    """Deterministic size-balanced initialization: snake round-robin over
    clusters sorted by size descending (a 4/3-approx of makespan — a strong,
    cheap start consistent with the paper's 'initial partitioning')."""
    order = np.argsort(-np.asarray(sizes), kind="stable")
    assign = np.empty(order.size, np.int32)
    lane = np.arange(order.size) % (2 * k)
    snake = np.where(lane < k, lane, 2 * k - 1 - lane)
    assign[order] = snake.astype(np.int32)
    return assign


def compute_delta(sizes: jax.Array, degs: jax.Array, k: int) -> jax.Array:
    """δ_max of paper Eq. (12): k·Σ(F(c_i)+|c_i|) / (Σ|c_i|)² — the upper end
    of the admissible normalization range (the paper uses the maximum)."""
    num = k * jnp.sum(degs + sizes)
    den = jnp.square(jnp.sum(sizes))
    return num / jnp.maximum(den, 1.0)


def _cluster_degrees(inputs: GameInputs, n_clusters: int) -> jax.Array:
    """deg_i = Σ_j Θ(i, j): total inter-cluster edge weight per cluster."""
    deg = jax.ops.segment_sum(inputs.pair_w, inputs.pair_a, num_segments=n_clusters + 1)
    deg = deg + jax.ops.segment_sum(inputs.pair_w, inputs.pair_b, num_segments=n_clusters + 1)
    return deg[:n_clusters]


def _neighbor_partition_weight(inputs: GameInputs, assign: jax.Array, n_clusters: int) -> jax.Array:
    """W[i, p] = Σ_{j: P(j)=p} Θ(i, j), via two scatter-adds over the pair list."""
    k = inputs.k
    pad = n_clusters  # padded pairs point at the sink row
    a = jnp.minimum(inputs.pair_a, pad)
    b = jnp.minimum(inputs.pair_b, pad)
    assign_ext = jnp.concatenate([assign, jnp.zeros((1,), jnp.int32)])
    w = jnp.zeros((n_clusters + 1, k), jnp.float32)
    w = w.at[a, assign_ext[b]].add(inputs.pair_w)
    w = w.at[b, assign_ext[a]].add(inputs.pair_w)
    return w[:n_clusters]


def _batch_update(inputs, degs, assign, active, key, dk, inv_k, accept_prob,
                  n_clusters, move_pen=None):
    """Best response for ``active`` clusters (one simultaneous batch).

    Within a batch moves are simultaneous (the paper's batch parallelism).
    Simultaneous moves can cycle — S(Λ) is an *exact potential* only for
    unilateral deviations — so each improving move is accepted with
    probability ``accept_prob`` (ε-damped best response, a.s. convergent
    in potential games).  ``wanted`` tracks whether anyone had an
    improving move at all: the equilibrium test.

    ``move_pen`` (C, k), when given, is added to the cost matrix — the
    elastic-resharding migration penalty (zero on each cluster's home
    partition, so staying put is never taxed).  Adding a
    strategy-dependent constant keeps S an exact potential, so the
    convergence argument is unchanged.
    """
    sizes, k = inputs.sizes, inputs.k
    w_ip = _neighbor_partition_weight(inputs, assign, n_clusters)  # (C, k)
    part_sizes = jax.ops.segment_sum(sizes, assign, num_segments=k)  # (k,)
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    # hypothetical |p| if i moved to p: current size + s_i when p ≠ P_i
    hyp = part_sizes[None, :] + sizes[:, None] * (1.0 - onehot)
    cost = dk * sizes[:, None] * hyp + (degs[:, None] - w_ip + sizes[:, None]) * inv_k
    if move_pen is not None:
        cost = cost + move_pen
    # deterministic tie-breaking: the current partition wins cost ties
    # (no churn between equal-cost strategies), remaining ties go to the
    # lowest partition id — best responses are a pure function of state
    cur = jnp.take_along_axis(cost, assign[:, None], axis=1)[:, 0]
    strictly_better = jnp.min(cost, axis=1) < cur
    best = jnp.where(
        strictly_better, jnp.argmin(cost, axis=1).astype(jnp.int32), assign
    )
    improves = active & (best != assign) & strictly_better
    lucky = jax.random.uniform(key, (n_clusters,)) < accept_prob
    new_assign = jnp.where(improves & lucky, best, assign)
    wanted = jnp.any(improves)
    moved = jnp.any(new_assign != assign)
    return new_assign, moved, wanted


@partial(
    jax.jit,
    static_argnames=("n_clusters", "n_head", "k", "batch_size", "max_rounds"),
)
def _run_game_jit(
    sizes,
    pair_a,
    pair_b,
    pair_w,
    assign0,
    delta,
    accept_prob,
    seed,
    *,
    n_clusters: int,
    n_head: int,
    k: int,
    batch_size: int,
    max_rounds: int,
):
    inputs = GameInputs(sizes, pair_a, pair_b, pair_w, n_head, k)
    degs = _cluster_degrees(inputs, n_clusters)
    cid = jnp.arange(n_clusters, dtype=jnp.int32)
    is_leader = cid < n_head
    n_batches_h = max(1, -(-n_head // batch_size))
    n_tail = n_clusters - n_head
    n_batches_t = max(1, -(-n_tail // batch_size))
    inv_k = 1.0 / k
    dk = delta * inv_k
    key0 = jax.random.PRNGKey(seed)

    def batch_update(assign, active, key):
        return _batch_update(inputs, degs, assign, active, key, dk, inv_k,
                             accept_prob, n_clusters)

    def stage(assign, moved, wanted, key, role_mask, n_batches, offset):
        def body(b, carry):
            assign, moved, wanted = carry
            lo = offset + b * batch_size
            in_batch = (cid >= lo) & (cid < lo + batch_size) & role_mask
            assign, m, w = batch_update(assign, in_batch, jax.random.fold_in(key, b))
            return assign, moved | m, wanted | w

        return jax.lax.fori_loop(0, n_batches, body, (assign, moved, wanted))

    def round_body(state):
        assign, _, rounds = state
        moved = jnp.bool_(False)
        wanted = jnp.bool_(False)
        key = jax.random.fold_in(key0, rounds)
        k1, k2 = jax.random.split(key)
        # Stage 1: leaders (head clusters) move first.
        assign, moved, wanted = stage(assign, moved, wanted, k1, is_leader, n_batches_h, 0)
        # Stage 2: followers respond to the leaders' committed strategies.
        assign, moved, wanted = stage(assign, moved, wanted, k2, ~is_leader, n_batches_t, n_head)
        return assign, wanted, rounds + 1

    def cond(state):
        _, wanted, rounds = state
        return wanted & (rounds < max_rounds)

    # Always run at least one round; `wanted` of the *last* round decides
    # convergence (False ⇒ pure Nash equilibrium reached).
    assign, wanted, rounds = round_body((assign0, jnp.bool_(True), jnp.int32(0)))
    assign, wanted, rounds = jax.lax.while_loop(
        cond, lambda s: round_body(s), (assign, wanted, rounds)
    )
    return assign, rounds, ~wanted


@partial(
    jax.jit,
    static_argnames=("n_clusters", "k", "batch_size", "max_rounds",
                     "use_move_cost"),
)
def _run_game_masked_jit(
    sizes,
    pair_a,
    pair_b,
    pair_w,
    assign0,
    delta,
    accept_prob,
    seed,
    leader_mask,
    move_mask,
    batch_ids,
    move_cost,
    home,
    *,
    n_clusters: int,
    k: int,
    batch_size: int,
    max_rounds: int,
    use_move_cost: bool,
):
    """Masked best-response dynamics (incremental refinement path).

    Identical move semantics to :func:`_run_game_jit` with two
    generalizations the warm-start subsystem needs: leaders are named by
    an explicit boolean mask (stable combined cluster ids interleave new
    head/tail clusters, so the leader set is no longer a contiguous id
    prefix), and only ``move_mask`` clusters may deviate (every other
    player is frozen but still shapes costs) — the "refine only what the
    delta touched" game.  ``batch_ids`` names the batch windows that hold
    at least one movable cluster (precomputed on host): a refinement over
    a handful of touched clusters pays for those batches only, not a full
    sweep — frozen-only batches are provably no-ops.

    ``use_move_cost`` (static) selects the elastic-resharding payoff: each
    cluster pays ``move_cost[i]`` on every partition except ``home[i]``
    (``home = -1`` ⇒ no free square — a uniform penalty that cannot bias
    the argmin).  False leaves the trace identical to the pre-move-cost
    masked game, so the refinement goldens hold.
    """
    inputs = GameInputs(sizes, pair_a, pair_b, pair_w, 0, k)
    degs = _cluster_degrees(inputs, n_clusters)
    cid = jnp.arange(n_clusters, dtype=jnp.int32)
    n_batches = batch_ids.shape[0]
    inv_k = 1.0 / k
    dk = delta * inv_k
    key0 = jax.random.PRNGKey(seed)
    move_pen = None
    if use_move_cost:
        at_home = jax.nn.one_hot(home, k, dtype=jnp.float32)  # -1 ⇒ all-zero
        move_pen = move_cost[:, None] * (1.0 - at_home)

    def stage(assign, moved, wanted, key, role_mask):
        def body(b, carry):
            assign, moved, wanted = carry
            bid = batch_ids[b]
            lo = bid * batch_size
            in_batch = (cid >= lo) & (cid < lo + batch_size) & role_mask
            # fold in the window id (not the loop index) so a window's
            # acceptance draws don't depend on which other windows ran
            assign, m, w = _batch_update(
                inputs, degs, assign, in_batch, jax.random.fold_in(key, bid),
                dk, inv_k, accept_prob, n_clusters, move_pen)
            return assign, moved | m, wanted | w

        return jax.lax.fori_loop(0, n_batches, body, (assign, moved, wanted))

    def round_body(state):
        assign, _, rounds = state
        moved = jnp.bool_(False)
        wanted = jnp.bool_(False)
        key = jax.random.fold_in(key0, rounds)
        k1, k2 = jax.random.split(key)
        assign, moved, wanted = stage(assign, moved, wanted, k1,
                                      leader_mask & move_mask)
        assign, moved, wanted = stage(assign, moved, wanted, k2,
                                      (~leader_mask) & move_mask)
        return assign, wanted, rounds + 1

    def cond(state):
        _, wanted, rounds = state
        return wanted & (rounds < max_rounds)

    assign, wanted, rounds = round_body((assign0, jnp.bool_(True), jnp.int32(0)))
    assign, wanted, rounds = jax.lax.while_loop(
        cond, lambda s: round_body(s), (assign, wanted, rounds)
    )
    return assign, rounds, ~wanted


def run_game(
    inputs: GameInputs,
    n_clusters: int,
    *,
    batch_size: int = 256,
    max_rounds: int = 64,
    accept_prob: float = 0.7,
    assign0: np.ndarray | None = None,
    delta: float | None = None,
    seed: int = 0,
    leader_mask: np.ndarray | None = None,
    move_mask: np.ndarray | None = None,
    move_cost: np.ndarray | None = None,
    home: np.ndarray | None = None,
) -> GameResult:
    """Run (damped) best-response dynamics to a pure Nash equilibrium.

    ``leader_mask``/``move_mask`` select the masked refinement path: an
    explicit (C,) leader set replaces the contiguous ``[0, n_head)``
    convention, and only ``move_mask`` players may deviate (all others are
    frozen context).  With both ``None`` the original full game runs —
    bit-identical to before the masks existed.

    ``move_cost`` (C,) adds a migration penalty to the masked game's
    payoff: cluster i pays ``move_cost[i]`` on every partition other than
    ``home[i]`` (default: its ``assign0`` seat; pass ``home[i] = -1`` for
    clusters with no surviving home — displaced by a shrink — which makes
    the penalty uniform and therefore neutral).  This is the bounded-
    migration knob of elastic k→k′ resharding: a cluster relocates only
    when the equilibrium gain exceeds its migration cost.
    """
    if assign0 is None:
        assign0 = init_assignment(np.asarray(inputs.sizes), inputs.k)
    degs = _cluster_degrees(inputs, n_clusters)
    if delta is None:
        delta = compute_delta(inputs.sizes, degs, inputs.k)
    if move_cost is not None and leader_mask is None and move_mask is None:
        # the migration-cost game is only defined on the masked path;
        # default every player movable with the contiguous leader prefix
        leader_mask = np.arange(n_clusters) < inputs.n_head
    if leader_mask is None and move_mask is None:
        assign, rounds, converged = _run_game_jit(
            inputs.sizes,
            inputs.pair_a,
            inputs.pair_b,
            inputs.pair_w,
            jnp.asarray(assign0, jnp.int32),
            jnp.asarray(delta, jnp.float32),
            jnp.float32(accept_prob),
            seed,
            n_clusters=n_clusters,
            n_head=inputs.n_head,
            k=inputs.k,
            batch_size=batch_size,
            max_rounds=max_rounds,
        )
        return GameResult(assignment=assign, rounds=rounds, converged=converged)
    if leader_mask is None:
        leader_mask = np.arange(n_clusters) < inputs.n_head
    if move_mask is None:
        move_mask = np.ones((n_clusters,), bool)
    # only batch windows holding a movable cluster are worth visiting
    batch_ids = np.unique(
        np.nonzero(np.asarray(move_mask))[0] // batch_size).astype(np.int32)
    if batch_ids.size == 0:  # every player frozen: a no-op equilibrium
        return GameResult(assignment=jnp.asarray(assign0, jnp.int32),
                          rounds=jnp.int32(0), converged=jnp.bool_(True))
    use_move_cost = move_cost is not None
    if use_move_cost:
        home = np.asarray(assign0, np.int32) if home is None else home
    else:  # dummy operands: unused under a use_move_cost=False trace
        move_cost = np.zeros((n_clusters,), np.float32)
        home = np.full((n_clusters,), -1, np.int32)
    assign, rounds, converged = _run_game_masked_jit(
        inputs.sizes,
        inputs.pair_a,
        inputs.pair_b,
        inputs.pair_w,
        jnp.asarray(assign0, jnp.int32),
        jnp.asarray(delta, jnp.float32),
        jnp.float32(accept_prob),
        seed,
        jnp.asarray(leader_mask, bool),
        jnp.asarray(move_mask, bool),
        jnp.asarray(batch_ids),
        jnp.asarray(move_cost, jnp.float32),
        jnp.asarray(home, jnp.int32),
        n_clusters=n_clusters,
        k=inputs.k,
        batch_size=batch_size,
        max_rounds=max_rounds,
        use_move_cost=use_move_cost,
    )
    return GameResult(assignment=assign, rounds=rounds, converged=converged)


def social_welfare(inputs: GameInputs, assign: jax.Array, delta: jax.Array) -> jax.Array:
    """S(Λ) of Eq. (5) = δ·Σ|p|²/k + Σ Θ(p, V)/k (Theorem 4 identity)."""
    k = inputs.k
    part_sizes = jax.ops.segment_sum(inputs.sizes, assign, num_segments=k)
    assign_ext = jnp.concatenate([assign, jnp.zeros((1,), jnp.int32)])
    cut = jnp.sum(
        inputs.pair_w
        * (assign_ext[inputs.pair_a] != assign_ext[inputs.pair_b]).astype(jnp.float32)
    )
    load = delta * jnp.sum(jnp.square(part_sizes)) / k
    # Θ(p_i, V) = Θ(p_i, V − p_i) + |p_i|; Σ_i Θ(p_i, V−p_i) counts each cut
    # pair from both sides ⇒ 2·cut.
    comm = (2.0 * cut + jnp.sum(part_sizes)) / k
    return load + comm


def best_response_gap(inputs: GameInputs, assign: jax.Array, n_clusters: int,
                      delta: jax.Array | None = None) -> jax.Array:
    """Max cost improvement any single player could get by deviating.

    0 ⇔ pure Nash equilibrium.  Used by the property tests (the converged
    flag of :func:`run_game` must imply gap == 0 *per batch semantics*, i.e.
    no player moves when all others are fixed)."""
    degs = _cluster_degrees(inputs, n_clusters)
    if delta is None:
        delta = compute_delta(inputs.sizes, degs, inputs.k)
    k = inputs.k
    sizes = inputs.sizes
    w_ip = _neighbor_partition_weight(inputs, assign, n_clusters)
    part_sizes = jax.ops.segment_sum(sizes, assign, num_segments=k)
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    hyp = part_sizes[None, :] + sizes[:, None] * (1.0 - onehot)
    cost = (delta / k) * sizes[:, None] * hyp + (degs[:, None] - w_ip + sizes[:, None]) / k
    cur = jnp.take_along_axis(cost, assign[:, None].astype(jnp.int32), axis=1)[:, 0]
    best = jnp.min(cost, axis=1)
    return jnp.max(cur - best)
