"""Distributed S5P: the streaming partitioner itself scaled over a mesh.

The paper's pipeline is single-node.  At cluster scale the partitioner must
itself be distributed — this module maps each phase onto jax-native
collectives (DESIGN.md §2):

Phase 1 (clustering)  — the edge stream is range-sharded over the ``data``
  mesh axis with ``shard_map``; **global degrees** are a ``psum`` of
  per-shard degree counts; each shard runs the Algorithm-1 scan over its
  own range, producing shard-local clusters (disjoint id spaces — a vertex
  may hold one cluster per shard that saw it).

Phase 2 (statistics)  — per-shard cluster adjacency is streamed into
  per-shard **count-min sketches and merged with one ``psum``** (the sketch
  is linear — the paper's choice of summary is exactly what makes the
  distributed merge a constant-size collective).  Cross-shard coupling
  comes from vertex co-membership pairs (a vertex's clusters in two shards
  are adjacent with weight = its local degree overlap).

Phase 3 (game)        — cluster count ≪ edge count, so the Stackelberg
  game runs replicated on every device (identical inputs ⇒ identical pure
  strategies; no communication).

Phase 4 (postprocess) — each shard places its own edge range; the global
  load vector is refreshed by ``psum`` once per stream chunk (bounded
  staleness; the per-chunk cap ``L/S`` keeps the τ bound, tested).

Only O(|C|²)-summary + O(k) state ever crosses the network — the property
that lets this scale to the 512-chip production mesh.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # jax ≥ 0.5 top-level API; older releases ship it under experimental
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

# pvary (varying-axis annotation) only exists on newer jax; on older
# shard_map it is unnecessary — replicated operands are implicitly varying
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

from . import clustering as _cl
from . import game as _game
from . import postprocess as _post
from .cms import make_sketch, cms_update, cms_query, pair_key, suggest_params
from .s5p import S5PConfig

__all__ = ["distributed_partition"]


def _shard_cluster(src_sh, dst_sh, n_vertices, xi, kappa, axis):
    """shard_map body: psum global degrees, then local Alg.1 scan."""
    ones = jnp.ones_like(src_sh[0])
    deg = jax.ops.segment_sum(ones, src_sh[0], num_segments=n_vertices)
    deg = deg + jax.ops.segment_sum(ones, dst_sh[0], num_segments=n_vertices)
    deg = jax.lax.psum(deg.astype(jnp.int32), axis)  # global degrees
    state = _cl.init_state(n_vertices)
    # the scan carry diverges per shard: mark it device-varying up front
    state = jax.tree.map(lambda x: _pvary(x, (axis,)), state)
    state = _cl.cluster_chunk(state, src_sh[0], dst_sh[0], deg, xi=xi, kappa=kappa)
    return (
        state.v2c_h[None],
        state.v2c_t[None],
        deg[None],
        state.next_h[None],
        state.next_t[None],
    )


def distributed_partition(src, dst, n_vertices: int, config: S5PConfig, mesh,
                          axis: str = "data"):
    """Run the S5P pipeline sharded over ``mesh[axis]``.

    Returns (parts, info).  Requires len(edges) divisible by the axis size
    (pad with self-loops upstream if needed — they are no-ops).
    """
    n_shards = mesh.shape[axis]
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    E = int(src.shape[0])
    pad = (-E) % n_shards
    if pad:
        src = jnp.concatenate([src, jnp.zeros((pad,), jnp.int32)])
        dst = jnp.concatenate([dst, jnp.zeros((pad,), jnp.int32)])
    k = config.k
    avg_deg = 2.0 * E / max(n_vertices, 1)
    xi = min(int(config.beta * avg_deg), 2**31 - 2)
    kappa = max(int(math.ceil(2.0 * E / k)), 2)

    # ---- Phase 1: sharded clustering ----
    spec = P(axis)
    fn = _shard_map(
        partial(_shard_cluster, n_vertices=n_vertices, xi=xi, kappa=kappa, axis=axis),
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
    )
    srcs = src.reshape(n_shards, -1)
    dsts = dst.reshape(n_shards, -1)
    v2c_h, v2c_t, degs, next_h, next_t = fn(srcs, dsts)
    v2c_h = np.asarray(v2c_h)  # (S, V)
    v2c_t = np.asarray(v2c_t)
    degrees = jnp.asarray(np.asarray(degs)[0])

    # ---- global cluster id space: concatenate shard-local spaces ----
    nh = np.asarray(next_h)
    nt = np.asarray(next_t)
    # head ids first (leaders), then tails, shard-major inside each role
    h_off = np.concatenate([[0], np.cumsum(nh)])[:-1]
    n_head = int(nh.sum())
    t_off = n_head + np.concatenate([[0], np.cumsum(nt)])[:-1]
    n_clusters = int(n_head + nt.sum())
    gh = np.where(v2c_h >= 0, v2c_h + h_off[:, None], -1).astype(np.int32)  # (S,V)
    gt = np.where(v2c_t >= 0, v2c_t + t_off[:, None], -1).astype(np.int32)

    # ---- Phase 2: statistics (sizes, adjacency, CMS merge) ----
    src_np = np.asarray(src).reshape(n_shards, -1)
    dst_np = np.asarray(dst).reshape(n_shards, -1)
    deg_np = np.asarray(degrees)
    sizes = np.zeros(n_clusters, np.float64)
    pair_chunks = []
    for s in range(n_shards):
        u, v = src_np[s], dst_np[s]
        valid = u != v
        is_head = (deg_np[u] > xi) & (deg_np[v] > xi)
        cu = np.where(is_head, gh[s][u], gt[s][u])
        cv = np.where(is_head, gh[s][v], gt[s][v])
        internal = (cu == cv) & valid & (cu >= 0)
        boundary = (cu != cv) & valid & (cu >= 0) & (cv >= 0)
        np.add.at(sizes, cu[internal], 1.0)
        np.add.at(sizes, cu[boundary], 0.5)
        np.add.at(sizes, cv[boundary], 0.5)
        a = np.minimum(cu[boundary], cv[boundary])
        b = np.maximum(cu[boundary], cv[boundary])
        pair_chunks.append((a, b))
        # cross-type membership pairs within the shard
        alt_u = np.where(is_head, gt[s][u], gh[s][u])
        ok = valid & (alt_u >= 0) & (alt_u != cv) & (cv >= 0)
        pair_chunks.append((np.minimum(alt_u[ok], cv[ok]), np.maximum(alt_u[ok], cv[ok])))
        alt_v = np.where(is_head, gt[s][v], gh[s][v])
        ok = valid & (alt_v >= 0) & (alt_v != cu) & (cu >= 0)
        pair_chunks.append((np.minimum(cu[ok], alt_v[ok]), np.maximum(cu[ok], alt_v[ok])))
    # cross-SHARD coupling: a vertex's clusters in different shards
    for table in (gh, gt):
        for s1 in range(n_shards):
            for s2 in range(s1 + 1, n_shards):
                both = (table[s1] >= 0) & (table[s2] >= 0)
                a = np.minimum(table[s1][both], table[s2][both])
                b = np.maximum(table[s1][both], table[s2][both])
                pair_chunks.append((a, b))
    a_all = np.concatenate([c[0] for c in pair_chunks])
    b_all = np.concatenate([c[1] for c in pair_chunks])
    keys = a_all.astype(np.int64) * (n_clusters + 1) + b_all
    uniq, counts = np.unique(keys, return_counts=True)
    pa = (uniq // (n_clusters + 1)).astype(np.int32)
    pb = (uniq % (n_clusters + 1)).astype(np.int32)

    if config.use_cms:
        # per-shard sketches merged by summation (linear sketch ≡ psum)
        w, d = suggest_params(config.cms_epsilon, config.cms_nu)
        width = w * max(1, int(math.sqrt(max(n_clusters, 1))))
        merged = make_sketch(width, d, seed=config.seed)
        merged = cms_update(
            merged, pair_key(jnp.asarray(a_all), jnp.asarray(b_all))
        )
        pw = cms_query(merged, pair_key(jnp.asarray(pa), jnp.asarray(pb))).astype(
            jnp.float32
        )
    else:
        pw = jnp.asarray(counts, jnp.float32)

    # ---- Phase 3: replicated game ----
    inputs = _game.GameInputs(
        sizes=jnp.asarray(sizes, jnp.float32),
        pair_a=jnp.asarray(pa),
        pair_b=jnp.asarray(pb),
        pair_w=pw,
        n_head=n_clusters if config.one_stage else n_head,
        k=k,
    )
    game = _game.run_game(
        inputs, n_clusters,
        batch_size=max(16, min(config.game_batch_size, n_clusters // 8)),
        max_rounds=config.game_max_rounds,
        accept_prob=config.game_accept_prob, seed=config.seed,
    )
    c2p = game.assignment

    # ---- Phase 4: per-shard postprocess, psum'd load per chunk ----
    max_load = int(math.ceil(config.tau * (E + pad) / k))
    parts_out = np.full(E + pad, -1, np.int32)
    load = jnp.zeros((k,), jnp.int32)
    chunk = max(config.chunk_size // max(n_shards, 1), 1024)
    shard_len = (E + pad) // n_shards
    for start in range(0, shard_len, chunk):
        stop = min(start + chunk, shard_len)
        for s in range(n_shards):
            u = src_np[s][start:stop]
            v = dst_np[s][start:stop]
            valid = u != v
            is_head = (deg_np[u] > xi) & (deg_np[v] > xi)
            cu = np.where(is_head, gh[s][u], gt[s][u])
            cv = np.where(is_head, gh[s][v], gt[s][v])
            load, p = _post._assign_chunk(
                load, jnp.int32(max_load),
                jnp.asarray(u), jnp.asarray(v),
                jnp.asarray(is_head), jnp.asarray(np.maximum(cu, 0)),
                jnp.asarray(np.maximum(cv, 0)), c2p, k=k,
            )
            parts_out[s * shard_len + start:s * shard_len + stop] = np.asarray(p)

    info = {
        "n_clusters": n_clusters,
        "n_head": n_head,
        "game_rounds": int(game.rounds),
        "converged": bool(game.converged),
        "n_shards": n_shards,
    }
    return jnp.asarray(parts_out[:E]), info
