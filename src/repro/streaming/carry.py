"""PartitionerCarry — the one carry protocol every streaming consumer speaks.

A streaming partitioner is an ``init / step_chunk / merge / finalize``
quadruple over an O(|V| + k) carry pytree:

- ``init()``        — the identity carry (empty replica bitmaps, zero loads);
- ``step_chunk``    — fold one EdgeStream chunk into the carry, optionally
  emitting per-edge results (``parts``) for that chunk;
- ``merge``         — reconcile carries produced by *independent* sub-streams
  (the HEP/CuSP parallel-ingest regime: S workers ingest disjoint chunk
  ranges, their carries are all-reduced at super-chunk boundaries);
- ``finalize``      — extract the consumer-facing result from the carry.

Merge semantics are declared **per field** via :attr:`merge_ops`, one op per
leaf of the carry pytree in ``jax.tree_util`` flattening order:

- ``SUM``        — additive statistics: partition loads, cluster volumes,
  HDRF partial-degree estimates, Θ count-min tables, degree counts.  Merging
  carries that diverged from a common ``base`` sums their *deltas*
  (``base + Σ (cᵢ − base)``), so the base is never double-counted.
- ``COUNTED``    — occupancy counters standing in for what used to be a
  monotone set: replica "bitmaps" are small per-(vertex, partition) int
  counters that **OR-project** (``> 0``) for scoring — the projection is
  bit-identical to the old boolean bitmap on insert-only streams, and the
  counter itself is an abelian-group element, so deletions subtract
  exactly (count hits 0 ⇒ the replica vanishes, no tombstone scan).
  Merge semantics are SUM.
- ``REPLICATED`` — scenario constants threaded through the carry (HDRF λ,
  the padded-k mask, grid row/col tables): identical in every sub-stream,
  merged by taking the first.
- ``OR``/``MAX`` — the legacy monotone ops (boolean union, prefer-any-
  assignment).  Kept for external ``FnCarry``-style consumers, but **no
  in-repo carry declares them anymore**: the decremental refactor moved
  every bitmap to ``COUNTED`` and every assignment/id-counter table to
  ``SUM``-of-transitions (the merged value telescopes ``base + Σ (cᵢ −
  base)``, which equals the writer's value when one sub-stream wrote it
  and a deterministic — clamped-at-projection — resolution otherwise).

Why these laws matter twice over:

1. *Parallel ingest* — ``SUM``/``COUNTED`` over integer arrays are
   associative and commutative with a shared merge base, so the merged
   carry is independent of worker count, merge tree shape, and arrival
   interleaving (``tests/test_carry.py`` pins this property-based).  That
   is the licence ``run_parallel`` needs to all-reduce carries with one
   collective per super-chunk.
2. *Deletions* — every non-replicated field now lives in an abelian
   **group**, not just a monoid: :meth:`PartitionerCarry.signed_delta`
   forms the difference of two carries, :meth:`~PartitionerCarry.negate`
   inverts it, and ``merge(merge(c, δ), −δ) == c`` holds **bitwise**
   (integer arithmetic; uint32 sketch tables are the group ℤ/2³²).
   :meth:`~PartitionerCarry.retract_chunk` is the per-chunk face of the
   same algebra: it subtracts exactly the accounting ``step_chunk`` added
   for those edges (given their recorded per-edge ``parts``), which is
   what makes edge deletion and sliding-window expiry exact for the
   scoring carries.

``CARRY_REPR`` names this representation generation; persisted carries
record it so a pre-refactor (monotone-bitmap) checkpoint is rejected with
a clear error instead of mis-restoring (see ``repro.incremental.store``).
"""

from __future__ import annotations

from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "SUM",
    "COUNTED",
    "OR",
    "MAX",
    "REPLICATED",
    "MERGE_OPS",
    "CARRY_REPR",
    "PartitionerCarry",
    "FnCarry",
    "RetractCarry",
]

SUM = "sum"
COUNTED = "counted"
OR = "or"
MAX = "max"
REPLICATED = "replicated"

MERGE_OPS = (SUM, COUNTED, OR, MAX, REPLICATED)

#: group ops — fields whose values form an abelian group under merge
#: (exact negation / subtraction; the substrate of edge deletion)
GROUP_OPS = (SUM, COUNTED)

#: representation generation of the carry algebra.  2 = the counted /
#: group-structured representation (decremental); 1 was the monotone
#: OR/MAX generation, whose checkpoints must not seed this code.
CARRY_REPR = 2


def _or_leaf(a, b):
    # ∨ on bools, elementwise max on int-encoded bitmaps — both monotone
    if a.dtype == jnp.bool_:
        return a | b
    return jnp.maximum(a, b)


def _check_ops(ops: Sequence[str], n_leaves: int) -> None:
    if len(ops) != n_leaves:
        raise ValueError(
            f"merge_ops declares {len(ops)} fields but the carry has "
            f"{n_leaves} leaves")
    for op in ops:
        if op not in MERGE_OPS:
            raise ValueError(f"unknown merge op {op!r}; one of {MERGE_OPS}")


class PartitionerCarry:
    """Base class: declare :attr:`merge_ops`, implement ``init``/``step_chunk``.

    ``step_chunk(carry, src, dst, n_valid, *extras) -> (carry, parts)`` must
    be pure and traceable (``n_valid`` arrives as a traced int32 scalar so
    one compiled step serves every chunk; padding entries are (0, 0)
    self-loops, which every consumer already masks).  ``parts`` is the
    per-edge result for the chunk (or ``None`` for state-only consumers
    like clustering and the Θ pass).

    ``merge``/``merge_stacked`` are derived from :attr:`merge_ops`;
    ``finalize`` defaults to the identity.
    """

    #: one merge op per carry leaf, in ``jax.tree_util`` flattening order
    merge_ops: tuple[str, ...] = ()

    #: leaf indices (into the flattened carry) whose SUM merge resolves
    #: *concurrent* writers by keeping the lowest-lane writer's value
    #: instead of the telescoped sum.  For assignment tables (vertex →
    #: cluster ids) the telescoped ``base + Σ (cᵢ − base)`` fabricates an
    #: id whenever two lanes reassigned the same vertex within one
    #: super-chunk; pick-first keeps a *real* id one lane assigned.  When
    #: at most one lane wrote a cell the result is bit-identical to the
    #: telescoped sum, so sequential and conflict-free parallel runs are
    #: unaffected.  The group algebra (signed_delta / apply_delta) still
    #: treats these leaves as plain integers — only merging changes.
    pick_first: tuple[int, ...] = ()

    #: False for state-only consumers whose step_chunk returns parts=None
    emits_parts: bool = True

    #: True once the consumer implements :meth:`retract_chunk`
    supports_retract: bool = False

    #: True when ``retract_chunk(step_chunk(c, chunk), chunk, parts) == c``
    #: holds bitwise for unpadded chunks (the scoring carries); False for
    #: consumers whose retraction is a documented approximation (Alg. 1
    #: clustering — migrations are history-dependent).
    retract_exact: bool = False

    # ------------------------------------------------------------ protocol
    def init(self):
        raise NotImplementedError

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        raise NotImplementedError

    def retract_chunk(self, carry, src, dst, n_valid, parts, *extras):
        """Undo the accounting ``step_chunk`` performed for these edges.

        ``parts`` is the per-edge result recorded when the edges were
        ingested (``None`` for state-only consumers).  Only entries with
        index ``< n_valid`` are retracted — chunk padding is never
        touched, so a deletion batch may be chunked arbitrarily.
        Retraction is order-independent (pure subtraction on the group
        fields), so chunks may be retracted in any order."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support edge deletion")

    def finalize(self, carry):
        return carry

    # -------------------------------------------------------- group algebra
    def signed_delta(self, after, before):
        """The group difference ``after ⊖ before`` per field.

        SUM/COUNTED fields subtract (ℤ, or ℤ/2³² for unsigned leaves);
        REPLICATED fields pass ``after`` through unchanged.  Raises for
        the legacy monotone ops — they have no inverse."""
        fa, treedef = jax.tree_util.tree_flatten(after)
        fb = jax.tree_util.tree_leaves(before)
        _check_ops(self.merge_ops, len(fa))
        out = []
        for op, a, b in zip(self.merge_ops, fa, fb):
            if op in GROUP_OPS:
                out.append(jnp.asarray(a) - jnp.asarray(b))
            elif op == REPLICATED:
                out.append(a)
            else:
                raise ValueError(
                    f"merge op {op!r} is monotone — it has no signed delta")
        return jax.tree_util.tree_unflatten(treedef, out)

    def negate(self, delta):
        """The group inverse of a signed delta (identity on REPLICATED)."""
        flat, treedef = jax.tree_util.tree_flatten(delta)
        _check_ops(self.merge_ops, len(flat))
        out = []
        for op, x in zip(self.merge_ops, flat):
            if op in GROUP_OPS:
                x = jnp.asarray(x)
                # unsigned leaves negate in ℤ/2³² (two's complement)
                out.append((jnp.zeros((), x.dtype) - x).astype(x.dtype))
            elif op == REPLICATED:
                out.append(x)
            else:
                raise ValueError(
                    f"merge op {op!r} is monotone — it has no inverse")
        return jax.tree_util.tree_unflatten(treedef, out)

    def apply_delta(self, carry, delta):
        """``carry ⊕ delta``: add group fields, keep replicated ones.

        ``apply_delta(apply_delta(c, δ), negate(δ)) == c`` bitwise — the
        group law every decremental consumer builds on."""
        fc, treedef = jax.tree_util.tree_flatten(carry)
        fd = jax.tree_util.tree_leaves(delta)
        _check_ops(self.merge_ops, len(fc))
        out = []
        for op, c, d in zip(self.merge_ops, fc, fd):
            if op in GROUP_OPS:
                c = jnp.asarray(c)
                out.append((c + jnp.asarray(d)).astype(c.dtype))
            elif op == REPLICATED:
                out.append(c)
            else:
                raise ValueError(
                    f"merge op {op!r} is monotone — signed deltas do not "
                    "apply")
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------- merging
    def merge(self, carries: Iterable[Any], base: Any | None = None):
        """Reconcile carries from independent sub-streams.

        With ``base`` given, every carry is treated as a divergence from
        that common ancestor (``SUM`` fields add deltas onto the base);
        without it, carries are deltas from the identity and ``SUM`` fields
        add directly.  ``merge([c])`` returns ``c`` unchanged (bitwise)."""
        carries = list(carries)
        if not carries:
            raise ValueError("merge() needs at least one carry")
        if len(carries) == 1:
            return carries[0]
        flat0, treedef = jax.tree_util.tree_flatten(carries[0])
        _check_ops(self.merge_ops, len(flat0))
        cols = [flat0] + [
            jax.tree_util.tree_flatten(c)[0] for c in carries[1:]
        ]
        base_flat = (jax.tree_util.tree_leaves(base)
                     if base is not None else None)
        out = []
        for i, op in enumerate(self.merge_ops):
            leaves = [jnp.asarray(c[i]) for c in cols]
            if op in GROUP_OPS:
                if i in self.pick_first and base_flat is not None:
                    b = jnp.asarray(base_flat[i])
                    acc = b
                    taken = jnp.zeros(b.shape, jnp.bool_)
                    for x in leaves:
                        ch = x != b
                        acc = jnp.where(ch & ~taken, x, acc)
                        taken = taken | ch
                    out.append(acc.astype(leaves[0].dtype))
                    continue
                acc = leaves[0]
                for x in leaves[1:]:
                    acc = acc + x
                if base_flat is not None:
                    b = jnp.asarray(base_flat[i])
                    acc = acc - (len(leaves) - 1) * b.astype(acc.dtype)
                out.append(acc)
            elif op in (OR, MAX):
                acc = leaves[0]
                for x in leaves[1:]:
                    acc = _or_leaf(acc, x) if op == OR else jnp.maximum(acc, x)
                out.append(acc)
            else:  # REPLICATED
                out.append(leaves[0])
        return jax.tree_util.tree_unflatten(treedef, out)

    def merge_stacked(self, stacked, base: Any | None = None):
        """Merge a carry whose every leaf carries a leading lane axis
        (the vmap parallel backend's layout) in one reduction per field."""
        flat, treedef = jax.tree_util.tree_flatten(stacked)
        _check_ops(self.merge_ops, len(flat))
        base_flat = (jax.tree_util.tree_leaves(base)
                     if base is not None else None)
        out = []
        for i, op in enumerate(self.merge_ops):
            x = jnp.asarray(flat[i])
            if op in GROUP_OPS:
                if i in self.pick_first and base_flat is not None:
                    b = jnp.asarray(base_flat[i])
                    changed = x != b[None, ...]
                    first = jnp.argmax(changed, axis=0)
                    picked = jnp.take_along_axis(x, first[None, ...],
                                                 axis=0)[0]
                    out.append(jnp.where(jnp.any(changed, axis=0), picked,
                                         b).astype(x.dtype))
                    continue
                acc = jnp.sum(x, axis=0)
                if base_flat is not None:
                    b = jnp.asarray(base_flat[i])
                    acc = acc - (x.shape[0] - 1) * b.astype(acc.dtype)
                out.append(acc.astype(x.dtype))
            elif op == OR:
                out.append(jnp.any(x, axis=0) if x.dtype == jnp.bool_
                           else jnp.max(x, axis=0))
            elif op == MAX:
                out.append(jnp.max(x, axis=0))
            else:  # REPLICATED
                out.append(x[0])
        return jax.tree_util.tree_unflatten(treedef, out)

    def occupancy_contest(self, before, after) -> float:
        """How contested the carry's placement state still is, as the
        fraction of active cells whose zero/nonzero projection flipped
        between two consecutive merge bases — the signal the adaptive
        super-chunk cadence (``super_chunk="auto"`` in
        :func:`~repro.streaming.parallel.run_parallel`) backs off on.

        COUNTED fields (replica-occupancy counters: the `(v, p)` bitmap
        projection is ``count > 0``) are the natural churn meter; carries
        without COUNTED fields (linear consumers like the degree/Θ
        sketches) fall back to the same projection over SUM fields, whose
        zero→nonzero transitions die out as the tables fill.  Returns a
        host float in ``[0, 1]`` (0 for carries with no group fields —
        nothing to contest, so auto cadence backs off immediately)."""
        fb = [jnp.asarray(x) for x in jax.tree_util.tree_leaves(before)]
        fa = [jnp.asarray(x) for x in jax.tree_util.tree_leaves(after)]
        _check_ops(self.merge_ops, len(fa))
        for pick in (COUNTED, SUM):
            changed = active = 0
            seen = False
            for op, b, a in zip(self.merge_ops, fb, fa):
                if op != pick:
                    continue
                seen = True
                changed += int(jnp.sum((b != 0) != (a != 0)))
                active += int(jnp.sum(a != 0))
            if seen:
                return changed / max(active, 1)
        return 0.0

    def merge_collective(self, local, base, axis: str):
        """The shard_map form of :meth:`merge`: one collective per field
        (``psum`` of deltas for SUM, ``pmax`` for OR/MAX, base for
        REPLICATED), evaluated on every device of mesh axis ``axis``."""
        flat, treedef = jax.tree_util.tree_flatten(local)
        _check_ops(self.merge_ops, len(flat))
        base_flat = jax.tree_util.tree_leaves(base)
        out = []
        for i, op in enumerate(self.merge_ops):
            x = flat[i]
            if op in GROUP_OPS:
                b = base_flat[i].astype(x.dtype)
                if i in self.pick_first:
                    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
                    idx = jax.lax.axis_index(axis).astype(jnp.int32)
                    changed = x != b
                    winner = jax.lax.pmin(jnp.where(changed, idx, n), axis)
                    contrib = jnp.where(changed & (idx == winner), x - b,
                                        jnp.zeros((), x.dtype))
                    out.append(b + jax.lax.psum(contrib, axis))
                    continue
                out.append(b + jax.lax.psum(x - b, axis))
            elif op in (OR, MAX):
                if x.dtype == jnp.bool_:
                    out.append(jax.lax.pmax(x.astype(jnp.int32), axis) > 0)
                else:
                    out.append(jax.lax.pmax(x, axis))
            else:  # REPLICATED
                out.append(base_flat[i])
        return jax.tree_util.tree_unflatten(treedef, out)


class FnCarry(PartitionerCarry):
    """Adapter: a bare ``(carry0, chunk_fn)`` pair as a PartitionerCarry.

    Wraps the legacy ``run_scan`` contract (``chunk_fn(carry, src, dst,
    *extras)``) so the engine has one driver code path.  No merge semantics
    are declared — sequential use only."""

    def __init__(self, carry0, chunk_fn: Callable):
        self._carry0 = carry0
        self._chunk_fn = chunk_fn

    def init(self):
        return self._carry0

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        return self._chunk_fn(carry, src, dst, *extras)


class RetractCarry(PartitionerCarry):
    """Adapter: drive a consumer's **retraction** through the fold engines.

    ``step_chunk`` of the adapter is ``retract_chunk`` of the wrapped
    consumer, with the deleted edges' recorded per-edge ``parts`` riding
    along as the first stream extra (state-only consumers pass
    ``parts=None`` and the adapter forwards ``None``).  Because
    retraction is pure subtraction on the carry's group fields, the
    adapted "fold" inherits everything the insertion path has: lane
    masking for exhausted streams, tree / collective merges, and all
    three ``run_parallel`` backends — a deletion batch shards exactly
    like an insertion batch.  State-only by construction
    (``emits_parts=False``); ``finalize`` is the identity because a
    retracted carry composes with further folds.
    """

    emits_parts = False

    def __init__(self, pc: PartitionerCarry, *, with_parts: bool = True):
        if not pc.supports_retract:
            raise NotImplementedError(
                f"{type(pc).__name__} does not support edge deletion")
        self._pc = pc
        self._with_parts = bool(with_parts)

    @property
    def merge_ops(self) -> tuple[str, ...]:
        return self._pc.merge_ops

    def init(self):
        return self._pc.init()

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        if self._with_parts:
            parts, extras = extras[0], extras[1:]
        else:
            parts = None
        return (self._pc.retract_chunk(carry, src, dst, n_valid, parts,
                                       *extras), None)
