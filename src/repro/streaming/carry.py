"""PartitionerCarry — the one carry protocol every streaming consumer speaks.

A streaming partitioner is an ``init / step_chunk / merge / finalize``
quadruple over an O(|V| + k) carry pytree:

- ``init()``        — the identity carry (empty replica bitmaps, zero loads);
- ``step_chunk``    — fold one EdgeStream chunk into the carry, optionally
  emitting per-edge results (``parts``) for that chunk;
- ``merge``         — reconcile carries produced by *independent* sub-streams
  (the HEP/CuSP parallel-ingest regime: S workers ingest disjoint chunk
  ranges, their carries are all-reduced at super-chunk boundaries);
- ``finalize``      — extract the consumer-facing result from the carry.

Merge semantics are declared **per field** via :attr:`merge_ops`, one op per
leaf of the carry pytree in ``jax.tree_util`` flattening order:

- ``SUM``        — additive statistics: partition loads, cluster volumes,
  HDRF partial-degree estimates, Θ count-min tables, degree counts.  Merging
  carries that diverged from a common ``base`` sums their *deltas*
  (``base + Σ (cᵢ − base)``), so the base is never double-counted.
- ``OR``         — monotone union: replica bitmaps (a vertex is replicated on
  a partition if *any* sub-stream put it there).  Implemented as elementwise
  maximum, which is ∨ on bools and works for int-encoded bitmaps.
- ``MAX``        — monotone resolution for assignment tables and id counters:
  vertex→cluster entries are ``-1`` when unassigned, so ``max`` prefers any
  assignment over none and breaks conflicting assignments deterministically.
- ``REPLICATED`` — scenario constants threaded through the carry (HDRF λ,
  the padded-k mask, grid row/col tables): identical in every sub-stream,
  merged by taking the first.

Why these laws matter: ``SUM``/``OR``/``MAX`` over integer/bool arrays are
associative and commutative, and ``init()`` is their identity — so the
merged carry is independent of worker count, merge tree shape, and arrival
interleaving of the merge itself (``tests/test_carry.py`` pins this
algebra property-based).  That is exactly the licence ``run_parallel``
needs to all-reduce carries with one collective per super-chunk.
"""

from __future__ import annotations

from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "SUM",
    "OR",
    "MAX",
    "REPLICATED",
    "MERGE_OPS",
    "PartitionerCarry",
    "FnCarry",
]

SUM = "sum"
OR = "or"
MAX = "max"
REPLICATED = "replicated"

MERGE_OPS = (SUM, OR, MAX, REPLICATED)


def _or_leaf(a, b):
    # ∨ on bools, elementwise max on int-encoded bitmaps — both monotone
    if a.dtype == jnp.bool_:
        return a | b
    return jnp.maximum(a, b)


def _check_ops(ops: Sequence[str], n_leaves: int) -> None:
    if len(ops) != n_leaves:
        raise ValueError(
            f"merge_ops declares {len(ops)} fields but the carry has "
            f"{n_leaves} leaves")
    for op in ops:
        if op not in MERGE_OPS:
            raise ValueError(f"unknown merge op {op!r}; one of {MERGE_OPS}")


class PartitionerCarry:
    """Base class: declare :attr:`merge_ops`, implement ``init``/``step_chunk``.

    ``step_chunk(carry, src, dst, n_valid, *extras) -> (carry, parts)`` must
    be pure and traceable (``n_valid`` arrives as a traced int32 scalar so
    one compiled step serves every chunk; padding entries are (0, 0)
    self-loops, which every consumer already masks).  ``parts`` is the
    per-edge result for the chunk (or ``None`` for state-only consumers
    like clustering and the Θ pass).

    ``merge``/``merge_stacked`` are derived from :attr:`merge_ops`;
    ``finalize`` defaults to the identity.
    """

    #: one merge op per carry leaf, in ``jax.tree_util`` flattening order
    merge_ops: tuple[str, ...] = ()

    #: False for state-only consumers whose step_chunk returns parts=None
    emits_parts: bool = True

    # ------------------------------------------------------------ protocol
    def init(self):
        raise NotImplementedError

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        raise NotImplementedError

    def finalize(self, carry):
        return carry

    # ------------------------------------------------------------- merging
    def merge(self, carries: Iterable[Any], base: Any | None = None):
        """Reconcile carries from independent sub-streams.

        With ``base`` given, every carry is treated as a divergence from
        that common ancestor (``SUM`` fields add deltas onto the base);
        without it, carries are deltas from the identity and ``SUM`` fields
        add directly.  ``merge([c])`` returns ``c`` unchanged (bitwise)."""
        carries = list(carries)
        if not carries:
            raise ValueError("merge() needs at least one carry")
        if len(carries) == 1:
            return carries[0]
        flat0, treedef = jax.tree_util.tree_flatten(carries[0])
        _check_ops(self.merge_ops, len(flat0))
        cols = [flat0] + [
            jax.tree_util.tree_flatten(c)[0] for c in carries[1:]
        ]
        base_flat = (jax.tree_util.tree_leaves(base)
                     if base is not None else None)
        out = []
        for i, op in enumerate(self.merge_ops):
            leaves = [jnp.asarray(c[i]) for c in cols]
            if op == SUM:
                acc = leaves[0]
                for x in leaves[1:]:
                    acc = acc + x
                if base_flat is not None:
                    b = jnp.asarray(base_flat[i])
                    acc = acc - (len(leaves) - 1) * b.astype(acc.dtype)
                out.append(acc)
            elif op in (OR, MAX):
                acc = leaves[0]
                for x in leaves[1:]:
                    acc = _or_leaf(acc, x) if op == OR else jnp.maximum(acc, x)
                out.append(acc)
            else:  # REPLICATED
                out.append(leaves[0])
        return jax.tree_util.tree_unflatten(treedef, out)

    def merge_stacked(self, stacked, base: Any | None = None):
        """Merge a carry whose every leaf carries a leading lane axis
        (the vmap parallel backend's layout) in one reduction per field."""
        flat, treedef = jax.tree_util.tree_flatten(stacked)
        _check_ops(self.merge_ops, len(flat))
        base_flat = (jax.tree_util.tree_leaves(base)
                     if base is not None else None)
        out = []
        for i, op in enumerate(self.merge_ops):
            x = jnp.asarray(flat[i])
            if op == SUM:
                acc = jnp.sum(x, axis=0)
                if base_flat is not None:
                    b = jnp.asarray(base_flat[i])
                    acc = acc - (x.shape[0] - 1) * b.astype(acc.dtype)
                out.append(acc.astype(x.dtype))
            elif op == OR:
                out.append(jnp.any(x, axis=0) if x.dtype == jnp.bool_
                           else jnp.max(x, axis=0))
            elif op == MAX:
                out.append(jnp.max(x, axis=0))
            else:  # REPLICATED
                out.append(x[0])
        return jax.tree_util.tree_unflatten(treedef, out)

    def merge_collective(self, local, base, axis: str):
        """The shard_map form of :meth:`merge`: one collective per field
        (``psum`` of deltas for SUM, ``pmax`` for OR/MAX, base for
        REPLICATED), evaluated on every device of mesh axis ``axis``."""
        flat, treedef = jax.tree_util.tree_flatten(local)
        _check_ops(self.merge_ops, len(flat))
        base_flat = jax.tree_util.tree_leaves(base)
        out = []
        for i, op in enumerate(self.merge_ops):
            x = flat[i]
            if op == SUM:
                b = base_flat[i].astype(x.dtype)
                out.append(b + jax.lax.psum(x - b, axis))
            elif op in (OR, MAX):
                if x.dtype == jnp.bool_:
                    out.append(jax.lax.pmax(x.astype(jnp.int32), axis) > 0)
                else:
                    out.append(jax.lax.pmax(x, axis))
            else:  # REPLICATED
                out.append(base_flat[i])
        return jax.tree_util.tree_unflatten(treedef, out)


class FnCarry(PartitionerCarry):
    """Adapter: a bare ``(carry0, chunk_fn)`` pair as a PartitionerCarry.

    Wraps the legacy ``run_scan`` contract (``chunk_fn(carry, src, dst,
    *extras)``) so the engine has one driver code path.  No merge semantics
    are declared — sequential use only."""

    def __init__(self, carry0, chunk_fn: Callable):
        self._carry0 = carry0
        self._chunk_fn = chunk_fn

    def init(self):
        return self._carry0

    def step_chunk(self, carry, src, dst, n_valid, *extras):
        return self._chunk_fn(carry, src, dst, *extras)
