"""Out-of-core edge shards: ``write_shards`` + mmap-paged ``ShardedEdgeStream``.

Shard format
------------
A *shard directory* is a flat directory of fixed-record ``.npy`` files plus
one small JSON manifest::

    manifest.json            counts, dtypes, shard table (see below)
    shard_00000.src.npy      int32 (n,)  — readable via np.load(mmap_mode="r")
    shard_00000.dst.npy      int32 (n,)
    shard_00000.<field>.npy  optional per-edge payload fields (any dtype/shape)
    shard_00001.src.npy      ...

Every shard holds exactly ``shard_edges`` edges except the last.  The
manifest records ``{version, n_edges, n_vertices, shard_edges, fields,
shards}`` where ``fields`` is a list of ``{name, dtype, shape}`` and
``shards`` a list of ``{id, offset, n_edges, files}``.  Plain ``.npy``
means any tool can inspect a shard; fixed offsets mean arrival index →
(shard, row) is arithmetic.

Memory model
------------
:class:`ShardedEdgeStream` never materializes the edge list.  Shards are
memory-mapped and paged by the OS; the only *host allocations* the stream
makes are O(chunk_size) staging copies, O(shard_edges) reorder buffers and
an O(window) heap — all routed through a :class:`HostBudget` accounting
hook (``stream.budget.peak_bytes``) that tests assert against.

Orderings out of core
---------------------
- ``natural``   — contiguous mmap reads, shard by shard.
- ``windowed``  — the shared bounded-buffer emitter (``_windowed_emit``)
  runs once over the ``dst`` field shard-by-shard (O(window) heap) and
  spills the emitted order to a scratch ``.npy``; chunks then gather
  through that order mmap (accesses stay within ~``window`` of the cursor).
- ``shuffled``  — the permutation must be *bit-identical* to the in-memory
  engine's ``rng.permutation(E)``, so Fisher–Yates runs in place on a
  scratch **memmap** (identical RNG draw sequence, OS-paged storage), then
  a bucketed gather pass spills reordered edge shards to scratch.
- ``dst-sorted``— external merge sort: per-shard stable argsort runs are
  spilled to scratch, then k-way merged (ties broken by arrival index,
  which reproduces the global stable argsort exactly) and the reordered
  edge shards are spilled like the shuffled case.

After the (one-off, budget-bounded) reorder pass, ``shuffled`` and
``dst-sorted`` read contiguously from the spilled scratch shards; the
order mmap is kept for extras alignment and :meth:`scatter_back`.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import weakref
from contextlib import contextmanager
from heapq import merge as _heap_merge
from pathlib import Path

import numpy as np

from .stream import DEFAULT_CHUNK, ORDERINGS, EdgeStream, _windowed_emit

__all__ = ["HostBudget", "BudgetExceededError", "ShardedEdgeStream",
           "write_shards", "append_shards", "read_manifest",
           "DEFAULT_SHARD_EDGES", "MANIFEST_NAME"]

MANIFEST_NAME = "manifest.json"
MANIFEST_VERSION = 1
DEFAULT_SHARD_EDGES = 1 << 20


# ---------------------------------------------------------------------------
# byte-budget accounting
# ---------------------------------------------------------------------------


class BudgetExceededError(MemoryError):
    """A :class:`HostBudget` charge would push residency past its hard cap."""

    def __init__(self, requested: int, current: int, limit: int):
        self.requested = int(requested)
        self.current = int(current)
        self.limit = int(limit)
        super().__init__(
            f"host budget exceeded: charging {requested} bytes at "
            f"{current} resident would pass the {limit}-byte limit")


class HostBudget:
    """Accounting hook for host allocations made *by the stream*.

    mmap-backed views are charged nothing (the OS pages and evicts them);
    every real ndarray the stream allocates — chunk staging copies, reorder
    block buffers, gather outputs — is charged while live.  ``peak_bytes``
    is what the bounded-memory tests assert against.

    ``limit_bytes`` turns the observer into an enforcer: a :meth:`charge`
    that would push ``current_bytes`` past the limit raises
    :class:`BudgetExceededError` *before* mutating any counter, so the
    hybrid planner's residency promise is a hard cap, not a report.  The
    default (``None``) keeps the original unlimited-observe behavior
    bit-for-bit.
    """

    def __init__(self, limit_bytes: int | None = None) -> None:
        if limit_bytes is not None and int(limit_bytes) < 0:
            raise ValueError(f"limit_bytes must be >= 0, got {limit_bytes}")
        self.limit_bytes = None if limit_bytes is None else int(limit_bytes)
        self.current_bytes = 0
        self.peak_bytes = 0

    def charge(self, nbytes: int) -> None:
        nbytes = int(nbytes)
        if (self.limit_bytes is not None
                and self.current_bytes + nbytes > self.limit_bytes):
            raise BudgetExceededError(nbytes, self.current_bytes,
                                      self.limit_bytes)
        self.current_bytes += nbytes
        if self.current_bytes > self.peak_bytes:
            self.peak_bytes = self.current_bytes

    def release(self, nbytes: int) -> None:
        self.current_bytes -= int(nbytes)

    @contextmanager
    def scoped(self, nbytes: int):
        self.charge(nbytes)
        try:
            yield
        finally:
            self.release(nbytes)


# ---------------------------------------------------------------------------
# shard writer + manifest
# ---------------------------------------------------------------------------


def write_shards(
    out_dir,
    src,
    dst,
    *extras,
    shard_edges: int = DEFAULT_SHARD_EDGES,
    n_vertices: int | None = None,
    field_names=None,
) -> Path:
    """Write ``src``/``dst`` (+ optional per-edge ``extras``) as edge shards.

    Returns the path of the written ``manifest.json``.  ``extras`` keep
    their dtype and trailing shape; ``field_names`` names them in the
    manifest (default ``x0, x1, ...``).
    """
    if shard_edges < 1:
        raise ValueError("shard_edges must be >= 1")
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    src = np.ascontiguousarray(src, np.int32)
    dst = np.ascontiguousarray(dst, np.int32)
    if src.ndim != 1 or src.shape != dst.shape:
        raise ValueError("src/dst must be equal-length 1-D arrays")
    ex = [np.ascontiguousarray(e) for e in extras]
    for e in ex:
        if e.shape[:1] != src.shape:
            raise ValueError("extra array length != n_edges")
    names = list(field_names) if field_names is not None else [
        f"x{i}" for i in range(len(ex))
    ]
    if len(names) != len(ex):
        raise ValueError("field_names length != number of extras")
    fields = ["src", "dst", *names]
    if len(set(fields)) != len(fields):
        raise ValueError(f"duplicate field names in {fields}")
    n = int(src.shape[0])
    if n_vertices is None:
        n_vertices = int(max(src.max(), dst.max())) + 1 if n else 0
    arrays = [src, dst, *ex]
    shard_rows = []
    for sid, lo in enumerate(range(0, n, shard_edges)):
        hi = min(lo + shard_edges, n)
        files = {}
        for name, arr in zip(fields, arrays):
            fname = f"shard_{sid:05d}.{name}.npy"
            np.save(out / fname, arr[lo:hi])
            files[name] = fname
        shard_rows.append({"id": sid, "offset": lo, "n_edges": hi - lo,
                           "files": files})
    manifest = {
        "version": MANIFEST_VERSION,
        "format": "s5p-edge-shards",
        "n_edges": n,
        "n_vertices": int(n_vertices),
        "shard_edges": int(shard_edges),
        "fields": [
            {"name": name, "dtype": str(arr.dtype), "shape": list(arr.shape[1:])}
            for name, arr in zip(fields, arrays)
        ],
        "shards": shard_rows,
    }
    mpath = out / MANIFEST_NAME
    mpath.write_text(json.dumps(manifest, indent=1))
    return mpath


def append_shards(manifest, src, dst, *extras) -> Path:
    """Grow an existing shard directory in place with an insertion batch.

    Bit-parity contract: ``append(prefix); append(delta)`` leaves a shard
    directory whose streamed chunks are identical to a single
    ``write_shards(prefix + delta)`` — the partial tail shard is topped up
    to exactly ``shard_edges`` before new shards are laid down, so shard
    boundaries (and therefore every mmap page and chunk) match the
    one-shot layout.  Extras must match the manifest's field list (name
    order, dtype, trailing shape).

    Commit order is crash-safe: tail-shard files are replaced first (their
    committed prefix rows are byte-identical, and the old manifest never
    points past them), new shard files next, the manifest last via
    tmp + ``os.replace``.  Appending while a :class:`ShardedEdgeStream`
    is live on the same manifest is not supported — reopen after growing.

    Returns the manifest path.
    """
    mpath, meta = read_manifest(manifest)
    root = mpath.parent
    src = np.ascontiguousarray(src, np.int32)
    dst = np.ascontiguousarray(dst, np.int32)
    if src.ndim != 1 or src.shape != dst.shape:
        raise ValueError("src/dst must be equal-length 1-D arrays")
    ex = [np.ascontiguousarray(e) for e in extras]
    fields = meta["fields"]
    if len(ex) != len(fields) - 2:
        raise ValueError(
            f"manifest has {len(fields) - 2} extra fields, got {len(ex)}")
    arrays = [src, dst, *ex]
    for f, arr in zip(fields, arrays):
        if arr.shape[:1] != src.shape:
            raise ValueError("extra array length != n_edges")
        if str(arr.dtype) != f["dtype"] or list(arr.shape[1:]) != f["shape"]:
            raise ValueError(
                f"field {f['name']!r} expects dtype {f['dtype']} shape "
                f"{f['shape']}, got {arr.dtype} {list(arr.shape[1:])}")
    names = [f["name"] for f in fields]
    se = int(meta["shard_edges"])
    n_new = int(src.shape[0])
    shard_rows = list(meta["shards"])

    consumed = 0
    if n_new and shard_rows and shard_rows[-1]["n_edges"] < se:
        tail = dict(shard_rows[-1])
        take = min(se - tail["n_edges"], n_new)
        for name, arr in zip(names, arrays):
            fpath = root / tail["files"][name]
            # slice to the manifest-recorded length: after a crash in the
            # window between tail replacement and manifest commit, the
            # file holds extra (uncommitted) rows that must not survive
            # into a retried append
            old = np.load(fpath)[: tail["n_edges"]]
            combined = np.concatenate([old, arr[:take]])
            tmp = fpath.with_name("tmp-" + fpath.name)  # keep the .npy suffix
            np.save(tmp, combined)                      # (np.save appends it)
            os.replace(tmp, fpath)
        tail["n_edges"] += take
        shard_rows[-1] = tail
        consumed = take
    next_off = (shard_rows[-1]["offset"] + shard_rows[-1]["n_edges"]
                if shard_rows else 0)
    sid = len(shard_rows)
    for lo in range(consumed, n_new, se):
        hi = min(lo + se, n_new)
        files = {}
        for name, arr in zip(names, arrays):
            fname = f"shard_{sid:05d}.{name}.npy"
            np.save(root / fname, arr[lo:hi])
            files[name] = fname
        shard_rows.append({"id": sid, "offset": next_off, "n_edges": hi - lo,
                           "files": files})
        next_off += hi - lo
        sid += 1

    n_vertices = int(meta["n_vertices"])
    if n_new:
        n_vertices = max(n_vertices, int(max(src.max(), dst.max())) + 1)
    meta = dict(meta, n_edges=int(meta["n_edges"]) + n_new,
                n_vertices=n_vertices, shards=shard_rows)
    tmp = mpath.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(meta, indent=1))
    os.replace(tmp, mpath)
    return mpath


def read_manifest(path) -> tuple[Path, dict]:
    """Resolve a manifest path (file or shard directory) and load it."""
    p = Path(path)
    if p.is_dir():
        p = p / MANIFEST_NAME
    meta = json.loads(p.read_text())
    version = meta.get("version")
    if version != MANIFEST_VERSION:
        raise ValueError(f"unsupported shard manifest version {version!r}")
    return p, meta


class _Shard:
    """One on-disk shard: lazily opened mmaps per field."""

    __slots__ = ("offset", "n", "root", "files", "_mm")

    def __init__(self, root: Path, offset: int, n: int, files: dict):
        self.root = root
        self.offset = int(offset)
        self.n = int(n)
        self.files = files
        self._mm: dict = {}

    def mm(self, field: str) -> np.ndarray:
        m = self._mm.get(field)
        if m is None:
            m = np.load(self.root / self.files[field], mmap_mode="r")
            self._mm[field] = m
        return m

    def close(self) -> None:
        self._mm.clear()


class _FieldView:
    """Array-like over one manifest field: mmap-paged, never materialized.

    Supports ``len``/``.shape`` and slice or fancy indexing (returning
    ndarray copies of just the requested rows), which is exactly the
    surface :meth:`EdgeStream.chunk_at` needs from an extras array — so
    stored extra fields ride through ``chunks()`` out-of-core too.
    """

    def __init__(self, stream: "ShardedEdgeStream", shards, field: str,
                 dtype, shape: tuple):
        self._stream = stream
        self._shards = shards
        self._field = field
        self.dtype = np.dtype(dtype)
        self.shape = shape
        self._staged = 0  # bytes of the last returned rows, still live

    def __len__(self) -> int:
        return self.shape[0]

    def _stage(self, rows: np.ndarray) -> np.ndarray:
        # same accounting pattern as the stream's chunk staging: the
        # previous read is dead once the next one is built
        budget = self._stream.budget
        budget.release(self._staged)
        self._staged = int(rows.nbytes)
        budget.charge(self._staged)
        return rows

    def __getitem__(self, sl):
        if isinstance(sl, slice):
            start, stop, step = sl.indices(self.shape[0])
            if step != 1:
                raise IndexError("field views support unit-stride slices only")
            return self._stage(self._stream._read_range(
                self._shards, self._field, start, stop))
        return self._stage(self._stream._gather(
            self._shards, self._field, np.asarray(sl, np.int64)))


# ---------------------------------------------------------------------------
# the stream
# ---------------------------------------------------------------------------


class ShardedEdgeStream(EdgeStream):
    """Out-of-core :class:`EdgeStream` over a shard directory.

    Same ``chunks()`` / ``chunk_at()`` / ``scatter_back()`` contract —
    consumers (``run_scan``, ``cluster_stream``, the Θ pass,
    ``assign_edges_stream``, every baseline scan) run unchanged; only the
    data access differs (mmap paging instead of host-resident arrays,
    see module docstring for the per-ordering strategy).

    ``scratch_dir`` receives reorder spills (order ``.npy`` + reordered
    shards); a private temp dir (removed on GC/:meth:`close`) is used when
    not given.  Spill names are keyed by (ordering, seed, window), so give
    each *concurrently live* stream its own scratch dir — rebuilding a
    spec truncates files another stream of the same spec may still map.
    ``budget`` is the :class:`HostBudget` accounting hook.
    """

    def __init__(
        self,
        manifest,
        *,
        chunk_size: int = DEFAULT_CHUNK,
        ordering: str = "natural",
        seed: int = 0,
        window: int = 4096,
        scratch_dir=None,
        budget: HostBudget | None = None,
    ):
        # deliberately no super().__init__ — storage is mmap shards, and the
        # base ctor's array fields are exactly what this class must not hold
        if ordering not in ORDERINGS:
            raise ValueError(f"unknown ordering {ordering!r}; one of {ORDERINGS}")
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        if window < 1:
            raise ValueError("window must be >= 1")
        self.manifest_path, self._meta = read_manifest(manifest)
        self.root = self.manifest_path.parent
        self._n_edges = int(self._meta["n_edges"])
        self.n_vertices = int(self._meta["n_vertices"])
        self.shard_edges = int(self._meta["shard_edges"])
        self._fields = {f["name"]: f for f in self._meta["fields"]}
        self._shards = [
            _Shard(self.root, s["offset"], s["n_edges"], s["files"])
            for s in self._meta["shards"]
        ]
        self.chunk_size = int(chunk_size)
        self.ordering = ordering
        self.seed = int(seed)
        self.window = int(window)
        self.budget = budget if budget is not None else HostBudget()
        # reorder block size: buffers stay O(shard_edges + chunk_size)
        self._block = max(min(self.shard_edges, 1 << 16), self.chunk_size, 1024)
        self._staged = 0  # bytes of the currently live chunk staging copy
        self._respilled: list[_Shard] | None = None
        self._scratch = Path(scratch_dir) if scratch_dir is not None else None
        self._finalizer = None
        if self._scratch is not None:
            self._scratch.mkdir(parents=True, exist_ok=True)
        self._order = self._make_order()

    # -------------------------------------------------------------- misc
    @property
    def n_edges(self) -> int:
        return self._n_edges

    @property
    def field_names(self) -> tuple:
        return tuple(self._fields)

    @property
    def src(self):
        raise AttributeError(
            "ShardedEdgeStream holds no host-resident edge arrays; page via "
            "chunks()/chunk_at(), or materialize explicitly with "
            "arrival_arrays()")

    dst = src

    def open_field(self, name: str) -> _FieldView:
        """Mmap-paged view of a stored per-edge field (for ``chunks(*extras)``)."""
        f = self._fields[name]
        return _FieldView(self, self._shards, name, f["dtype"],
                          (self._n_edges, *f["shape"]))

    def arrival_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """Materialize (src, dst) in arrival order — O(E) host memory, for
        metrics/converters only; the streaming read path never calls this."""
        return (self._read_range(self._shards, "src", 0, self._n_edges),
                self._read_range(self._shards, "dst", 0, self._n_edges))

    def close(self) -> None:
        for sh in self._shards:
            sh.close()
        if self._respilled:
            for sh in self._respilled:
                sh.close()
        if self._finalizer is not None:
            self._finalizer()
            self._finalizer = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ----------------------------------------------------------- scratch
    def _scratch_path(self, name: str) -> Path:
        if self._scratch is None:
            self._scratch = Path(tempfile.mkdtemp(prefix="oocstream-"))
            self._finalizer = weakref.finalize(
                self, shutil.rmtree, str(self._scratch), ignore_errors=True)
        return self._scratch / name

    @property
    def _tag(self) -> str:
        return f"{self.ordering}-s{self.seed}-w{self.window}"

    # --------------------------------------------------------- raw reads
    def _read_range(self, shards, field: str, start: int, stop: int) -> np.ndarray:
        """Contiguous rows [start, stop) across a shard list.  A
        single-shard read returns a zero-copy mmap view; budget charging
        is the caller's job (the rows outlive this call)."""
        if stop <= start:
            f = self._fields.get(field)
            shape = (0, *(f["shape"] if f else ()))
            return np.empty(shape, f["dtype"] if f else np.int32)
        parts = []
        for sh in shards:
            lo = max(start - sh.offset, 0)
            hi = min(stop - sh.offset, sh.n)
            if lo < hi:
                parts.append(sh.mm(field)[lo:hi])
        if len(parts) == 1:
            return parts[0]  # mmap view — paged, not a host allocation
        out = np.concatenate(parts)
        return out

    def _gather(self, shards, field: str, idx: np.ndarray) -> np.ndarray:
        """Rows at arbitrary arrival indices (grouped per shard)."""
        first = shards[0].mm(field) if shards else None
        dt = first.dtype if first is not None else np.int32
        trail = first.shape[1:] if first is not None else ()
        out = np.empty((idx.shape[0], *trail), dt)
        with self.budget.scoped(idx.nbytes):  # mask/offset scratch bound
            for sh in shards:
                m = (idx >= sh.offset) & (idx < sh.offset + sh.n)
                if m.any():
                    out[m] = sh.mm(field)[idx[m] - sh.offset]
        return out

    def _iter_field(self, field: str):
        """Python-int iterator over a field, block-buffered per shard."""
        for sh in self._shards:
            mm = sh.mm(field)
            for lo in range(0, sh.n, self._block):
                blk = np.asarray(mm[lo:lo + self._block])
                with self.budget.scoped(blk.nbytes):
                    yield from blk.tolist()

    # ----------------------------------------------------- order building
    def _make_order(self):
        if self.ordering == "natural":
            return None
        if self._n_edges == 0:
            return np.empty(0, np.int64)
        opath = self._scratch_path(f"order-{self._tag}.npy")
        if self.ordering == "shuffled":
            self._build_shuffled_order(opath)
        elif self.ordering == "dst-sorted":
            self._build_dst_sorted_order(opath)
        else:
            self._build_windowed_order(opath)
        order = np.load(opath, mmap_mode="r")
        if self.ordering in ("shuffled", "dst-sorted"):
            self._respilled = self._spill_reordered(order)
        return order

    def _build_shuffled_order(self, opath: Path) -> None:
        """Bit-parity shuffle: ``Generator.permutation(E)`` is arange +
        in-place Fisher–Yates, and the draw sequence depends only on E —
        so running ``rng.shuffle`` on a scratch *memmap* yields the exact
        permutation of the in-memory engine with OS-paged storage."""
        E = self._n_edges
        perm = np.lib.format.open_memmap(opath, mode="w+", dtype=np.int64,
                                         shape=(E,))
        with self.budget.scoped(self._block * 8):
            for lo in range(0, E, self._block):
                hi = min(lo + self._block, E)
                perm[lo:hi] = np.arange(lo, hi, dtype=np.int64)
        np.random.default_rng(self.seed).shuffle(perm)
        perm.flush()
        del perm

    def _build_dst_sorted_order(self, opath: Path) -> None:
        """External stable merge sort by dst.  Per-shard stable argsort
        runs + a k-way merge tie-broken on arrival index reproduce
        ``np.argsort(dst, kind="stable")`` exactly (stable order is
        unique), with O(shard_edges) peak buffers."""
        runs = []
        for sh in self._shards:
            d = np.asarray(sh.mm("dst"))
            with self.budget.scoped(d.nbytes * 4):  # d + argsort + key + idx
                loc = np.argsort(d, kind="stable")
                kpath = self._scratch_path(f"run-{sh.offset}.key.npy")
                ipath = self._scratch_path(f"run-{sh.offset}.idx.npy")
                np.save(kpath, d[loc])
                np.save(ipath, loc.astype(np.int64) + sh.offset)
            runs.append((kpath, ipath))
        del d, loc

        block = max(256, min(self._block,
                             -(-self._block // max(len(runs), 1))))

        def run_iter(kpath, ipath):
            key = np.load(kpath, mmap_mode="r")
            idx = np.load(ipath, mmap_mode="r")
            for lo in range(0, key.shape[0], block):
                kb = np.asarray(key[lo:lo + block])
                ib = np.asarray(idx[lo:lo + block])
                with self.budget.scoped(kb.nbytes + ib.nbytes):
                    yield from zip(kb.tolist(), ib.tolist())

        out = np.lib.format.open_memmap(opath, mode="w+", dtype=np.int64,
                                        shape=(self._n_edges,))
        buf = np.empty(self._block, np.int64)
        with self.budget.scoped(buf.nbytes):
            j = 0
            pos = 0
            for _, arrival in _heap_merge(*(run_iter(k, i) for k, i in runs)):
                buf[j] = arrival
                j += 1
                if j == buf.shape[0]:
                    out[pos:pos + j] = buf
                    pos += j
                    j = 0
            if j:
                out[pos:pos + j] = buf[:j]
        out.flush()
        del out
        for kpath, ipath in runs:
            kpath.unlink()
            ipath.unlink()

    def _build_windowed_order(self, opath: Path) -> None:
        """One bounded-buffer pass of the shared emitter over the dst field
        (shard by shard); emitted arrival indices spill blockwise."""
        out = np.lib.format.open_memmap(opath, mode="w+", dtype=np.int64,
                                        shape=(self._n_edges,))
        buf = np.empty(self._block, np.int64)
        # the emitter's heap holds <= window+1 (dst, index) int pairs
        with self.budget.scoped(buf.nbytes + (self.window + 1) * 64):
            j = 0
            pos = 0
            for arrival in _windowed_emit(self._iter_field("dst"), self.window):
                buf[j] = arrival
                j += 1
                if j == buf.shape[0]:
                    out[pos:pos + j] = buf
                    pos += j
                    j = 0
            if j:
                out[pos:pos + j] = buf[:j]
        out.flush()
        del out

    def _spill_reordered(self, order) -> list[_Shard]:
        """Bucketed gather pass: rewrite src/dst in stream order as scratch
        shards of ``shard_edges`` edges, so the read path is contiguous."""
        spilled = []
        se = self.shard_edges
        for sid, lo in enumerate(range(0, self._n_edges, se)):
            hi = min(lo + se, self._n_edges)
            idx = np.asarray(order[lo:hi])
            with self.budget.scoped(idx.nbytes):
                files = {}
                for field in ("src", "dst"):
                    rows = self._gather(self._shards, field, idx)
                    with self.budget.scoped(rows.nbytes):
                        fname = f"spill-{self._tag}-{sid:05d}.{field}.npy"
                        np.save(self._scratch_path(fname), rows)
                    files[field] = fname
            spilled.append(_Shard(self._scratch, lo, hi - lo, files))
        return spilled

    # ----------------------------------------------------------- read path
    def scatter_back(self, values):
        """Map per-edge results from stream order back to arrival order.

        ``values`` and the returned array are *result-sized* (the caller's
        O(E) output — the same class of allocation as ``run_scan``'s
        concatenated parts, unavoidable at this API); unlike the base
        implementation, no O(E) inverse-permutation array is built — the
        scatter walks the order mmap in O(block) charged slices, so the
        stream adds only bounded host memory on top of the result."""
        if self._order is None:
            return values
        import jax.numpy as jnp

        vals = np.asarray(values)
        out = np.empty_like(vals)
        for lo in range(0, self._n_edges, self._block):
            idx = np.asarray(self._order[lo:lo + self._block])
            with self.budget.scoped(idx.nbytes):
                out[..., idx] = vals[..., lo:lo + idx.shape[0]]
        return jnp.asarray(out)

    def _edges_at(self, sl, start: int, stop: int):
        # previous chunk's staging copy is dead once the next one is built
        self.budget.release(self._staged)
        self._staged = 0
        if isinstance(sl, slice):
            s = self._read_range(self._shards, "src", start, stop)
            d = self._read_range(self._shards, "dst", start, stop)
        elif self._respilled is not None:
            s = self._read_range(self._respilled, "src", start, stop)
            d = self._read_range(self._respilled, "dst", start, stop)
        else:  # windowed: gather through the order mmap (near-local)
            s = self._gather(self._shards, "src", sl)
            d = self._gather(self._shards, "dst", sl)
        # charge conservatively even when the reads were zero-copy views
        self._staged = int(s.nbytes + d.nbytes)
        self.budget.charge(self._staged)
        return s, d
