"""EdgeStream — chunked, replayable edge streams with pluggable orderings.

The stream owns host-resident edge arrays; the device only ever sees one
fixed-size chunk (padded with self-loops, which every consumer already
masks as no-ops).  Replay is free: ``chunks()`` is a generator over the
same deterministic order every time it is called, so the multi-pass
structure of the paper's pipeline (clustering pass → Θ pass → placement
pass) is three replays of one stream object.

Orderings (``ordering=``):

- ``"natural"``   — arrival order as given (the paper's setting);
- ``"shuffled"``  — a seeded global permutation (stream-order robustness);
- ``"dst-sorted"``— stable sort by destination (CSR-ish locality; the
  order the segment_agg kernel's data pipeline emits);
- ``"windowed"``  — bounded-buffer reordering: a sliding window of
  ``window`` edges from which the lowest-destination edge is emitted
  first (Patwary et al. 2019-style window streaming — locality gains
  without breaking the bounded-memory contract).
"""

from __future__ import annotations

import heapq
from typing import Iterator, NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = ["Chunk", "EdgeStream", "ORDERINGS"]

ORDERINGS = ("natural", "shuffled", "dst-sorted", "windowed")

DEFAULT_CHUNK = 1 << 16


class Chunk(NamedTuple):
    """One device-resident slice of the stream.

    Padding entries (tail chunk only) are (0, 0) self-loops with zeroed
    extras — the masked no-op every scan consumer already skips.
    """

    src: jnp.ndarray  # (B,) int32
    dst: jnp.ndarray  # (B,) int32
    extras: tuple  # per-edge arrays sliced in the same order
    start: int  # offset of this chunk in stream order
    n_valid: int  # true (unpadded) edge count, ≤ B


def _windowed_emit(dst_iter, window: int) -> Iterator[int]:
    """Sliding-buffer reorder: emit the buffered edge with the smallest
    destination first.  Deterministic; the buffer never holds more than
    ``window`` edges (bounded memory), so no edge is emitted more than
    ``window`` slots *before* its arrival position.  Departure can be
    late without bound — a high-destination edge sits until the drain.

    Shared by the in-memory and the out-of-core engines (the latter feeds
    ``dst`` shard-by-shard), so the two orders agree by construction.
    """
    heap: list[tuple[int, int]] = []
    for i, d in enumerate(dst_iter):
        heapq.heappush(heap, (int(d), i))
        if len(heap) > window:
            yield heapq.heappop(heap)[1]
    while heap:
        yield heapq.heappop(heap)[1]


def _windowed_order(dst: np.ndarray, window: int) -> np.ndarray:
    n = dst.shape[0]
    return np.fromiter(_windowed_emit(dst, window), np.int64, count=n)


class EdgeStream:
    """Chunked multi-pass view over an edge list (bounded device memory)."""

    def __init__(
        self,
        src,
        dst,
        n_vertices: int | None = None,
        *,
        chunk_size: int = DEFAULT_CHUNK,
        ordering: str = "natural",
        seed: int = 0,
        window: int = 4096,
    ):
        if ordering not in ORDERINGS:
            raise ValueError(f"unknown ordering {ordering!r}; one of {ORDERINGS}")
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        if window < 1:
            raise ValueError("window must be >= 1")
        self.src = np.asarray(src, np.int32)
        self.dst = np.asarray(dst, np.int32)
        if self.src.shape != self.dst.shape:
            raise ValueError("src/dst shape mismatch")
        if n_vertices is None:  # metadata only — infer when not supplied
            n_vertices = int(max(self.src.max(), self.dst.max())) + 1 if self.src.size else 0
        self.n_vertices = int(n_vertices)
        self.chunk_size = int(chunk_size)
        self.ordering = ordering
        self.seed = int(seed)
        self.window = int(window)
        self._order = self._make_order()

    # ------------------------------------------------------------------
    def _make_order(self) -> np.ndarray | None:
        if self.ordering == "natural":
            return None
        if self.ordering == "shuffled":
            return np.random.default_rng(self.seed).permutation(self.n_edges)
        if self.ordering == "dst-sorted":
            return np.argsort(self.dst, kind="stable")
        return _windowed_order(self.dst, self.window)

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def __len__(self) -> int:
        return self.n_edges

    @property
    def n_chunks(self) -> int:
        return max(-(-self.n_edges // self.chunk_size), 1)

    @property
    def order(self) -> np.ndarray | None:
        """Stream order as a permutation of arrival indices (None = identity)."""
        return self._order

    # ------------------------------------------------------------------
    def _edges_at(self, sl, start: int, stop: int):
        """Data-access hook: edges for stream positions [start, stop).

        ``sl`` is a ``slice`` (natural order) or an int array of arrival
        indices (permuted orders); out-of-core subclasses override this to
        page from disk — everything else in :meth:`chunk_at` (padding,
        extras, dtypes) is shared, which is what makes the engines
        bit-identical.
        """
        return self.src[sl], self.dst[sl]

    def chunk_at(self, i: int, *extras, pad: bool = True) -> Chunk:
        """Build chunk ``i`` on demand — O(chunk) host/device footprint.

        ``extras`` are per-edge arrays sliced/permuted alongside src/dst
        (padded with zeros).  With ``pad=True`` every chunk of a multi-chunk
        stream has exactly ``chunk_size`` entries so one compiled scan step
        serves all chunks; a single-chunk stream comes back unpadded.
        """
        if not 0 <= i < self.n_chunks:
            raise IndexError(f"chunk {i} out of range [0, {self.n_chunks})")
        # anything already exposing .shape/__getitem__ (ndarray, memmap, an
        # out-of-core field view) passes through unmaterialized
        ex = [e if hasattr(e, "shape") else np.asarray(e) for e in extras]
        for e in ex:
            if e.shape[0] != self.n_edges:
                raise ValueError("extra array length != n_edges")
        n, cs = self.n_edges, self.chunk_size
        start = i * cs
        stop = min(start + cs, n)
        if self._order is None:
            sl = slice(start, stop)
        else:
            sl = np.asarray(self._order[start:stop])
        s, d = self._edges_at(sl, start, stop)
        exc = [e[sl] for e in ex]
        if pad and s.shape[0] < cs and start > 0:
            padn = cs - s.shape[0]
            s = np.concatenate([s, np.zeros(padn, np.int32)])
            d = np.concatenate([d, np.zeros(padn, np.int32)])
            exc = [
                np.concatenate([e, np.zeros((padn,) + e.shape[1:], e.dtype)])
                for e in exc
            ]
        return Chunk(
            src=jnp.asarray(s),
            dst=jnp.asarray(d),
            extras=tuple(jnp.asarray(e) for e in exc),
            start=start,
            n_valid=stop - start,
        )

    def chunks(self, *extras, pad: bool = True) -> Iterator[Chunk]:
        """Yield the stream as fixed-size chunks (a fresh replay per call);
        only one chunk is device-resident at a time — see :meth:`chunk_at`.
        """
        for i in range(self.n_chunks):
            yield self.chunk_at(i, *extras, pad=pad)

    # ------------------------------------------------------------------
    def scatter_back(self, values):
        """Map per-edge results from stream order back to arrival order.

        Works on (E,) or batched (..., E) arrays (last axis = edges).
        """
        if self._order is None:
            return values
        order = np.asarray(self._order)  # mmap-backed orders view in cheaply
        inv = np.empty(order.size, order.dtype)
        inv[order] = np.arange(order.size)
        return jnp.take(values, jnp.asarray(inv), axis=-1)
