"""Scan drivers: thread an O(|V|+k) carry through EdgeStream chunks.

A *chunk function* has signature ``(carry, src, dst, *extras) -> (carry,
parts)`` and is jitted by its author (module-level, so the compile cache is
shared across every call with the same chunk shape — the engine never
recompiles per invocation).  ``repro.kernels.stream_scan.ref`` hosts the
chunk functions for the scoring baselines; ``cluster_chunk`` and
``_assign_chunk`` are the other two consumers.

``run_scan_batched`` vmaps one compiled chunk function over a stacked
carry: many seeds, many HDRF λ values, or many (padded) partition counts
run as one batched engine over a single pass of the stream.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .stream import EdgeStream

__all__ = ["run_scan", "run_scan_batched"]


def run_scan(
    stream: EdgeStream,
    carry,
    chunk_fn: Callable,
    *extras,
):
    """Drive ``chunk_fn`` over every chunk; returns (parts, final_carry).

    ``parts`` is in arrival order (stream-order results are scattered back
    through the stream's permutation).
    """
    outs = []
    for ch in stream.chunks(*extras):
        carry, parts = chunk_fn(carry, ch.src, ch.dst, *ch.extras)
        outs.append(parts[: ch.n_valid])
    parts = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
    return stream.scatter_back(parts), carry


def run_scan_batched(
    stream: EdgeStream,
    carries,
    chunk_fn: Callable,
    *extras,
):
    """Batched ``run_scan``: ``carries`` is a pytree with a leading batch
    axis (one entry per scenario — seed, λ, padded-k mask, …).  The chunk
    function is vmapped over the carry only; the stream is read once and
    broadcast.  Returns (parts (B, E), final carries)."""
    n_extra = len(extras)
    vfn = jax.vmap(chunk_fn, in_axes=(0, None, None) + (None,) * n_extra)
    outs = []
    for ch in stream.chunks(*extras):
        carries, parts = vfn(carries, ch.src, ch.dst, *ch.extras)
        outs.append(parts[..., : ch.n_valid])
    parts = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
    return stream.scatter_back(parts), carries
